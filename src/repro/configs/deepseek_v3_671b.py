"""DeepSeek-V3 671B [arXiv:2412.19437; hf-verified].

Spec: 61L d_model=7168 128H d_ff=2048(expert) vocab=129280, MLA,
1 shared + 256 routed experts top-8.  MLA dims and the 3 leading dense
layers (d_ff 18432) follow the published config.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280,
    attention="mla", rope_theta=1e4,
    q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128,
    n_experts=256, top_k=8, moe_d_ff=2048, n_shared_experts=1,
    first_dense_layers=3,
    tp_profile="tp", tie_embeddings=False,
)
