"""The paper's own configuration: distributed SHT for CMB-scale problems.

Shapes (paper §5 and the target-application sizes):
  * synth_2k_k8   -- l_max=2048,  K=8   (Monte-Carlo batch, GL grid)
  * synth_4k_k1   -- l_max=4096,  K=1   (paper's headline single-map size)
  * anal_4k_k4    -- l_max=4096,  K=4, direct transform
  * synth_8k_k4   -- l_max=8192,  K=4   (Planck-scale)
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class SHTConfig:
    name: str
    l_max: int
    K: int
    direction: str = "synth"     # synth | anal
    grid: str = "gl"
    fold: bool = False           # paper-faithful baseline: fold off
    comm_dtype: str | None = None
    dtype: str = "float32"


CONFIG = SHTConfig(name="sht_cmb", l_max=4096, K=1)

SHT_SHAPES = {
    "synth_2k_k8": SHTConfig("sht_cmb", 2048, 8, "synth"),
    "synth_4k_k1": SHTConfig("sht_cmb", 4096, 1, "synth"),
    "anal_4k_k4": SHTConfig("sht_cmb", 4096, 4, "anal"),
    "synth_8k_k4": SHTConfig("sht_cmb", 8192, 4, "synth"),
}
