"""xLSTM-125M [arXiv:2405.04517; spec-literal].

Spec: 12L d_model=768 4H d_ff=0 vocab=50304; alternating sLSTM + mLSTM
blocks (1:1).  O(1) decode state => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    attention="none", block_pattern=("mlstm", "slstm"),
    mlstm_pf=2.0,
    tp_profile="small", long_context_ok=True,
)
