"""Kimi K2 -- trillion-param MoE [arXiv:2501.kimi2; spec-literal].

Spec: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840,
MoE 384 experts top-8.  All layers MoE per the assignment table.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    attention="gqa", rope_theta=5e4,
    n_experts=384, top_k=8, moe_d_ff=2048, n_shared_experts=0,
    first_dense_layers=0,
    tp_profile="tp", tie_embeddings=False,
)
