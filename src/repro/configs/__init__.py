# One module per assigned architecture (exact public-literature configs)
# plus the paper's own SHT configuration.  See registry.py.
