"""H2O Danube3-4B [arXiv:2401.16818; spec-literal].

Spec: 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention (window 4096).
SWA => sub-quadratic decode => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, head_dim=120,
    attention="gqa", sliding_window=4096, rope_theta=1e4,
    tp_profile="tp", long_context_ok=True,
)
