"""RecurrentGemma-9B [arXiv:2402.19427; spec-literal].

Spec: 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000;
RG-LRU + local attention 1:2 (pattern: rglru, rglru, local window 2048).
Bounded state => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    attention="gqa", block_pattern=("rglru", "rglru", "local"),
    lru_width=4096, local_window=2048,
    tp_profile="tp", long_context_ok=True,
)
