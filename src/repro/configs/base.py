"""Architecture & run configuration dataclasses + the assigned shape set."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # attention
    attention: str = "gqa"         # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1e4

    # MLA (DeepSeek-family)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "a2a"           # a2a (seq-split dispatch) | replicated

    # recurrent / hybrid
    block_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("rglru","rglru","local")
    lru_width: Optional[int] = None
    local_window: int = 2048
    mlstm_pf: float = 2.0

    # encoder-decoder / multimodal
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: Optional[str] = None  # audio_stub | vision_stub
    n_vision_tokens: int = 256      # stub patch embeddings per sample (vlm)

    # norms / activations / embeddings
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = True

    # dtypes & sharding
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    tp_profile: str = "tp"          # tp | small  (DESIGN.md §6)
    long_context_ok: bool = False   # may run the long_500k cell
    remat: bool = True

    # accounting-lowering knobs (roofline correction for while-loop
    # trip-count undercounting in XLA cost analysis; see launch/dryrun.py)
    attn_impl: str = "mea"          # mea | dense
    loss_chunks: int = 8
    scan_unroll: bool = False       # unroll the layer scan
    inner_unroll: bool = False      # unroll block-internal chunk scans

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (reported in EXPERIMENTS.md)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "gqa":
            per_layer += d * self.n_heads * self.hd * 2          # q, o
            per_layer += d * self.n_kv_heads * self.hd * 2       # k, v
        elif self.attention == "mla":
            per_layer += d * self.q_lora_rank
            per_layer += self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * d
        if self.n_experts:
            moe = 3 * self.moe_d_ff * d * (self.n_experts
                                           + self.n_shared_experts)
            dense = 3 * self.d_ff * d
            per_layer += moe  # dominated by experts
            total = emb + (L - self.first_dense_layers) * per_layer \
                + self.first_dense_layers * (per_layer - moe + dense)
            return total
        if self.d_ff:
            per_layer += 3 * d * self.d_ff if self.act == "swiglu" \
                else 2 * d * self.d_ff
        if self.block_pattern and "mlstm" in self.block_pattern:
            per_layer = 0  # handled coarsely below
            di = int(d * self.mlstm_pf)
            per_layer += 2 * d * di + 3 * di * di + di * d      # mLSTM-ish
        if self.block_pattern and "rglru" in self.block_pattern:
            w = self.lru_width or d
            per_layer += 2 * d * w + 2 * w * w + w * d
        enc = self.n_encoder_layers * per_layer if self.is_encoder_decoder else 0
        return emb + L * per_layer + enc

    def n_active_params(self) -> int:
        """Active params per token (MoE top-k)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        active_moe = 3 * self.moe_d_ff * d * (self.top_k
                                              + self.n_shared_experts)
        full_moe = 3 * self.moe_d_ff * d * (self.n_experts
                                            + self.n_shared_experts)
        return self.n_params() - self.n_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        n_layers=min(cfg.n_layers, 2 if not cfg.block_pattern
                     else len(cfg.block_pattern)),
        d_model=128,
        n_heads=max(2, min(cfg.n_heads, 4)),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32 if cfg.head_dim else None,
        remat=False,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.n_experts:
        base.update(n_experts=8, top_k=2, moe_d_ff=64,
                    n_shared_experts=min(cfg.n_shared_experts, 1),
                    first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.attention == "mla":
        base.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                    qk_rope_dim=16, v_head_dim=32, head_dim=None)
    if cfg.lru_width:
        base.update(lru_width=128)
    if cfg.sliding_window:
        base.update(sliding_window=64)
    if cfg.is_encoder_decoder:
        base.update(n_encoder_layers=2)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
