"""Qwen2-0.5B [arXiv:2407.10671; hf-verified].

Spec: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, QKV bias.
14 heads % 16 mesh => `small` TP profile (attention replicated on model).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151936, head_dim=64,
    attention="gqa", qkv_bias=True, rope_theta=1e6,
    tp_profile="small",
)
