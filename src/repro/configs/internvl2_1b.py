"""InternVL2-1B [arXiv:2404.16821; hf-verified backbone].

Spec: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT frontend is a STUB per the task: input_specs() provides
precomputed patch embeddings (n_vision_tokens per sample).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655, head_dim=64,
    attention="gqa", qkv_bias=True, rope_theta=1e6,
    frontend="vision_stub", n_vision_tokens=256,
    tp_profile="small",
)
