"""Qwen1.5-32B [hf:Qwen family; spec-literal].

Spec: 64L d_model=5120 40H (GQA kv=40 == MHA) d_ff=27392 vocab=152064,
QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=27392, vocab=152064, head_dim=128,
    attention="gqa", qkv_bias=True, rope_theta=1e6,
    tp_profile="tp", tie_embeddings=False,
)
