"""--arch <id> registry: the 10 assigned architectures + the paper's own."""

from repro.configs import (kimi_k2_1t_a32b, deepseek_v3_671b, internvl2_1b,
                           qwen1_5_32b, qwen3_8b, h2o_danube_3_4b,
                           qwen2_0_5b, xlstm_125m, recurrentgemma_9b,
                           whisper_large_v3)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    kimi_k2_1t_a32b, deepseek_v3_671b, internvl2_1b, qwen1_5_32b, qwen3_8b,
    h2o_danube_3_4b, qwen2_0_5b, xlstm_125m, recurrentgemma_9b,
    whisper_large_v3)}


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
