"""Whisper large-v3 [arXiv:2212.04356; spec-literal].

Spec: 32L(enc)+32L(dec) d_model=1280 20H MHA d_ff=5120 vocab=51866;
encoder-decoder with conv audio frontend STUBBED per the task
(input_specs() provides precomputed frame embeddings).
20 heads % 16 mesh => `small` TP profile.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    attention="gqa", norm="layernorm", act="gelu",
    is_encoder_decoder=True, n_encoder_layers=32,
    frontend="audio_stub",
    tp_profile="small", tie_embeddings=False,
)
