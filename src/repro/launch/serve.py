"""Serving CLI: the SHT request-coalescing engine under synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --requests 8 --smoke
    PYTHONPATH=src python -m repro.launch.serve --p99-target-ms 50

Runs the double-buffered serving threads (batch i+1 stages while batch i
computes), submits a mixed spin-0/spin-2 request stream, waits for every
future, and prints the stats table (p50/p95/p99 latency, coalescing
factor, admission caps, plan-pool hit rate).  ``--p99-target-ms`` turns
on roofline admission control: the coalesced K per signature is capped by
the latency target instead of ``--max-k`` alone.
"""

import argparse

import numpy as np

import repro  # noqa: F401
from repro.core import sht
from repro.serve import ShtEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=32)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mode", default="jnp",
                    help="plan dispatch mode for pooled plans "
                         "(jnp | auto | model | pallas_*)")
    ap.add_argument("--p99-target-ms", type=float, default=None,
                    help="roofline admission: cap each group's coalesced "
                         "K to fit this tail-latency target")
    ap.add_argument("--smoke", action="store_true")
    a = ap.parse_args()
    if a.smoke:
        a.lmax = min(a.lmax, 16)

    target_s = None if a.p99_target_ms is None else a.p99_target_ms * 1e-3
    eng = ShtEngine(max_k=a.max_k, mode=a.mode, warm_after=2,
                    p99_target_s=target_s)
    with eng:                          # double-buffered form/exec threads
        futs = []
        for rid in range(a.requests):
            if rid % 2 == 0:
                alm = np.asarray(sht.random_alm(
                    seed=rid, l_max=a.lmax, m_max=a.lmax))[..., 0]
                futs.append(eng.submit(direction="alm2map", payload=alm,
                                       grid="gl", l_max=a.lmax))
            else:
                alm = np.asarray(sht.random_alm_spin(
                    seed=rid, l_max=a.lmax, m_max=a.lmax))[..., 0]
                futs.append(eng.submit(direction="alm2map", payload=alm,
                                       grid="gl", l_max=a.lmax, spin=2))
        results = [f.result(timeout=600) for f in futs]
    assert all(np.isfinite(r).all() for r in results)
    print(eng.report())
    done = eng.stats()["requests"]["completed"]
    print(f"completed {done}/{a.requests} requests")


if __name__ == "__main__":
    main()
