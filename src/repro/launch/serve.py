"""Serving CLI: the SHT request-coalescing engine under synthetic load.

    PYTHONPATH=src python -m repro.launch.serve --requests 8 --smoke

Runs the background serving thread, submits a mixed spin-0/spin-2 request
stream, waits for every future, and prints the stats table (p50/p95/p99
latency, coalescing factor, plan-pool hit rate).
"""

import argparse

import numpy as np

import repro  # noqa: F401
from repro.core import sht
from repro.serve import ShtEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=32)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--mode", default="jnp",
                    help="plan dispatch mode for pooled plans "
                         "(jnp | auto | model | pallas_*)")
    ap.add_argument("--smoke", action="store_true")
    a = ap.parse_args()
    if a.smoke:
        a.lmax = min(a.lmax, 16)

    eng = ShtEngine(max_k=a.max_k, mode=a.mode, warm_after=2)
    with eng:                                    # background serving thread
        futs = []
        for rid in range(a.requests):
            if rid % 2 == 0:
                alm = np.asarray(sht.random_alm(
                    seed=rid, l_max=a.lmax, m_max=a.lmax))[..., 0]
                futs.append(eng.submit(direction="alm2map", payload=alm,
                                       grid="gl", l_max=a.lmax))
            else:
                alm = np.asarray(sht.random_alm_spin(
                    seed=rid, l_max=a.lmax, m_max=a.lmax))[..., 0]
                futs.append(eng.submit(direction="alm2map", payload=alm,
                                       grid="gl", l_max=a.lmax, spin=2))
        results = [f.result(timeout=600) for f in futs]
    assert all(np.isfinite(r).all() for r in results)
    print(eng.report())
    done = eng.stats()["requests"]["completed"]
    print(f"completed {done}/{a.requests} requests")


if __name__ == "__main__":
    main()
