"""Serving CLI: batched greedy decoding behind the static-slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
"""

import argparse

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.launch.mesh import make_production_mesh
from repro.models.model import make_bundle
from repro.serve.serve_loop import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    a = ap.parse_args()

    cfg = registry.get(a.arch)
    if a.smoke or jax.device_count() == 1:
        cfg = reduced(cfg, n_layers=2)
        mesh = None
    else:
        mesh = make_production_mesh()
    bundle = make_bundle(cfg, mesh)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, batch=a.batch, max_len=a.max_len)
    rng = np.random.default_rng(0)
    for rid in range(a.requests):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 5)
                           .astype(np.int32), max_new=8))
    done = eng.run(params, max_steps=300)
    print(f"completed {sum(r.done for r in done)}/{a.requests} requests")


if __name__ == "__main__":
    main()
