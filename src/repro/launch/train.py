"""Production training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --steps 1000 --ckpt /path/ckpt [--multi-pod] [--smoke]

On a real TPU fleet this binary is launched once per host (JAX distributed
initialisation via megascale env); on this CPU container use --smoke for a
reduced-width single-device run, or set
XLA_FLAGS=--xla_force_host_platform_device_count=N before launch to
exercise the real sharding path.

Recommended production XLA flags (applied on TPU backends):
  --xla_tpu_enable_latency_hiding_scheduler=true   (overlap grad all-reduce
                                                    with backward compute)
  --xla_tpu_spmd_rng_bit_generator_unsafe=1
"""

import argparse
import time

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.launch.mesh import make_production_mesh
from repro.models.model import make_bundle
from repro.train import checkpoint as C
from repro.train import data as D
from repro.train import fault as F
from repro.train import optimizer as O
from repro.train import train_loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced width, single device")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "bfloat16", "int8"])
    a = ap.parse_args()

    cfg = registry.get(a.arch)
    if a.smoke or jax.device_count() == 1:
        cfg = reduced(cfg, d_model=256, n_layers=2, d_ff=512, vocab=4096)
        mesh = None
    else:
        mesh = make_production_mesh(multi_pod=a.multi_pod)
    bundle = make_bundle(cfg, mesh)
    tcfg = TL.TrainConfig(
        opt=O.AdamWConfig(total_steps=a.steps),
        grad_accum=a.grad_accum, grad_compression=a.grad_compression)
    step_fn_j = jax.jit(TL.make_train_step(bundle, tcfg),
                        donate_argnums=(0, 1))
    ds = D.SyntheticLM(vocab=cfg.vocab, seq_len=a.seq,
                       global_batch=a.global_batch, seed=0,
                       frontend=cfg.frontend, d_model=cfg.d_model,
                       n_frontend_tokens=64)
    key = jax.random.PRNGKey(0)

    def init_state():
        params = bundle.init(key)
        return {"params": params, "opt": O.init_opt_state(params, tcfg.opt)}

    def step_fn(state, i):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        p, o, m = step_fn_j(state["params"], state["opt"], batch, key)
        if i % 10 == 0:
            print(f"step {i} loss {float(m['loss']):.4f}")
        return {"params": p, "opt": o}

    F.run_with_restarts(
        F.RunConfig(total_steps=a.steps, ckpt_dir=a.ckpt,
                    ckpt_every=a.ckpt_every),
        init_state=init_state, step_fn=step_fn)
    print("training complete")


if __name__ == "__main__":
    main()
