import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 host-platform devices stand in for 2 TPU v5e pods; every
cell's step function must partition, lower and compile, and the compiled
artifact yields the memory/cost analysis the roofline reads.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single --out results/dryrun

The XLA_FLAGS assignment above MUST precede any jax import (device count
locks at first init); it is deliberately NOT set in conftest.py or
pyproject -- smoke tests and benches see 1 device.

Accounting correction
---------------------
XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so a scan-over-layers model under-reports flops/bytes by ~n_layers
and hides per-iteration collectives.  We therefore compile, per cell:
  * the FULL-depth step (the required mesh-validity + memory proof), and
  * 2-3 shallow "accounting" variants (scan unrolled, dense attention,
    single-chunk loss) whose per-group cost slopes extrapolate exactly to
    the full depth:  f_full = f_base + sum_g (reps_g - base_g) * slope_g.
Both raw and corrected numbers are recorded; the roofline (EXPERIMENTS.md)
uses the corrected ones.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401  (enables x64; models pass explicit dtypes)
from repro.configs import registry
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh, sht_axis_names
from repro.models.model import make_bundle, input_specs
from repro.roofline import analysis as RA
from repro.train import optimizer as O
from repro.train import train_loop as TL


def _sds_with(tree_sds, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, shardings)


def _nrows(mesh):
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a in ("pod", "data")]))


def _maybe_flat_batch_bundle(cfg, mesh, B):
    """Bundle with a replicated batch axis when B doesn't split the DP rows
    (the long_500k B=1 cell: data axis idle by design)."""
    bundle = make_bundle(cfg, mesh)
    if B % _nrows(mesh) != 0:
        rules = dataclasses.replace(bundle.rt.rules, batch=None)
        rt = dataclasses.replace(bundle.rt, rules=rules)
        bundle = dataclasses.replace(bundle, rt=rt)
    return bundle


def _lower_step(cfg, shape, mesh):
    """Lower one cell's step function (train/prefill/decode)."""
    B, S = shape.global_batch, shape.seq_len
    bundle = _maybe_flat_batch_bundle(cfg, mesh, B)
    if shape.kind == "train":
        tcfg = TL.TrainConfig()
        step = TL.make_train_step(bundle, tcfg)
        p_sh, o_sh = TL.train_state_shardings(bundle, tcfg)
        p_sds = _sds_with(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)),
                          p_sh)
        o_sds = _sds_with(
            jax.eval_shape(lambda p: O.init_opt_state(p, tcfg.opt), p_sds),
            o_sh)
        batch = input_specs(cfg, shape, mesh)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return jax.jit(step, donate_argnums=(0, 1)).lower(
            p_sds, o_sds, batch, rng)
    p_sh = bundle.param_shardings()
    p_sds = _sds_with(jax.eval_shape(bundle.init, jax.random.PRNGKey(0)),
                      p_sh)
    if shape.kind == "prefill":
        batch = input_specs(cfg, shape, mesh)
        caches = input_specs(cfg, dataclasses.replace(shape, kind="decode"),
                             mesh)["caches"]
        return jax.jit(bundle.prefill_fn, donate_argnums=(2,)).lower(
            p_sds, batch, caches)
    ins = input_specs(cfg, shape, mesh)
    return jax.jit(bundle.decode_fn, donate_argnums=(3,)).lower(
        p_sds, ins["token"], ins["pos"], ins["caches"])


# -- accounting variants --------------------------------------------------------


def _depth_overrides(cfg, reps):
    """Map per-group repeat counts -> ArchConfig depth overrides."""
    if cfg.is_encoder_decoder:
        return dict(n_encoder_layers=reps[0], n_layers=reps[1])
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        n_full = cfg.n_layers // len(pat)
        rem = cfg.n_layers - n_full * len(pat)
        return dict(n_layers=reps[0] * len(pat) + rem)
    if cfg.n_experts and cfg.first_dense_layers:
        return dict(first_dense_layers=reps[0], n_layers=reps[0] + reps[1])
    return dict(n_layers=reps[0])


def _group_reps_full(cfg):
    if cfg.is_encoder_decoder:
        return [cfg.n_encoder_layers, cfg.n_layers]
    if cfg.block_pattern:
        return [cfg.n_layers // len(cfg.block_pattern)]
    if cfg.n_experts and cfg.first_dense_layers:
        return [cfg.first_dense_layers, cfg.n_layers - cfg.first_dense_layers]
    return [cfg.n_layers]


def _acct_cfg(cfg, reps, attn_impl="dense"):
    # inner_unroll explodes HLO for the mlstm chunk scan at 32k+ sequences;
    # the ssm family gets analytic flops instead (below), so never unroll it.
    return dataclasses.replace(
        cfg, scan_unroll=True, attn_impl=attn_impl, loss_chunks=1,
        inner_unroll=(cfg.family != "ssm"), **_depth_overrides(cfg, reps))


def _ssm_analytic_flops(cfg, shape, n_dev):
    """Closed-form per-device flops for the xLSTM family (the chunkwise
    mixing lives inside a scan whose trip count scales with S, which defeats
    the depth-slope trick; the architecture is exactly known, so count it).
    """
    d = cfg.d_model
    di = int(d * cfg.mlstm_pf)
    H = cfg.n_heads
    hd = di // H
    c = 64                                   # production chunk size
    per_tok_mlstm = (2 * d * 2 * di          # up
                     + 3 * 2 * di * di       # q, k, v
                     + 2 * 2 * di * H        # gates
                     + 2 * 2 * c * di        # intra-chunk qk + pv
                     + 2 * 2 * H * hd * hd   # inter read + state update
                     + 2 * di * d            # down
                     + 20 * di)              # norms/gating elementwise
    dff = int(d * 4.0 / 3.0)
    per_tok_slstm = (4 * 2 * d * d           # wz, wi, wf, wo
                     + 3 * 2 * d * dff       # ffn
                     + 30 * d)               # scan elementwise
    n_m = sum(1 for g_, n in
              [(p, 1) for p in (cfg.block_pattern or ())] if g_ == "mlstm")
    pat = cfg.block_pattern or ("mlstm",)
    L = cfg.n_layers
    n_mlstm = sum(1 for i in range(L) if pat[i % len(pat)] == "mlstm")
    n_slstm = L - n_mlstm
    per_tok = n_mlstm * per_tok_mlstm + n_slstm * per_tok_slstm
    loss = 2 * d * cfg.vocab
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = tokens * (per_tok * 4.0 + loss * 3.0)   # bwd + remat
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = tokens * per_tok + shape.global_batch * loss
    else:
        total = shape.global_batch * (per_tok + loss)
    return total / n_dev


def _measure(cfg, shape, mesh, n_dev):
    lowered = _lower_step(cfg, shape, mesh)
    compiled = lowered.compile()
    cost = {}
    try:
        cost = compiled.cost_analysis() or {}
    except Exception:
        pass
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    wire = RA.collective_bytes(compiled.as_text(), n_dev)["total"]
    return {"flops": flops, "bytes": byts, "wire": wire}


def _extrapolate(cfg, shape, mesh, n_dev, attn_impl):
    full = _group_reps_full(cfg)
    base_reps = [1] * len(full)
    base = _measure(_acct_cfg(cfg, base_reps, attn_impl), shape, mesh, n_dev)
    out = dict(base)
    details = {"base": base, "slopes": []}
    for g in range(len(full)):
        bump = list(base_reps)
        bump[g] += 1
        m = _measure(_acct_cfg(cfg, bump, attn_impl), shape, mesh, n_dev)
        slope = {k: m[k] - base[k] for k in base}
        details["slopes"].append(slope)
        for k in out:
            out[k] += (full[g] - base_reps[g]) * slope[k]
    return {k: max(v, 0.0) for k, v in out.items()}, details


def account_lm_cell(cfg, shape, mesh):
    """Extrapolated full-depth per-device (flops, bytes, wire bytes).

    Two passes: a dense-attention pass counts the true attention FLOPs in
    one un-looped HLO; an mea pass counts HBM-realistic BYTES (a fused TPU
    attention kernel keeps score tiles in VMEM -- the dense pass would
    charge the S^2 score materialisation to HBM).  Wire bytes: max of both.
    """
    n_dev = mesh.size
    if cfg.family == "ssm":
        by, d2 = _extrapolate(cfg, shape, mesh, n_dev, "mea")
        out = {"flops": _ssm_analytic_flops(cfg, shape, n_dev),
               "bytes": by["bytes"], "wire": by["wire"]}
        return out, {"mea_pass": d2, "flops": "analytic (ssm family)"}
    fl, d1 = _extrapolate(cfg, shape, mesh, n_dev, "dense")
    by, d2 = _extrapolate(cfg, shape, mesh, n_dev, "mea")
    out = {"flops": fl["flops"], "bytes": by["bytes"],
           "wire": max(fl["wire"], by["wire"])}
    return out, {"dense_pass": d1, "mea_pass": d2}


# -- cell drivers ----------------------------------------------------------------


def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  profile: str | None = None, moe_impl: str | None = None):
    cfg = registry.get(arch)
    if profile:
        cfg = dataclasses.replace(cfg, tp_profile=profile)
    if moe_impl:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    B, S = shape.global_batch, shape.seq_len

    if shape_name == "long_500k" and not cfg.long_context_ok:
        return {"status": "skip",
                "reason": "full-attention arch cannot serve a 524288-token "
                          "dense KV cache; sub-quadratic archs only "
                          "(DESIGN.md §6)"}

    lowered = _lower_step(cfg, shape, mesh)
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        model_flops = 6.0 * n_active * B * S
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * B * S
    else:
        model_flops = 2.0 * n_active * B
    return {"status": "ok", "lowered": lowered, "n_devices": mesh.size,
            "model_flops": model_flops, "cfg": cfg, "shape": shape,
            "mesh_obj": mesh, "n_params": cfg.n_params(),
            "n_active_params": n_active}


def lower_sht_cell(shape_name: str, multi_pod: bool, *, fold=False,
                   comm_dtype=None, stage1="jnp", variant=None):
    from repro.configs.sht_cmb import SHT_SHAPES
    from repro.core import grids, plan as planlib, dist_sht
    scfg = SHT_SHAPES[shape_name]
    if comm_dtype is not None or fold:
        scfg = dataclasses.replace(scfg, fold=fold, comm_dtype=comm_dtype)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    g = grids.make_grid("gl", l_max=scfg.l_max)
    p = planlib.SHTPlan(g, scfg.l_max, scfg.l_max, n_dev)
    if variant is not None:
        os.environ["REPRO_LEGENDRE_VARIANT"] = variant
    d = dist_sht.DistSHT(p, mesh, sht_axis_names(mesh), dtype=scfg.dtype,
                         fold=scfg.fold, comm_dtype=scfg.comm_dtype,
                         stage1=stage1)
    if scfg.direction == "synth":
        lowered, _ = d.lower_synth(scfg.K)
    else:
        lowered, _ = d.lower_anal(scfg.K)
    # Useful flops: recurrence (6) + complex accumulate (8K) per (l>=m, m,
    # ring) triple, + the batched FFT stage.  (No layer scans: the l loop is
    # a real sequential dependence counted per-iteration... NOT -- it is a
    # fori_loop, also undercounted; corrected analytically below since the
    # trip count (l_max+1) is exact and the body is homogeneous.)
    L1 = scfg.l_max + 1
    tri = g.n_rings * L1 * (L1 + 1) / 2.0
    n = g.max_n_phi
    fft = 5.0 * g.n_rings * n * np.log2(n) * scfg.K
    model_flops = tri * (6.0 + 8.0 * scfg.K) + fft
    return {"status": "ok", "lowered": lowered, "n_devices": n_dev,
            "model_flops": model_flops, "n_params": 0, "n_active_params": 0,
            "sht_cfg": scfg, "sht_grid": g}


def _sht_corrected(rec_roof, scfg, grid, n_dev, K):
    """Analytic while-loop correction for the SHT cell: the l fori_loop has
    l_max+1 iterations; stage-1 flops/bytes scale with it.  Collective
    bytes (one all_to_all outside the loop) are already correct.
    fold=True: the recurrence runs on northern rings only (20 -> 10 flops
    per triple); the parity accumulate cost is unchanged."""
    L1 = scfg.l_max + 1
    # per-device recurrence work (triangular, min-max balanced)
    tri_steps = grid.n_rings * L1 * (L1 + 1) / 2.0 / n_dev
    rec_per_step = (10.0 if scfg.fold else 20.0) + 8.0 * K
    rec_flops = tri_steps * rec_per_step
    n = grid.max_n_phi
    fft_flops = 5.0 * (grid.n_rings / n_dev) * n * np.log2(n) * K
    flops = rec_flops + fft_flops
    # bytes: a_lm read once, Delta written once, exchanged, maps written
    dt = 4 if scfg.dtype == "float32" else 8
    bytes_ = (L1 * L1 / 2 / n_dev * 2 * K          # alm
              + 2 * grid.n_rings * L1 / n_dev * 2 * K   # Delta in/out
              + grid.n_rings * n / n_dev * K) * dt
    return flops, bytes_


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             skip_existing: bool = True, account: bool = True, **sht_kw):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    if sht_kw:
        extras = "_".join(f"{k}-{v}" for k, v in sorted(sht_kw.items())
                          if v not in (None, False, "jnp"))
        if extras:
            tag += "__" + extras
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[dryrun] {tag}: cached")
        return json.load(open(path))
    multi = mesh_kind == "multi"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    t0 = time.time()
    try:
        if arch == "sht_cmb":
            out = lower_sht_cell(shape_name, multi, **sht_kw)
        else:
            out = lower_lm_cell(arch, shape_name, multi,
                                profile=sht_kw.get("profile"),
                                moe_impl=sht_kw.get("moe_impl"))
        rec["status"] = out["status"]
        if out["status"] == "skip":
            rec["reason"] = out["reason"]
        else:
            lowered = out.pop("lowered")
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1
            try:
                m = compiled.memory_analysis()
                rec["memory_analysis"] = {k: int(getattr(m, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "alias_size_in_bytes") if
                    hasattr(m, k)}
            except Exception as e:  # pragma: no cover
                rec["memory_analysis"] = {"error": str(e)}
            roof_raw = RA.analyze_compiled(
                compiled, n_devices=out["n_devices"],
                model_flops=out["model_flops"])
            rec["roofline_raw"] = roof_raw.to_dict()
            # corrected accounting
            if arch == "sht_cmb":
                fl, by = _sht_corrected(rec["roofline_raw"], out["sht_cfg"],
                                        out["sht_grid"], out["n_devices"],
                                        out["sht_cfg"].K)
                roof = dataclasses.replace(
                    roof_raw, flops_per_device=fl, bytes_per_device=by)
                rec["roofline"] = roof.to_dict()
            elif account:
                cfg = out["cfg"]
                acct, details = account_lm_cell(cfg, out["shape"],
                                                out["mesh_obj"])
                roof = dataclasses.replace(
                    roof_raw, flops_per_device=acct["flops"],
                    bytes_per_device=acct["bytes"],
                    wire_bytes_per_device=max(acct["wire"],
                                              roof_raw.wire_bytes_per_device))
                rec["roofline"] = roof.to_dict()
                rec["accounting"] = details
            else:
                rec["roofline"] = rec["roofline_raw"]
            rec["n_params"] = out["n_params"]
            rec["n_active_params"] = out["n_active_params"]
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    bot = rec.get("roofline", {}).get("bottleneck", "-")
    print(f"[dryrun] {tag}: {rec['status']} ({rec['wall_s']:.1f}s, "
          f"bottleneck={bot})")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-account", action="store_true")
    ap.add_argument("--fold", action="store_true")
    ap.add_argument("--comm-dtype", default=None)
    ap.add_argument("--stage1", default="jnp")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--profile", default=None,
                    help="override tp_profile (tp|small|dp) for perf iters")
    ap.add_argument("--moe-impl", default=None, choices=[None, "a2a",
                                                         "replicated"])
    a = ap.parse_args()
    kw = {}
    if a.arch == "sht_cmb":
        kw = dict(fold=a.fold, comm_dtype=a.comm_dtype, stage1=a.stage1,
                  variant=a.variant)
    else:
        if a.profile:
            kw["profile"] = a.profile
        if a.moe_impl:
            kw["moe_impl"] = a.moe_impl
    rec = run_cell(a.arch, a.shape, a.mesh, a.out,
                   skip_existing=not a.force, account=not a.no_account, **kw)
    raise SystemExit(0 if rec["status"] in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
