# Launch layer: production mesh builders, the multi-pod dry-run driver,
# and the train/serve CLIs.
