"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).

Topology: TPU v5e pods of 256 chips as a (16, 16) (data, model) mesh;
multi-pod adds a leading "pod" axis (pure DP across pods -> the cross-pod
collective traffic is one gradient all-reduce per step, the right shape
for DCI-connected pods).  `elastic_mesh` builds degraded topologies for
the fault-tolerance path.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "elastic_mesh", "sht_axis_names"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def elastic_mesh(n_devices: int, *, model: int = 16):
    """Degraded-topology mesh after losing hosts (n_devices multiple of
    ``model``); used by the elastic-restore tests."""
    assert n_devices % model == 0
    return jax.make_mesh((n_devices // model, model), ("data", "model"))


def sht_axis_names(mesh) -> tuple:
    """The SHT flattens every mesh axis into one S^2HAT process ring."""
    return tuple(mesh.axis_names)
