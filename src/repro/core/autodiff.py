"""Adjoint-based differentiation rules for the transform layers.

The paper's structural dichotomy -- the direct and inverse SHT are (up to
quadrature weights) adjoints of each other -- is exactly the identity JAX
needs to differentiate a transform without tracing through its
implementation.  Every layer of the transform stack is a *linear* map with
a hand-written adjoint that is just the opposite-direction transform of the
same layer:

  ===========================  =======================================
  layer (forward)              adjoint (transpose)
  ===========================  =======================================
  Legendre synthesis           Legendre analysis with unit weights
  Legendre analysis (w)        w * Legendre synthesis
  phase synthesis              fac_m * phase analysis / weights
  phase analysis               phase synthesis(w * cotangent / fac_m)
  Pallas kernel synth          Pallas kernel anal (same schedule)
  Pallas kernel anal           Pallas kernel synth (same schedule)
  ===========================  =======================================

:func:`linear_pair` packages one such (forward, transpose) pair as a
function that is differentiable in both modes:

* **JVP** (forward mode): the map is linear, so the tangent rule is the
  forward map applied to the tangents (``jax.custom_jvp``).
* **VJP** (reverse mode): the tangent-side computation is expressed with
  :func:`jax.custom_derivatives.linear_call`, whose registered transpose
  rule invokes the supplied adjoint -- so ``jax.grad`` calls the
  opposite-direction transform instead of transposing kernel internals
  (Pallas kernels are not transposable at all; for the jnp engine this
  also avoids storing one recurrence panel per multipole).

Contract
--------
``fwd(residuals, operands)`` must be linear in ``operands``;
``transpose(residuals, cotangents)`` must be its exact transpose with
respect to the standard real inner product, returning arrays whose
shapes/dtypes match ``operands``.  ``residuals`` (geometry, seed tables,
index maps) are treated as constants of the differentiation: their
tangents are dropped, and gradients with respect to them are not defined.
Double-backward (reverse-over-reverse) is not supported by ``linear_call``;
forward-over-forward and first-order reverse are.

The adjointness of every registered pair is enforced by the property-based
dot-product tests in ``tests/test_adjoint.py``:
``<fwd(x), y> == <x, transpose(y)>`` to dtype rounding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero, linear_call
from jax.tree_util import tree_flatten, tree_unflatten

__all__ = ["linear_pair"]


def _is_szero(x) -> bool:
    return isinstance(x, SymbolicZero)


def linear_pair(fwd, transpose, residuals, operands):
    """Run ``fwd(residuals, operands)`` with adjoint-based custom AD rules.

    Parameters
    ----------
    fwd : callable(residuals, operands) -> outputs, linear in ``operands``.
    transpose : callable(residuals, cotangents) -> operand cotangents; the
        exact transpose of ``fwd`` (see module docstring contract).
    residuals : pytree of non-differentiated arrays (may be traced, e.g.
        sharded geometry operands inside shard_map; may include ints).
    operands : pytree of arrays carrying the linearity (and the gradients).

    Returns ``fwd(residuals, operands)``, differentiable in forward mode
    (tangent = ``fwd`` on tangents) and first-order reverse mode
    (cotangent = ``transpose`` on cotangents).
    """

    @jax.custom_jvp
    def call(ops, res):
        return fwd(res, ops)

    @functools.partial(call.defjvp, symbolic_zeros=True)
    def call_jvp(primals, tangents):
        ops, res = primals
        d_ops, d_res = tangents
        # Residuals are constants of the differentiation: a perturbed
        # residual (non-symbolic-zero tangent) means someone is asking for
        # d/d(weights, geometry, seeds, ...), which this rule does not
        # provide -- fail loudly rather than return a silently-zero grad.
        if any(not _is_szero(t) for t in tree_flatten(
                d_res, is_leaf=_is_szero)[0]):
            raise ValueError(
                "linear_pair: differentiation with respect to a residual "
                "argument (quadrature weights, grid geometry, seed tables, "
                "index maps) is not supported -- only the linear operands "
                "(alm / maps / delta) carry adjoint-based gradients")
        y = call(ops, res)
        # linear_call transposition requires every linear operand to be an
        # actual linear (undefined-primal) input: operands with symbolic-zero
        # tangents (not differentiated) must stay OUT of the linear slot, so
        # partition the tangent leaves and close the zeros over as constants.
        t_leaves, tdef = tree_flatten(d_ops, is_leaf=_is_szero)
        dead = [_is_szero(t) for t in t_leaves]
        live = [t for t, z in zip(t_leaves, dead) if not z]
        if not live:                       # nothing perturbed: zero tangent
            return y, jax.tree_util.tree_map(
                lambda v: SymbolicZero(jax.core.get_aval(v).at_least_vspace()),
                y)

        def fwd_live(res_, live_ops):
            it = iter(live_ops)
            full = [jnp.zeros(t.aval.shape, t.aval.dtype) if z else next(it)
                    for t, z in zip(t_leaves, dead)]
            return fwd(res_, tree_unflatten(tdef, full))

        def bwd_live(res_, cts):
            full_ct = tree_flatten(transpose(res_, cts))[0]
            return [c for c, z in zip(full_ct, dead) if not z]

        return y, linear_call(fwd_live, bwd_live, res, live)

    return call(operands, residuals)
