"""Iso-latitude sphere grids for spherical harmonic transforms.

The paper (§2.2) restricts pixelisations to iso-latitude rings with
equidistant pixels per ring, which is what makes the O(R_N * l_max^2)
algorithm possible.  We provide three grid families:

  * ``gl``            -- Gauss-Legendre rings (exact quadrature for
                         band-limited fields), uniform n_phi.  The TPU
                         production grid.
  * ``healpix_ring``  -- HEALPix ring latitudes and area weights, but a
                         uniform number of samples per ring ("ring-uniform"
                         variant).  Approximate quadrature, mirroring the
                         paper's HEALPix error behaviour, TPU friendly.
  * ``healpix``       -- true HEALPix ring structure (n_phi = 4i in the
                         polar caps).  Ragged; served by the device-resident
                         ring-bucket phase stage (repro.core.phase) on every
                         backend, with `ring_buckets` grouping rings by
                         rounded-up FFT length.
  * ``ecp``           -- equidistant cylindrical (equiangular theta rings,
                         uniform n_phi, latitude-band area weights).
                         Approximate quadrature like HEALPix; the simplest
                         uniform grid, used by the adjointness test matrix
                         as a non-Gauss exact-FFT case.

All geometry is computed with numpy in float64 at plan time; nothing here
touches jax device state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "RingGrid",
    "FFTBucket",
    "BucketLayout",
    "ring_buckets",
    "gauss_legendre_grid",
    "ecp_grid",
    "healpix_ring_grid",
    "healpix_grid",
    "make_grid",
]


# ---------------------------------------------------------------------------
# FFT ring buckets (the ragged-grid phase-stage geometry)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FFTBucket:
    """One batched-FFT group of rings.

    ``length`` is the bucket's FFT length B; every member ring's ``n_phi``
    divides B, which is what makes the padded transform *exact*: a ring's
    length-n spectrum embeds at stride B/n in the length-B spectrum
    (synthesis), and zero-padding its n samples to B leaves the DFT bins at
    stride B/n untouched (analysis).
    """

    length: int
    rings: np.ndarray         # grid ring indices served by this bucket

    @property
    def n_rings(self) -> int:
        return int(self.rings.shape[0])


def ring_buckets(n_phi: np.ndarray,
                 max_stretch: Optional[float] = None) -> tuple[FFTBucket, ...]:
    """Group rings by rounded-up FFT length (libsharp-style bucketing).

    Distinct ring lengths are processed in descending order; each length n
    joins the smallest existing bucket length B with ``B % n == 0`` (exact
    divisor embedding, see :class:`FFTBucket`), else opens its own bucket.
    Every bucket length is therefore an actual ring length, so
    ``B <= max(n_phi)`` always.

    ``max_stretch`` caps ``B / n`` per ring: lower values mean less FFT
    padding waste but more buckets (``max_stretch=1`` degenerates to one
    bucket per distinct length).  The default (None) merges maximally --
    on HEALPix the rings a bucket absorbs are the short polar-cap ones, so
    the absolute waste stays small while the bucket count roughly halves.
    """
    n_phi = np.asarray(n_phi)
    lengths: list[int] = []           # bucket length per bucket index
    members: list[list[int]] = []     # distinct n values per bucket index
    for n in np.unique(n_phi)[::-1].tolist():
        n = int(n)
        cands = [i for i, B in enumerate(lengths)
                 if B % n == 0
                 and (max_stretch is None or B <= max_stretch * n)]
        if cands:
            members[min(cands, key=lambda i: lengths[i])].append(n)
        else:
            lengths.append(n)
            members.append([n])
    return tuple(
        FFTBucket(B, np.where(np.isin(n_phi, ns))[0])
        for B, ns in zip(lengths, members))


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static slot->bucket structure consumed by the phase stage.

    ``slots[k]`` are the ring (or plan-slot) indices whose FFTs run in
    bucket k at batched length ``lengths[k]``.  Pure numpy: safe to build at
    plan time and to close over as static data inside jit/shard_map.
    """

    lengths: tuple[int, ...]
    slots: tuple               # of np.ndarray index arrays

    @property
    def n_buckets(self) -> int:
        return len(self.lengths)

    @property
    def fft_lengths(self) -> np.ndarray:
        """(R,) per-slot FFT length (the slot's bucket length)."""
        n = sum(len(s) for s in self.slots)
        out = np.zeros(n, dtype=np.int64)
        for B, sl in zip(self.lengths, self.slots):
            out[np.asarray(sl)] = B
        return out

    def padded_frac(self, n_phi: np.ndarray) -> float:
        """FFT-length inflation from bucketing: sum(B)/sum(n_phi) - 1."""
        n_phi = np.asarray(n_phi)
        tot_b = sum(B * len(sl) for B, sl in zip(self.lengths, self.slots))
        tot_n = float(np.sum(n_phi))
        return float(tot_b / tot_n - 1.0) if tot_n else 0.0

    @classmethod
    def from_buckets(cls, buckets: tuple[FFTBucket, ...]) -> "BucketLayout":
        return cls(tuple(b.length for b in buckets),
                   tuple(np.asarray(b.rings) for b in buckets))


@dataclasses.dataclass(frozen=True)
class RingGrid:
    """Geometry of an iso-latitude ring grid.

    Rings are stored north-to-south.  ``n_phi`` may vary per ring (true
    HEALPix) or be constant (``uniform`` grids).  ``phi0`` is the azimuth of
    the first pixel in each ring (paper eq. 11 phase factor).
    """

    name: str
    cos_theta: np.ndarray     # (R,) float64, ring latitudes (cos theta), descending
    sin_theta: np.ndarray     # (R,) float64, sin theta (>0)
    weights: np.ndarray       # (R,) float64, quadrature weight per *sample* on the ring
    n_phi: np.ndarray         # (R,) int64, samples per ring
    phi0: np.ndarray          # (R,) float64, azimuth of first sample per ring
    uniform: bool             # all rings share n_phi
    nside: Optional[int] = None  # set for healpix-family grids

    @property
    def n_rings(self) -> int:
        return int(self.cos_theta.shape[0])

    @property
    def n_pix(self) -> int:
        return int(self.n_phi.sum())

    @property
    def max_n_phi(self) -> int:
        return int(self.n_phi.max())

    @property
    def equator_symmetric(self) -> bool:
        """True if ring i and ring R-1-i are mirror images (cosθ -> -cosθ)."""
        ct = self.cos_theta
        return bool(np.allclose(ct, -ct[::-1], atol=1e-12))

    def ring_areas(self) -> np.ndarray:
        """Total quadrature weight per ring (weight * n_phi)."""
        return self.weights * self.n_phi

    def fft_buckets(self, max_stretch: Optional[float] = None
                    ) -> tuple["FFTBucket", ...]:
        """Ring-bucket decomposition of the FFT/phase stage (one bucket for
        uniform grids; libsharp-style rounded-up groups for ragged ones)."""
        if self.uniform:
            return (FFTBucket(self.max_n_phi, np.arange(self.n_rings)),)
        return ring_buckets(self.n_phi, max_stretch)

    def bucket_lengths(self, max_stretch: Optional[float] = None
                       ) -> np.ndarray:
        """(R,) per-ring batched-FFT length under bucketing."""
        return BucketLayout.from_buckets(
            self.fft_buckets(max_stretch)).fft_lengths

    def bucket_permutation(self, max_stretch: Optional[float] = None
                           ) -> np.ndarray:
        """(R,) ring permutation ordering rings bucket-major (stable within
        a bucket), so bucket members are contiguous."""
        return np.concatenate(
            [b.rings for b in self.fft_buckets(max_stretch)])

    def validate(self) -> None:
        assert self.cos_theta.ndim == 1
        r = self.n_rings
        for arr in (self.sin_theta, self.weights, self.n_phi, self.phi0):
            assert arr.shape == (r,), (arr.shape, r)
        assert np.all(np.diff(self.cos_theta) < 0), "rings must go north->south"
        assert np.all(self.sin_theta > 0)
        assert np.all(self.n_phi >= 1)
        if self.uniform:
            assert np.all(self.n_phi == self.n_phi[0])
        # Sum of all weights approximates the sphere area 4*pi.
        total = float(np.sum(self.weights * self.n_phi))
        assert abs(total - 4.0 * np.pi) < 1e-6 * 4.0 * np.pi, total


# ---------------------------------------------------------------------------
# Gauss-Legendre grid
# ---------------------------------------------------------------------------


def _gauss_legendre_nodes(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Nodes & weights of n-point Gauss-Legendre quadrature on [-1, 1].

    Newton iteration on P_n with the standard Chebyshev initial guess.
    float64, no scipy.  Matches numpy.polynomial.legendre.leggauss (which we
    also use as a cross-check in tests) to ~1e-15.
    """
    k = np.arange(1, n + 1, dtype=np.float64)
    x = np.cos(np.pi * (k - 0.25) / (n + 0.5))  # initial guess, descending
    for _ in range(100):
        # Evaluate P_n(x) and P_{n-1}(x) via the (unnormalised) recurrence.
        p0 = np.ones_like(x)
        p1 = x.copy()
        for ell in range(2, n + 1):
            p0, p1 = p1, ((2 * ell - 1) * x * p1 - (ell - 1) * p0) / ell
        # derivative: P'_n = n (x P_n - P_{n-1}) / (x^2 - 1)
        dp = n * (x * p1 - p0) / (x * x - 1.0)
        dx = p1 / dp
        x = x - dx
        if np.max(np.abs(dx)) < 1e-15:
            break
    # weights: w = 2 / ((1 - x^2) P'_n(x)^2)
    p0 = np.ones_like(x)
    p1 = x.copy()
    for ell in range(2, n + 1):
        p0, p1 = p1, ((2 * ell - 1) * x * p1 - (ell - 1) * p0) / ell
    dp = n * (x * p1 - p0) / (x * x - 1.0)
    w = 2.0 / ((1.0 - x * x) * dp * dp)
    return x, w


def gauss_legendre_grid(l_max: int, n_rings: Optional[int] = None,
                        n_phi: Optional[int] = None) -> RingGrid:
    """Gauss-Legendre grid, exact for fields band-limited at ``l_max``.

    Defaults: ``n_rings = l_max + 1`` (GL quadrature of degree 2*l_max+1 is
    exact for the P_lm * P_l'm' integrand), ``n_phi = 2*l_max + 2`` (exact
    azimuthal quadrature for |m| <= l_max, kept even for rfft friendliness).
    """
    if n_rings is None:
        n_rings = l_max + 1
    if n_phi is None:
        n_phi = 2 * l_max + 2
    x, w = _gauss_legendre_nodes(n_rings)
    # x descending == north -> south already.
    # Per-sample weight: ring weight * (2 pi / n_phi).
    w_sample = w * (2.0 * np.pi / n_phi)
    r = n_rings
    return RingGrid(
        name="gl",
        cos_theta=x,
        sin_theta=np.sqrt(1.0 - x * x),
        weights=w_sample,
        n_phi=np.full(r, n_phi, dtype=np.int64),
        phi0=np.zeros(r, dtype=np.float64),
        uniform=True,
    )


# ---------------------------------------------------------------------------
# Equidistant cylindrical (ECP) grid
# ---------------------------------------------------------------------------


def ecp_grid(l_max: int, n_rings: Optional[int] = None,
             n_phi: Optional[int] = None) -> RingGrid:
    """Equidistant cylindrical grid: theta_r = (r + 1/2) * pi / R.

    Defaults: ``n_rings = 2 * (l_max + 1)`` (mid-point theta sampling needs
    ~2x the rings of Gauss-Legendre for comparable quadrature error),
    ``n_phi = 2 * l_max + 2`` (exact azimuthal quadrature, rfft-friendly).
    Per-sample weight is the exact latitude-band area
    ``2 pi (cos theta_{r-1/2} - cos theta_{r+1/2}) / n_phi``, so the
    weights sum to the sphere area exactly; the theta quadrature itself is
    approximate (like HEALPix, ``map2alm(iters>0)`` refines it).  Symmetric
    about the equator, so ``fold=True`` plans are eligible.
    """
    if n_rings is None:
        n_rings = 2 * (l_max + 1)
    if n_phi is None:
        n_phi = 2 * l_max + 2
    r = np.arange(n_rings, dtype=np.float64)
    theta = (r + 0.5) * np.pi / n_rings
    edge = np.cos(np.arange(n_rings + 1, dtype=np.float64) * np.pi / n_rings)
    band = 2.0 * np.pi * (edge[:-1] - edge[1:])          # exact band areas
    return RingGrid(
        name="ecp",
        cos_theta=np.cos(theta),
        sin_theta=np.sin(theta),
        weights=band / n_phi,
        n_phi=np.full(n_rings, n_phi, dtype=np.int64),
        phi0=np.zeros(n_rings, dtype=np.float64),
        uniform=True,
    )


# ---------------------------------------------------------------------------
# HEALPix-family grids
# ---------------------------------------------------------------------------


def _healpix_ring_geometry(nside: int):
    """Ring latitudes / counts / phases of the HEALPix ring scheme.

    Standard formulas (Gorski et al. 2005):
      north cap   i = 1..nside-1 : z = 1 - i^2/(3 nside^2),  n_phi = 4i,
                                   phi0 = pi / (4 i)
      equatorial  i = nside..3*nside : z = 4/3 - 2i/(3 nside),  n_phi = 4 nside,
                                   phi0 = (pi / (4 nside)) * ((i - nside + 1) % 2)
      south cap: mirror of the north cap.
    """
    assert nside >= 1
    zs, nphis, phi0s = [], [], []
    for i in range(1, nside):  # north polar cap
        zs.append(1.0 - (i * i) / (3.0 * nside * nside))
        nphis.append(4 * i)
        phi0s.append(np.pi / (4.0 * i))
    for i in range(nside, 3 * nside + 1):  # equatorial belt (incl. equator)
        zs.append(4.0 / 3.0 - 2.0 * i / (3.0 * nside))
        nphis.append(4 * nside)
        s = (i - nside + 1) % 2
        phi0s.append((np.pi / (4.0 * nside)) * s)
    for i in range(nside - 1, 0, -1):  # south polar cap
        zs.append(-(1.0 - (i * i) / (3.0 * nside * nside)))
        nphis.append(4 * i)
        phi0s.append(np.pi / (4.0 * i))
    z = np.asarray(zs, dtype=np.float64)
    n_phi = np.asarray(nphis, dtype=np.int64)
    phi0 = np.asarray(phi0s, dtype=np.float64)
    return z, n_phi, phi0


def healpix_grid(nside: int) -> RingGrid:
    """True HEALPix ring grid (ragged n_phi).  Equal-area sample weights."""
    z, n_phi, phi0 = _healpix_ring_geometry(nside)
    n_pix = 12 * nside * nside
    w_pix = 4.0 * np.pi / n_pix  # equal-area pixels
    r = z.shape[0]
    return RingGrid(
        name="healpix",
        cos_theta=z,
        sin_theta=np.sqrt(1.0 - z * z),
        weights=np.full(r, w_pix, dtype=np.float64),
        n_phi=n_phi,
        phi0=phi0,
        uniform=False,
        nside=nside,
    )


def healpix_ring_grid(nside: int) -> RingGrid:
    """Ring-uniform HEALPix variant: same latitudes & per-ring areas as
    HEALPix, but a uniform ``n_phi = 4*nside`` samples on every ring.

    The theta quadrature (and hence the approximate-analysis error behaviour,
    paper Fig. 8) is identical to HEALPix; the phi quadrature is exact for
    m < 2*nside on every ring.  This is the TPU-friendly variant: one batched
    FFT of length 4*nside serves every ring.
    """
    z, n_phi_true, phi0 = _healpix_ring_geometry(nside)
    n_pix = 12 * nside * nside
    ring_area = (4.0 * np.pi / n_pix) * n_phi_true  # true HEALPix ring areas
    n_phi_u = 4 * nside
    w_sample = ring_area / n_phi_u
    r = z.shape[0]
    return RingGrid(
        name="healpix_ring",
        cos_theta=z,
        sin_theta=np.sqrt(1.0 - z * z),
        weights=w_sample.astype(np.float64),
        n_phi=np.full(r, n_phi_u, dtype=np.int64),
        phi0=phi0,
        uniform=True,
        nside=nside,
    )


def make_grid(kind: str, *, l_max: Optional[int] = None,
              nside: Optional[int] = None, **kw) -> RingGrid:
    if kind == "gl":
        assert l_max is not None, "gl grid needs l_max"
        g = gauss_legendre_grid(l_max, **kw)
    elif kind == "ecp":
        assert l_max is not None, "ecp grid needs l_max"
        g = ecp_grid(l_max, **kw)
    elif kind == "healpix_ring":
        assert nside is not None, "healpix_ring grid needs nside"
        g = healpix_ring_grid(nside)
    elif kind == "healpix":
        assert nside is not None, "healpix grid needs nside"
        g = healpix_grid(nside)
    else:
        raise ValueError(f"unknown grid kind: {kind!r}")
    g.validate()
    return g
