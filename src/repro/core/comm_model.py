"""Alpha-beta performance model of the parallel SHT (paper §4.1.2).

Reproduces the paper's analysis (eq. 16-17 and Fig. 4): the single global
all-to-all exchanging the Delta arrays, modelled per MPICH's algorithm
switch (Bruck index algorithm for short messages, pairwise exchange for
long ones), against the gamma-per-flop compute model of the recurrence and
FFT stages.  Used by benchmarks/bench_scaling_model.py and, with TPU ICI
constants, by the roofline sanity checks.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CommParams", "MPICH_CLUSTER", "TPU_V5E_ICI", "sht_times",
           "sht_times_overlap", "best_chunks", "crossover_nproc"]


@dataclasses.dataclass(frozen=True)
class CommParams:
    """alpha: latency per message [s]; beta: inverse bandwidth [s/byte];
    gamma: seconds per flop of an MPI process / chip;
    bruck_cutoff: message size [bytes] below which the Bruck algorithm is
    assumed (paper: MPICH switches at 256 kB)."""
    alpha: float
    beta: float
    gamma: float
    bruck_cutoff: float = 256e3
    name: str = ""


# The paper's indicative constants (§4.1.2): alpha = 1e-5 s, beta = 1e-9 s/B,
# 10 Gflop/s effective per MPI process.
MPICH_CLUSTER = CommParams(alpha=1e-5, beta=1e-9, gamma=1e-10,
                           name="paper-cluster")

# TPU v5e ICI: ~50 GB/s per link, ~1 us effective collective latency,
# 197 Tflop/s bf16 peak with a realistic 40% recurrence efficiency.
TPU_V5E_ICI = CommParams(alpha=1e-6, beta=1.0 / 50e9,
                         gamma=1.0 / (0.4 * 197e12), name="tpu-v5e")


def message_size(r_n: int, m_max: int, n_proc: int, n_c: int = 16) -> float:
    """Paper eq. 16: bytes exchanged between each pair of processes."""
    return r_n * (m_max / n_proc) * n_c


def t_comm(r_n: int, m_max: int, n_proc: int, p: CommParams,
           n_c: int = 16) -> float:
    """Paper eq. 17: total all-to-all time."""
    if n_proc <= 1:
        return 0.0
    s = message_size(r_n, m_max, n_proc, n_c)
    if s <= p.bruck_cutoff:
        return p.alpha * np.log2(n_proc) + p.beta * s * (n_proc / 2.0) * np.log2(n_proc)
    return p.alpha * (n_proc - 1) + p.beta * s * (n_proc - 1)


def t_recurrence(r_n: int, l_max: int, m_max: int, n_proc: int,
                 p: CommParams, flops_per_step: float = 14.0,
                 fold: bool = False) -> float:
    """Legendre stage: O(R_N * l_max * m_max / n_proc) steps (paper Table 1).

    ``flops_per_step`` counts recurrence + rescale + accumulate per
    (ring, l, m) triple; the triangular l >= m structure contributes the 1/2.
    """
    steps = 0.5 * r_n * l_max * (m_max / n_proc)
    if fold:
        steps *= 0.75  # recurrence flops halve; accumulate flops unchanged
    return p.gamma * flops_per_step * steps


def t_fft(r_n: int, m_max: int, n_proc: int, p: CommParams,
          flops_per_point: float = 5.0) -> float:
    """FFT stage: O(R_N/n_proc * m_max log m_max) (paper Table 1)."""
    n = max(m_max, 2)
    return p.gamma * flops_per_point * (r_n / n_proc) * n * np.log2(n)


def t_precompute(m_max: int, p: CommParams) -> float:
    """Redundant seed precomputation, O(m_max) per process (paper Table 1)."""
    return p.gamma * 10.0 * m_max


def sht_times(n_side: int, n_proc: int, p: CommParams,
              l_max: int | None = None, fold: bool = False) -> dict:
    """Full model for a HEALPix-parameterised problem (paper Fig. 4 setup):
    l_max = m_max = 2 n_side, R_N = 4 n_side - 1."""
    l_max = 2 * n_side if l_max is None else l_max
    m_max = l_max
    r_n = 4 * n_side - 1
    comp = (t_recurrence(r_n, l_max, m_max, n_proc, p, fold=fold)
            + t_fft(r_n, m_max, n_proc, p) + t_precompute(m_max, p))
    comm = t_comm(r_n, m_max, n_proc, p)
    return {"compute": comp, "comm": comm, "total": comp + comm,
            "msg_bytes": message_size(r_n, m_max, n_proc)}


def sht_times_overlap(n_side: int, n_proc: int, p: CommParams,
                      chunks: int | None = None, l_max: int | None = None,
                      fold: bool = False, max_chunks: int = 256) -> dict:
    """Chunked-exchange pipeline model (the comm/compute-overlap analogue
    of the paper's eq. 16-17 serial sum).

    The Delta block is split into C chunks; chunk i's collective is
    issued while chunk i+1 computes, so the steady state advances at
    ``max(comp_chunk, comm_chunk)`` per chunk with one compute chunk of
    pipeline *fill* and one comm chunk of *drain*:

        t_overlap = comp/C + comm_chunk + (C-1) * max(comp/C, comm_chunk)
        comm_chunk = comm/C + alpha        (chunking splits the payload;
                                            every extra chunk pays one more
                                            collective-launch latency)

    ``chunks=None`` scans powers of two up to ``max_chunks`` and keeps the
    argmin.  ``hidden_frac`` reports the realised fraction of the
    *hideable* time ``min(comp, comm)`` -- the serial term a perfect
    pipeline removes from the critical path (in the communication-bound
    regime the paper's Fig. 4 predicts everywhere at scale, that is the
    whole compute stage disappearing behind the wire).
    """
    base = sht_times(n_side, n_proc, p, l_max=l_max, fold=fold)
    comp, comm = base["compute"], base["comm"]
    serial = comp + comm

    def total(c: int) -> float:
        if c <= 1 or n_proc <= 1 or comm <= 0.0:
            return serial
        comp_c = comp / c
        comm_c = comm / c + p.alpha
        return comp_c + comm_c + (c - 1) * max(comp_c, comm_c)

    if chunks is None:
        cands = [1 << k for k in range(0, 17) if (1 << k) <= max_chunks]
        chunks = min(cands, key=total)
    chunks = max(1, int(chunks))
    t = total(chunks)
    hideable = min(comp, comm)
    hidden = max(0.0, serial - t)
    return {**base, "chunks": chunks, "serial": serial, "overlap": t,
            "total": t, "hidden": hidden,
            "hidden_frac": hidden / hideable if hideable > 0 else 0.0}


def best_chunks(n_side: int, n_proc: int, p: CommParams,
                max_chunks: int = 256, l_max: int | None = None,
                fold: bool = False) -> int:
    """Model-optimal chunk count (argmin of `sht_times_overlap`)."""
    return int(sht_times_overlap(n_side, n_proc, p, chunks=None, l_max=l_max,
                                 fold=fold, max_chunks=max_chunks)["chunks"])


def crossover_nproc(n_side: int, p: CommParams, n_max: int = 1 << 16) -> int:
    """Smallest process count where comm >= compute (paper Fig. 4 right
    panel, the contour labelled 1.0)."""
    for k in range(0, 17):
        n = 1 << k
        if n > n_max:
            break
        t = sht_times(n_side, n, p)
        if t["comm"] >= t["compute"]:
            return n
    return n_max
