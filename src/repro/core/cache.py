"""Persistent precompute cache for transform plans.

The paper's precompute-vs-on-the-fly trade-off (§4.2.2), made explicit: a
plan's expensive host-side precomputation -- Gauss-Legendre nodes (Newton
iteration), ``pmm``/``pms`` recurrence seed tables, autotune decisions --
is cached by **plan signature** so repeated pipeline runs skip recompute.

Two tiers:

* **memory** -- a process-global dict keyed by signature hash.  Always
  consulted first; this is what makes a second ``make_plan`` with an
  identical signature free.
* **disk** -- ``.npz`` payloads (plus ``.json`` sidecars for autotune
  decisions) under ``$REPRO_CACHE_DIR`` (default ``~/.cache/repro_sht``),
  surviving across processes.  Written atomically (tmp + rename) so
  concurrent pipeline jobs never read torn files.

Every entry also records build/hit counters (`stats()`), which the tests
use to assert "no recompute" and `Plan.describe()` surfaces to users.

Payloads are flat ``dict[str, np.ndarray]`` (the npz model); anything
richer (autotune decisions) goes through the json decision store.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import warnings
from typing import Callable, Optional

import numpy as np

__all__ = [
    "CACHE_VERSION", "signature_key", "get_or_build", "cache_dir",
    "load_decision", "save_decision", "clear_memory", "clear_disk",
    "stats", "reset_stats", "LRU",
]

#: Bump when the payload layout of any cached builder changes; old disk
#: entries are then simply never matched (keys embed the version).
#: v2: autotune decisions gained the "fused" layout (PR 7) -- v1 decisions
#: would pin plans to staged-only choices.
CACHE_VERSION = 2

_MEMORY: dict[str, dict[str, np.ndarray]] = {}
_DECISIONS: dict[str, dict] = {}


@dataclasses.dataclass
class CacheStats:
    """Counters for cache behaviour; reset with :func:`reset_stats`."""

    builds: int = 0          # times a builder actually ran
    memory_hits: int = 0
    disk_hits: int = 0
    misses: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


_STATS = CacheStats()


class LRU:
    """Tiny bounded least-recently-used mapping.

    The unbounded signature caches above are right for precompute payloads
    (small, shared); live ``Plan`` objects are not -- each one owns seed
    tables and compiled executables -- so holders of *bounded* plan sets
    (the serving engine's warm pool) evict through this.  ``on_evict`` is
    called with ``(key, value)`` after removal so the holder can release
    external references (e.g. ``transform.drop_plan``).
    """

    def __init__(self, capacity: int, on_evict=None):
        assert capacity >= 1, capacity
        self.capacity = int(capacity)
        self._on_evict = on_evict
        self._data: dict = {}          # insertion-ordered; end = most recent
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def keys(self):
        return list(self._data)

    def get(self, key, default=None):
        """Fetch and mark ``key`` most-recently-used."""
        if key not in self._data:
            return default
        value = self._data.pop(key)
        self._data[key] = value
        return value

    def put(self, key, value) -> None:
        """Insert/refresh ``key``, evicting the LRU entry when full."""
        if key in self._data:
            self._data.pop(key)
        self._data[key] = value
        while len(self._data) > self.capacity:
            old_key = next(iter(self._data))
            old_val = self._data.pop(old_key)
            self.evictions += 1
            if self._on_evict is not None:
                self._on_evict(old_key, old_val)

    def pop(self, key, default=None):
        return self._data.pop(key, default)

    def clear(self) -> None:
        self._data.clear()


def stats() -> CacheStats:
    """The process-global cache counters (live object)."""
    return _STATS


def reset_stats() -> None:
    global _STATS
    _STATS = CacheStats()


def clear_memory() -> None:
    """Drop the in-memory tier (disk entries survive).  Test hook."""
    _MEMORY.clear()
    _DECISIONS.clear()


def clear_disk(directory: Optional[str] = None) -> int:
    """Remove the persistent tier under ``directory`` (default resolution
    as in :func:`cache_dir`).  Only files this layer wrote are touched --
    32-hex-digit signature names plus the ``chardb_<16-hex>`` hardware
    characterization stores, ``.npz``/``.json`` suffixes -- so a
    mis-pointed ``$REPRO_CACHE_DIR`` cannot wipe unrelated data.  Returns
    the number of entries removed; a missing directory is a no-op.
    """
    d = cache_dir(directory)
    if not os.path.isdir(d):
        return 0
    removed = 0
    for name in os.listdir(d):
        stem, dot, ext = name.rpartition(".")
        if ext not in ("npz", "json"):
            continue
        if stem.startswith("chardb_"):
            stem = stem[len("chardb_"):]
            if len(stem) != 16:
                continue
        elif len(stem) != 32:
            continue
        if not all(c in "0123456789abcdef" for c in stem):
            continue
        try:
            os.unlink(os.path.join(d, name))
            removed += 1
        except OSError:  # concurrent clear / permissions: best effort
            pass
    return removed


def cache_dir(override: Optional[str] = None) -> str:
    if override:
        return override
    env = os.environ.get("REPRO_CACHE_DIR")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro_sht")


def signature_key(kind: str, **fields) -> str:
    """Stable content hash of a plan-signature field dict.

    numpy arrays hash by value (shape + dtype + bytes), so a ``RingGrid``
    passed by instance keys identically to one rebuilt from the same spec.
    """
    h = hashlib.sha256()
    h.update(f"v{CACHE_VERSION}:{kind}".encode())
    for name in sorted(fields):
        v = fields[name]
        h.update(name.encode())
        if isinstance(v, np.ndarray):
            h.update(str(v.shape).encode())
            h.update(str(v.dtype).encode())
            h.update(np.ascontiguousarray(v).tobytes())
        else:
            h.update(repr(v).encode())
    return h.hexdigest()[:32]


def _atomic_write(path: str, write_fn: Callable[[str], None]) -> None:
    """Best-effort atomic persist: an unwritable cache dir degrades to
    memory-only caching (warn once) instead of failing the plan build."""
    tmp = None
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        os.close(fd)
        write_fn(tmp)
        os.replace(tmp, path)
    except OSError as e:
        warnings.warn(f"repro cache: cannot persist {path!r} ({e}); "
                      "falling back to in-memory caching", RuntimeWarning)
    finally:
        if tmp is not None and os.path.exists(tmp):
            os.unlink(tmp)


def get_or_build(key: str, builder: Callable[[], dict],
                 *, cache: str = "memory",
                 directory: Optional[str] = None) -> dict:
    """Return the payload for ``key``, building it at most once.

    cache: ``"off"`` (always build), ``"memory"`` (process-local), or
    ``"disk"`` (memory first, then ``<dir>/<key>.npz``, else build+persist).
    Builders return flat ``dict[str, np.ndarray]``.
    """
    if cache == "off":
        _STATS.builds += 1
        return builder()
    if key in _MEMORY:
        _STATS.memory_hits += 1
        return _MEMORY[key]
    if cache == "disk":
        path = os.path.join(cache_dir(directory), key + ".npz")
        if os.path.exists(path):
            try:
                with np.load(path, allow_pickle=False) as z:
                    payload = {k: z[k] for k in z.files}
                _STATS.disk_hits += 1
                _MEMORY[key] = payload
                return payload
            except Exception:
                pass  # torn/stale file: fall through and rebuild
    _STATS.misses += 1
    _STATS.builds += 1
    payload = builder()
    _MEMORY[key] = payload
    if cache == "disk":
        path = os.path.join(cache_dir(directory), key + ".npz")

        def write(tmp: str) -> None:
            # write through a file object: np.savez must not append ".npz"
            with open(tmp, "wb") as f:
                np.savez(f, **payload)

        _atomic_write(path, write)
    return payload


def load_decision(key: str, *, cache: str = "memory",
                  directory: Optional[str] = None) -> Optional[dict]:
    """Fetch a cached autotune decision (json-able dict) or None."""
    if cache == "off":
        return None
    if key in _DECISIONS:
        _STATS.memory_hits += 1
        return _DECISIONS[key]
    if cache == "disk":
        path = os.path.join(cache_dir(directory), key + ".json")
        if os.path.exists(path):
            try:
                with open(path) as f:
                    d = json.load(f)
                _STATS.disk_hits += 1
                _DECISIONS[key] = d
                return d
            except Exception:
                return None
    return None


def save_decision(key: str, decision: dict, *, cache: str = "memory",
                  directory: Optional[str] = None) -> None:
    if cache == "off":
        return
    _DECISIONS[key] = decision
    if cache == "disk":
        path = os.path.join(cache_dir(directory), key + ".json")

        def write(tmp: str) -> None:
            with open(tmp, "w") as f:
                json.dump(decision, f, indent=1, sort_keys=True)

        _atomic_write(path, write)
