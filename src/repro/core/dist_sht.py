"""Distributed spherical harmonic transforms (paper §4.1, Algorithm 3).

The two-stage structure, verbatim from the paper but phrased in shard_map:

  alm2map:  [m-sharded]  Delta^A_m(r) for local m, ALL rings   (Legendre)
            --- one global all_to_all (the paper's MPI_Alltoallv) ---
            [ring-sharded]  per-ring inverse FFTs for local rings, all m

  map2alm:  [ring-sharded]  per-ring forward FFTs (weights applied)
            --- one global all_to_all (reversed) ---
            [m-sharded]  a_lm projection for local m over ALL rings

Design notes (DESIGN.md §2):
* The SHTPlan pads the m list and the ring-pair list so every shard has
  identical slot counts: `lax.all_to_all(tiled=True)` replaces Alltoallv.
* Real/imag (and the K map batch) are packed into one trailing channel axis
  so each transform issues exactly ONE collective, like the paper.
* `fold=True` runs the Legendre recurrence on northern rings only
  (equatorial symmetry), the libpsht-style optimisation.
* `comm_dtype` optionally down-casts the Delta exchange (e.g. bfloat16) --
  the paper explicitly leaves lossy-compressed communication to future work
  (§4.1.2); we implement it and measure the accuracy cost in tests.
* `stage1` selects the jnp reference path or the Pallas kernel path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import legendre
from repro.core.plan import SHTPlan

__all__ = ["DistSHT"]


def _complex_dtype(real_dtype) -> jnp.dtype:
    return jnp.dtype(jnp.complex128 if jnp.dtype(real_dtype) == jnp.float64
                     else jnp.complex64)


@dataclasses.dataclass(frozen=True)
class DistSHT:
    """Distributed SHT bound to a plan, mesh and axis name(s).

    ``axis_names`` may be a single mesh axis or a tuple (the m/ring shards
    span the flattened product, e.g. ("data", "model") uses all 256 chips of
    a pod as one S^2HAT process ring).
    """

    plan: SHTPlan
    mesh: Mesh
    axis_names: tuple[str, ...]
    dtype: str = "float64"
    fold: bool = False
    comm_dtype: Optional[str] = None      # e.g. "bfloat16" for compressed Delta
    stage1: str = "jnp"                    # "jnp" | "pallas"

    def __post_init__(self):
        n = int(np.prod([self.mesh.shape[a] for a in self.axis_names]))
        assert n == self.plan.n_shards, (n, self.plan.n_shards)
        if self.fold:
            assert self.plan.grid.equator_symmetric

    # -- shardings -------------------------------------------------------------

    @property
    def _axis(self):
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]

    def alm_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def map_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _spec_sharded(self) -> P:
        return P(self.axis_names)

    # -- static geometry (closed over as constants) ------------------------------

    @functools.cached_property
    def _log_mu(self) -> np.ndarray:
        return legendre.log_mu(self.plan.m_max)

    @functools.cached_property
    def _geom(self):
        return self.plan.ring_geometry

    # -- stage 1: Legendre synthesis (m-sharded) ---------------------------------

    def _stage1_synth(self, a_re, a_im, m_loc):
        """Per-shard: (m_local, L, K) -> Delta (m_local, R_pad, K) x (re, im).

        Closes over the full ring geometry (every shard sees all rings).
        """
        p = self.plan
        dt = jnp.dtype(self.dtype)
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.delta_from_alm_auto(
                a_re, a_im, m_loc, self._geom, self._log_mu,
                l_max=p.l_max, fold=self.fold, dtype=dt)
        g = self._geom
        if not self.fold:
            return legendre.delta_from_alm(
                a_re, a_im, m_loc, g["cos_theta"], g["sin_theta"],
                self._log_mu, l_max=p.l_max, dtype=dt)
        nx = g["cos_theta"][0::2]
        ns = g["sin_theta"][0::2]
        ere, eim, ore_, oim = legendre.delta_from_alm_folded(
            a_re, a_im, m_loc, nx, ns, self._log_mu, l_max=p.l_max, dtype=dt)
        # interleave (E+O, E-O) back to plan slot order
        d_re = jnp.stack([ere + ore_, ere - ore_], axis=2)
        d_im = jnp.stack([eim + oim, eim - oim], axis=2)
        ml, npair, _, K = d_re.shape
        return (d_re.reshape(ml, 2 * npair, K), d_im.reshape(ml, 2 * npair, K))

    def _stage1_anal(self, dw_re, dw_im, m_loc):
        """Per-shard: weighted Delta^S (m_local, R_pad, K) -> alm (m_local, L, K)."""
        p = self.plan
        dt = jnp.dtype(self.dtype)
        g = self._geom
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.alm_from_delta_auto(
                dw_re, dw_im, m_loc, g, self._log_mu,
                l_max=p.l_max, fold=self.fold, dtype=dt)
        if not self.fold:
            ones = np.ones(p.r_pad)
            return legendre.alm_from_delta(
                dw_re, dw_im, m_loc, g["cos_theta"], g["sin_theta"], ones,
                self._log_mu, l_max=p.l_max, dtype=dt)
        nx = g["cos_theta"][0::2]
        ns = g["sin_theta"][0::2]
        n_re, s_re = dw_re[:, 0::2], dw_re[:, 1::2]
        n_im, s_im = dw_im[:, 0::2], dw_im[:, 1::2]
        return legendre.alm_from_delta_folded(
            n_re + s_re, n_im + s_im, n_re - s_re, n_im - s_im,
            m_loc, nx, ns, self._log_mu, l_max=p.l_max, dtype=dt)

    # -- stage 2: FFTs (ring-sharded), plan-slot m ordering ----------------------

    def _synth_fft(self, d_re, d_im, phi0_loc, w_dummy_loc):
        """(Mp, r_local, K) Delta -> (r_local, n_phi, K) samples."""
        p = self.plan
        n = p.grid.max_n_phi
        cdt = _complex_dtype(self.dtype)
        m_flat = p.m_flat                                  # static (Mp,)
        msafe = np.maximum(m_flat, 0)
        delta = (d_re + 1j * d_im).astype(cdt)
        phase = jnp.exp(1j * jnp.asarray(msafe, self.dtype)[:, None]
                        * phi0_loc[None, :]).astype(cdt)
        dp = delta * phase[..., None]
        dp = jnp.where(jnp.asarray(m_flat >= 0)[:, None, None], dp, 0.0)
        b = msafe % n
        hi = b > n // 2
        bins = np.where(hi, n - b, b)
        nyq = 2 * b == n
        half = n // 2 + 1
        vals = jnp.where(jnp.asarray(hi)[:, None, None], jnp.conj(dp), dp)
        vals = jnp.where(jnp.asarray(nyq)[:, None, None],
                         2.0 * jnp.real(vals).astype(cdt), vals)
        H = jnp.zeros((half,) + dp.shape[1:], cdt)
        H = H.at[jnp.asarray(bins)].add(vals)
        H = jnp.moveaxis(H, 0, 1)                          # (r_local, half, K)
        s = jnp.fft.irfft(H, n=n, axis=1) * n
        return s.astype(self.dtype) * w_dummy_loc[:, None, None]

    def _anal_fft(self, maps_loc, phi0_loc, w_loc):
        """(r_local, n_phi, K) samples -> weighted Delta^S (Mp, r_local, K)."""
        p = self.plan
        n = p.grid.max_n_phi
        cdt = _complex_dtype(self.dtype)
        m_flat = p.m_flat
        msafe = np.maximum(m_flat, 0)
        F = jnp.fft.rfft(maps_loc.astype(self.dtype), axis=1)  # (r_local, half, K)
        b = msafe % n
        hi = b > n // 2
        bins = np.where(hi, n - b, b)
        Fm = F[:, jnp.asarray(bins), :]
        Fm = jnp.where(jnp.asarray(hi)[None, :, None], jnp.conj(Fm), Fm)
        Fm = jnp.moveaxis(Fm, 1, 0).astype(cdt)                # (Mp, r_local, K)
        phase = jnp.exp(-1j * jnp.asarray(msafe, self.dtype)[:, None]
                        * phi0_loc[None, :]).astype(cdt)
        dw = Fm * phase[..., None] * w_loc[None, :, None]
        return jnp.real(dw).astype(self.dtype), jnp.imag(dw).astype(self.dtype)

    # -- collective ---------------------------------------------------------------

    def _exchange(self, x, *, to_rings: bool):
        """The paper's single global communication step.

        to_rings:  (m_local, R_pad, C) -> (Mp, r_local, C)
        else:      (Mp, r_local, C)    -> (m_local, R_pad, C)
        """
        if self.comm_dtype is not None:
            x = x.astype(self.comm_dtype)
        if to_rings:
            out = jax.lax.all_to_all(x, self._axis, split_axis=1,
                                     concat_axis=0, tiled=True)
        else:
            out = jax.lax.all_to_all(x, self._axis, split_axis=0,
                                     concat_axis=1, tiled=True)
        return out.astype(self.dtype)

    # -- public transforms ---------------------------------------------------------

    def _build(self, K: int):
        cache = getattr(self, "_built", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_built", cache)
        if K in cache:
            return cache[K]
        out = self._build_uncached(K)
        cache[K] = out
        return out

    def _build_uncached(self, K: int):
        p = self.plan
        geom = self._geom
        phi0_all = jnp.asarray(geom["phi0"], self.dtype)
        w_all = jnp.asarray(geom["weights"], self.dtype)
        valid_all = jnp.asarray(geom["valid"].astype(np.float64), self.dtype)
        m_flat = jnp.asarray(p.m_flat, jnp.int32)

        def synth_shard(a_re, a_im, m_loc, phi0_loc, valid_loc):
            d_re, d_im = self._stage1_synth(a_re, a_im, m_loc)
            packed = jnp.concatenate([d_re, d_im], axis=-1)     # (m_local, R_pad, 2K)
            packed = self._exchange(packed, to_rings=True)       # (Mp, r_local, 2K)
            d_re, d_im = packed[..., :K], packed[..., K:]
            return self._synth_fft(d_re, d_im, phi0_loc, valid_loc)

        def anal_shard(maps_loc, m_loc, phi0_loc, w_loc):
            dw_re, dw_im = self._anal_fft(maps_loc, phi0_loc, w_loc)
            packed = jnp.concatenate([dw_re, dw_im], axis=-1)    # (Mp, r_local, 2K)
            packed = self._exchange(packed, to_rings=False)      # (m_local, R_pad, 2K)
            dw_re, dw_im = packed[..., :K], packed[..., K:]
            return self._stage1_anal(dw_re, dw_im, m_loc)

        spec = self._spec_sharded()
        # The compat shim disables the replication/VMA tracker: the
        # Legendre loop carries are seeded from constants (unvarying) and
        # become shard-varying inside the loop; we opt out rather than
        # pcast-ing deep inside the shared recurrence code.
        synth = jax.jit(compat.shard_map(
            synth_shard, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, spec),
            out_specs=spec))
        anal = jax.jit(compat.shard_map(
            anal_shard, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec),
            out_specs=(spec, spec)))
        consts = dict(phi0=phi0_all, w=w_all, valid=valid_all, m_flat=m_flat)
        return synth, anal, consts

    def alm2map(self, alm_packed):
        """Packed plan-layout alm (Mp, L, K) complex -> maps (R_pad, n_phi, K).

        Input rows follow plan.m_flat; use plan.pack_alm / plan.scatter_map
        for dense-layout conversion.  Output rows follow plan.ring_order.
        """
        K = alm_packed.shape[-1]
        synth, _, c = self._build(K)
        a_re = jnp.real(alm_packed).astype(self.dtype)
        a_im = jnp.imag(alm_packed).astype(self.dtype)
        return synth(a_re, a_im, c["m_flat"], c["phi0"], c["valid"])

    def map2alm(self, maps_plan):
        """maps (R_pad, n_phi, K) in plan ring order -> packed alm (Mp, L, K)."""
        K = maps_plan.shape[-1]
        _, anal, c = self._build(K)
        a_re, a_im = anal(maps_plan.astype(self.dtype), c["m_flat"],
                          c["phi0"], c["w"])
        return a_re + 1j * a_im

    # -- shape-only entry points for the dry-run -----------------------------------

    def lower_synth(self, K: int):
        """Return (lowered, input ShapeDtypeStructs) for the dry-run."""
        p = self.plan
        synth, _, c = self._build(K)
        sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
        sh = self.alm_sharding()
        Mp = p.n_shards * p.m_local
        args = (
            jax.ShapeDtypeStruct((Mp, p.l_max + 1, K), jnp.dtype(self.dtype), sharding=sh),
            jax.ShapeDtypeStruct((Mp, p.l_max + 1, K), jnp.dtype(self.dtype), sharding=sh),
            c["m_flat"], c["phi0"], c["valid"],
        )
        return synth.lower(*args), args

    def lower_anal(self, K: int):
        p = self.plan
        _, anal, c = self._build(K)
        sh = self.map_sharding()
        args = (
            jax.ShapeDtypeStruct((p.r_pad, p.grid.max_n_phi, K),
                                 jnp.dtype(self.dtype), sharding=sh),
            c["m_flat"], c["phi0"], c["w"],
        )
        return anal.lower(*args), args
