"""Distributed spherical harmonic transforms (paper §4.1, Algorithm 3).

The two-stage structure, verbatim from the paper but phrased in shard_map:

  alm2map:  [m-sharded]  Delta^A_m(r) for local m, ALL rings   (Legendre)
            --- one global all_to_all (the paper's MPI_Alltoallv) ---
            [ring-sharded]  per-ring inverse FFTs for local rings, all m

  map2alm:  [ring-sharded]  per-ring forward FFTs (weights applied)
            --- one global all_to_all (reversed) ---
            [m-sharded]  a_lm projection for local m over ALL rings

Design notes (DESIGN.md §2):
* The SHTPlan pads the m list and the ring-pair list so every shard has
  identical slot counts: `lax.all_to_all(tiled=True)` replaces Alltoallv.
* Real/imag (and the K map batch) are packed into one trailing channel axis
  so each transform issues exactly ONE collective, like the paper.
* `fold=True` runs the Legendre recurrence on northern rings only
  (equatorial symmetry), the libpsht-style optimisation.
* `comm_dtype` optionally down-casts the Delta exchange (e.g. bfloat16) --
  the paper explicitly leaves lossy-compressed communication to future work
  (§4.1.2); we implement it and measure the accuracy cost in tests.
* `stage1` selects the jnp reference path or the Pallas kernel path.
* `comm_chunks = C > 1` replaces the monolithic exchange with a chunked,
  software-pipelined one: the Delta block is split into C chunks along the
  K map-batch axis (or the local m rows when K is too small, see
  `SHTPlan.chunk_schedule`), and each chunk runs its own stage-1 compute +
  all_to_all.  The chunks are data-independent, so XLA's latency-hiding
  scheduler can keep chunk i's collective in flight while chunk i+1's
  Legendre recurrence (synthesis) or chunk i-1's projection (analysis)
  computes -- the libsharp-style comm/compute overlap the scaling model
  says the distributed path is starved for.  Chunking is a pure
  reordering of independent per-(m, k) work: outputs are bit-identical to
  the monolithic path (tests/helpers/dist_chunk_check.py), and every
  chunk exchange is still `lax.all_to_all`, so the adjoint contract
  (transposed reverse exchange) survives unchanged.
* Both transforms are differentiable inside shard_map: stage 1 and the
  phase stage carry adjoint-based custom VJP/JVP rules (linear_call
  transposes), and `lax.all_to_all` transposes to the reverse exchange --
  so `jax.grad` of a loss through `alm2map`/`map2alm` runs the
  opposite-direction two-stage transform with the same single collective
  (checked by the gradchecks in tests/helpers/dist_sht_check.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import legendre
from repro.core import phase as phaselib
from repro.core.plan import SHTPlan

__all__ = ["DistSHT"]


def _complex_dtype(real_dtype) -> jnp.dtype:
    return jnp.dtype(jnp.complex128 if jnp.dtype(real_dtype) == jnp.float64
                     else jnp.complex64)


@dataclasses.dataclass(frozen=True)
class DistSHT:
    """Distributed SHT bound to a plan, mesh and axis name(s).

    ``axis_names`` may be a single mesh axis or a tuple (the m/ring shards
    span the flattened product, e.g. ("data", "model") uses all 256 chips of
    a pod as one S^2HAT process ring).
    """

    plan: SHTPlan
    mesh: Mesh
    axis_names: tuple[str, ...]
    dtype: str = "float64"
    fold: bool = False
    comm_dtype: Optional[str] = None      # e.g. "bfloat16" for compressed Delta
    stage1: str = "jnp"                    # "jnp" | "pallas"
    comm_chunks: Optional[int] = None      # None -> plan.comm_chunks; C>1 =
                                           # chunked pipelined exchange

    def __post_init__(self):
        n = int(np.prod([self.mesh.shape[a] for a in self.axis_names]))
        assert n == self.plan.n_shards, (n, self.plan.n_shards)
        if self.fold:
            assert self.plan.grid.equator_symmetric
        assert self._comm_chunks >= 1, self.comm_chunks

    @property
    def _comm_chunks(self) -> int:
        c = self.plan.comm_chunks if self.comm_chunks is None \
            else self.comm_chunks
        return max(1, int(c))

    # -- shardings -------------------------------------------------------------

    @property
    def _axis(self):
        return self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]

    def alm_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def map_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _spec_sharded(self) -> P:
        return P(self.axis_names)

    # -- static geometry (closed over as constants) ------------------------------

    @functools.cached_property
    def _log_mu(self) -> np.ndarray:
        return legendre.log_mu(self.plan.m_max)

    @functools.cached_property
    def _geom(self):
        return self.plan.ring_geometry

    # -- stage 1: Legendre synthesis (m-sharded) ---------------------------------

    def _stage1_synth(self, a_re, a_im, m_loc):
        """Per-shard: (m_local, L, K) -> Delta (m_local, R_pad, K) x (re, im).

        Closes over the full ring geometry (every shard sees all rings).
        """
        p = self.plan
        dt = jnp.dtype(self.dtype)
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.delta_from_alm_auto(
                a_re, a_im, m_loc, self._geom, self._log_mu,
                l_max=p.l_max, fold=self.fold, dtype=dt)
        g = self._geom
        if not self.fold:
            return legendre.delta_from_alm(
                a_re, a_im, m_loc, g["cos_theta"], g["sin_theta"],
                self._log_mu, l_max=p.l_max, dtype=dt)
        nx = g["cos_theta"][0::2]
        ns = g["sin_theta"][0::2]
        ere, eim, ore_, oim = legendre.delta_from_alm_folded(
            a_re, a_im, m_loc, nx, ns, self._log_mu, l_max=p.l_max, dtype=dt)
        # interleave (E+O, E-O) back to plan slot order
        d_re = jnp.stack([ere + ore_, ere - ore_], axis=2)
        d_im = jnp.stack([eim + oim, eim - oim], axis=2)
        ml, npair, _, K = d_re.shape
        return (d_re.reshape(ml, 2 * npair, K), d_im.reshape(ml, 2 * npair, K))

    def _stage1_anal(self, dw_re, dw_im, m_loc):
        """Per-shard: weighted Delta^S (m_local, R_pad, K) -> alm (m_local, L, K)."""
        p = self.plan
        dt = jnp.dtype(self.dtype)
        g = self._geom
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.alm_from_delta_auto(
                dw_re, dw_im, m_loc, g, self._log_mu,
                l_max=p.l_max, fold=self.fold, dtype=dt)
        if not self.fold:
            ones = np.ones(p.r_pad)
            return legendre.alm_from_delta(
                dw_re, dw_im, m_loc, g["cos_theta"], g["sin_theta"], ones,
                self._log_mu, l_max=p.l_max, dtype=dt)
        nx = g["cos_theta"][0::2]
        ns = g["sin_theta"][0::2]
        n_re, s_re = dw_re[:, 0::2], dw_re[:, 1::2]
        n_im, s_im = dw_im[:, 0::2], dw_im[:, 1::2]
        return legendre.alm_from_delta_folded(
            n_re + s_re, n_im + s_im, n_re - s_re, n_im - s_im,
            m_loc, nx, ns, self._log_mu, l_max=p.l_max, dtype=dt)

    # -- spin-2 stage 1 (two stacked Wigner-d recurrences per shard) -------------

    def _stage1_synth_spin(self, e_re, e_im, b_re, b_im, m_loc):
        """Per-shard spin-2 Legendre synthesis: (E, B) (m_local, L, K) ->
        (dq_re, dq_im, du_re, du_im), each (m_local, R_pad, K)."""
        p = self.plan
        dt = jnp.dtype(self.dtype)
        g = self._geom
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.delta_from_alm_spin_auto(
                e_re, e_im, b_re, b_im, m_loc, g, l_max=p.l_max,
                m_max=p.m_max, dtype=dt)
        return legendre.delta_from_alm_spin(
            e_re, e_im, b_re, b_im, m_loc, g["cos_theta"], g["sin_theta"],
            l_max=p.l_max, m_max=p.m_max, dtype=dt)

    def _stage1_anal_spin(self, dq_re, dq_im, du_re, du_im, m_loc):
        """Per-shard spin-2 Legendre analysis: weighted (Delta_Q, Delta_U)
        (m_local, R_pad, K) -> (e_re, e_im, b_re, b_im) (m_local, L, K)."""
        p = self.plan
        dt = jnp.dtype(self.dtype)
        g = self._geom
        if self.stage1 == "pallas":
            from repro.kernels import ops as kops
            return kops.alm_from_delta_spin_auto(
                dq_re, dq_im, du_re, du_im, m_loc, g, l_max=p.l_max,
                m_max=p.m_max, dtype=dt)
        return legendre.alm_from_delta_spin(
            dq_re, dq_im, du_re, du_im, m_loc, g["cos_theta"],
            g["sin_theta"], l_max=p.l_max, m_max=p.m_max, dtype=dt)

    # -- stage 2: FFTs (ring-sharded), plan-slot m ordering ----------------------
    #
    # Both directions delegate to the pluggable phase layer
    # (repro.core.phase): the batched-rfft engine for uniform grids, the
    # ring-bucket engine for ragged (true HEALPix) ones.  Every shard runs
    # the same static bucket structure (plan.local_fft_layout); the
    # per-slot geometry and alias-fold bin maps arrive as *sharded
    # operands* so one SPMD program serves all shards.

    def _synth_fft(self, d_re, d_im, phi0_loc, w_dummy_loc, fft_ops=()):
        """(Mp, r_local, K) Delta -> (r_local, n_phi, K) samples."""
        p = self.plan
        cdt = _complex_dtype(self.dtype)
        delta = (d_re + 1j * d_im).astype(cdt)
        if p.grid.uniform:
            return phaselib.uniform_synth(
                delta, p.m_flat, p.grid.max_n_phi, phi0_loc,
                dtype=self.dtype, scale_rows=w_dummy_loc)
        n_loc, pos_loc, neg_loc = fft_ops
        return phaselib.bucket_synth(
            delta, p.local_fft_layout, pos_loc.T, neg_loc.T, n_loc,
            phi0_loc, p.m_flat, out_width=p.grid.max_n_phi,
            dtype=self.dtype, scale_rows=w_dummy_loc)

    def _anal_fft(self, maps_loc, phi0_loc, w_loc, fft_ops=()):
        """(r_local, n_phi, K) samples -> weighted Delta^S (Mp, r_local, K)."""
        p = self.plan
        if p.grid.uniform:
            dw = phaselib.uniform_anal(
                maps_loc, p.m_flat, p.grid.max_n_phi, phi0_loc, w_loc,
                dtype=self.dtype)
        else:
            n_loc, pos_loc = fft_ops
            dw = phaselib.bucket_anal(
                maps_loc, p.local_fft_layout, pos_loc.T, n_loc, phi0_loc,
                w_loc, p.m_flat, dtype=self.dtype)
        return jnp.real(dw).astype(self.dtype), jnp.imag(dw).astype(self.dtype)

    # -- collective ---------------------------------------------------------------

    def _exchange(self, x, *, to_rings: bool):
        """The paper's global communication step (one per chunk).

        to_rings:  (m_local, R_pad, C) -> (Mp, r_local, C)
        else:      (Mp, r_local, C)    -> (m_local, R_pad, C)
        """
        n = self.plan.n_shards
        split_axis = 1 if to_rings else 0
        what = "dealt ring-pair slot" if to_rings else "dealt m-row slot"
        if x.shape[split_axis] % n != 0:
            raise ValueError(
                f"all_to_all(tiled=True) needs the {what} count to be a "
                f"multiple of the device count: axis {split_axis} has "
                f"{x.shape[split_axis]} slots but the mesh "
                f"{dict(self.mesh.shape)} spans {n} devices over axes "
                f"{self.axis_names} (shape {x.shape})")
        if self.comm_dtype is not None:
            x = x.astype(self.comm_dtype)
        if to_rings:
            out = jax.lax.all_to_all(x, self._axis, split_axis=1,
                                     concat_axis=0, tiled=True)
        else:
            out = jax.lax.all_to_all(x, self._axis, split_axis=0,
                                     concat_axis=1, tiled=True)
        return out.astype(self.dtype)

    # -- chunked pipelined exchange helpers ----------------------------------
    #
    # Each chunk is an independent (stage-1 compute, all_to_all) pair: the
    # loops below emit C data-independent collectives interleaved with the
    # adjacent chunks' compute, which is exactly the dependence structure an
    # async/latency-hiding scheduler needs to keep the wire and the ALUs
    # busy at the same time.  Numerically this is a pure reordering of
    # per-(m, k)-independent work, so results match the monolithic path
    # bit-for-bit.

    def _schedule(self, K: int, ncomp: int = 1):
        return self.plan.chunk_schedule(K, ncomp=ncomp,
                                        chunks=self._comm_chunks)

    def _merge_m_chunks(self, parts):
        """Exchanged m-chunks [(n*mc_j, r_local, C)] -> (Mp, r_local, C).

        Each chunk's global rows are shard-major over that chunk's slice
        of the local m rows; re-interleave so the full plan slot order
        (shard-major over m_local) is restored exactly.
        """
        n = self.plan.n_shards
        segs = [p.reshape((n, p.shape[0] // n) + p.shape[1:]) for p in parts]
        cat = jnp.concatenate(segs, axis=1)
        return cat.reshape((n * cat.shape[1],) + cat.shape[2:])

    def _split_m_chunk(self, packed, m0: int, m1: int):
        """(Mp, r_local, C) plan-order rows -> the (n*(m1-m0), r_local, C)
        block holding local rows [m0, m1) of every shard (inverse of one
        `_merge_m_chunks` segment)."""
        n = self.plan.n_shards
        g = packed.reshape((n, packed.shape[0] // n) + packed.shape[1:])
        piece = g[:, m0:m1]
        return piece.reshape((n * (m1 - m0),) + packed.shape[1:])

    # -- public transforms ---------------------------------------------------------

    def _build(self, K: int, spin: int = 0):
        cache = getattr(self, "_built", None)
        if cache is None:
            cache = {}
            object.__setattr__(self, "_built", cache)
        key = (spin, K)
        if key in cache:
            return cache[key]
        out = self._build_uncached(K) if spin == 0 \
            else self._build_spin_uncached(K)
        cache[key] = out
        return out

    def _consts(self):
        """Static per-slot operands closed over by the shard programs."""
        p = self.plan
        geom = self._geom
        phi0_all = jnp.asarray(geom["phi0"], self.dtype)
        w_all = jnp.asarray(geom["weights"], self.dtype)
        valid_all = jnp.asarray(geom["valid"].astype(np.float64), self.dtype)
        m_flat = jnp.asarray(p.m_flat, jnp.int32)
        # ragged grids: per-slot FFT geometry + precomputed alias-fold bin
        # maps ride along as ring-sharded operands (plan.fft_bin_maps)
        if p.grid.uniform:
            synth_ops = anal_ops = ()
        else:
            pos_all, neg_all = p.fft_bin_maps            # (R_pad, Mp) int32
            n_all = jnp.asarray(geom["n_phi"], jnp.int32)
            synth_ops = (n_all, jnp.asarray(pos_all), jnp.asarray(neg_all))
            anal_ops = (n_all, jnp.asarray(pos_all))
        return dict(phi0=phi0_all, w=w_all, valid=valid_all, m_flat=m_flat,
                    synth_ops=synth_ops, anal_ops=anal_ops)

    def _build_uncached(self, K: int):
        consts = self._consts()
        synth_ops, anal_ops = consts["synth_ops"], consts["anal_ops"]
        axis, bounds = self._schedule(K)

        def synth_shard(a_re, a_im, m_loc, phi0_loc, valid_loc, *fft_ops):
            if axis == "k":
                # chunk i's collective is issued while chunk i+1's Legendre
                # recurrence runs (the chunks share no data)
                parts = []
                for k0, k1 in bounds:
                    d_re, d_im = self._stage1_synth(
                        a_re[..., k0:k1], a_im[..., k0:k1], m_loc)
                    parts.append(self._exchange(
                        jnp.concatenate([d_re, d_im], axis=-1),
                        to_rings=True))                 # (Mp, r_local, 2kc)
                d_re = jnp.concatenate(
                    [p[..., : p.shape[-1] // 2] for p in parts], axis=-1)
                d_im = jnp.concatenate(
                    [p[..., p.shape[-1] // 2:] for p in parts], axis=-1)
            elif axis == "m":
                parts = []
                for m0, m1 in bounds:
                    d_re, d_im = self._stage1_synth(
                        a_re[m0:m1], a_im[m0:m1], m_loc[m0:m1])
                    parts.append(self._exchange(
                        jnp.concatenate([d_re, d_im], axis=-1),
                        to_rings=True))              # (n*mc, r_local, 2K)
                packed = self._merge_m_chunks(parts)   # (Mp, r_local, 2K)
                d_re, d_im = packed[..., :K], packed[..., K:]
            else:
                d_re, d_im = self._stage1_synth(a_re, a_im, m_loc)
                packed = jnp.concatenate([d_re, d_im], axis=-1)  # (m_local, R_pad, 2K)
                packed = self._exchange(packed, to_rings=True)   # (Mp, r_local, 2K)
                d_re, d_im = packed[..., :K], packed[..., K:]
            return self._synth_fft(d_re, d_im, phi0_loc, valid_loc, fft_ops)

        def anal_shard(maps_loc, m_loc, phi0_loc, w_loc, *fft_ops):
            if axis == "k":
                # chunk i's collective overlaps chunk i-1's projection and
                # chunk i+1's FFT
                res = []
                for k0, k1 in bounds:
                    dw_re, dw_im = self._anal_fft(
                        maps_loc[..., k0:k1], phi0_loc, w_loc, fft_ops)
                    packed = self._exchange(
                        jnp.concatenate([dw_re, dw_im], axis=-1),
                        to_rings=False)              # (m_local, R_pad, 2kc)
                    kc = k1 - k0
                    res.append(self._stage1_anal(
                        packed[..., :kc], packed[..., kc:], m_loc))
                return (jnp.concatenate([r[0] for r in res], axis=-1),
                        jnp.concatenate([r[1] for r in res], axis=-1))
            if axis == "m":
                dw_re, dw_im = self._anal_fft(maps_loc, phi0_loc, w_loc,
                                              fft_ops)
                full = jnp.concatenate([dw_re, dw_im], axis=-1)  # (Mp, r, 2K)
                res = []
                for m0, m1 in bounds:
                    packed = self._exchange(
                        self._split_m_chunk(full, m0, m1),
                        to_rings=False)                  # (mc, R_pad, 2K)
                    res.append(self._stage1_anal(
                        packed[..., :K], packed[..., K:], m_loc[m0:m1]))
                return (jnp.concatenate([r[0] for r in res], axis=0),
                        jnp.concatenate([r[1] for r in res], axis=0))
            dw_re, dw_im = self._anal_fft(maps_loc, phi0_loc, w_loc, fft_ops)
            packed = jnp.concatenate([dw_re, dw_im], axis=-1)    # (Mp, r_local, 2K)
            packed = self._exchange(packed, to_rings=False)      # (m_local, R_pad, 2K)
            dw_re, dw_im = packed[..., :K], packed[..., K:]
            return self._stage1_anal(dw_re, dw_im, m_loc)

        spec = self._spec_sharded()
        # The compat shim disables the replication/VMA tracker: the
        # Legendre loop carries are seeded from constants (unvarying) and
        # become shard-varying inside the loop; we opt out rather than
        # pcast-ing deep inside the shared recurrence code.
        synth = jax.jit(compat.shard_map(
            synth_shard, mesh=self.mesh,
            in_specs=(spec,) * (5 + len(synth_ops)),
            out_specs=spec))
        anal = jax.jit(compat.shard_map(
            anal_shard, mesh=self.mesh,
            in_specs=(spec,) * (4 + len(anal_ops)),
            out_specs=(spec, spec)))
        return synth, anal, consts

    def _build_spin_uncached(self, K: int):
        """Spin-2 shard programs.  Identical two-stage structure: the
        (Q, U) / (E, B) component pair is packed into the trailing channel
        axis (2K complex channels through the phase stage, 4K real
        channels through the ONE all_to_all), so the exchange count and
        the bucketed phase stage are untouched."""
        assert not self.fold, "fold is not supported for spin transforms"
        consts = self._consts()
        synth_ops, anal_ops = consts["synth_ops"], consts["anal_ops"]
        # the (Q, U) pair is coupled through the Wigner lambda^{+/-} pair,
        # so chunk boundaries ride the K axis only (ncomp channels stay
        # inside each chunk) -- or fall back to m rows for small K.
        axis, bounds = self._schedule(K, ncomp=2)

        def _synth_one(e_re, e_im, b_re, b_im, m_loc):
            """Stage 1 + exchange for one chunk -> packed (Mp, r, 4kc)."""
            dq_re, dq_im, du_re, du_im = self._stage1_synth_spin(
                e_re, e_im, b_re, b_im, m_loc)
            packed = jnp.concatenate([dq_re, du_re, dq_im, du_im],
                                     axis=-1)          # (m_local, R_pad, 4kc)
            return self._exchange(packed, to_rings=True)

        def synth_shard(e_re, e_im, b_re, b_im, m_loc, phi0_loc, valid_loc,
                        *fft_ops):
            if axis == "k":
                parts = [_synth_one(e_re[..., k0:k1], e_im[..., k0:k1],
                                    b_re[..., k0:k1], b_im[..., k0:k1], m_loc)
                         for k0, k1 in bounds]
                quad = [[p.reshape(p.shape[:-1] + (4, p.shape[-1] // 4))
                         [..., c, :] for p in parts] for c in range(4)]
                d_re = jnp.concatenate(quad[0] + quad[1], axis=-1)  # [Q|U] re
                d_im = jnp.concatenate(quad[2] + quad[3], axis=-1)  # [Q|U] im
            elif axis == "m":
                parts = [_synth_one(e_re[m0:m1], e_im[m0:m1], b_re[m0:m1],
                                    b_im[m0:m1], m_loc[m0:m1])
                         for m0, m1 in bounds]
                packed = self._merge_m_chunks(parts)     # (Mp, r_local, 4K)
                d_re, d_im = packed[..., :2 * K], packed[..., 2 * K:]
            else:
                packed = _synth_one(e_re, e_im, b_re, b_im, m_loc)
                d_re, d_im = packed[..., :2 * K], packed[..., 2 * K:]
            return self._synth_fft(d_re, d_im, phi0_loc, valid_loc, fft_ops)

        def _anal_one(maps_c, kc, m_loc, phi0_loc, w_loc, fft_ops):
            """FFT + exchange + stage 1 for one (r_local, n_phi, 2kc) chunk."""
            dw_re, dw_im = self._anal_fft(maps_c, phi0_loc, w_loc, fft_ops)
            packed = jnp.concatenate([dw_re, dw_im], axis=-1)  # (Mp, r, 4kc)
            packed = self._exchange(packed, to_rings=False)
            dq_re, du_re = packed[..., :kc], packed[..., kc:2 * kc]
            dq_im, du_im = packed[..., 2 * kc:3 * kc], packed[..., 3 * kc:]
            return self._stage1_anal_spin(dq_re, dq_im, du_re, du_im, m_loc)

        def anal_shard(maps_loc, m_loc, phi0_loc, w_loc, *fft_ops):
            # maps_loc: (r_local, n_phi, 2K) = [Q | U] channels
            if axis == "k":
                res = []
                for k0, k1 in bounds:
                    maps_c = jnp.concatenate(
                        [maps_loc[..., k0:k1], maps_loc[..., K + k0:K + k1]],
                        axis=-1)
                    res.append(_anal_one(maps_c, k1 - k0, m_loc, phi0_loc,
                                         w_loc, fft_ops))
                return tuple(jnp.concatenate([r[c] for r in res], axis=-1)
                             for c in range(4))
            if axis == "m":
                dw_re, dw_im = self._anal_fft(maps_loc, phi0_loc, w_loc,
                                              fft_ops)
                full = jnp.concatenate([dw_re, dw_im], axis=-1)  # (Mp, r, 4K)
                res = []
                for m0, m1 in bounds:
                    packed = self._exchange(
                        self._split_m_chunk(full, m0, m1), to_rings=False)
                    dq_re, du_re = packed[..., :K], packed[..., K:2 * K]
                    dq_im = packed[..., 2 * K:3 * K]
                    du_im = packed[..., 3 * K:]
                    res.append(self._stage1_anal_spin(
                        dq_re, dq_im, du_re, du_im, m_loc[m0:m1]))
                return tuple(jnp.concatenate([r[c] for r in res], axis=0)
                             for c in range(4))
            return _anal_one(maps_loc, K, m_loc, phi0_loc, w_loc, fft_ops)

        spec = self._spec_sharded()
        synth = jax.jit(compat.shard_map(
            synth_shard, mesh=self.mesh,
            in_specs=(spec,) * (7 + len(synth_ops)),
            out_specs=spec))
        anal = jax.jit(compat.shard_map(
            anal_shard, mesh=self.mesh,
            in_specs=(spec,) * (4 + len(anal_ops)),
            out_specs=(spec,) * 4))
        return synth, anal, consts

    def alm2map(self, alm_packed):
        """Packed plan-layout alm (Mp, L, K) complex -> maps (R_pad, n_phi, K).

        Input rows follow plan.m_flat; use plan.pack_alm / plan.scatter_map
        for dense-layout conversion.  Output rows follow plan.ring_order.
        """
        K = alm_packed.shape[-1]
        synth, _, c = self._build(K)
        a_re = jnp.real(alm_packed).astype(self.dtype)
        a_im = jnp.imag(alm_packed).astype(self.dtype)
        return synth(a_re, a_im, c["m_flat"], c["phi0"], c["valid"],
                     *c["synth_ops"])

    def map2alm(self, maps_plan):
        """maps (R_pad, n_phi, K) in plan ring order -> packed alm (Mp, L, K)."""
        K = maps_plan.shape[-1]
        _, anal, c = self._build(K)
        a_re, a_im = anal(maps_plan.astype(self.dtype), c["m_flat"],
                          c["phi0"], c["w"], *c["anal_ops"])
        return a_re + 1j * a_im

    def alm2map_spin(self, alm_packed_eb):
        """Spin-2 synthesis: packed (E, B) alm (2, Mp, L, K) complex ->
        (Q, U) maps (2, R_pad, n_phi, K) in plan ring order."""
        K = alm_packed_eb.shape[-1]
        synth, _, c = self._build(K, spin=2)
        e, b = alm_packed_eb[0], alm_packed_eb[1]
        args = [jnp.real(e), jnp.imag(e), jnp.real(b), jnp.imag(b)]
        args = [a.astype(self.dtype) for a in args]
        maps2 = synth(*args, c["m_flat"], c["phi0"], c["valid"],
                      *c["synth_ops"])               # (R_pad, n_phi, 2K)
        return jnp.stack([maps2[..., :K], maps2[..., K:]], axis=0)

    def map2alm_spin(self, maps_plan_qu):
        """Spin-2 analysis: (Q, U) maps (2, R_pad, n_phi, K) in plan ring
        order -> packed (E, B) alm (2, Mp, L, K) complex."""
        K = maps_plan_qu.shape[-1]
        _, anal, c = self._build(K, spin=2)
        maps2 = jnp.concatenate([maps_plan_qu[0], maps_plan_qu[1]],
                                axis=-1).astype(self.dtype)
        e_re, e_im, b_re, b_im = anal(maps2, c["m_flat"], c["phi0"],
                                      c["w"], *c["anal_ops"])
        return jnp.stack([e_re + 1j * e_im, b_re + 1j * b_im], axis=0)

    # -- shape-only entry points for the dry-run -----------------------------------

    def lower_synth(self, K: int):
        """Return (lowered, input ShapeDtypeStructs) for the dry-run."""
        p = self.plan
        synth, _, c = self._build(K)
        sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, jnp.dtype(dt))
        sh = self.alm_sharding()
        Mp = p.n_shards * p.m_local
        args = (
            jax.ShapeDtypeStruct((Mp, p.l_max + 1, K), jnp.dtype(self.dtype), sharding=sh),
            jax.ShapeDtypeStruct((Mp, p.l_max + 1, K), jnp.dtype(self.dtype), sharding=sh),
            c["m_flat"], c["phi0"], c["valid"], *c["synth_ops"],
        )
        return synth.lower(*args), args

    def lower_anal(self, K: int):
        p = self.plan
        _, anal, c = self._build(K)
        sh = self.map_sharding()
        args = (
            jax.ShapeDtypeStruct((p.r_pad, p.grid.max_n_phi, K),
                                 jnp.dtype(self.dtype), sharding=sh),
            c["m_flat"], c["phi0"], c["w"], *c["anal_ops"],
        )
        return anal.lower(*args), args
