"""SHTPlan: the data-distribution plan for the parallel transforms.

Encodes the paper's §4.1.1 layout decisions as static (numpy, host-side)
arrays consumed by ``dist_sht``:

* **m distribution with min-max pairing** (paper Fig. 5): the global m list
  is reordered as [0, m_max, 1, m_max-1, ...] and pairs are dealt
  round-robin to shards, so every shard's total recurrence length is the
  paper's invariant  sum over pairs of (2 l_max - m_max + 2).  Padding slots
  (m = -1) keep every shard's slot count identical -- the TPU analogue of
  `Alltoallv` raggedness (DESIGN.md §2).
* **ring distribution**: rings are dealt to shards as blocks of mirror pairs
  (north_i, south_mirror_i) so each shard can fold about the equator; dummy
  rings (weight 0) pad R to a multiple of the shard count.
* **bucket-aware dealing (ragged grids)**: for variable-n_phi grids the
  mirror pairs are dealt *per FFT bucket* (grids.ring_buckets), each
  bucket's pair list padded to a multiple of the shard count, so every
  shard owns the same number of rings from every bucket.  That gives each
  shard balanced Legendre FLOPs *and* balanced FFT work (paper §4.1), and
  -- crucially for shard_map's single-program model -- an *identical*
  local slot->bucket structure (`local_fft_layout`) on every shard.

The plan is pure geometry: it never touches jax device state and can be
built under `jax.eval_shape` / dry-run tracing.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core import legendre
from repro.core.grids import BucketLayout, RingGrid

__all__ = ["SHTPlan", "minmax_m_order", "Plan", "make_plan", "drop_plan"]


def __getattr__(name):
    """Lazy aliases for the unified transform-plan API.

    ``repro.core.plan.Plan`` / ``make_plan`` / ``drop_plan`` live in
    ``repro.core.transform`` (which imports jax); resolving them lazily
    keeps this module pure host-side geometry, importable under
    ``jax.eval_shape`` dry-runs with no device state.
    """
    if name in ("Plan", "make_plan", "drop_plan"):
        from repro.core import transform
        return getattr(transform, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def minmax_m_order(m_max: int) -> np.ndarray:
    """[0, m_max, 1, m_max-1, ...] -- the min-max pair ordering."""
    out = np.empty(m_max + 1, dtype=np.int64)
    out[0::2] = np.arange((m_max + 2) // 2)
    out[1::2] = m_max - np.arange((m_max + 1) // 2)
    return out


@dataclasses.dataclass(frozen=True)
class SHTPlan:
    """Distribution plan for a (grid, l_max, m_max, n_shards) problem.

    ``comm_chunks`` is the default chunk count of the chunked-exchange
    pipeline (`DistSHT` overrides it per engine): the Delta block is
    split into C chunks so each chunk's all_to_all overlaps the adjacent
    chunk's Legendre/FFT compute.  ``chunk_schedule`` resolves which axis
    the split rides on for a given K.
    """

    grid: RingGrid
    l_max: int
    m_max: int
    n_shards: int
    comm_chunks: int = 1

    # ---- m axis ------------------------------------------------------------

    @functools.cached_property
    def m_assignment(self) -> np.ndarray:
        """(n_shards, m_local) global m value per slot; -1 = padding.

        Pairs from ``minmax_m_order`` are dealt round-robin: pair p goes to
        shard p % n_shards, preserving the paper's balance invariant.
        """
        order = minmax_m_order(self.m_max)
        # Group into pairs [(0, m_max), (1, m_max-1), ...]; a lone middle
        # element (even m_max+1 count has none) forms a singleton pair.
        pairs = [order[i:i + 2] for i in range(0, len(order), 2)]
        per_shard: list[list[int]] = [[] for _ in range(self.n_shards)]
        for p, pair in enumerate(pairs):
            per_shard[p % self.n_shards].extend(int(v) for v in pair)
        m_local = max(len(s) for s in per_shard)
        out = np.full((self.n_shards, m_local), -1, dtype=np.int64)
        for i, s in enumerate(per_shard):
            out[i, : len(s)] = s
        return out

    @property
    def m_local(self) -> int:
        return self.m_assignment.shape[1]

    @functools.cached_property
    def m_flat(self) -> np.ndarray:
        """(n_shards * m_local,) global m per global slot (row-major)."""
        return self.m_assignment.reshape(-1)

    @functools.cached_property
    def recurrence_steps_per_shard(self) -> np.ndarray:
        """Work balance diagnostic: total l-recurrence steps per shard."""
        a = self.m_assignment
        steps = np.where(a >= 0, self.l_max + 1 - np.maximum(a, 0), 0)
        return steps.sum(axis=1)

    def pack_alm(self, alm: np.ndarray) -> np.ndarray:
        """(M, L, K) dense alm -> (n_shards * m_local, L, K) plan layout.

        Padding slots are zero.  Works with numpy or jnp inputs.
        """
        M, L, K = alm.shape
        assert M == self.m_max + 1 and L == self.l_max + 1
        import jax.numpy as jnp
        xp = jnp if not isinstance(alm, np.ndarray) else np
        safe = np.maximum(self.m_flat, 0)
        out = alm[safe]
        mask = (self.m_flat >= 0)[:, None, None]
        return xp.where(xp.asarray(mask), out, xp.zeros_like(out))

    def unpack_alm(self, packed: np.ndarray) -> np.ndarray:
        """Inverse of pack_alm (padding rows dropped)."""
        import jax.numpy as jnp
        xp = jnp if not isinstance(packed, np.ndarray) else np
        M = self.m_max + 1
        out_shape = (M,) + tuple(packed.shape[1:])
        out = xp.zeros(out_shape, packed.dtype)
        valid = self.m_flat >= 0
        idx = self.m_flat[valid]
        if xp is np:
            out[idx] = packed[valid]
            return out
        return out.at[xp.asarray(idx)].set(packed[xp.asarray(valid)])

    # ---- chunked-exchange dealing -------------------------------------------

    def chunk_schedule(self, K: int, ncomp: int = 1,
                       chunks: int | None = None) -> tuple[str, tuple]:
        """Resolve the chunked-exchange split for a C-chunk pipeline.

        Returns ``(axis, bounds)`` where ``axis`` is ``"none"`` (C=1,
        monolithic exchange), ``"k"`` (split the K map-batch axis -- the
        ``ncomp`` spin components and the re/im pair ride *inside* each
        chunk, so chunk boundaries never cut a coupled channel group), or
        ``"m"`` (K too small: split the local m rows instead), and
        ``bounds`` is a tuple of half-open ``(start, stop)`` index pairs
        along that axis.  C is clamped to what the chosen axis can carry;
        pure host-side arithmetic (no jax).
        """
        C = int(self.comm_chunks if chunks is None else chunks)
        if C <= 1:
            return "none", ()
        if K >= C:
            axis, n = "k", int(K)
        else:
            axis, n = "m", int(self.m_local)
            C = min(C, n)
            if C <= 1:
                return "none", ()
        edges = np.linspace(0, n, C + 1).astype(np.int64)
        bounds = tuple((int(a), int(b)) for a, b in zip(edges[:-1], edges[1:]))
        assert all(b > a for a, b in bounds), bounds
        return axis, bounds

    # ---- ring axis -----------------------------------------------------------

    @functools.cached_property
    def _pairs(self) -> np.ndarray:
        """(n_pairs, 2) mirror pairs (north, south); equator south = -1."""
        R = self.grid.n_rings
        out = [(i, R - 1 - i) for i in range(R // 2)]
        if R % 2 == 1:
            out.append((R // 2, -1))
        return np.asarray(out, dtype=np.int64)

    @functools.cached_property
    def _bucket_deal(self):
        """Bucket-aware pair dealing for ragged grids.

        Returns ``(bucket_lengths, counts, ring_order)``: pairs are grouped
        by their FFT bucket (a pair's bucket is its north ring's -- mirrors
        share n_phi on symmetric grids, asserted), each bucket's pair list
        is dealt round-robin and padded to ``counts[k]`` pairs per shard,
        and the plan slot order is shard-major with buckets contiguous
        inside each shard -- so every shard sees the identical local
        slot->bucket structure (shard_map runs one program).
        """
        buckets = self.grid.fft_buckets()
        R = self.grid.n_rings
        ring2b = np.empty(R, dtype=np.int64)
        for k, b in enumerate(buckets):
            ring2b[b.rings] = k
        pairs = self._pairs
        pb = ring2b[pairs[:, 0]]
        south = pairs[:, 1]
        assert np.all((south < 0)
                      | (ring2b[np.maximum(south, 0)] == pb)), \
            "mirror pair spans two FFT buckets (grid not symmetric?)"
        n = self.n_shards
        per_bucket = [np.where(pb == k)[0] for k in range(len(buckets))]
        counts = [-(-len(p) // n) for p in per_bucket]
        order = np.full((n, sum(counts), 2), -1, dtype=np.int64)
        for k, p in enumerate(per_bucket):
            off = sum(counts[:k])
            for j, pair_idx in enumerate(p):
                order[j % n, off + j // n] = pairs[pair_idx]
        return [b.length for b in buckets], counts, order.reshape(-1)

    @functools.cached_property
    def n_pairs_pad(self) -> int:
        """Mirror-pair count padded to a multiple of n_shards (ragged
        grids: padded per bucket, see ``_bucket_deal``)."""
        if not self.grid.uniform:
            return self.n_shards * sum(self._bucket_deal[1])
        n_pairs = (self.grid.n_rings + 1) // 2
        return -(-n_pairs // self.n_shards) * self.n_shards

    @functools.cached_property
    def ring_order(self) -> np.ndarray:
        """(R_pad,) grid ring index per plan slot; -1 = dummy padding ring.

        Pair-interleaved: slot 2i is pair i's northern ring, slot 2i+1 its
        southern mirror.  An odd equator ring is a pair with a dummy south;
        padding pairs are (dummy, dummy).  Every shard owns r_local/2
        consecutive *pairs*, which is what the fold optimisation and the
        tiled all_to_all both want.  Ragged grids deal pairs bucket-aware
        (``_bucket_deal``) so FFT work is balanced too.
        """
        if not self.grid.uniform:
            return self._bucket_deal[2]
        R = self.grid.n_rings
        out = np.full(2 * self.n_pairs_pad, -1, dtype=np.int64)
        for i in range(R // 2):
            out[2 * i] = i                 # northern ring
            out[2 * i + 1] = R - 1 - i     # its mirror
        if R % 2 == 1:
            out[2 * (R // 2)] = R // 2     # equator (dummy south partner)
        return out

    @functools.cached_property
    def local_fft_layout(self) -> BucketLayout:
        """Static local-slot -> FFT-bucket structure, identical on every
        shard (uniform grids: one bucket over all local slots)."""
        if self.grid.uniform:
            return BucketLayout((self.grid.max_n_phi,),
                                (np.arange(self.r_local),))
        lengths, counts, _ = self._bucket_deal
        slots, off = [], 0
        for c in counts:
            slots.append(np.arange(2 * off, 2 * (off + c)))
            off += c
        return BucketLayout(tuple(lengths), tuple(slots))

    @functools.cached_property
    def slot_fft_len(self) -> np.ndarray:
        """(R_pad,) batched-FFT length of each plan slot's bucket."""
        return np.tile(self.local_fft_layout.fft_lengths, self.n_shards)

    @functools.cached_property
    def fft_bin_maps(self) -> tuple[np.ndarray, np.ndarray]:
        """(pos, neg) (R_pad, Mp) int32 alias-fold bin maps in plan slot
        order -- `phase.bucket_bin_maps` over ``m_flat`` and the slot
        geometry, shaped rings-first so they shard as stage-2 operands."""
        from repro.core.phase import bucket_bin_maps
        g = self.ring_geometry
        pos, neg = bucket_bin_maps(self.m_flat, g["n_phi"],
                                   self.slot_fft_len)
        return np.ascontiguousarray(pos.T), np.ascontiguousarray(neg.T)

    @property
    def r_pad(self) -> int:
        return self.ring_order.shape[0]

    @property
    def r_local(self) -> int:
        return self.r_pad // self.n_shards

    @functools.cached_property
    def north_order(self) -> np.ndarray:
        """(n_pairs_pad,) grid ring index of each pair's north; -1 padding."""
        return self.ring_order[0::2]

    @functools.cached_property
    def ring_geometry(self) -> dict[str, np.ndarray]:
        """Per-plan-slot ring geometry (R_pad,), dummies weight-0/benign."""
        g = self.grid
        ro = self.ring_order
        safe = np.maximum(ro, 0)
        dummy = ro < 0
        cos = np.where(dummy, 0.123456, g.cos_theta[safe])
        sin = np.sqrt(1.0 - cos * cos)
        w = np.where(dummy, 0.0, g.weights[safe])
        phi0 = np.where(dummy, 0.0, g.phi0[safe])
        # dummy slots adopt their bucket's FFT length so the bucket engine's
        # stride arithmetic stays exact (their output is weight-masked away)
        dummy_n = g.max_n_phi if g.uniform else self.slot_fft_len
        nphi = np.where(dummy, dummy_n, g.n_phi[safe])
        return {"cos_theta": cos, "sin_theta": sin, "weights": w,
                "phi0": phi0, "n_phi": nphi, "valid": ~dummy}

    def scatter_map(self, maps_plan: np.ndarray) -> np.ndarray:
        """(R_pad, n_phi, K) plan-order maps -> (R, n_phi, K) grid order."""
        import jax.numpy as jnp
        xp = jnp if not isinstance(maps_plan, np.ndarray) else np
        R = self.grid.n_rings
        out = xp.zeros((R,) + tuple(maps_plan.shape[1:]), maps_plan.dtype)
        valid = self.ring_order >= 0
        idx = self.ring_order[valid]
        if xp is np:
            out[idx] = maps_plan[valid]
            return out
        return out.at[xp.asarray(idx)].set(maps_plan[xp.asarray(valid)])

    def gather_map(self, maps_grid: np.ndarray) -> np.ndarray:
        """(R, n_phi, K) grid-order maps -> (R_pad, n_phi, K) plan order."""
        import jax.numpy as jnp
        xp = jnp if not isinstance(maps_grid, np.ndarray) else np
        safe = np.maximum(self.ring_order, 0)
        out = maps_grid[xp.asarray(safe)] if xp is not np else maps_grid[safe]
        mask = (self.ring_order >= 0)[:, None, None]
        return xp.where(xp.asarray(mask), out, xp.zeros_like(out))

    # ---- logs ---------------------------------------------------------------

    def describe(self) -> str:
        steps = self.recurrence_steps_per_shard
        return (f"SHTPlan(grid={self.grid.name}, l_max={self.l_max}, "
                f"m_max={self.m_max}, shards={self.n_shards}, "
                f"m_local={self.m_local}, r_pad={self.r_pad}, "
                f"r_local={self.r_local}, "
                f"balance={steps.min()}/{steps.max()} steps)")
