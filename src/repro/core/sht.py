"""Serial/batched spherical harmonic transforms (the pure-jnp engine).

Implements paper Algorithms 1 & 2 for iso-latitude grids:

  alm2map (inverse / synthesis, paper eq. 11-12):
      Delta^A_m(r) = sum_l a_lm P_lm(cos theta_r)        (Legendre stage)
      s(r, phi_j)  = sum_m e^{i m phi_j} Delta^A_m(r)    (FFT stage)

  map2alm (direct / analysis, paper eq. 13-14):
      Delta^S_m(r) = sum_j w_r s(r, phi_j) e^{-i m phi_j}  (FFT stage)
      a_lm         = sum_r Delta^S_m(r) P_lm(cos theta_r)  (Legendre stage)

This module is the *oracle*: float64 by default, used by every test.  The
Pallas kernels (repro.kernels) and the distributed transforms
(repro.core.dist_sht) are validated against it.

The FFT stage is NOT implemented here: it lives in the pluggable phase
layer (`repro.core.phase`), which picks the batched-uniform engine or the
ring-bucket engine (true ragged HEALPix) per grid.  The oracle, the Pallas
backends and the distributed transform all share that one implementation.

Conventions
-----------
* Fields are real; only m >= 0 coefficients are stored (a_{l,-m} = (-1)^m
  conj(a_lm)).
* alm layout: dense rectangle ``(m_max+1, l_max+1, K)`` complex ("MLK"),
  entries with l < m must be zero.  ``K`` is the number of simultaneous maps
  (the batched/multi-map transform -- the paper's Monte-Carlo target
  workload and our MXU lever).
* maps layout: ``(R, n_phi_max, K)`` real; ragged grids are padded with
  zeros beyond each ring's n_phi.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import legendre
from repro.core.grids import RingGrid

__all__ = ["SHT", "alm_rect_zeros", "random_alm", "alm_mask"]


def alm_mask(l_max: int, m_max: int) -> np.ndarray:
    """(m_max+1, l_max+1) bool mask of valid (m, l) entries (l >= m)."""
    m = np.arange(m_max + 1)[:, None]
    l = np.arange(l_max + 1)[None, :]
    return l >= m


def alm_rect_zeros(l_max: int, m_max: int, K: int = 1,
                   dtype=np.complex128) -> np.ndarray:
    return np.zeros((m_max + 1, l_max + 1, K), dtype=dtype)


def random_alm(key, l_max: int, m_max: int, K: int = 1,
               dtype=jnp.float64) -> jnp.ndarray:
    """Random a_lm, uniform in (-1, 1) (paper §5 experimental setup).

    m = 0 entries are real (required for a real field).
    """
    kr, ki = jax.random.split(jax.random.PRNGKey(0) if key is None else key)
    shape = (m_max + 1, l_max + 1, K)
    re = jax.random.uniform(kr, shape, dtype, -1.0, 1.0)
    im = jax.random.uniform(ki, shape, dtype, -1.0, 1.0)
    im = im.at[0].set(0.0)  # m = 0 is real
    mask = jnp.asarray(alm_mask(l_max, m_max))[..., None]
    return jnp.where(mask, re + 1j * im, 0.0)


@dataclasses.dataclass(frozen=True)
class SHT:
    """Batched serial SHT engine on an iso-latitude grid.

    Parameters
    ----------
    grid : RingGrid
    l_max, m_max : band limits (m_max <= l_max; default m_max = l_max)
    dtype : recurrence/accumulation dtype (float64 oracle, float32 perf)
    fold : use the equator-fold optimisation (grid must be symmetric)
    """

    grid: RingGrid
    l_max: int
    m_max: int
    dtype: str = "float64"
    fold: bool = False
    #: cache policy for the phase stage's precomputed index maps
    #: ("off" | "memory" | "disk"), and the disk-tier directory override.
    phase_cache: str = "memory"
    phase_cache_dir: Optional[str] = None

    def __post_init__(self):
        assert self.m_max <= self.l_max
        if self.fold:
            assert self.grid.equator_symmetric, "fold requires a symmetric grid"

    # -- geometry helpers ---------------------------------------------------

    @property
    def n_north(self) -> int:
        """Number of northern rings incl. the equator ring if present."""
        return (self.grid.n_rings + 1) // 2

    @property
    def has_equator(self) -> bool:
        return self.grid.n_rings % 2 == 1

    @functools.cached_property
    def _log_mu(self) -> np.ndarray:
        return legendre.log_mu(self.m_max)

    @functools.cached_property
    def _m_all(self) -> np.ndarray:
        return np.arange(self.m_max + 1)

    # -- FFT/phase stage (pluggable, shared with Pallas and dist paths) -----

    @functools.cached_property
    def phase(self):
        """The grid's phase stage: batched-uniform or ring-bucket engine
        (`repro.core.phase.make_phase`), device-resident either way."""
        from repro.core.phase import make_phase
        return make_phase(self.grid, self.m_max, self.dtype,
                          cache=self.phase_cache,
                          cache_dir=self.phase_cache_dir)

    # -- Legendre stage -----------------------------------------------------

    def _delta_from_alm(self, alm: jnp.ndarray) -> jnp.ndarray:
        """(M, L, K) complex alm -> (M, R, K) complex Delta^A."""
        g = self.grid
        dt = jnp.dtype(self.dtype)
        if not self.fold:
            d_re, d_im = legendre.delta_from_alm(
                jnp.real(alm), jnp.imag(alm), self._m_all, g.cos_theta,
                g.sin_theta, self._log_mu, l_max=self.l_max, dtype=dt)
            return d_re + 1j * d_im
        nh = self.n_north
        ere, eim, ore_, oim = legendre.delta_from_alm_folded(
            jnp.real(alm), jnp.imag(alm), self._m_all, g.cos_theta[:nh],
            g.sin_theta[:nh], self._log_mu, l_max=self.l_max, dtype=dt)
        north = (ere + ore_) + 1j * (eim + oim)               # (M, nh, K)
        ns = nh - 1 if self.has_equator else nh
        south = (ere - ore_)[:, :ns] + 1j * (eim - oim)[:, :ns]
        return jnp.concatenate([north, south[:, ::-1]], axis=1)

    def _alm_from_delta(self, delta_w: jnp.ndarray) -> jnp.ndarray:
        """(M, R, K) weighted Delta^S -> (M, L, K) complex alm.

        ``delta_w`` must already include the quadrature weights (the FFT
        stage applies them)."""
        g = self.grid
        dt = jnp.dtype(self.dtype)
        if not self.fold:
            ones = np.ones(g.n_rings)  # weights pre-applied
            a_re, a_im = legendre.alm_from_delta(
                jnp.real(delta_w), jnp.imag(delta_w), self._m_all,
                g.cos_theta, g.sin_theta, ones, self._log_mu,
                l_max=self.l_max, dtype=dt)
            return a_re + 1j * a_im
        nh = self.n_north
        north = delta_w[:, :nh]
        ns = nh - 1 if self.has_equator else nh
        south = delta_w[:, nh:][:, ::-1]                      # mirror order
        pad = north[:, ns:nh] * 0.0                           # equator slot
        south_p = jnp.concatenate([south, pad], axis=1) if self.has_equator else south
        s_e = north + south_p
        s_o = north - south_p
        # (equator ring: P_lm(0) = 0 for odd l+m, so its s_o value is inert)
        a_re, a_im = legendre.alm_from_delta_folded(
            jnp.real(s_e), jnp.imag(s_e), jnp.real(s_o), jnp.imag(s_o),
            self._m_all, g.cos_theta[:nh], g.sin_theta[:nh], self._log_mu,
            l_max=self.l_max, dtype=dt)
        return a_re + 1j * a_im

    # -- public API ----------------------------------------------------------

    def alm2map(self, alm: jnp.ndarray) -> jnp.ndarray:
        """Inverse SHT (synthesis).  alm (M, L, K) -> maps (R, n_phi, K).

        For ragged grids the output is padded; samples beyond n_phi(r) are 0.
        """
        assert alm.shape[:2] == (self.m_max + 1, self.l_max + 1), alm.shape
        delta = self._delta_from_alm(alm)
        return self.phase.synth(delta)

    def map2alm(self, maps: jnp.ndarray, iters: int = 0) -> jnp.ndarray:
        """Direct SHT (analysis).  maps (R, n_phi, K) -> alm (M, L, K).

        ``iters`` > 0 applies Jacobi residual refinement (the HEALPix
        map2alm_iter technique):  a_{n+1} = a_n + A(m - S(a_n)).  Each
        iteration costs one synthesis + one analysis and drives the
        approximate-quadrature error of the HEALPix-family grids down by
        roughly an order of magnitude per pass (exact grids gain nothing).
        """
        assert maps.shape[0] == self.grid.n_rings, maps.shape
        delta_w = self.phase.anal(jnp.asarray(maps))
        alm = self._alm_from_delta(delta_w)
        for _ in range(iters):
            resid = maps - self.alm2map(alm)
            alm = alm + self.map2alm(resid, iters=0)
        return alm
