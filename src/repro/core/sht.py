"""Serial/batched spherical harmonic transforms (the pure-jnp engine).

Implements paper Algorithms 1 & 2 for iso-latitude grids:

  alm2map (inverse / synthesis, paper eq. 11-12):
      Delta^A_m(r) = sum_l a_lm P_lm(cos theta_r)        (Legendre stage)
      s(r, phi_j)  = sum_m e^{i m phi_j} Delta^A_m(r)    (FFT stage)

  map2alm (direct / analysis, paper eq. 13-14):
      Delta^S_m(r) = sum_j w_r s(r, phi_j) e^{-i m phi_j}  (FFT stage)
      a_lm         = sum_r Delta^S_m(r) P_lm(cos theta_r)  (Legendre stage)

This module is the *oracle*: float64 by default, used by every test.  The
Pallas kernels (repro.kernels) and the distributed transforms
(repro.core.dist_sht) are validated against it.

The FFT stage is NOT implemented here: it lives in the pluggable phase
layer (`repro.core.phase`), which picks the batched-uniform engine or the
ring-bucket engine (true ragged HEALPix) per grid.  The oracle, the Pallas
backends and the distributed transform all share that one implementation.

Conventions
-----------
* Fields are real; only m >= 0 coefficients are stored (a_{l,-m} = (-1)^m
  conj(a_lm)).
* alm layout: dense rectangle ``(m_max+1, l_max+1, K)`` complex ("MLK"),
  entries with l < m must be zero.  ``K`` is the number of simultaneous maps
  (the batched/multi-map transform -- the paper's Monte-Carlo target
  workload and our MXU lever).
* maps layout: ``(R, n_phi_max, K)`` real; ragged grids are padded with
  zeros beyond each ring's n_phi.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import legendre
from repro.core.grids import RingGrid

__all__ = ["SHT", "alm_rect_zeros", "random_alm", "random_alm_spin",
           "alm_mask"]


def alm_mask(l_max: int, m_max: int, spin: int = 0) -> np.ndarray:
    """(m_max+1, l_max+1) bool mask of valid (m, l) entries.

    Valid means ``l >= m`` and ``l >= spin`` (spin-s harmonics start at
    l = s; for polarisation E/B that is l = 2).
    """
    m = np.arange(m_max + 1)[:, None]
    l = np.arange(l_max + 1)[None, :]
    return (l >= m) & (l >= spin)


def alm_rect_zeros(l_max: int, m_max: int, K: int = 1,
                   dtype=np.complex128) -> np.ndarray:
    return np.zeros((m_max + 1, l_max + 1, K), dtype=dtype)


def _resolve_key(key, seed, caller: str):
    if (key is None) == (seed is None):
        raise ValueError(
            f"{caller} requires exactly one of `key` or `seed=` -- the old "
            "silent key=None -> PRNGKey(0) fallback has been removed; pass "
            "jax.random.PRNGKey(...) explicitly or use seed=<int>")
    return jax.random.PRNGKey(seed) if key is None else key


def random_alm(key=None, l_max: int = None, m_max: int = None, K: int = 1,
               dtype=jnp.float64, *, spin: int = 0,
               seed=None) -> jnp.ndarray:
    """Random a_lm, uniform in (-1, 1) (paper §5 experimental setup).

    Exactly one of ``key`` (a jax PRNG key) or ``seed=`` (an int, documented
    deterministic shorthand) must be given.  m = 0 entries are real
    (required for a real field); ``spin`` zeroes the l < spin rows.
    """
    key = _resolve_key(key, seed, "random_alm")
    kr, ki = jax.random.split(key)
    shape = (m_max + 1, l_max + 1, K)
    re = jax.random.uniform(kr, shape, dtype, -1.0, 1.0)
    im = jax.random.uniform(ki, shape, dtype, -1.0, 1.0)
    im = im.at[0].set(0.0)  # m = 0 is real
    mask = jnp.asarray(alm_mask(l_max, m_max, spin))[..., None]
    return jnp.where(mask, re + 1j * im, 0.0)


def random_alm_spin(key=None, l_max: int = None, m_max: int = None,
                    K: int = 1, dtype=jnp.float64, *,
                    seed=None) -> jnp.ndarray:
    """Random (E, B) alm pair for spin-2 transforms, shape (2, M, L1, K).

    Same key/seed contract as :func:`random_alm`; rows with l < 2 are zero
    (no spin-2 harmonics below the spin)."""
    key = _resolve_key(key, seed, "random_alm_spin")
    ke, kb = jax.random.split(key)
    e = random_alm(ke, l_max, m_max, K, dtype, spin=2)
    b = random_alm(kb, l_max, m_max, K, dtype, spin=2)
    return jnp.stack([e, b], axis=0)


@dataclasses.dataclass(frozen=True)
class SHT:
    """Batched serial SHT engine on an iso-latitude grid.

    Parameters
    ----------
    grid : RingGrid
    l_max, m_max : band limits (m_max <= l_max; default m_max = l_max)
    dtype : recurrence/accumulation dtype (float64 oracle, float32 perf)
    fold : use the equator-fold optimisation (grid must be symmetric)
    """

    grid: RingGrid
    l_max: int
    m_max: int
    dtype: str = "float64"
    fold: bool = False
    #: cache policy for the phase stage's precomputed index maps
    #: ("off" | "memory" | "disk"), and the disk-tier directory override.
    phase_cache: str = "memory"
    phase_cache_dir: Optional[str] = None

    def __post_init__(self):
        assert self.m_max <= self.l_max
        if self.fold:
            assert self.grid.equator_symmetric, "fold requires a symmetric grid"

    # -- geometry helpers ---------------------------------------------------

    @property
    def n_north(self) -> int:
        """Number of northern rings incl. the equator ring if present."""
        return (self.grid.n_rings + 1) // 2

    @property
    def has_equator(self) -> bool:
        return self.grid.n_rings % 2 == 1

    @functools.cached_property
    def _log_mu(self) -> np.ndarray:
        return legendre.log_mu(self.m_max)

    @functools.cached_property
    def _m_all(self) -> np.ndarray:
        return np.arange(self.m_max + 1)

    # -- FFT/phase stage (pluggable, shared with Pallas and dist paths) -----

    @functools.cached_property
    def phase(self):
        """The grid's phase stage: batched-uniform or ring-bucket engine
        (`repro.core.phase.make_phase`), device-resident either way."""
        from repro.core.phase import make_phase
        return make_phase(self.grid, self.m_max, self.dtype,
                          cache=self.phase_cache,
                          cache_dir=self.phase_cache_dir)

    # -- Legendre stage (spin-aware harmonic core) --------------------------

    def _harmonic_core(self, spin: int) -> "legendre.HarmonicCore":
        """The spin-aware recurrence layer bound to this grid/band-limit."""
        cache = self.__dict__.setdefault("_cores", {})
        if spin not in cache:
            g = self.grid
            cache[spin] = legendre.HarmonicCore(
                m_vals=self._m_all, grid_x=g.cos_theta, grid_sin=g.sin_theta,
                log_mu_all=self._log_mu, l_max=self.l_max, spin=spin,
                dtype=self.dtype)
        return cache[spin]

    def _delta_from_alm(self, alm: jnp.ndarray) -> jnp.ndarray:
        """(M, L, K) complex alm -> (M, R, K) complex Delta^A."""
        g = self.grid
        dt = jnp.dtype(self.dtype)
        if not self.fold:
            return self._harmonic_core(0).delta_from_alm(alm)
        nh = self.n_north
        ere, eim, ore_, oim = legendre.delta_from_alm_folded(
            jnp.real(alm), jnp.imag(alm), self._m_all, g.cos_theta[:nh],
            g.sin_theta[:nh], self._log_mu, l_max=self.l_max, dtype=dt)
        north = (ere + ore_) + 1j * (eim + oim)               # (M, nh, K)
        ns = nh - 1 if self.has_equator else nh
        south = (ere - ore_)[:, :ns] + 1j * (eim - oim)[:, :ns]
        return jnp.concatenate([north, south[:, ::-1]], axis=1)

    def _alm_from_delta(self, delta_w: jnp.ndarray) -> jnp.ndarray:
        """(M, R, K) weighted Delta^S -> (M, L, K) complex alm.

        ``delta_w`` must already include the quadrature weights (the FFT
        stage applies them)."""
        g = self.grid
        dt = jnp.dtype(self.dtype)
        if not self.fold:
            return self._harmonic_core(0).alm_from_delta(delta_w)
        nh = self.n_north
        north = delta_w[:, :nh]
        ns = nh - 1 if self.has_equator else nh
        south = delta_w[:, nh:][:, ::-1]                      # mirror order
        pad = north[:, ns:nh] * 0.0                           # equator slot
        south_p = jnp.concatenate([south, pad], axis=1) if self.has_equator else south
        s_e = north + south_p
        s_o = north - south_p
        # (equator ring: P_lm(0) = 0 for odd l+m, so its s_o value is inert)
        a_re, a_im = legendre.alm_from_delta_folded(
            jnp.real(s_e), jnp.imag(s_e), jnp.real(s_o), jnp.imag(s_o),
            self._m_all, g.cos_theta[:nh], g.sin_theta[:nh], self._log_mu,
            l_max=self.l_max, dtype=dt)
        return a_re + 1j * a_im

    # -- public API ----------------------------------------------------------

    def alm2map(self, alm: jnp.ndarray) -> jnp.ndarray:
        """Inverse SHT (synthesis).  alm (M, L, K) -> maps (R, n_phi, K).

        For ragged grids the output is padded; samples beyond n_phi(r) are 0.
        """
        assert alm.shape[:2] == (self.m_max + 1, self.l_max + 1), alm.shape
        delta = self._delta_from_alm(alm)
        return self.phase.synth(delta)

    def map2alm(self, maps: jnp.ndarray, iters: int = 0) -> jnp.ndarray:
        """Direct SHT (analysis).  maps (R, n_phi, K) -> alm (M, L, K).

        ``iters`` > 0 applies Jacobi residual refinement (the HEALPix
        map2alm_iter technique):  a_{n+1} = a_n + A(m - S(a_n)).  Each
        iteration costs one synthesis + one analysis and drives the
        approximate-quadrature error of the HEALPix-family grids down by
        roughly an order of magnitude per pass (exact grids gain nothing).
        """
        assert maps.shape[0] == self.grid.n_rings, maps.shape
        delta_w = self.phase.anal(jnp.asarray(maps))
        alm = self._alm_from_delta(delta_w)
        for _ in range(iters):
            resid = maps - self.alm2map(alm)
            alm = alm + self.map2alm(resid, iters=0)
        return alm

    # -- spin-2 transforms (polarisation: E/B <-> Q/U) -----------------------
    #
    # The phase stage is spin-blind (e^{im phi} factors are identical), so
    # the (Q, U) component pair rides the trailing K channel axis through
    # the same engine; only the Legendre stage switches to the spin-2
    # harmonic core (two stacked Wigner-d recurrences, lambda^{+/-} mixing).

    def alm2map_spin(self, alm_eb: jnp.ndarray) -> jnp.ndarray:
        """Spin-2 synthesis: (E, B) alm (2, M, L, K) -> (Q, U) maps
        (2, R, n_phi, K)."""
        assert not self.fold, "fold is not supported for spin transforms"
        assert alm_eb.shape[:3] == (2, self.m_max + 1, self.l_max + 1), \
            alm_eb.shape
        K = alm_eb.shape[-1]
        delta = self._harmonic_core(2).delta_from_alm(alm_eb)  # (2, M, R, K)
        d2 = jnp.concatenate([delta[0], delta[1]], axis=-1)    # (M, R, 2K)
        s = self.phase.synth(d2)                               # (R, nphi, 2K)
        return jnp.stack([s[..., :K], s[..., K:]], axis=0)

    def map2alm_spin(self, maps_qu: jnp.ndarray, iters: int = 0) -> jnp.ndarray:
        """Spin-2 analysis: (Q, U) maps (2, R, n_phi, K) -> (E, B) alm
        (2, M, L, K); ``iters`` as in :meth:`map2alm`."""
        assert not self.fold, "fold is not supported for spin transforms"
        assert maps_qu.shape[0] == 2 and \
            maps_qu.shape[1] == self.grid.n_rings, maps_qu.shape
        maps_qu = jnp.asarray(maps_qu)
        K = maps_qu.shape[-1]
        m2 = jnp.concatenate([maps_qu[0], maps_qu[1]], axis=-1)
        dw = self.phase.anal(m2)                               # (M, R, 2K)
        delta_w = jnp.stack([dw[..., :K], dw[..., K:]], axis=0)
        alm = self._harmonic_core(2).alm_from_delta(delta_w)
        for _ in range(iters):
            resid = maps_qu - self.alm2map_spin(alm)
            alm = alm + self.map2alm_spin(resid, iters=0)
        return alm
