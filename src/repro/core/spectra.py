"""Harmonic-domain utilities: power spectra, random realisations, errors.

Supports the paper's validation methodology (§5): random a_lm in (-1, 1),
round-trip relative error D_err (paper eq. 19), plus CMB-flavoured helpers
used by the examples (synthesis of a_lm from an angular power spectrum C_l
and pseudo-C_l estimation -- the paper's target application domain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sht import alm_mask

__all__ = ["d_err", "alm_from_cl", "cl_from_alm", "cmb_like_cl"]


def d_err(a_init, a_out) -> float:
    """Paper eq. 19: relative round-trip error over all (l, m)."""
    a_init = np.asarray(a_init)
    a_out = np.asarray(a_out)
    num = np.sum(np.abs(a_init - a_out) ** 2)
    den = np.sum(np.abs(a_init) ** 2)
    return float(np.sqrt(num / den))


def cmb_like_cl(l_max: int, *, amp: float = 1.0, l_peak: float = 220.0,
               tilt: float = -2.0) -> np.ndarray:
    """A toy CMB-ish TT spectrum: acoustic-peak bump + damping tail.

    Not a physical model -- just gives the examples a realistic dynamic range
    (flat Sachs-Wolfe plateau, oscillations, exponential damping).
    """
    l = np.arange(l_max + 1, dtype=np.float64)
    lsafe = np.maximum(l, 1.0)
    plateau = 1.0 / (lsafe * (lsafe + 1.0))
    osc = 1.0 + 0.6 * np.cos(np.pi * l / l_peak) ** 2 * np.exp(-l / (3 * l_peak))
    damp = np.exp(-((l / (5.0 * l_peak)) ** 2))
    cl = amp * plateau * osc * damp * (lsafe / l_peak) ** (tilt + 2.0)
    cl[0] = 0.0
    return cl


def alm_from_cl(key, cl: np.ndarray, m_max: int | None = None,
                K: int = 1, dtype=jnp.float64) -> jnp.ndarray:
    """Gaussian random a_lm with <|a_lm|^2> = C_l, packed (M, L, K) complex.

    Standard CMB convention: a_l0 ~ N(0, C_l) real; for m > 0,
    Re/Im ~ N(0, C_l / 2) independently.
    """
    l_max = len(cl) - 1
    if m_max is None:
        m_max = l_max
    shape = (m_max + 1, l_max + 1, K)
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, shape, dtype)
    im = jax.random.normal(ki, shape, dtype)
    sig = jnp.sqrt(jnp.asarray(cl, dtype))[None, :, None]
    alm = (re + 1j * im) * sig / jnp.sqrt(2.0)
    alm = alm.at[0].set((re[0] * sig[0]).astype(dtype))  # m=0 real, full var
    mask = jnp.asarray(alm_mask(l_max, m_max))[..., None]
    return jnp.where(mask, alm, 0.0)


def cl_from_alm(alm: jnp.ndarray) -> jnp.ndarray:
    """Pseudo-C_l estimator from packed (M, L, K) alm (real-field m>=0).

    C_l = (|a_l0|^2 + 2 sum_{m=1}^{min(l, m_max)} |a_lm|^2) / (2 l + 1).
    """
    m_max = alm.shape[0] - 1
    l_max = alm.shape[1] - 1
    p = jnp.abs(alm) ** 2                                     # (M, L, K)
    tot = p[0] + 2.0 * jnp.sum(p[1:], axis=0)                 # (L, K)
    l = jnp.arange(l_max + 1, dtype=p.dtype)[:, None]
    return tot / (2.0 * l + 1.0)
