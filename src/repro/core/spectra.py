"""Harmonic-domain utilities: power spectra, random realisations, errors.

Supports the paper's validation methodology (§5): random a_lm in (-1, 1),
round-trip relative error D_err (paper eq. 19), plus CMB-flavoured helpers
used by the examples (synthesis of a_lm from an angular power spectrum C_l
and pseudo-C_l estimation -- the paper's target application domain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sht import alm_mask

__all__ = ["d_err", "alm_from_cl", "cl_from_alm", "cmb_like_cl",
           "cmb_like_cl_pol", "alm_from_cl_pol", "cl_cross_from_alm"]


def d_err(a_init, a_out) -> float:
    """Paper eq. 19: relative round-trip error over all (l, m)."""
    a_init = np.asarray(a_init)
    a_out = np.asarray(a_out)
    num = np.sum(np.abs(a_init - a_out) ** 2)
    den = np.sum(np.abs(a_init) ** 2)
    return float(np.sqrt(num / den))


def cmb_like_cl(l_max: int, *, amp: float = 1.0, l_peak: float = 220.0,
               tilt: float = -2.0) -> np.ndarray:
    """A toy CMB-ish TT spectrum: acoustic-peak bump + damping tail.

    Not a physical model -- just gives the examples a realistic dynamic range
    (flat Sachs-Wolfe plateau, oscillations, exponential damping).
    """
    l = np.arange(l_max + 1, dtype=np.float64)
    lsafe = np.maximum(l, 1.0)
    plateau = 1.0 / (lsafe * (lsafe + 1.0))
    osc = 1.0 + 0.6 * np.cos(np.pi * l / l_peak) ** 2 * np.exp(-l / (3 * l_peak))
    damp = np.exp(-((l / (5.0 * l_peak)) ** 2))
    cl = amp * plateau * osc * damp * (lsafe / l_peak) ** (tilt + 2.0)
    cl[0] = 0.0
    return cl


def alm_from_cl(key, cl: np.ndarray, m_max: int | None = None,
                K: int = 1, dtype=jnp.float64) -> jnp.ndarray:
    """Gaussian random a_lm with <|a_lm|^2> = C_l, packed (M, L, K) complex.

    Standard CMB convention: a_l0 ~ N(0, C_l) real; for m > 0,
    Re/Im ~ N(0, C_l / 2) independently.
    """
    l_max = len(cl) - 1
    if m_max is None:
        m_max = l_max
    shape = (m_max + 1, l_max + 1, K)
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, shape, dtype)
    im = jax.random.normal(ki, shape, dtype)
    sig = jnp.sqrt(jnp.asarray(cl, dtype))[None, :, None]
    alm = (re + 1j * im) * sig / jnp.sqrt(2.0)
    alm = alm.at[0].set((re[0] * sig[0]).astype(dtype))  # m=0 real, full var
    mask = jnp.asarray(alm_mask(l_max, m_max))[..., None]
    return jnp.where(mask, alm, 0.0)


def cmb_like_cl_pol(l_max: int, *, amp: float = 1.0) -> dict:
    """Toy TT/EE/BB/TE spectra with CMB-like structure (not physical).

    EE is a few percent of TT with peaks shifted half a period (polarisation
    peaks sit at the temperature troughs), BB is a small fraction of EE
    (tensor+lensing stand-in), and TE oscillates with |TE| strictly below
    sqrt(TT*EE) so the (T, E) covariance stays positive definite.
    EE/BB/TE vanish at l < 2.
    """
    l = np.arange(l_max + 1, dtype=np.float64)
    tt = cmb_like_cl(l_max, amp=amp)
    ee = 0.04 * cmb_like_cl(l_max, amp=amp, l_peak=160.0)
    bb = 0.05 * ee * np.exp(-l / 300.0)
    te = 0.6 * np.sqrt(tt * ee) * np.cos(np.pi * l / 190.0)
    for c in (ee, bb, te):
        c[:2] = 0.0
    return {"tt": tt, "ee": ee, "bb": bb, "te": te}


def _unit_alm(key, shape, dtype):
    """Unit-variance complex alm with the real-field convention
    (<|a|^2> = 1; m = 0 real with full variance)."""
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, shape, dtype)
    im = jax.random.normal(ki, shape, dtype)
    z = (re + 1j * im) / jnp.sqrt(2.0)
    return z.at[0].set(re[0].astype(dtype))


def alm_from_cl_pol(key, cls: dict, m_max: int | None = None, K: int = 1,
                    dtype=jnp.float64) -> jnp.ndarray:
    """Correlated Gaussian (T, E, B) alm from TT/EE/BB/TE spectra.

    ``cls`` as from :func:`cmb_like_cl_pol`.  Returns (3, M, L1, K) complex
    [T, E, B]: T/E drawn with the standard Cholesky split
    (a_E = (TE/sqrt(TT)) xi_T + sqrt(EE - TE^2/TT) xi_2), B independent.
    E/B rows with l < 2 are zero.
    """
    tt = np.asarray(cls["tt"], np.float64)
    ee = np.asarray(cls["ee"], np.float64)
    bb = np.asarray(cls["bb"], np.float64)
    te = np.asarray(cls["te"], np.float64)
    l_max = len(tt) - 1
    if m_max is None:
        m_max = l_max
    shape = (m_max + 1, l_max + 1, K)
    k1, k2, k3 = jax.random.split(key, 3)
    x1 = _unit_alm(k1, shape, dtype)
    x2 = _unit_alm(k2, shape, dtype)
    x3 = _unit_alm(k3, shape, dtype)
    s_tt = np.sqrt(tt)
    c_et = np.divide(te, s_tt, out=np.zeros_like(te), where=s_tt > 0)
    s_ee = np.sqrt(np.maximum(ee - c_et ** 2, 0.0))
    row = lambda v: jnp.asarray(v, dtype)[None, :, None]
    a_t = x1 * row(s_tt)
    a_e = x1 * row(c_et) + x2 * row(s_ee)
    a_b = x3 * row(np.sqrt(bb))
    mask0 = jnp.asarray(alm_mask(l_max, m_max))[..., None]
    mask2 = jnp.asarray(alm_mask(l_max, m_max, spin=2))[..., None]
    return jnp.stack([jnp.where(mask0, a_t, 0.0),
                      jnp.where(mask2, a_e, 0.0),
                      jnp.where(mask2, a_b, 0.0)], axis=0)


def cl_cross_from_alm(alm_x: jnp.ndarray, alm_y: jnp.ndarray) -> jnp.ndarray:
    """Pseudo cross-spectrum C_l^{XY} from two packed (M, L, K) alm.

    C_l = (Re[a^X_l0 conj(a^Y_l0)] + 2 sum_{m>=1} Re[a^X conj(a^Y)])
          / (2l + 1).
    """
    p = jnp.real(alm_x * jnp.conj(alm_y))                     # (M, L, K)
    tot = p[0] + 2.0 * jnp.sum(p[1:], axis=0)                 # (L, K)
    l_max = alm_x.shape[1] - 1
    l = jnp.arange(l_max + 1, dtype=tot.dtype)[:, None]
    return tot / (2.0 * l + 1.0)


def cl_from_alm(alm: jnp.ndarray) -> jnp.ndarray:
    """Pseudo-C_l estimator from packed (M, L, K) alm (real-field m>=0).

    C_l = (|a_l0|^2 + 2 sum_{m=1}^{min(l, m_max)} |a_lm|^2) / (2 l + 1).
    """
    m_max = alm.shape[0] - 1
    l_max = alm.shape[1] - 1
    p = jnp.abs(alm) ** 2                                     # (M, L, K)
    tot = p[0] + 2.0 * jnp.sum(p[1:], axis=0)                 # (L, K)
    l = jnp.arange(l_max + 1, dtype=p.dtype)[:, None]
    return tot / (2.0 * l + 1.0)
