"""Normalised associated Legendre functions via the scaled two-term recurrence.

Implements the paper's §2.1 machinery in a vectorised, branch-free form:

  recurrence (paper eq. 7, with the sign corrected -- the published "+" is a
  typo; the standard normalised recurrence is)

      P_{l,m}(x) = beta_{l,m} * x * P_{l-1,m}(x) - (beta_{l,m}/beta_{l-1,m}) * P_{l-2,m}(x)
      beta_{l,m} = sqrt((4 l^2 - 1) / (l^2 - m^2))                (paper eq. 8)

  seeds (paper eqs. 9-10, normalised convention P_mm = mu_m (1-x^2)^{m/2})

      mu_m   = sqrt(1/(4 pi)) * prod_{k=1..m} sqrt((2k+1)/(2k))
      P_{m+1,m} = sqrt(2m+3) * x * P_mm

  and the under/overflow rescaling: instead of the paper's per-value test and
  scale-vector lookup (a scalar-code construct), we carry every P value as a
  (mantissa, scale) pair with P = mant * 2^(scale * SCALE_BITS), scale <= 0,
  and renormalise with vector selects.  Contributions with scale < 0 (i.e.
  |P| < 2^-(SCALE_BITS/2)) are dropped from accumulations; they are below the
  dtype's resolution by construction.  This is the SIMD-uniform TPU adaptation
  of the paper's scheme (DESIGN.md §2).

Everything in this module is pure jnp and dtype-parametric: float64 for the
reference/validation engine, float32 matching the Pallas kernel numerics.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autodiff import linear_pair

__all__ = [
    "log_mu",
    "log_factorials",
    "scale_bits_for",
    "pmm_scaled",
    "recurrence_step",
    "delta_from_alm",
    "alm_from_delta",
    "delta_from_alm_folded",
    "alm_from_delta_folded",
    # spin-aware harmonic core (Wigner-d generalisation)
    "spin_seeds_scaled",
    "recurrence_step_general",
    "delta_from_alm_general",
    "alm_from_delta_general",
    "spin_pack_alm",
    "spin_unpack_delta",
    "spin_pack_delta",
    "spin_unpack_alm",
    "delta_from_alm_spin",
    "alm_from_delta_spin",
    "HarmonicCore",
]

_LN2 = float(np.log(2.0))


def scale_bits_for(dtype) -> int:
    """SCALE_BITS used by the scaled recurrence for a given dtype."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.dtype(jnp.float64):
        return 512
    if dtype == jnp.dtype(jnp.float32):
        return 64
    raise ValueError(f"unsupported recurrence dtype {dtype}")


def log_mu(m_max: int) -> np.ndarray:
    """log(mu_m) for m = 0..m_max (host-side, float64).

    mu_m = sqrt(1/(4 pi)) * prod_{k=1..m} sqrt((2k+1)/(2k)); computed as a
    cumulative sum of logs so it is exact to f64 rounding for any m.
    """
    m = np.arange(1, m_max + 1, dtype=np.float64)
    inc = 0.5 * np.log((2.0 * m + 1.0) / (2.0 * m))
    out = np.empty(m_max + 1, dtype=np.float64)
    out[0] = -0.5 * np.log(4.0 * np.pi)
    out[1:] = out[0] + np.cumsum(inc)
    return out


def pmm_scaled(log_mu_m, m, sin_theta, *, dtype, scale_bits: int):
    """Scaled seed P_mm = mu_m * sin(theta)^m as (mantissa, scale).

    log P_mm = log mu_m + m * log(sin theta); split into scale * SCALE_BITS
    octaves + mantissa so the seed is representable for any m, theta.
    All logs are evaluated in float64 on the *host-precision* path (inputs may
    be numpy) and cast at the end, so the f32 engine seeds are as accurate as
    f32 allows.
    """
    log_p = log_mu_m + m * jnp.log(sin_theta)  # f64 if inputs are f64
    denom = scale_bits * _LN2
    # round (not floor): keeps the mantissa within [2^-B/2, 2^B/2] and maps
    # any representable P (log_p near 0) to scale == 0 exactly.
    scale = jnp.minimum(jnp.round(log_p / denom), 0.0)
    mant = jnp.exp(log_p - scale * denom)
    return mant.astype(dtype), scale.astype(jnp.int32)


def _beta(l, m, dtype):
    """beta_{l,m}; caller guarantees l > m (paper eq. 8)."""
    l = l.astype(dtype) if hasattr(l, "astype") else jnp.asarray(l, dtype)
    m = m.astype(dtype) if hasattr(m, "astype") else jnp.asarray(m, dtype)
    return jnp.sqrt((4.0 * l * l - 1.0) / (l * l - m * m))


def recurrence_step(l, m, x, mant_prev, mant_curr, scale, pmm_mant, pmm_scale,
                    *, scale_bits: int, dtype):
    """One vectorised step of the scaled recurrence at multipole ``l``.

    Shapes: ``m`` is (M, 1), ``x`` is (1, R) (or any broadcastable pair);
    carries are (M, R).  Returns (new_prev, new_curr, new_scale, value) where
    ``value`` is the descaled P_{l,m} (zero wherever scale < 0 or l < m).
    """
    fdt = dtype
    lf = jnp.asarray(l, fdt)
    mf = m.astype(fdt)
    # beta_{l,m} and beta_{l-1,m}: guard the l <= m+1 cases with safe values.
    # (Also guards padded lanes with m = -1 used by the distributed plan:
    # those never seed, so any finite beta keeps them at exactly zero.)
    safe = lambda v: jnp.where(jnp.isfinite(v), v, 0.0)
    bl = safe(_beta(jnp.maximum(lf, mf + 2.0), m, fdt))
    blm1 = safe(_beta(jnp.maximum(lf - 1.0, mf + 1.0), m, fdt))
    ratio = jnp.where(blm1 > 0, bl / jnp.where(blm1 > 0, blm1, 1.0), 0.0)
    two_m_p3 = jnp.sqrt(jnp.maximum(2.0 * mf + 3.0, 0.0))

    p_rec = bl * x * mant_curr - ratio * mant_prev
    p_first = two_m_p3 * x * mant_curr          # l == m+1 (curr holds P_mm)
    is_seed = l == m                             # (M, 1) broadcast
    is_first = l == m + 1
    before = l < m

    new_curr = jnp.where(before, 0.0,
               jnp.where(is_seed, pmm_mant,
               jnp.where(is_first, p_first, p_rec)))
    new_prev = jnp.where(before | is_seed, 0.0, mant_curr)
    new_scale = jnp.where(is_seed, pmm_scale, scale)

    # Renormalise: if the pair has grown past 2^(B/2), push an octave of
    # 2^B back into the scale (only meaningful while scale < 0).
    big = jnp.asarray(2.0, fdt) ** (scale_bits // 2)
    inv_big2 = jnp.asarray(2.0, fdt) ** (-scale_bits)
    grow = (jnp.abs(new_curr) > big) & (new_scale < 0)
    new_curr = jnp.where(grow, new_curr * inv_big2, new_curr)
    new_prev = jnp.where(grow, new_prev * inv_big2, new_prev)
    new_scale = jnp.where(grow, new_scale + 1, new_scale)
    # Shrink guard (pair heading to underflow while still scaled): rare for
    # the synthesis direction (P grows towards the turning point) but present
    # for completeness and required for very high m at near-polar rings.
    small = (jnp.abs(new_curr) < 1.0 / big) & (jnp.abs(new_prev) < 1.0 / big) \
        & (new_scale > jnp.int32(-32000)) & ~before & ~is_seed
    big2 = jnp.asarray(2.0, fdt) ** scale_bits
    new_curr2 = jnp.where(small, new_curr * big2, new_curr)
    new_prev2 = jnp.where(small, new_prev * big2, new_prev)
    new_scale2 = jnp.where(small, new_scale - 1, new_scale)

    value = jnp.where((new_scale2 == 0) & ~before, new_curr2, 0.0)
    return new_prev2, new_curr2, new_scale2, value


def _prep(m_vals, grid_x, log_mu_all, dtype):
    m = jnp.asarray(m_vals, jnp.int32)[:, None]                  # (M, 1)
    x = jnp.asarray(grid_x, dtype)[None, :]                      # (1, R)
    lm = jnp.asarray(log_mu_all, jnp.float64)[jnp.asarray(m_vals, jnp.int32)]
    return m, x, lm[:, None]


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _delta_from_alm_impl(a_re, a_im, m, x, sin_theta, log_mu_m, *, l_max,
                         scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    K = a_re.shape[-1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    carry0 = (
        jnp.zeros((M, R), dtype),          # P_{l-2} mantissa
        jnp.zeros((M, R), dtype),          # P_{l-1} mantissa
        jnp.zeros((M, R), jnp.int32),      # scale
        jnp.zeros((M, R, K), dtype),       # d_re accumulator
        jnp.zeros((M, R, K), dtype),       # d_im accumulator
    )

    def body(l, carry):
        mp, mc, sc, dre, dim = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        # Delta_m(r) += a_{l,m} * P_{l,m}(r)   (paper eq. 12)
        are = jax.lax.dynamic_index_in_dim(a_re, l, axis=1, keepdims=False)
        aim = jax.lax.dynamic_index_in_dim(a_im, l, axis=1, keepdims=False)
        dre = dre + val[..., None] * are[:, None, :]
        dim = dim + val[..., None] * aim[:, None, :]
        return mp, mc, sc, dre, dim

    _, _, _, d_re, d_im = jax.lax.fori_loop(0, l_max + 1, body, carry0)
    return d_re, d_im


def delta_from_alm(a_re, a_im, m_vals, grid_x, grid_sin, log_mu_all, *,
                   l_max: int, dtype=jnp.float64):
    """Synthesis inner step: Delta^A_m(r) = sum_l a_lm P_lm(cos theta_r).

    a_re/a_im: (M, l_max+1, K) with rows l < m zero-padded.
    Returns (d_re, d_im): (M, R, K).  This is paper Algorithm 2 STEP 2 /
    Algorithm 3 STEP 2, vectorised over (m, ring) with the l loop sequential.

    Differentiable both ways via the adjoint identity (VJP = analysis with
    unit weights); ``m_vals`` may be traced (the distributed stage-1 path).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    gx = np.asarray(grid_x)
    gs = np.asarray(grid_sin, np.float64)
    lm_all = np.asarray(log_mu_all)
    a_re = jnp.asarray(a_re, dtype)
    a_im = jnp.asarray(a_im, dtype)
    assert a_re.shape[1] == l_max + 1, (a_re.shape, l_max)
    R = gx.shape[0]

    def fwd(m_vals_, ops):
        ar, ai = ops
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _delta_from_alm_impl(ar, ai, m, x, gs, log_mu_m, l_max=l_max,
                                    scale_bits=sb, dtype_name=dtype.name)

    def bwd(m_vals_, cts):
        gd_re, gd_im = cts
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        ones = jnp.ones((R,), dtype)
        return _alm_from_delta_impl(gd_re, gd_im, m, x, gs, log_mu_m, ones,
                                    l_max=l_max, scale_bits=sb,
                                    dtype_name=dtype.name)

    return linear_pair(fwd, bwd, m_vals, (a_re, a_im))


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _alm_from_delta_impl(d_re, d_im, m, x, sin_theta, log_mu_m, w, *, l_max,
                         scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    dw_re = d_re * w[None, :, None]
    dw_im = d_im * w[None, :, None]
    carry0 = (
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), jnp.int32),
    )

    def step(carry, l):
        mp, mc, sc = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        # a_{l,m} = sum_r w_r Delta^S_m(r) P_lm(r)   (paper eq. 13)
        a_re_l = jnp.einsum("mr,mrk->mk", val, dw_re)
        a_im_l = jnp.einsum("mr,mrk->mk", val, dw_im)
        return (mp, mc, sc), (a_re_l, a_im_l)

    _, (a_re, a_im) = jax.lax.scan(step, carry0, jnp.arange(l_max + 1))
    # scan stacks on axis 0 -> (L, M, K); reorder to (M, L, K).
    return jnp.swapaxes(a_re, 0, 1), jnp.swapaxes(a_im, 0, 1)


def alm_from_delta(d_re, d_im, m_vals, grid_x, grid_sin, weights, log_mu_all,
                   *, l_max: int, dtype=jnp.float64):
    """Analysis inner step: a_lm = sum_r w_r Delta^S_m(r) P_lm(cos theta_r).

    d_re/d_im: (M, R, K).  Returns (a_re, a_im): (M, l_max+1, K) with rows
    l < m exactly zero.  Paper Algorithm 1 STEP 3.

    Differentiable both ways via the adjoint identity (VJP = weights times
    synthesis of the cotangent).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    gx = np.asarray(grid_x)
    gs = np.asarray(grid_sin, np.float64)
    lm_all = np.asarray(log_mu_all)
    d_re = jnp.asarray(d_re, dtype)
    d_im = jnp.asarray(d_im, dtype)

    def fwd(res, ops):
        m_vals_, w = res
        dr, di = ops
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _alm_from_delta_impl(dr, di, m, x, gs, log_mu_m, w,
                                    l_max=l_max, scale_bits=sb,
                                    dtype_name=dtype.name)

    def bwd(res, cts):
        m_vals_, w = res
        ga_re, ga_im = cts
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        gd_re, gd_im = _delta_from_alm_impl(ga_re, ga_im, m, x, gs, log_mu_m,
                                            l_max=l_max, scale_bits=sb,
                                            dtype_name=dtype.name)
        return gd_re * w[None, :, None], gd_im * w[None, :, None]

    return linear_pair(fwd, bwd, (m_vals, jnp.asarray(weights, dtype)),
                       (d_re, d_im))


# ---------------------------------------------------------------------------
# Equator-folded variants (beyond-paper optimisation; libpsht-style).
#
# P_lm(-x) = (-1)^(l+m) P_lm(x), so for a grid symmetric about the equator the
# recurrence only needs to run over the northern half of the rings:
#   Delta(north r) = E(r) + O(r),   Delta(mirror r) = E(r) - O(r)
# with E/O the even/odd (l+m) partial sums.  Halves the recurrence flops; the
# accumulate flops stay constant.  Used by the `fold=True` engine path and the
# Pallas kernel hillclimb (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _delta_from_alm_folded_impl(a_re, a_im, m, x, sin_theta, log_mu_m, *,
                                l_max, scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]      # R = number of *northern* rings
    K = a_re.shape[-1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    zeros = lambda *s: jnp.zeros(s, dtype)
    carry0 = (zeros(M, R), zeros(M, R), jnp.zeros((M, R), jnp.int32),
              zeros(M, R, K), zeros(M, R, K),   # even re/im
              zeros(M, R, K), zeros(M, R, K))   # odd re/im

    def body(l, carry):
        mp, mc, sc, ere, eim, ore_, oim = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        are = jax.lax.dynamic_index_in_dim(a_re, l, axis=1, keepdims=False)
        aim = jax.lax.dynamic_index_in_dim(a_im, l, axis=1, keepdims=False)
        cre = val[..., None] * are[:, None, :]
        cim = val[..., None] * aim[:, None, :]
        even = (((l + m) % 2) == 0)[..., None]     # (M, 1, 1)
        ere = ere + jnp.where(even, cre, 0.0)
        eim = eim + jnp.where(even, cim, 0.0)
        ore_ = ore_ + jnp.where(even, 0.0, cre)
        oim = oim + jnp.where(even, 0.0, cim)
        return mp, mc, sc, ere, eim, ore_, oim

    _, _, _, ere, eim, ore_, oim = jax.lax.fori_loop(0, l_max + 1, body, carry0)
    return ere, eim, ore_, oim


def delta_from_alm_folded(a_re, a_im, m_vals, north_x, north_sin, log_mu_all,
                          *, l_max: int, dtype=jnp.float64):
    """Folded synthesis: returns even/odd partials over the northern rings.

    (d_even_re, d_even_im, d_odd_re, d_odd_im), each (M, R_north, K).
    North ring r: even + odd; its mirror: even - odd.

    Differentiable both ways: the VJP is the folded analysis of the even/odd
    cotangent partials (the parity split is its own transpose).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    gx = np.asarray(north_x)
    gs = np.asarray(north_sin, np.float64)
    lm_all = np.asarray(log_mu_all)
    a_re = jnp.asarray(a_re, dtype)
    a_im = jnp.asarray(a_im, dtype)
    assert a_re.shape[1] == l_max + 1, (a_re.shape, l_max)

    def fwd(m_vals_, ops):
        ar, ai = ops
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _delta_from_alm_folded_impl(ar, ai, m, x, gs, log_mu_m,
                                           l_max=l_max, scale_bits=sb,
                                           dtype_name=dtype.name)

    def bwd(m_vals_, cts):
        ge_re, ge_im, go_re, go_im = cts
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _alm_from_delta_folded_impl(ge_re, ge_im, go_re, go_im, m, x,
                                           gs, log_mu_m, l_max=l_max,
                                           scale_bits=sb,
                                           dtype_name=dtype.name)

    return linear_pair(fwd, bwd, m_vals, (a_re, a_im))


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _alm_from_delta_folded_impl(s_e_re, s_e_im, s_o_re, s_o_im, m, x,
                                sin_theta, log_mu_m, *, l_max, scale_bits,
                                dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    carry0 = (jnp.zeros((M, R), dtype), jnp.zeros((M, R), dtype),
              jnp.zeros((M, R), jnp.int32))

    def step(carry, l):
        mp, mc, sc = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        even = (((l + m) % 2) == 0)[..., None]     # (M, 1) -> (M, 1, 1) below
        sre = jnp.where(even, s_e_re, s_o_re)
        sim = jnp.where(even, s_e_im, s_o_im)
        a_re_l = jnp.einsum("mr,mrk->mk", val, sre)
        a_im_l = jnp.einsum("mr,mrk->mk", val, sim)
        return (mp, mc, sc), (a_re_l, a_im_l)

    _, (a_re, a_im) = jax.lax.scan(step, carry0, jnp.arange(l_max + 1))
    return jnp.swapaxes(a_re, 0, 1), jnp.swapaxes(a_im, 0, 1)


def alm_from_delta_folded(sum_e_re, sum_e_im, sum_o_re, sum_o_im, m_vals,
                          north_x, north_sin, log_mu_all, *, l_max: int,
                          dtype=jnp.float64):
    """Folded analysis.  Inputs are the pre-folded weighted sums over ring
    pairs: sum_e = w_n*Delta(north) + w_s*Delta(south mirror), sum_o = the
    difference (equator ring, if any, contributes to sum_e and sum_o with the
    same value and half... no: with its own weight in sum_e and ZERO in sum_o
    handled by the caller).  Each (M, R_north, K).

    Differentiable both ways: the VJP is the folded synthesis of the alm
    cotangent (even/odd partials of the gradient).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    gx = np.asarray(north_x)
    gs = np.asarray(north_sin, np.float64)
    lm_all = np.asarray(log_mu_all)
    ops = tuple(jnp.asarray(v, dtype)
                for v in (sum_e_re, sum_e_im, sum_o_re, sum_o_im))

    def fwd(m_vals_, ops_):
        se_re, se_im, so_re, so_im = ops_
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _alm_from_delta_folded_impl(se_re, se_im, so_re, so_im, m, x,
                                           gs, log_mu_m, l_max=l_max,
                                           scale_bits=sb,
                                           dtype_name=dtype.name)

    def bwd(m_vals_, cts):
        ga_re, ga_im = cts
        m, x, log_mu_m = _prep(m_vals_, gx, lm_all, dtype)
        return _delta_from_alm_folded_impl(ga_re, ga_im, m, x, gs, log_mu_m,
                                           l_max=l_max, scale_bits=sb,
                                           dtype_name=dtype.name)

    return linear_pair(fwd, bwd, m_vals, ops)


# ===========================================================================
# Spin-aware harmonic core (the Wigner-d generalisation of the above).
#
# The scalar P_lm are the m' = 0 slice of the normalised Wigner-d functions
#
#     lam^{(m')}_lm(theta) = (-1)^m sqrt((2l+1)/4pi) d^l_{m,m'}(theta),
#
# and spin-s transforms need the m' = -s / m' = +s slices: for polarisation
# (spin 2, Stokes Q/U <-> E/B) the spin-(+2) harmonics are built from
# lam^{(-2)} and the spin-(-2) ones from lam^{(+2)} (the lambda^+/- pair of
# libsharp is just their half-sum/half-difference).  All slices satisfy ONE
# three-term recurrence in l (fixed m, m'), the standard Wigner-d recursion
#
#     lam_l = (a_l x + b_l) lam_{l-1} - c_l lam_{l-2},      l > l0,
#     l0   = max(m, |m'|),
#     D_l  = sqrt((l^2 - m^2)(l^2 - m'^2)),
#     a_l  = l sqrt(4l^2 - 1) / D_l,
#     b_l  = -m m' sqrt(4l^2 - 1) / ((l-1) D_l),
#     c_l  = sqrt((2l+1)/(2l-3)) l D_{l-1} / ((l-1) D_l),
#
# which reduces exactly to the scalar recurrence at m' = 0 (b_l = 0,
# a_l = beta_{l,m}, c_l = beta_{l,m}/beta_{l-1,m}) and needs no special
# "first step" case: c_{l0+1} contains D_{l0} = 0, so the lam_{l0-1} term
# vanishes by construction.  The (mantissa, scale) rescaling of the scalar
# engine carries over unchanged.
#
# Seeds at l0 (derived from d^j_{j,m'} and the d^2 table via the standard
# Wigner-d symmetries; signs folded with the (-1)^m of the lam definition):
#
#   m >= |m'|:  lam^{(m')}_{m,m} = sqrt((2m+1)/4pi)
#                 * sqrt((2m)! / ((m+m')!(m-m')!))
#                 * cos(t/2)^{m+m'} sin(t/2)^{m-m'}          (positive)
#   m' = +-2, m = 0:  lam^{(+-2)}_{2,0} =  sqrt(5/4pi) sqrt(6)/4 sin^2 t
#   m' = -2,  m = 1:  lam^{(-2)}_{2,1} =  sqrt(5/4pi) (sin t / 2) (1 - x)
#   m' = +2,  m = 1:  lam^{(+2)}_{2,1} = -sqrt(5/4pi) (sin t / 2) (1 + x)
#
# Spin-2 synthesis / analysis then reuse the whole scalar pipeline through
# the "+/-" component packing (a^+- = -(E +- iB), Delta_Q +- i Delta_U):
# two independent recurrences (m' = -2 and m' = +2) stacked along the m-row
# axis, each accumulating exactly like a scalar transform.
# ===========================================================================


def log_factorials(n_max: int) -> np.ndarray:
    """log(n!) for n = 0..n_max (host-side float64 cumulative log-sum)."""
    out = np.zeros(n_max + 1, dtype=np.float64)
    if n_max >= 1:
        out[1:] = np.cumsum(np.log(np.arange(1, n_max + 1, dtype=np.float64)))
    return out


def spin_seeds_scaled(m_vals, mprime_vals, grid_x, grid_sin, logfact, *,
                      dtype, scale_bits: int):
    """Scaled seeds lam^{(m')}_{l0,m} as (mantissa, scale), l0 = max(m,|m'|).

    ``m_vals``/``mprime_vals``: (Ms,) int (m < 0 rows are padding -> zero
    seeds); ``grid_x``/``grid_sin``: (R,) float64; ``logfact``: host table
    from :func:`log_factorials`, length >= 2*max(m)+1.  Trace-friendly
    (pure jnp), so the distributed path can pass sharded ``m_vals``.
    Currently |m'| must be 0 or 2 (asserted host-side where possible).
    """
    m = jnp.asarray(m_vals, jnp.int32)[:, None]                  # (Ms, 1)
    mp = jnp.asarray(mprime_vals, jnp.int32)[:, None]
    x = jnp.asarray(grid_x, jnp.float64)[None, :]                # (1, R)
    sin_t = jnp.asarray(grid_sin, jnp.float64)[None, :]
    lf = jnp.asarray(logfact, jnp.float64)
    mf = m.astype(jnp.float64)
    mpf = mp.astype(jnp.float64)

    # log cos(t/2), log sin(t/2) from x = cos t (grids never hit the poles)
    log_c = 0.5 * jnp.log(jnp.maximum((1.0 + x) / 2.0, 1e-300))
    log_s = 0.5 * jnp.log(jnp.maximum((1.0 - x) / 2.0, 1e-300))

    # --- general m >= |m'| branch (log domain; also the scalar m' = 0 seed)
    msafe = jnp.maximum(m, 0)
    idx = lambda v: jnp.clip(v, 0, lf.shape[0] - 1)
    log_norm = 0.5 * (jnp.log(2.0 * jnp.maximum(mf, 0.0) + 1.0)
                      - jnp.log(4.0 * jnp.pi))
    log_ratio = 0.5 * (lf[idx(2 * msafe)] - lf[idx(msafe + mp)]
                       - lf[idx(msafe - mp)])
    log_p = (log_norm + log_ratio
             + (mf + mpf) * log_c + (mf - mpf) * log_s)
    denom = scale_bits * _LN2
    scale_g = jnp.minimum(jnp.round(log_p / denom), 0.0)
    mant_g = jnp.exp(log_p - scale_g * denom)

    # --- |m'| = 2, m < 2 branches (O(1) values, unscaled)
    c5 = float(np.sqrt(5.0 / (4.0 * np.pi)))
    v_m0 = c5 * (np.sqrt(6.0) / 4.0) * sin_t * sin_t
    v_m1 = jnp.where(mp < 0,
                     c5 * 0.5 * sin_t * (1.0 - x),      # m' = -2
                     -c5 * 0.5 * sin_t * (1.0 + x))     # m' = +2
    low = (m < jnp.abs(mp)) & (m >= 0)
    mant = jnp.where(low, jnp.where(m == 0, v_m0, v_m1), mant_g)
    scale = jnp.where(low, 0.0, scale_g)
    mant = jnp.where(m >= 0, mant, 0.0)
    scale = jnp.where(m >= 0, scale, 0.0)
    return mant.astype(dtype), scale.astype(jnp.int32)


def recurrence_step_general(l, m, mp, x, mant_prev, mant_curr, scale,
                            seed_mant, seed_scale, *, scale_bits: int, dtype):
    """One step of the generalised (spin-aware) scaled recurrence.

    Identical contract to :func:`recurrence_step` but seeded at
    ``l0 = max(m, |m'|)`` and using the Wigner-d coefficients; reduces to
    the scalar recurrence at ``m' = 0``.  ``mp`` is (Ms, 1) like ``m``.
    """
    fdt = dtype
    lf = jnp.asarray(l, fdt)
    mf = m.astype(fdt)
    mpf = mp.astype(fdt)
    l0 = jnp.maximum(mf, jnp.abs(mpf))
    ls = jnp.maximum(lf, l0 + 1.0)                    # safe l for coefficients
    d2 = jnp.maximum((ls * ls - mf * mf) * (ls * ls - mpf * mpf), 1e-30)
    lm1 = ls - 1.0
    d2m1 = jnp.maximum((lm1 * lm1 - mf * mf) * (lm1 * lm1 - mpf * mpf), 0.0)
    s2l = jnp.sqrt(4.0 * ls * ls - 1.0)
    inv_d = 1.0 / jnp.sqrt(d2)
    inv_lm1 = 1.0 / jnp.maximum(lm1, 1.0)
    a = ls * s2l * inv_d
    b = -(mf * mpf) * s2l * inv_d * inv_lm1
    c = (jnp.sqrt((2.0 * ls + 1.0) / jnp.maximum(2.0 * ls - 3.0, 1.0))
         * ls * jnp.sqrt(d2m1) * inv_d * inv_lm1)

    p_rec = (a * x + b) * mant_curr - c * mant_prev
    is_seed = lf == l0
    before = lf < l0

    new_curr = jnp.where(before, 0.0,
               jnp.where(is_seed, seed_mant, p_rec))
    new_prev = jnp.where(before | is_seed, 0.0, mant_curr)
    new_scale = jnp.where(is_seed, seed_scale, scale)

    big = jnp.asarray(2.0, fdt) ** (scale_bits // 2)
    inv_big2 = jnp.asarray(2.0, fdt) ** (-scale_bits)
    grow = (jnp.abs(new_curr) > big) & (new_scale < 0)
    new_curr = jnp.where(grow, new_curr * inv_big2, new_curr)
    new_prev = jnp.where(grow, new_prev * inv_big2, new_prev)
    new_scale = jnp.where(grow, new_scale + 1, new_scale)
    small = (jnp.abs(new_curr) < 1.0 / big) & (jnp.abs(new_prev) < 1.0 / big) \
        & (new_scale > jnp.int32(-32000)) & ~before & ~is_seed
    big2 = jnp.asarray(2.0, fdt) ** scale_bits
    new_curr2 = jnp.where(small, new_curr * big2, new_curr)
    new_prev2 = jnp.where(small, new_prev * big2, new_prev)
    new_scale2 = jnp.where(small, new_scale - 1, new_scale)

    value = jnp.where((new_scale2 == 0) & ~before, new_curr2, 0.0)
    return new_prev2, new_curr2, new_scale2, value


def _prep_general(m_vals, mprime_vals, grid_x, dtype):
    m = jnp.asarray(m_vals, jnp.int32)[:, None]
    mp = jnp.asarray(mprime_vals, jnp.int32)[:, None]
    x = jnp.asarray(grid_x, dtype)[None, :]
    return m, mp, x


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits",
                                             "dtype_name"))
def _delta_from_alm_general_impl(a_re, a_im, m, mp, x, seed_mant, seed_scale,
                                 *, l_max, scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    K = a_re.shape[-1]
    carry0 = (
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), jnp.int32),
        jnp.zeros((M, R, K), dtype),
        jnp.zeros((M, R, K), dtype),
    )

    def body(l, carry):
        mprev, mcurr, sc, dre, dim = carry
        mprev, mcurr, sc, val = recurrence_step_general(
            l, m, mp, x, mprev, mcurr, sc, seed_mant, seed_scale,
            scale_bits=scale_bits, dtype=dtype)
        are = jax.lax.dynamic_index_in_dim(a_re, l, axis=1, keepdims=False)
        aim = jax.lax.dynamic_index_in_dim(a_im, l, axis=1, keepdims=False)
        dre = dre + val[..., None] * are[:, None, :]
        dim = dim + val[..., None] * aim[:, None, :]
        return mprev, mcurr, sc, dre, dim

    _, _, _, d_re, d_im = jax.lax.fori_loop(0, l_max + 1, body, carry0)
    return d_re, d_im


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits",
                                             "dtype_name"))
def _alm_from_delta_general_impl(d_re, d_im, m, mp, x, seed_mant, seed_scale,
                                 *, l_max, scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    carry0 = (jnp.zeros((M, R), dtype), jnp.zeros((M, R), dtype),
              jnp.zeros((M, R), jnp.int32))

    def step(carry, l):
        mprev, mcurr, sc = carry
        mprev, mcurr, sc, val = recurrence_step_general(
            l, m, mp, x, mprev, mcurr, sc, seed_mant, seed_scale,
            scale_bits=scale_bits, dtype=dtype)
        a_re_l = jnp.einsum("mr,mrk->mk", val, d_re)
        a_im_l = jnp.einsum("mr,mrk->mk", val, d_im)
        return (mprev, mcurr, sc), (a_re_l, a_im_l)

    _, (a_re, a_im) = jax.lax.scan(step, carry0, jnp.arange(l_max + 1))
    return jnp.swapaxes(a_re, 0, 1), jnp.swapaxes(a_im, 0, 1)


def _seed_tables(m_vals, mprime_vals, grid_x, grid_sin, m_max, dtype, sb):
    if m_max is None:
        m_max = int(np.max(np.asarray(m_vals)))
    logfact = log_factorials(2 * max(int(m_max), 2) + 1)
    return spin_seeds_scaled(m_vals, mprime_vals, grid_x, grid_sin, logfact,
                             dtype=dtype, scale_bits=sb)


def delta_from_alm_general(a_re, a_im, m_vals, mprime_vals, grid_x, grid_sin,
                           *, l_max: int, m_max: Optional[int] = None,
                           dtype=jnp.float64):
    """Generalised synthesis inner step over lam^{(m')} rows.

    Like :func:`delta_from_alm` but each row carries its own (m, m') pair
    (m' = 0 rows reproduce the scalar transform through the generalised
    recurrence).  a_re/a_im: (Ms, l_max+1, K) -> (Ms, R, K).
    ``m_max`` must be given when ``m_vals`` is traced (distributed path).

    Differentiable both ways (VJP = generalised analysis of the cotangent,
    same Wigner-d rows, unit weights).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    seed_mant, seed_scale = _seed_tables(m_vals, mprime_vals, grid_x,
                                         grid_sin, m_max, dtype, sb)
    a_re = jnp.asarray(a_re, dtype)
    a_im = jnp.asarray(a_im, dtype)
    assert a_re.shape[1] == l_max + 1, (a_re.shape, l_max)

    def fwd(res, ops):
        m, mp, x, sm, ss = res
        ar, ai = ops
        return _delta_from_alm_general_impl(ar, ai, m, mp, x, sm, ss,
                                            l_max=l_max, scale_bits=sb,
                                            dtype_name=dtype.name)

    def bwd(res, cts):
        m, mp, x, sm, ss = res
        gd_re, gd_im = cts
        return _alm_from_delta_general_impl(gd_re, gd_im, m, mp, x, sm, ss,
                                            l_max=l_max, scale_bits=sb,
                                            dtype_name=dtype.name)

    m, mp, x = _prep_general(m_vals, mprime_vals, grid_x, dtype)
    return linear_pair(fwd, bwd, (m, mp, x, seed_mant, seed_scale),
                       (a_re, a_im))


def alm_from_delta_general(d_re, d_im, m_vals, mprime_vals, grid_x, grid_sin,
                           *, l_max: int, m_max: Optional[int] = None,
                           dtype=jnp.float64):
    """Generalised analysis inner step (adjoint of the above).

    d_re/d_im: (Ms, R, K) *weighted* Delta -> (Ms, l_max+1, K); rows with
    l < max(m, |m'|) come out exactly zero.

    Differentiable both ways (VJP = generalised synthesis of the alm
    cotangent).
    """
    dtype = jnp.dtype(dtype)
    sb = scale_bits_for(dtype)
    seed_mant, seed_scale = _seed_tables(m_vals, mprime_vals, grid_x,
                                         grid_sin, m_max, dtype, sb)
    d_re = jnp.asarray(d_re, dtype)
    d_im = jnp.asarray(d_im, dtype)

    def fwd(res, ops):
        m, mp, x, sm, ss = res
        dr, di = ops
        return _alm_from_delta_general_impl(dr, di, m, mp, x, sm, ss,
                                            l_max=l_max, scale_bits=sb,
                                            dtype_name=dtype.name)

    def bwd(res, cts):
        m, mp, x, sm, ss = res
        ga_re, ga_im = cts
        return _delta_from_alm_general_impl(ga_re, ga_im, m, mp, x, sm, ss,
                                            l_max=l_max, scale_bits=sb,
                                            dtype_name=dtype.name)

    m, mp, x = _prep_general(m_vals, mprime_vals, grid_x, dtype)
    return linear_pair(fwd, bwd, (m, mp, x, seed_mant, seed_scale),
                       (d_re, d_im))


# ---------------------------------------------------------------------------
# Spin-2 component packing: (E, B) <-> a^+- = -(E +- iB), stacked along the
# row axis as [m' = -2 rows | m' = +2 rows], and (Delta_Q, Delta_U) <->
# Delta^+- = Delta_Q +- i Delta_U.  Shared by the f64 engine, the Pallas
# wrappers and the distributed transform (all dtypes, any trailing dims).
# ---------------------------------------------------------------------------


def spin_pack_alm(e_re, e_im, b_re, b_im):
    """(E, B) -> stacked a^+- rows: a2 = [-(E+iB) | -(E-iB)], (2M, ...)."""
    a_p_re = -(e_re - b_im)
    a_p_im = -(e_im + b_re)
    a_m_re = -(e_re + b_im)
    a_m_im = -(e_im - b_re)
    return (jnp.concatenate([a_p_re, a_m_re], axis=0),
            jnp.concatenate([a_p_im, a_m_im], axis=0))


def spin_unpack_delta(d_re, d_im):
    """Stacked Delta^+- rows (2M, ...) -> (dq_re, dq_im, du_re, du_im).

    Delta_Q = (Delta^+ + Delta^-)/2,  Delta_U = -i (Delta^+ - Delta^-)/2.
    """
    M = d_re.shape[0] // 2
    dp_re, dm_re = d_re[:M], d_re[M:]
    dp_im, dm_im = d_im[:M], d_im[M:]
    dq_re = 0.5 * (dp_re + dm_re)
    dq_im = 0.5 * (dp_im + dm_im)
    du_re = 0.5 * (dp_im - dm_im)
    du_im = -0.5 * (dp_re - dm_re)
    return dq_re, dq_im, du_re, du_im


def spin_pack_delta(dq_re, dq_im, du_re, du_im):
    """(Delta_Q, Delta_U) -> stacked Delta^+- = Delta_Q +- i Delta_U rows."""
    dp_re = dq_re - du_im
    dp_im = dq_im + du_re
    dm_re = dq_re + du_im
    dm_im = dq_im - du_re
    return (jnp.concatenate([dp_re, dm_re], axis=0),
            jnp.concatenate([dp_im, dm_im], axis=0))


def spin_unpack_alm(a_re, a_im):
    """Stacked a^+- rows (2M, ...) -> (e_re, e_im, b_re, b_im).

    E = -(a^+ + a^-)/2,  B = i (a^+ - a^-)/2.
    """
    M = a_re.shape[0] // 2
    ap_re, am_re = a_re[:M], a_re[M:]
    ap_im, am_im = a_im[:M], a_im[M:]
    e_re = -0.5 * (ap_re + am_re)
    e_im = -0.5 * (ap_im + am_im)
    b_re = -0.5 * (ap_im - am_im)
    b_im = 0.5 * (ap_re - am_re)
    return e_re, e_im, b_re, b_im


def _spin_rows(m_vals):
    """Stack m rows for the two spin recurrences -> (m2, mp2), each (2M,).

    Stays numpy for concrete inputs (so plan layers can treat the result
    as static); traced ``m_vals`` (the distributed path) stay jnp.
    """
    if isinstance(m_vals, (np.ndarray, list, tuple)):
        m2 = np.concatenate([np.asarray(m_vals, np.int32)] * 2, axis=0)
        M = m2.shape[0] // 2
    else:
        m2 = jnp.concatenate([jnp.asarray(m_vals, jnp.int32)] * 2, axis=0)
        M = m2.shape[0] // 2
    mp2 = np.concatenate([np.full(M, -2, np.int32), np.full(M, 2, np.int32)])
    return m2, mp2


def delta_from_alm_spin(e_re, e_im, b_re, b_im, m_vals, grid_x, grid_sin, *,
                        l_max: int, m_max: Optional[int] = None,
                        dtype=jnp.float64):
    """Spin-2 synthesis inner step: (E, B) alm -> (Delta_Q, Delta_U).

    Inputs (M, l_max+1, K) real/imag parts; returns
    (dq_re, dq_im, du_re, du_im), each (M, R, K).
    """
    a2_re, a2_im = spin_pack_alm(e_re, e_im, b_re, b_im)
    m2, mp2 = _spin_rows(m_vals)
    d_re, d_im = delta_from_alm_general(
        a2_re, a2_im, m2, mp2, grid_x, grid_sin, l_max=l_max, m_max=m_max,
        dtype=dtype)
    return spin_unpack_delta(d_re, d_im)


def alm_from_delta_spin(dq_re, dq_im, du_re, du_im, m_vals, grid_x, grid_sin,
                        *, l_max: int, m_max: Optional[int] = None,
                        dtype=jnp.float64):
    """Spin-2 analysis inner step: weighted (Delta_Q, Delta_U) -> (E, B).

    Inputs (M, R, K); returns (e_re, e_im, b_re, b_im), each (M, L1, K).
    """
    d2_re, d2_im = spin_pack_delta(dq_re, dq_im, du_re, du_im)
    m2, mp2 = _spin_rows(m_vals)
    a_re, a_im = alm_from_delta_general(
        d2_re, d2_im, m2, mp2, grid_x, grid_sin, l_max=l_max, m_max=m_max,
        dtype=dtype)
    return spin_unpack_alm(a_re, a_im)


import dataclasses as _dataclasses


@_dataclasses.dataclass(frozen=True)
class HarmonicCore:
    """Spin-aware recurrence layer: one surface over the scalar P_lm panels
    (spin 0) and the spin-weighted lambda pairs (spin 2).

    The serial engine (`core.sht.SHT`), and through it every plan backend,
    produces/consumes per-ring Fourier coefficients via this object:

      ``delta_from_alm``: complex alm (M, L, K)            [spin 0]
                          or (2, M, L, K) = (E, B)          [spin 2]
                       -> Delta (M, R, K) / (2, M, R, K) = (Q, U) rows.
      ``alm_from_delta``: the adjoint (weighted Delta in).

    Spin 2 runs two generalised Wigner-d recurrences (m' = -2, +2) stacked
    along the row axis -- exactly 2x the scalar panel work -- and mixes the
    components host-side (`spin_pack_alm` and friends).
    """

    m_vals: tuple
    grid_x: np.ndarray
    grid_sin: np.ndarray
    log_mu_all: np.ndarray
    l_max: int
    spin: int = 0
    dtype: str = "float64"

    def __post_init__(self):
        assert self.spin in (0, 2), f"unsupported spin {self.spin}"

    @property
    def n_components(self) -> int:
        return 1 if self.spin == 0 else 2

    def delta_from_alm(self, alm):
        dt = jnp.dtype(self.dtype)
        if self.spin == 0:
            d_re, d_im = delta_from_alm(
                jnp.real(alm), jnp.imag(alm), self.m_vals, self.grid_x,
                self.grid_sin, self.log_mu_all, l_max=self.l_max, dtype=dt)
            return d_re + 1j * d_im
        e, b = alm[0], alm[1]
        dq_re, dq_im, du_re, du_im = delta_from_alm_spin(
            jnp.real(e), jnp.imag(e), jnp.real(b), jnp.imag(b), self.m_vals,
            self.grid_x, self.grid_sin, l_max=self.l_max, dtype=dt)
        return jnp.stack([dq_re + 1j * dq_im, du_re + 1j * du_im], axis=0)

    def alm_from_delta(self, delta_w):
        dt = jnp.dtype(self.dtype)
        if self.spin == 0:
            ones = np.ones(np.asarray(self.grid_x).shape[0])
            a_re, a_im = alm_from_delta(
                jnp.real(delta_w), jnp.imag(delta_w), self.m_vals,
                self.grid_x, self.grid_sin, ones, self.log_mu_all,
                l_max=self.l_max, dtype=dt)
            return a_re + 1j * a_im
        dq, du = delta_w[0], delta_w[1]
        e_re, e_im, b_re, b_im = alm_from_delta_spin(
            jnp.real(dq), jnp.imag(dq), jnp.real(du), jnp.imag(du),
            self.m_vals, self.grid_x, self.grid_sin, l_max=self.l_max,
            dtype=dt)
        return jnp.stack([e_re + 1j * e_im, b_re + 1j * b_im], axis=0)
