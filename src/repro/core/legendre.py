"""Normalised associated Legendre functions via the scaled two-term recurrence.

Implements the paper's §2.1 machinery in a vectorised, branch-free form:

  recurrence (paper eq. 7, with the sign corrected -- the published "+" is a
  typo; the standard normalised recurrence is)

      P_{l,m}(x) = beta_{l,m} * x * P_{l-1,m}(x) - (beta_{l,m}/beta_{l-1,m}) * P_{l-2,m}(x)
      beta_{l,m} = sqrt((4 l^2 - 1) / (l^2 - m^2))                (paper eq. 8)

  seeds (paper eqs. 9-10, normalised convention P_mm = mu_m (1-x^2)^{m/2})

      mu_m   = sqrt(1/(4 pi)) * prod_{k=1..m} sqrt((2k+1)/(2k))
      P_{m+1,m} = sqrt(2m+3) * x * P_mm

  and the under/overflow rescaling: instead of the paper's per-value test and
  scale-vector lookup (a scalar-code construct), we carry every P value as a
  (mantissa, scale) pair with P = mant * 2^(scale * SCALE_BITS), scale <= 0,
  and renormalise with vector selects.  Contributions with scale < 0 (i.e.
  |P| < 2^-(SCALE_BITS/2)) are dropped from accumulations; they are below the
  dtype's resolution by construction.  This is the SIMD-uniform TPU adaptation
  of the paper's scheme (DESIGN.md §2).

Everything in this module is pure jnp and dtype-parametric: float64 for the
reference/validation engine, float32 matching the Pallas kernel numerics.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "log_mu",
    "scale_bits_for",
    "pmm_scaled",
    "recurrence_step",
    "delta_from_alm",
    "alm_from_delta",
    "delta_from_alm_folded",
    "alm_from_delta_folded",
]

_LN2 = float(np.log(2.0))


def scale_bits_for(dtype) -> int:
    """SCALE_BITS used by the scaled recurrence for a given dtype."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.dtype(jnp.float64):
        return 512
    if dtype == jnp.dtype(jnp.float32):
        return 64
    raise ValueError(f"unsupported recurrence dtype {dtype}")


def log_mu(m_max: int) -> np.ndarray:
    """log(mu_m) for m = 0..m_max (host-side, float64).

    mu_m = sqrt(1/(4 pi)) * prod_{k=1..m} sqrt((2k+1)/(2k)); computed as a
    cumulative sum of logs so it is exact to f64 rounding for any m.
    """
    m = np.arange(1, m_max + 1, dtype=np.float64)
    inc = 0.5 * np.log((2.0 * m + 1.0) / (2.0 * m))
    out = np.empty(m_max + 1, dtype=np.float64)
    out[0] = -0.5 * np.log(4.0 * np.pi)
    out[1:] = out[0] + np.cumsum(inc)
    return out


def pmm_scaled(log_mu_m, m, sin_theta, *, dtype, scale_bits: int):
    """Scaled seed P_mm = mu_m * sin(theta)^m as (mantissa, scale).

    log P_mm = log mu_m + m * log(sin theta); split into scale * SCALE_BITS
    octaves + mantissa so the seed is representable for any m, theta.
    All logs are evaluated in float64 on the *host-precision* path (inputs may
    be numpy) and cast at the end, so the f32 engine seeds are as accurate as
    f32 allows.
    """
    log_p = log_mu_m + m * jnp.log(sin_theta)  # f64 if inputs are f64
    denom = scale_bits * _LN2
    # round (not floor): keeps the mantissa within [2^-B/2, 2^B/2] and maps
    # any representable P (log_p near 0) to scale == 0 exactly.
    scale = jnp.minimum(jnp.round(log_p / denom), 0.0)
    mant = jnp.exp(log_p - scale * denom)
    return mant.astype(dtype), scale.astype(jnp.int32)


def _beta(l, m, dtype):
    """beta_{l,m}; caller guarantees l > m (paper eq. 8)."""
    l = l.astype(dtype) if hasattr(l, "astype") else jnp.asarray(l, dtype)
    m = m.astype(dtype) if hasattr(m, "astype") else jnp.asarray(m, dtype)
    return jnp.sqrt((4.0 * l * l - 1.0) / (l * l - m * m))


def recurrence_step(l, m, x, mant_prev, mant_curr, scale, pmm_mant, pmm_scale,
                    *, scale_bits: int, dtype):
    """One vectorised step of the scaled recurrence at multipole ``l``.

    Shapes: ``m`` is (M, 1), ``x`` is (1, R) (or any broadcastable pair);
    carries are (M, R).  Returns (new_prev, new_curr, new_scale, value) where
    ``value`` is the descaled P_{l,m} (zero wherever scale < 0 or l < m).
    """
    fdt = dtype
    lf = jnp.asarray(l, fdt)
    mf = m.astype(fdt)
    # beta_{l,m} and beta_{l-1,m}: guard the l <= m+1 cases with safe values.
    # (Also guards padded lanes with m = -1 used by the distributed plan:
    # those never seed, so any finite beta keeps them at exactly zero.)
    safe = lambda v: jnp.where(jnp.isfinite(v), v, 0.0)
    bl = safe(_beta(jnp.maximum(lf, mf + 2.0), m, fdt))
    blm1 = safe(_beta(jnp.maximum(lf - 1.0, mf + 1.0), m, fdt))
    ratio = jnp.where(blm1 > 0, bl / jnp.where(blm1 > 0, blm1, 1.0), 0.0)
    two_m_p3 = jnp.sqrt(jnp.maximum(2.0 * mf + 3.0, 0.0))

    p_rec = bl * x * mant_curr - ratio * mant_prev
    p_first = two_m_p3 * x * mant_curr          # l == m+1 (curr holds P_mm)
    is_seed = l == m                             # (M, 1) broadcast
    is_first = l == m + 1
    before = l < m

    new_curr = jnp.where(before, 0.0,
               jnp.where(is_seed, pmm_mant,
               jnp.where(is_first, p_first, p_rec)))
    new_prev = jnp.where(before | is_seed, 0.0, mant_curr)
    new_scale = jnp.where(is_seed, pmm_scale, scale)

    # Renormalise: if the pair has grown past 2^(B/2), push an octave of
    # 2^B back into the scale (only meaningful while scale < 0).
    big = jnp.asarray(2.0, fdt) ** (scale_bits // 2)
    inv_big2 = jnp.asarray(2.0, fdt) ** (-scale_bits)
    grow = (jnp.abs(new_curr) > big) & (new_scale < 0)
    new_curr = jnp.where(grow, new_curr * inv_big2, new_curr)
    new_prev = jnp.where(grow, new_prev * inv_big2, new_prev)
    new_scale = jnp.where(grow, new_scale + 1, new_scale)
    # Shrink guard (pair heading to underflow while still scaled): rare for
    # the synthesis direction (P grows towards the turning point) but present
    # for completeness and required for very high m at near-polar rings.
    small = (jnp.abs(new_curr) < 1.0 / big) & (jnp.abs(new_prev) < 1.0 / big) \
        & (new_scale > jnp.int32(-32000)) & ~before & ~is_seed
    big2 = jnp.asarray(2.0, fdt) ** scale_bits
    new_curr2 = jnp.where(small, new_curr * big2, new_curr)
    new_prev2 = jnp.where(small, new_prev * big2, new_prev)
    new_scale2 = jnp.where(small, new_scale - 1, new_scale)

    value = jnp.where((new_scale2 == 0) & ~before, new_curr2, 0.0)
    return new_prev2, new_curr2, new_scale2, value


def _prep(m_vals, grid_x, log_mu_all, dtype):
    m = jnp.asarray(m_vals, jnp.int32)[:, None]                  # (M, 1)
    x = jnp.asarray(grid_x, dtype)[None, :]                      # (1, R)
    lm = jnp.asarray(log_mu_all, jnp.float64)[jnp.asarray(m_vals, jnp.int32)]
    return m, x, lm[:, None]


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _delta_from_alm_impl(a_re, a_im, m, x, sin_theta, log_mu_m, *, l_max,
                         scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    K = a_re.shape[-1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    carry0 = (
        jnp.zeros((M, R), dtype),          # P_{l-2} mantissa
        jnp.zeros((M, R), dtype),          # P_{l-1} mantissa
        jnp.zeros((M, R), jnp.int32),      # scale
        jnp.zeros((M, R, K), dtype),       # d_re accumulator
        jnp.zeros((M, R, K), dtype),       # d_im accumulator
    )

    def body(l, carry):
        mp, mc, sc, dre, dim = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        # Delta_m(r) += a_{l,m} * P_{l,m}(r)   (paper eq. 12)
        are = jax.lax.dynamic_index_in_dim(a_re, l, axis=1, keepdims=False)
        aim = jax.lax.dynamic_index_in_dim(a_im, l, axis=1, keepdims=False)
        dre = dre + val[..., None] * are[:, None, :]
        dim = dim + val[..., None] * aim[:, None, :]
        return mp, mc, sc, dre, dim

    _, _, _, d_re, d_im = jax.lax.fori_loop(0, l_max + 1, body, carry0)
    return d_re, d_im


def delta_from_alm(a_re, a_im, m_vals, grid_x, grid_sin, log_mu_all, *,
                   l_max: int, dtype=jnp.float64):
    """Synthesis inner step: Delta^A_m(r) = sum_l a_lm P_lm(cos theta_r).

    a_re/a_im: (M, l_max+1, K) with rows l < m zero-padded.
    Returns (d_re, d_im): (M, R, K).  This is paper Algorithm 2 STEP 2 /
    Algorithm 3 STEP 2, vectorised over (m, ring) with the l loop sequential.
    """
    dtype = jnp.dtype(dtype)
    m, x, log_mu_m = _prep(m_vals, grid_x, log_mu_all, dtype)
    sb = scale_bits_for(dtype)
    return _delta_from_alm_impl(
        jnp.asarray(a_re, dtype), jnp.asarray(a_im, dtype), m, x,
        np.asarray(grid_sin, np.float64), log_mu_m,
        l_max=l_max, scale_bits=sb, dtype_name=dtype.name)


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _alm_from_delta_impl(d_re, d_im, m, x, sin_theta, log_mu_m, w, *, l_max,
                         scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    dw_re = d_re * w[None, :, None]
    dw_im = d_im * w[None, :, None]
    carry0 = (
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), dtype),
        jnp.zeros((M, R), jnp.int32),
    )

    def step(carry, l):
        mp, mc, sc = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        # a_{l,m} = sum_r w_r Delta^S_m(r) P_lm(r)   (paper eq. 13)
        a_re_l = jnp.einsum("mr,mrk->mk", val, dw_re)
        a_im_l = jnp.einsum("mr,mrk->mk", val, dw_im)
        return (mp, mc, sc), (a_re_l, a_im_l)

    _, (a_re, a_im) = jax.lax.scan(step, carry0, jnp.arange(l_max + 1))
    # scan stacks on axis 0 -> (L, M, K); reorder to (M, L, K).
    return jnp.swapaxes(a_re, 0, 1), jnp.swapaxes(a_im, 0, 1)


def alm_from_delta(d_re, d_im, m_vals, grid_x, grid_sin, weights, log_mu_all,
                   *, l_max: int, dtype=jnp.float64):
    """Analysis inner step: a_lm = sum_r w_r Delta^S_m(r) P_lm(cos theta_r).

    d_re/d_im: (M, R, K).  Returns (a_re, a_im): (M, l_max+1, K) with rows
    l < m exactly zero.  Paper Algorithm 1 STEP 3.
    """
    dtype = jnp.dtype(dtype)
    m, x, log_mu_m = _prep(m_vals, grid_x, log_mu_all, dtype)
    sb = scale_bits_for(dtype)
    w = jnp.asarray(weights, dtype)
    return _alm_from_delta_impl(
        jnp.asarray(d_re, dtype), jnp.asarray(d_im, dtype), m, x,
        np.asarray(grid_sin, np.float64), log_mu_m, w,
        l_max=l_max, scale_bits=sb, dtype_name=dtype.name)


# ---------------------------------------------------------------------------
# Equator-folded variants (beyond-paper optimisation; libpsht-style).
#
# P_lm(-x) = (-1)^(l+m) P_lm(x), so for a grid symmetric about the equator the
# recurrence only needs to run over the northern half of the rings:
#   Delta(north r) = E(r) + O(r),   Delta(mirror r) = E(r) - O(r)
# with E/O the even/odd (l+m) partial sums.  Halves the recurrence flops; the
# accumulate flops stay constant.  Used by the `fold=True` engine path and the
# Pallas kernel hillclimb (EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _delta_from_alm_folded_impl(a_re, a_im, m, x, sin_theta, log_mu_m, *,
                                l_max, scale_bits, dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]      # R = number of *northern* rings
    K = a_re.shape[-1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    zeros = lambda *s: jnp.zeros(s, dtype)
    carry0 = (zeros(M, R), zeros(M, R), jnp.zeros((M, R), jnp.int32),
              zeros(M, R, K), zeros(M, R, K),   # even re/im
              zeros(M, R, K), zeros(M, R, K))   # odd re/im

    def body(l, carry):
        mp, mc, sc, ere, eim, ore_, oim = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        are = jax.lax.dynamic_index_in_dim(a_re, l, axis=1, keepdims=False)
        aim = jax.lax.dynamic_index_in_dim(a_im, l, axis=1, keepdims=False)
        cre = val[..., None] * are[:, None, :]
        cim = val[..., None] * aim[:, None, :]
        even = (((l + m) % 2) == 0)[..., None]     # (M, 1, 1)
        ere = ere + jnp.where(even, cre, 0.0)
        eim = eim + jnp.where(even, cim, 0.0)
        ore_ = ore_ + jnp.where(even, 0.0, cre)
        oim = oim + jnp.where(even, 0.0, cim)
        return mp, mc, sc, ere, eim, ore_, oim

    _, _, _, ere, eim, ore_, oim = jax.lax.fori_loop(0, l_max + 1, body, carry0)
    return ere, eim, ore_, oim


def delta_from_alm_folded(a_re, a_im, m_vals, north_x, north_sin, log_mu_all,
                          *, l_max: int, dtype=jnp.float64):
    """Folded synthesis: returns even/odd partials over the northern rings.

    (d_even_re, d_even_im, d_odd_re, d_odd_im), each (M, R_north, K).
    North ring r: even + odd; its mirror: even - odd.
    """
    dtype = jnp.dtype(dtype)
    m, x, log_mu_m = _prep(m_vals, north_x, log_mu_all, dtype)
    sb = scale_bits_for(dtype)
    return _delta_from_alm_folded_impl(
        jnp.asarray(a_re, dtype), jnp.asarray(a_im, dtype), m, x,
        np.asarray(north_sin, np.float64), log_mu_m,
        l_max=l_max, scale_bits=sb, dtype_name=dtype.name)


@functools.partial(jax.jit, static_argnames=("l_max", "scale_bits", "dtype_name"))
def _alm_from_delta_folded_impl(s_e_re, s_e_im, s_o_re, s_o_im, m, x,
                                sin_theta, log_mu_m, *, l_max, scale_bits,
                                dtype_name):
    dtype = jnp.dtype(dtype_name)
    M, R = m.shape[0], x.shape[1]
    pmm_mant, pmm_scale = pmm_scaled(log_mu_m, m.astype(jnp.float64),
                                     jnp.asarray(sin_theta, jnp.float64)[None, :],
                                     dtype=dtype, scale_bits=scale_bits)
    carry0 = (jnp.zeros((M, R), dtype), jnp.zeros((M, R), dtype),
              jnp.zeros((M, R), jnp.int32))

    def step(carry, l):
        mp, mc, sc = carry
        mp, mc, sc, val = recurrence_step(
            l, m, x, mp, mc, sc, pmm_mant, pmm_scale,
            scale_bits=scale_bits, dtype=dtype)
        even = (((l + m) % 2) == 0)[..., None]     # (M, 1) -> (M, 1, 1) below
        sre = jnp.where(even, s_e_re, s_o_re)
        sim = jnp.where(even, s_e_im, s_o_im)
        a_re_l = jnp.einsum("mr,mrk->mk", val, sre)
        a_im_l = jnp.einsum("mr,mrk->mk", val, sim)
        return (mp, mc, sc), (a_re_l, a_im_l)

    _, (a_re, a_im) = jax.lax.scan(step, carry0, jnp.arange(l_max + 1))
    return jnp.swapaxes(a_re, 0, 1), jnp.swapaxes(a_im, 0, 1)


def alm_from_delta_folded(sum_e_re, sum_e_im, sum_o_re, sum_o_im, m_vals,
                          north_x, north_sin, log_mu_all, *, l_max: int,
                          dtype=jnp.float64):
    """Folded analysis.  Inputs are the pre-folded weighted sums over ring
    pairs: sum_e = w_n*Delta(north) + w_s*Delta(south mirror), sum_o = the
    difference (equator ring, if any, contributes to sum_e and sum_o with the
    same value and half... no: with its own weight in sum_e and ZERO in sum_o
    handled by the caller).  Each (M, R_north, K).
    """
    dtype = jnp.dtype(dtype)
    m, x, log_mu_m = _prep(m_vals, north_x, log_mu_all, dtype)
    sb = scale_bits_for(dtype)
    return _alm_from_delta_folded_impl(
        jnp.asarray(sum_e_re, dtype), jnp.asarray(sum_e_im, dtype),
        jnp.asarray(sum_o_re, dtype), jnp.asarray(sum_o_im, dtype), m, x,
        np.asarray(north_sin, np.float64), log_mu_m,
        l_max=l_max, scale_bits=sb, dtype_name=dtype.name)
