"""Unified transform plans: one entry point for every SHT execution path.

This is the dispatch seam the paper's "dichotomy" demands (§4-5): the
winning kernel differs between problem sizes *and between the direct and
inverse transforms*, so ``make_plan`` chooses an execution backend per
``(grid, l_max, K, dtype)`` signature and per direction, instead of callers
hand-wiring ``SHT`` / ``legendre_pallas`` / ``DistSHT`` themselves::

    import repro
    plan = repro.make_plan("gl", l_max=256, K=8, dtype="float32")
    maps = plan.alm2map(alm)       # inverse  (synthesis)
    alm2 = plan.map2alm(maps)      # direct   (analysis)
    print(plan.report())           # chosen kernels, predicted vs measured

Backends
--------
``jnp``
    The pure-jnp engine (`repro.core.sht.SHT`): float64 oracle, runs on any
    grid (including ragged HEALPix).  The only candidate when
    ``dtype="float64"`` -- the Pallas kernels compute in float32.
``pallas_vpu`` / ``pallas_mxu``
    The Pallas Legendre kernels (`repro.kernels`) for the recurrence stage,
    with the shared phase stage (`repro.core.phase`) for the FFTs --
    batched-uniform or ring-bucket per grid, so ragged HEALPix runs here
    too.  ``vpu`` is the broadcast-FMA variant (small K); ``mxu`` contracts
    P panels on the matrix unit (large K, the Monte-Carlo batch workload).
``dist``
    The two-stage distributed transform (`repro.core.dist_sht.DistSHT`,
    paper Algorithm 3) across every visible device, with bucket-aware
    ring-pair sharding on ragged grids.  Dense alm/maps in, dense out --
    plan packing/unpacking is handled internally.

Backends that are *not* eligible for a signature are reported with the
reason they were skipped (``describe()["skipped"]`` / the ``report()``
footer), so dispatch decisions stay debuggable.

Dispatch modes
--------------
``mode="model"``  rank backends with the analytic roofline cost model
                  (`repro.roofline.predict_sht_time`) -- free, deterministic.
``mode="auto"``   measure each candidate once per direction (one warm-up +
                  one timed call) and pick the fastest; the decision is
                  cached by plan signature (memory + optional disk), so the
                  autotune pass runs once per signature, ever.  The raw
                  corner timings additionally land in the persistent
                  per-hardware characterization DB (`repro.roofline.chardb`)
                  keyed by workload -- NOT by plan signature or mode -- so
                  even a decision-cache-cold rebuild re-measures zero
                  corners, and ``REPRO_CHARDB_SMOKE=1`` runs skip missing
                  corners entirely (cost-model fallback) instead of timing.
``mode=<backend>`` force one backend for both directions.

Pallas plans additionally dispatch a per-direction Legendre *layout*
(``plan.layouts``): the ``packed``/``plain`` grids of the staged pipeline,
plus ``fused`` -- the single-kernel Legendre+phase pipeline
(`repro.kernels.fused`), which keeps the intermediate ``delta_m`` on-chip
for every plan shape: spin 0 and 2, equator-folded, uniform and bucketed
(ragged HEALPix) grids.  The fused panel length (``lp_size``) is
chardb-autotuned per corner.  ``describe()["fusion"]`` reports
eligibility, the chosen ``lp_size``, and the fallback reason for the two
residual staged shapes (fold on a bucket phase stage; spin-2 at the
uniform Nyquist alias point).

Differentiability
-----------------
``Plan.alm2map`` and ``Plan.map2alm`` carry adjoint-based custom JVP/VJP
rules on every backend (spin 0 and 2, plain and packed layouts, ragged
bucket FFTs, shard_map dist): the synthesis VJP is the weighted analysis
and vice versa, so ``jax.grad`` never traces kernel internals.  See
``Plan.grad_ready``, ``describe()["differentiable"]`` and
docs/architecture.md ("Differentiation via adjoints").

Precompute caching
------------------
Grid geometry (Gauss-Legendre Newton iteration), ``pmm``/``pms`` recurrence
seed tables and autotune decisions are cached by plan signature through
`repro.core.cache` -- in memory always, and on disk under
``$REPRO_CACHE_DIR`` when ``cache="disk"``.  A second ``make_plan`` with an
identical signature returns the *same* plan object without recomputing
anything (asserted by tests/test_transform_plan.py).
"""

from __future__ import annotations

import os
import time
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as plancache
from repro.core import grids as gridlib
from repro.core import legendre
from repro.core.grids import RingGrid
from repro.core.sht import SHT, alm_mask, random_alm, random_alm_spin
from repro.roofline import analysis as roofline

__all__ = ["Plan", "make_plan", "available_backends", "backend_eligibility",
           "clear_plan_cache", "drop_plan"]

BACKENDS = ("jnp", "pallas_vpu", "pallas_mxu", "dist")

#: make_plan memoisation: signature key -> Plan.  This is the "second
#: make_plan is free" tier; the payload caches underneath make a cold
#: rebuild (new process, cache="disk") cheap too.
_PLANS: dict[str, "Plan"] = {}


def clear_plan_cache(*, disk: bool = False,
                     directory: Optional[str] = None) -> None:
    """Drop memoised plans AND the in-memory precompute tier (test hook).

    ``disk=True`` additionally removes the persistent tier under
    ``directory`` (default: ``$REPRO_CACHE_DIR`` / the cache default) --
    without it a clear left stale ``.npz``/``.json`` entries behind that a
    later ``cache="disk"`` plan would silently resurrect.
    """
    _PLANS.clear()
    plancache.clear_memory()
    if disk:
        plancache.clear_disk(directory)


def drop_plan(plan: "Plan") -> bool:
    """Remove one memoised plan so it can be garbage-collected.

    ``clear_plan_cache`` is all-or-nothing; bounded plan holders (the
    serving engine's LRU pool, `repro.serve.PlanPool`) evict a single
    signature through this.  The shared precompute payloads (geometry,
    seed tables) stay cached -- only the live Plan object (compiled
    executables, device seed arrays) is released.  Returns True when the
    plan was actually memoised.
    """
    return _PLANS.pop(plan._signature_key, None) is not None


def _pallas_ops():
    """Import the kernel layer lazily (keeps `import repro` light and lets
    non-Pallas builds still use the jnp/dist backends)."""
    from repro.kernels import ops as kops
    return kops


def backend_eligibility(grid: RingGrid, dtype: str,
                        n_devices: Optional[int] = None
                        ) -> dict[str, Optional[str]]:
    """Why-or-why-not per backend: ``{backend: None | skip_reason}``.

    float64 restricts to the jnp oracle (the kernels compute in float32);
    dist needs >= 2 devices.  Grid raggedness is NOT a restriction: the
    phase stage (`repro.core.phase`) serves every backend on every grid.
    """
    out: dict[str, Optional[str]] = {b: None for b in BACKENDS}
    if dtype != "float32":
        reason = (f"kernels compute in float32 (plan dtype {dtype!r}); "
                  "force mode='pallas_*' to accept the precision drop")
        out["pallas_vpu"] = out["pallas_mxu"] = reason
    else:
        try:
            _pallas_ops()
        except Exception as e:  # pallas not importable on this build
            reason = f"pallas unavailable: {type(e).__name__}: {e}"
            out["pallas_vpu"] = out["pallas_mxu"] = reason
    n_dev = jax.device_count() if n_devices is None else n_devices
    if n_dev < 2:
        out["dist"] = f"needs >= 2 devices (visible: {n_dev})"
    return out


def available_backends(grid: RingGrid, dtype: str,
                       n_devices: Optional[int] = None) -> list[str]:
    """Backends eligible for this signature (see `backend_eligibility`
    for the skip reasons of the rest)."""
    elig = backend_eligibility(grid, dtype, n_devices)
    return [b for b in BACKENDS if elig[b] is None]


def _complex_dtype(dtype: str):
    return jnp.complex128 if jnp.dtype(dtype) == jnp.float64 else jnp.complex64


class Plan:
    """An executable SHT plan: precompute + layout + kernel choice.

    Construct through :func:`make_plan` (which memoises by signature); the
    constructor itself does no autotuning and no device work.

    Attributes
    ----------
    grid, l_max, m_max, K, dtype, fold, spin : the plan signature.
    mode : dispatch mode this plan was built with.
    backends : ``{"synth": name, "anal": name}`` -- the chosen execution
        backend per direction (the paper's direct/inverse dichotomy made
        into a data structure).

    A ``spin=2`` plan transforms (E, B) alm pairs ``(2, M, L, K)`` to/from
    (Q, U) map pairs ``(2, R, n_phi, K)`` -- same K batch axis, same
    backends, twice the Legendre-panel work (lambda^{+/-} pair).
    """

    def __init__(self, grid: RingGrid, l_max: int, m_max: int, K: int,
                 dtype: str, *, mode: str, fold: bool, spin: int,
                 cache_kind: str, cache_dir: Optional[str],
                 n_shards: Optional[int], signature_key: str,
                 comm_chunks: Union[int, str] = "auto"):
        self.grid = grid
        self.l_max = int(l_max)
        self.m_max = int(m_max)
        self.K = int(K)
        self.dtype = str(dtype)
        self.mode = mode
        self.fold = bool(fold)
        self.spin = int(spin)
        self._cache_kind = cache_kind
        self._cache_dir = cache_dir
        self._n_shards = n_shards
        self._signature_key = signature_key
        self._sht = SHT(grid, l_max=self.l_max, m_max=self.m_max,
                        dtype=self.dtype, fold=self.fold,
                        phase_cache=cache_kind, phase_cache_dir=cache_dir)
        self._m_vals = np.arange(self.m_max + 1)
        self._seeds_cache: Optional[tuple] = None
        self._seeds_spin_cache: Optional[tuple] = None
        self._dists: dict = {}          # comm_chunks C -> DistSHT engine
        self._dist_splan = None
        self._comm_spec = comm_chunks   # "auto" or a forced chunk count
        self._compiled: dict = {}
        self.backends: dict = {}
        #: Legendre layout per direction (pallas backends only; None
        #: elsewhere): "packed" / "plain" staged grids, or "fused" -- the
        #: single-kernel Legendre+phase pipeline (kernels/fused.py).
        self.layouts: dict = {}
        #: Exchange chunk count per direction (dist backend only; None
        #: elsewhere): C > 1 runs the chunked pipelined all_to_all.
        self.comm_chunks: dict = {}
        self.candidates: list[str] = []
        self.skipped: dict = {}
        self.predicted_s: dict = {}
        self.measured_s: dict = {}
        self.cache_events: dict = {}

    @property
    def phase(self):
        """The plan's FFT/phase stage (`repro.core.phase.PhaseStage`):
        the uniform batched engine or the ring-bucket engine, shared by
        every backend of this plan."""
        return self._sht.phase

    # -- precompute (cached by signature) -----------------------------------

    def _seeds(self):
        """(pmm, pms, x32) float32 seed tables for the Pallas kernels.

        Fold plans seed northern rings only (half the table).  Built once
        per plan, persisted by signature when ``cache="disk"``.
        """
        if self._seeds_cache is not None:
            return self._seeds_cache
        g = self.grid
        nh = (g.n_rings + 1) // 2
        sin = g.sin_theta[:nh] if self.fold else g.sin_theta
        x = g.cos_theta[:nh] if self.fold else g.cos_theta

        def build():
            from repro.kernels import ref as kref
            lm = legendre.log_mu(self.m_max)
            pmm, pms = kref.prepare_seeds(self._m_vals, sin, lm)
            return {"pmm": np.asarray(pmm), "pms": np.asarray(pms)}

        key = plancache.signature_key(
            "seeds", sig=self._signature_key, fold=self.fold)
        payload = plancache.get_or_build(
            key, build, cache=self._cache_kind, directory=self._cache_dir)
        self.cache_events.setdefault("seeds", key)
        self._seeds_cache = (jnp.asarray(payload["pmm"]),
                             jnp.asarray(payload["pms"]),
                             jnp.asarray(x, jnp.float32))
        return self._seeds_cache

    def _seeds_spin(self):
        """Spin-2 float32 seed tables for the Pallas kernels: the stacked
        (m' = -2 | +2) lambda rows, persisted by signature like `_seeds`."""
        if self._seeds_spin_cache is not None:
            return self._seeds_spin_cache
        from repro.core import legendre as leg
        g = self.grid
        m2, mp2 = leg._spin_rows(self._m_vals)

        def build():
            from repro.kernels import ref as kref
            pmm, pms = kref.prepare_seeds_spin(
                m2, mp2, g.cos_theta, g.sin_theta, m_max=self.m_max)
            return {"pmm": np.asarray(pmm), "pms": np.asarray(pms)}

        key = plancache.signature_key("seeds_spin", sig=self._signature_key)
        payload = plancache.get_or_build(
            key, build, cache=self._cache_kind, directory=self._cache_dir)
        self.cache_events.setdefault("seeds_spin", key)
        self._seeds_spin_cache = (jnp.asarray(payload["pmm"]),
                                  jnp.asarray(payload["pms"]),
                                  jnp.asarray(g.cos_theta, jnp.float32),
                                  m2, mp2)
        return self._seeds_spin_cache

    def _dist_engine(self, comm_chunks: int = 1):
        """The distributed engine for one exchange chunk count (engines are
        cached per C; the dealing plan and mesh are shared)."""
        C = max(1, int(comm_chunks))
        if C not in self._dists:
            from repro.core.dist_sht import DistSHT
            from repro.core.plan import SHTPlan
            n = self._n_shards or jax.device_count()
            if self._dist_splan is None:
                self._dist_splan = (jax.make_mesh((n,), ("sht",)),
                                    SHTPlan(self.grid, self.l_max,
                                            self.m_max, n))
            mesh, splan = self._dist_splan
            stage1 = "pallas" if self.dtype == "float32" else "jnp"
            self._dists[C] = DistSHT(splan, mesh, ("sht",), dtype=self.dtype,
                                     fold=False, stage1=stage1,
                                     comm_chunks=C)
        return self._dists[C]

    # -- per-backend execution ------------------------------------------------

    def _apply_layout_env(self, backend: str, layout):
        """Honour ``$REPRO_LEGENDRE_LAYOUT=fused`` at the plan level.

        The staged wrappers reject the value (`ops.pick_layout`); here the
        override routes an eligible pallas direction onto the fused
        pipeline, and raises (naming the eligibility reason) instead of
        silently falling back when the plan cannot be fused -- the same
        silent-fallback bug class as the PR-7 packed-anal mistiming.
        """
        if backend not in ("pallas_vpu", "pallas_mxu") or layout == "fused":
            return layout
        if os.environ.get("REPRO_LEGENDRE_LAYOUT") != "fused":
            return layout
        ok, reason = self._fusion_eligibility()
        if not ok:
            raise ValueError(
                "$REPRO_LEGENDRE_LAYOUT=fused requested, but the fused "
                f"pipeline is ineligible for this plan: {reason}")
        return "fused"

    def _synth_fn(self, backend: str, layout: Optional[str] = None):
        """Synthesis callable alm -> maps for ``backend`` (jitted; compiled
        executables are cached on the plan).  ``layout`` overrides the
        plan's packed-vs-plain choice (autotune measures both); for the
        dist backend it carries the exchange chunk count C instead."""
        if layout is None:
            layout = self.layouts.get("synth")
        if backend == "dist" and layout is None:
            layout = self.comm_chunks.get("synth") or 1
        layout = self._apply_layout_env(backend, layout)
        key = ("synth", backend, layout)
        if key in self._compiled:
            return self._compiled[key]
        spin = self.spin != 0
        if backend == "jnp":
            fn = jax.jit(self._sht.alm2map_spin if spin
                         else self._sht.alm2map)
        elif backend in ("pallas_vpu", "pallas_mxu"):
            variant = backend.split("_")[1]
            if layout == "fused":
                ok, reason = self._fusion_eligibility()
                if not ok:
                    raise ValueError(f"fused layout unavailable: {reason}")
                fn = self._make_fused_synth(variant=variant)
            elif spin:
                fn = self._make_pallas_synth_spin(variant=variant,
                                                  layout=layout)
            else:
                fn = self._make_pallas_synth(variant=variant, layout=layout)
            fn = jax.jit(fn)
        elif backend == "dist":
            d = self._dist_engine(comm_chunks=int(layout or 1))
            splan = d.plan

            if spin:
                def fn(alm_eb):
                    packed = jnp.stack([splan.pack_alm(alm_eb[0]),
                                        splan.pack_alm(alm_eb[1])], axis=0)
                    mp = d.alm2map_spin(packed)        # (2, R_pad, nphi, K)
                    return jnp.stack([splan.scatter_map(mp[0]),
                                      splan.scatter_map(mp[1])], axis=0)
            else:
                def fn(alm):
                    maps_plan = d.alm2map(splan.pack_alm(alm))
                    return splan.scatter_map(maps_plan)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        self._compiled[key] = fn
        return fn

    def _anal_fn(self, backend: str, layout: Optional[str] = None):
        """Analysis callable maps -> alm for ``backend`` (``layout``: see
        :meth:`_synth_fn` -- chunk count C for the dist backend)."""
        if layout is None:
            layout = self.layouts.get("anal")
        if backend == "dist" and layout is None:
            layout = self.comm_chunks.get("anal") or 1
        layout = self._apply_layout_env(backend, layout)
        key = ("anal", backend, layout)
        if key in self._compiled:
            return self._compiled[key]
        spin = self.spin != 0
        if backend == "jnp":
            fn = jax.jit(self._sht.map2alm_spin if spin
                         else self._sht.map2alm)
        elif backend in ("pallas_vpu", "pallas_mxu"):
            variant = backend.split("_")[1]
            if layout == "fused":
                ok, reason = self._fusion_eligibility()
                if not ok:
                    raise ValueError(f"fused layout unavailable: {reason}")
                fn = self._make_fused_anal(variant=variant)
            elif spin:
                fn = self._make_pallas_anal_spin(variant=variant,
                                                 layout=layout)
            else:
                fn = self._make_pallas_anal(variant=variant, layout=layout)
            fn = jax.jit(fn)
        elif backend == "dist":
            d = self._dist_engine(comm_chunks=int(layout or 1))
            splan = d.plan

            if spin:
                def fn(maps_qu):
                    packed = jnp.stack([splan.gather_map(maps_qu[0]),
                                        splan.gather_map(maps_qu[1])], axis=0)
                    alm_p = d.map2alm_spin(packed)     # (2, Mp, L, K)
                    return jnp.stack([splan.unpack_alm(alm_p[0]),
                                      splan.unpack_alm(alm_p[1])], axis=0)
            else:
                def fn(maps):
                    alm_packed = d.map2alm(splan.gather_map(maps))
                    return splan.unpack_alm(alm_packed)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        self._compiled[key] = fn
        return fn

    def _make_pallas_synth(self, variant: str, layout=None):
        kops = _pallas_ops()
        K, nh = self.K, (self.grid.n_rings + 1) // 2
        ns = nh - 1 if self.grid.n_rings % 2 == 1 else nh
        cdt = _complex_dtype(self.dtype)
        pmm, pms, x32 = self._seeds()      # eager: built once, closed over

        def fn(alm):
            a32 = jnp.concatenate(
                [jnp.real(alm), jnp.imag(alm)], axis=-1).astype(jnp.float32)
            out = kops.synth(a32, self._m_vals, x32, pmm, pms,
                             l_max=self.l_max, fold=self.fold,
                             variant=variant, layout=layout)
            if self.fold:
                e, o = out[:, 0], out[:, 1]               # (M, nh, 2K)
                north = e + o
                south = (e - o)[:, :ns][:, ::-1]
                flat = jnp.concatenate([north, south], axis=1)
            else:
                flat = out[:, 0]                          # (M, R, 2K)
            delta = (flat[..., :K] + 1j * flat[..., K:]).astype(cdt)
            return self._sht.phase.synth(delta).astype(self.dtype)

        return fn

    def _make_pallas_anal(self, variant: str, layout=None):
        kops = _pallas_ops()
        K, R = self.K, self.grid.n_rings
        nh = (R + 1) // 2
        cdt = _complex_dtype(self.dtype)
        pmm, pms, x32 = self._seeds()      # eager: built once, closed over

        def fn(maps):
            dwc = self._sht.phase.anal(maps)              # (M, R, K) complex
            dw = jnp.concatenate(
                [jnp.real(dwc), jnp.imag(dwc)], axis=-1).astype(jnp.float32)
            if self.fold:
                n_part = dw[:, :nh]
                s_part = jnp.zeros_like(n_part)
                s_part = s_part.at[:, : R - nh].set(dw[:, nh:][:, ::-1])
                dwk = jnp.stack([n_part + s_part, n_part - s_part], axis=1)
            else:
                dwk = dw[:, None]                         # (M, 1, R, 2K)
            out = kops.anal(dwk, self._m_vals, x32, pmm, pms,
                            l_max=self.l_max, fold=self.fold, variant=variant,
                            layout=layout)
            alm = (out[..., :K] + 1j * out[..., K:]).astype(cdt)
            mask = jnp.asarray(alm_mask(self.l_max, self.m_max))[..., None]
            return jnp.where(mask, alm, 0.0)

        return fn

    def _make_pallas_synth_spin(self, variant: str, layout=None):
        """Spin-2 kernel synthesis: stacked lambda^{(m' = -+2)} rows through
        the same kernels, component mixing host-side, shared phase stage."""
        from repro.core import legendre as leg
        kops = _pallas_ops()
        K = self.K
        cdt = _complex_dtype(self.dtype)
        pmm, pms, x32, m2, mp2 = self._seeds_spin()

        def fn(alm_eb):
            e, b = alm_eb[0], alm_eb[1]
            a2_re, a2_im = leg.spin_pack_alm(
                jnp.real(e), jnp.imag(e), jnp.real(b), jnp.imag(b))
            a32 = jnp.concatenate([a2_re, a2_im], axis=-1).astype(jnp.float32)
            out = kops.synth(a32, m2, x32, pmm, pms, l_max=self.l_max,
                             fold=False, variant=variant, mp_vals=mp2,
                             layout=layout)
            flat = out[:, 0]                          # (2M, R, 2K)
            dq_re, dq_im, du_re, du_im = leg.spin_unpack_delta(
                flat[..., :K], flat[..., K:])
            delta = jnp.concatenate(
                [dq_re + 1j * dq_im, du_re + 1j * du_im],
                axis=-1).astype(cdt)                  # (M, R, 2K)
            s = self._sht.phase.synth(delta).astype(self.dtype)
            return jnp.stack([s[..., :K], s[..., K:]], axis=0)

        return fn

    def _make_pallas_anal_spin(self, variant: str, layout=None):
        from repro.core import legendre as leg
        kops = _pallas_ops()
        K = self.K
        cdt = _complex_dtype(self.dtype)
        pmm, pms, x32, m2, mp2 = self._seeds_spin()

        def fn(maps_qu):
            m2d = jnp.concatenate([maps_qu[0], maps_qu[1]], axis=-1)
            dwc = self._sht.phase.anal(m2d)           # (M, R, 2K) complex
            d2_re, d2_im = leg.spin_pack_delta(
                jnp.real(dwc[..., :K]), jnp.imag(dwc[..., :K]),
                jnp.real(dwc[..., K:]), jnp.imag(dwc[..., K:]))
            dw32 = jnp.concatenate([d2_re, d2_im],
                                   axis=-1).astype(jnp.float32)[:, None]
            out = kops.anal(dw32, m2, x32, pmm, pms, l_max=self.l_max,
                            fold=False, variant=variant, mp_vals=mp2,
                            layout=layout)
            e_re, e_im, b_re, b_im = leg.spin_unpack_alm(
                out[..., :K], out[..., K:])
            alm = jnp.stack([e_re + 1j * e_im, b_re + 1j * b_im],
                            axis=0).astype(cdt)
            mask = jnp.asarray(
                alm_mask(self.l_max, self.m_max, spin=2))[..., None]
            return jnp.where(mask[None], alm, 0.0)

        return fn

    # -- fused pipeline (layout "fused") --------------------------------------

    def _fusion_eligibility(self) -> tuple:
        """(eligible, reason) for the fused Legendre+phase pipeline.

        The fused kernels now cover spin 0 and 2, equator-folded, uniform
        and bucketed (ragged HEALPix) plans.  Two residual shapes stay
        staged: the equator fold combine is baked into the uniform-engine
        rotation tables (no folded bucket tables), and spin-2 at the
        uniform Nyquist alias point would need the real-part doubling --
        which is not complex-linear and so cannot commute with the
        lambda^{+/-} pair unpacking that follows the in-kernel rotation.
        """
        if self.fold and self.phase.kind != "uniform":
            return False, (f"equator fold on a {self.phase.kind!r} phase "
                           "stage is not fused (staged path)")
        if (self.spin != 0 and self.phase.kind == "uniform"
                and self.grid.max_n_phi == 2 * self.m_max):
            return False, ("spin-2 at the Nyquist alias point "
                           "(n_phi == 2*m_max) is not fused (staged path)")
        return True, None

    def _fused_lp_size(self) -> int:
        """The fused pipeline's panel length, chardb-autotuned per corner.

        Candidate block shapes come from `pack.fused_lp_candidates`; under
        ``mode="auto"`` each candidate is timed once per hardware through
        the characterization DB (a second plan build re-measures zero
        corners), otherwise (model mode, chardb smoke) the roofline model
        ranks them.  Memoized on the plan.
        """
        if getattr(self, "_fused_lp", None) is not None:
            return self._fused_lp
        from repro.kernels import pack as kpack
        from repro.roofline import chardb
        cands = kpack.fused_lp_candidates(self.l_max)
        if len(cands) == 1:
            self._fused_lp = int(cands[0])
            return self._fused_lp
        times: dict = {}
        if self.mode == "auto" and not chardb.smoke_mode():
            db = self._chardb()
            cdt = _complex_dtype(self.dtype)
            arg = jnp.zeros(self._alm_shape, cdt)
            for c in cands:

                def measure(c=c):
                    fn = jax.jit(self._make_fused_synth(
                        variant="vpu", lp_size=int(c)))
                    jax.block_until_ready(fn(arg))      # warm-up/compile
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn(arg))
                    return (time.perf_counter() - t0) * 1e6

                # base fields on the *staged* corner and override: the
                # fused fields would recurse into this very chooser.
                fields = self._corner_fields("pallas_vpu", "synth", "packed")
                fields["layout"] = "fused"
                fields["lp_size"] = int(c)
                try:
                    us, _ = db.get_or_measure(measure, **fields)
                except Exception:
                    us = None
                times[int(c)] = float("inf") if us is None else float(us)
        if not times or not np.isfinite(min(times.values())):
            g = self.grid
            hw = (roofline.HW_HOST if jax.default_backend() == "cpu"
                  else roofline.HW_V5E)
            times = {int(c): roofline.predict_sht_time(
                "pallas_vpu", layout="packed", pipeline="fused",
                lp_size=int(c), l_max=self.l_max, m_max=self.m_max,
                n_rings=g.n_rings, n_phi=g.max_n_phi, K=self.K,
                direction="synth", hw=hw,
                fft_lengths=self._sht.phase.fft_lengths, spin=self.spin)
                for c in cands}
        self._fused_lp = int(min(times, key=times.get))
        return self._fused_lp

    def _fused_layout(self, lp_size: Optional[int] = None):
        """The packed slot layout shared by both fused directions (pure
        numpy; one per panel length).  Spin-2 plans pack the stacked
        lambda^{+/-} row set (`legendre._spin_rows`)."""
        if getattr(self, "_fused_los", None) is None:
            self._fused_los = {}
        lp = int(lp_size) if lp_size else self._fused_lp_size()
        if lp not in self._fused_los:
            from repro.kernels import pack as kpack
            if self.spin:
                m2, mp2 = legendre._spin_rows(self._m_vals)
                self._fused_los[lp] = kpack.build_layout(
                    m2, self.l_max, lp_size=lp, mp_vals=mp2)
            else:
                self._fused_los[lp] = kpack.build_layout(
                    self._m_vals, self.l_max, lp_size=lp)
        return self._fused_los[lp]

    def _fused_parts(self, variant: str, bf16: bool, lp_size):
        """Shared fused-dispatch plumbing: seeds, layout, the phase-flavour
        keyword block, and the (synth_fn, anal_fn) kernel-chain pair for
        this plan's shape (scalar/spin x uniform/fold/bucket)."""
        from repro.kernels import fused as kfused
        g, ph = self.grid, self.phase
        lp = int(lp_size) if lp_size else self._fused_lp_size()
        lo = self._fused_layout(lp)
        if self.spin == 0:
            pmm, pms, x32 = self._seeds()
            m_vals, mp2 = self._m_vals, None
        else:
            pmm, pms, x32, m2, mp2 = self._seeds_spin()
            m_vals = m2
        kw = dict(l_max=self.l_max, variant=variant, bf16=bf16, lo=lo,
                  lp_size=lp, mp_vals=mp2)
        if ph.kind == "uniform":
            kw.update(n=ph.n, phi0=g.phi0,
                      fold_rings=(g.n_rings if self.fold else None))
            pair = (kfused.fused_synth, kfused.fused_anal)
        else:
            kw.update(layout=ph.layout, pos=ph._pos, neg=ph._neg,
                      n_phi=g.n_phi, phi0=g.phi0)
            pair = (kfused.fused_synth_bucket, kfused.fused_anal_bucket)
        return m_vals, x32, pmm, pms, kw, pair

    def _make_fused_synth(self, variant: str, bf16: bool = False,
                          lp_size: Optional[int] = None):
        from repro.core import legendre as leg
        K = self.K
        m_vals, x32, pmm, pms, kw, (fsynth, _) = \
            self._fused_parts(variant, bf16, lp_size)
        if self.phase.kind == "bucket":
            kw = dict(kw, out_width=self.grid.max_n_phi)

        if self.spin == 0:
            def fn(alm):
                a32 = jnp.concatenate(
                    [jnp.real(alm), jnp.imag(alm)],
                    axis=-1).astype(jnp.float32)
                maps = fsynth(a32, m_vals, x32, pmm, pms, **kw)
                return maps.astype(self.dtype)
        else:
            def fn(alm_eb):
                e, b = alm_eb[0], alm_eb[1]
                a2_re, a2_im = leg.spin_pack_alm(
                    jnp.real(e), jnp.imag(e), jnp.real(b), jnp.imag(b))
                a32 = jnp.concatenate([a2_re, a2_im],
                                      axis=-1).astype(jnp.float32)
                s = fsynth(a32, m_vals, x32, pmm, pms, **kw)
                s = s.astype(self.dtype)
                return jnp.stack([s[..., :K], s[..., K:]], axis=0)

        return fn

    def _make_fused_anal(self, variant: str, bf16: bool = False,
                         lp_size: Optional[int] = None):
        from repro.core import legendre as leg
        K = self.K
        cdt = _complex_dtype(self.dtype)
        m_vals, x32, pmm, pms, kw, (_, fanal) = \
            self._fused_parts(variant, bf16, lp_size)
        w = jnp.asarray(self.grid.weights)
        mask = jnp.asarray(
            alm_mask(self.l_max, self.m_max, spin=self.spin))[..., None]

        if self.spin == 0:
            def fn(maps):
                out = fanal(maps, w, m_vals, x32, pmm, pms, **kw)
                alm = (out[..., :K] + 1j * out[..., K:]).astype(cdt)
                return jnp.where(mask, alm, 0.0)
        else:
            def fn(maps_qu):
                m2d = jnp.concatenate([maps_qu[0], maps_qu[1]], axis=-1)
                out = fanal(m2d, w, m_vals, x32, pmm, pms, **kw)
                e_re, e_im, b_re, b_im = leg.spin_unpack_alm(
                    out[..., :K], out[..., K:])
                alm = jnp.stack([e_re + 1j * e_im, b_re + 1j * b_im],
                                axis=0).astype(cdt)
                return jnp.where(mask[None], alm, 0.0)

        return fn

    # -- dispatch -------------------------------------------------------------

    def _pallas_layouts(self) -> tuple:
        """Candidate Legendre layouts for the pallas backends."""
        lays = ("packed", "plain")
        if self._fusion_eligibility()[0]:
            lays = lays + ("fused",)
        return lays

    def _predict_all(self, hw=None) -> dict:
        """Cost-model prediction per candidate per direction (seconds).

        Pallas candidates are modelled per Legendre *layout* (packed vs
        plain grid); ``out[b][d]`` is the better of the two and
        ``out[b][f"{d}_layout"]`` names it.
        """
        g = self.grid
        if hw is None:
            hw = (roofline.HW_HOST if jax.default_backend() == "cpu"
                  else roofline.HW_V5E)
        n_dev = self._n_shards or jax.device_count()
        fl = self._sht.phase.fft_lengths        # per-bucket cost on ragged
        out = {}
        for b in self.candidates:
            out[b] = {}
            for d in ("synth", "anal"):
                kw = dict(l_max=self.l_max, m_max=self.m_max,
                          n_rings=g.n_rings, n_phi=g.max_n_phi, K=self.K,
                          direction=d, hw=hw,
                          n_devices=n_dev if b == "dist" else 1,
                          fft_lengths=fl, spin=self.spin)
                if b in ("pallas_vpu", "pallas_mxu"):
                    per = {lay: roofline.predict_sht_time(
                               b, layout="packed" if lay == "fused" else lay,
                               pipeline="fused" if lay == "fused"
                               else "staged", **kw)
                           for lay in self._pallas_layouts()}
                    lay = min(per, key=per.get)
                    out[b][d] = per[lay]
                    out[b][f"{d}_layout"] = lay
                elif b == "dist":
                    # overlapped pipeline model: pick the exchange chunk
                    # count C that minimizes the modelled time.
                    per = {c: roofline.predict_sht_time(
                               b, overlap=True, comm_chunks=c, **kw)
                           for c in self._dist_chunk_variants(d)}
                    c_best = min(per, key=per.get)
                    out[b][d] = per[c_best]
                    out[b][f"{d}_chunks"] = c_best
                else:
                    out[b][d] = roofline.predict_sht_time(b, **kw)
        return out

    def _dist_chunk_variants(self, direction: str) -> tuple:
        """Candidate exchange chunk counts for the dist backend: the
        monolithic baseline plus the overlap model's pick (or just the
        forced count when ``comm_chunks`` was given as an int)."""
        if isinstance(self._comm_spec, (int, np.integer)):
            return (max(1, int(self._comm_spec)),)
        g = self.grid
        n_dev = self._n_shards or jax.device_count()
        hw = (roofline.HW_HOST if jax.default_backend() == "cpu"
              else roofline.HW_V5E)
        c = roofline.predict_comm_chunks(
            l_max=self.l_max, m_max=self.m_max, n_rings=g.n_rings,
            n_phi=g.max_n_phi, K=self.K, direction=direction, hw=hw,
            n_devices=n_dev, fft_lengths=self._sht.phase.fft_lengths,
            spin=self.spin)
        return tuple(sorted({1, int(c)}))

    def _chardb(self):
        """The persistent per-hardware characterization DB this plan's
        corner timings live in (disk-backed iff the plan's cache is)."""
        from repro.roofline import chardb
        directory = None
        if self._cache_kind == "disk":
            directory = plancache.cache_dir(self._cache_dir)
        return chardb.get_db(directory)

    def _corner_fields(self, backend: str, direction: str, layout) -> dict:
        """Workload coordinates of one autotune corner.  Deliberately
        excludes the dispatch mode and the plan signature key: any plan
        exercising the same workload on the same hardware reuses the
        measurement.  For the dist backend the variant slot carries the
        exchange chunk count instead of a Legendre layout."""
        fields = dict(
            grid=self.grid.name, n_rings=self.grid.n_rings,
            n_phi=self.grid.max_n_phi, l_max=self.l_max, m_max=self.m_max,
            K=self.K, dtype=self.dtype, spin=self.spin, fold=self.fold,
            backend=backend, direction=direction, layout=layout or "-",
            n_devices=((self._n_shards or jax.device_count())
                       if backend == "dist" else 1))
        # block-shape coordinate: fused corners are only comparable at one
        # panel length (staged kernels are pinned to 128)
        fields["lp_size"] = (self._fused_lp_size() if layout == "fused"
                             else 128)
        if backend == "dist":
            fields["layout"] = "-"
            fields["comm_chunks"] = max(1, int(layout or 1))
        return fields

    def _measure_all(self) -> dict:
        """Corner timings per candidate per direction, through the chardb:
        already-characterized corners are reused without running anything;
        missing/stale ones get one warm-up + one timed call (or are
        skipped entirely under ``REPRO_CHARDB_SMOKE=1``)."""
        db = self._chardb()
        cdt = _complex_dtype(self.dtype)
        if self.spin == 0:
            alm = random_alm(jax.random.PRNGKey(0), self.l_max, self.m_max,
                             K=self.K).astype(cdt)
            maps = jnp.zeros((self.grid.n_rings, self.grid.max_n_phi,
                              self.K), jnp.dtype(self.dtype))
        else:
            alm = random_alm_spin(jax.random.PRNGKey(0), self.l_max,
                                  self.m_max, K=self.K).astype(cdt)
            maps = jnp.zeros((2, self.grid.n_rings, self.grid.max_n_phi,
                              self.K), jnp.dtype(self.dtype))
        out: dict = {}
        for b in self.candidates:
            out[b] = {}
            for direction, fn_of, arg in (("synth", self._synth_fn, alm),
                                          ("anal", self._anal_fn, maps)):
                if b in ("pallas_vpu", "pallas_mxu"):
                    layouts = self._pallas_layouts()
                elif b == "dist":
                    layouts = self._dist_chunk_variants(direction)
                else:
                    layouts = (None,)
                best, best_lay, errs = float("inf"), None, {}
                for lay in layouts:

                    def measure(b=b, lay=lay, fn_of=fn_of, arg=arg):
                        fn = fn_of(b, lay) if lay is not None else fn_of(b)
                        jax.block_until_ready(fn(arg))      # warm-up/compile
                        t0 = time.perf_counter()
                        jax.block_until_ready(fn(arg))
                        return (time.perf_counter() - t0) * 1e6

                    try:
                        us, status = db.get_or_measure(
                            measure, **self._corner_fields(b, direction, lay))
                        t = float("inf") if us is None else us * 1e-6
                        if status == "skipped":
                            out[b][f"{direction}_skipped"] = True
                    except Exception as e:  # unusable here: rank last
                        t = float("inf")
                        errs[lay] = f"{type(e).__name__}: {e}"
                        if lay is not None:
                            out[b][f"{direction}_{lay}_error"] = errs[lay]
                    if lay is not None:
                        out[b][f"{direction}_{lay}"] = t
                    if t < best:
                        best, best_lay = t, lay
                out[b][direction] = best
                if not np.isfinite(best):   # every layout failed: backend
                    out[b][f"{direction}_error"] = \
                        "; ".join(errs.values())            # unusable
                if best_lay is not None:
                    slot = "chunks" if b == "dist" else "layout"
                    out[b][f"{direction}_{slot}"] = best_lay
        return out

    def _fill_layouts(self, source: dict) -> None:
        """Set ``self.layouts`` per direction from a per-candidate table
        (``{backend: {"<dir>_layout": ...}}``); model predictions fill any
        gap, non-pallas backends get None."""
        self.layouts = {}
        for d in ("synth", "anal"):
            b = self.backends.get(d)
            if b not in ("pallas_vpu", "pallas_mxu"):
                self.layouts[d] = None
                continue
            lay = source.get(b, {}).get(f"{d}_layout") \
                or self.predicted_s.get(b, {}).get(f"{d}_layout")
            self.layouts[d] = lay or "packed"

    def _fill_comm_chunks(self, source: dict) -> None:
        """Set ``self.comm_chunks`` per direction: the forced count when
        ``comm_chunks`` was an int, else the measured winner from ``source``
        (``{"dist": {"<dir>_chunks": C}}``) with the overlap model's pick
        filling any gap.  Non-dist directions get None."""
        self.comm_chunks = {}
        for d in ("synth", "anal"):
            if self.backends.get(d) != "dist":
                self.comm_chunks[d] = None
                continue
            if isinstance(self._comm_spec, (int, np.integer)):
                self.comm_chunks[d] = max(1, int(self._comm_spec))
                continue
            c = source.get("dist", {}).get(f"{d}_chunks")
            if c is None:
                c = self.predicted_s.get("dist", {}).get(f"{d}_chunks")
            self.comm_chunks[d] = max(1, int(c or 1))

    def _choose_backends(self) -> None:
        """Fill ``self.backends``/``self.layouts`` according to ``mode``."""
        self.predicted_s = self._predict_all()
        if self.mode in BACKENDS:                   # forced backend
            self.backends = {"synth": self.mode, "anal": self.mode}
            self._fill_layouts(self.predicted_s)
            self._fill_comm_chunks(self.predicted_s)
            return
        if self.mode == "model":
            self.backends = {
                d: min(self.candidates, key=lambda b: self.predicted_s[b][d])
                for d in ("synth", "anal")}
            self._fill_layouts(self.predicted_s)
            self._fill_comm_chunks(self.predicted_s)
            return
        assert self.mode == "auto", self.mode
        dkey = plancache.signature_key("decision", sig=self._signature_key)
        cached = plancache.load_decision(dkey, cache=self._cache_kind,
                                         directory=self._cache_dir)
        if cached is not None and all(
                cached.get(d) in self.candidates for d in ("synth", "anal")):
            self.backends = {d: cached[d] for d in ("synth", "anal")}
            self.measured_s = cached.get("measured", {})
            self._fill_layouts(self.measured_s)
            self._fill_comm_chunks(self.measured_s)
            cached_lay = cached.get("layouts")
            if cached_lay:
                self.layouts.update({d: cached_lay.get(d)
                                     for d in ("synth", "anal")
                                     if d in cached_lay})
            cached_cc = cached.get("comm_chunks")
            if cached_cc:
                self.comm_chunks.update(
                    {d: cached_cc.get(d) for d in ("synth", "anal")
                     if d in cached_cc})
            self.cache_events["decision"] = "hit"
            return
        self.measured_s = self._measure_all()
        self.backends, fell_back = {}, False
        for d in ("synth", "anal"):
            finite = [b for b in self.candidates
                      if np.isfinite(self.measured_s[b][d])]
            if finite:
                self.backends[d] = min(
                    finite, key=lambda b: self.measured_s[b][d])
            else:
                # every corner skipped (chardb smoke mode) or unusable:
                # rank by the cost model instead of timing anything.
                self.backends[d] = min(
                    self.candidates, key=lambda b: self.predicted_s[b][d])
                fell_back = True
        self._fill_layouts(self.measured_s)
        self._fill_comm_chunks(self.measured_s)
        if fell_back:
            # an un-measured decision must not shadow a later real autotune
            self.cache_events["decision"] = "model-fallback"
            return
        self.cache_events["decision"] = "autotuned"
        plancache.save_decision(
            dkey, {**self.backends, "measured": self.measured_s,
                   "layouts": dict(self.layouts),
                   "comm_chunks": dict(self.comm_chunks)},
            cache=self._cache_kind, directory=self._cache_dir)

    # -- public API -----------------------------------------------------------

    @property
    def _alm_shape(self) -> tuple:
        base = (self.m_max + 1, self.l_max + 1, self.K)
        return base if self.spin == 0 else (2,) + base

    @property
    def _maps_shape(self) -> tuple:
        base = (self.grid.n_rings, self.grid.max_n_phi, self.K)
        return base if self.spin == 0 else (2,) + base

    def alm2map(self, alm) -> jnp.ndarray:
        """Inverse SHT (synthesis) through the chosen backend.

        spin 0: alm ``(m_max+1, l_max+1, K)`` -> maps ``(R, n_phi, K)``;
        spin 2: (E, B) alm ``(2, M, L, K)`` -> (Q, U) maps
        ``(2, R, n_phi, K)``.
        """
        assert alm.shape == self._alm_shape, \
            (alm.shape, f"plan was built for {self._alm_shape}")
        return self._synth_fn(self.backends["synth"])(jnp.asarray(alm))

    def map2alm(self, maps, iters: int = 0) -> jnp.ndarray:
        """Direct SHT (analysis): maps -> alm through the chosen backend.

        ``iters > 0`` applies Jacobi residual refinement (one extra
        synthesis + analysis per pass) -- worthwhile on approximate-
        quadrature grids (HEALPix family), a no-op improvement on exact
        Gauss-Legendre grids.  Spin-2 plans take/return the stacked
        (Q, U) / (E, B) pair shapes (see :meth:`alm2map`).
        """
        assert maps.shape == self._maps_shape, \
            (maps.shape, f"plan was built for {self._maps_shape}")
        maps = jnp.asarray(maps)
        alm = self._anal_fn(self.backends["anal"])(maps)
        for _ in range(iters):
            resid = maps - self.alm2map(alm)
            alm = alm + self._anal_fn(self.backends["anal"])(resid)
        return alm

    def warmup(self, directions=("synth", "anal")) -> "Plan":
        """Compile and execute each direction once on zero inputs.

        The serving pool's warm-up hook: after ``warmup()`` the first real
        request through this plan pays no trace/compile latency.  Blocks
        until the device work is done; safe to call from a background
        thread (the executables land in ``self._compiled``).
        """
        cdt = _complex_dtype(self.dtype)
        for d in directions:
            if d == "synth":
                out = self._synth_fn(self.backends["synth"])(
                    jnp.zeros(self._alm_shape, cdt))
            else:
                out = self._anal_fn(self.backends["anal"])(
                    jnp.zeros(self._maps_shape, jnp.dtype(self.dtype)))
            jax.block_until_ready(out)
        return self

    @property
    def grad_ready(self) -> dict:
        """Per-direction differentiability of the chosen execution paths.

        ``{"synth": bool, "anal": bool}`` -- True when that direction's
        backend carries the adjoint-based custom JVP/VJP rules, i.e.
        ``jax.grad``/``jax.jvp`` flow through :meth:`alm2map` /
        :meth:`map2alm` without tracing kernel internals.  Every built-in
        backend (jnp, pallas_vpu, pallas_mxu, dist) qualifies; the rules
        are first-order (no reverse-over-reverse).
        """
        return {d: self.backends.get(d) in BACKENDS
                for d in ("synth", "anal")}

    def memory_footprint(self) -> dict:
        """Estimated working-set bytes per buffer class."""
        g = self.grid
        M, L1, K = self.m_max + 1, self.l_max + 1, self.K
        ncomp = 1 if self.spin == 0 else 2
        csize = 16 if self.dtype == "float64" else 8
        rsize = 8 if self.dtype == "float64" else 4
        out = {
            "alm_bytes": M * L1 * K * csize * ncomp,
            "maps_bytes": g.n_rings * g.max_n_phi * K * rsize * ncomp,
            "delta_bytes": M * g.n_rings * K * csize * ncomp,
            "seed_bytes": (2 * M * g.n_rings * 4 * ncomp
                           if any(b.startswith("pallas")
                                  for b in self.backends.values()) else 0),
        }
        out["total_bytes"] = sum(out.values())
        return out

    def describe(self) -> dict:
        """Structured report: signature, chosen kernels, predicted vs
        measured seconds per candidate, memory footprint, cache counters.

        Benchmarks and docs consume this dict; ``report()`` pretty-prints
        it.
        """
        w = roofline.sht_work(self.l_max, self.m_max, self.grid.n_rings,
                              self.grid.max_n_phi, self.K,
                              fft_lengths=self._sht.phase.fft_lengths,
                              spin=self.spin)
        from repro.roofline import chardb
        layouts = dict(self.layouts)
        fusion_ok, fusion_reason = self._fusion_eligibility()
        return {
            "signature": {
                "grid": self.grid.name, "n_rings": self.grid.n_rings,
                "n_phi": self.grid.max_n_phi, "l_max": self.l_max,
                "m_max": self.m_max, "K": self.K, "dtype": self.dtype,
                "fold": self.fold, "spin": self.spin,
                "key": self._signature_key,
            },
            "mode": self.mode,
            "backends": dict(self.backends),
            "differentiable": {**self.grad_ready,
                               "rule": "adjoint (custom_jvp + linear_call)",
                               "higher_order": False},
            "layouts": layouts,
            "fusion": {
                "eligible": fusion_ok, "reason": fusion_reason,
                # the eligibility reason again, under the name the env
                # override error uses -- None when nothing was skipped
                "skipped": fusion_reason,
                "lp_size": getattr(self, "_fused_lp", None),
                "active": {d: layouts.get(d) == "fused"
                           for d in ("synth", "anal")},
                "pipelines": {d: ("fused" if layouts.get(d) == "fused"
                                  else "staged")
                              for d in ("synth", "anal")},
            },
            "comm": {
                "spec": self._comm_spec,
                "chunks": dict(self.comm_chunks),
                "pipelined": {d: (self.comm_chunks.get(d) or 1) > 1
                              for d in ("synth", "anal")},
            },
            "candidates": list(self.candidates),
            "skipped": dict(self.skipped),
            # grouped view of the packing decision; panels comes from the
            # sht_work() call above (same legendre_panel_counts dict)
            "legendre": {"layouts": layouts, "panels": w["panels"]},
            "phase": self._sht.phase.describe(),
            "predicted_s": self.predicted_s,
            "measured_s": self.measured_s,
            "work": w,
            "memory": self.memory_footprint(),
            "cache": {"events": dict(self.cache_events),
                      **plancache.stats().to_dict(),
                      "chardb": chardb.stats()},
        }

    def report(self) -> str:
        """Human-readable ``describe()`` (chosen kernel, predicted vs
        measured time per direction, memory footprint, and *why* any
        backend was skipped)."""
        d = self.describe()
        s = d["signature"]
        lines = [
            f"Plan {s['grid']} l_max={s['l_max']} m_max={s['m_max']} "
            f"K={s['K']} {s['dtype']} fold={s['fold']} "
            f"spin={s['spin']} mode={d['mode']}",
            f"  rings={s['n_rings']} n_phi={s['n_phi']} "
            f"n_lm={d['work']['n_lm']} "
            f"flops/dir~{d['work']['total_flops']:.3g}",
            f"  memory ~{d['memory']['total_bytes'] / 1e6:.2f} MB",
        ]
        ph = d["phase"]
        if ph["kind"] != "uniform":
            lines.append(
                f"  phase: {ph['kind']} x{ph['n_buckets']} buckets "
                f"{ph['bucket_lengths']} (+{ph['padded_frac'] * 100:.1f}% "
                f"fft padding)")
        pc = d["legendre"]["panels"]
        lines.append(
            f"  legendre: packed {pc['packed']} vs plain "
            f"{pc['plain_launched']} grid steps "
            f"({pc['launched_ratio']:.2f}x fewer, occupancy "
            f"{pc['packed_occupancy']:.2f})")
        for direction in ("synth", "anal"):
            chosen = d["backends"].get(direction, "?")
            pred = d["predicted_s"].get(chosen, {}).get(direction)
            meas = d["measured_s"].get(chosen, {}).get(direction) \
                if d["measured_s"] else None
            bits = [f"  {direction:5s} -> {chosen}"]
            lay = d["layouts"].get(direction)
            if lay:
                bits[0] += f"[{lay}]"
            cc = d["comm"]["chunks"].get(direction)
            if chosen == "dist" and cc:
                bits[0] += f"[C={cc}]"
            if pred is not None:
                bits.append(f"predicted {pred * 1e6:.1f} us")
            if meas is not None and np.isfinite(meas):
                bits.append(f"measured {meas * 1e6:.1f} us")
            lines.append("  ".join(bits))
        for b, reason in d["skipped"].items():
            lines.append(f"  skipped {b}: {reason}")
        ev = d["cache"]["events"]
        lines.append(f"  cache: {ev if ev else 'cold'} "
                     f"(mem_hits={d['cache']['memory_hits']} "
                     f"disk_hits={d['cache']['disk_hits']} "
                     f"builds={d['cache']['builds']})")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Plan(grid={self.grid.name!r}, l_max={self.l_max}, "
                f"K={self.K}, dtype={self.dtype!r}, "
                f"backends={self.backends})")


# ---------------------------------------------------------------------------
# make_plan
# ---------------------------------------------------------------------------


def _resolve_grid(grid, l_max, nside, cache_kind, cache_dir):
    """Grid spec -> (RingGrid, signature fields).  String specs go through
    the geometry cache (the GL Newton iteration is the expensive part)."""
    if isinstance(grid, RingGrid):
        return grid, {"grid_cos": grid.cos_theta, "grid_nphi": grid.n_phi,
                      "grid_w": grid.weights, "grid_name": grid.name}
    kind = str(grid)
    # Key each family only on the fields its geometry depends on: GL/ECP on
    # l_max, healpix on nside.  Keying on the irrelevant one would fragment
    # the cache (and the plan memoisation) for identical grids.
    by_lmax = kind in ("gl", "ecp")
    spec = {"grid_kind": kind, "grid_l_max": l_max if by_lmax else None,
            "grid_nside": None if by_lmax else nside}
    key = plancache.signature_key("geometry", **spec)

    def build():
        g = gridlib.make_grid(kind, l_max=l_max, nside=nside)
        return {"cos_theta": g.cos_theta, "sin_theta": g.sin_theta,
                "weights": g.weights, "n_phi": g.n_phi, "phi0": g.phi0,
                "uniform": np.array(g.uniform),
                "nside": np.array(-1 if g.nside is None else g.nside)}

    p = plancache.get_or_build(key, build, cache=cache_kind,
                               directory=cache_dir)
    g = RingGrid(name=kind, cos_theta=p["cos_theta"],
                 sin_theta=p["sin_theta"], weights=p["weights"],
                 n_phi=p["n_phi"], phi0=p["phi0"], uniform=bool(p["uniform"]),
                 nside=None if int(p["nside"]) < 0 else int(p["nside"]))
    return g, spec


def make_plan(grid: Union[str, RingGrid] = "gl", l_max: Optional[int] = None,
              *, nside: Optional[int] = None, m_max: Optional[int] = None,
              K: int = 1, dtype: str = "float64", mode: str = "auto",
              fold: bool = False, spin: int = 0, cache: str = "auto",
              cache_dir: Optional[str] = None,
              n_shards: Optional[int] = None,
              comm_chunks: Union[int, str] = "auto") -> Plan:
    """Build (or fetch) the transform plan for a problem signature.

    Parameters
    ----------
    grid : ``"gl"`` | ``"ecp"`` | ``"healpix_ring"`` | ``"healpix"`` | RingGrid
        Grid spec (cached geometry) or a prebuilt grid instance.
    l_max, m_max : band limits (``m_max`` defaults to ``l_max``).
    nside : HEALPix resolution (required for healpix-family string specs).
    K : number of simultaneous maps the plan is specialised for (the
        batched Monte-Carlo workload; drives the VPU/MXU choice).
    dtype : ``"float64"`` (oracle precision, jnp backend only) or
        ``"float32"`` (performance; enables the Pallas kernels).
    mode : ``"auto"`` (autotune, cached), ``"model"`` (cost model), or an
        explicit backend name (``"jnp"``, ``"pallas_vpu"``, ``"pallas_mxu"``,
        ``"dist"``).
    fold : use the equator-fold optimisation (symmetric grids only).
    spin : 0 (scalar) or 2 (polarisation).  A spin-2 plan transforms
        (E, B) alm pairs ``(2, M, L, K)`` <-> (Q, U) map pairs
        ``(2, R, n_phi, K)`` on every backend; costs ~2x the Legendre
        panels (the lambda^{+/-} pair) at the same FFT structure.
    cache : ``"auto"`` (memory; disk iff $REPRO_CACHE_DIR is set),
        ``"memory"``, ``"disk"``, or ``"off"``.
    cache_dir : override the on-disk cache location.
    n_shards : device count for the ``dist`` backend (default: all).
    comm_chunks : exchange chunk count for the ``dist`` backend.
        ``"auto"`` (default) picks C from the overlapped roofline model
        (measured against the monolithic C=1 baseline under
        ``mode="auto"``); an int forces that chunk count.  ``C > 1``
        splits the Delta all_to_all into C chunks pipelined against the
        adjacent chunks' compute (bit-identical results).

    Returns the memoised :class:`Plan`: calling ``make_plan`` twice with an
    identical signature returns the same object and reuses every cached
    precompute payload.
    """
    if isinstance(grid, str) and grid in ("gl", "ecp") and l_max is None:
        raise ValueError(f"make_plan({grid!r}, ...) requires l_max")
    if mode not in ("auto", "model") + BACKENDS:
        raise ValueError(f"unknown mode {mode!r}: expected 'auto', 'model' "
                         f"or a backend name {BACKENDS}")
    if spin not in (0, 2):
        raise ValueError(f"unsupported spin {spin!r}: expected 0 or 2")
    if comm_chunks != "auto":
        if not isinstance(comm_chunks, (int, np.integer)) or comm_chunks < 1:
            raise ValueError(f"comm_chunks must be 'auto' or an int >= 1, "
                             f"got {comm_chunks!r}")
        comm_chunks = int(comm_chunks)
    if spin and fold:
        raise ValueError("fold is not supported for spin transforms")
    if cache == "auto":
        cache_kind = "disk" if (cache_dir or os.environ.get("REPRO_CACHE_DIR")) \
            else "memory"
    else:
        cache_kind = cache
    assert cache_kind in ("off", "memory", "disk"), cache_kind

    g, grid_sig = _resolve_grid(grid, l_max, nside, cache_kind, cache_dir)
    if l_max is None:
        # derive a safe band limit from the grid (HEALPix rule of thumb)
        l_max = 2 * g.nside if g.nside else g.n_rings - 1
    m_max = l_max if m_max is None else m_max
    assert m_max <= l_max, (m_max, l_max)
    assert dtype in ("float64", "float32"), dtype
    if spin:
        assert l_max >= spin, (l_max, spin)
    if fold:
        assert g.equator_symmetric, "fold requires a symmetric grid"

    # cache policy is part of the memoisation key: a plan built with
    # cache="off" must not shadow a later request for disk persistence.
    sig_key = plancache.signature_key(
        "plan", l_max=l_max, m_max=m_max, K=K, dtype=dtype, mode=mode,
        fold=fold, spin=spin, n_shards=n_shards, cache_kind=cache_kind,
        cache_dir=cache_dir, comm_chunks=comm_chunks, **grid_sig)
    if sig_key in _PLANS:
        plancache.stats().memory_hits += 1
        return _PLANS[sig_key]

    plan = Plan(g, l_max, m_max, K, dtype, mode=mode, fold=fold, spin=spin,
                cache_kind=cache_kind, cache_dir=cache_dir,
                n_shards=n_shards, signature_key=sig_key,
                comm_chunks=comm_chunks)
    elig = backend_eligibility(g, dtype, n_shards)
    cand = [b for b in BACKENDS if elig[b] is None]
    if mode in BACKENDS and mode not in cand:
        # explicit request overrides the eligibility policy (e.g. pallas
        # under float64: runs in f32 internally) -- but not impossibility.
        if mode.startswith("pallas") and dtype != "float32":
            cand = cand + [mode]
            elig[mode] = None
        else:
            raise ValueError(
                f"backend {mode!r} unavailable for this signature: "
                f"{elig[mode]} (candidates: {cand})")
    plan.candidates = cand
    plan.skipped = {b: r for b, r in elig.items() if r is not None}
    plan._choose_backends()
    _PLANS[sig_key] = plan
    return plan
