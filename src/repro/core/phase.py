"""Pluggable FFT/phase stage of the spherical harmonic transforms.

Every SHT backend shares the same two-stage structure (paper Alg. 1-2):
a Legendre stage producing/consuming per-ring Fourier coefficients
Delta_m(r), and a *phase stage* turning them into ring samples (synthesis,
eq. 11) or back (analysis, eq. 14).  This module is the single home of
that phase stage, with two device-resident engines:

``uniform``
    One batched real FFT over all rings (rfft/irfft of the shared n_phi),
    with alias folding of m into the half-spectrum.  The production path
    for Gauss-Legendre and ring-uniform HEALPix grids.

``bucket``
    The ragged-grid (true HEALPix) engine: rings are grouped by rounded-up
    FFT length into buckets (`repro.core.grids.ring_buckets`, libsharp
    style) and each bucket runs ONE batched complex FFT.  Exactness under
    padding comes from the divisor embedding: ring r with n = n_phi(r)
    samples lives in a bucket of length B with n | B, so

      synthesis  -- its alias-folded length-n spectrum is scattered at
                    stride B/n into the length-B spectrum; the length-B
                    inverse FFT then *periodically repeats* the ring's n
                    samples, and a mask keeps the first n;
      analysis   -- its n samples are zero-padded to B; the length-B
                    forward FFT evaluated at bins (m mod n) * (B/n) equals
                    the length-n DFT at bins (m mod n) exactly.

    The scatter/gather index maps are pure geometry, precomputed at plan
    time (`bucket_bin_maps`) and served from the signature-keyed cache.

Both engines are expressed as trace-friendly functions taking the ring
geometry (phi0, weights, n_phi) and the index maps as *arguments*, so the
same code serves three callers:

  * the serial engine (`core.sht.SHT`) via the `UniformPhase`/`BucketPhase`
    classes built by :func:`make_phase` (geometry closed over as numpy
    constants -- free under jit);
  * the Pallas backends (`core.transform`), which reuse the serial plan's
    phase object after their kernel Legendre stage;
  * the distributed transform (`core.dist_sht`), which passes *sharded*
    geometry/index-map operands inside shard_map (every shard runs the
    same bucket structure by construction -- see SHTPlan.local_fft_layout).

Conventions match `core.sht`: delta rows follow ``m_vals`` (entries with
m < 0 are padding and contribute nothing), maps are ``(R, n_phi_max, K)``
real with samples beyond a ring's n_phi zeroed, and analysis output has
the quadrature weights already applied.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import cache as plancache
from repro.core.autodiff import linear_pair
from repro.core.grids import BucketLayout, RingGrid

__all__ = [
    "uniform_synth", "uniform_anal", "bucket_synth", "bucket_anal",
    "bucket_bin_maps", "uniform_bin_maps", "uniform_rotation_tables",
    "bucket_rotation_tables", "phase_factors",
    "PhaseStage", "UniformPhase", "BucketPhase", "make_phase",
]


def _complex_dtype(dtype):
    return jnp.complex128 if jnp.dtype(dtype) == jnp.float64 else jnp.complex64


def phase_factors(m_vals, phi0, sign: float, dtype) -> jnp.ndarray:
    """e^{sign * i * m * phi0(r)} as (M, R) complex; rows with m < 0 are 0.

    ``phi0`` may be a numpy constant (serial path) or a traced shard-local
    operand (dist path).
    """
    m = np.asarray(m_vals)
    msafe = np.maximum(m, 0).astype(np.float64)
    ph = jnp.exp(sign * 1j * msafe[:, None] * jnp.asarray(phi0)[None, :])
    ph = ph.astype(_complex_dtype(dtype))
    if np.any(m < 0):
        ph = jnp.where(jnp.asarray(m >= 0)[:, None], ph, 0.0)
    return ph


# ---------------------------------------------------------------------------
# uniform engine: one batched real FFT over all rings
# ---------------------------------------------------------------------------
#
# Differentiation: both engines carry adjoint-based custom JVP/VJP rules
# (repro.core.autodiff.linear_pair).  The forward maps are real-linear in
# delta/maps; their exact transposes are the opposite-direction phase stage
# with the quadrature weights stripped and a per-m factor
#
#     fac_m = 1 (m == 0) | 2 (m > 0)
#
# compensating the implicit negative-m (conjugate) half of the spectrum:
# the synthesis of each m > 0 row contributes both e^{+im phi} and its
# conjugate, so <synth(delta), t> picks up each positive-m row twice.
# The transposes below are verified against dot-product identities and
# native AD in tests/test_adjoint.py.


def _fac_rows(m_vals, dtype):
    """(M, 1, 1) adjoint compensation factors: 1 for m == 0, else 2
    (padding rows m < 0 are irrelevant -- their phase factors are zero).
    Pure numpy: these are closed over by transpose rules that run in a
    *different* trace than the forward call, so they must not be device
    arrays created under the forward trace (leaked-tracer hazard)."""
    m = np.asarray(m_vals)
    return np.where(m == 0, 1.0, 2.0).astype(
        jnp.dtype(dtype))[:, None, None]


def uniform_bin_maps(m_vals, n):
    """Alias-fold bin maps for the uniform engine, all numpy.

    Returns ``(bins, hi, nyq)``: the rfft half-spectrum bin each m row
    lands in, whether it wraps onto the conjugate half (``hi``: scatter /
    gather the conjugate), and whether it sits on the Nyquist bin (real
    part doubles on synthesis).  Shared by the host engine below and by
    the fused Legendre+phase kernels (kernels/fused.py), which bake the
    same maps into their per-slot rotation tables."""
    m = np.asarray(m_vals)
    b = np.maximum(m, 0) % n
    hi = b > n // 2                                # conjugate wrap
    bins = np.where(hi, n - b, b)
    nyq = 2 * b == n                               # Nyquist: real part doubles
    return bins, hi, nyq


def uniform_rotation_tables(m_vals, phi0, n, direction):
    """Real 2x2 per-(row, ring) phase-rotation tables, (M, 4, R) f64 numpy.

    Encodes the uniform engine's e^{+-i m phi0(r)} rotation *and* the
    conjugate-wrap / Nyquist handling of :func:`uniform_bin_maps` as a real
    linear map so the fused kernels can apply the phase stage in-kernel:

        h_re = t0 * d_re + t1 * d_im
        h_im = t2 * d_re + t3 * d_im

    ``direction`` is ``"synth"`` (Delta -> half-spectrum row, sign +1,
    conjugate scattered for hi rows, doubled real part on Nyquist) or
    ``"anal"`` (gathered half-spectrum row -> Delta, sign -1, conjugate
    gathered for hi rows; no Nyquist term -- exactly the host engine's
    math).  Rows with m < 0 are zeroed like :func:`phase_factors`."""
    m = np.asarray(m_vals)
    bins, hi, nyq = uniform_bin_maps(m, n)
    msafe = np.maximum(m, 0).astype(np.float64)
    ang = msafe[:, None] * np.asarray(phi0, np.float64)[None, :]
    c, s = np.cos(ang), np.sin(ang)
    hi_c = hi[:, None]
    if direction == "synth":
        ta, tb = c, -s
        tc = np.where(hi_c, -s, s)
        td = np.where(hi_c, -c, c)
        nyq_c = nyq[:, None]
        ta = np.where(nyq_c, 2.0 * c, ta)
        tb = np.where(nyq_c, -2.0 * s, tb)
        tc = np.where(nyq_c, 0.0, tc)
        td = np.where(nyq_c, 0.0, td)
    elif direction == "anal":
        ta = c
        tb = np.where(hi_c, -s, s)
        tc = -s
        td = np.where(hi_c, -c, c)
    else:
        raise ValueError(f"unknown direction {direction!r}")
    t = np.stack([ta, tb, tc, td], axis=1)         # (M, 4, R)
    return np.where((m >= 0)[:, None, None], t, 0.0)


def bucket_rotation_tables(m_vals, phi0, direction):
    """Real 2x2 per-(row, ring) phase tables for the bucket engine,
    (M, 4, R) f64 numpy.

    Unlike :func:`uniform_rotation_tables` there is no conjugate-wrap or
    Nyquist folding here -- the bucket engine's alias fold is a pure index
    map (:func:`bucket_bin_maps`), applied by the host-side scatter/gather
    around the fused kernels.  The tables only encode e^{+-i m phi0(r)}:

        synth  h = e^{+i m phi0} d   ->  (c, -s, s, c)
        anal   d = e^{-i m phi0} f   ->  (c, s, -s, c)

    Rows with m < 0 are zeroed like :func:`phase_factors`."""
    m = np.asarray(m_vals)
    msafe = np.maximum(m, 0).astype(np.float64)
    ang = msafe[:, None] * np.asarray(phi0, np.float64)[None, :]
    c, s = np.cos(ang), np.sin(ang)
    if direction == "synth":
        t = np.stack([c, -s, s, c], axis=1)
    elif direction == "anal":
        t = np.stack([c, s, -s, c], axis=1)
    else:
        raise ValueError(f"unknown direction {direction!r}")
    return np.where((m >= 0)[:, None, None], t, 0.0)


def _uniform_synth_body(d_re, d_im, phi0, scale_rows, m, n, dtype):
    cdt = _complex_dtype(dtype)
    delta = (d_re + 1j * d_im).astype(cdt)
    dp = delta * phase_factors(m, phi0, +1.0, dtype)[..., None]
    bins, hi, nyq = uniform_bin_maps(m, n)
    half = n // 2 + 1
    vals = jnp.where(jnp.asarray(hi)[:, None, None], jnp.conj(dp), dp)
    vals = jnp.where(jnp.asarray(nyq)[:, None, None],
                     2.0 * jnp.real(vals).astype(cdt), vals)
    H = jnp.zeros((half,) + dp.shape[1:], cdt)
    H = H.at[jnp.asarray(bins)].add(vals)
    H = jnp.moveaxis(H, 0, 1)                      # (R, half, K)
    s = (jnp.fft.irfft(H, n=n, axis=1) * n).astype(dtype)
    if scale_rows is not None:
        s = s * scale_rows[:, None, None]
    return s


def _uniform_anal_core(maps, phi0, m, n, dtype):
    """Weight-free analysis core: maps (R, n, K) -> (A_re, A_im), each
    (M, R, K): the e^{-im phi} projection without the quadrature weights."""
    cdt = _complex_dtype(dtype)
    F = jnp.fft.rfft(maps.astype(dtype), axis=1)   # (R, n//2+1, K)
    bins, hi, _ = uniform_bin_maps(m, n)
    Fm = F[:, jnp.asarray(bins), :]                # (R, M, K)
    Fm = jnp.where(jnp.asarray(hi)[None, :, None], jnp.conj(Fm), Fm)
    Fm = jnp.moveaxis(Fm, 1, 0).astype(cdt)        # (M, R, K)
    A = Fm * phase_factors(m, phi0, -1.0, dtype)[..., None]
    return jnp.real(A).astype(dtype), jnp.imag(A).astype(dtype)


def uniform_synth(delta, m_vals, n: int, phi0, *, dtype,
                  scale_rows=None) -> jnp.ndarray:
    """Synthesis phase stage on a uniform grid.

    delta: (M, R, K) complex Delta^A rows following ``m_vals`` ->
    maps (R, n, K) real.  Alias-folds every m into the rfft half-spectrum
    (bins past n/2 wrap to the conjugate half; the Nyquist bin doubles its
    real part).  ``scale_rows`` optionally scales rings on the way out
    (the dist path's dummy-ring mask).

    Differentiable both ways: the VJP is ``fac_m`` times the weight-free
    analysis of the map cotangent.
    """
    dt = jnp.dtype(dtype)
    m = np.asarray(m_vals)
    cdt = _complex_dtype(dtype)
    delta = jnp.asarray(delta).astype(cdt)
    fac = _fac_rows(m, dt)

    def fwd(res, ops):
        phi0_, sr = res
        dr, di = ops
        return _uniform_synth_body(dr, di, phi0_, sr, m, n, dtype)

    def bwd(res, t):
        phi0_, sr = res
        if sr is not None:
            t = t * sr[:, None, None]
        a_re, a_im = _uniform_anal_core(t, phi0_, m, n, dtype)
        return (fac * a_re).astype(dt), (fac * a_im).astype(dt)

    return linear_pair(fwd, bwd, (phi0, scale_rows),
                       (jnp.real(delta), jnp.imag(delta)))


def uniform_anal(maps, m_vals, n: int, phi0, weights, *, dtype) -> jnp.ndarray:
    """Analysis phase stage on a uniform grid.

    maps: (R, n, K) real -> weighted Delta^S (M, R, K) complex, rows
    following ``m_vals`` (quadrature ``weights`` applied per ring).

    Differentiable both ways: the VJP is the synthesis of the
    ``fac_m``-normalised, weight-scaled Delta cotangent.
    """
    dt = jnp.dtype(dtype)
    cdt = _complex_dtype(dtype)
    m = np.asarray(m_vals)
    maps = jnp.asarray(maps).astype(dt)
    fac = _fac_rows(m, dt)

    def fwd(res, mp):
        (phi0_,) = res
        return _uniform_anal_core(mp, phi0_, m, n, dtype)

    def bwd(res, cts):
        (phi0_,) = res
        g_re, g_im = cts
        return _uniform_synth_body(g_re / fac, g_im / fac, phi0_, None,
                                   m, n, dtype).astype(dt)

    a_re, a_im = linear_pair(fwd, bwd, (phi0,), maps)
    w = jnp.asarray(weights).astype(dt)
    return (a_re + 1j * a_im).astype(cdt) * w[None, :, None]


# ---------------------------------------------------------------------------
# bucket engine: one batched complex FFT per rounded-up ring-length group
# ---------------------------------------------------------------------------


def bucket_bin_maps(m_vals, n_phi, bucket_len):
    """Alias-fold scatter/gather bin maps for the bucket engine.

    Returns ``(pos, neg)`` int32 arrays of shape (M, R): ring r's +m
    contribution lands in bin ``(m mod n_r) * (B_r / n_r)`` of its bucket's
    length-B_r spectrum, the conjugate -m contribution in
    ``((-m) mod n_r) * (B_r / n_r)``.  Pure numpy -- precomputed at plan
    time and cached by plan signature.
    """
    m = np.maximum(np.asarray(m_vals), 0)[:, None]
    n = np.asarray(n_phi)[None, :]
    stride = np.asarray(bucket_len)[None, :] // n  # exact by bucket invariant
    fold = m % n
    pos = fold * stride
    neg = ((n - fold) % n) * stride
    return pos.astype(np.int32), neg.astype(np.int32)


def _bucket_synth_body(d_re, d_im, pos, neg, n_phi, phi0, scale_rows, m,
                       layout, out_width, dtype):
    """Bucket synthesis body.  ``neg`` may be None: the conjugate-half bin
    map is then derived per bucket as ``(B - pos) % B`` (the adjoint path
    of the analysis direction only carries ``pos``)."""
    cdt = _complex_dtype(dtype)
    delta = (d_re + 1j * d_im).astype(cdt)
    dp = delta * phase_factors(m, phi0, +1.0, dtype)[..., None]
    M, R, K = dp.shape
    # m = 0 must not receive its own conjugate (it would double-count);
    # padding rows (m < 0) are already zeroed by the phase factor.
    neg_ok = jnp.asarray(m > 0)[:, None, None]
    nn = jnp.asarray(n_phi)
    out = jnp.zeros((R, out_width, K), dtype)
    for B, sl in zip(layout.lengths, layout.slots):
        sl = np.asarray(sl)
        Rb = sl.shape[0]
        if Rb == 0:
            continue
        dp_b = dp[:, sl, :]                         # (M, Rb, K)
        pos_b = pos[:, sl]
        neg_b = neg[:, sl] if neg is not None else (B - pos_b) % B
        row = np.arange(Rb, dtype=np.int32)[None, :] * B
        S = jnp.zeros((Rb * B, K), cdt)
        S = S.at[jnp.reshape(row + pos_b, (-1,))].add(
            dp_b.reshape(M * Rb, K))
        S = S.at[jnp.reshape(row + neg_b, (-1,))].add(
            jnp.where(neg_ok, jnp.conj(dp_b), 0.0).reshape(M * Rb, K))
        s = jnp.fft.ifft(S.reshape(Rb, B, K), axis=1) * B
        # the length-B inverse FFT repeats each ring's n samples B/n times;
        # keep the first period, zero the padding
        keep = (jnp.arange(B)[None, :] < nn[sl][:, None]).astype(dtype)
        samp = jnp.real(s).astype(dtype) * keep[:, :, None]
        if B < out_width:
            samp = jnp.pad(samp, ((0, 0), (0, out_width - B), (0, 0)))
        out = out.at[jnp.asarray(sl)].set(samp)
    if scale_rows is not None:
        out = out * scale_rows[:, None, None]
    return out


def _bucket_anal_core(maps, pos, n_phi, phi0, m, layout, dtype):
    """Weight-free bucket analysis core: maps (R, W, K) -> (A_re, A_im)."""
    cdt = _complex_dtype(dtype)
    M = m.shape[0]
    R, W, K = maps.shape
    maps = maps.astype(dtype)
    nn = jnp.asarray(n_phi)
    delta = jnp.zeros((M, R, K), cdt)
    for B, sl in zip(layout.lengths, layout.slots):
        sl = np.asarray(sl)
        if sl.shape[0] == 0:
            continue
        x = maps[sl]                                # (Rb, W, K)
        x = x[:, :B, :] if B <= W else \
            jnp.pad(x, ((0, 0), (0, B - W), (0, 0)))
        keep = (jnp.arange(B)[None, :] < nn[sl][:, None]).astype(dtype)
        F = jnp.fft.fft(x * keep[:, :, None], axis=1)          # (Rb, B, K)
        idx = jnp.moveaxis(jnp.asarray(pos[:, sl]), 0, 1)      # (Rb, M)
        Fm = jnp.take_along_axis(F, idx[..., None], axis=1)    # (Rb, M, K)
        delta = delta.at[:, jnp.asarray(sl), :].set(
            jnp.moveaxis(Fm, 1, 0).astype(cdt))
    A = delta * phase_factors(m, phi0, -1.0, dtype)[..., None]
    return jnp.real(A).astype(dtype), jnp.imag(A).astype(dtype)


def bucket_synth(delta, layout: BucketLayout, pos, neg, n_phi, phi0, m_vals,
                 *, out_width: int, dtype, scale_rows=None) -> jnp.ndarray:
    """Synthesis phase stage on a ragged grid, one batched FFT per bucket.

    delta: (M, R, K) complex -> maps (R, out_width, K) real, padded with
    zeros beyond each ring's n_phi.  ``pos``/``neg`` are the (M, R) bin
    maps from :func:`bucket_bin_maps`; ``n_phi``/``phi0`` may be traced
    shard-local operands (dist) or numpy constants (serial).

    Differentiable both ways: the VJP is ``fac_m`` times the weight-free
    bucket analysis of the map cotangent (exact under the divisor
    embedding: the folded length-B gather equals the length-n DFT).
    """
    dt = jnp.dtype(dtype)
    cdt = _complex_dtype(dtype)
    m = np.asarray(m_vals)
    delta = jnp.asarray(delta).astype(cdt)
    fac = _fac_rows(m, dt)

    def fwd(res, ops):
        pos_, neg_, nn_, phi0_, sr = res
        dr, di = ops
        return _bucket_synth_body(dr, di, pos_, neg_, nn_, phi0_, sr, m,
                                  layout, out_width, dtype)

    def bwd(res, t):
        pos_, neg_, nn_, phi0_, sr = res
        if sr is not None:
            t = t * sr[:, None, None]
        a_re, a_im = _bucket_anal_core(t, pos_, nn_, phi0_, m, layout, dtype)
        return (fac * a_re).astype(dt), (fac * a_im).astype(dt)

    return linear_pair(fwd, bwd, (pos, neg, n_phi, phi0, scale_rows),
                       (jnp.real(delta), jnp.imag(delta)))


def bucket_anal(maps, layout: BucketLayout, pos, n_phi, phi0, weights,
                m_vals, *, dtype) -> jnp.ndarray:
    """Analysis phase stage on a ragged grid, one batched FFT per bucket.

    maps: (R, W, K) real (padded) -> weighted Delta^S (M, R, K) complex.
    Samples at or beyond each ring's n_phi are masked before the FFT, so
    garbage in the padding region cannot alias into the result.

    Differentiable both ways: the VJP is the bucket synthesis of the
    ``fac_m``-normalised, weight-scaled Delta cotangent (the conjugate-half
    bin map is rebuilt as ``(B - pos) % B`` per bucket).
    """
    dt = jnp.dtype(dtype)
    cdt = _complex_dtype(dtype)
    m = np.asarray(m_vals)
    maps = jnp.asarray(maps).astype(dt)
    W = maps.shape[1]
    fac = _fac_rows(m, dt)

    def fwd(res, mp):
        pos_, nn_, phi0_ = res
        return _bucket_anal_core(mp, pos_, nn_, phi0_, m, layout, dtype)

    def bwd(res, cts):
        pos_, nn_, phi0_ = res
        g_re, g_im = cts
        return _bucket_synth_body(g_re / fac, g_im / fac, pos_, None, nn_,
                                  phi0_, None, m, layout, W,
                                  dtype).astype(dt)

    a_re, a_im = linear_pair(fwd, bwd, (pos, n_phi, phi0), maps)
    w = jnp.asarray(weights).astype(dt)
    return (a_re + 1j * a_im).astype(cdt) * w[None, :, None]


# ---------------------------------------------------------------------------
# grid-bound phase-stage objects (the serial/Pallas integration point)
# ---------------------------------------------------------------------------


class PhaseStage:
    """Common surface of the grid-bound phase engines.

    ``synth``: (M, R, K) complex Delta -> (R, n_phi_max, K) real maps.
    ``anal``:  (R, n_phi_max, K) real maps -> (M, R, K) weighted Delta.
    """

    kind: str = "?"

    def synth(self, delta) -> jnp.ndarray:
        raise NotImplementedError

    def anal(self, maps) -> jnp.ndarray:
        raise NotImplementedError

    @property
    def fft_lengths(self) -> np.ndarray:
        """(R,) per-ring batched FFT length (the cost model's input)."""
        raise NotImplementedError

    def describe(self) -> dict:
        raise NotImplementedError


class UniformPhase(PhaseStage):
    """Batched-rfft phase stage for uniform grids."""

    kind = "uniform"

    def __init__(self, grid: RingGrid, m_vals, dtype):
        assert grid.uniform
        self.n = grid.max_n_phi
        self._phi0 = grid.phi0
        self._weights = grid.weights
        self._m_vals = np.asarray(m_vals)
        self._dtype = dtype
        self._n_rings = grid.n_rings
        assert self.n >= 2 * int(self._m_vals.max()), \
            "uniform FFT stage requires n_phi >= 2*m_max"

    def synth(self, delta) -> jnp.ndarray:
        return uniform_synth(delta, self._m_vals, self.n, self._phi0,
                             dtype=self._dtype)

    def anal(self, maps) -> jnp.ndarray:
        return uniform_anal(maps, self._m_vals, self.n, self._phi0,
                            self._weights, dtype=self._dtype)

    @property
    def fft_lengths(self) -> np.ndarray:
        return np.full(self._n_rings, self.n, dtype=np.int64)

    def describe(self) -> dict:
        return {"kind": self.kind, "n_buckets": 1,
                "bucket_lengths": [self.n], "padded_frac": 0.0}


class BucketPhase(PhaseStage):
    """Ring-bucket phase stage for ragged grids (index maps from the cache)."""

    kind = "bucket"

    def __init__(self, grid: RingGrid, m_vals, dtype, payload: dict):
        self._grid = grid
        self._m_vals = np.asarray(m_vals)
        self._dtype = dtype
        nb = int(payload["n_buckets"])
        self.layout = BucketLayout(
            tuple(int(v) for v in payload["lengths"]),
            tuple(np.asarray(payload[f"slots_{k}"]) for k in range(nb)))
        self._pos = np.asarray(payload["pos"])
        self._neg = np.asarray(payload["neg"])

    def synth(self, delta) -> jnp.ndarray:
        return bucket_synth(delta, self.layout, self._pos, self._neg,
                            self._grid.n_phi, self._grid.phi0, self._m_vals,
                            out_width=self._grid.max_n_phi,
                            dtype=self._dtype)

    def anal(self, maps) -> jnp.ndarray:
        return bucket_anal(maps, self.layout, self._pos, self._grid.n_phi,
                           self._grid.phi0, self._grid.weights, self._m_vals,
                           dtype=self._dtype)

    @property
    def fft_lengths(self) -> np.ndarray:
        return self.layout.fft_lengths

    def describe(self) -> dict:
        return {"kind": self.kind, "n_buckets": self.layout.n_buckets,
                "bucket_lengths": list(self.layout.lengths),
                "padded_frac": self.layout.padded_frac(self._grid.n_phi)}


def make_phase(grid: RingGrid, m_max: int, dtype, *, cache: str = "memory",
               cache_dir: Optional[str] = None,
               max_stretch: Optional[float] = None) -> PhaseStage:
    """Build the phase stage for a grid: uniform engine for uniform grids,
    ring-bucket engine (index maps through the signature-keyed precompute
    cache) for ragged ones."""
    m_vals = np.arange(m_max + 1)
    if grid.uniform:
        return UniformPhase(grid, m_vals, dtype)

    def build() -> dict:
        layout = BucketLayout.from_buckets(grid.fft_buckets(max_stretch))
        pos, neg = bucket_bin_maps(m_vals, grid.n_phi, layout.fft_lengths)
        payload = {
            "n_buckets": np.array(layout.n_buckets),
            "lengths": np.asarray(layout.lengths, dtype=np.int64),
            "pos": pos, "neg": neg,
        }
        for k, sl in enumerate(layout.slots):
            payload[f"slots_{k}"] = np.asarray(sl)
        return payload

    key = plancache.signature_key(
        "phase", grid_nphi=grid.n_phi, grid_phi0=grid.phi0, m_max=m_max,
        max_stretch=max_stretch)
    payload = plancache.get_or_build(key, build, cache=cache,
                                     directory=cache_dir)
    return BucketPhase(grid, m_vals, dtype, payload)
