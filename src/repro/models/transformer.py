"""Decoder-LM assembly: config-driven blocks, scan-over-layers, caches.

Layers are grouped by the architecture's block pattern and stacked so the
whole depth is ONE `lax.scan` per group (small HLO => tractable 512-way
SPMD compiles; standard MaxText-style remat point).

Block kinds:
  dense  -- attention + dense MLP          (qwen*, danube, internvl)
  moe    -- attention + expert-parallel MoE (kimi, deepseek)
  mlstm / slstm -- xLSTM blocks
  rglru  -- RG-LRU mixer + MLP; local -- windowed attention + MLP (gemma)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

__all__ = ["make_rules", "build_groups", "init_lm", "lm_specs", "Runtime",
           "forward_train", "init_caches", "caches_specs", "decode_step",
           "prefill"]


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Everything the model functions need besides params & inputs."""
    cfg: object
    mesh: Optional[Mesh]
    rules: L.ShardingRules

    @property
    def cdt(self):
        return jnp.dtype(self.cfg.compute_dtype)

    @property
    def pdt(self):
        return jnp.dtype(self.cfg.param_dtype)

    def axis_size(self, name):
        if self.mesh is None or name not in self.mesh.shape:
            return 1
        return self.mesh.shape[name]


def make_rules(cfg, mesh: Optional[Mesh]) -> L.ShardingRules:
    axes = set(mesh.axis_names) if mesh is not None else set()
    batch = tuple(a for a in ("pod", "data") if a in axes) or None
    model = "model" if "model" in axes else None
    if cfg.tp_profile == "dp":
        # pure data parallelism: the model axis joins the batch axes and
        # every parameter is replicated (perf iteration for small archs
        # whose TP shards are too thin; see EXPERIMENTS.md §Perf)
        batch = tuple(a for a in ("pod", "data", "model") if a in axes) or None
        return L.ShardingRules(batch=batch, heads=None, kv_heads=None,
                               d_ff=None, vocab=None, d_model=None,
                               experts=None, seq=None, layers=None)
    msize = mesh.shape["model"] if (mesh and model) else 1
    small = cfg.tp_profile == "small"
    heads = None if small else model
    kv = model if (not small and model and cfg.n_kv_heads % msize == 0
                   and cfg.n_kv_heads >= msize) else None
    d_ff = model if (cfg.d_ff or cfg.lru_width) and not (
        cfg.family == "ssm") else None
    if small and cfg.family == "ssm":
        d_ff = None
    vocab = model if (model and cfg.vocab % msize == 0) else None
    return L.ShardingRules(
        batch=batch, heads=heads, kv_heads=kv, d_ff=d_ff,
        vocab=vocab, d_model=None, experts=model, seq=None, layers=None)


def build_groups(cfg):
    """[(pattern tuple, n_repeat), ...] covering all layers."""
    if cfg.block_pattern:
        pat = tuple(cfg.block_pattern)
        n_full = cfg.n_layers // len(pat)
        rem = cfg.n_layers - n_full * len(pat)
        groups = [(pat, n_full)] if n_full else []
        if rem:
            groups.append((pat[:rem], 1))
        return groups
    if cfg.n_experts:
        g = []
        if cfg.first_dense_layers:
            g.append((("dense",), cfg.first_dense_layers))
        g.append((("moe",), cfg.n_layers - cfg.first_dense_layers))
        return g
    return [(("dense",), cfg.n_layers)]


# -- per-kind block init/spec/apply -------------------------------------------


def block_init(key, kind, cfg, dtype):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "moe", "local"):
        p = {"ln1": L.init_norm(cfg.d_model, kind=cfg.norm),
             "attn": A.init_attention(ks[0], cfg, dtype),
             "ln2": L.init_norm(cfg.d_model, kind=cfg.norm)}
        if kind == "moe":
            p["moe"] = M.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, act=cfg.act,
                                  dtype=dtype)
        return p
    if kind == "mlstm":
        return {"ln": L.init_norm(cfg.d_model, kind=cfg.norm),
                "cell": S.init_mlstm(ks[0], cfg.d_model, cfg.n_heads,
                                     pf=cfg.mlstm_pf, dtype=dtype)}
    if kind == "slstm":
        return {"ln": L.init_norm(cfg.d_model, kind=cfg.norm),
                "cell": S.init_slstm(ks[0], cfg.d_model, cfg.n_heads,
                                     dtype=dtype)}
    if kind == "rglru":
        return {"ln1": L.init_norm(cfg.d_model, kind=cfg.norm),
                "cell": S.init_rglru(ks[0], cfg.d_model,
                                     cfg.lru_width or cfg.d_model,
                                     dtype=dtype),
                "ln2": L.init_norm(cfg.d_model, kind=cfg.norm),
                "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, act=cfg.act,
                                  dtype=dtype)}
    raise ValueError(kind)


def block_spec(kind, cfg, rules, *, layer_stacked=True):
    kw = dict(layer_stacked=layer_stacked)
    nk = dict(kind=cfg.norm, layer_stacked=layer_stacked)
    if kind in ("dense", "moe", "local"):
        s = {"ln1": L.spec_norm(rules, **nk),
             "attn": A.spec_attention(cfg, rules, **kw),
             "ln2": L.spec_norm(rules, **nk)}
        if kind == "moe":
            s["moe"] = M.spec_moe(cfg, rules, **kw)
        else:
            s["mlp"] = L.spec_mlp(rules, act=cfg.act, **kw)
        return s
    if kind in ("mlstm", "slstm"):
        cell = S.spec_mlstm(rules, **kw) if kind == "mlstm" \
            else S.spec_slstm(rules, **kw)
        return {"ln": L.spec_norm(rules, **nk), "cell": cell}
    if kind == "rglru":
        return {"ln1": L.spec_norm(rules, **nk),
                "cell": S.spec_rglru(rules, **kw),
                "ln2": L.spec_norm(rules, **nk),
                "mlp": L.spec_mlp(rules, act=cfg.act, **kw)}
    raise ValueError(kind)


def _moe_block(p, x, rt: Runtime):
    """Expert-parallel MoE sub-layer.  Chooses the all-to-all path when the
    per-row token count splits over the model axis, else the replicated
    (decode-friendly) path."""
    cfg = rt.cfg
    B, Sq, d = x.shape
    ms = rt.axis_size("model")
    batch_axes = rt.rules.batch or ()
    rows = int(np.prod([rt.axis_size(a) for a in batch_axes])) or 1
    cdt = rt.cdt

    if rt.mesh is None or ms == 1:
        # single-shard fallback (smoke tests)
        y, aux = M.moe_apply_local(p, x.reshape(-1, d), cfg, cdt=cdt)
        return y.reshape(B, Sq, d), aux

    if cfg.moe_impl == "a2a" and Sq % ms == 0 and Sq // ms > 0:
        in_spec = P(rt.rules.batch, "model", None)
        def body(p_loc, x_loc):
            b, s, _ = x_loc.shape
            y, aux = M.moe_apply(p_loc, x_loc.reshape(b * s, d), cfg,
                                 axis_name="model", cdt=cdt)
            return y.reshape(b, s, d), aux
    else:
        in_spec = P(rt.rules.batch, None, None)
        def body(p_loc, x_loc):
            b, s, _ = x_loc.shape
            y, aux = M.moe_apply_replicated(p_loc, x_loc.reshape(b * s, d),
                                            cfg, axis_name="model", cdt=cdt)
            return y.reshape(b, s, d), aux

    pspec = M.spec_moe(cfg, rt.rules, layer_stacked=False)
    routed_keys = ("router", "gate", "up", "down")
    p_routed = {k: p[k] for k in routed_keys}
    pspec_routed = {k: pspec[k] for k in routed_keys}
    # Pin the boundary shardings explicitly: without these GSPMD resolves
    # the (replicated-seq -> seq-sharded) transition at the shard_map edge
    # with a last-resort FULL replication of the global activation
    # (hundreds of GB of all-gather per layer in the 7168-wide models).
    # Measured in EXPERIMENTS.md §Perf (deepseek hillclimb, iteration 1).
    if rt.mesh is not None:
        x = jax.lax.with_sharding_constraint(
            x, jax.NamedSharding(rt.mesh, in_spec))
    y, aux = compat.shard_map(
        body, mesh=rt.mesh, in_specs=(pspec_routed, in_spec),
        out_specs=(in_spec, P()))(p_routed, x)
    if rt.mesh is not None:
        # ...and bring the output BACK to batch-only sharding: letting the
        # seq-sharding leak into the next layer's attention makes GSPMD
        # replicate q/k/v globally there (the 103 GB/layer all-gathers).
        y = jax.lax.with_sharding_constraint(
            y, jax.NamedSharding(rt.mesh, P(rt.rules.batch, None, None)))
    if cfg.n_shared_experts:
        y = y + L.swiglu(p["shared"], x.astype(cdt), cdt)
    return y, aux


def block_apply(kind, p, x, positions, rt: Runtime):
    """Training/prefill forward for one block.  Returns (x', aux_loss)."""
    cfg = rt.cfg
    cdt = rt.cdt
    aux = jnp.float32(0.0)
    if kind in ("dense", "moe", "local"):
        win = cfg.local_window if kind == "local" else cfg.sliding_window
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, _ = A.attention_train(p["attn"], h, positions, cfg, window=win,
                                 cdt=cdt)
        x = x + y
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            y, aux = _moe_block(p["moe"], h, rt)
        else:
            y = L.apply_mlp(p["mlp"], h, cfg.act, cdt)
        return x + y, aux
    if kind == "mlstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        return x + S.mlstm_train(p["cell"], h, cfg.n_heads, cdt=cdt,
                                 unroll=cfg.inner_unroll), aux
    if kind == "slstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        return x + S.slstm_train(p["cell"], h, cdt=cdt), aux
    if kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        x = x + S.rglru_train(p["cell"], h, cdt=cdt)
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], h, cfg.act, cdt), aux
    raise ValueError(kind)


# -- whole-model init / specs ----------------------------------------------------


def init_lm(key, cfg, dtype=None):
    dtype = jnp.dtype(cfg.param_dtype) if dtype is None else dtype
    groups = build_groups(cfg)
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    params = {"embed": L.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype),
              "final_norm": L.init_norm(cfg.d_model, kind=cfg.norm)}
    gparams = []
    kg = jax.random.split(k_blocks, len(groups))
    for (pat, n_rep), gk in zip(groups, kg):
        keys = jax.random.split(gk, n_rep * len(pat)).reshape(
            n_rep, len(pat), 2)
        stacked = []
        for j, kind in enumerate(pat):
            init_one = lambda k, kind=kind: block_init(k, kind, cfg, dtype)
            stacked.append(jax.vmap(init_one)(keys[:, j]))
        gparams.append(stacked)
    params["groups"] = gparams
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(k_head, cfg.d_model, cfg.vocab,
                                         dtype=dtype)
    return params


def lm_specs(cfg, rules):
    groups = build_groups(cfg)
    specs = {"embed": L.spec_embedding(rules),
             "final_norm": L.spec_norm(rules, kind=cfg.norm)}
    gspecs = []
    for pat, _ in groups:
        gspecs.append([block_spec(kind, cfg, rules) for kind in pat])
    specs["groups"] = gspecs
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.spec_dense(rules, "d_model", "vocab")
    return specs


# -- training forward --------------------------------------------------------------


def _run_groups(params, x, positions, rt: Runtime):
    cfg = rt.cfg
    aux_total = jnp.float32(0.0)
    for (pat, n_rep), stacked in zip(build_groups(cfg), params["groups"]):
        def body(carry, layer_params):
            x, aux = carry
            for kind, p in zip(pat, layer_params):
                x, a = block_apply(kind, p, x, positions, rt)
                aux = aux + a
            return (x, aux), None
        if cfg.remat:
            # full per-layer remat: saves only the residual stream between
            # layers (peak = carry + one layer) -- the 1M-token cells need it
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), tuple(stacked),
            unroll=True if cfg.scan_unroll else 1)
    return x, aux_total


def embed_tokens(params, tokens, rt: Runtime):
    table = params["embed"]["table"]
    return jnp.take(table, tokens, axis=0).astype(rt.cdt)


def forward_train(params, tokens, rt: Runtime, *, extra=None,
                  aux_weight: float = 0.01):
    """Decoder-LM loss.  tokens: (B, S) int32.  extra: dict for vlm stubs
    ({"patch_embeds": (B, n_vis, d)}).  Targets = tokens shifted left."""
    cfg = rt.cfg
    x = embed_tokens(params, tokens, rt)
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
    if extra is not None and "patch_embeds" in extra:
        pe = extra["patch_embeds"].astype(rt.cdt)
        x = jnp.concatenate([pe, x], axis=1)
        targets = jnp.concatenate(
            [jnp.full(pe.shape[:2], -1, targets.dtype), targets], axis=1)
    if rt.rules.batch:
        x = jax.lax.with_sharding_constraint(
            x, jax.NamedSharding(rt.mesh, P(rt.rules.batch, None, None)))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, aux = _run_groups(params, x, positions, rt)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    table = params.get("lm_head", {}).get("w")
    if table is None:
        table = params["embed"]["table"]
    else:
        table = table.T
    loss = L.cross_entropy_loss(table, x, targets, compute_dtype=rt.cdt,
                                n_chunks=cfg.loss_chunks)
    return loss + aux_weight * aux


# -- serving: caches, prefill, decode --------------------------------------------------


def block_cache(kind, cfg, batch, max_len, dtype):
    if kind in ("dense", "moe"):
        return A.init_cache(cfg, batch, max_len, dtype)
    if kind == "local":
        local_cfg = dataclasses.replace(cfg, sliding_window=cfg.local_window)
        return A.init_cache(local_cfg, batch, max_len, dtype)
    if kind == "mlstm":
        return S.mlstm_state(cfg, batch, cfg.d_model, cfg.n_heads,
                             cfg.mlstm_pf)
    if kind == "slstm":
        return S.slstm_state(batch, cfg.d_model)
    if kind == "rglru":
        return S.rglru_state(batch, cfg.lru_width or cfg.d_model)
    raise ValueError(kind)


def init_caches(cfg, batch, max_len, dtype=jnp.bfloat16):
    caches = []
    for pat, n_rep in build_groups(cfg):
        stacked = []
        for kind in pat:
            one = block_cache(kind, cfg, batch, max_len, dtype)
            stacked.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), one))
        caches.append(stacked)
    return caches


def caches_specs(cfg, rules):
    out = []
    for pat, _ in build_groups(cfg):
        stacked = []
        for kind in pat:
            if kind in ("dense", "moe", "local"):
                s = A.cache_specs(cfg, rules)
            else:
                b = rules.batch
                if kind == "mlstm":
                    s = {"C": P(b, None, None, None), "N": P(b, None, None),
                         "M": P(b, None)}
                elif kind == "slstm":
                    s = {"c": P(b, None), "n": P(b, None), "m": P(b, None)}
                else:
                    s = {"h": P(b, None), "conv": P(b, None, None)}
            stacked.append(jax.tree.map(lambda sp: P(*((None,) + tuple(sp))),
                                        s, is_leaf=lambda v: isinstance(v, P)))
        out.append(stacked)
    return out


def block_decode(kind, p, x, pos, cache, rt: Runtime):
    cfg = rt.cfg
    cdt = rt.cdt
    if kind in ("dense", "moe", "local"):
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, cache = A.attention_decode(p["attn"], h, pos, cache, cfg, cdt=cdt)
        x = x + y
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            y, _ = _moe_block(p["moe"], h, rt)
        else:
            y = L.apply_mlp(p["mlp"], h, cfg.act, cdt)
        return x + y, cache
    if kind == "mlstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        y, cache = S.mlstm_decode(p["cell"], h, cache, cfg.n_heads, cdt=cdt)
        return x + y, cache
    if kind == "slstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        y, cache = S.slstm_decode(p["cell"], h, cache, cdt=cdt)
        return x + y, cache
    if kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, cache = S.rglru_decode(p["cell"], h, cache, cdt=cdt)
        x = x + y
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], h, cfg.act, cdt), cache
    raise ValueError(kind)


def decode_step(params, token, pos, caches, rt: Runtime):
    """One decode step.  token: (B, 1) int32; pos: scalar int32.

    Returns (logits (B, vocab), caches')."""
    cfg = rt.cfg
    x = embed_tokens(params, token, rt)
    new_caches = []
    for (pat, n_rep), stacked, cstack in zip(build_groups(cfg),
                                             params["groups"], caches):
        def body(x, xs):
            layer_params, layer_caches = xs
            new_lc = []
            for j, kind in enumerate(pat):
                x, c2 = block_decode(kind, layer_params[j], x, pos,
                                     layer_caches[j], rt)
                new_lc.append(c2)
            return x, new_lc
        x, ncs = jax.lax.scan(body, x, (tuple(stacked), tuple(cstack)),
                              unroll=True if cfg.scan_unroll else 1)
        new_caches.append(ncs)
    x = L.apply_norm(params["final_norm"], x, cfg.norm)
    table = params.get("lm_head", {}).get("w")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(rt.cdt),
                            params["embed"]["table"].astype(rt.cdt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x.astype(rt.cdt),
                            table.astype(rt.cdt))
    return logits[:, 0].astype(jnp.float32), new_caches


def prefill(params, tokens, caches, rt: Runtime):
    """Prefill the caches with a full prompt.  tokens: (B, S).

    Returns (last-token logits (B, vocab), caches')."""
    cfg = rt.cfg
    x = embed_tokens(params, tokens, rt)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    new_caches = []
    for (pat, n_rep), stacked, cstack in zip(build_groups(cfg),
                                             params["groups"], caches):
        def body(x, xs):
            layer_params, layer_caches = xs
            new_lc = []
            for j, kind in enumerate(pat):
                x, c2 = _block_prefill(kind, layer_params[j], x, positions,
                                       layer_caches[j], rt)
                new_lc.append(c2)
            return x, new_lc
        x, ncs = jax.lax.scan(body, x, (tuple(stacked), tuple(cstack)),
                              unroll=True if cfg.scan_unroll else 1)
        new_caches.append(ncs)
    x = L.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    table = params.get("lm_head", {}).get("w")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(rt.cdt),
                            params["embed"]["table"].astype(rt.cdt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x.astype(rt.cdt),
                            table.astype(rt.cdt))
    return logits[:, 0].astype(jnp.float32), new_caches


def _block_prefill(kind, p, x, positions, cache, rt: Runtime):
    cfg = rt.cfg
    cdt = rt.cdt
    if kind in ("dense", "moe", "local"):
        win = cfg.local_window if kind == "local" else cfg.sliding_window
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, cache = A.attention_train(p["attn"], h, positions, cfg, window=win,
                                     cdt=cdt, cache=cache)
        x = x + y
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        if kind == "moe":
            y, _ = _moe_block(p["moe"], h, rt)
        else:
            y = L.apply_mlp(p["mlp"], h, cfg.act, cdt)
        return x + y, cache
    # recurrent blocks: the chunkwise/scan training path also emits the
    # final state, which becomes the decode cache.
    if kind == "mlstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        y, st = S.mlstm_train(p["cell"], h, cfg.n_heads, cdt=cdt,
                              return_state=True, unroll=cfg.inner_unroll)
        return x + y, st
    if kind == "slstm":
        h = L.apply_norm(p["ln"], x, cfg.norm)
        y, st = S.slstm_train(p["cell"], h, cdt=cdt, return_state=True)
        return x + y, st
    if kind == "rglru":
        h = L.apply_norm(p["ln1"], x, cfg.norm)
        y, st = S.rglru_train(p["cell"], h, cdt=cdt, return_state=True)
        x = x + y
        h = L.apply_norm(p["ln2"], x, cfg.norm)
        return x + L.apply_mlp(p["mlp"], h, cfg.act, cdt), st
    raise ValueError(kind)
