# LM model zoo substrate: the assigned architectures as config-driven
# functional JAX models (params = pytrees, explicit dtypes, sharding specs
# built alongside each parameter tree).
