"""Mixture-of-Experts layer with expert-parallel all-to-all dispatch.

This is the LM-side incarnation of the paper's two-domain pattern
(DESIGN.md §4): tokens are computed in the sequence-sharded domain, one
all-to-all moves them to the expert-sharded domain, expert FFNs run locally,
and the reverse all-to-all brings results home -- exactly the
Delta-exchange structure of the SHT (stage / all_to_all / stage).

Mechanics (inside one shard_map over the full mesh):
  * activations arrive sequence-sharded over the "model" axis (SP), token-
    sharded over ("pod", "data");
  * router (replicated weights) computes top-k experts per token;
  * tokens are bucketed per destination expert-shard with a static capacity
    C = ceil(T_local * k / n_shards * capacity_factor); overflow tokens are
    dropped (standard capacity-style MoE; the aux loss keeps routing
    balanced so drops are rare);
  * ONE all_to_all ships (payload, expert-id) buckets; expert shards run a
    grouped matmul (jax.lax.ragged_dot) over their local experts; ONE
    reverse all_to_all ships results back;
  * source shards combine with router probabilities (scatter-add).

A shared-expert branch (DeepSeek-style) and the load-balance auxiliary
loss are included.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import layers as L

__all__ = ["init_moe", "spec_moe", "moe_apply"]


def init_moe(key, cfg, dtype=jnp.bfloat16):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(ks[0], (d, E), jnp.float32)
                         * scale).astype(jnp.float32)},
        "gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
                 * scale).astype(dtype),
        "up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
               * scale).astype(dtype),
        "down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                 / np.sqrt(ff)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, ff * cfg.n_shared_experts,
                                 act="swiglu", dtype=dtype)
    return p


def spec_moe(cfg, rules: L.ShardingRules, *, layer_stacked=True):
    lead = (rules.ax("layers"),) if layer_stacked else ()
    e = rules.ax("experts")
    s = {
        "router": {"w": P(*lead, None, None)},
        "gate": P(*lead, e, None, None),
        "up": P(*lead, e, None, None),
        "down": P(*lead, e, None, None),
    }
    if cfg.n_shared_experts:
        s["shared"] = L.spec_mlp(rules, layer_stacked=layer_stacked)
    return s


def _router(p, x, cfg):
    """x: (T, d) -> (probs (T, k), experts (T, k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalise
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return top_p.astype(jnp.float32), top_e.astype(jnp.int32), aux


def _dispatch_buckets(flat_e, n_shards, e_per_shard, capacity):
    """flat_e: (N,) expert ids.  Returns (dest, rank) with rank = position
    within the destination's bucket (== capacity -> dropped)."""
    dest = flat_e // e_per_shard                              # (N,)
    order = jnp.argsort(dest, stable=True)
    dest_sorted = dest[order]
    counts = jnp.bincount(dest_sorted, length=n_shards)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(dest.shape[0]) - starts[dest_sorted]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    rank = jnp.minimum(rank, capacity)                        # overflow slot
    return dest, rank


def _grouped_ffn(p, xs, eids, e_per_shard, cdt):
    """Grouped SwiGLU over local experts.  xs: (N, d); eids: (N,) local ids."""
    order = jnp.argsort(eids, stable=True)
    xs_s = xs[order]
    gsz = jnp.bincount(eids, length=e_per_shard).astype(jnp.int32)
    g = jax.lax.ragged_dot(xs_s.astype(cdt), p["gate"].astype(cdt), gsz)
    u = jax.lax.ragged_dot(xs_s.astype(cdt), p["up"].astype(cdt), gsz)
    h = jax.nn.silu(g) * u
    y_s = jax.lax.ragged_dot(h, p["down"].astype(cdt), gsz)
    return jnp.zeros_like(y_s).at[order].set(y_s)


def moe_apply(p, x_loc, cfg, axis_name="model", *, cdt=jnp.bfloat16):
    """Expert-parallel MoE on one shard (call inside shard_map).

    x_loc: (T_local, d) tokens owned by this model shard (sequence-split).
    Returns (y_loc (T_local, d), aux_loss scalar local mean).
    """
    T, d = x_loc.shape
    E, k = cfg.n_experts, cfg.top_k
    S = compat.axis_size(axis_name)
    e_per_shard = E // S
    cap = int(np.ceil(T * k / S * cfg.capacity_factor))

    top_p, top_e, aux = _router(p, x_loc, cfg)
    flat_e = top_e.reshape(-1)                                # (T*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)

    dest, rank = _dispatch_buckets(flat_e, S, e_per_shard, cap)

    # Build send buffers; overflow rank == cap lands in a discarded slot.
    send = jnp.zeros((S, cap + 1, d), cdt)
    send = send.at[dest, rank].set(x_loc[flat_tok].astype(cdt))
    send_eid = jnp.full((S, cap + 1), e_per_shard - 1, jnp.int32)
    send_eid = send_eid.at[dest, rank].set(flat_e % e_per_shard)
    send, send_eid = send[:, :cap], send_eid[:, :cap]

    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=True).reshape(S * cap, d)
    recv_eid = jax.lax.all_to_all(send_eid, axis_name, split_axis=0,
                                  concat_axis=0, tiled=True).reshape(S * cap)

    y = _grouped_ffn(p, recv, recv_eid, e_per_shard, cdt)     # (S*cap, d)

    back = jax.lax.all_to_all(y.reshape(S, cap, d), axis_name, split_axis=0,
                              concat_axis=0, tiled=True)      # (S, cap, d)

    # Combine: slot (dest, rank) corresponds to flat entry; gather + weight.
    valid = (rank < cap).astype(jnp.float32)
    contrib = back[dest, jnp.minimum(rank, cap - 1)]          # (T*k, d)
    w = (flat_p * valid)[:, None].astype(jnp.float32)
    out = jnp.zeros((T, d), jnp.float32).at[flat_tok].add(
        contrib.astype(jnp.float32) * w)
    out = out.astype(cdt)
    # NOTE: the shared-expert branch is applied OUTSIDE the shard_map (its
    # d_ff axis is model-sharded; the partial-sum reduction belongs to
    # GSPMD, not to this token-sharded body).  See transformer._moe_block.
    return out, aux


def moe_apply_replicated(p_loc, x_loc, cfg, axis_name="model", *,
                         cdt=jnp.bfloat16):
    """Decode-path MoE: activations replicated across the expert axis.

    Each expert shard routes ALL local tokens, computes the subset that hit
    its experts, and a psum combines.  No all-to-all; right when the token
    count is too small to split (single-token decode steps).
    x_loc: (T, d) (same on every shard of ``axis_name``).
    """
    T, d = x_loc.shape
    E, k = cfg.n_experts, cfg.top_k
    S = compat.axis_size(axis_name)
    e_loc = E // S
    off = jax.lax.axis_index(axis_name) * e_loc

    top_p, top_e, aux = _router(p_loc, x_loc, cfg)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    mine = (flat_e >= off) & (flat_e < off + e_loc)
    # Capacity-gather ONLY the locally-routed assignments before the
    # grouped matmul -- computing all T*k rows on every shard costs S x the
    # necessary flops (measured: 12x compute blow-up at 61 MoE layers;
    # EXPERIMENTS.md deepseek hillclimb, iteration 2a vs 2b).
    cap = int(np.ceil(T * k / S * cfg.capacity_factor))
    rank = jnp.cumsum(mine.astype(jnp.int32)) - 1
    slot = jnp.where(mine & (rank < cap), rank, cap)
    buf = jnp.zeros((cap + 1, d), cdt).at[slot].set(x_loc[flat_tok].astype(cdt))
    eid_buf = jnp.full((cap + 1,), e_loc - 1, jnp.int32).at[slot].set(
        jnp.where(mine, flat_e - off, e_loc - 1))
    y = _grouped_ffn(p_loc, buf[:cap], eid_buf[:cap], e_loc, cdt)
    contrib = y[jnp.minimum(slot, cap - 1)]                  # (T*k, d)
    w = jnp.where(mine & (slot < cap), flat_p, 0.0)
    out = jnp.zeros((T, d), jnp.float32).at[flat_tok].add(
        contrib.astype(jnp.float32) * w[:, None])
    out = jax.lax.psum(out, axis_name).astype(cdt)
    return out, aux / S


def moe_apply_local(p, x, cfg, *, cdt=jnp.bfloat16):
    """Single-shard MoE (smoke tests / 1-device runs)."""
    T, d = x.shape
    top_p, top_e, aux = _router(p, x, cfg)
    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), cfg.top_k)
    y = _grouped_ffn(p, x[flat_tok].astype(cdt), flat_e, cfg.n_experts, cdt)
    out = jnp.zeros((T, d), jnp.float32).at[flat_tok].add(
        y.astype(jnp.float32) * flat_p[:, None])
    out = out.astype(cdt)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], x.astype(cdt), cdt)
    return out, aux
