"""Recurrent sequence-mixing blocks: mLSTM, sLSTM (xLSTM) and RG-LRU
(RecurrentGemma / Griffin).

Training paths avoid O(S^2) work:
  * mLSTM  -- chunkwise-parallel form (matrix memory; exponential gating in
    log space for stability), O(S * d^2 / chunk + S * chunk * d);
  * RG-LRU -- diagonal linear recurrence via jax.lax.associative_scan;
  * sLSTM  -- inherently sequential scalar memory -> lax.scan over time
    (the xLSTM paper's own characterisation).

Decode paths carry O(1) state per layer -- the reason these architectures
run the long_500k cell that dense-attention models cannot (DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers as L

__all__ = [
    "init_mlstm", "spec_mlstm", "mlstm_train", "mlstm_decode", "mlstm_state",
    "init_slstm", "spec_slstm", "slstm_train", "slstm_decode", "slstm_state",
    "init_rglru", "spec_rglru", "rglru_train", "rglru_decode", "rglru_state",
]


# =============================================================================
# mLSTM (xLSTM matrix-memory block)
# =============================================================================


def init_mlstm(key, d: int, n_heads: int, *, pf: float = 2.0,
               dtype=jnp.bfloat16):
    di = int(d * pf)
    ks = jax.random.split(key, 8)
    return {
        "up": L.init_dense(ks[0], d, 2 * di, dtype=dtype),     # x, gate z
        "wq": L.init_dense(ks[1], di, di, dtype=dtype),
        "wk": L.init_dense(ks[2], di, di, dtype=dtype),
        "wv": L.init_dense(ks[3], di, di, dtype=dtype),
        "wi": L.init_dense(ks[4], di, n_heads, bias=True, dtype=jnp.float32),
        "wf": L.init_dense(ks[5], di, n_heads, bias=True, dtype=jnp.float32),
        "norm": L.init_norm(di),
        "down": L.init_dense(ks[6], di, d, dtype=dtype),
    }


def spec_mlstm(rules: L.ShardingRules, *, layer_stacked=True):
    kw = dict(layer_stacked=layer_stacked)
    return {
        "up": L.spec_dense(rules, "d_model", "d_ff", **kw),
        "wq": L.spec_dense(rules, "d_ff", None, **kw),
        "wk": L.spec_dense(rules, "d_ff", None, **kw),
        "wv": L.spec_dense(rules, "d_ff", None, **kw),
        "wi": L.spec_dense(rules, "d_ff", None, bias=True, **kw),
        "wf": L.spec_dense(rules, "d_ff", None, bias=True, **kw),
        "norm": L.spec_norm(rules, **kw),
        "down": L.spec_dense(rules, "d_ff", "d_model", **kw),
    }


def _mlstm_gates(p, xi, cdt):
    """log input/forget gates, (B, S, H) float32."""
    logi = L.dense(p["wi"], xi, jnp.float32)                  # pre-act
    logf = jax.nn.log_sigmoid(L.dense(p["wf"], xi, jnp.float32))
    return logi, logf


def mlstm_train(p, x, n_heads: int, *, chunk: int = 64, cdt=jnp.bfloat16,
                return_state=False, unroll=False):
    """Chunkwise-parallel mLSTM.  x: (B, S, d) -> (B, S, d)
    (+ final (C, N, M) state when return_state)."""
    B, S, d = x.shape
    u = L.dense(p["up"], x, cdt)
    xi, z = jnp.split(u, 2, axis=-1)
    di = xi.shape[-1]
    H = n_heads
    hd = di // H
    q = L.dense(p["wq"], xi, cdt).reshape(B, S, H, hd)
    k = (L.dense(p["wk"], xi, cdt) / float(np.sqrt(hd))).reshape(B, S, H, hd)
    v = L.dense(p["wv"], xi, cdt).reshape(B, S, H, hd)
    logi, logf = _mlstm_gates(p, xi, cdt)                     # (B, S, H)

    chunk = max(1, min(chunk, S))
    while S % chunk:
        chunk //= 2
    n = S // chunk
    rs = lambda t: t.reshape((B, n, chunk) + t.shape[2:])
    qc, kc, vc = rs(q), rs(k), rs(v)
    lic, lfc = rs(logi), rs(logf)

    # log cumulative forget within chunk: F[t] = sum_{s<=t} logf
    Fc = jnp.cumsum(lfc, axis=2)                               # (B, n, c, H)
    Ftot = Fc[:, :, -1]                                        # (B, n, H)

    # --- stabilised chunkwise recurrence ---
    # Per query position t (within a chunk): stabiliser m_t = F_t + G_t with
    # G_t = max(M_prev, cummax_{s<=t}(li_s - F_s)); every exp() below is then
    # bounded by 1.  State carries C~ = C_true * exp(-M), M = Ftot + G_end.
    def chunk_step(carry, xs):
        Cm, Nm, Mm = carry                    # (B,H,hd,hd), (B,H,hd), (B,H)
        q_, k_, v_, F_, li_, Ft_ = xs         # F_: (B,c,H) cumulative logf
        lg = li_ - F_                                          # (B,c,H)
        G = jnp.maximum(Mm[:, None, :], jax.lax.cummax(lg, axis=1))
        # inter-chunk term: q_t reads the carried state with exp(Mm - G_t)
        qf = jnp.exp(Mm[:, None, :] - G)                       # (B,c,H) <= 1
        qw = (q_.astype(jnp.float32) * qf[..., None])
        inter = jnp.einsum("bchd,bhde->bche", qw, Cm)
        inter_n = jnp.einsum("bchd,bhd->bch", qw, Nm)
        # intra-chunk term: weight(t,s) = exp(lg_s - G_t), causal
        w = lg[:, None, :, :] - G[:, :, None, :]               # (B,t,s,H)
        causal = jnp.tril(jnp.ones((w.shape[1], w.shape[1]), bool))
        w = jnp.where(causal[None, :, :, None], jnp.exp(w), 0.0)
        s = jnp.einsum("bchd,bkhd->bckh", q_.astype(jnp.float32),
                       k_.astype(jnp.float32))
        aw = s * w
        intra = jnp.einsum("bckh,bkhd->bchd", aw, v_.astype(jnp.float32))
        intra_n = jnp.sum(aw, axis=2)                          # (B,c,H)
        num = inter + intra
        den = inter_n + intra_n
        norm = jnp.maximum(jnp.abs(den), jnp.exp(-(F_ + G)))
        out = num / norm[..., None]
        # state update to end of chunk: M' = Ftot + G_end
        G_end = G[:, -1]                                       # (B,H)
        s_state = jnp.exp(Mm - G_end)                          # <= 1
        kw_ = jnp.exp(lg - G_end[:, None, :])                  # (B,c,H) <= 1
        kv = jnp.einsum("bchd,bche->bhde",
                        k_.astype(jnp.float32) * kw_[..., None],
                        v_.astype(jnp.float32))
        kn = jnp.sum(k_.astype(jnp.float32) * kw_[..., None], axis=1)
        Cm2 = Cm * s_state[..., None, None] + kv
        Nm2 = Nm * s_state[..., None] + kn
        return (Cm2, Nm2, Ft_ + G_end), out

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    N0 = jnp.zeros((B, H, hd), jnp.float32)
    M0 = jnp.full((B, H), -1e30, jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, Fc, lic, Ftot))
    (Cf, Nf, Mf), outs = jax.lax.scan(chunk_step, (C0, N0, M0), xs,
                                      unroll=True if unroll else 1)
    h = jnp.moveaxis(outs, 0, 1).reshape(B, S, di).astype(cdt)
    h = L.rms_norm(p["norm"], h) * jax.nn.silu(z)
    y = L.dense(p["down"], h, cdt)
    if return_state:
        return y, {"C": Cf, "N": Nf, "M": Mf}
    return y


def mlstm_state(cfg, batch: int, d: int, n_heads: int, pf: float = 2.0,
                dtype=jnp.float32):
    di = int(d * pf)
    hd = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, hd, hd), dtype),
            "N": jnp.zeros((batch, n_heads, hd), dtype),
            "M": jnp.full((batch, n_heads), -1e30, dtype)}


def mlstm_decode(p, x, state, n_heads: int, *, cdt=jnp.bfloat16):
    """One-token step.  x: (B, 1, d)."""
    B = x.shape[0]
    u = L.dense(p["up"], x, cdt)
    xi, z = jnp.split(u, 2, axis=-1)
    di = xi.shape[-1]
    hd = di // n_heads
    q = L.dense(p["wq"], xi, cdt).reshape(B, n_heads, hd)
    k = (L.dense(p["wk"], xi, cdt) / float(np.sqrt(hd))).reshape(B, n_heads, hd)
    v = L.dense(p["wv"], xi, cdt).reshape(B, n_heads, hd)
    logi = L.dense(p["wi"], xi, jnp.float32)[:, 0]             # (B, H)
    logf = jax.nn.log_sigmoid(L.dense(p["wf"], xi, jnp.float32))[:, 0]
    m_new = jnp.maximum(state["M"] + logf, logi)
    sf = jnp.exp(state["M"] + logf - m_new)
    si = jnp.exp(logi - m_new)
    C = state["C"] * sf[..., None, None] + si[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    N = state["N"] * sf[..., None] + si[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), N)
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    out = (num / norm[..., None]).reshape(B, 1, di)
    h = L.rms_norm(p["norm"], out.astype(cdt)) * jax.nn.silu(z)
    return L.dense(p["down"], h, cdt), {"C": C, "N": N, "M": m_new}


# =============================================================================
# sLSTM (xLSTM scalar-memory block; sequential scan)
# =============================================================================


def init_slstm(key, d: int, n_heads: int, *, pf: float = 4.0 / 3.0,
               dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    return {
        "wz": L.init_dense(ks[0], d, d, bias=True, dtype=dtype),
        "wi": L.init_dense(ks[1], d, d, bias=True, dtype=jnp.float32),
        "wf": L.init_dense(ks[2], d, d, bias=True, dtype=jnp.float32),
        "wo": L.init_dense(ks[3], d, d, bias=True, dtype=dtype),
        "norm": L.init_norm(d),
        "ffn": L.init_mlp(ks[4], d, int(d * pf), act="swiglu", dtype=dtype),
    }


def spec_slstm(rules: L.ShardingRules, *, layer_stacked=True):
    kw = dict(bias=True, layer_stacked=layer_stacked)
    return {
        "wz": L.spec_dense(rules, "d_model", None, **kw),
        "wi": L.spec_dense(rules, "d_model", None, **kw),
        "wf": L.spec_dense(rules, "d_model", None, **kw),
        "wo": L.spec_dense(rules, "d_model", None, **kw),
        "norm": L.spec_norm(rules, layer_stacked=layer_stacked),
        "ffn": L.spec_mlp(rules, layer_stacked=layer_stacked),
    }


def _slstm_scan(z, i_pre, f_pre, state):
    """Stabilised sLSTM recurrence over time.  All (B, S, d) inputs."""
    def step(carry, xs):
        c, n, m = carry
        z_t, i_t, f_t = xs
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        ip = jnp.exp(i_t - m_new)
        fp = jnp.exp(logf + m - m_new)
        c2 = fp * c + ip * jnp.tanh(z_t)
        n2 = fp * n + ip
        h = c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (z, i_pre, f_pre))
    (c, n, m), hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), (c, n, m)


def slstm_state(batch: int, d: int, dtype=jnp.float32):
    return {"c": jnp.zeros((batch, d), dtype), "n": jnp.zeros((batch, d), dtype),
            "m": jnp.full((batch, d), -1e30, dtype)}


def slstm_train(p, x, *, cdt=jnp.bfloat16, return_state=False):
    B, S, d = x.shape
    z = L.dense(p["wz"], x, jnp.float32)
    i_pre = L.dense(p["wi"], x, jnp.float32)
    f_pre = L.dense(p["wf"], x, jnp.float32)
    st = slstm_state(B, d)
    h, (c, n, m) = _slstm_scan(z, i_pre, f_pre, (st["c"], st["n"], st["m"]))
    h = h.astype(cdt) * jax.nn.sigmoid(L.dense(p["wo"], x, cdt))
    h = L.rms_norm(p["norm"], h)
    y = L.swiglu(p["ffn"], h, cdt)
    if return_state:
        return y, {"c": c, "n": n, "m": m}
    return y


def slstm_decode(p, x, state, *, cdt=jnp.bfloat16):
    B = x.shape[0]
    z = L.dense(p["wz"], x, jnp.float32)[:, 0]
    i_pre = L.dense(p["wi"], x, jnp.float32)[:, 0]
    f_pre = L.dense(p["wf"], x, jnp.float32)[:, 0]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    ip = jnp.exp(i_pre - m_new)
    fp = jnp.exp(logf + state["m"] - m_new)
    c2 = fp * state["c"] + ip * jnp.tanh(z)
    n2 = fp * state["n"] + ip
    h = (c2 / jnp.maximum(n2, 1.0))[:, None, :].astype(cdt)
    h = h * jax.nn.sigmoid(L.dense(p["wo"], x, cdt))
    h = L.rms_norm(p["norm"], h)
    y = L.swiglu(p["ffn"], h, cdt)
    return y, {"c": c2, "n": n2, "m": m_new}


# =============================================================================
# RG-LRU (RecurrentGemma recurrent block)
# =============================================================================


def init_rglru(key, d: int, lru_width: int, *, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 7)
    w = lru_width
    # Lambda parameterisation: a = sigmoid(Lambda) ** (8 * r_t)
    lam0 = np.log(np.exp(np.linspace(0.9, 0.999, w) * 8.0) - 1.0) / 8.0
    return {
        "in_x": L.init_dense(ks[0], d, w, dtype=dtype),
        "in_gate": L.init_dense(ks[1], d, w, dtype=dtype),
        "wr": L.init_dense(ks[2], w, w, bias=True, dtype=jnp.float32),
        "wi": L.init_dense(ks[3], w, w, bias=True, dtype=jnp.float32),
        "lam": jnp.asarray(lam0, jnp.float32),
        "out": L.init_dense(ks[4], w, d, dtype=dtype),
        "conv": (jax.random.normal(ks[5], (4, w), jnp.float32) * 0.1
                 ).astype(dtype),
    }


def spec_rglru(rules: L.ShardingRules, *, layer_stacked=True):
    kw = dict(layer_stacked=layer_stacked)
    lead = (rules.ax("layers"),) if layer_stacked else ()
    return {
        "in_x": L.spec_dense(rules, "d_model", "d_ff", **kw),
        "in_gate": L.spec_dense(rules, "d_model", "d_ff", **kw),
        "wr": L.spec_dense(rules, "d_ff", None, bias=True, **kw),
        "wi": L.spec_dense(rules, "d_ff", None, bias=True, **kw),
        "lam": P(*lead, rules.ax("d_ff")),
        "out": L.spec_dense(rules, "d_ff", "d_model", **kw),
        "conv": P(*lead, None, rules.ax("d_ff")),
    }


def _causal_conv4(xw, kernel, state=None):
    """Depthwise causal conv, width 4.  xw: (B, S, w)."""
    B, S, w = xw.shape
    if state is None:
        pad = jnp.zeros((B, 3, w), xw.dtype)
    else:
        pad = state                                             # (B, 3, w)
    xp = jnp.concatenate([pad, xw], axis=1)
    out = sum(xp[:, 3 - t: 3 - t + S] * kernel[3 - t][None, None, :]
              for t in range(4))
    new_state = xp[:, -3:]
    return out, new_state


def rglru_train(p, x, *, cdt=jnp.bfloat16, return_state=False):
    B, S, d = x.shape
    xw = L.dense(p["in_x"], x, cdt)
    gate = jax.nn.gelu(L.dense(p["in_gate"], x, cdt))
    xw_raw = xw
    xw, conv_tail = _causal_conv4(xw, p["conv"].astype(cdt))
    r = jax.nn.sigmoid(L.dense(p["wr"], xw, jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["wi"], xw, jnp.float32))
    log_a = -8.0 * r * jax.nn.softplus(p["lam"].astype(jnp.float32))[None, None]
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = (i * xw.astype(jnp.float32)) * mult
    # h_t = a_t * h_{t-1} + gated_t  via associative scan
    def comb(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, br + ar * bl
    aa, hh = jax.lax.associative_scan(comb, (a, gated), axis=1)
    h = hh.astype(cdt) * gate
    y = L.dense(p["out"], h, cdt)
    if return_state:
        return y, {"h": hh[:, -1], "conv": conv_tail.astype(jnp.float32)}
    return y


def rglru_state(batch: int, lru_width: int, dtype=jnp.float32):
    return {"h": jnp.zeros((batch, lru_width), dtype),
            "conv": jnp.zeros((batch, 3, lru_width), jnp.float32)}


def rglru_decode(p, x, state, *, cdt=jnp.bfloat16):
    B = x.shape[0]
    xw = L.dense(p["in_x"], x, cdt)
    gate = jax.nn.gelu(L.dense(p["in_gate"], x, cdt))
    xw, conv_state = _causal_conv4(xw, p["conv"].astype(cdt),
                                   state["conv"].astype(cdt))
    r = jax.nn.sigmoid(L.dense(p["wr"], xw, jnp.float32))[:, 0]
    i = jax.nn.sigmoid(L.dense(p["wi"], xw, jnp.float32))[:, 0]
    log_a = -8.0 * r * jax.nn.softplus(p["lam"].astype(jnp.float32))[None]
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * state["h"] + (i * xw[:, 0].astype(jnp.float32)) * mult
    y = (h[:, None].astype(cdt)) * gate
    return L.dense(p["out"], y, cdt), {"h": h, "conv": conv_state.astype(jnp.float32)}
