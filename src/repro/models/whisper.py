"""Whisper-style encoder-decoder (audio backbone; conv frontend stubbed).

Per the task spec the modality frontend is a STUB: `input_specs()` provides
precomputed frame embeddings (B, S_enc, d) -- the conv1/conv2 subsampling
stack is replaced by an identity over those embeddings plus learned
positions.  The transformer backbone (32L enc + 32L dec, d=1280, 20H MHA,
GELU MLPs, LayerNorm) is implemented in full.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models import layers as L

__all__ = ["init_whisper", "whisper_specs", "whisper_train",
           "init_whisper_caches", "whisper_cache_specs",
           "whisper_decode_step", "whisper_prefill"]

_MAX_POS = 1 << 20   # learned positions are sliced to the actual length


def _enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {"ln1": L.init_norm(cfg.d_model, kind="layernorm"),
            "attn": A.init_attention(ks[0], cfg, dtype),
            "ln2": L.init_norm(cfg.d_model, kind="layernorm"),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, act="gelu",
                              dtype=dtype)}


def _dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {"ln1": L.init_norm(cfg.d_model, kind="layernorm"),
            "attn": A.init_attention(ks[0], cfg, dtype),
            "ln_x": L.init_norm(cfg.d_model, kind="layernorm"),
            "xattn": A.init_attention(ks[1], cfg, dtype),
            "ln2": L.init_norm(cfg.d_model, kind="layernorm"),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, act="gelu",
                              dtype=dtype)}


def init_whisper(key, cfg, max_enc: int = 32768, max_dec: int = 32768):
    dtype = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(key, 6)
    enc_keys = jax.random.split(k[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(k[1], cfg.n_layers)
    enc = jax.vmap(lambda kk: _enc_block_init(kk, cfg, dtype))(enc_keys)
    dec = jax.vmap(lambda kk: _dec_block_init(kk, cfg, dtype))(dec_keys)
    return {
        "enc_pos": (jax.random.normal(k[2], (max_enc, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(k[3], (max_dec, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dtype),
        "embed": L.init_embedding(k[4], cfg.vocab, cfg.d_model, dtype),
        "enc": enc,
        "dec": dec,
        "enc_norm": L.init_norm(cfg.d_model, kind="layernorm"),
        "dec_norm": L.init_norm(cfg.d_model, kind="layernorm"),
        "lm_head": L.init_dense(k[5], cfg.d_model, cfg.vocab, dtype=dtype),
    }


def whisper_specs(cfg, rules):
    nk = dict(kind="layernorm", layer_stacked=True)
    enc = {"ln1": L.spec_norm(rules, **nk),
           "attn": A.spec_attention(cfg, rules, layer_stacked=True),
           "ln2": L.spec_norm(rules, **nk),
           "mlp": L.spec_mlp(rules, act="gelu", layer_stacked=True)}
    dec = dict(enc)
    dec.update({"ln_x": L.spec_norm(rules, **nk),
                "xattn": A.spec_attention(cfg, rules, layer_stacked=True)})
    return {
        "enc_pos": P(None, None),
        "dec_pos": P(None, None),
        "embed": L.spec_embedding(rules),
        "enc": enc, "dec": dec,
        "enc_norm": L.spec_norm(rules, kind="layernorm"),
        "dec_norm": L.spec_norm(rules, kind="layernorm"),
        "lm_head": L.spec_dense(rules, "d_model", "vocab"),
    }


def _cross_attention(p, x, enc_kv, cfg, cdt):
    """x: (B, Sd, d); enc_kv: precomputed (k, v) (B, Se, H, hd)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = cfg.hd
    q = L.dense(p["wq"], x, cdt).reshape(B, S, H, hd)
    k, v = enc_kv
    qpos = jnp.arange(S, dtype=jnp.int32)
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    impl = A.mea if cfg.attn_impl == "mea" else A.dense_attention
    out = impl(q, k, v, qpos, kpos, causal=False)
    return L.dense(p["wo"], out.reshape(B, S, -1), cdt)


def _enc_kv(p, enc_h, cfg, cdt):
    B, Se, d = enc_h.shape
    k = L.dense(p["wk"], enc_h, cdt).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    v = L.dense(p["wv"], enc_h, cdt).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
    return k, v


def encode(params, frames, cfg, *, cdt):
    """frames: (B, Se, d) stub embeddings -> encoder hidden states."""
    B, Se, d = frames.shape
    x = frames.astype(cdt) + params["enc_pos"][:Se][None].astype(cdt)
    pos = jnp.arange(Se, dtype=jnp.int32)

    def body(x, p):
        h = L.layer_norm(p["ln1"], x)
        q = L.dense(p["attn"]["wq"], h, cdt).reshape(B, Se, cfg.n_heads, cfg.hd)
        k = L.dense(p["attn"]["wk"], h, cdt).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        v = L.dense(p["attn"]["wv"], h, cdt).reshape(B, Se, cfg.n_kv_heads, cfg.hd)
        impl = A.mea if cfg.attn_impl == "mea" else A.dense_attention
        o = impl(q, k, v, pos, pos, causal=False)
        x = x + L.dense(p["attn"]["wo"], o.reshape(B, Se, -1), cdt)
        h = L.layer_norm(p["ln2"], x)
        return x + L.gelu_mlp(p["mlp"], h, cdt), None

    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=True if cfg.scan_unroll else 1)
    return L.layer_norm(params["enc_norm"], x)


def _dec_block(p, x, positions, enc_h, cfg, cdt):
    h = L.layer_norm(p["ln1"], x)
    y, _ = A.attention_train(p["attn"], h, positions, cfg, cdt=cdt)
    x = x + y
    h = L.layer_norm(p["ln_x"], x)
    x = x + _cross_attention(p["xattn"], h, _enc_kv(p["xattn"], enc_h, cfg, cdt),
                             cfg, cdt)
    h = L.layer_norm(p["ln2"], x)
    return x + L.gelu_mlp(p["mlp"], h, cdt)


def whisper_train(params, batch, rt):
    """batch: {"frames": (B, Se, d), "tokens": (B, Sd)} -> scalar loss."""
    cfg, cdt = rt.cfg, rt.cdt
    frames, tokens = batch["frames"], batch["tokens"]
    enc_h = encode(params, frames, cfg, cdt=cdt)
    B, Sd = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cdt)
    x = x + params["dec_pos"][:Sd][None].astype(cdt)
    positions = jnp.arange(Sd, dtype=jnp.int32)

    def body(x, p):
        return _dec_block(p, x, positions, enc_h, cfg, cdt), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec"],
                        unroll=True if cfg.scan_unroll else 1)
    x = L.layer_norm(params["dec_norm"], x)
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
    return L.cross_entropy_loss(params["lm_head"]["w"].T, x, targets,
                                compute_dtype=cdt, n_chunks=cfg.loss_chunks)


# -- serving ---------------------------------------------------------------------


def init_whisper_caches(cfg, batch, max_len, enc_len, dtype=jnp.bfloat16):
    Ld = cfg.n_layers
    self_c = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (Ld,) + a.shape),
        A.init_cache(cfg, batch, max_len, dtype))
    xkv = {
        "k": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
    }
    return {"self": self_c, "cross": xkv}


def whisper_cache_specs(cfg, rules):
    b = rules.batch
    s = jax.tree.map(lambda sp: P(*((None,) + tuple(sp))),
                     A.cache_specs(cfg, rules),
                     is_leaf=lambda v: isinstance(v, P))
    return {"self": s,
            "cross": {"k": P(None, b, None, rules.ax("kv_heads"), None),
                      "v": P(None, b, None, rules.ax("kv_heads"), None)}}


def whisper_prefill(params, frames, tokens, caches, rt):
    """Encode audio, precompute cross-KV, prefill decoder self-cache."""
    cfg, cdt = rt.cfg, rt.cdt
    enc_h = encode(params, frames, cfg, cdt=cdt)
    B, Sd = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cdt)
    x = x + params["dec_pos"][:Sd][None].astype(cdt)
    positions = jnp.arange(Sd, dtype=jnp.int32)

    def body(x, xs):
        p, self_c = xs
        h = L.layer_norm(p["ln1"], x)
        y, self_c = A.attention_train(p["attn"], h, positions, cfg, cdt=cdt,
                                      cache=self_c)
        x = x + y
        xk, xv = _enc_kv(p["xattn"], enc_h, cfg, cdt)
        h = L.layer_norm(p["ln_x"], x)
        x = x + _cross_attention(p["xattn"], h, (xk, xv), cfg, cdt)
        h = L.layer_norm(p["ln2"], x)
        return x + L.gelu_mlp(p["mlp"], h, cdt), (self_c, xk, xv)

    x, (self_c, xk, xv) = jax.lax.scan(body, x, (params["dec"],
                                                 caches["self"]),
                                       unroll=True if cfg.scan_unroll else 1)
    x = L.layer_norm(params["dec_norm"], x[:, -1:])
    logits = L.dense(params["lm_head"], x, cdt)[:, 0]
    return logits.astype(jnp.float32), {"self": self_c,
                                        "cross": {"k": xk, "v": xv}}


def whisper_decode_step(params, token, pos, caches, rt):
    cfg, cdt = rt.cfg, rt.cdt
    B = token.shape[0]
    x = jnp.take(params["embed"]["table"], token, axis=0).astype(cdt)
    posv = jnp.full((1,), pos, jnp.int32)
    x = x + jnp.take(params["dec_pos"], posv, axis=0)[None].astype(cdt)

    def body(x, xs):
        p, self_c, xk, xv = xs
        h = L.layer_norm(p["ln1"], x)
        y, self_c = A.attention_decode(p["attn"], h, pos, self_c, cfg,
                                       cdt=cdt)
        x = x + y
        h = L.layer_norm(p["ln_x"], x)
        x = x + _cross_attention(p["xattn"], h, (xk, xv), cfg, cdt)
        h = L.layer_norm(p["ln2"], x)
        return x + L.gelu_mlp(p["mlp"], h, cdt), self_c

    x, self_c = jax.lax.scan(body, x, (params["dec"], caches["self"],
                                       caches["cross"]["k"],
                                       caches["cross"]["v"]),
                             unroll=True if cfg.scan_unroll else 1)
    x = L.layer_norm(params["dec_norm"], x)
    logits = L.dense(params["lm_head"], x, cdt)[:, 0]
    return logits.astype(jnp.float32), {"self": self_c,
                                        "cross": caches["cross"]}