"""Shared neural-net building blocks (functional style, explicit dtypes).

Every builder comes in a pair:
  * ``init_*(key, ...) -> params``  (dict pytree of jnp arrays)
  * ``spec_*(...) -> specs``        (identically-structured pytree of
                                     PartitionSpec for pjit sharding)
The spec tree mirroring the param tree is asserted in tests.

Logical sharding axes are resolved through ``ShardingRules`` so the same
model code serves the TP profile (heads/ffn/vocab on "model"), the `small`
profile (attention replicated), FSDP variants, and single-device smoke
tests (everything None).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "ShardingRules", "init_dense", "spec_dense", "init_norm", "spec_norm",
    "rms_norm", "layer_norm", "apply_rope", "rope_freqs", "init_embedding",
    "spec_embedding", "dense", "swiglu", "gelu_mlp", "init_mlp", "spec_mlp",
    "cross_entropy_loss",
]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (or None) resolution."""
    batch: tuple | str | None = ("pod", "data")
    heads: str | None = "model"        # attention head axis
    kv_heads: str | None = None        # usually replicated (kv < mesh)
    d_ff: str | None = "model"         # MLP hidden
    vocab: str | None = "model"        # embedding/logits vocab axis
    d_model: str | None = None         # residual axis ("data" under FSDP)
    experts: str | None = "model"      # MoE expert axis
    seq: str | None = None             # sequence axis (SP when set)
    layers: str | None = None          # stacked-layer axis (FSDP variant)

    def ax(self, name: str | None):
        if name is None:
            return None
        return getattr(self, name)


def _init_normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -- dense ---------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, dtype=jnp.bfloat16):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    p = {"w": _init_normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def spec_dense(rules: ShardingRules, in_axis: str | None, out_axis: str | None,
               *, bias: bool = False, layer_stacked: bool = False):
    lead = (rules.ax("layers"),) if layer_stacked else ()
    s = {"w": P(*lead, rules.ax(in_axis), rules.ax(out_axis))}
    if bias:
        s["b"] = P(*lead, rules.ax(out_axis))
    return s


def dense(p, x, compute_dtype=jnp.bfloat16):
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype),
                   p["w"].astype(compute_dtype))
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# -- norms ---------------------------------------------------------------------


def init_norm(d: int, *, kind: str = "rmsnorm", dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def spec_norm(rules: ShardingRules, *, kind: str = "rmsnorm",
              layer_stacked: bool = False):
    lead = (rules.ax("layers"),) if layer_stacked else ()
    s = {"scale": P(*lead, None)}
    if kind == "layernorm":
        s["bias"] = P(*lead, None)
    return s


def rms_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(p, x, kind: str):
    return rms_norm(p, x) if kind == "rmsnorm" else layer_norm(p, x)


# -- RoPE ------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 1e4):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(half, dtype=np.float64) * 2.0 / head_dim))


def apply_rope(x, positions, *, theta: float = 1e4):
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- embeddings --------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": _init_normal(key, (vocab, d), 0.02, dtype)}


def spec_embedding(rules: ShardingRules):
    return {"table": P(rules.ax("vocab"), rules.ax("d_model"))}


# -- MLPs ---------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, *, act: str = "swiglu",
             dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": init_dense(ks[0], d, d_ff, dtype=dtype),
            "up": init_dense(ks[1], d, d_ff, dtype=dtype),
            "down": init_dense(ks[2], d_ff, d, dtype=dtype),
        }
    return {  # gelu
        "up": init_dense(ks[0], d, d_ff, dtype=dtype),
        "down": init_dense(ks[1], d_ff, d, dtype=dtype),
    }


def spec_mlp(rules: ShardingRules, *, act: str = "swiglu",
             layer_stacked: bool = False):
    kw = dict(layer_stacked=layer_stacked)
    if act == "swiglu":
        return {
            "gate": spec_dense(rules, "d_model", "d_ff", **kw),
            "up": spec_dense(rules, "d_model", "d_ff", **kw),
            "down": spec_dense(rules, "d_ff", "d_model", **kw),
        }
    return {
        "up": spec_dense(rules, "d_model", "d_ff", **kw),
        "down": spec_dense(rules, "d_ff", "d_model", **kw),
    }


def swiglu(p, x, compute_dtype=jnp.bfloat16):
    g = dense(p["gate"], x, compute_dtype)
    u = dense(p["up"], x, compute_dtype)
    return dense(p["down"], jax.nn.silu(g) * u, compute_dtype)


def gelu_mlp(p, x, compute_dtype=jnp.bfloat16):
    u = dense(p["up"], x, compute_dtype)
    return dense(p["down"], jax.nn.gelu(u), compute_dtype)


def apply_mlp(p, x, act: str, compute_dtype=jnp.bfloat16):
    return swiglu(p, x, compute_dtype) if act == "swiglu" \
        else gelu_mlp(p, x, compute_dtype)


# -- loss ------------------------------------------------------------------------------


def cross_entropy_loss(embedding_table, h, targets, *, n_chunks: int = 8,
                       compute_dtype=jnp.bfloat16, z_loss: float = 0.0):
    """Chunked softmax cross entropy against tied-embedding logits.

    h: (B, S, D) final hidden states; targets: (B, S) int32 (-1 = pad).
    The (B, S, V) logits tensor is never materialised in full: the sequence
    is processed in ``n_chunks`` pieces (memory high-water-mark control at
    1M-token batches with 150k vocabularies).
    """
    B, S, D = h.shape
    V = embedding_table.shape[0]
    n_chunks = max(1, min(n_chunks, S))
    while S % n_chunks:
        n_chunks -= 1
    hs = h.reshape(B, n_chunks, S // n_chunks, D)
    ts = targets.reshape(B, n_chunks, S // n_chunks)
    table = embedding_table.astype(compute_dtype)

    def chunk(carry, xs):
        hc, tc = xs                                  # (B, s, D), (B, s)
        logits = jnp.einsum("bsd,vd->bsv", hc.astype(compute_dtype),
                            table).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        nll = (lse - tgt) * valid
        zl = z_loss * (lse ** 2) * valid if z_loss else 0.0
        tot, cnt = carry
        return (tot + jnp.sum(nll + zl), cnt + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        chunk, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ts, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)
