"""Model bundles: config -> init / train-loss / prefill / decode + specs.

The single integration surface used by train/serve/launch code.  Every
entry point is shape-only-safe: `jax.eval_shape(bundle.init, key)` gives
the parameter ShapeDtypeStructs for the dry-run without allocating.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L
from repro.models import transformer as T
from repro.models import whisper as W

__all__ = ["ModelBundle", "make_bundle", "input_specs"]


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    rt: T.Runtime

    # -- params ------------------------------------------------------------

    def init(self, key):
        if self.cfg.is_encoder_decoder:
            return W.init_whisper(key, self.cfg)
        return T.init_lm(key, self.cfg)

    def param_specs(self):
        if self.cfg.is_encoder_decoder:
            return W.whisper_specs(self.cfg, self.rt.rules)
        return T.lm_specs(self.cfg, self.rt.rules)

    def param_shardings(self):
        mesh = self.rt.mesh
        return jax.tree.map(lambda s: NamedSharding(mesh, s),
                            self.param_specs(),
                            is_leaf=lambda v: isinstance(v, P))

    # -- train -------------------------------------------------------------

    def loss_fn(self, params, batch):
        if self.cfg.is_encoder_decoder:
            return W.whisper_train(params, batch, self.rt)
        extra = {k: v for k, v in batch.items() if k != "tokens"} or None
        return T.forward_train(params, batch["tokens"], self.rt, extra=extra)

    # -- serve -------------------------------------------------------------

    def init_caches(self, batch: int, max_len: int, enc_len: int = 1500):
        dt = jnp.dtype(self.cfg.compute_dtype)
        if self.cfg.is_encoder_decoder:
            return W.init_whisper_caches(self.cfg, batch, max_len, enc_len, dt)
        return T.init_caches(self.cfg, batch, max_len, dt)

    def cache_specs(self):
        if self.cfg.is_encoder_decoder:
            return W.whisper_cache_specs(self.cfg, self.rt.rules)
        return T.caches_specs(self.cfg, self.rt.rules)

    def prefill_fn(self, params, batch, caches):
        if self.cfg.is_encoder_decoder:
            return W.whisper_prefill(params, batch["frames"],
                                     batch["tokens"], caches, self.rt)
        return T.prefill(params, batch["tokens"], caches, self.rt)

    def decode_fn(self, params, token, pos, caches):
        if self.cfg.is_encoder_decoder:
            return W.whisper_decode_step(params, token, pos, caches, self.rt)
        return T.decode_step(params, token, pos, caches, self.rt)


def make_bundle(cfg, mesh: Optional[Mesh] = None) -> ModelBundle:
    rules = T.make_rules(cfg, mesh)
    return ModelBundle(cfg=cfg, rt=T.Runtime(cfg=cfg, mesh=mesh, rules=rules))


# =============================================================================
# input_specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell
# =============================================================================


def input_specs(cfg, shape, mesh: Optional[Mesh] = None):
    """Shape/dtype stand-ins for a cell's inputs (no device allocation).

    train  : {"tokens": (B, S)} (+ stub frontend embeddings)
    prefill: {"tokens": (B, S)} (+ frames for enc-dec)
    decode : (token (B, 1), pos scalar, caches(seq_len))
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype(jnp.int32)
    cdt = jnp.dtype(cfg.compute_dtype)
    batch_axes = tuple(a for a in ("pod", "data")
                       if mesh is not None and a in mesh.axis_names) or None
    if batch_axes is not None:
        nrows = int(np.prod([mesh.shape[a] for a in batch_axes]))
        if B % nrows != 0:      # e.g. long_500k B=1: DP rows idle by design
            batch_axes = None
    tok_sh = (NamedSharding(mesh, P(batch_axes, None))
              if mesh is not None else None)

    def sds(shp, dt, sh=None):
        if sh is None and mesh is not None:
            sh = NamedSharding(mesh, P())
        return jax.ShapeDtypeStruct(shp, dt, sharding=sh) if sh is not None \
            else jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            # enc:dec = 1:1 token budget split (DESIGN.md §6)
            se = sd = S // 2
            fr_sh = (NamedSharding(mesh, P(batch_axes, None, None))
                     if mesh is not None else None)
            return {"frames": sds((B, se, cfg.d_model), cdt, fr_sh),
                    "tokens": sds((B, sd), i32, tok_sh)}
        if cfg.frontend == "vision_stub":
            nv = cfg.n_vision_tokens
            fr_sh = (NamedSharding(mesh, P(batch_axes, None, None))
                     if mesh is not None else None)
            return {"tokens": sds((B, S - nv), i32, tok_sh),
                    "patch_embeds": sds((B, nv, cfg.d_model), cdt, fr_sh)}
        return {"tokens": sds((B, S), i32, tok_sh)}

    # decode: one new token against a seq_len-deep cache
    assert shape.kind == "decode"
    bundle = make_bundle(cfg, mesh)
    if batch_axes is None and bundle.rt.rules.batch is not None:
        rules = dataclasses.replace(bundle.rt.rules, batch=None)
        bundle = dataclasses.replace(
            bundle, rt=dataclasses.replace(bundle.rt, rules=rules))
    caches = jax.eval_shape(
        lambda: bundle.init_caches(B, S))
    if mesh is not None:
        specs = bundle.cache_specs()
        caches = jax.tree.map(
            lambda c, s: jax.ShapeDtypeStruct(
                c.shape, c.dtype, sharding=NamedSharding(mesh, s)),
            caches, specs)
    token = sds((B, 1), i32, tok_sh)
    pos = sds((), i32, NamedSharding(mesh, P()) if mesh is not None else None)
    return {"token": token, "pos": pos, "caches": caches}
