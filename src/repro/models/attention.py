"""Attention family: GQA (+bias/qk_norm/sliding-window), MLA, caches.

Memory discipline: training/prefill attention uses a blockwise
online-softmax implementation (`mea`) so the (S, T) score matrix is never
materialised -- at the assigned shapes (4k x 1M-token batches, 32k prefill)
a dense score tensor would dominate the HBM budget.  FLOPs are identical,
so the roofline accounting is unaffected.

Decode uses position-indexed caches:
  * dense GQA cache (B, S, Kv, Dh)
  * ring-buffer sliding-window cache (B, W, Kv, Dh)  [SWA / local attention]
  * MLA compressed cache (B, S, c_kv + rope) with absorbed-matmul scoring,
    so the per-token cache cost is (kv_lora + rope) elements instead of
    2 * H * Dh -- DeepSeek-V3's central serving trick.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import layers as L

__all__ = ["init_attention", "spec_attention", "attention_train",
           "attention_decode", "init_cache", "cache_specs", "mea",
           "dense_attention", "ulysses_attention"]


# =============================================================================
# blockwise attention core (online softmax; pure JAX flash-style)
# =============================================================================


def _mask_bias(qpos, kpos, window):
    """Additive mask: causal, optionally sliding-window.  qpos: (Sq,),
    kpos: (Sk,) -> (Sq, Sk) float32 {0, -inf}."""
    ok = kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= (qpos[:, None] - kpos[None, :]) < window
    ok &= kpos[None, :] >= 0          # invalid slots carry position -1
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def mea(q, k, v, qpos, kpos, *, window=None, q_block=512, kv_block=1024,
        causal=True):
    """Memory-efficient attention.  q: (B, Sq, H, D); k/v: (B, Sk, KvH, D).

    GQA: H must be a multiple of KvH.  Returns (B, Sq, H, Dv) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Sk, KvH, Dv = v.shape
    G = H // KvH
    scale = float(1.0 / np.sqrt(D))
    q_block = min(q_block, Sq)
    while Sq % q_block:
        q_block //= 2
    kv_block = min(kv_block, Sk)
    while Sk % kv_block:
        kv_block //= 2
    nq, nk = Sq // q_block, Sk // kv_block

    qg = q.reshape(B, Sq, KvH, G, D)

    def q_step(qi):
        qs = jax.lax.dynamic_slice_in_dim(qg, qi * q_block, q_block, 1)
        qp = jax.lax.dynamic_slice_in_dim(qpos, qi * q_block, q_block, 0)

        def kv_step(carry, ki):
            acc, m_run, l_run = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ki * kv_block, kv_block, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, ki * kv_block, kv_block, 1)
            kp = jax.lax.dynamic_slice_in_dim(kpos, ki * kv_block, kv_block, 0)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qs.astype(jnp.float32),
                           ks.astype(jnp.float32)) * scale
            bias = _mask_bias(qp, kp, window) if causal else \
                jnp.where(kp[None, :] >= 0, 0.0, -jnp.inf).astype(jnp.float32)
            s = s + bias[None, None, None]
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vs.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, KvH, G, q_block, Dv), jnp.float32)
        m0 = jnp.full((B, KvH, G, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KvH, G, q_block), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(nk))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1).reshape(B, q_block, H, Dv)

    # remat per q-block: the kv-scan VJP otherwise saves its carries for
    # every (q-block, kv-block) pair; recomputing per block keeps the
    # backward working set at one q-block's scan.
    q_step = jax.checkpoint(q_step)
    outs = jax.lax.map(q_step, jnp.arange(nq))            # (nq, B, qb, H, Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def ulysses_attention(q, k, v, qpos, kpos, mesh, axis="model", *, window=None,
                      causal=True):
    """Sequence<->head re-sharded attention (DeepSpeed-Ulysses pattern).

    This is the LM-side instance of the paper's two-domain structure
    (DESIGN.md §4): activations arrive SEQUENCE-sharded over ``axis``; one
    all_to_all moves them to the HEAD-sharded domain where the attention
    contraction is local; the reverse all_to_all brings outputs home --
    exactly the SHT's m-domain / ring-domain exchange.

    q/k/v: global (B, S, H, D) arrays, sequence(-dim-1)-sharded on ``axis``.
    H must be divisible by the axis size.  qpos/kpos are global (S,).
    """
    from jax.sharding import PartitionSpec as P

    def body(q_loc, k_loc, v_loc):
        # (B, S/n, H, D) -> (B, S, H/n, D): heads scatter, sequence gathers
        a2a = lambda t: jax.lax.all_to_all(t, axis, split_axis=2,
                                           concat_axis=1, tiled=True)
        qh, kh, vh = a2a(q_loc), a2a(k_loc), a2a(v_loc)
        out = mea(qh, kh, vh, qpos, kpos, window=window, causal=causal)
        return jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=2,
                                  tiled=True)

    spec = P(None, axis, None, None)
    return compat.shard_map(body, mesh=mesh, in_specs=(spec, spec, spec),
                            out_specs=spec)(q, k, v)


# =============================================================================
# GQA
# =============================================================================


def init_attention(key, cfg, dtype=jnp.bfloat16):
    if cfg.attention == "mla":
        return _init_mla(key, cfg, dtype)
    d, H, KvH = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim or d // H
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.init_dense(ks[0], d, H * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": L.init_dense(ks[1], d, KvH * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": L.init_dense(ks[2], d, KvH * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": L.init_dense(ks[3], H * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.init_norm(hd)
        p["k_norm"] = L.init_norm(hd)
    return p


def spec_attention(cfg, rules: L.ShardingRules, *, layer_stacked=True):
    if cfg.attention == "mla":
        return _spec_mla(cfg, rules, layer_stacked=layer_stacked)
    kw = dict(bias=cfg.qkv_bias, layer_stacked=layer_stacked)
    s = {
        "wq": L.spec_dense(rules, "d_model", "heads", **kw),
        "wk": L.spec_dense(rules, "d_model", "kv_heads", **kw),
        "wv": L.spec_dense(rules, "d_model", "kv_heads", **kw),
        "wo": L.spec_dense(rules, "heads", "d_model",
                           layer_stacked=layer_stacked),
    }
    if cfg.qk_norm:
        s["q_norm"] = L.spec_norm(rules, layer_stacked=layer_stacked)
        s["k_norm"] = L.spec_norm(rules, layer_stacked=layer_stacked)
    return s


def _qkv(p, x, cfg, positions, cdt):
    B, S, d = x.shape
    H, KvH = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim or d // H
    q = L.dense(p["wq"], x, cdt).reshape(B, S, H, hd)
    k = L.dense(p["wk"], x, cdt).reshape(B, S, KvH, hd)
    v = L.dense(p["wv"], x, cdt).reshape(B, S, KvH, hd)
    if cfg.qk_norm:
        q = L.rms_norm(p["q_norm"], q)
        k = L.rms_norm(p["k_norm"], k)
    q = L.apply_rope(q, positions, theta=cfg.rope_theta)
    k = L.apply_rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def dense_attention(q, k, v, qpos, kpos, *, window=None, causal=True):
    """Unblocked attention (materialised scores).  Used by the accounting
    lowerings (single-pass flop counting) and tiny smoke shapes."""
    B, Sq, H, D = q.shape
    KvH = v.shape[2]
    G = H // KvH
    scale = float(1.0 / np.sqrt(D))
    qg = q.reshape(B, Sq, KvH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        bias = _mask_bias(qpos, kpos, window)
    else:
        bias = jnp.where(kpos[None, :] >= 0, 0.0,
                         -jnp.inf).astype(jnp.float32)
    w = jax.nn.softmax(s + bias[None, None, None], axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", w, v.astype(jnp.float32))
    return jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, -1).astype(q.dtype)


def attention_train(p, x, positions, cfg, *, window=None, cdt=jnp.bfloat16,
                    cache=None, cache_pos0=None):
    """Causal self-attention for train/prefill.  Optionally fills a cache.

    Returns (y, cache') -- cache' is None when cache is None.
    """
    if cfg.attention == "mla":
        return _mla_train(p, x, positions, cfg, cdt=cdt, cache=cache)
    B, S, d = x.shape
    q, k, v = _qkv(p, x, cfg, positions, cdt)
    win = window if window is not None else cfg.sliding_window
    impl = mea if getattr(cfg, "attn_impl", "mea") == "mea" else dense_attention
    out = impl(q, k, v, positions[0] if positions.ndim > 1 else positions,
               positions[0] if positions.ndim > 1 else positions, window=win)
    y = L.dense(p["wo"], out.reshape(B, S, -1), cdt)
    new_cache = None
    if cache is not None:
        new_cache = _fill_cache(cache, k, v, positions, win)
    return y, new_cache


# -- caches ---------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Self-attention cache for one layer."""
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
            "pos": jnp.full((max_len,), -1, jnp.int32),
        }
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    W = cfg.sliding_window
    slots = min(max_len, W) if W else max_len
    return {
        "k": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, slots, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((slots,), -1, jnp.int32),
    }


def cache_specs(cfg, rules: L.ShardingRules):
    if cfg.attention == "mla":
        return {"ckv": P(rules.ax("batch"), None, None),
                "krope": P(rules.ax("batch"), None, None),
                "pos": P(None)}
    return {"k": P(rules.ax("batch"), None, rules.ax("kv_heads"), None),
            "v": P(rules.ax("batch"), None, rules.ax("kv_heads"), None),
            "pos": P(None)}


def _fill_cache(cache, k, v, positions, window):
    """Write a prefill chunk into the (possibly ring-buffer) cache."""
    pos = positions[0] if positions.ndim > 1 else positions    # (S,)
    slots = cache["k"].shape[1]
    idx = pos % slots
    ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
    cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
    cp = cache["pos"].at[idx].set(pos.astype(jnp.int32))
    return {"k": ck, "v": cv, "pos": cp}


def attention_decode(p, x, pos, cache, cfg, *, cdt=jnp.bfloat16):
    """One-token decode.  x: (B, 1, d); pos: scalar int32 (current position).

    Returns (y (B, 1, d), cache').
    """
    if cfg.attention == "mla":
        return _mla_decode(p, x, pos, cache, cfg, cdt=cdt)
    B = x.shape[0]
    H, KvH = cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim or cfg.d_model // cfg.n_heads
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions, cdt)
    slots = cache["k"].shape[1]
    slot = pos % slots
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    cp = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=0)
    win = cfg.sliding_window
    scale = float(1.0 / float(np.sqrt(hd)))
    qh = q.reshape(B, 1, KvH, H // KvH, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh.astype(jnp.float32),
                   ck.astype(jnp.float32)) * scale
    bias = _mask_bias(positions, cp, win)                      # (1, slots)
    s = s + bias[None, None, None]
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", w, cv.astype(jnp.float32))
    out = jnp.moveaxis(out, 3, 1).reshape(B, 1, H * hd).astype(cdt)
    y = L.dense(p["wo"], out, cdt)
    return y, {"k": ck, "v": cv, "pos": cp}


# =============================================================================
# MLA (DeepSeek-V3 style multi-head latent attention)
# =============================================================================


def _init_mla(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    qn, qr, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq_a": L.init_dense(ks[0], d, cfg.q_lora_rank, dtype=dtype),
        "q_norm": L.init_norm(cfg.q_lora_rank),
        "wq_b": L.init_dense(ks[1], cfg.q_lora_rank, H * (qn + qr), dtype=dtype),
        "wkv_a": L.init_dense(ks[2], d, cfg.kv_lora_rank + qr, dtype=dtype),
        "kv_norm": L.init_norm(cfg.kv_lora_rank),
        "wk_b": L.init_dense(ks[3], cfg.kv_lora_rank, H * qn, dtype=dtype),
        "wv_b": L.init_dense(ks[4], cfg.kv_lora_rank, H * vh, dtype=dtype),
        "wo": L.init_dense(ks[5], H * vh, d, dtype=dtype),
    }
    return p


def _spec_mla(cfg, rules, *, layer_stacked=True):
    kw = dict(layer_stacked=layer_stacked)
    return {
        "wq_a": L.spec_dense(rules, "d_model", None, **kw),
        "q_norm": L.spec_norm(rules, **kw),
        "wq_b": L.spec_dense(rules, None, "heads", **kw),
        "wkv_a": L.spec_dense(rules, "d_model", None, **kw),
        "kv_norm": L.spec_norm(rules, **kw),
        "wk_b": L.spec_dense(rules, None, "heads", **kw),
        "wv_b": L.spec_dense(rules, None, "heads", **kw),
        "wo": L.spec_dense(rules, "heads", "d_model", **kw),
    }


def _mla_qkv_expand(p, x, positions, cfg, cdt):
    """Expanded-KV MLA path (train/prefill)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    qn, qr, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = L.rms_norm(p["q_norm"], L.dense(p["wq_a"], x, cdt))
    q = L.dense(p["wq_b"], cq, cdt).reshape(B, S, H, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = L.apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kv = L.dense(p["wkv_a"], x, cdt)
    ckv = L.rms_norm(p["kv_norm"], kv[..., : cfg.kv_lora_rank])
    k_rope = kv[..., cfg.kv_lora_rank:].reshape(B, S, 1, qr)
    k_rope = L.apply_rope(k_rope, positions, theta=cfg.rope_theta)

    k_nope = L.dense(p["wk_b"], ckv, cdt).reshape(B, S, H, qn)
    v = L.dense(p["wv_b"], ckv, cdt).reshape(B, S, H, vh)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, qr))],
                        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    return q_full, k, v, ckv, k_rope


def _mla_train(p, x, positions, cfg, *, cdt, cache=None):
    B, S, _ = x.shape
    q, k, v, ckv, k_rope = _mla_qkv_expand(p, x, positions, cfg, cdt)
    pos1 = positions[0] if positions.ndim > 1 else positions
    impl = mea if getattr(cfg, "attn_impl", "mea") == "mea" else dense_attention
    out = impl(q, k, v, pos1, pos1, window=None)
    y = L.dense(p["wo"], out.reshape(B, S, -1), cdt)
    new_cache = None
    if cache is not None:
        idx = pos1 % cache["ckv"].shape[1]
        new_cache = {
            "ckv": cache["ckv"].at[:, idx].set(ckv.astype(cache["ckv"].dtype)),
            "krope": cache["krope"].at[:, idx].set(
                k_rope[:, :, 0].astype(cache["krope"].dtype)),
            "pos": cache["pos"].at[idx].set(pos1.astype(jnp.int32)),
        }
    return y, new_cache


def _mla_decode(p, x, pos, cache, cfg, *, cdt):
    """Absorbed-matmul decode: scores and values computed against the
    *compressed* cache; W_uk / W_uv are folded into the query/output sides.
    Per-token cache traffic: kv_lora + rope elements (vs 2*H*Dh dense)."""
    B = x.shape[0]
    H = cfg.n_heads
    qn, qr, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    cq = L.rms_norm(p["q_norm"], L.dense(p["wq_a"], x, cdt))
    q = L.dense(p["wq_b"], cq, cdt).reshape(B, 1, H, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = L.apply_rope(q_rope, positions, theta=cfg.rope_theta)

    kv = L.dense(p["wkv_a"], x, cdt)
    ckv_new = L.rms_norm(p["kv_norm"], kv[..., : cfg.kv_lora_rank])
    k_rope_new = kv[..., cfg.kv_lora_rank:].reshape(B, 1, 1, qr)
    k_rope_new = L.apply_rope(k_rope_new, positions, theta=cfg.rope_theta)

    slots = cache["ckv"].shape[1]
    slot = pos % slots
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), slot, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], k_rope_new[:, :, 0].astype(cache["krope"].dtype),
        slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=0)

    wk_b = p["wk_b"]["w"].astype(cdt).reshape(cfg.kv_lora_rank, H, qn)
    q_eff = jnp.einsum("bshd,chd->bshc", q_nope, wk_b)    # absorb W_uk
    s = jnp.einsum("bshc,btc->bhst", q_eff.astype(jnp.float32),
                   ckv.astype(jnp.float32))
    s = s + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s * float(1.0 / np.sqrt(qn + qr))
    bias = _mask_bias(positions, cpos, None)
    s = s + bias[None, None]
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhst,btc->bshc", w, ckv.astype(jnp.float32))
    wv_b = p["wv_b"]["w"].astype(cdt).reshape(cfg.kv_lora_rank, H, vh)
    out = jnp.einsum("bshc,chd->bshd", o_c.astype(cdt), wv_b)  # absorb W_uv
    y = L.dense(p["wo"], out.reshape(B, 1, H * vh), cdt)
    return y, {"ckv": ckv, "krope": krope, "pos": cpos}
