"""Pallas TPU kernels for the Legendre-recurrence hot spot (paper §4.2.2).

The paper's GPU algorithm assigns one *ring* per CUDA thread so every thread
executes the identical l-recurrence (SIMD-uniform), and recomputes beta_lm
instead of storing it.  The TPU translation (DESIGN.md §2):

  * rings live on the VPU lane/sublane dimensions (8x128 vectors instead of
    threads);
  * the l loop is the sequential inner `fori_loop`, with the (mantissa,
    scale) pair of the rescaled recurrence carried in VMEM scratch across
    l-panel grid steps;
  * beta is recomputed from l, m on the fly (2 mults + 1 rsqrt per step) --
    never materialised in HBM;
  * m and ring-blocks form the (sequential) Pallas grid; panels fully below
    the diagonal (l < m) are skipped, preserving the triangular work count;
  * the direct-transform (analysis) reduction that costs the paper its GPU
    performance (atomics / host-side reduction, Algorithm 5) is here an
    accumulation into the output block across sequential grid steps --
    race-free by construction because the TPU grid is sequential per core.

Two variants per direction:

  * ``vpu``  -- broadcast-FMA accumulation; the faithful analogue of the
    paper's scalar-per-thread inner loop.  Right for small K (few maps).
  * ``mxu``  -- P panels are materialised in VMEM (l on the sublane axis)
    and contracted against a (l, 2K) coefficient panel on the MXU.  This is
    the beyond-paper optimisation: the paper's Monte-Carlo workload
    transforms many maps with identical geometry, which becomes a matmul.

Inputs are pre-scaled seeds (pmm mantissa + scale) computed host-side in
float64; everything inside the kernels is float32.

All kernels are validated in interpret mode against repro.kernels.ref
(bit-matched algorithm) and against the float64 core engine in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "synth_vpu", "synth_mxu", "anal_vpu", "anal_mxu",
    "synth_vpu_packed", "synth_mxu_packed",
    "anal_vpu_packed", "anal_mxu_packed",
    "SCALE_BITS_F32",
]

SCALE_BITS_F32 = 64
_BIG = float(2.0 ** (SCALE_BITS_F32 // 2))        # 2^32
_INV_BIG2 = float(2.0 ** (-SCALE_BITS_F32))       # 2^-64
_BIG2 = float(2.0 ** SCALE_BITS_F32)              # 2^64

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


def _pad_rows(blk, rf):
    """Zero-pad a ring-shrunk operand block back to the full (..., 8, 128)
    VPU tile.  Interpret-mode input-block fetches are slow per byte, so
    operands whose rings fit one row block ship only their ``rf`` real
    128-lane rows; the padding rows (pure ring padding, zero by
    construction) are rebuilt here as cheap vector zeros."""
    if rf == 8:
        return blk
    pad = blk.shape[:-2] + (8 - rf, 128)
    return jnp.concatenate([blk, jnp.zeros(pad, blk.dtype)], axis=-2)


def _f32_step(l, m_f, x, pp, pc, sc, pmm, pms):
    """One scaled-recurrence step, float32, branch-free.

    l: traced scalar (current multipole); m_f: scalar f32 (this grid step's
    m); x, pp, pc, pmm: f32 tiles; sc, pms: i32 tiles.
    Returns (pp', pc', sc', value) with `value` the descaled P_{l,m}.
    """
    lf = l.astype(jnp.float32) if hasattr(l, "astype") else jnp.float32(l)
    # beta recomputed on the fly (paper's GPU choice): guard l<=m+1 lanes.
    lb = jnp.maximum(lf, m_f + 2.0)
    bl = jax.lax.rsqrt((lb * lb - m_f * m_f) / (4.0 * lb * lb - 1.0))
    lb1 = jnp.maximum(lf - 1.0, m_f + 1.0)
    bl1 = jax.lax.rsqrt((lb1 * lb1 - m_f * m_f) / (4.0 * lb1 * lb1 - 1.0))
    ratio = bl / bl1
    p_rec = bl * x * pc - ratio * pp
    p_first = jnp.sqrt(jnp.maximum(2.0 * m_f + 3.0, 0.0)) * x * pc

    is_seed = lf == m_f
    is_first = lf == m_f + 1.0
    before = lf < m_f
    new_c = jnp.where(before, 0.0,
            jnp.where(is_seed, pmm,
            jnp.where(is_first, p_first, p_rec)))
    new_p = jnp.where(before | is_seed, 0.0, pc)
    new_s = jnp.where(is_seed, pms, sc)

    grow = (jnp.abs(new_c) > _BIG) & (new_s < 0)
    new_c = jnp.where(grow, new_c * _INV_BIG2, new_c)
    new_p = jnp.where(grow, new_p * _INV_BIG2, new_p)
    new_s = jnp.where(grow, new_s + 1, new_s)
    shrink = (jnp.abs(new_c) < 1.0 / _BIG) & (jnp.abs(new_p) < 1.0 / _BIG) \
        & ~before & ~is_seed
    new_c2 = jnp.where(shrink, new_c * _BIG2, new_c)
    new_p2 = jnp.where(shrink, new_p * _BIG2, new_p)
    new_s2 = jnp.where(shrink, new_s - 1, new_s)

    value = jnp.where((new_s2 == 0) & ~before, new_c2, 0.0)
    return new_p2, new_c2, new_s2, value


def _f32_step_spin(l, m_f, mp_f, x, pp, pc, sc, pmm, pms):
    """One step of the generalised (Wigner-d) scaled recurrence, float32.

    The spin-weighted lambda^{(m')} functions satisfy
    lam_l = (a_l x + b_l) lam_{l-1} - c_l lam_{l-2} seeded at
    l0 = max(m, |m'|) (see core/legendre.py); coefficients are recomputed
    on the fly like the scalar beta.  ``mp_f`` is this row's m' (scalar
    f32); everything else as in `_f32_step`.
    """
    lf = l.astype(jnp.float32) if hasattr(l, "astype") else jnp.float32(l)
    l0 = jnp.maximum(m_f, jnp.abs(mp_f))
    ls = jnp.maximum(lf, l0 + 1.0)
    d2 = jnp.maximum((ls * ls - m_f * m_f) * (ls * ls - mp_f * mp_f), 1e-30)
    lm1 = ls - 1.0
    d2m1 = jnp.maximum((lm1 * lm1 - m_f * m_f) * (lm1 * lm1 - mp_f * mp_f),
                       0.0)
    s2l = jnp.sqrt(4.0 * ls * ls - 1.0)
    inv_d = jax.lax.rsqrt(d2)
    inv_lm1 = 1.0 / jnp.maximum(lm1, 1.0)
    a = ls * s2l * inv_d
    b = -(m_f * mp_f) * s2l * inv_d * inv_lm1
    c = (jnp.sqrt((2.0 * ls + 1.0) / jnp.maximum(2.0 * ls - 3.0, 1.0))
         * ls * jnp.sqrt(d2m1) * inv_d * inv_lm1)

    p_rec = (a * x + b) * pc - c * pp
    is_seed = lf == l0
    before = lf < l0
    new_c = jnp.where(before, 0.0, jnp.where(is_seed, pmm, p_rec))
    new_p = jnp.where(before | is_seed, 0.0, pc)
    new_s = jnp.where(is_seed, pms, sc)

    grow = (jnp.abs(new_c) > _BIG) & (new_s < 0)
    new_c = jnp.where(grow, new_c * _INV_BIG2, new_c)
    new_p = jnp.where(grow, new_p * _INV_BIG2, new_p)
    new_s = jnp.where(grow, new_s + 1, new_s)
    shrink = (jnp.abs(new_c) < 1.0 / _BIG) & (jnp.abs(new_p) < 1.0 / _BIG) \
        & ~before & ~is_seed
    new_c2 = jnp.where(shrink, new_c * _BIG2, new_c)
    new_p2 = jnp.where(shrink, new_p * _BIG2, new_p)
    new_s2 = jnp.where(shrink, new_s - 1, new_s)

    value = jnp.where((new_s2 == 0) & ~before, new_c2, 0.0)
    return new_p2, new_c2, new_s2, value


def _step(spin, l, m_f, mp_f, x, pp, pc, sc, pmm, pms):
    """Static dispatch between the scalar and spin recurrence steps."""
    if spin:
        return _f32_step_spin(l, m_f, mp_f, x, pp, pc, sc, pmm, pms)
    return _f32_step(l, m_f, x, pp, pc, sc, pmm, pms)


# =============================================================================
# Synthesis (inverse transform stage 1): Delta_m(r) = sum_l a_lm P_lm(r)
# =============================================================================


def _synth_vpu_kernel(m_vals_ref, mp_vals_ref, x_ref, pmm_ref, pms_ref,
                      a_ref, out_ref, pp_ref, pc_ref, sc_ref, *, lp_size,
                      n_k2, fold, spin):
    mi = pl.program_id(0)
    lp = pl.program_id(2)
    m = m_vals_ref[mi]
    m_f = m.astype(jnp.float32)
    mp_f = mp_vals_ref[mi].astype(jnp.float32)
    l0 = lp * lp_size

    @pl.when(lp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(l0 + lp_size > m)   # skip panels fully below the diagonal
    def _work():
        x = x_ref[...]                       # (8, 128)
        pmm = pmm_ref[0]                     # (8, 128)
        pms = pms_ref[0]
        acc = out_ref[0]                     # (P?, 2K, 8, 128) P=1|2 (fold)

        def body(j, carry):
            acc, pp, pc, sc = carry
            l = l0 + j
            pp, pc, sc, val = _step(spin, l, m_f, mp_f, x, pp, pc, sc,
                                    pmm, pms)
            av = a_ref[0, j, :]              # (2K,)
            contrib = av[:, None, None] * val[None, :, :]   # (2K, 8, 128)
            if fold:
                par = (l + m) % 2            # 0 even, 1 odd
                sel = (jnp.arange(2, dtype=jnp.int32) == par)
                acc = acc + jnp.where(sel[:, None, None, None],
                                      contrib[None], 0.0)
            else:
                acc = acc + contrib[None]
            return acc, pp, pc, sc

        acc, pp, pc, sc = jax.lax.fori_loop(
            0, lp_size, body,
            (acc, pp_ref[...], pc_ref[...], sc_ref[...]))
        out_ref[0] = acc
        pp_ref[...] = pp
        pc_ref[...] = pc
        sc_ref[...] = sc


def synth_vpu(a, m_vals, x2d, pmm, pms, *, l_max, fold=False, mp_vals=None,
              lp_size=128, interpret=True):
    """VPU synthesis kernel.

    a      : (Mp, L1p, 2K) f32, L1p a multiple of lp_size, rows l<m zero
    m_vals : (Mp,) i32 (plan m per slot; -1 padding rows never seed)
    mp_vals: (Mp,) i32 Wigner m' per row (None -> scalar P_lm path)
    x2d    : (R1, 128) f32 cos(theta), R1 a multiple of 8
    pmm    : (Mp, R1, 128) f32 seed mantissas;  pms likewise i32 scales
    returns: (Mp, P, 2K, R1, 128) f32 with P = 2 (even, odd) if fold else 1
    """
    Mp, L1p, K2 = a.shape
    R1 = x2d.shape[0]
    assert L1p % lp_size == 0 and R1 % 8 == 0
    spin = mp_vals is not None
    assert not (spin and fold), "fold is not supported on the spin path"
    mp = jnp.zeros(Mp, jnp.int32) if mp_vals is None \
        else jnp.asarray(mp_vals, jnp.int32)
    n_par = 2 if fold else 1
    grid = (Mp, R1 // 8, L1p // lp_size)
    kernel = functools.partial(_synth_vpu_kernel, lp_size=lp_size,
                               n_k2=K2, fold=fold, spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda m, rb, lp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 8, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, 8, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, lp_size, K2), lambda m, rb, lp, *_refs: (m, lp, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_par, K2, 8, 128),
                                   lambda m, rb, lp, *_refs: (m, 0, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, n_par, K2, R1, 128), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(m_vals, mp, x2d, pmm, pms, a)


def _synth_mxu_kernel(m_vals_ref, mp_vals_ref, x_ref, pmm_ref, pms_ref,
                      a_ref, out_ref, pp_ref, pc_ref, sc_ref, panel_ref, *,
                      lp_size, fold, spin):
    mi = pl.program_id(0)
    lp = pl.program_id(2)
    m = m_vals_ref[mi]
    m_f = m.astype(jnp.float32)
    mp_f = mp_vals_ref[mi].astype(jnp.float32)
    l0 = lp * lp_size

    @pl.when(lp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(l0 + lp_size > m)
    def _work():
        x = x_ref[...]                        # (1, 128)
        pmm = pmm_ref[0]                      # (1, 128)
        pms = pms_ref[0]

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _step(spin, l0 + j, m_f, mp_f, x, pp, pc, sc,
                                    pmm, pms)
            panel_ref[pl.ds(j, 1), :] = val   # P panel row (l on sublanes)
            return pp, pc, sc

        pp, pc, sc = jax.lax.fori_loop(
            0, lp_size, gen, (pp_ref[...], pc_ref[...], sc_ref[...]))
        pp_ref[...] = pp
        pc_ref[...] = pc
        sc_ref[...] = sc

        panel = panel_ref[...]                # (LP, 128)
        a_blk = a_ref[0]                      # (LP, 2K)
        dims = (((0,), (0,)), ((), ()))       # contract over l
        if fold:
            ls = l0 + jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
            even = ((ls + m) % 2) == 0
            a_e = jnp.where(even, a_blk, 0.0)
            a_o = jnp.where(even, 0.0, a_blk)
            ce = jax.lax.dot_general(panel, a_e, dims,
                                     preferred_element_type=jnp.float32)
            co = jax.lax.dot_general(panel, a_o, dims,
                                     preferred_element_type=jnp.float32)
            out_ref[0, 0] += ce               # (128, 2K)
            out_ref[0, 1] += co
        else:
            c = jax.lax.dot_general(panel, a_blk, dims,
                                    preferred_element_type=jnp.float32)
            out_ref[0, 0] += c


def synth_mxu(a, m_vals, x2d, pmm, pms, *, l_max, fold=False, mp_vals=None,
              lp_size=128, interpret=True):
    """MXU synthesis kernel (multi-map panel matmul).

    Layouts as synth_vpu except rings advance 128 at a time and the output
    is (Mp, P, R1*?, ...) -- concretely (Mp, P, R, 2K) with R = R1 * 128.
    """
    Mp, L1p, K2 = a.shape
    R1 = x2d.shape[0]
    R = R1 * 128
    assert L1p % lp_size == 0
    spin = mp_vals is not None
    assert not (spin and fold), "fold is not supported on the spin path"
    mp = jnp.zeros(Mp, jnp.int32) if mp_vals is None \
        else jnp.asarray(mp_vals, jnp.int32)
    n_par = 2 if fold else 1
    grid = (Mp, R1, L1p // lp_size)
    x_flat = x2d.reshape(R1, 128)
    pmm_f = pmm.reshape(Mp, R1, 128)
    pms_f = pms.reshape(Mp, R1, 128)
    kernel = functools.partial(_synth_mxu_kernel, lp_size=lp_size, fold=fold,
                               spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda m, rb, lp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 1, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, 1, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, lp_size, K2), lambda m, rb, lp, *_refs: (m, lp, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_par, 128, K2),
                                   lambda m, rb, lp, *_refs: (m, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, n_par, R, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(m_vals, mp, x_flat, pmm_f, pms_f, a)


# =============================================================================
# Analysis (direct transform stage): a_lm = sum_r Delta_m(r) P_lm(r)
# =============================================================================


def _anal_vpu_kernel(m_vals_ref, mp_vals_ref, x_ref, pmm_ref, pms_ref,
                     dw_ref, out_ref, pp_ref, pc_ref, sc_ref, acc_ref, *,
                     lp_size, fold, spin):
    """Analysis VPU kernel.  A separate VMEM accumulator (acc_ref) holds the
    current panel's rows; it is added into out_ref at the end of the grid
    step so the out block accumulates across ring blocks (@rb==0 init)."""
    mi = pl.program_id(0)
    rb = pl.program_id(1)
    lp = pl.program_id(2)
    m = m_vals_ref[mi]
    m_f = m.astype(jnp.float32)
    mp_f = mp_vals_ref[mi].astype(jnp.float32)
    l0 = lp * lp_size

    @pl.when(lp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(l0 + lp_size > m)
    def _work():
        x = x_ref[...]
        pmm = pmm_ref[0]
        pms = pms_ref[0]
        dw = dw_ref[0]                          # (P, 2K, 8, 128)
        acc_ref[...] = jnp.zeros_like(acc_ref)  # (LP, 2K)

        def body(j, carry):
            pp, pc, sc = carry
            l = l0 + j
            pp, pc, sc, val = _step(spin, l, m_f, mp_f, x, pp, pc, sc,
                                    pmm, pms)
            if fold:
                par = (l + m) % 2
                sel = (jnp.arange(2, dtype=jnp.int32) == par)
                d = jnp.sum(jnp.where(sel[:, None, None, None], dw, 0.0),
                            axis=0)
            else:
                d = dw[0]
            row = jnp.sum(d * val[None, :, :], axis=(1, 2))   # (2K,)
            acc_ref[pl.ds(j, 1), :] = row[None, :]
            return pp, pc, sc

        pp, pc, sc = jax.lax.fori_loop(
            0, lp_size, body, (pp_ref[...], pc_ref[...], sc_ref[...]))
        out_ref[0] += acc_ref[...]
        pp_ref[...] = pp
        pc_ref[...] = pc
        sc_ref[...] = sc


def anal_vpu(dw, m_vals, x2d, pmm, pms, *, l_max, l1p, fold=False,
             mp_vals=None, lp_size=128, interpret=True):
    """VPU analysis kernel.

    dw     : (Mp, P, 2K, R1, 128) weighted Delta (P = 2 (e,o) if fold else 1)
    returns: (Mp, L1p, 2K) f32
    """
    Mp, n_par, K2 = dw.shape[0], dw.shape[1], dw.shape[2]
    R1 = dw.shape[3]
    assert l1p % lp_size == 0 and R1 % 8 == 0
    spin = mp_vals is not None
    assert not (spin and fold), "fold is not supported on the spin path"
    mp = jnp.zeros(Mp, jnp.int32) if mp_vals is None \
        else jnp.asarray(mp_vals, jnp.int32)
    grid = (Mp, R1 // 8, l1p // lp_size)
    kernel = functools.partial(_anal_vpu_kernel, lp_size=lp_size,
                               fold=fold, spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda m, rb, lp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 8, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, 8, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, n_par, K2, 8, 128),
                             lambda m, rb, lp, *_refs: (m, 0, 0, rb, 0)),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda m, rb, lp, *_refs: (m, lp, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, K2), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, l1p, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(m_vals, mp, x2d, pmm, pms, dw)


# =============================================================================
# Packed (triangular m-pair) kernels.
#
# The plain kernels above launch a dense rectangular (Mp, L1p/lp_size)
# grid and mask sub-diagonal panels with `pl.when` -- ~2x wasted grid
# steps at m_max = l_max.  The packed kernels run the min-max paired grid
# built by `kernels.pack.build_layout`: each *slot* fuses two m rows whose
# concatenated l-ranges have near-constant total length, streamed
# back-to-back through (n_sp) full panels with NO `pl.when` diagonal test.
# Five per-slot scalar-prefetch maps (m/m' per segment + the intra-slot
# seam step `seed`) tell every grid step which (m, l) window it serves;
# the (pp, pc, sc) carry re-seeds itself at the seam because the step
# functions seed whenever l == l0, and the packed schedule lands the
# seam step exactly there.
#
# The slot grid dimension is marked "parallel": slots touch disjoint
# output blocks and their carry chains are self-contained (re-initialised
# at panel 0), so Mosaic may partition slots across TensorCores.
# =============================================================================


def _packed_row_masks(base, jsw, m0, m1, mp0, mp1, lp_size, n_par, fold):
    """Per-panel-row (lp_size, 1) bool masks selecting each fused output
    component q = segment * n_par + parity (the MXU kernels' row splits)."""
    iot = jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
    g_row = base + iot
    hi_row = g_row >= jsw
    masks = []
    for q in range(2 * n_par):
        seg = q // n_par
        mask = hi_row if seg == 1 else ~hi_row
        if fold:
            l00 = jnp.maximum(m0, jnp.abs(mp0))
            l01 = jnp.maximum(m1, jnp.abs(mp1))
            l_row = jnp.where(hi_row, l01 + g_row - jsw, l00 + g_row)
            m_row = jnp.where(hi_row, m1, m0)
            even = ((l_row + m_row) % 2) == 0
            mask = mask & (even if q % n_par == 0 else ~even)
        masks.append(mask)
    return masks


def _synth_vpu_packed_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                             x_ref, pmm_ref, pms_ref, a_ref, out_ref,
                             pp_ref, pc_ref, sc_ref, *, lp_size, n_par,
                             fold, spin):
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    x = x_ref[...]                           # (8, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))
    # Split the panel at the intra-slot seam: steps below j0 serve segment
    # 0, steps at/after j0 serve segment 1.  Each half runs a select-free
    # body (constant m / seed operands, static output slot) instead of the
    # per-step `where` chains over both fused rows -- those selects were
    # eating the packed grid-step win on analysis.  The (pp, pc, sc) carry
    # still re-seeds itself at the seam because segment 1's first step
    # lands exactly on l == l01 (duplicate slots have jsw == S, so their
    # segment-1 loop is empty).
    j0 = jnp.clip(jsw - base, 0, lp_size)

    def seg_body(seg, m, mp_v, l_base, pmm, pms):
        m_f = m.astype(jnp.float32)
        mp_f = mp_v.astype(jnp.float32)
        lo = seg * n_par

        def body(j, carry):
            acc, pp, pc, sc = carry
            l = l_base + j
            pp, pc, sc, val = _step(spin, l, m_f, mp_f, x, pp, pc, sc,
                                    pmm, pms)
            av = a_ref[0, j, :]              # (2K,)
            contrib = av[:, None, None] * val[None, :, :]   # (2K, 8, 128)
            if fold:
                par = (l + m) % 2
                sel = (jnp.arange(n_par, dtype=jnp.int32) == par)
                upd = jnp.where(sel[:, None, None, None], contrib[None], 0.0)
            else:
                upd = contrib[None]
            acc = acc.at[lo:lo + n_par].add(upd)
            return acc, pp, pc, sc

        return body

    carry = (out_ref[0], pp_ref[...], pc_ref[...], sc_ref[...])
    carry = jax.lax.fori_loop(
        0, j0, seg_body(0, m0, mp0, l00 + base, pmm0, pms0), carry)
    acc, pp, pc, sc = jax.lax.fori_loop(
        j0, lp_size, seg_body(1, m1, mp1, l01 + base - jsw, pmm1, pms1),
        carry)
    out_ref[0] = acc
    pp_ref[...] = pp
    pc_ref[...] = pc
    sc_ref[...] = sc


def synth_vpu_packed(a_pk, maps, x2d, pmm_pk, pms_pk, *, l_max, fold=False,
                     spin=False, lp_size=128, interpret=True):
    """VPU synthesis on the packed (slot, panel) grid.

    a_pk   : (n_slots, S, 2K) f32 packed coefficient streams
    maps   : (m0, m1, mp0, mp1, seed) i32 per-slot scalar-prefetch arrays
    x2d    : (R1, 128) f32;  pmm_pk/pms_pk: (n_slots, 2, R1, 128)
    returns: (n_slots, Q, 2K, R1, 128) f32, Q = 2 segments x (2 if fold)
    """
    n_slots, S, K2 = a_pk.shape
    R1 = x2d.shape[0]
    assert S % lp_size == 0 and R1 % 8 == 0
    n_par = 2 if fold else 1
    assert not (spin and fold), "fold is not supported on the spin path"
    n_q = 2 * n_par
    grid = (n_slots, R1 // 8, S // lp_size)
    kernel = functools.partial(_synth_vpu_packed_kernel, lp_size=lp_size,
                               n_par=n_par, fold=fold, spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, lp_size, K2),
                             lambda s, rb, sp, *_refs: (s, sp, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_q, K2, 8, 128),
                                   lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, n_q, K2, R1, 128),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, a_pk)


def _synth_mxu_packed_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                             x_ref, pmm_ref, pms_ref, a_ref, out_ref,
                             pp_ref, pc_ref, sc_ref, panel_ref, *, lp_size,
                             n_par, fold, spin):
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    x = x_ref[...]                           # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))
    j0 = jnp.clip(jsw - base, 0, lp_size)    # seam split (see VPU kernel)

    def seg_gen(m, mp_v, l_base, pmm, pms):
        m_f = m.astype(jnp.float32)
        mp_f = mp_v.astype(jnp.float32)

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _step(spin, l_base + j, m_f, mp_f, x,
                                    pp, pc, sc, pmm, pms)
            panel_ref[pl.ds(j, 1), :] = val
            return pp, pc, sc

        return gen

    carry = (pp_ref[...], pc_ref[...], sc_ref[...])
    carry = jax.lax.fori_loop(
        0, j0, seg_gen(m0, mp0, l00 + base, pmm0, pms0), carry)
    pp, pc, sc = jax.lax.fori_loop(
        j0, lp_size, seg_gen(m1, mp1, l01 + base - jsw, pmm1, pms1), carry)
    pp_ref[...] = pp
    pc_ref[...] = pc
    sc_ref[...] = sc

    panel = panel_ref[...]                   # (LP, 128)
    a_blk = a_ref[0]                         # (LP, 2K)
    dims = (((0,), (0,)), ((), ()))          # contract over the l stream
    masks = _packed_row_masks(base, jsw, m0, m1, mp0, mp1, lp_size, n_par,
                              fold)
    for q, mask in enumerate(masks):
        a_q = jnp.where(mask, a_blk, 0.0)
        c = jax.lax.dot_general(panel, a_q, dims,
                                preferred_element_type=jnp.float32)
        out_ref[0, q] += c                   # (128, 2K)


def synth_mxu_packed(a_pk, maps, x2d, pmm_pk, pms_pk, *, l_max, fold=False,
                     spin=False, lp_size=128, interpret=True):
    """MXU synthesis on the packed grid (multi-map panel matmul).

    Layouts as :func:`synth_vpu_packed` except rings advance 128 at a
    time; returns (n_slots, Q, R, 2K) with R = R1 * 128.
    """
    n_slots, S, K2 = a_pk.shape
    R1 = x2d.shape[0]
    R = R1 * 128
    assert S % lp_size == 0
    n_par = 2 if fold else 1
    assert not (spin and fold), "fold is not supported on the spin path"
    n_q = 2 * n_par
    grid = (n_slots, R1, S // lp_size)
    kernel = functools.partial(_synth_mxu_packed_kernel, lp_size=lp_size,
                               n_par=n_par, fold=fold, spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, lp_size, K2),
                             lambda s, rb, sp, *_refs: (s, sp, 0)),
            ],
            out_specs=pl.BlockSpec((1, n_q, 128, K2),
                                   lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, n_q, R, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
      pms_pk.reshape(n_slots, 2, R1, 128), a_pk)


def _anal_vpu_packed_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                            x_ref, pmm_ref, pms_ref, dw_ref, out_ref,
                            pp_ref, pc_ref, sc_ref, acc_ref, *, lp_size,
                            n_par, fold, spin, rf, l_max):
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    pmm = _pad_rows(pmm_ref[0], rf)          # (2, 8, 128)
    pms = _pad_rows(pms_ref[0], rf)
    dw = _pad_rows(dw_ref[0], rf)            # (Q, 2K, 8, 128)
    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))

    # ONE static-bound loop with a branch-free where-selected seam (the
    # ref oracle's schedule): a pair of dynamic-bound loops split at the
    # seam lowers to while_loops whose per-step overhead roughly doubles
    # the panel cost vs the plain kernel's scan; the per-step selects are
    # a handful of (8, 128) ops and _step reseeds itself at l == l0.
    def body(j, carry):
        pp, pc, sc = carry
        g = base + j
        hi = g >= jsw
        m = jnp.where(hi, m1, m0)
        mp_v = jnp.where(hi, mp1, mp0)
        l = jnp.where(hi, l01 + g - jsw, l00 + g)
        pmm_s = jnp.where(hi, pmm[1], pmm[0])
        pms_s = jnp.where(hi, pms[1], pms[0])
        pp, pc, sc, val = _step(spin, l, m.astype(jnp.float32),
                                mp_v.astype(jnp.float32), x, pp, pc, sc,
                                pmm_s, pms_s)
        # positions past the real stream (l > l_max) are padding the host
        # unpack discards; zero them so the packed rows match the oracle
        val = jnp.where(l <= l_max, val, 0.0)
        if fold:
            q = hi.astype(jnp.int32) * n_par + (l + m) % 2
            sel = (jnp.arange(2 * n_par, dtype=jnp.int32) == q)
            d = jnp.sum(jnp.where(sel[:, None, None, None], dw, 0.0),
                        axis=0)
        else:
            d = jnp.where(hi, dw[1], dw[0])
        row = jnp.sum(d * val[None, :, :], axis=(1, 2))   # (2K,)
        acc_ref[pl.ds(j, 1), :] = row[None, :]
        return pp, pc, sc

    pp, pc, sc = jax.lax.fori_loop(
        0, lp_size, body, (pp_ref[...], pc_ref[...], sc_ref[...]))
    out_ref[0] += acc_ref[...]
    pp_ref[...] = pp
    pc_ref[...] = pc
    sc_ref[...] = sc


def anal_vpu_packed(dw_pk, maps, x2d, pmm_pk, pms_pk, *, l_max, s_len,
                    fold=False, spin=False, lp_size=128, interpret=True):
    """VPU analysis on the packed grid.

    dw_pk  : (n_slots, Q, 2K, Rw, 128) weighted Delta per fused component.
             ``Rw`` is either the full ``R1`` row count of ``x2d``, or --
             when the ring axis fits one 8-row grid block (R1 == 8) -- the
             ring-shrunk ``ceil(R/128)`` real rows; the kernel rebuilds the
             zero padding rows in-register (`_pad_rows`), so the slow
             interpret-mode input fetch only ships real data.  The
             ``pmm_pk``/``pms_pk`` seed tables (n_slots, 2, Rw, 128) shrink
             with it (their padding entries are zero by construction).
    s_len  : packed l-stream length per slot (layout.S)
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    """
    n_slots, n_q, K2, n_rows = dw_pk.shape[:4]
    R1 = x2d.shape[0]
    rf = n_rows if (R1 == 8 and n_rows < 8) else 8
    n_par = 2 if fold else 1
    assert n_q == 2 * n_par and R1 % 8 == 0
    assert n_rows == (rf if rf < 8 else R1), (n_rows, R1, rf)
    assert pmm_pk.shape[2] == pms_pk.shape[2] == n_rows, \
        (pmm_pk.shape, n_rows)
    assert not (spin and fold), "fold is not supported on the spin path"
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1 // 8, S // lp_size)
    kernel = functools.partial(_anal_vpu_packed_kernel, lp_size=lp_size,
                               n_par=n_par, fold=fold, spin=spin, rf=rf,
                               l_max=l_max)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, rf, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, rf, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, n_q, K2, rf, 128),
                             lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, K2), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, dw_pk)


def _anal_mxu_packed_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                            x_ref, pmm_ref, pms_ref, dw_ref, out_ref,
                            pp_ref, pc_ref, sc_ref, panel_ref, *, lp_size,
                            n_par, fold, spin):
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                           # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))
    j0 = jnp.clip(jsw - base, 0, lp_size)    # seam split (see VPU kernel)

    def seg_gen(m, mp_v, l_base, pmm, pms):
        m_f = m.astype(jnp.float32)
        mp_f = mp_v.astype(jnp.float32)

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _step(spin, l_base + j, m_f, mp_f, x,
                                    pp, pc, sc, pmm, pms)
            panel_ref[pl.ds(j, 1), :] = val
            return pp, pc, sc

        return gen

    carry = (pp_ref[...], pc_ref[...], sc_ref[...])
    carry = jax.lax.fori_loop(
        0, j0, seg_gen(m0, mp0, l00 + base, pmm0, pms0), carry)
    pp, pc, sc = jax.lax.fori_loop(
        j0, lp_size, seg_gen(m1, mp1, l01 + base - jsw, pmm1, pms1), carry)
    pp_ref[...] = pp
    pc_ref[...] = pc
    sc_ref[...] = sc

    panel = panel_ref[...]                   # (LP, 128)
    dims = (((1,), (0,)), ((), ()))          # contract over rings(128)
    masks = _packed_row_masks(base, jsw, m0, m1, mp0, mp1, lp_size, n_par,
                              fold)
    acc = jnp.zeros_like(out_ref[0])
    for q, mask in enumerate(masks):
        c = jax.lax.dot_general(panel, dw_ref[0, q], dims,
                                preferred_element_type=jnp.float32)
        acc = acc + jnp.where(mask, c, 0.0)  # (LP, 2K)
    out_ref[0] += acc


def anal_mxu_packed(dw_pk, maps, x2d, pmm_pk, pms_pk, *, l_max, s_len,
                    fold=False, spin=False, lp_size=128, interpret=True):
    """MXU analysis on the packed grid.

    dw_pk  : (n_slots, Q, R, 2K) weighted Delta (ring-major), R = R1 * 128
    s_len  : packed l-stream length per slot (layout.S)
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    """
    n_slots, n_q, R, K2 = dw_pk.shape
    R1 = R // 128
    n_par = 2 if fold else 1
    assert n_q == 2 * n_par and R % 128 == 0
    assert not (spin and fold), "fold is not supported on the spin path"
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1, S // lp_size)
    kernel = functools.partial(_anal_mxu_packed_kernel, lp_size=lp_size,
                               n_par=n_par, fold=fold, spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, n_q, 128, K2),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
      pms_pk.reshape(n_slots, 2, R1, 128), dw_pk)


def _anal_mxu_kernel(m_vals_ref, mp_vals_ref, x_ref, pmm_ref, pms_ref,
                     dw_ref, out_ref, pp_ref, pc_ref, sc_ref, panel_ref, *,
                     lp_size, fold, spin):
    mi = pl.program_id(0)
    rb = pl.program_id(1)
    lp = pl.program_id(2)
    m = m_vals_ref[mi]
    m_f = m.astype(jnp.float32)
    mp_f = mp_vals_ref[mi].astype(jnp.float32)
    l0 = lp * lp_size

    @pl.when(lp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(l0 + lp_size > m)
    def _work():
        x = x_ref[...]                          # (1, 128)
        pmm = pmm_ref[0]
        pms = pms_ref[0]

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _step(spin, l0 + j, m_f, mp_f, x, pp, pc, sc,
                                    pmm, pms)
            panel_ref[pl.ds(j, 1), :] = val
            return pp, pc, sc

        pp, pc, sc = jax.lax.fori_loop(
            0, lp_size, gen, (pp_ref[...], pc_ref[...], sc_ref[...]))
        pp_ref[...] = pp
        pc_ref[...] = pc
        sc_ref[...] = sc

        panel = panel_ref[...]                  # (LP, 128)
        dims = (((1,), (0,)), ((), ()))         # contract over rings(128)
        if fold:
            ls = l0 + jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
            even = ((ls + m) % 2) == 0
            ce = jax.lax.dot_general(panel, dw_ref[0, 0], dims,
                                     preferred_element_type=jnp.float32)
            co = jax.lax.dot_general(panel, dw_ref[0, 1], dims,
                                     preferred_element_type=jnp.float32)
            out_ref[0] += jnp.where(even, ce, co)
        else:
            c = jax.lax.dot_general(panel, dw_ref[0, 0], dims,
                                    preferred_element_type=jnp.float32)
            out_ref[0] += c


def anal_mxu(dw, m_vals, x2d, pmm, pms, *, l_max, l1p, fold=False,
             mp_vals=None, lp_size=128, interpret=True):
    """MXU analysis kernel.

    dw     : (Mp, P, R, 2K) weighted Delta (ring-major), R = R1 * 128
    returns: (Mp, L1p, 2K) f32
    """
    Mp, n_par, R, K2 = dw.shape
    R1 = R // 128
    assert l1p % lp_size == 0 and R % 128 == 0
    spin = mp_vals is not None
    assert not (spin and fold), "fold is not supported on the spin path"
    mp = jnp.zeros(Mp, jnp.int32) if mp_vals is None \
        else jnp.asarray(mp_vals, jnp.int32)
    grid = (Mp, R1, l1p // lp_size)
    kernel = functools.partial(_anal_mxu_kernel, lp_size=lp_size, fold=fold,
                               spin=spin)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda m, rb, lp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 1, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, 1, 128), lambda m, rb, lp, *_refs: (m, rb, 0)),
                pl.BlockSpec((1, n_par, 128, K2),
                             lambda m, rb, lp, *_refs: (m, 0, rb, 0)),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda m, rb, lp, *_refs: (m, lp, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, l1p, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
    )(m_vals, mp, x2d, pmm, pms, dw)
