"""Pallas TPU kernel layer for the Legendre-recurrence hot spot.

``legendre_pallas`` holds the kernels (VPU broadcast-FMA and MXU panel
matmul variants, paper §4.2.2 translated to TPU), ``ops`` the jit'd
padding/layout wrappers and the ``stage1="pallas"`` adapters used by
``DistSHT``, and ``ref`` the bit-matched jnp oracles the kernels are
validated against.

Callers normally do not import this package directly: ``repro.make_plan``
dispatches into it when a plan selects a ``pallas_*`` backend.  The import
is kept lazy/fallible so builds without Pallas can still use the jnp and
dist backends (``PALLAS_AVAILABLE`` reports the outcome).
"""

try:
    from repro.kernels import ops  # noqa: F401
    from repro.kernels import pack  # noqa: F401
    from repro.kernels.ops import (  # noqa: F401
        alm_from_delta_auto, anal, delta_from_alm_auto, pick_layout,
        pick_variant, should_interpret, synth,
    )
    PALLAS_AVAILABLE = True
except Exception:  # pragma: no cover - non-Pallas builds raise Import-,
    PALLAS_AVAILABLE = False  # Attribute- or jaxlib-mismatch RuntimeErrors
