"""Jit'd wrappers around the Pallas Legendre kernels.

Responsibilities:
  * padding/layout conversion between the engine's (M, R, K) world and the
    kernels' tiled (Mp, R1, 128 / 2K) world;
  * seed precomputation (float64 -> scaled f32 mantissas);
  * variant selection (VPU broadcast-FMA for few maps, MXU panel matmul for
    many) with env/arg overrides;
  * `interpret=True` execution on CPU (this container) vs. compiled Mosaic
    on real TPU backends.

These wrappers are the integration point used by core.dist_sht's
``stage1="pallas"`` mode and by the benchmarks.
"""

from __future__ import annotations

import functools
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autodiff import linear_pair
from repro.kernels import legendre_pallas as lk
from repro.kernels import pack as kpack
from repro.kernels import ref as kref

__all__ = ["synth", "anal", "delta_from_alm_auto", "alm_from_delta_auto",
           "delta_from_alm_spin_auto", "alm_from_delta_spin_auto",
           "spin_rows", "pick_variant", "pick_layout", "should_interpret"]


def should_interpret() -> bool:
    """Pallas interpret mode unless running on a real TPU backend."""
    forced = os.environ.get("REPRO_PALLAS_INTERPRET")
    if forced is not None:
        return forced not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


#: canonical problem size for the vpu/mxu autotune measurement
_AUTOTUNE_LMAX = 32


def _measure_variant(K2: int, var: str) -> float:
    """One warm-up + one timed synth call of ``var`` at the canonical size."""
    import time
    from repro.core import grids as _grids
    from repro.core import legendre as _legendre
    l_max = _AUTOTUNE_LMAX
    g = _grids.make_grid("gl", l_max=l_max)
    lm = _legendre.log_mu(l_max)
    m_vals = np.arange(l_max + 1)
    pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
    a = jnp.ones((l_max + 1, l_max + 1, K2), jnp.float32)
    x32 = jnp.asarray(g.cos_theta, jnp.float32)

    def fn():
        return synth(a, m_vals, x32, pmm, pms, l_max=l_max, variant=var)

    jax.block_until_ready(fn())            # warm-up / compile
    t0 = time.perf_counter()
    jax.block_until_ready(fn())
    return time.perf_counter() - t0


def _autotune_variant(K2: int):
    """Measured vpu-vs-mxu decision, cached by (K2, interpret) signature."""
    from repro.core import cache as plancache
    kind = "disk" if os.environ.get("REPRO_CACHE_DIR") else "memory"
    key = plancache.signature_key("legendre_variant", K2=int(K2),
                                  interpret=should_interpret())
    dec = plancache.load_decision(key, cache=kind)
    if dec is not None:
        v = dec.get("variant")
        return v if v in ("vpu", "mxu") else None   # cached failure: static
    try:
        meas = {v: _measure_variant(K2, v) for v in ("vpu", "mxu")}
    except Exception as e:                 # measurement unavailable: cache
        plancache.save_decision(           # the failure, fall back static
            key, {"variant": "static-fallback",
                  "error": f"{type(e).__name__}: {e}"}, cache=kind)
        return None
    best = min(meas, key=meas.get)
    plancache.save_decision(key, {"variant": best, "measured": meas},
                            cache=kind)
    return best


def pick_variant(K2: int, variant: str | None = None) -> str:
    """vpu-vs-mxu selection: explicit arg > $REPRO_LEGENDRE_VARIANT >
    cached autotune measurement (when $REPRO_LEGENDRE_AUTOTUNE is set) >
    the static ``K2 >= 16`` rule."""
    if variant in ("vpu", "mxu"):
        return variant
    env = os.environ.get("REPRO_LEGENDRE_VARIANT")
    if env in ("vpu", "mxu"):
        return env
    if os.environ.get("REPRO_LEGENDRE_AUTOTUNE", "0") \
            not in ("", "0", "false", "False"):
        tuned = _autotune_variant(K2)
        if tuned is not None:
            return tuned
    return "mxu" if K2 >= 16 else "vpu"


def _concrete_rows(v):
    """Static numpy view of a row array, or None when traced."""
    if v is None or isinstance(v, jax.core.Tracer):
        return None
    if isinstance(v, np.ndarray):
        return v
    try:
        return np.asarray(v)
    except Exception:
        return None


#: one-time traced-row degradation warning (see pick_layout); benches that
#: accidentally jit m_vals as an argument silently timed the plain kernel
#: under a packed label once (the PR-7 "packed anal slowdown") -- never again.
_TRACED_WARNED = False


def pick_layout(m_vals, layout: str | None = None, mp_vals=None) -> str:
    """packed-vs-plain selection.

    Traced row sets (the distributed stage-1 path) can never build a
    static packing and always run the plain rectangular grid, whatever
    the caller asked for -- warned once per process, because a traced
    ``m_vals`` usually means a bench/jit boundary mistake timing the
    wrong kernel.  Otherwise ``$REPRO_LEGENDRE_LAYOUT`` is the global
    debugging override (it outranks the per-call argument, so it also
    forces plans whose autotuner passes an explicit layout), then the
    explicit ``layout`` argument, then packed by default.  The override
    value ``fused`` is rejected here: the fused pipeline dispatches at
    the plan level, not through the staged wrappers."""
    global _TRACED_WARNED
    if _concrete_rows(m_vals) is None or \
            (mp_vals is not None and _concrete_rows(mp_vals) is None):
        if not _TRACED_WARNED:
            _TRACED_WARNED = True
            warnings.warn(
                "ops.synth/ops.anal received traced m_vals/mp_vals and are "
                "degrading to the plain rectangular layout (a static "
                "packing needs concrete rows). If this is a benchmark or a "
                "jit boundary, close over m_vals instead of passing it as "
                "a jit argument -- otherwise the packed/fused kernels are "
                "never the ones being timed.", RuntimeWarning, stacklevel=3)
        return "plain"
    env = os.environ.get("REPRO_LEGENDRE_LAYOUT")
    if env == "fused":
        raise ValueError(
            "$REPRO_LEGENDRE_LAYOUT=fused cannot be served by the staged "
            "kernel wrappers (ops.synth/ops.anal) -- the fused "
            "Legendre+phase pipeline dispatches at the plan level "
            "(repro.make_plan, layout 'fused'). Use a Plan, or set the "
            "override to 'plain' or 'packed'.")
    if env in ("plain", "packed"):
        return env
    if layout in ("plain", "packed"):
        return layout
    return "packed"


def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


# ---------------------------------------------------------------------------
# packed-layout conversion (kernels.pack <-> the plain (Mp, L1/R) world)
# ---------------------------------------------------------------------------


def _pack_maps(lo):
    """The five per-slot scalar-prefetch arrays for the packed kernels."""
    return (jnp.asarray(lo.slot_m[:, 0], jnp.int32),
            jnp.asarray(lo.slot_m[:, 1], jnp.int32),
            jnp.asarray(lo.slot_mp[:, 0], jnp.int32),
            jnp.asarray(lo.slot_mp[:, 1], jnp.int32),
            jnp.asarray(lo.slot_seed, jnp.int32))


def _pack_a(a, lo):
    """(Mp, L1, 2K) coefficients -> (n_slots, S, 2K) packed l-streams."""
    Mp, L1, K2 = a.shape
    flat = a.reshape(Mp * L1, K2)
    valid = (lo.a_row >= 0) & (lo.a_l < L1)
    idx = np.where(valid, lo.a_row * L1 + np.maximum(lo.a_l, 0), 0)
    out = jnp.take(flat, jnp.asarray(idx.reshape(-1)), axis=0)
    out = jnp.where(jnp.asarray(valid.reshape(-1))[:, None], out, 0.0)
    return out.reshape(lo.n_slots, lo.S, K2)


def _pack_rows(arr, lo):
    """(Mp, ...) per-row operand -> (n_slots, 2, ...) per-segment."""
    safe = np.maximum(lo.slot_row, 0).reshape(-1)
    out = jnp.take(jnp.asarray(arr), jnp.asarray(safe), axis=0)
    mask = (lo.slot_row >= 0).reshape((-1,) + (1,) * (out.ndim - 1))
    out = jnp.where(jnp.asarray(mask), out, 0)
    return out.reshape((lo.n_slots, 2) + tuple(arr.shape[1:]))


def _unpack_rows(seg, lo, n_rows):
    """(n_slots * 2, ...) per-segment results -> (n_rows, ...) plain rows
    (plan-padding rows come back as zeros)."""
    idx = np.maximum(lo.row_dst, 0)
    out = jnp.take(seg, jnp.asarray(idx), axis=0)
    mask = (lo.row_dst >= 0).reshape((-1,) + (1,) * (out.ndim - 1))
    return jnp.where(jnp.asarray(mask), out, 0.0)


def _unpack_alm(packed, lo):
    """(n_slots, S, 2K) packed l-stream rows -> (n_rows, l_max + 1, 2K)."""
    K2 = packed.shape[-1]
    flat = packed.reshape(lo.n_slots * lo.S, K2)
    src = lo.alm_src
    out = jnp.take(flat, jnp.asarray(np.maximum(src, 0).reshape(-1)), axis=0)
    out = jnp.where(jnp.asarray((src >= 0).reshape(-1))[:, None], out, 0.0)
    return out.reshape(lo.n_rows, lo.l_max + 1, K2)


def _synth_packed(a, lo, x, pmm, pms, *, l_max, fold, var, spin, lp_size,
                  interpret):
    Mp, L1, K2 = a.shape
    R = x.shape[0]
    n_par = 2 if fold else 1
    a_pk = _pack_a(a, lo)
    Rp = _pad_to(R, 1024 if var == "vpu" else 128)
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_pk = _pack_rows(jnp.pad(pmm, ((0, 0), (0, Rp - R))), lo)
    pms_pk = _pack_rows(jnp.pad(pms, ((0, 0), (0, Rp - R))), lo)
    R1 = Rp // 128
    x2d = x_p.reshape(R1, 128)
    pmm2 = pmm_pk.reshape(lo.n_slots, 2, R1, 128)
    pms2 = pms_pk.reshape(lo.n_slots, 2, R1, 128)
    maps = _pack_maps(lo)
    if var == "vpu":
        out = lk.synth_vpu_packed(a_pk, maps, x2d, pmm2, pms2, l_max=l_max,
                                  fold=fold, spin=spin, lp_size=lp_size,
                                  interpret=interpret)
        out = jnp.moveaxis(out, 2, -1)       # (n_slots, Q, R1, 128, 2K)
        out = out.reshape(lo.n_slots, 2 * n_par, Rp, K2)
    else:
        out = lk.synth_mxu_packed(a_pk, maps, x2d, pmm2, pms2, l_max=l_max,
                                  fold=fold, spin=spin, lp_size=lp_size,
                                  interpret=interpret)
    seg = out.reshape(lo.n_slots * 2, n_par, Rp, K2)
    return _unpack_rows(seg, lo, Mp)[:, :, :R, :]


def _anal_packed(dw, lo, x, pmm, pms, *, l_max, fold, var, spin, lp_size,
                 interpret):
    Mp, n_par, R, K2 = dw.shape
    Rp = _pad_to(R, 1024 if var == "vpu" else 128)
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_pk = _pack_rows(jnp.pad(pmm, ((0, 0), (0, Rp - R))), lo)
    pms_pk = _pack_rows(jnp.pad(pms, ((0, 0), (0, Rp - R))), lo)
    R1 = Rp // 128
    x2d = x_p.reshape(R1, 128)
    pmm2 = pmm_pk.reshape(lo.n_slots, 2, R1, 128)
    pms2 = pms_pk.reshape(lo.n_slots, 2, R1, 128)
    maps = _pack_maps(lo)
    if var == "vpu":
        # Ring-shrink the data operands when the ring axis fits one grid
        # row-block: ship only the ceil(R/128) real 128-lane rows of dw
        # and the seed tables and let the kernel rebuild the zero padding
        # rows in-register (the slow interpret-mode input fetch then only
        # moves real data; same technique as kernels/fused.py).
        rn = _pad_to(R, 128) if Rp == 1024 else Rp
        dw_p = jnp.pad(dw, ((0, 0), (0, 0), (0, rn - R), (0, 0)))
        dwk = jnp.moveaxis(
            _pack_rows(dw_p, lo).reshape(
                lo.n_slots, 2 * n_par, rn // 128, 128, K2), -1, 2)
        pmm2s = _pack_rows(jnp.pad(pmm, ((0, 0), (0, rn - R))), lo) \
            .reshape(lo.n_slots, 2, rn // 128, 128)
        pms2s = _pack_rows(jnp.pad(pms, ((0, 0), (0, rn - R))), lo) \
            .reshape(lo.n_slots, 2, rn // 128, 128)
        out = lk.anal_vpu_packed(dwk, maps, x2d, pmm2s, pms2s, l_max=l_max,
                                 s_len=lo.S, fold=fold, spin=spin,
                                 lp_size=lp_size, interpret=interpret)
    else:
        dw_p = jnp.pad(dw, ((0, 0), (0, 0), (0, Rp - R), (0, 0)))
        dw_pk = _pack_rows(dw_p, lo).reshape(lo.n_slots, 2 * n_par, Rp, K2)
        out = lk.anal_mxu_packed(dw_pk, maps, x2d, pmm2, pms2, l_max=l_max,
                                 s_len=lo.S, fold=fold, spin=spin,
                                 lp_size=lp_size, interpret=interpret)
    return _unpack_alm(out, lo)


def _resolve_layout(m_vals, layout, mp_vals, l_max, lp_size):
    """Trace-time packed-vs-plain resolution: the packed layout object (or
    None for the plain rectangular grid)."""
    if pick_layout(m_vals, layout, mp_vals) != "packed":
        return None
    return kpack.build_layout(_concrete_rows(m_vals), l_max, lp_size=lp_size,
                              mp_vals=_concrete_rows(mp_vals))


def _synth_exec(a, m_vals, x, pmm, pms, mp_vals, *, l_max, fold, var, lo,
                lp_size, interpret):
    """Synthesis body with the layout/variant decision already made
    (``lo`` is the packed layout or None for plain)."""
    Mp, L1, K2 = a.shape
    R = x.shape[0]
    if lo is not None:
        return _synth_packed(a, lo, x, pmm, pms, l_max=l_max, fold=fold,
                             var=var, spin=mp_vals is not None,
                             lp_size=lp_size, interpret=interpret)
    L1p = _pad_to(L1, lp_size)
    Rp = _pad_to(R, 1024 if var == "vpu" else 128)
    a_p = jnp.pad(a, ((0, 0), (0, L1p - L1), (0, 0)))
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_p = jnp.pad(pmm, ((0, 0), (0, Rp - R)))
    pms_p = jnp.pad(pms, ((0, 0), (0, Rp - R)))
    R1 = Rp // 128
    x2d = x_p.reshape(R1, 128)
    pmm2 = pmm_p.reshape(Mp, R1, 128)
    pms2 = pms_p.reshape(Mp, R1, 128)
    if var == "vpu":
        out = lk.synth_vpu(a_p, jnp.asarray(m_vals, jnp.int32), x2d, pmm2,
                           pms2, l_max=l_max, fold=fold, mp_vals=mp_vals,
                           lp_size=lp_size, interpret=interpret)
        n_par = out.shape[1]
        out = jnp.moveaxis(out, 2, -1)            # (Mp, P, R1, 128, 2K)
        out = out.reshape(Mp, n_par, Rp, K2)
    else:
        out = lk.synth_mxu(a_p, jnp.asarray(m_vals, jnp.int32), x2d, pmm2,
                           pms2, l_max=l_max, fold=fold, mp_vals=mp_vals,
                           lp_size=lp_size, interpret=interpret)
    return out[:, :, :R, :]


def _anal_exec(dw, m_vals, x, pmm, pms, mp_vals, *, l_max, l1p, fold, var,
               lo, lp_size, interpret):
    """Analysis body with the layout/variant decision already made."""
    Mp, n_par, R, K2 = dw.shape
    L1 = l_max + 1
    if lo is not None:
        return _anal_packed(dw, lo, x, pmm, pms, l_max=l_max, fold=fold,
                            var=var, spin=mp_vals is not None,
                            lp_size=lp_size, interpret=interpret)
    L1p = _pad_to(L1 if l1p is None else l1p, lp_size)
    Rp = _pad_to(R, 1024 if var == "vpu" else 128)
    dw_p = jnp.pad(dw, ((0, 0), (0, 0), (0, Rp - R), (0, 0)))
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_p = jnp.pad(pmm, ((0, 0), (0, Rp - R)))
    pms_p = jnp.pad(pms, ((0, 0), (0, Rp - R)))
    R1 = Rp // 128
    x2d = x_p.reshape(R1, 128)
    pmm2 = pmm_p.reshape(Mp, R1, 128)
    pms2 = pms_p.reshape(Mp, R1, 128)
    mv = jnp.asarray(m_vals, jnp.int32)
    if var == "vpu":
        dw_k = jnp.moveaxis(dw_p.reshape(Mp, n_par, R1, 128, K2), -1, 2)
        out = lk.anal_vpu(dw_k, mv, x2d, pmm2, pms2, l_max=l_max, l1p=L1p,
                          fold=fold, mp_vals=mp_vals, lp_size=lp_size,
                          interpret=interpret)
    else:
        out = lk.anal_mxu(dw_p, mv, x2d, pmm2, pms2, l_max=l_max, l1p=L1p,
                          fold=fold, mp_vals=mp_vals, lp_size=lp_size,
                          interpret=interpret)
    return out[:, :L1, :]


def synth(a, m_vals, x, pmm, pms, *, l_max, fold=False, variant=None,
          mp_vals=None, lp_size=128, interpret=None, layout=None):
    """Kernel-backed synthesis with automatic padding.

    a: (Mp, L1, 2K) f32;  x: (R,) f32;  pmm/pms: (Mp, R).
    ``mp_vals`` (Mp,) switches rows to the spin-weighted (Wigner m')
    recurrence -- seeds must then come from ref.prepare_seeds_spin.
    ``layout`` selects the packed triangular m-pair grid vs the plain
    rectangular one (see :func:`pick_layout`).
    Returns (Mp, P, R, 2K) f32 matching ref.synth_ref.

    Differentiable both ways (when ``L1 == l_max + 1``, which every plan
    layout satisfies): Pallas kernels are opaque to JAX AD, so the VJP is
    the adjoint transform -- the *analysis* kernel with the same seeds,
    variant and packed schedule (synthesis and analysis panels are exact
    transposes of each other; no quadrature weights live at this layer).
    """
    if interpret is None:
        interpret = should_interpret()
    Mp, L1, K2 = a.shape
    var = pick_variant(K2, variant)
    lo = _resolve_layout(m_vals, layout, mp_vals, l_max, lp_size)
    kw = dict(l_max=l_max, fold=fold, var=var, lo=lo, lp_size=lp_size,
              interpret=interpret)
    if L1 != l_max + 1:     # non-plan layout: no adjoint contract, run raw
        return _synth_exec(a, m_vals, x, pmm, pms, mp_vals, **kw)

    def fwd(res, a_):
        m_, x_, pmm_, pms_, mp_ = res
        return _synth_exec(a_, m_, x_, pmm_, pms_, mp_, **kw)

    def bwd(res, g):
        m_, x_, pmm_, pms_, mp_ = res
        return _anal_exec(g, m_, x_, pmm_, pms_, mp_, l1p=None, **kw)

    return linear_pair(fwd, bwd, (m_vals, x, pmm, pms, mp_vals), a)


def anal(dw, m_vals, x, pmm, pms, *, l_max, l1p=None, fold=False,
         variant=None, mp_vals=None, lp_size=128, interpret=None,
         layout=None):
    """Kernel-backed analysis with automatic padding.

    dw: (Mp, P, R, 2K) f32;  returns (Mp, L1, 2K) f32 (L1 = l_max+1).
    ``mp_vals`` / ``layout`` as in :func:`synth`.

    Differentiable both ways: the VJP is the *synthesis* kernel with the
    same seeds, variant and packed schedule (see :func:`synth`).
    """
    if interpret is None:
        interpret = should_interpret()
    Mp, n_par, R, K2 = dw.shape
    var = pick_variant(K2, variant)
    lo = _resolve_layout(m_vals, layout, mp_vals, l_max, lp_size)
    kw = dict(l_max=l_max, fold=fold, var=var, lo=lo, lp_size=lp_size,
              interpret=interpret)
    if n_par != (2 if fold else 1):  # non-plan panel count: run raw
        return _anal_exec(dw, m_vals, x, pmm, pms, mp_vals, l1p=l1p, **kw)

    def fwd(res, dw_):
        m_, x_, pmm_, pms_, mp_ = res
        return _anal_exec(dw_, m_, x_, pmm_, pms_, mp_, l1p=l1p, **kw)

    def bwd(res, g):
        m_, x_, pmm_, pms_, mp_ = res
        return _synth_exec(g, m_, x_, pmm_, pms_, mp_, **kw)

    return linear_pair(fwd, bwd, (m_vals, x, pmm, pms, mp_vals), dw)


# ---------------------------------------------------------------------------
# dist_sht stage-1 adapters (the `stage1="pallas"` path)
# ---------------------------------------------------------------------------


def delta_from_alm_auto(a_re, a_im, m_vals, geom, log_mu_all, *, l_max,
                        fold=False, dtype=jnp.float32, variant=None,
                        layout=None):
    """Drop-in for legendre.delta_from_alm(+_folded) backed by the kernels.

    a_re/a_im: (M, L1, K); geom: plan.ring_geometry dict (numpy, static).
    Returns (d_re, d_im): (M, R_pad, K) in plan slot order (fold handled
    internally: even/odd parts recombined and re-interleaved).
    Kernel math is float32; inputs/outputs are cast from/to ``dtype``.
    """
    M, L1, K = a_re.shape
    if fold:
        sin = geom["sin_theta"][0::2]
        x = geom["cos_theta"][0::2]
    else:
        sin = geom["sin_theta"]
        x = geom["cos_theta"]
    pmm, pms = kref.prepare_seeds(m_vals, sin, log_mu_all)
    a = jnp.concatenate([a_re, a_im], axis=-1).astype(jnp.float32)
    out = synth(a, m_vals, jnp.asarray(x, jnp.float32), pmm, pms,
                l_max=l_max, fold=fold, variant=variant,
                layout=layout)                             # (M, P, R', 2K)
    if fold:
        e, o = out[:, 0], out[:, 1]                        # (M, R_north, 2K)
        north, south = e + o, e - o
        inter = jnp.stack([north, south], axis=2)          # (M, Rn, 2, 2K)
        out2 = inter.reshape(M, 2 * north.shape[1], 2 * K)
    else:
        out2 = out[:, 0]
    d_re = out2[..., :K].astype(dtype)
    d_im = out2[..., K:].astype(dtype)
    return d_re, d_im


def alm_from_delta_auto(dw_re, dw_im, m_vals, geom, log_mu_all, *, l_max,
                        fold=False, dtype=jnp.float32, variant=None,
                        layout=None):
    """Drop-in for legendre.alm_from_delta(+_folded) backed by the kernels.

    dw_re/dw_im: (M, R_pad, K) weighted Delta in plan slot order.
    Returns (a_re, a_im): (M, L1, K).
    """
    M, R_pad, K = dw_re.shape
    dw = jnp.concatenate([dw_re, dw_im], axis=-1).astype(jnp.float32)
    if fold:
        n, s = dw[:, 0::2], dw[:, 1::2]
        dwk = jnp.stack([n + s, n - s], axis=1)            # (M, 2, Rn, 2K)
        sin = geom["sin_theta"][0::2]
        x = geom["cos_theta"][0::2]
    else:
        dwk = dw[:, None]
        sin = geom["sin_theta"]
        x = geom["cos_theta"]
    pmm, pms = kref.prepare_seeds(m_vals, sin, log_mu_all)
    out = anal(dwk, m_vals, jnp.asarray(x, jnp.float32), pmm, pms,
               l_max=l_max, fold=fold, variant=variant,
               layout=layout)                              # (M, L1, 2K)
    return out[..., :K].astype(dtype), out[..., K:].astype(dtype)


# ---------------------------------------------------------------------------
# spin-2 adapters: two stacked Wigner-d recurrences (m' = -2 | +2 row
# blocks) through the same kernels; component mixing via legendre.spin_*.
# ---------------------------------------------------------------------------


def spin_rows(m_vals):
    """Stack the m rows for the two spin recurrences: (m2, mp2), (2M,)."""
    from repro.core import legendre
    return legendre._spin_rows(m_vals)


def delta_from_alm_spin_auto(e_re, e_im, b_re, b_im, m_vals, geom, *, l_max,
                             m_max, dtype=jnp.float32, variant=None,
                             layout=None):
    """Spin-2 drop-in for legendre.delta_from_alm_spin backed by the kernels.

    e/b re/im: (M, L1, K); geom: plan.ring_geometry dict (or any dict with
    ``cos_theta``/``sin_theta``).  Returns (dq_re, dq_im, du_re, du_im),
    each (M, R, K) in the geometry's ring order.  Kernel math is float32.
    """
    from repro.core import legendre
    from repro.kernels import ref as kref_
    M, L1, K = e_re.shape
    x = geom["cos_theta"]
    sin = geom["sin_theta"]
    m2, mp2 = spin_rows(m_vals)
    a2_re, a2_im = legendre.spin_pack_alm(e_re, e_im, b_re, b_im)
    a = jnp.concatenate([a2_re, a2_im], axis=-1).astype(jnp.float32)
    pmm, pms = kref_.prepare_seeds_spin(m2, mp2, x, sin, m_max=m_max)
    out = synth(a, m2, jnp.asarray(x, jnp.float32), pmm, pms, l_max=l_max,
                fold=False, variant=variant, mp_vals=mp2,
                layout=layout)                              # (2M, 1, R, 2K)
    flat = out[:, 0]
    d_re = flat[..., :K].astype(dtype)
    d_im = flat[..., K:].astype(dtype)
    return legendre.spin_unpack_delta(d_re, d_im)


def alm_from_delta_spin_auto(dq_re, dq_im, du_re, du_im, m_vals, geom, *,
                             l_max, m_max, dtype=jnp.float32, variant=None,
                             layout=None):
    """Spin-2 drop-in for legendre.alm_from_delta_spin backed by the kernels.

    dq/du re/im: (M, R, K) weighted Delta_Q/Delta_U.  Returns
    (e_re, e_im, b_re, b_im), each (M, L1, K).
    """
    from repro.core import legendre
    from repro.kernels import ref as kref_
    M, R, K = dq_re.shape
    x = geom["cos_theta"]
    sin = geom["sin_theta"]
    m2, mp2 = spin_rows(m_vals)
    d2_re, d2_im = legendre.spin_pack_delta(dq_re, dq_im, du_re, du_im)
    dw = jnp.concatenate([d2_re, d2_im], axis=-1).astype(jnp.float32)
    pmm, pms = kref_.prepare_seeds_spin(m2, mp2, x, sin, m_max=m_max)
    out = anal(dw[:, None], m2, jnp.asarray(x, jnp.float32), pmm, pms,
               l_max=l_max, fold=False, variant=variant, mp_vals=mp2,
               layout=layout)
    a_re = out[..., :K].astype(dtype)
    a_im = out[..., K:].astype(dtype)
    return legendre.spin_unpack_alm(a_re, a_im)
