"""Triangular m-pair packing for the Pallas Legendre kernels.

The paper's central cost invariant is the *triangular* recurrence count
(sum over m of ``l_max - l0(m) + 1`` steps), and its MPI layer preserves
it with min-max m-pairing (paper §4.1.1, Fig. 5; `core.plan.SHTPlan`).
The plain single-device kernels, however, launch a dense rectangular
``(Mp, L1p/lp_size)`` grid and mask sub-diagonal panels with ``pl.when``
-- roughly half the grid steps, the ``a``-coefficient rows and the
analysis-output rows are zero padding travelling through HBM.

This module applies the same pairing discipline *inside* the kernels.
Rows are paired longest-with-shortest (for the scalar transform that is
exactly ``(m, m_max - m)``), so every fused *slot* runs a near-constant
``2*l_max - m_max + 2`` recurrence steps.  A slot's two coefficient
streams are concatenated back-to-back -- the second row's seed step
(``slot_seed``) may sit anywhere inside a panel, so there are **no**
alignment zeros and **no** ``pl.when``-skipped panels: every grid step of
the packed ``(n_slots, n_sp)`` grid does ``lp_size`` real recurrence
steps (up to the final tail of the slot).  The carry ``(pp, pc, sc)``
re-seeds itself at the intra-slot boundary because the recurrence step
function seeds whenever ``l == l0`` -- the packed schedule lands the
boundary step exactly there.

The layout is pure host-side numpy (static under jit): per-slot
scalar-prefetch maps for the kernels plus gather index maps for the
layout conversions in `kernels.ops`.

One layout serves both transform directions AND their adjoints: the
packed synthesis and packed analysis kernels consume the identical
``slot_m``/``slot_mp``/``slot_seed`` schedule and compute the same
per-slot lambda streams, which makes them exact mutual transposes.  The
custom VJP rules in `kernels.ops` rely on this -- the gradient of a
packed synthesis is the packed analysis with the *same* layout object
(and vice versa), so the backward pass inherits the packed grid's
occupancy win with no transpose-only kernels.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = ["PackedLayout", "build_layout", "panel_counts",
           "fused_lp_candidates"]


def fused_lp_candidates(l_max: int) -> tuple:
    """Candidate panel lengths (``lp_size``) for the fused pipeline's
    chardb-driven block autotune.

    128 is the VPU-native sublane multiple; 256 halves the grid-step
    count (fewer per-panel block fetches, one dot over a taller panel)
    at double the VMEM value-panel footprint, which only has a chance of
    paying off once a slot actually spans multiple 128-panels.  Small
    bands where the whole slot fits one 128-panel have nothing to fuse
    further, so they keep the single candidate.
    """
    return (128, 256) if l_max + 1 > 128 else (128,)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static description of a packed (slot, panel) Legendre grid.

    A *slot* fuses (at most) two rows of the plain layout: segment 0 is
    the longer row, segment 1 (if any) seeds at intra-slot step
    ``slot_seed``.  ``slot_*`` arrays are the kernels' scalar-prefetch
    maps; ``a_row``/``a_l``/``alm_src``/``row_dst`` drive the host-side
    pack/unpack gathers.
    """

    l_max: int
    lp_size: int
    n_rows: int                  # plain row-slot count (incl. m = -1 pads)
    n_slots: int
    n_sp: int                    # panels per slot (uniform, no skips)
    slot_m: np.ndarray           # (n_slots, 2) i32: m per segment
    slot_mp: np.ndarray          # (n_slots, 2) i32: m' per segment (spin)
    slot_seed: np.ndarray        # (n_slots,) i32: step where segment 1 seeds
    slot_row: np.ndarray         # (n_slots, 2) i32: plain row index; -1 none
    spin: bool

    @property
    def S(self) -> int:
        """Packed l-stream length per slot (n_sp * lp_size)."""
        return self.n_sp * self.lp_size

    @property
    def n_panels(self) -> int:
        """Grid steps per ring block -- the packed panel count."""
        return self.n_slots * self.n_sp

    @functools.cached_property
    def _stream(self) -> tuple[np.ndarray, np.ndarray]:
        """(row, l) per packed stream position, each (n_slots, S); -1 where
        the position is tail padding past a segment's l_max."""
        g = np.arange(self.S)[None, :]                      # (1, S)
        seg1 = g >= self.slot_seed[:, None]                 # (n_slots, S)
        l0 = np.maximum(self.slot_m, np.abs(self.slot_mp))  # (n_slots, 2)
        l = np.where(seg1, l0[:, 1:2] + g - self.slot_seed[:, None],
                     l0[:, 0:1] + g)
        row = np.where(seg1, self.slot_row[:, 1:2], self.slot_row[:, 0:1])
        valid = (row >= 0) & (l <= self.l_max)
        return (np.where(valid, row, -1).astype(np.int64),
                np.where(valid, l, -1).astype(np.int64))

    @property
    def a_row(self) -> np.ndarray:
        """(n_slots, S) plain row index per stream position (-1 padding)."""
        return self._stream[0]

    @property
    def a_l(self) -> np.ndarray:
        """(n_slots, S) multipole l per stream position (-1 padding)."""
        return self._stream[1]

    @functools.cached_property
    def alm_src(self) -> np.ndarray:
        """(n_rows, l_max + 1) flat index into the (n_slots * S) packed
        l-stream; -1 where the (row, l) pair does not exist (l < l0 or a
        padding row)."""
        out = np.full((self.n_rows, self.l_max + 1), -1, dtype=np.int64)
        row, l = self._stream
        valid = row >= 0
        flat = np.arange(self.n_slots * self.S).reshape(self.n_slots, self.S)
        out[row[valid], l[valid]] = flat[valid]
        return out

    @functools.cached_property
    def row_dst(self) -> np.ndarray:
        """(n_rows,) flat index into (n_slots * 2) segments; -1 padding."""
        out = np.full(self.n_rows, -1, dtype=np.int64)
        for s in range(self.n_slots):
            for seg in range(2):
                r = int(self.slot_row[s, seg])
                if r >= 0:
                    out[r] = 2 * s + seg
        return out

    def occupancy(self) -> float:
        """Useful recurrence steps / executed steps of the packed grid."""
        return float(np.count_nonzero(self.a_row >= 0)) \
            / float(self.n_slots * self.S)


def _build(m_key: bytes, mp_key: bytes | None, n_rows: int, l_max: int,
           lp_size: int) -> PackedLayout | None:
    m_vals = np.frombuffer(m_key, dtype=np.int64)
    spin = mp_key is not None
    mp_vals = (np.frombuffer(mp_key, dtype=np.int64) if spin
               else np.zeros(n_rows, np.int64))
    rows = np.where(m_vals >= 0)[0]
    if rows.size == 0:
        return None
    l0 = np.maximum(m_vals[rows], np.abs(mp_vals[rows]))
    if int(np.max(l0)) > l_max:
        return None                        # a row with no l-range: bail out
    lengths = l_max + 1 - l0
    order = rows[np.argsort(-lengths, kind="stable")]
    n = order.size
    n_slots = (n + 1) // 2
    slot_row = np.full((n_slots, 2), -1, dtype=np.int64)
    slot_row[:, 0] = order[:n_slots]                     # longest first
    slot_row[: n - n_slots, 1] = order[::-1][: n - n_slots]
    seg_valid = slot_row >= 0
    safe = np.maximum(slot_row, 0)
    slot_m = np.where(seg_valid, m_vals[safe], 0)
    slot_mp = np.where(seg_valid, mp_vals[safe], 0)
    # duplicate segment 0 into empty segment 1 slots so in-kernel selects
    # stay benign; slot_seed = S means the seam is never reached.
    slot_m[:, 1] = np.where(seg_valid[:, 1], slot_m[:, 1], slot_m[:, 0])
    slot_mp[:, 1] = np.where(seg_valid[:, 1], slot_mp[:, 1], slot_mp[:, 0])
    len0 = l_max + 1 - np.maximum(slot_m[:, 0], np.abs(slot_mp[:, 0]))
    len1 = np.where(seg_valid[:, 1],
                    l_max + 1 - np.maximum(slot_m[:, 1],
                                           np.abs(slot_mp[:, 1])), 0)
    n_sp = int(-(-int(np.max(len0 + len1)) // lp_size))
    S = n_sp * lp_size
    slot_seed = np.where(seg_valid[:, 1], len0, S).astype(np.int64)
    layout = PackedLayout(
        l_max=int(l_max), lp_size=int(lp_size), n_rows=int(n_rows),
        n_slots=int(n_slots), n_sp=n_sp,
        slot_m=slot_m.astype(np.int64), slot_mp=slot_mp.astype(np.int64),
        slot_seed=slot_seed, slot_row=slot_row, spin=bool(spin))
    return layout


@functools.lru_cache(maxsize=128)
def _build_cached(m_key, mp_key, n_rows, l_max, lp_size):
    return _build(m_key, mp_key, n_rows, l_max, lp_size)


def build_layout(m_vals, l_max: int, *, lp_size: int = 128,
                 mp_vals=None) -> PackedLayout | None:
    """Build (or fetch) the packed layout for a static row set.

    ``m_vals`` (and ``mp_vals`` on the spin path) must be concrete --
    traced rows (the distributed stage-1 path) cannot pack and should use
    the plain layout.  Rows with ``m < 0`` (plan padding) are excluded
    from the packed grid entirely; returns None when nothing remains.
    """
    m = np.asarray(m_vals, dtype=np.int64)
    mp_key = (np.ascontiguousarray(
        np.asarray(mp_vals, dtype=np.int64)).tobytes()
        if mp_vals is not None else None)
    return _build_cached(np.ascontiguousarray(m).tobytes(), mp_key,
                         int(m.shape[0]), int(l_max), int(lp_size))


def panel_counts(m_vals, l_max: int, *, lp_size: int = 128,
                 mp_vals=None) -> dict:
    """Grid-step accounting, plain vs packed, for a concrete row set.

    ``plain_launched`` counts every grid step of the dense rectangular
    grid (they all pay grid-step latency); ``plain_worked`` counts the
    subset passing the ``pl.when`` diagonal test; ``packed`` is the packed
    grid's step count (every one works).  ``ideal_steps`` is the paper's
    triangular invariant, sum over rows of ``l_max - l0 + 1``.
    """
    m = np.asarray(m_vals, dtype=np.int64)
    n_rows = int(m.shape[0])
    L1p = -(-(l_max + 1) // lp_size) * lp_size
    n_lp = L1p // lp_size
    plain_launched = n_rows * n_lp
    skipped = np.where(m >= 0, np.maximum(m, 0) // lp_size, 0)
    plain_worked = int(n_rows * n_lp - np.sum(skipped))
    layout = build_layout(m, l_max, lp_size=lp_size, mp_vals=mp_vals)
    packed = 0 if layout is None else layout.n_panels
    if mp_vals is None:
        l0 = np.where(m >= 0, np.maximum(m, 0), l_max + 1)
    else:
        mp = np.asarray(mp_vals, dtype=np.int64)
        l0 = np.where(m >= 0, np.maximum(np.maximum(m, 0), np.abs(mp)),
                      l_max + 1)
    ideal = int(np.sum(np.maximum(l_max + 1 - l0, 0)))
    return {
        "lp_size": int(lp_size),
        "plain_launched": int(plain_launched),
        "plain_worked": plain_worked,
        "packed": int(packed),
        "ideal_steps": ideal,
        "launched_ratio": (plain_launched / packed) if packed else 0.0,
        "worked_ratio": (plain_worked / packed) if packed else 0.0,
        "packed_occupancy": (ideal / (packed * lp_size)) if packed else 0.0,
    }
