"""Fused Legendre+phase Pallas pipeline (single-kernel inverse/direct SHT
stage pair for uniform grids).

The staged pipeline (kernels/ops.py + core/phase.py) materialises the
intermediate ``delta_m(r)`` rows in HBM between the Legendre kernel and the
host phase stage -- the exact traffic the paper identifies as the GPU
bottleneck of the inverse transform, and what libsharp's fused ring-major
loop avoids.  The kernels here keep the per-ring accumulation on-chip:

  * synthesis: the packed-slot Legendre accumulate is contracted per panel
    and immediately rotated by a per-(row, ring) *phase table*
    (core.phase.uniform_rotation_tables -- cos/sin of m*phi0 with the
    conjugate-wrap and Nyquist handling of the uniform engine baked in), so
    the kernel's only output is the rotated half-spectrum row block.  The
    unrotated Delta never exists as a pallas output ref (asserted on the
    jaxpr in tests/test_fused.py).
  * analysis: the gathered rfft rows are rotated into Delta in-kernel (once
    per ring block, hoisted out of the l loop) and contracted against the
    recurrence panel; only packed a_lm l-streams leave the kernel.

Beyond the fusion itself the kernels carry two raw-speed upgrades over the
staged ones:

  * panel-contraction accumulate: recurrence values stream into a VMEM
    value panel (via the exact shared `_f32_step`, so fused synthesis is
    bit-identical to staged) and are contracted against the coefficient
    block once per panel (one dot) instead of a broadcast-FMA per l-step
    -- the per-l cost stops scaling with K.
  * ring-shrunk data operands: on the VPU layout the ring axis is padded
    to 1024 lanes but only ``ceil(R/128)`` row blocks carry data, so the
    ``f``/phase-table operands are shipped at that reduced row count and
    the zero padding rows are rebuilt in-register (`_pad_rows`).  Input
    block fetches are the dominant cost in interpret mode; not reading
    megabytes of structural zeros is most of the measured fused win.

The synthesis VPU kernel double-buffers its per-panel output flush
(`hbuf` two-slot scratch): panel p's contracted+rotated block is written
to HBM while panel p+1's recurrence values stream into the value panel --
the manual-prefetch-in-the-carry analogue of ``pltpu.emit_pipeline`` (in
interpret mode the schedule is sequential; on hardware the structure lets
Mosaic overlap the flush DMA with compute).

The MXU variants take ``bf16=True`` to run the panel contraction in
bfloat16 with float32 accumulation (`preferred_element_type`); the
measured error band rides in benchmarks/bench_recurrence.py (`bf16_err`
rows).

Only the scalar (spin == 0), unfolded path is fused; plans fall back to
the staged pipeline otherwise (see Plan.describe()["fusion"]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.autodiff import linear_pair
from repro.kernels.legendre_pallas import (_CompilerParams, _f32_step,
                                           _pad_rows)

__all__ = [
    "synth_fused_vpu", "synth_fused_mxu",
    "anal_fused_vpu", "anal_fused_mxu",
    "fused_synth", "fused_anal",
]

def _fill_panel(panel_ref, x, m0, m1, jsw, base, lp_size, pmm0, pms0,
                pmm1, pms1, carry):
    """Stream the split-seam recurrence values of one panel into the VMEM
    value panel via the exact shared `_f32_step`.  Returns the (pp, pc, sc)
    carry.  Scalar (spin-0) path: segment l0 == m."""
    j0 = jnp.clip(jsw - base, 0, lp_size)

    def seg_gen(m, l_base, pmm, pms):
        m_f = m.astype(jnp.float32)

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _f32_step(l_base + j, m_f, x, pp, pc, sc,
                                        pmm, pms)
            panel_ref[pl.ds(j, 1)] = val.reshape((1,) + panel_ref.shape[1:])
            return pp, pc, sc

        return gen

    carry = jax.lax.fori_loop(
        0, j0, seg_gen(m0, m0 + base, pmm0, pms0), carry)
    return jax.lax.fori_loop(
        j0, lp_size, seg_gen(m1, m1 + base - jsw, pmm1, pms1), carry)


def _hi_row_mask(base, jsw, lp_size):
    iot = jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
    return (base + iot) >= jsw


# =============================================================================
# Fused synthesis: packed a_lm -> rotated half-spectrum rows, one kernel
# =============================================================================


def _synth_fused_vpu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                            x_ref, pmm_ref, pms_ref, tab_ref, a_ref,
                            out_ref, pp_ref, pc_ref, sc_ref, panel_ref,
                            hbuf_ref, *, lp_size, n_k, n_sp, rf):
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    # double-buffered flush: panel sp-1's contracted+rotated block drains
    # to the output ref while this panel's recurrence values stream in
    @pl.when(sp > 0)
    def _flush_prev():
        out_ref[0] += hbuf_ref[pl.ds((sp - 1) % 2, 1)][0]

    x = x_ref[...]                            # (8, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    carry = _fill_panel(panel_ref, x, m0, m1, jsw, base, lp_size,
                        pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...].reshape(lp_size, -1)       # (LP, 8*128)
    a_blk = a_ref[0]                          # (LP, 2K)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    hs = []
    for seg in (0, 1):
        a_seg = jnp.where(hi_row if seg else ~hi_row, a_blk, 0.0)
        d = jax.lax.dot_general(a_seg, panel, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        d = d.reshape(2 * n_k, 8, 128)
        d_re, d_im = d[:n_k], d[n_k:]         # (K, 8, 128) each
        t = _pad_rows(tab_ref[0, seg], rf)    # (4, 8, 128)
        h_re = t[0] * d_re + t[1] * d_im
        h_im = t[2] * d_re + t[3] * d_im
        hs.append(jnp.concatenate([h_re, h_im], axis=0))
    hbuf_ref[pl.ds(sp % 2, 1)] = jnp.stack(hs, axis=0)[None]

    @pl.when(sp == n_sp - 1)
    def _flush_last():
        out_ref[0] += hbuf_ref[pl.ds(sp % 2, 1)][0]


def synth_fused_vpu(a_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max,
                    lp_size=128, interpret=True):
    """VPU fused synthesis on the packed (slot, panel) grid.

    a_pk   : (n_slots, S, 2K) f32 packed coefficient streams
    maps   : (m0, m1, mp0, mp1, seed) i32 per-slot scalar-prefetch arrays
    x2d    : (R1, 128) f32;  pmm_pk/pms_pk: (n_slots, 2, R1, 128)
    tab_pk : (n_slots, 2, 4, Rf1, 128) f32 per-segment phase tables,
             ring-shrunk to ``Rf1`` real row blocks (= R1 on multi-row
             grids)
    returns: (n_slots, 2, 2K, R1, 128) f32 rotated half-spectrum rows
    """
    n_slots, S, K2 = a_pk.shape
    R1 = x2d.shape[0]
    assert S % lp_size == 0 and R1 % 8 == 0 and K2 % 2 == 0
    n_sp = S // lp_size
    rf = tab_pk.shape[3] if R1 == 8 else 8
    assert tab_pk.shape[3] == (rf if R1 == 8 else R1)
    tab_spec = pl.BlockSpec((1, 2, 4, rf, 128),
                            (lambda s, rb, sp, *_refs: (s, 0, 0, 0, 0))
                            if R1 == 8 else
                            (lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)))
    grid = (n_slots, R1 // 8, n_sp)
    kernel = functools.partial(_synth_fused_vpu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, n_sp=n_sp, rf=rf)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                tab_spec,
                pl.BlockSpec((1, lp_size, K2),
                             lambda s, rb, sp, *_refs: (s, sp, 0)),
            ],
            out_specs=pl.BlockSpec((1, 2, K2, 8, 128),
                                   lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, 8, 128), jnp.float32),
                pltpu.VMEM((2, 2, K2, 8, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, 2, K2, R1, 128),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, tab_pk, a_pk)


def _synth_fused_mxu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                            x_ref, pmm_ref, pms_ref, tab_ref, a_ref,
                            out_ref, pp_ref, pc_ref, sc_ref, panel_ref, *,
                            lp_size, n_k, bf16):
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    x = x_ref[...]                            # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    carry = _fill_panel(panel_ref, x, m0, m1, jsw, base, lp_size,
                        pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...]                    # (LP, 128)
    if bf16:
        panel = panel.astype(jnp.bfloat16)
    a_blk = a_ref[0]                          # (LP, 2K)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    for seg in (0, 1):
        a_seg = jnp.where(hi_row if seg else ~hi_row, a_blk, 0.0)
        if bf16:
            a_seg = a_seg.astype(jnp.bfloat16)
        c = jax.lax.dot_general(panel, a_seg, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        c_re, c_im = c[:, :n_k], c[:, n_k:]   # (128, K) each
        t = tab_ref[0, seg][:, 0, :]          # (4, 128)
        h_re = t[0][:, None] * c_re + t[1][:, None] * c_im
        h_im = t[2][:, None] * c_re + t[3][:, None] * c_im
        out_ref[0, seg] += jnp.concatenate([h_re, h_im], axis=1)


def synth_fused_mxu(a_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max,
                    bf16=False, lp_size=128, interpret=True):
    """MXU fused synthesis (panel matmul + in-kernel rotation).

    Layouts as :func:`synth_fused_vpu` except rings advance 128 at a time;
    tab_pk is (n_slots, 2, 4, R1, 128); returns (n_slots, 2, R, 2K) with
    R = R1 * 128.  ``bf16=True`` contracts the recurrence panel in
    bfloat16 with f32 accumulation.
    """
    n_slots, S, K2 = a_pk.shape
    R1 = x2d.shape[0]
    R = R1 * 128
    assert S % lp_size == 0 and K2 % 2 == 0
    grid = (n_slots, R1, S // lp_size)
    kernel = functools.partial(_synth_fused_mxu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, bf16=bf16)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 4, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)),
                pl.BlockSpec((1, lp_size, K2),
                             lambda s, rb, sp, *_refs: (s, sp, 0)),
            ],
            out_specs=pl.BlockSpec((1, 2, 128, K2),
                                   lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, 2, R, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
      pms_pk.reshape(n_slots, 2, R1, 128),
      tab_pk.reshape(n_slots, 2, 4, R1, 128), a_pk)


# =============================================================================
# Fused analysis: gathered rfft rows -> packed a_lm l-streams, one kernel
# =============================================================================


def _anal_fused_vpu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                           x_ref, pmm_ref, pms_ref, tab_ref, f_ref,
                           out_ref, pp_ref, pc_ref, sc_ref, panel_ref, *,
                           lp_size, n_k, rf):
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]

    # rotate the gathered half-spectrum rows into Delta once per grid step
    # (l-independent, so hoisted out of the recurrence loop entirely)
    f = _pad_rows(f_ref[0], rf)               # (2, 2K, 8, 128)
    ds = []
    for seg in (0, 1):
        f_re, f_im = f[seg, :n_k], f[seg, n_k:]
        t = _pad_rows(tab_ref[0, seg], rf)    # (4, 8, 128)
        d_re = t[0] * f_re + t[1] * f_im
        d_im = t[2] * f_re + t[3] * f_im
        ds.append(jnp.concatenate([d_re, d_im], axis=0)
                  .reshape(2 * n_k, -1))      # (2K, 8*128)

    carry = _fill_panel(panel_ref, x, m0, m1, jsw, base, lp_size,
                        pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...].reshape(lp_size, -1)       # (LP, 8*128)
    dims = (((1,), (1,)), ((), ()))           # NT gemm over the ring tile
    c0 = jax.lax.dot_general(panel, ds[0], dims,
                             preferred_element_type=jnp.float32)
    c1 = jax.lax.dot_general(panel, ds[1], dims,
                             preferred_element_type=jnp.float32)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    out_ref[0] += jnp.where(hi_row, c1, c0)   # (LP, 2K)


def anal_fused_vpu(f_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max, s_len,
                   lp_size=128, interpret=True):
    """VPU fused analysis on the packed grid.

    f_pk   : (n_slots, 2, 2K, Rf1, 128) gathered rfft rows per segment,
             ring-shrunk like ``tab_pk`` (Rf1 = R1 on multi-row grids)
    tab_pk : (n_slots, 2, 4, Rf1, 128) f32 anal-direction phase tables
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    """
    n_slots, n_seg, K2 = f_pk.shape[:3]
    R1 = x2d.shape[0]
    assert n_seg == 2 and R1 % 8 == 0 and K2 % 2 == 0
    rf = f_pk.shape[3] if R1 == 8 else 8
    assert f_pk.shape[3] == tab_pk.shape[3] == (rf if R1 == 8 else R1)
    idx = ((lambda s, rb, sp, *_refs: (s, 0, 0, 0, 0)) if R1 == 8 else
           (lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)))
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1 // 8, S // lp_size)
    kernel = functools.partial(_anal_fused_vpu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, rf=rf)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 4, rf, 128), idx),
                pl.BlockSpec((1, 2, K2, rf, 128), idx),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, 8, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, tab_pk, f_pk)


def _anal_fused_mxu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                           x_ref, pmm_ref, pms_ref, tab_ref, f_ref,
                           out_ref, pp_ref, pc_ref, sc_ref, panel_ref, *,
                           lp_size, n_k, bf16):
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]                            # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]

    f = f_ref[0]                              # (2, 128, 2K)
    ds = []
    for seg in (0, 1):
        f_re, f_im = f[seg][:, :n_k], f[seg][:, n_k:]
        t = tab_ref[0, seg][:, 0, :]          # (4, 128)
        d_re = t[0][:, None] * f_re + t[1][:, None] * f_im
        d_im = t[2][:, None] * f_re + t[3][:, None] * f_im
        d = jnp.concatenate([d_re, d_im], axis=1)     # (128, 2K)
        ds.append(d.astype(jnp.bfloat16) if bf16 else d)

    carry = _fill_panel(panel_ref, x, m0, m1, jsw, base, lp_size,
                        pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...]                    # (LP, 128)
    if bf16:
        panel = panel.astype(jnp.bfloat16)
    dims = (((1,), (0,)), ((), ()))           # contract over rings(128)
    c0 = jax.lax.dot_general(panel, ds[0], dims,
                             preferred_element_type=jnp.float32)
    c1 = jax.lax.dot_general(panel, ds[1], dims,
                             preferred_element_type=jnp.float32)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    out_ref[0] += jnp.where(hi_row, c1, c0)


def anal_fused_mxu(f_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max, s_len,
                   bf16=False, lp_size=128, interpret=True):
    """MXU fused analysis (ring-contraction matmul + in-kernel rotation).

    f_pk   : (n_slots, 2, R, 2K) gathered rfft rows (ring-major)
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    """
    n_slots, n_seg, R, K2 = f_pk.shape
    R1 = R // 128
    assert n_seg == 2 and R % 128 == 0 and K2 % 2 == 0
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1, S // lp_size)
    kernel = functools.partial(_anal_fused_mxu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, bf16=bf16)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 4, 1, 128),
                             lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)),
                pl.BlockSpec((1, 2, 128, K2),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
      pms_pk.reshape(n_slots, 2, R1, 128),
      tab_pk.reshape(n_slots, 2, 4, R1, 128), f_pk)


# =============================================================================
# Host chains: packing + FFT around the kernels, adjoint-paired
# =============================================================================


def _prep(lo, x, pmm, pms, var):
    """Ring padding + per-slot packing shared by both directions.

    ``Rf1`` is the ring-shrunk row-block count for data operands (f rows,
    phase tables): on a single-row-block VPU grid only the rows holding
    real rings ship to the kernel (interpret-mode block fetches are slow
    per byte); the zero padding rows are rebuilt in-kernel (`_pad_rows`).
    """
    from repro.kernels import ops as kops
    R = x.shape[0]
    Rp = kops._pad_to(R, 1024 if var == "vpu" else 128)
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_pk = kops._pack_rows(jnp.pad(pmm, ((0, 0), (0, Rp - R))), lo)
    pms_pk = kops._pack_rows(jnp.pad(pms, ((0, 0), (0, Rp - R))), lo)
    R1 = Rp // 128
    Rf1 = kops._pad_to(R, 128) // 128 if (var == "vpu" and R1 == 8) else R1
    return (Rp, R1, Rf1, x_p.reshape(R1, 128),
            pmm_pk.reshape(lo.n_slots, 2, R1, 128),
            pms_pk.reshape(lo.n_slots, 2, R1, 128))


def _pack_tables(m_vals, phi0, n, direction, lo, Rf1):
    """(M, 4, R) f64 rotation tables -> (n_slots, 2, 4, Rf1, 128) f32,
    ring-shrunk to the kernels' data-operand row count."""
    from repro.core import phase
    from repro.kernels import ops as kops
    tabs = phase.uniform_rotation_tables(m_vals, phi0, n, direction)
    R = tabs.shape[-1]
    t = jnp.asarray(np.pad(tabs, ((0, 0), (0, 0), (0, Rf1 * 128 - R))),
                    jnp.float32)
    return kops._pack_rows(t, lo).reshape(lo.n_slots, 2, 4, Rf1, 128)


def _synth_chain(a, m_vals, x, pmm, pms, *, l_max, n, phi0, var, bf16, lo,
                 lp_size, interpret):
    """Weight-free fused synthesis: a (M, L1, 2K) f32 -> maps (R, n, K)."""
    from repro.core import phase
    from repro.kernels import ops as kops
    M, L1, K2 = a.shape
    n_k = K2 // 2
    R = x.shape[0]
    a_pk = kops._pack_a(a, lo)
    Rp, R1, Rf1, x2d, pmm2, pms2 = _prep(lo, x, pmm, pms, var)
    tab_pk = _pack_tables(m_vals, phi0, n, "synth", lo, Rf1)
    pmaps = kops._pack_maps(lo)
    if var == "vpu":
        out = synth_fused_vpu(a_pk, pmaps, x2d, pmm2, pms2, tab_pk,
                              l_max=l_max, lp_size=lp_size,
                              interpret=interpret)
        out = jnp.moveaxis(out, 2, -1).reshape(lo.n_slots, 2, Rp, K2)
    else:
        out = synth_fused_mxu(a_pk, pmaps, x2d, pmm2, pms2, tab_pk,
                              l_max=l_max, bf16=bf16, lp_size=lp_size,
                              interpret=interpret)
    seg = out.reshape(lo.n_slots * 2, Rp, K2)
    h = kops._unpack_rows(seg, lo, M)[:, :R, :]       # (M, R, 2K) H rows
    bins, _, _ = phase.uniform_bin_maps(m_vals, n)
    half = n // 2 + 1
    hc = (h[..., :n_k] + 1j * h[..., n_k:]).astype(jnp.complex64)
    H = jnp.zeros((R, half, n_k), jnp.complex64)
    H = H.at[:, jnp.asarray(bins)].add(jnp.moveaxis(hc, 0, 1))
    return (jnp.fft.irfft(H, n=n, axis=1) * n).astype(jnp.float32)


def _anal_chain(maps_w, m_vals, x, pmm, pms, *, l_max, n, phi0, var, bf16,
                lo, lp_size, interpret):
    """Weight-free fused analysis core: (already ring-weighted) maps
    (R, n, K) f32 -> a (M, L1, 2K) f32."""
    from repro.core import phase
    from repro.kernels import ops as kops
    R = maps_w.shape[0]
    F = jnp.fft.rfft(maps_w.astype(jnp.float32), axis=1)   # (R, half, K)
    bins, _, _ = phase.uniform_bin_maps(m_vals, n)
    Fm = F[:, jnp.asarray(bins), :]                        # (R, M, K)
    f = jnp.concatenate([jnp.moveaxis(jnp.real(Fm), 1, 0),
                         jnp.moveaxis(jnp.imag(Fm), 1, 0)],
                        axis=-1).astype(jnp.float32)       # (M, R, 2K)
    K2 = f.shape[-1]
    Rp, R1, Rf1, x2d, pmm2, pms2 = _prep(lo, x, pmm, pms, var)
    f_pk = kops._pack_rows(
        jnp.pad(f, ((0, 0), (0, Rf1 * 128 - R), (0, 0))), lo)
    tab_pk = _pack_tables(m_vals, phi0, n, "anal", lo, Rf1)
    pmaps = kops._pack_maps(lo)
    if var == "vpu":
        fk = jnp.moveaxis(f_pk.reshape(lo.n_slots, 2, Rf1, 128, K2), -1, 2)
        out = anal_fused_vpu(fk, pmaps, x2d, pmm2, pms2, tab_pk,
                             l_max=l_max, s_len=lo.S, lp_size=lp_size,
                             interpret=interpret)
    else:
        out = anal_fused_mxu(f_pk.reshape(lo.n_slots, 2, Rp, K2), pmaps,
                             x2d, pmm2, pms2, tab_pk, l_max=l_max,
                             s_len=lo.S, bf16=bf16, lp_size=lp_size,
                             interpret=interpret)
    return kops._unpack_alm(out, lo)


def _resolve(m_vals, l_max, lp_size, lo, interpret):
    from repro.kernels import pack as kpack
    from repro.kernels.ops import should_interpret
    if lo is None:
        lo = kpack.build_layout(np.asarray(m_vals), l_max, lp_size=lp_size)
    if interpret is None:
        interpret = should_interpret()
    return lo, interpret


def fused_synth(a, m_vals, x, pmm, pms, *, l_max, n, phi0, variant="vpu",
                bf16=False, lo=None, lp_size=128, interpret=None):
    """Differentiable fused synthesis: a (M, L1, 2K) f32 -> maps (R, n, K).

    Adjoint: the VJP is the per-m fac-compensated fused analysis core of
    the (unweighted) map cotangent -- the whole-chain analogue of the
    staged pipeline's composed transposes (fac commutes with the Legendre
    stage because it is block-diagonal per m)."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret)
    kw = dict(l_max=l_max, n=n, phi0=phi0, var=variant, bf16=bf16, lo=lo,
              lp_size=lp_size, interpret=interpret)
    fac = _fac_rows(m_vals, jnp.float32)

    def fwd(res, a_):
        x_, pmm_, pms_ = res
        return _synth_chain(a_, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, t):
        x_, pmm_, pms_ = res
        return fac * _anal_chain(t, m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), a)


def fused_anal(maps, weights, m_vals, x, pmm, pms, *, l_max, n, phi0,
               variant="vpu", bf16=False, lo=None, lp_size=128,
               interpret=None):
    """Differentiable fused analysis: maps (R, n, K) -> a (M, L1, 2K) f32.

    Ring quadrature weights are applied to the maps *outside* the linear
    core (they commute with the phi-axis FFT), keeping the core's adjoint
    the weight-free fused synthesis of the fac-normalised cotangent."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret)
    kw = dict(l_max=l_max, n=n, phi0=phi0, var=variant, bf16=bf16, lo=lo,
              lp_size=lp_size, interpret=interpret)
    fac = _fac_rows(m_vals, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    maps_w = jnp.asarray(maps, jnp.float32) * w[:, None, None]

    def fwd(res, mw):
        x_, pmm_, pms_ = res
        return _anal_chain(mw, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, g):
        x_, pmm_, pms_ = res
        return _synth_chain(g / fac, m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), maps_w)
