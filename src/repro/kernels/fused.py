"""Fused Legendre+phase Pallas pipeline (single-kernel inverse/direct SHT
stage pair).

The staged pipeline (kernels/ops.py + core/phase.py) materialises the
intermediate ``delta_m(r)`` rows in HBM between the Legendre kernel and the
host phase stage -- the exact traffic the paper identifies as the GPU
bottleneck of the inverse transform, and what libsharp's fused ring-major
loop avoids.  The kernels here keep the per-ring accumulation on-chip:

  * synthesis: the packed-slot Legendre accumulate is contracted per panel
    and immediately rotated by a per-(row, ring) *phase table*
    (core.phase.uniform_rotation_tables / bucket_rotation_tables -- cos/sin
    of m*phi0 with the engine's conjugate-wrap and Nyquist handling baked
    in), so the kernel's only output is the rotated spectrum-row block.
    The unrotated Delta never exists as a pallas output ref (asserted on
    the jaxpr in tests/test_fused.py).
  * analysis: the gathered FFT rows are rotated into Delta in-kernel (once
    per (slot, ring-block), hoisted out of the panel loop into a VMEM
    scratch) and contracted against the recurrence panel; only packed a_lm
    l-streams leave the kernel.

Every plan shape the staged path serves dispatches through here:

  * **spin-2**: the packed row set carries both lambda^{+-} recurrences
    (``m_vals``/``mp_vals`` from legendre._spin_rows, coefficients from
    spin_pack_alm), the kernels run the generalised Wigner-d step
    (`_step(spin=2, ...)`), and the host epilogue/prologue converts between
    the +-pair and Q/U through the channel axis.  The e^{+-i m phi0}
    rotation is complex-linear and both pair rows share one m, so rotating
    in-kernel commutes with the pair (un)packing exactly.
  * **equator fold**: the kernels carry a plane axis (north | south).  The
    parity split of the coefficient rows happens in-register -- for stream
    position j of a panel, (l + m) mod 2 == (base + j - seam) mod 2, an
    m-independent mask -- and the north/south symmetry combine
    (north = even + odd, south = even - odd) runs in-kernel on the
    contracted planes, replacing the staged path's host reshapes.
  * **bucket (ragged HEALPix)**: the rotation tables are plain
    e^{+-i m phi0(r)} (`phase.bucket_rotation_tables`); the alias-fold
    scatter/gather through `phase.bucket_bin_maps` wraps the kernel on the
    host side (`_bucket_scatter`/`_bucket_gather`), so the Delta rows skip
    the staged path's HBM round-trip between the Legendre kernel and the
    bucket FFT engine.

Beyond the fusion itself the kernels carry raw-speed upgrades over the
staged ones:

  * panel-contraction accumulate: recurrence values stream into a VMEM
    value panel (via the exact shared `_step`, so fused synthesis is
    bit-identical to staged) and are contracted against the coefficient
    block once per panel (one dot) instead of a broadcast-FMA per l-step.
  * ring-shrunk data operands: on the VPU layout the ring axis is padded
    to 1024 lanes but only ``ceil(R/128)`` row blocks carry data, so the
    ``f``/phase-table operands are shipped at that reduced row count and
    the zero padding rows are rebuilt in-register (`_pad_rows`).
  * the MXU synthesis accumulates the panel contraction into a VMEM
    scratch and rotates **once** per ring block (at the last panel),
    not per panel -- undoing per-step rotation+flush traffic was the
    root-cause fix of the historical fused-MXU < 1x regression.

The synthesis VPU kernel double-buffers its per-panel output flush
(`hbuf` two-slot scratch): panel p's contracted+rotated block is written
to HBM while panel p+1's recurrence values stream into the value panel.

The MXU variants take ``bf16=True`` to run the panel contraction in
bfloat16 with float32 accumulation (`preferred_element_type`); the
measured error band rides in benchmarks/bench_recurrence.py (`bf16_err`
rows).

The only shapes still staged: equator fold on a bucket phase stage, and
spin-2 on a uniform grid at the Nyquist alias point (n_phi == 2*m_max)
-- see Plan._fusion_eligibility / Plan.describe()["fusion"].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.autodiff import linear_pair
from repro.kernels.legendre_pallas import _CompilerParams, _pad_rows, _step

__all__ = [
    "synth_fused_vpu", "synth_fused_mxu",
    "anal_fused_vpu", "anal_fused_mxu",
    "fused_synth", "fused_anal",
    "fused_synth_bucket", "fused_anal_bucket",
]

def _fill_panel(panel_ref, x, m0, m1, mp0, mp1, jsw, base, lp_size, spin,
                pmm0, pms0, pmm1, pms1, carry, l_max=None):
    """Stream the split-seam recurrence values of one panel into the VMEM
    value panel via the exact shared `_step`.  Returns the (pp, pc, sc)
    carry.  Segment l0 == max(m, |m'|) (== m on the scalar path).

    With ``l_max`` given, each segment's loop stops at its true stream
    end (l == l_max) instead of running to the panel edge: positions past
    the end keep whatever the scratch panel last held, which is safe only
    for consumers that zero those rows on the other dot operand (the
    packed ``a`` rows there are zero by construction).  The min-max slot
    pairing leaves ~(S - l_max - 2) dead positions per slot, so the MXU
    kernels skip that fraction of the serial recurrence."""
    j0 = jnp.clip(jsw - base, 0, lp_size)

    def seg_gen(m, mp_v, l_base, pmm, pms):
        m_f = m.astype(jnp.float32)
        mp_f = mp_v.astype(jnp.float32)

        def gen(j, carry):
            pp, pc, sc = carry
            pp, pc, sc, val = _step(spin, l_base + j, m_f, mp_f, x, pp, pc,
                                    sc, pmm, pms)
            panel_ref[pl.ds(j, 1)] = val.reshape((1,) + panel_ref.shape[1:])
            return pp, pc, sc

        return gen

    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))
    if l_max is None:
        end0, end1 = j0, lp_size
    else:
        end0 = jnp.clip(l_max + 1 - l00 - base, 0, j0)
        end1 = jnp.clip(jsw + l_max + 1 - l01 - base, j0, lp_size)
    carry = jax.lax.fori_loop(
        0, end0, seg_gen(m0, mp0, l00 + base, pmm0, pms0), carry)
    return jax.lax.fori_loop(
        j0, end1, seg_gen(m1, mp1, l01 + base - jsw, pmm1, pms1), carry)


def _hi_row_mask(base, jsw, lp_size):
    iot = jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
    return (base + iot) >= jsw


def _parity_masks(base, jsw, lp_size):
    """(l + m) even per packed stream position, per segment -- the fold
    plane split.  2m is even so only the panel-local l offset counts:
    seg0 l = l0 + base + j, seg1 l = l0 + base + j - seam."""
    iot = jax.lax.broadcasted_iota(jnp.int32, (lp_size, 1), 0)
    par0 = ((base + iot) % 2) == 0
    par1 = ((base + iot - jsw) % 2) == 0
    return par0, par1


# =============================================================================
# Fused synthesis: packed a_lm -> rotated spectrum rows, one kernel
# =============================================================================


def _synth_fused_vpu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                            x_ref, pmm_ref, pms_ref, tab_ref, a_ref,
                            out_ref, pp_ref, pc_ref, sc_ref, panel_ref,
                            hbuf_ref, *, lp_size, n_k, n_sp, rf, spin, n_pl):
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size

    @pl.when(sp == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    # double-buffered flush: panel sp-1's contracted+rotated block drains
    # to the output ref while this panel's recurrence values stream in
    @pl.when(sp > 0)
    def _flush_prev():
        out_ref[0] += hbuf_ref[pl.ds((sp - 1) % 2, 1)][0]

    x = x_ref[...]                            # (8, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    carry = _fill_panel(panel_ref, x, m0, m1, mp0, mp1, jsw, base, lp_size,
                        spin, pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...].reshape(lp_size, -1)       # (LP, 8*128)
    a_blk = a_ref[0]                          # (LP, 2K)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    if n_pl == 2:
        par0, par1 = _parity_masks(base, jsw, lp_size)
    hs = []
    for seg in (0, 1):
        a_seg = jnp.where(hi_row if seg else ~hi_row, a_blk, 0.0)
        if n_pl == 2:
            par = par1 if seg else par0
            a_seg = jnp.concatenate([jnp.where(par, a_seg, 0.0),
                                     jnp.where(par, 0.0, a_seg)], axis=1)
        d = jax.lax.dot_general(a_seg, panel, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        d = d.reshape(n_pl * 2 * n_k, 8, 128)
        if n_pl == 2:
            e, o = d[:2 * n_k], d[2 * n_k:]
            planes = (e + o, e - o)           # north | south
        else:
            planes = (d,)
        hp = []
        for pi, dpl in enumerate(planes):
            d_re, d_im = dpl[:n_k], dpl[n_k:]         # (K, 8, 128) each
            t = _pad_rows(tab_ref[0, seg, pi], rf)    # (4, 8, 128)
            h_re = t[0] * d_re + t[1] * d_im
            h_im = t[2] * d_re + t[3] * d_im
            hp.append(jnp.concatenate([h_re, h_im], axis=0))
        hs.append(jnp.stack(hp, axis=0))      # (n_pl, 2K, 8, 128)
    hbuf_ref[pl.ds(sp % 2, 1)] = jnp.stack(hs, axis=0)[None]

    @pl.when(sp == n_sp - 1)
    def _flush_last():
        out_ref[0] += hbuf_ref[pl.ds(sp % 2, 1)][0]


def synth_fused_vpu(a_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max,
                    spin=0, lp_size=128, interpret=True):
    """VPU fused synthesis on the packed (slot, panel) grid.

    a_pk   : (n_slots, S, 2K) f32 packed coefficient streams
    maps   : (m0, m1, mp0, mp1, seed) i32 per-slot scalar-prefetch arrays
    x2d    : (R1, 128) f32;  pmm_pk/pms_pk: (n_slots, 2, R1, 128)
    tab_pk : (n_slots, 2, n_pl, 4, Rf1, 128) f32 per-(segment, plane) phase
             tables, ring-shrunk to ``Rf1`` real row blocks (= R1 on
             multi-row grids); n_pl == 2 on the equator-fold path
    returns: (n_slots, 2, n_pl, 2K, R1, 128) f32 rotated spectrum rows
    """
    n_slots, S, K2 = a_pk.shape
    n_pl = tab_pk.shape[2]
    R1 = x2d.shape[0]
    assert S % lp_size == 0 and R1 % 8 == 0 and K2 % 2 == 0
    n_sp = S // lp_size
    rf = tab_pk.shape[4] if R1 == 8 else 8
    assert tab_pk.shape[4] == (rf if R1 == 8 else R1)
    tab_spec = pl.BlockSpec((1, 2, n_pl, 4, rf, 128),
                            (lambda s, rb, sp, *_refs: (s, 0, 0, 0, 0, 0))
                            if R1 == 8 else
                            (lambda s, rb, sp, *_refs: (s, 0, 0, 0, rb, 0)))
    grid = (n_slots, R1 // 8, n_sp)
    kernel = functools.partial(_synth_fused_vpu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, n_sp=n_sp, rf=rf, spin=spin,
                               n_pl=n_pl)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                tab_spec,
                pl.BlockSpec((1, lp_size, K2),
                             lambda s, rb, sp, *_refs: (s, sp, 0)),
            ],
            out_specs=pl.BlockSpec((1, 2, n_pl, K2, 8, 128),
                                   lambda s, rb, sp, *_refs:
                                   (s, 0, 0, 0, rb, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, 8, 128), jnp.float32),
                pltpu.VMEM((2, 2, n_pl, K2, 8, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, 2, n_pl, K2, R1, 128),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, tab_pk, a_pk)


def _tables_identity(tabs):
    """True iff the (host-side) rotation tables are exactly the identity
    rotation on every plane and ring -- any uniform grid with phi0 == 0
    (the Gauss-Legendre/ECP default).  The MXU kernels then drop the
    table operand and the rotate epilogue entirely; ``1*re + 0*im == re``
    exactly in f32, so the skip is bit-identical -- it just stops
    fetching and applying a dead block every grid step.  Fold tables
    never qualify: their south plane zeroes the rows past the mirror
    count, and that masking must stay."""
    t = np.asarray(tabs)
    return bool(np.all(t[:, :, 0] == 1.0) and np.all(t[:, :, 3] == 1.0)
                and np.all(t[:, :, 1] == 0.0) and np.all(t[:, :, 2] == 0.0))


def _synth_fused_mxu_kernel(*refs, lp_size, n_k, n_sp, l_max, bf16, spin,
                            n_pl, rot):
    (m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref, x_ref, pmm_ref, pms_ref,
     *rest) = refs
    rest = list(rest)
    tab_ref = rest.pop(0) if rot else None
    a_ref, out_ref, pp_ref, pc_ref, sc_ref, panel_ref = rest[:6]
    acc_ref = rest[6] if n_sp > 1 else None
    si = pl.program_id(0)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size
    K2 = 2 * n_k

    @pl.when(sp == 0)
    def _init():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)
        # the truncated fill leaves the dead stream tail unwritten; one
        # vectorized zero write keeps those rows from reading scratch
        # garbage (they still multiply all-zero a rows, so any finite
        # value is correct -- NaN/Inf garbage is not)
        panel_ref[...] = jnp.zeros_like(panel_ref)
        if n_sp > 1:
            acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                            # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    # truncated fill: stop at each segment's true stream end; the stale
    # rows past it hit all-zero packed-a rows, so the dot is unchanged
    carry = _fill_panel(panel_ref, x, m0, m1, mp0, mp1, jsw, base, lp_size,
                        spin, pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]),
                        l_max=l_max)
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...]                    # (LP, 128)
    if bf16:
        panel = panel.astype(jnp.bfloat16)
    a_blk = a_ref[0]                          # (LP, 2K)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    if n_pl == 2:
        par0, par1 = _parity_masks(base, jsw, lp_size)

    # two narrow masked dots, as in the staged kernel: a single wide
    # [seg0 | seg1] contraction is measurably slower than the narrow pair
    def contract(seg):
        a_seg = jnp.where(hi_row if seg else ~hi_row, a_blk, 0.0)
        if n_pl == 2:
            par = par1 if seg else par0
            a_seg = jnp.concatenate([jnp.where(par, a_seg, 0.0),
                                     jnp.where(par, 0.0, a_seg)], axis=1)
        return jax.lax.dot_general(panel, a_seg, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    def flush(seg, cs):                       # (128, n_pl*2K)
        if n_pl == 2:
            e, o = cs[:, :K2], cs[:, K2:]
            planes = (e + o, e - o)           # north | south
        else:
            planes = (cs,)
        for pi, cp in enumerate(planes):
            if rot:
                c_re, c_im = cp[:, :n_k], cp[:, n_k:]
                t = tab_ref[0, seg, pi][:, 0, :]  # (4, 128)
                cp = jnp.concatenate(
                    [t[0][:, None] * c_re + t[1][:, None] * c_im,
                     t[2][:, None] * c_re + t[3][:, None] * c_im],
                    axis=1)
            out_ref[0, seg, pi] = cp

    if n_sp == 1:
        for seg in (0, 1):
            flush(seg, contract(seg))
    else:
        for seg in (0, 1):
            acc_ref[seg] += contract(seg)

        @pl.when(sp == n_sp - 1)
        def _rotate_flush():
            for seg in (0, 1):
                flush(seg, acc_ref[seg])


def synth_fused_mxu(a_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max,
                    spin=0, bf16=False, lp_size=128, interpret=True,
                    rot=True):
    """MXU fused synthesis (panel matmul + per-ring-block rotation).

    Layouts as :func:`synth_fused_vpu` except rings advance 128 at a time;
    tab_pk is (n_slots, 2, n_pl, 4, R1, 128); returns
    (n_slots, 2, n_pl, R, 2K) with R = R1 * 128.  ``bf16=True`` contracts
    the recurrence panel in bfloat16 with f32 accumulation.  ``rot=False``
    (identity tables, see :func:`_tables_identity`) drops the table
    operand and the rotate epilogue.
    """
    n_slots, S, K2 = a_pk.shape
    n_pl = tab_pk.shape[2]
    R1 = x2d.shape[0]
    R = R1 * 128
    assert S % lp_size == 0 and K2 % 2 == 0
    n_sp = S // lp_size
    if bf16:
        a_pk = a_pk.astype(jnp.bfloat16)
    grid = (n_slots, R1, n_sp)
    kernel = functools.partial(_synth_fused_mxu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, n_sp=n_sp, l_max=l_max,
                               bf16=bf16, spin=spin, n_pl=n_pl, rot=rot)
    in_specs = [
        pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
        pl.BlockSpec((1, 2, 1, 128),
                     lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
        pl.BlockSpec((1, 2, 1, 128),
                     lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
    ]
    operands = [x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
                pms_pk.reshape(n_slots, 2, R1, 128)]
    if rot:
        in_specs.append(
            pl.BlockSpec((1, 2, n_pl, 4, 1, 128),
                         lambda s, rb, sp, *_refs: (s, 0, 0, 0, rb, 0)))
        operands.append(tab_pk)
    in_specs.append(pl.BlockSpec((1, lp_size, K2),
                                 lambda s, rb, sp, *_refs: (s, sp, 0)))
    operands.append(a_pk)
    scratch = [
        pltpu.VMEM((1, 128), jnp.float32),
        pltpu.VMEM((1, 128), jnp.float32),
        pltpu.VMEM((1, 128), jnp.int32),
        pltpu.VMEM((lp_size, 128), jnp.float32),
    ]
    if n_sp > 1:
        scratch.append(pltpu.VMEM((2, 128, n_pl * K2), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 2, n_pl, 128, K2),
                                   lambda s, rb, sp, *_refs:
                                   (s, 0, 0, rb, 0)),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, 2, n_pl, R, K2),
                                       jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(*maps, *operands)


# =============================================================================
# Fused analysis: gathered FFT rows -> packed a_lm l-streams, one kernel
# =============================================================================


def _anal_fused_vpu_kernel(m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref,
                           x_ref, pmm_ref, pms_ref, tab_ref, f_ref,
                           out_ref, pp_ref, pc_ref, sc_ref, panel_ref,
                           dbuf_ref, *, lp_size, n_k, rf, spin, n_pl):
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size
    K2 = 2 * n_k

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    # rotate the gathered spectrum rows into Delta once per (slot, ring
    # block) -- l-independent, so hoisted out of the panel loop into a
    # VMEM scratch instead of recomputed every grid step
    @pl.when(sp == 0)
    def _rotate():
        f = _pad_rows(f_ref[0], rf)           # (2, n_pl, 2K, 8, 128)
        for seg in (0, 1):
            dp = []
            for pi in range(n_pl):
                f_re, f_im = f[seg, pi, :n_k], f[seg, pi, n_k:]
                t = _pad_rows(tab_ref[0, seg, pi], rf)    # (4, 8, 128)
                d_re = t[0] * f_re + t[1] * f_im
                d_im = t[2] * f_re + t[3] * f_im
                dp.append(jnp.concatenate([d_re, d_im], axis=0))
            if n_pl == 2:
                # even/odd planes: the l-parity selection happens on the
                # contracted rows below
                dcat = jnp.concatenate([dp[0] + dp[1], dp[0] - dp[1]],
                                       axis=0)
            else:
                dcat = dp[0]
            dbuf_ref[seg] = dcat              # (n_pl*2K, 8, 128)

    x = x_ref[...]
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    carry = _fill_panel(panel_ref, x, m0, m1, mp0, mp1, jsw, base, lp_size,
                        spin, pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]))
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...].reshape(lp_size, -1)       # (LP, 8*128)
    dims = (((1,), (1,)), ((), ()))           # NT gemm over the ring tile
    c0 = jax.lax.dot_general(panel, dbuf_ref[0].reshape(n_pl * K2, -1),
                             dims, preferred_element_type=jnp.float32)
    c1 = jax.lax.dot_general(panel, dbuf_ref[1].reshape(n_pl * K2, -1),
                             dims, preferred_element_type=jnp.float32)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    if n_pl == 2:
        par0, par1 = _parity_masks(base, jsw, lp_size)
        c0 = jnp.where(par0, c0[:, :K2], c0[:, K2:])
        c1 = jnp.where(par1, c1[:, :K2], c1[:, K2:])
    out_ref[0] += jnp.where(hi_row, c1, c0)   # (LP, 2K)


def anal_fused_vpu(f_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max, s_len,
                   spin=0, lp_size=128, interpret=True):
    """VPU fused analysis on the packed grid.

    f_pk   : (n_slots, 2, n_pl, 2K, Rf1, 128) gathered per-plane FFT rows
             per segment, ring-shrunk like ``tab_pk``
    tab_pk : (n_slots, 2, n_pl, 4, Rf1, 128) f32 anal-direction tables
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    """
    n_slots, n_seg, n_pl, K2 = f_pk.shape[:4]
    R1 = x2d.shape[0]
    assert n_seg == 2 and R1 % 8 == 0 and K2 % 2 == 0
    rf = f_pk.shape[4] if R1 == 8 else 8
    assert f_pk.shape[4] == tab_pk.shape[4] == (rf if R1 == 8 else R1)
    idx = ((lambda s, rb, sp, *_refs: (s, 0, 0, 0, 0, 0)) if R1 == 8 else
           (lambda s, rb, sp, *_refs: (s, 0, 0, 0, rb, 0)))
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1 // 8, S // lp_size)
    kernel = functools.partial(_anal_fused_vpu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, rf=rf, spin=spin, n_pl=n_pl)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((8, 128), lambda s, rb, sp, *_refs: (rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, 8, 128),
                             lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
                pl.BlockSpec((1, 2, n_pl, 4, rf, 128), idx),
                pl.BlockSpec((1, 2, n_pl, K2, rf, 128), idx),
            ],
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.float32),
                pltpu.VMEM((8, 128), jnp.int32),
                pltpu.VMEM((lp_size, 8, 128), jnp.float32),
                pltpu.VMEM((2, n_pl * K2, 8, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, x2d, pmm_pk, pms_pk, tab_pk, f_pk)


def _anal_fused_mxu_kernel(*refs, lp_size, n_k, l_max, bf16, spin, n_pl,
                           rot):
    (m0_ref, m1_ref, mp0_ref, mp1_ref, seed_ref, x_ref, pmm_ref, pms_ref,
     *rest) = refs
    rest = list(rest)
    tab_ref = rest.pop(0) if rot else None
    f_ref, out_ref, pp_ref, pc_ref, sc_ref, panel_ref, dbuf_ref = rest
    si = pl.program_id(0)
    rb = pl.program_id(1)
    sp = pl.program_id(2)
    m0, m1 = m0_ref[si], m1_ref[si]
    mp0, mp1 = mp0_ref[si], mp1_ref[si]
    jsw = seed_ref[si]
    base = sp * lp_size
    K2 = 2 * n_k

    @pl.when(sp == 0)
    def _init_carry():
        pp_ref[...] = jnp.zeros_like(pp_ref)
        pc_ref[...] = jnp.zeros_like(pc_ref)
        sc_ref[...] = jnp.zeros_like(sc_ref)

    @pl.when(rb == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    # keep the truncated fill's unwritten tail rows finite (their
    # contracted output lands on packed positions the unpack never
    # gathers, but NaN scratch garbage would otherwise propagate)
    @pl.when(sp == 0)
    def _init_panel():
        panel_ref[...] = jnp.zeros_like(panel_ref)

    # rotate the gathered spectrum rows into Delta once per (slot, ring
    # block) -- l-independent, so hoisted out of the panel loop into a
    # VMEM scratch instead of recomputed every grid step
    @pl.when(sp == 0)
    def _rotate():
        f = f_ref[0]                          # (2, n_pl, 128, 2K)
        for seg in (0, 1):
            dp = []
            for pi in range(n_pl):
                fs = f[seg, pi]
                if rot:
                    f_re, f_im = fs[:, :n_k], fs[:, n_k:]
                    t = tab_ref[0, seg, pi][:, 0, :]  # (4, 128)
                    fs = jnp.concatenate(
                        [t[0][:, None] * f_re + t[1][:, None] * f_im,
                         t[2][:, None] * f_re + t[3][:, None] * f_im],
                        axis=1)
                dp.append(fs)
            if n_pl == 2:
                dbuf_ref[seg] = jnp.concatenate([dp[0] + dp[1],
                                                 dp[0] - dp[1]], axis=1)
            else:
                dbuf_ref[seg] = dp[0]

    x = x_ref[...]                            # (1, 128)
    pmm0, pmm1 = pmm_ref[0, 0], pmm_ref[0, 1]
    pms0, pms1 = pms_ref[0, 0], pms_ref[0, 1]
    # truncated fill: rows past each segment's stream end stay stale, so
    # their contracted output rows are garbage -- but those packed
    # positions are never gathered by the unpack (alm_src == -1 there)
    carry = _fill_panel(panel_ref, x, m0, m1, mp0, mp1, jsw, base, lp_size,
                        spin, pmm0, pms0, pmm1, pms1,
                        (pp_ref[...], pc_ref[...], sc_ref[...]),
                        l_max=l_max)
    pp_ref[...], pc_ref[...], sc_ref[...] = carry

    panel = panel_ref[...]                    # (LP, 128)
    d = dbuf_ref[...]                         # (2, 128, W)
    if bf16:
        panel = panel.astype(jnp.bfloat16)
        d = d.astype(jnp.bfloat16)
    # two narrow ring contractions (one per segment), as in the staged
    # kernel -- a single wide [seg0 | seg1] dot is measurably slower
    c0 = jax.lax.dot_general(panel, d[0], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    c1 = jax.lax.dot_general(panel, d[1], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    hi_row = _hi_row_mask(base, jsw, lp_size)
    if n_pl == 2:
        par0, par1 = _parity_masks(base, jsw, lp_size)
        c0 = jnp.where(par0, c0[:, :K2], c0[:, K2:])
        c1 = jnp.where(par1, c1[:, :K2], c1[:, K2:])
    out_ref[0] += jnp.where(hi_row, c1, c0)


def anal_fused_mxu(f_pk, maps, x2d, pmm_pk, pms_pk, tab_pk, *, l_max, s_len,
                   spin=0, bf16=False, lp_size=128, interpret=True,
                   rot=True):
    """MXU fused analysis (ring-contraction matmul + hoisted rotation).

    f_pk   : (n_slots, 2, n_pl, R, 2K) gathered per-plane FFT rows
    returns: (n_slots, S, 2K) f32 packed l-stream rows
    ``rot=False`` (identity tables) drops the table operand and the
    rotate half of the per-ring-block prologue.
    """
    n_slots, n_seg, n_pl, R, K2 = f_pk.shape
    R1 = R // 128
    assert n_seg == 2 and R % 128 == 0 and K2 % 2 == 0
    S = int(s_len)
    assert S % lp_size == 0
    grid = (n_slots, R1, S // lp_size)
    kernel = functools.partial(_anal_fused_mxu_kernel, lp_size=lp_size,
                               n_k=K2 // 2, l_max=l_max, bf16=bf16,
                               spin=spin, n_pl=n_pl, rot=rot)
    in_specs = [
        pl.BlockSpec((1, 128), lambda s, rb, sp, *_refs: (rb, 0)),
        pl.BlockSpec((1, 2, 1, 128),
                     lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
        pl.BlockSpec((1, 2, 1, 128),
                     lambda s, rb, sp, *_refs: (s, 0, rb, 0)),
    ]
    operands = [x2d, pmm_pk.reshape(n_slots, 2, R1, 128),
                pms_pk.reshape(n_slots, 2, R1, 128)]
    if rot:
        in_specs.append(
            pl.BlockSpec((1, 2, n_pl, 4, 1, 128),
                         lambda s, rb, sp, *_refs: (s, 0, 0, 0, rb, 0)))
        operands.append(tab_pk)
    in_specs.append(pl.BlockSpec((1, 2, n_pl, 128, K2),
                                 lambda s, rb, sp, *_refs: (s, 0, 0, rb, 0)))
    operands.append(f_pk)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, lp_size, K2),
                                   lambda s, rb, sp, *_refs: (s, sp, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.float32),
                pltpu.VMEM((1, 128), jnp.int32),
                pltpu.VMEM((lp_size, 128), jnp.float32),
                pltpu.VMEM((2, 128, n_pl * K2), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((n_slots, S, K2), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
    )(*maps, *operands)


# =============================================================================
# Host chains: packing + FFT/scatter around the kernels, adjoint-paired
# =============================================================================


def _prep(lo, x, pmm, pms, var):
    """Ring padding + per-slot packing shared by both directions.

    ``Rf1`` is the ring-shrunk row-block count for data operands (f rows,
    phase tables): on a single-row-block VPU grid only the rows holding
    real rings ship to the kernel (interpret-mode block fetches are slow
    per byte); the zero padding rows are rebuilt in-kernel (`_pad_rows`).
    """
    from repro.kernels import ops as kops
    R = x.shape[0]
    Rp = kops._pad_to(R, 1024 if var == "vpu" else 128)
    x_p = jnp.pad(jnp.asarray(x, jnp.float32), (0, Rp - R))
    pmm_pk = kops._pack_rows(jnp.pad(pmm, ((0, 0), (0, Rp - R))), lo)
    pms_pk = kops._pack_rows(jnp.pad(pms, ((0, 0), (0, Rp - R))), lo)
    R1 = Rp // 128
    Rf1 = kops._pad_to(R, 128) // 128 if (var == "vpu" and R1 == 8) else R1
    return (Rp, R1, Rf1, x_p.reshape(R1, 128),
            pmm_pk.reshape(lo.n_slots, 2, R1, 128),
            pms_pk.reshape(lo.n_slots, 2, R1, 128))


def _pack_tables(tabs, lo, Rf1):
    """(M, n_pl, 4, R) f64 rotation tables ->
    (n_slots, 2, n_pl, 4, Rf1, 128) f32, ring-shrunk to the kernels'
    data-operand row count."""
    from repro.kernels import ops as kops
    _, n_pl, _, R = tabs.shape
    t = jnp.asarray(np.pad(tabs, ((0, 0), (0, 0), (0, 0),
                                  (0, Rf1 * 128 - R))), jnp.float32)
    return kops._pack_rows(t, lo).reshape(lo.n_slots, 2, n_pl, 4, Rf1, 128)


def _rotation_tables(m_vals, direction, *, phase_kind, n, phi0, fold_rings,
                     n_half):
    """(M, n_pl, 4, R_kernel) f64 tables for every fused phase flavour.

    Uniform unfolded: one plane of uniform_rotation_tables.  Fold: north
    plane = rings [0, nh), south plane row i = full-grid ring R-1-i (the
    staged combine's reversal baked into the table order; rows past the
    southern count stay zero -- the odd-R equator has no mirror).  Bucket:
    one plane of the pure e^{+-i m phi0(r)} tables; the alias fold is the
    host-side scatter/gather."""
    from repro.core import phase
    if phase_kind == "bucket":
        return phase.bucket_rotation_tables(m_vals, phi0, direction)[:, None]
    full = phase.uniform_rotation_tables(m_vals, phi0, n, direction)
    if fold_rings is None:
        return full[:, None]
    nh = n_half
    ns = fold_rings - nh
    north = full[:, :, :nh]
    south = np.zeros_like(north)
    south[:, :, :ns] = full[:, :, nh:][:, :, ::-1]
    return np.stack([north, south], axis=1)


def _kernel_synth(a, tabs, x, pmm, pms, *, l_max, var, bf16, lo, lp_size,
                  interpret, spin):
    """Packed fused kernel leg: a (Mr, L1, 2K) + (Mr, n_pl, 4, R) tables ->
    rotated per-plane rows h (Mr, n_pl, R, 2K)."""
    from repro.kernels import ops as kops
    Mr = a.shape[0]
    K2 = a.shape[-1]
    n_pl = tabs.shape[1]
    R = x.shape[0]
    a_pk = kops._pack_a(a, lo)
    Rp, R1, Rf1, x2d, pmm2, pms2 = _prep(lo, x, pmm, pms, var)
    tab_pk = _pack_tables(tabs, lo, Rf1)
    pmaps = kops._pack_maps(lo)
    if var == "vpu":
        out = synth_fused_vpu(a_pk, pmaps, x2d, pmm2, pms2, tab_pk,
                              l_max=l_max, spin=spin, lp_size=lp_size,
                              interpret=interpret)
        out = jnp.moveaxis(out, 3, -1).reshape(lo.n_slots, 2, n_pl, Rp, K2)
    else:
        out = synth_fused_mxu(a_pk, pmaps, x2d, pmm2, pms2, tab_pk,
                              l_max=l_max, spin=spin, bf16=bf16,
                              lp_size=lp_size, interpret=interpret,
                              rot=not _tables_identity(tabs))
    seg = out.reshape(lo.n_slots * 2, n_pl, Rp, K2)
    return kops._unpack_rows(seg, lo, Mr)[:, :, :R, :]


def _kernel_anal(fp, tabs, x, pmm, pms, *, l_max, var, bf16, lo, lp_size,
                 interpret, spin):
    """Packed fused kernel leg: per-plane unrotated-input rows fp
    (Mr, n_pl, R, 2K) + anal tables -> packed a (Mr, L1, 2K)."""
    from repro.kernels import ops as kops
    Mr, n_pl, R, K2 = fp.shape
    Rp, R1, Rf1, x2d, pmm2, pms2 = _prep(lo, x, pmm, pms, var)
    tab_pk = _pack_tables(tabs, lo, Rf1)
    pmaps = kops._pack_maps(lo)
    f_pk = kops._pack_rows(
        jnp.pad(fp, ((0, 0), (0, 0), (0, Rf1 * 128 - R), (0, 0))), lo)
    f_pk = f_pk.reshape(lo.n_slots, 2, n_pl, Rf1, 128, K2)
    if var == "vpu":
        fk = jnp.moveaxis(f_pk, -1, 3)        # (n_slots, 2, n_pl, 2K, Rf1, 128)
        out = anal_fused_vpu(fk, pmaps, x2d, pmm2, pms2, tab_pk,
                             l_max=l_max, s_len=lo.S, spin=spin,
                             lp_size=lp_size, interpret=interpret)
    else:
        out = anal_fused_mxu(f_pk.reshape(lo.n_slots, 2, n_pl, Rp, K2),
                             pmaps, x2d, pmm2, pms2, tab_pk, l_max=l_max,
                             s_len=lo.S, spin=spin, bf16=bf16,
                             lp_size=lp_size, interpret=interpret,
                             rot=not _tables_identity(tabs))
    return kops._unpack_alm(out, lo)


def _bucket_scatter(hc, m_vals, layout, pos, neg, n_phi, out_width):
    """Host epilogue of the fused bucket synthesis: rotated rows hc
    (M, R, C) complex64 -> ring samples (R, out_width, C) f32.  The
    alias-fold scatter of core.phase._bucket_synth_body with the phase
    rotation already applied in-kernel."""
    m = np.asarray(m_vals)
    M, R, C = hc.shape
    neg_ok = jnp.asarray(m > 0)[:, None, None]
    nn = jnp.asarray(n_phi)
    out = jnp.zeros((R, out_width, C), jnp.float32)
    for B, sl in zip(layout.lengths, layout.slots):
        sl = np.asarray(sl)
        Rb = sl.shape[0]
        if Rb == 0:
            continue
        dp_b = hc[:, sl, :]                   # (M, Rb, C)
        pos_b, neg_b = pos[:, sl], neg[:, sl]
        row = np.arange(Rb, dtype=np.int32)[None, :] * B
        S = jnp.zeros((Rb * B, C), jnp.complex64)
        S = S.at[jnp.reshape(row + pos_b, (-1,))].add(
            dp_b.reshape(M * Rb, C))
        S = S.at[jnp.reshape(row + neg_b, (-1,))].add(
            jnp.where(neg_ok, jnp.conj(dp_b), 0.0).reshape(M * Rb, C))
        s = jnp.fft.ifft(S.reshape(Rb, B, C), axis=1) * B
        keep = (jnp.arange(B)[None, :] < nn[jnp.asarray(sl)][:, None]
                ).astype(jnp.float32)
        samp = jnp.real(s).astype(jnp.float32) * keep[:, :, None]
        if B < out_width:
            samp = jnp.pad(samp, ((0, 0), (0, out_width - B), (0, 0)))
        out = out.at[jnp.asarray(sl)].set(samp)
    return out


def _bucket_gather(maps_w, m_vals, layout, pos, n_phi):
    """Host prologue of the fused bucket analysis: ring samples (R, W, C)
    -> gathered UNrotated spectrum rows (M, R, C) complex64 (the in-kernel
    anal tables apply e^{-i m phi0}).  Mirrors
    core.phase._bucket_anal_core minus the phase factor."""
    M = np.asarray(m_vals).shape[0]
    R, W, C = maps_w.shape
    maps_w = maps_w.astype(jnp.float32)
    nn = jnp.asarray(n_phi)
    delta = jnp.zeros((M, R, C), jnp.complex64)
    for B, sl in zip(layout.lengths, layout.slots):
        sl = np.asarray(sl)
        if sl.shape[0] == 0:
            continue
        xb = maps_w[jnp.asarray(sl)]          # (Rb, W, C)
        xb = xb[:, :B, :] if B <= W else \
            jnp.pad(xb, ((0, 0), (0, B - W), (0, 0)))
        keep = (jnp.arange(B)[None, :] < nn[jnp.asarray(sl)][:, None]
                ).astype(jnp.float32)
        F = jnp.fft.fft(xb * keep[:, :, None], axis=1)         # (Rb, B, C)
        idx = jnp.moveaxis(jnp.asarray(pos[:, sl]), 0, 1)      # (Rb, M)
        Fm = jnp.take_along_axis(F, idx[..., None], axis=1)    # (Rb, M, C)
        delta = delta.at[:, jnp.asarray(sl), :].set(
            jnp.moveaxis(Fm, 1, 0).astype(jnp.complex64))
    return delta


def _synth_chain(a, m_vals, x, pmm, pms, *, l_max, var, bf16, lo, lp_size,
                 interpret, spin, phase_kind, n=None, phi0=None,
                 fold_rings=None, bucket=None):
    """Weight-free fused synthesis for every fused plan shape:
    a (Mr, L1, 2K) f32 -> maps (R_out, width, C) f32.  ``Mr`` is the
    kernel row count (2M lambda^{+-} rows on the spin path, C = 2K Q|U
    channels out)."""
    from repro.core import legendre as leg
    from repro.core import phase
    K2 = a.shape[-1]
    n_k = K2 // 2
    tabs = _rotation_tables(m_vals, "synth", phase_kind=phase_kind, n=n,
                            phi0=phi0, fold_rings=fold_rings,
                            n_half=x.shape[0])
    h = _kernel_synth(a, tabs, x, pmm, pms, l_max=l_max, var=var, bf16=bf16,
                      lo=lo, lp_size=lp_size, interpret=interpret, spin=spin)
    if fold_rings is not None:
        # in-kernel combine already produced (north | south) planes; the
        # south rows come out in fold order (equator-out), reverse + trim
        ns = fold_rings - x.shape[0]
        flat = jnp.concatenate([h[:, 0], h[:, 1, :ns][:, ::-1]], axis=1)
    else:
        flat = h[:, 0]                        # (Mr, R, 2K)
    if spin:
        dq_re, dq_im, du_re, du_im = leg.spin_unpack_delta(
            flat[..., :n_k], flat[..., n_k:])
        hc = jnp.concatenate([dq_re + 1j * dq_im, du_re + 1j * du_im],
                             axis=-1).astype(jnp.complex64)   # (M, R, 2K)
        mv = np.asarray(m_vals)[:a.shape[0] // 2]
    else:
        hc = (flat[..., :n_k] + 1j * flat[..., n_k:]).astype(jnp.complex64)
        mv = np.asarray(m_vals)
    if phase_kind == "bucket":
        return _bucket_scatter(hc, mv, bucket["layout"], bucket["pos"],
                               bucket["neg"], bucket["n_phi"],
                               bucket["out_width"])
    R_out, C = hc.shape[1], hc.shape[-1]
    bins, _, _ = phase.uniform_bin_maps(mv, n)
    half = n // 2 + 1
    H = jnp.zeros((R_out, half, C), jnp.complex64)
    H = H.at[:, jnp.asarray(bins)].add(jnp.moveaxis(hc, 0, 1))
    return (jnp.fft.irfft(H, n=n, axis=1) * n).astype(jnp.float32)


def _anal_chain(maps_w, m_vals, x, pmm, pms, *, l_max, var, bf16, lo,
                lp_size, interpret, spin, phase_kind, n=None, phi0=None,
                fold_rings=None, bucket=None):
    """Weight-free fused analysis core: (already ring-weighted) maps
    (R_full, W, C) f32 -> a (Mr, L1, 2K) f32."""
    from repro.core import legendre as leg
    from repro.core import phase
    mall = np.asarray(m_vals)
    mv = mall[:mall.shape[0] // 2] if spin else mall
    R_full = maps_w.shape[0]
    if phase_kind == "bucket":
        Fm = _bucket_gather(maps_w, mv, bucket["layout"], bucket["pos"],
                            bucket["n_phi"])
    else:
        F = jnp.fft.rfft(maps_w.astype(jnp.float32), axis=1)   # (R, half, C)
        bins, _, _ = phase.uniform_bin_maps(mv, n)
        Fm = jnp.moveaxis(F[:, jnp.asarray(bins), :], 1, 0)    # (M, R, C)
    if spin:
        n_k = Fm.shape[-1] // 2
        f_re, f_im = leg.spin_pack_delta(
            jnp.real(Fm[..., :n_k]), jnp.imag(Fm[..., :n_k]),
            jnp.real(Fm[..., n_k:]), jnp.imag(Fm[..., n_k:]))
        f = jnp.concatenate([f_re, f_im], axis=-1).astype(jnp.float32)
    else:
        f = jnp.concatenate([jnp.real(Fm), jnp.imag(Fm)],
                            axis=-1).astype(jnp.float32)       # (M, R, 2K)
    if fold_rings is not None:
        nh = x.shape[0]
        ns = R_full - nh
        f_n = f[:, :nh]
        f_s = jnp.zeros_like(f_n).at[:, :ns].set(f[:, nh:][:, ::-1])
        fp = jnp.stack([f_n, f_s], axis=1)    # (Mr, 2, nh, 2K)
    else:
        fp = f[:, None]                       # (Mr, 1, R, 2K)
    tabs = _rotation_tables(m_vals, "anal", phase_kind=phase_kind, n=n,
                            phi0=phi0, fold_rings=fold_rings,
                            n_half=x.shape[0])
    return _kernel_anal(fp, tabs, x, pmm, pms, l_max=l_max, var=var,
                        bf16=bf16, lo=lo, lp_size=lp_size,
                        interpret=interpret, spin=spin)


def _resolve(m_vals, l_max, lp_size, lo, interpret, mp_vals=None):
    from repro.kernels import pack as kpack
    from repro.kernels.ops import should_interpret
    if lo is None:
        lo = kpack.build_layout(
            np.asarray(m_vals), l_max, lp_size=lp_size,
            mp_vals=None if mp_vals is None else np.asarray(mp_vals))
    if interpret is None:
        interpret = should_interpret()
    return lo, interpret


# The whole-chain adjoints below compose the staged pipeline's transposes:
# scalar  synth^T = fac * anal-core      (fac = 1|2 per m, phase.py)
# spin    synth^T = 0.5 * fac * anal-core:  spin_unpack_delta^T is
#         spin_pack_delta / 2 and spin_pack_alm^T is 2 * spin_unpack_alm,
#         so the pair packing contributes a net 1/2 on the synth adjoint
#         (and its inverse 2 on the anal adjoint).  fac commutes with the
#         Legendre stage (block-diagonal per m) and with the pair packing
#         (both +- rows share one m).  The bucket scatter's transpose is
#         fac * the bucket gather (for real cotangents the conjugate-half
#         scatter bin contributes the conjugate of the positive bin), and
#         the fold combine's transpose is exactly the fold split -- both
#         verified in tests/test_fused.py adjoint identities.


def fused_synth(a, m_vals, x, pmm, pms, *, l_max, n, phi0, variant="vpu",
                bf16=False, lo=None, lp_size=128, interpret=None,
                mp_vals=None, fold_rings=None):
    """Differentiable fused synthesis on a uniform grid:
    a (Mr, L1, 2K) f32 -> maps (R, n, C).

    Spin-2: pass the stacked lambda^{+-} row set (``m_vals``/``mp_vals``
    from legendre._spin_rows, ``a`` channels from spin_pack_alm as
    re|im); the epilogue unpacks Q/U through the channel axis (C = 2K).
    Equator fold: pass ``fold_rings`` = the full ring count; ``x``/
    ``pmm``/``pms`` cover the northern half only and the north/south
    combine runs in-kernel."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret, mp_vals)
    spin = 2 if lo.spin else 0
    kw = dict(l_max=l_max, var=variant, bf16=bf16, lo=lo, lp_size=lp_size,
              interpret=interpret, spin=spin, phase_kind="uniform", n=n,
              phi0=phi0, fold_rings=fold_rings)
    fac = _fac_rows(m_vals, jnp.float32)
    bsc = 0.5 if spin else 1.0

    def fwd(res, a_):
        x_, pmm_, pms_ = res
        return _synth_chain(a_, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, t):
        x_, pmm_, pms_ = res
        return bsc * fac * _anal_chain(t, m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), a)


def fused_anal(maps, weights, m_vals, x, pmm, pms, *, l_max, n, phi0,
               variant="vpu", bf16=False, lo=None, lp_size=128,
               interpret=None, mp_vals=None, fold_rings=None):
    """Differentiable fused analysis on a uniform grid:
    maps (R, n, C) -> a (Mr, L1, 2K) f32.

    Ring quadrature weights are applied to the maps *outside* the linear
    core (they commute with the phi-axis FFT), keeping the core's adjoint
    the weight-free fused synthesis of the fac-normalised cotangent."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret, mp_vals)
    spin = 2 if lo.spin else 0
    kw = dict(l_max=l_max, var=variant, bf16=bf16, lo=lo, lp_size=lp_size,
              interpret=interpret, spin=spin, phase_kind="uniform", n=n,
              phi0=phi0, fold_rings=fold_rings)
    fac = _fac_rows(m_vals, jnp.float32)
    bsc = 0.5 if spin else 1.0
    w = jnp.asarray(weights, jnp.float32)
    maps_w = jnp.asarray(maps, jnp.float32) * w[:, None, None]

    def fwd(res, mw):
        x_, pmm_, pms_ = res
        return _anal_chain(mw, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, g):
        x_, pmm_, pms_ = res
        return _synth_chain(g / (bsc * fac), m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), maps_w)


def fused_synth_bucket(a, m_vals, x, pmm, pms, *, l_max, layout, pos, neg,
                       n_phi, phi0, out_width, variant="vpu", bf16=False,
                       lo=None, lp_size=128, interpret=None, mp_vals=None):
    """Differentiable fused synthesis on a ragged (bucketed) grid:
    a (Mr, L1, 2K) f32 -> maps (R, out_width, C) f32.

    The kernel rotates the Delta rows by e^{+i m phi0(r)} in-register
    (`phase.bucket_rotation_tables`); the alias-fold scatter through the
    per-bucket bin maps (``pos``/``neg`` from `phase.bucket_bin_maps`,
    ``layout`` a BucketLayout) runs on the host around the one kernel, so
    the unrotated Delta never round-trips HBM.  Spin-2 rides exactly like
    :func:`fused_synth` (``mp_vals`` + stacked rows)."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret, mp_vals)
    spin = 2 if lo.spin else 0
    bucket = dict(layout=layout, pos=np.asarray(pos), neg=np.asarray(neg),
                  n_phi=np.asarray(n_phi), out_width=int(out_width))
    kw = dict(l_max=l_max, var=variant, bf16=bf16, lo=lo, lp_size=lp_size,
              interpret=interpret, spin=spin, phase_kind="bucket",
              phi0=phi0, bucket=bucket)
    fac = _fac_rows(m_vals, jnp.float32)
    bsc = 0.5 if spin else 1.0

    def fwd(res, a_):
        x_, pmm_, pms_ = res
        return _synth_chain(a_, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, t):
        x_, pmm_, pms_ = res
        return bsc * fac * _anal_chain(t, m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), a)


def fused_anal_bucket(maps, weights, m_vals, x, pmm, pms, *, l_max, layout,
                      pos, neg, n_phi, phi0, variant="vpu", bf16=False,
                      lo=None, lp_size=128, interpret=None, mp_vals=None):
    """Differentiable fused analysis on a ragged (bucketed) grid:
    maps (R, W, C) -> a (Mr, L1, 2K) f32.  The per-bucket gather feeds
    unrotated spectrum rows to the kernel; the e^{-i m phi0} rotation
    happens in-register via the anal-direction bucket tables."""
    from repro.core.phase import _fac_rows
    lo, interpret = _resolve(m_vals, l_max, lp_size, lo, interpret, mp_vals)
    spin = 2 if lo.spin else 0
    bucket = dict(layout=layout, pos=np.asarray(pos), neg=np.asarray(neg),
                  n_phi=np.asarray(n_phi), out_width=int(maps.shape[1]))
    kw = dict(l_max=l_max, var=variant, bf16=bf16, lo=lo, lp_size=lp_size,
              interpret=interpret, spin=spin, phase_kind="bucket",
              phi0=phi0, bucket=bucket)
    fac = _fac_rows(m_vals, jnp.float32)
    bsc = 0.5 if spin else 1.0
    w = jnp.asarray(weights, jnp.float32)
    maps_w = jnp.asarray(maps, jnp.float32) * w[:, None, None]

    def fwd(res, mw):
        x_, pmm_, pms_ = res
        return _anal_chain(mw, m_vals, x_, pmm_, pms_, **kw)

    def bwd(res, g):
        x_, pmm_, pms_ = res
        return _synth_chain(g / (bsc * fac), m_vals, x_, pmm_, pms_, **kw)

    return linear_pair(fwd, bwd, (x, pmm, pms), maps_w)
