"""Pure-jnp oracles for the Pallas Legendre kernels.

Bit-matched algorithm (same float32 scaled recurrence, same seed inputs,
same accumulation order up to reassociation) so the interpret-mode kernels
can be checked with tight tolerances; the float64 core engine
(repro.core.legendre) provides the independent ground truth on top.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import legendre as _legendre
from repro.kernels.legendre_pallas import _f32_step, _f32_step_spin

__all__ = ["synth_ref", "anal_ref", "synth_packed_ref", "anal_packed_ref",
           "prepare_seeds", "prepare_seeds_spin"]


def prepare_seeds(m_vals, sin_theta, log_mu_all, scale_bits: int = 64):
    """Scaled P_mm seeds for the f32 kernels, computed in float64.

    m_vals: (Mp,) int (may include -1 padding -> inert seeds of 0);
    sin_theta: (R,) f64.  Returns (pmm (Mp, R) f32, pms (Mp, R) i32).
    """
    m_vals = jnp.asarray(m_vals)
    msafe = jnp.maximum(m_vals, 0)
    lm = jnp.asarray(log_mu_all, jnp.float64)[msafe][:, None]
    st = jnp.asarray(sin_theta, jnp.float64)[None, :]
    log_p = lm + msafe.astype(jnp.float64)[:, None] * jnp.log(st)
    denom = scale_bits * np.log(2.0)
    scale = jnp.minimum(jnp.round(log_p / denom), 0.0)
    mant = jnp.exp(log_p - scale * denom)
    mant = jnp.where((m_vals >= 0)[:, None], mant, 0.0)
    return mant.astype(jnp.float32), scale.astype(jnp.int32)


def prepare_seeds_spin(m_vals, mprime_vals, cos_theta, sin_theta,
                       m_max=None, scale_bits: int = 64):
    """Scaled spin-weighted lambda^{(m')} seeds for the f32 kernels.

    m_vals/mprime_vals: (Ms,) int rows (m < 0 padding -> inert 0 seeds);
    cos_theta/sin_theta: (R,) f64.  ``m_max`` must be given when ``m_vals``
    is traced (the distributed path).  Returns (pmm f32, pms i32), (Ms, R).
    """
    if m_max is None:
        m_max = int(np.max(np.asarray(m_vals)))
    logfact = _legendre.log_factorials(2 * max(int(m_max), 2) + 1)
    mant, scale = _legendre.spin_seeds_scaled(
        m_vals, mprime_vals, cos_theta, sin_theta, logfact,
        dtype=jnp.float32, scale_bits=scale_bits)
    return mant, scale


def _ref_step(spin, l, m_f, mp_f, xb, pp, pc, sc, pmm, pms):
    if spin:
        return _f32_step_spin(l, m_f, mp_f, xb, pp, pc, sc, pmm, pms)
    return _f32_step(l, m_f, xb, pp, pc, sc, pmm, pms)


@functools.partial(jax.jit, static_argnames=("l_max", "fold"))
def synth_ref(a, m_vals, x, pmm, pms, *, l_max: int, fold: bool = False,
              mp_vals=None):
    """Oracle for synth_{vpu,mxu}.

    a: (Mp, L1p, 2K) f32;  x: (R,) f32;  pmm/pms: (Mp, R).
    ``mp_vals`` (Mp,) selects the spin-weighted recurrence per row
    (None -> scalar P_lm).  Returns (Mp, P, R, 2K) f32 (P = 2 if fold).
    """
    Mp, L1p, K2 = a.shape
    R = x.shape[0]
    m = jnp.asarray(m_vals, jnp.int32)[:, None]
    m_f = m.astype(jnp.float32)
    spin = mp_vals is not None
    mp_f = (jnp.asarray(mp_vals, jnp.int32)[:, None].astype(jnp.float32)
            if spin else jnp.zeros_like(m_f))
    xb = jnp.asarray(x, jnp.float32)[None, :]
    n_par = 2 if fold else 1
    carry0 = (jnp.zeros((Mp, R), jnp.float32), jnp.zeros((Mp, R), jnp.float32),
              jnp.zeros((Mp, R), jnp.int32),
              jnp.zeros((Mp, n_par, R, K2), jnp.float32))

    def body(l, carry):
        pp, pc, sc, acc = carry
        pp, pc, sc, val = _ref_step(spin, l, m_f, mp_f, xb, pp, pc, sc,
                                    pmm, pms)
        av = jax.lax.dynamic_index_in_dim(a, l, axis=1, keepdims=False)
        contrib = val[:, :, None] * av[:, None, :]       # (Mp, R, 2K)
        if fold:
            par = ((l + m) % 2)[..., None]               # (Mp, 1, 1)
            upd = jnp.stack([jnp.where(par == 0, contrib, 0.0),
                             jnp.where(par == 1, contrib, 0.0)], axis=1)
            acc = acc + upd
        else:
            acc = acc + contrib[:, None]
        return pp, pc, sc, acc

    _, _, _, acc = jax.lax.fori_loop(0, min(l_max + 1, L1p), body, carry0)
    return acc


@functools.partial(jax.jit, static_argnames=("l_max", "l1p", "fold"))
def anal_ref(dw, m_vals, x, pmm, pms, *, l_max: int, l1p: int,
             fold: bool = False, mp_vals=None):
    """Oracle for anal_{vpu,mxu}.

    dw: (Mp, P, R, 2K) f32 weighted Delta;  returns (Mp, L1p, 2K) f32.
    """
    Mp, n_par, R, K2 = dw.shape
    m = jnp.asarray(m_vals, jnp.int32)[:, None]
    m_f = m.astype(jnp.float32)
    spin = mp_vals is not None
    mp_f = (jnp.asarray(mp_vals, jnp.int32)[:, None].astype(jnp.float32)
            if spin else jnp.zeros_like(m_f))
    xb = jnp.asarray(x, jnp.float32)[None, :]
    carry0 = (jnp.zeros((Mp, R), jnp.float32), jnp.zeros((Mp, R), jnp.float32),
              jnp.zeros((Mp, R), jnp.int32))

    def step(carry, l):
        pp, pc, sc = carry
        pp, pc, sc, val = _ref_step(spin, l, m_f, mp_f, xb, pp, pc, sc,
                                    pmm, pms)
        if fold:
            par = ((l + m) % 2)[..., None]               # (Mp, 1, 1)
            d = jnp.where(par == 0, dw[:, 0], dw[:, 1])
        else:
            d = dw[:, 0]
        row = jnp.einsum("mr,mrk->mk", val, d)
        return (pp, pc, sc), row

    _, rows = jax.lax.scan(step, carry0, jnp.arange(l1p))
    out = jnp.swapaxes(rows, 0, 1)                        # (Mp, L1p, 2K)
    lmask = (jnp.arange(l1p) <= l_max)[None, :, None]
    return jnp.where(lmask, out, 0.0)


# ---------------------------------------------------------------------------
# Packed (triangular m-pair) schedule oracles -- bit-matched to the packed
# kernels: same per-step (segment, m, m', l) selection, same seed-at-seam
# behaviour, same accumulation order.  See kernels.pack for the layout.
# ---------------------------------------------------------------------------


def _packed_maps_ref(layout):
    m0 = jnp.asarray(layout.slot_m[:, 0], jnp.int32)[:, None]
    m1 = jnp.asarray(layout.slot_m[:, 1], jnp.int32)[:, None]
    mp0 = jnp.asarray(layout.slot_mp[:, 0], jnp.int32)[:, None]
    mp1 = jnp.asarray(layout.slot_mp[:, 1], jnp.int32)[:, None]
    seed = jnp.asarray(layout.slot_seed, jnp.int32)[:, None]
    return m0, m1, mp0, mp1, seed


def _packed_step_ref(g, layout_maps, spin, x, pmm_pk, pms_pk, pp, pc, sc):
    """One packed-schedule step at intra-slot index ``g`` for every slot."""
    m0, m1, mp0, mp1, seed = layout_maps
    hi = (g >= seed).astype(jnp.int32)                 # (n_slots, 1)
    m = jnp.where(hi == 1, m1, m0)
    mp_v = jnp.where(hi == 1, mp1, mp0)
    l00 = jnp.maximum(m0, jnp.abs(mp0))
    l01 = jnp.maximum(m1, jnp.abs(mp1))
    l = jnp.where(hi == 1, l01 + g - seed, l00 + g)
    pmm = jnp.where(hi == 1, pmm_pk[:, 1], pmm_pk[:, 0])
    pms = jnp.where(hi == 1, pms_pk[:, 1], pms_pk[:, 0])
    pp, pc, sc, val = _ref_step(spin, l, m.astype(jnp.float32),
                                mp_v.astype(jnp.float32), x[None, :],
                                pp, pc, sc, pmm, pms)
    return pp, pc, sc, val, hi, m, l


def synth_packed_ref(a_pk, layout, x, pmm_pk, pms_pk, *, fold: bool = False):
    """Oracle for synth_{vpu,mxu}_packed.

    a_pk: (n_slots, S, 2K) f32;  x: (R,) f32;  pmm_pk/pms_pk: (n_slots, 2, R).
    Returns (n_slots, Q, R, 2K) f32 with Q = 2 segments x (2 if fold).
    """
    n_slots, S, K2 = a_pk.shape
    R = x.shape[0]
    spin = layout.spin
    n_par = 2 if fold else 1
    n_q = 2 * n_par
    maps = _packed_maps_ref(layout)
    x32 = jnp.asarray(x, jnp.float32)
    carry0 = (jnp.zeros((n_slots, R), jnp.float32),
              jnp.zeros((n_slots, R), jnp.float32),
              jnp.zeros((n_slots, R), jnp.int32),
              jnp.zeros((n_slots, n_q, R, K2), jnp.float32))

    def body(g, carry):
        pp, pc, sc, acc = carry
        pp, pc, sc, val, hi, m, l = _packed_step_ref(
            g, maps, spin, x32, pmm_pk, pms_pk, pp, pc, sc)
        av = jax.lax.dynamic_index_in_dim(a_pk, g, axis=1, keepdims=False)
        contrib = val[:, :, None] * av[:, None, :]     # (n_slots, R, 2K)
        q = hi * n_par + ((l + m) % 2 if fold else 0)  # (n_slots, 1)
        sel = jnp.arange(n_q, dtype=jnp.int32)[None, :] == q
        acc = acc + jnp.where(sel[:, :, None, None], contrib[:, None], 0.0)
        return pp, pc, sc, acc

    _, _, _, acc = jax.lax.fori_loop(0, S, body, carry0)
    return acc


def anal_packed_ref(dw_pk, layout, x, pmm_pk, pms_pk, *, fold: bool = False):
    """Oracle for anal_{vpu,mxu}_packed.

    dw_pk: (n_slots, Q, R, 2K) f32 weighted Delta per fused component.
    Returns (n_slots, S, 2K) f32 packed l-stream rows.
    """
    n_slots, n_q, R, K2 = dw_pk.shape
    spin = layout.spin
    n_par = 2 if fold else 1
    assert n_q == 2 * n_par
    maps = _packed_maps_ref(layout)
    x32 = jnp.asarray(x, jnp.float32)
    carry0 = (jnp.zeros((n_slots, R), jnp.float32),
              jnp.zeros((n_slots, R), jnp.float32),
              jnp.zeros((n_slots, R), jnp.int32))

    def step(carry, g):
        pp, pc, sc = carry
        pp, pc, sc, val, hi, m, l = _packed_step_ref(
            g, maps, spin, x32, pmm_pk, pms_pk, pp, pc, sc)
        # positions past the real stream (l > l_max) are padding the host
        # unpack discards; the vpu kernel stops its loops there, so the
        # oracle zeroes them to stay bit-matched
        val = jnp.where(l <= layout.l_max, val, 0.0)
        q = hi * n_par + ((l + m) % 2 if fold else 0)  # (n_slots, 1)
        d = jnp.take_along_axis(dw_pk, q[:, :, None, None], axis=1)[:, 0]
        row = jnp.einsum("sr,srk->sk", val, d)
        return (pp, pc, sc), row

    _, rows = jax.lax.scan(step, carry0, jnp.arange(layout.S))
    return jnp.swapaxes(rows, 0, 1)                    # (n_slots, S, 2K)
