"""Fault tolerance: checkpoint/restart driver, straggler & elasticity policy.

What is implemented and TESTED here (single-host simulation of the
cluster-control-plane behaviours):

  * run_with_restarts -- supervises a train loop; on (injected) failure it
    restores the latest atomic checkpoint and resumes with the SAME data
    stream position (tests/test_fault.py kills the loop mid-run and asserts
    bit-identical loss trajectories vs an uninterrupted run);
  * elastic restore -- restore() re-places arrays under a different mesh
    (e.g. 512 -> 256 chips after losing a pod); data.skip-ahead keeps the
    sample order;
  * straggler mitigation policy (documented + simulated):
      - synchronous SPMD has no per-step laggards to drop: mitigation is
        (a) deterministic redistribute-and-restart via elastic restore when
        a host degrades persistently, and (b) checkpoint cadence tuned so
        MTTR * failure-rate << step budget (see EXPERIMENTS.md);
      - the simulate_straggler test models a slow host by step-time
        inflation and asserts the elastic path recovers throughput.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional

import jax

from repro.train import checkpoint as C

__all__ = ["RunConfig", "run_with_restarts", "FailureInjector"]


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests: fail before given steps."""
    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected node failure before step {step}")


@dataclasses.dataclass(frozen=True)
class RunConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 10
    max_restarts: int = 3


def run_with_restarts(run_cfg: RunConfig, *, init_state: Callable[[], dict],
                      step_fn: Callable[[dict, int], dict],
                      injector: Optional[FailureInjector] = None,
                      on_metrics=None):
    """Supervise a training loop with checkpoint/restart semantics.

    init_state() -> state dict (params/opt/...); step_fn(state, step) ->
    state'.  Checkpoints every ckpt_every steps; resumes from the latest
    checkpoint after a failure (up to max_restarts).
    """
    restarts = 0
    while True:
        try:
            last = C.latest_step(run_cfg.ckpt_dir)
            if last is None:
                state, step0 = init_state(), 0
            else:
                like = jax.eval_shape(init_state)
                state, step0 = C.restore(run_cfg.ckpt_dir, last, like), last
            for step in range(step0, run_cfg.total_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                state = step_fn(state, step)
                nxt = step + 1
                if nxt % run_cfg.ckpt_every == 0 or nxt == run_cfg.total_steps:
                    C.save(run_cfg.ckpt_dir, nxt, state)
                if on_metrics is not None:
                    on_metrics(step, state)
            return state
        except RuntimeError as e:
            restarts += 1
            if restarts > run_cfg.max_restarts:
                raise
            # control plane would reschedule the job here; we just loop
            continue
