"""AdamW with ZeRO-1 sharding specs, gradient clipping and schedules.

Implemented directly (no optax dependency).  Optimizer state mirrors the
parameter tree; its sharding specs extend the param specs by additionally
sharding the largest replicated axis over the "data" mesh axis when
``zero1=True`` (the optimizer-state partitioning trick -- each data-parallel
rank keeps 1/N of the moments, XLA gathers on use).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "init_opt_state", "opt_state_specs", "adamw_update",
           "cosine_schedule", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    zero1: bool = True            # shard moments over the data axis
    moment_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(np.pi * prog))


def init_opt_state(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def _zero1_spec(spec: P, shape, data_size: int) -> P:
    """Extend a param spec: shard the largest divisible None-axis over
    "data" (ZeRO-1 moment partitioning)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat = []
    for e in entries:
        flat.extend(e if isinstance(e, tuple) else (e,))
    if "data" in flat:
        return P(*entries)
    best, best_size = None, 0
    for i, (ax, n) in enumerate(zip(entries, shape)):
        if ax is None and n > best_size and n % data_size == 0:
            best, best_size = i, n
    if best is None:
        return P(*entries)
    entries[best] = "data"
    return P(*entries)


def opt_state_specs(param_specs, param_shapes, cfg: AdamWConfig,
                    data_size: int = 16):
    """Specs for the optimizer state tree (ZeRO-1 over "data" if enabled)."""
    is_p = lambda v: isinstance(v, P)
    if not cfg.zero1:
        mom = param_specs
    else:
        mom = jax.tree.map(
            lambda s, shp: _zero1_spec(s, shp.shape, data_size), param_specs,
            param_shapes, is_leaf=is_p)
    return {"mu": mom, "nu": mom, "step": P()}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    gn = jnp.sqrt(gn2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  Returns (params', state', metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu2 = b1 * mu.astype(jnp.float32) + (1 - b1) * g32
        nu2 = b2 * nu.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = mu2 / bc1
        vhat = nu2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return (p2.astype(p.dtype), mu2.astype(mu.dtype), nu2.astype(nu.dtype))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    params2 = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda v: isinstance(v, tuple))
    mu2 = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda v: isinstance(v, tuple))
    nu2 = jax.tree.map(lambda t: t[2], out,
                       is_leaf=lambda v: isinstance(v, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params2, {"mu": mu2, "nu": nu2, "step": step}, metrics
