"""train_step factory: donation, grad accumulation, compression, sharding.

Distributed-optimization features (system-prompt checklist):
  * compute/comm overlap -- gradients are produced by a scan-over-layers
    backward; XLA's latency-hiding scheduler overlaps the per-layer gradient
    all-reduces with the next layer's backward (enabled via
    --xla_tpu_enable_latency_hiding_scheduler in launch scripts; on the CPU
    dry-run we verify the collective count/sizes instead);
  * gradient compression -- optional bf16 (2x) or stochastic-rounded int8
    (4x) cast applied to gradients before the data-parallel reduction
    (applied inside a shard_map psum when enabled);
  * grad accumulation -- microbatch scan for batch sizes beyond memory;
  * ZeRO-1 -- optimizer moments sharded over "data" (optimizer.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.train import optimizer as O

__all__ = ["TrainConfig", "make_train_step", "train_state_shardings"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: O.AdamWConfig = O.AdamWConfig()
    grad_accum: int = 1
    grad_compression: Optional[str] = None   # None | "bfloat16" | "int8"


def _compress_decompress(g, kind, key):
    """Lossy gradient cast applied before the DP all-reduce."""
    if kind == "bfloat16":
        return g.astype(jnp.bfloat16).astype(g.dtype)
    if kind == "int8":
        amax = jnp.max(jnp.abs(g)) + 1e-12
        scale = amax / 127.0
        noise = jax.random.uniform(key, g.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale + noise),
                     -127, 127).astype(jnp.int8)
        return (q.astype(jnp.float32) * scale).astype(g.dtype)
    return g


def make_train_step(bundle, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, rng) -> (params',
    opt_state', metrics).  Jit with donate_argnums=(0, 1)."""

    def loss_of(params, batch):
        return bundle.loss_fn(params, batch)

    def train_step(params, opt_state, batch, rng):
        if tcfg.grad_accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum,
                                     x.shape[0] // tcfg.grad_accum)
                                    + x.shape[1:]), batch)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0.0)),
                                           mbs)
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, gsum)
            loss = lsum / tcfg.grad_accum
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        if tcfg.grad_compression:
            keys = jax.random.split(rng, len(jax.tree.leaves(grads)))
            keys_tree = jax.tree.unflatten(jax.tree.structure(grads),
                                           list(keys))
            grads = jax.tree.map(
                lambda g, k: _compress_decompress(g, tcfg.grad_compression, k),
                grads, keys_tree)

        params2, opt2, metrics = O.adamw_update(params, grads, opt_state,
                                                tcfg.opt)
        metrics = dict(metrics, loss=loss)
        return params2, opt2, metrics

    return train_step


def train_state_shardings(bundle, tcfg: TrainConfig):
    """(param shardings, opt-state shardings) for pjit in/out."""
    mesh = bundle.rt.mesh
    pspecs = bundle.param_specs()
    pshapes = jax.eval_shape(bundle.init, jax.random.PRNGKey(0))
    data_size = bundle.rt.axis_size("data")
    ospecs = O.opt_state_specs(pspecs, pshapes, tcfg.opt,
                               data_size=max(data_size, 1))
    as_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda v: isinstance(v, P))
    return as_shard(pspecs), as_shard(ospecs)
