# Training substrate: optimizer (AdamW + ZeRO sharding), train-step factory
# (remat, grad-accum, compression), checkpointing, data pipeline, fault
# tolerance.
