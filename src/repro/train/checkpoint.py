"""Sharded, step-atomic checkpointing with elastic restore.

Layout:  <dir>/step_<N>/shard_<i>.npz + manifest.json
  * each host writes only the leaves (slices) it owns -- here, single-host
    CPU, one shard file; the format is host-count-agnostic;
  * writes go to step_<N>.tmp and are atomically renamed, so a failure
    mid-write never corrupts the latest checkpoint (restart safety);
  * restore onto a DIFFERENT mesh is supported: arrays are loaded full and
    re-placed with the new shardings (elastic scaling path).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, state: dict) -> str:
    """state: arbitrary pytree of arrays (params, opt state, data step...)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    return final


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: dict, shardings=None) -> dict:
    """Restore into the structure of ``like`` (tree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree for elastic
    re-placement onto a new mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "shard_0.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    _, treedef = _flatten(like)
    state = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(lambda a, s: jax.device_put(a, s), state,
                             shardings)
    else:
        state = jax.tree.map(jnp.asarray, state)
    return state
