"""Deterministic synthetic data pipeline with resume/skip-ahead.

Framework-grade properties the trainer relies on:
  * stateless indexing -- batch(step) is a pure function of (seed, step), so
    restart-after-failure reproduces the exact token stream (no data-order
    drift across checkpoint restores, elastic re-runs, or straggler
    re-execution);
  * per-host sharding -- each host materialises only its slice of the
    global batch (process_index-aware), matching the batch sharding specs;
  * double-buffered prefetch for the CPU-host -> device copy.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "prefetch"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Zipf-distributed token stream (power-law ids like natural text)."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    frontend: Optional[str] = None       # None | vision_stub | audio_stub
    d_model: int = 0
    n_frontend_tokens: int = 0

    def batch(self, step: int, *, host_index: int = 0, n_hosts: int = 1):
        """The step-th global batch slice for this host (numpy, pinned)."""
        assert self.global_batch % n_hosts == 0
        b_local = self.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        # Inverse-CDF Zipf over a finite vocab (rejection-free).
        u = rng.random((b_local, self.seq_len))
        ranks = (self.vocab ** (1.0 - u) - 1.0) / (self.vocab - 1.0)
        toks = np.clip((ranks * self.vocab).astype(np.int32), 0,
                       self.vocab - 1)
        out = {"tokens": toks}
        if self.frontend == "vision_stub":
            out["patch_embeds"] = rng.standard_normal(
                (b_local, self.n_frontend_tokens, self.d_model),
                dtype=np.float32)
        elif self.frontend == "audio_stub":
            out["frames"] = rng.standard_normal(
                (b_local, self.n_frontend_tokens, self.d_model),
                dtype=np.float32)
        return out

    def iterate(self, start_step: int = 0, **kw) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, **kw)
            step += 1


def prefetch(it: Iterator[dict], shardings=None, depth: int = 2):
    """Double-buffered host->device prefetch."""
    import collections
    buf = collections.deque()

    def put(x):
        if shardings is not None:
            buf.append(jax.tree.map(
                lambda a, s: jax.device_put(a, s), x, shardings))
        else:
            buf.append(jax.tree.map(jnp.asarray, x))

    for x in it:
        put(x)
        if len(buf) >= depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
