"""Version-compatibility shims for the jax API surface this repo uses.

The repo targets the current jax API; older jaxlibs in baked containers
spell a few things differently.  Centralising the fallbacks here keeps
every call site on one idiom.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map", "axis_size"]


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, inside shard_map/pmap bodies.

    ``jax.lax.axis_size`` on recent jax; on older releases the same static
    metadata lives on the axis environment.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax._src.core import get_axis_env
    return get_axis_env().axis_size(axis_name)


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map(..., check_vma=False)`` across jax versions.

    Newer jax exposes ``jax.shard_map`` with a ``check_vma`` flag; older
    releases have ``jax.experimental.shard_map.shard_map`` with the same
    flag named ``check_rep``.  We always disable the replication/VMA
    tracker: the Legendre loop carries are seeded from unvarying constants
    and become shard-varying inside the loop (see dist_sht).
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
