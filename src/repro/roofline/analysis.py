"""Three-term roofline analysis from compiled XLA artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the compiled
module is the per-device SPMD program, so they are already per-device).
Collective bytes are NOT in cost_analysis: we parse the post-optimisation
HLO text and sum the wire traffic of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, using ring-algorithm
per-device wire-byte formulas.

Hardware model (TPU v5e, per task spec): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

__all__ = ["HW_V5E", "HW_HOST", "Roofline", "collective_bytes",
           "analyze_compiled", "parse_hlo_collectives",
           "sht_work", "legendre_panel_counts", "predict_sht_time",
           "predict_comm_chunks", "BACKEND_MODELS", "BackendModel"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # bf16 FLOP/s per chip
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per ICI link
    coll_latency: float = 1e-6   # launch latency per collective [s]


HW_V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9)

#: Crude single-host CPU model (this container's baseline).  Used by the
#: ``mode="model"`` dispatch when no accelerator is attached; the absolute
#: numbers matter less than the *relative* per-backend ranking.  Simulated
#: host "collectives" are memcpys behind a dispatch, so the per-collective
#: launch latency is an order worse than real ICI.
HW_HOST = Hardware("host-cpu", 2e11, 5e10, 1e10, coll_latency=1e-5)


# ---------------------------------------------------------------------------
# Analytic SHT cost model (drives repro.make_plan's ``mode="model"`` dispatch)
# ---------------------------------------------------------------------------


def sht_work(l_max: int, m_max: int, n_rings: int, n_phi: int,
             K: int, fft_lengths=None, spin: int = 0) -> dict:
    """Operation counts of one transform direction (paper §3 complexity).

    Returns a dict with:
      ``recurrence_flops`` -- P_lm generation, O(R * n_lm), K-independent
                              (the paper's on-the-fly beta recomputation:
                              ~10 flops per (l, m, ring) step);
      ``accum_flops``      -- the a_lm / Delta_m contraction, 4K flops per
                              (l, m, ring) (complex FMA) -- this is the part
                              an MXU can take as a matmul;
      ``fft_flops``        -- batched ring FFTs.  With ``fft_lengths``
                              (the per-ring bucket lengths of a ragged
                              grid's phase stage) the cost is summed per
                              bucketed ring instead of assuming one n_phi;
      ``bytes``            -- HBM traffic lower bound (alm + maps + Delta).

    ``spin=2`` doubles every term: the spin path runs TWO Wigner-d
    recurrences per m (the lambda^{+/-} panel pair), accumulates two alm
    components (E, B) and transforms two maps (Q, U).
    """
    ncomp = 1 if spin == 0 else 2
    n_lm = (m_max + 1) * (l_max + 1) - m_max * (m_max + 1) // 2
    rec = 10.0 * n_lm * n_rings * ncomp
    acc = 4.0 * n_lm * n_rings * K * ncomp
    if fft_lengths is not None:
        fl = np.asarray(fft_lengths, dtype=np.float64)
        fft = 5.0 * float(np.sum(fl * np.log2(np.maximum(fl, 2.0)))) * K
        maps_elems = float(np.sum(fl)) * K
    else:
        fft = 5.0 * n_rings * n_phi * float(np.log2(max(n_phi, 2))) * K
        maps_elems = float(n_rings * n_phi) * K
    fft *= ncomp
    maps_elems *= ncomp
    byts = (16.0 * (m_max + 1) * (l_max + 1) * K * ncomp   # alm (complex)
            + 8.0 * maps_elems                             # maps
            + 16.0 * (m_max + 1) * n_rings * K * ncomp)    # Delta (complex)
    return {"n_lm": n_lm, "recurrence_flops": rec, "accum_flops": acc,
            "fft_flops": fft, "bytes": byts,
            "total_flops": rec + acc + fft,
            # Legendre grid-step accounting (plain vs packed kernel grids);
            # the dispatch layer uses this to model packed-vs-plain honestly.
            "panels": legendre_panel_counts(l_max, m_max, spin=spin)}


def legendre_panel_counts(l_max: int, m_max: int, *, lp_size: int = 128,
                          spin: int = 0) -> dict:
    """Grid-step accounting of the Legendre stage, plain vs packed.

    Delegates to `repro.kernels.pack.panel_counts` on the canonical row
    set (``m = 0..m_max``; doubled ``m' = -+2`` rows for ``spin=2``) so the
    cost model and the kernels agree by construction.  Keys:
    ``plain_launched`` (dense grid steps, all paying launch latency),
    ``plain_worked`` (steps passing the ``pl.when`` diagonal test),
    ``packed`` (packed grid steps -- every one works), ``ideal_steps``
    (the paper's triangular invariant) and the derived ratios.
    """
    from repro.kernels import pack
    m = np.arange(m_max + 1)
    if spin:
        m2 = np.concatenate([m, m])
        mp2 = np.concatenate([np.full(m_max + 1, -2), np.full(m_max + 1, 2)])
        return pack.panel_counts(m2, l_max, lp_size=lp_size, mp_vals=mp2)
    return pack.panel_counts(m, l_max, lp_size=lp_size)


@dataclasses.dataclass(frozen=True)
class BackendModel:
    """Effective-throughput model of one execution backend.

    ``vector_eff``/``matrix_eff`` are fractions of ``Hardware.peak_flops``
    achieved on vector (VPU/scalar) and matrix (MXU) work; ``matrix_eff = 0``
    means the accumulation runs on the vector unit too.  ``anal_penalty``
    models the paper's direct/inverse dichotomy (§5): the analysis direction
    pays extra for its ring reduction (the paper's Algorithm 5 atomics; our
    sequential-grid accumulation), so the same backend may win synthesis and
    lose analysis.
    """

    name: str
    vector_eff: float
    matrix_eff: float = 0.0
    anal_penalty: float = 1.0


BACKEND_MODELS = {
    # float64 un-fused HLO ops: correct but memory-bound.
    "jnp": BackendModel("jnp", vector_eff=0.01, anal_penalty=1.0),
    # broadcast-FMA kernel: good vector efficiency, no MXU use.
    "pallas_vpu": BackendModel("pallas_vpu", vector_eff=0.08,
                               anal_penalty=1.3),
    # panel matmul: accumulation on the MXU, recurrence still vector work.
    "pallas_mxu": BackendModel("pallas_mxu", vector_eff=0.06, matrix_eff=0.4,
                               anal_penalty=1.2),
    # dist = best local kernel / n_devices + one all_to_all on the wire.
    "dist": BackendModel("dist", vector_eff=0.06, matrix_eff=0.4,
                         anal_penalty=1.2),
}


def predict_sht_time(backend: str, *, l_max: int, m_max: int, n_rings: int,
                     n_phi: int, K: int, direction: str = "synth",
                     hw: Hardware = HW_V5E, n_devices: int = 1,
                     fft_lengths=None, spin: int = 0, layout: str = None,
                     lp_size: int = 128, pipeline: str = "staged",
                     overlap: bool = False, comm_chunks: int = 1) -> float:
    """Predicted seconds for one transform on ``backend`` (3-term model).

    compute = recurrence/vector + accumulation/(matrix or vector) + fft;
    memory = bytes / HBM bw;  collective (dist only) = all_to_all wire
    bytes / link bw.  The terms are summed (no overlap assumed -- the
    paper's kernels are serial stages), and ``anal_penalty`` is applied for
    ``direction="anal"``.  ``fft_lengths`` carries a ragged grid's
    per-ring bucket lengths into the FFT term; ``spin=2`` doubles every
    term including the exchanged Delta block (see `sht_work`).

    ``layout`` ("plain" | "packed", pallas backends only) scales the
    Legendre terms by that grid's executed-step overhead over the ideal
    triangular count (`legendre_panel_counts`), so the packed-vs-plain
    dispatch decision is modelled honestly.

    ``pipeline="fused"`` (pallas backends only) models the single-kernel
    Legendre+phase pipeline (`repro.kernels.fused`): the intermediate
    Delta block never round-trips HBM, so its bytes term is dropped --
    the fused pipeline's advantage in this model is purely the removed
    memory traffic (the flop terms are identical).

    ``overlap=True`` with ``comm_chunks=C > 1`` (dist backend only) models
    the chunked software-pipelined exchange (`DistSHT(comm_chunks=C)`):
    instead of ``comp + comm``, the distributed time is the pipeline

        comp/C + comm_chunk + (C-1) * max(comp/C, comm_chunk)

    where ``comm_chunk = comm/C + hw.coll_latency`` -- each chunk's
    collective hides behind the adjacent chunk's compute, at the price of
    one extra collective-launch latency per chunk.  ``C=1`` reproduces
    the serial sum exactly.
    """
    if backend not in BACKEND_MODELS:
        raise ValueError(f"unknown backend {backend!r}")
    if pipeline not in ("staged", "fused"):
        raise ValueError(f"unknown pipeline {pipeline!r}")
    m = BACKEND_MODELS[backend]
    w = sht_work(l_max, m_max, n_rings, n_phi, K, fft_lengths=fft_lengths,
                 spin=spin)
    byts = w["bytes"]
    if pipeline == "fused" and backend.startswith("pallas"):
        ncomp = 1 if spin == 0 else 2
        byts -= 16.0 * (m_max + 1) * n_rings * K * ncomp   # Delta stays on-chip
    leg_scale = 1.0
    if layout in ("plain", "packed") and backend.startswith("pallas"):
        pc = w["panels"] if lp_size == 128 else legendre_panel_counts(
            l_max, m_max, lp_size=lp_size, spin=spin)
        steps = (pc["plain_worked"] if layout == "plain" else pc["packed"]) \
            * pc["lp_size"]
        if pc["ideal_steps"] > 0:
            leg_scale = steps / pc["ideal_steps"]
    vec_rate = hw.peak_flops * m.vector_eff
    t = w["recurrence_flops"] * leg_scale / vec_rate \
        + w["fft_flops"] / vec_rate
    if m.matrix_eff > 0:
        t += w["accum_flops"] * leg_scale / (hw.peak_flops * m.matrix_eff)
    else:
        t += w["accum_flops"] * leg_scale / vec_rate
    t += byts / hw.hbm_bw
    if backend == "dist" and n_devices > 1:
        t /= n_devices
        # one tiled all_to_all of the (M, R, ncomp*2K) Delta block
        ncomp = 1 if spin == 0 else 2
        wire = 16.0 * (m_max + 1) * n_rings * K * ncomp / n_devices \
            * (n_devices - 1) / n_devices
        comm = wire / hw.link_bw
        C = max(1, int(comm_chunks))
        if overlap and C > 1 and comm > 0.0:
            comp_c = t / C
            comm_c = comm / C + hw.coll_latency
            t = comp_c + comm_c + (C - 1) * max(comp_c, comm_c)
        else:
            t += comm
    if direction == "anal":
        t *= m.anal_penalty
    return float(t)


def predict_comm_chunks(*, l_max: int, m_max: int, n_rings: int, n_phi: int,
                        K: int, direction: str = "synth",
                        hw: Hardware = HW_V5E, n_devices: int = 1,
                        fft_lengths=None, spin: int = 0,
                        max_chunks: int = 64) -> int:
    """Model-optimal ``comm_chunks`` for the dist backend's chunked
    exchange: argmin over powers of two of the overlapped
    `predict_sht_time`.  The cap is additionally clamped to what the plan
    can actually split -- the K channel axis, falling back to the local
    m rows (`SHTPlan.chunk_schedule` applies the same rule)."""
    if n_devices <= 1:
        return 1
    m_local = max(1, -(-(m_max + 2) // (2 * max(1, n_devices))) * 2)
    cap = min(max_chunks, max(int(K), m_local))
    cands = [1]
    while cands[-1] * 2 <= cap:
        cands.append(cands[-1] * 2)
    t_of = {c: predict_sht_time(
        "dist", l_max=l_max, m_max=m_max, n_rings=n_rings, n_phi=n_phi,
        K=K, direction=direction, hw=hw, n_devices=n_devices,
        fft_lengths=fft_lengths, spin=spin, overlap=True, comm_chunks=c)
        for c in cands}
    return int(min(t_of, key=t_of.get))

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  %all-reduce.1 = f32[512,128]{1,0} all-reduce(...), replica_groups=...
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_COLL_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, world: int) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_RE2.search(line)
    if m:  # replica_groups=[G,S] -> S per group
        return int(m.group(2))
    return world


def parse_hlo_collectives(hlo_text: str, world: int):
    """Yield (op_kind, payload_bytes, group_size) per collective op."""
    out = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            out.append((kind, _shape_bytes(dtype, dims),
                        _group_size(line, world)))
            continue
        m = _TUPLE_COLL_RE.search(line)
        if m:
            kind = m.group(2)
            tot = sum(_shape_bytes(d, s)
                      for d, s in _SHAPE_RE.findall(m.group(1)))
            # async tuple shapes repeat (operand, result): halve
            out.append((kind, tot // 2 if "-start" in line else tot,
                        _group_size(line, world)))
    return out


def collective_bytes(hlo_text: str, world: int) -> dict:
    """Per-device wire bytes by collective kind (ring-algorithm model)."""
    per_kind: dict = {}
    total = 0.0
    for kind, size, g in parse_hlo_collectives(hlo_text, world):
        frac = (g - 1) / max(g, 1)
        if kind == "all-reduce":
            wire = 2.0 * size * frac          # reduce-scatter + all-gather
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            wire = size * frac
        else:  # collective-permute
            wire = float(size)
        per_kind[kind] = per_kind.get(kind, 0.0) + wire
        per_kind.setdefault(f"{kind}_count", 0)
        per_kind[f"{kind}_count"] += 1
        total += wire
    per_kind["total"] = total
    return per_kind


def _cost_get(cost, key):
    if cost is None:
        return 0.0
    if isinstance(cost, dict):
        return float(cost.get(key, 0.0))
    if isinstance(cost, (list, tuple)) and cost:
        return float(cost[0].get(key, 0.0))
    return 0.0


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    n_devices: int
    hw: Hardware = HW_V5E
    model_flops: float = 0.0           # 6*N*D (or 6*N_active*D) total
    collectives: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def useful_flops_fraction(self) -> float:
        tot = self.flops_per_device * self.n_devices
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource bound achieved by useful work:
        t_useful_compute / max(t_compute, t_memory, t_collective)."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        if t_bound <= 0:
            return 0.0
        t_useful = (self.model_flops / max(self.n_devices, 1)) \
            / self.hw.peak_flops
        return t_useful / t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
        }


def analyze_compiled(compiled, *, n_devices: int, model_flops: float = 0.0,
                     hw: Hardware = HW_V5E) -> Roofline:
    cost = None
    try:
        cost = compiled.cost_analysis()
    except Exception:
        pass
    flops = _cost_get(cost, "flops")
    byts = _cost_get(cost, "bytes accessed")
    try:
        txt = compiled.as_text()
    except Exception:
        txt = ""
    colls = collective_bytes(txt, n_devices)
    return Roofline(
        flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=colls["total"], n_devices=n_devices, hw=hw,
        model_flops=model_flops, collectives=colls)
