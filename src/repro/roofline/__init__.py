# Roofline analysis: compiled-artifact cost extraction + 3-term model.
