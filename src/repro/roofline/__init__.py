# Roofline analysis: compiled-artifact cost extraction + 3-term model,
# plus the analytic SHT cost model that drives make_plan's dispatch, the
# persistent per-hardware characterization DB behind mode="auto", and the
# serving engine's latency-target admission control.
from repro.roofline import admission, chardb  # noqa: F401
from repro.roofline.analysis import (  # noqa: F401
    BACKEND_MODELS, BackendModel, HW_HOST, HW_V5E, Hardware, Roofline,
    analyze_compiled, collective_bytes, parse_hlo_collectives,
    predict_sht_time, sht_work,
)
