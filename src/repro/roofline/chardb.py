"""Persistent per-hardware autotune characterization DB.

``make_plan(mode="auto")`` used to one-shot time every candidate corner
(backend x direction x layout) on every cache-cold plan build.  This
module replaces that with a characterization database: measured corner
timings are cached under a *hardware fingerprint* (accelerator backend,
device kind/count, jax version, interpret flag), so

  * a corner is measured at most once per hardware per schema epoch --
    later plan builds (even after the decision cache is cleared) reuse
    the stored microseconds and re-measure zero corners;
  * stale corners (written by an older ``SCHEMA``) are transparently
    re-measured, gating regressions when the timing methodology changes;
  * smoke/CI runs (``REPRO_CHARDB_SMOKE=1``) *skip* corners absent from
    the DB instead of timing them, so CI runtime stays bounded --
    dispatch then falls back to the analytic cost-model ordering.

The store lives in process memory and, when a cache directory is in play
(the same disk tier ``core.cache`` uses, see `cache.cache_dir`), in a
``chardb_<fingerprint>.json`` file next to the other cached payloads.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Callable, Optional

__all__ = [
    "SCHEMA", "CharDB", "hardware_fingerprint", "get_db", "stats",
    "reset_stats", "clear",
]

#: bump when the timing methodology changes; older corners become stale.
#: 2: fused corners gained an ``lp_size`` coordinate (block-shape autotune)
#: and the fused-MXU kernels were restructured, invalidating old timings.
SCHEMA = 2

_SMOKE_ENV = "REPRO_CHARDB_SMOKE"

_lock = threading.Lock()
_DBS: dict[str, "CharDB"] = {}


def smoke_mode() -> bool:
    """True when CI asked for bounded runtime: never measure, only reuse."""
    return os.environ.get(_SMOKE_ENV, "") not in ("", "0")


def hardware_fingerprint(*, interpret: Optional[bool] = None) -> tuple:
    """(short-hash, human-readable string) identifying the hardware the
    timings are valid for.  Interpret-mode pallas timings are a different
    machine than compiled-TPU timings, so the flag is part of the key."""
    import jax
    dev = jax.devices()[0]
    if interpret is None:
        from repro.kernels.ops import should_interpret
        interpret = should_interpret()
    desc = "|".join([
        jax.default_backend(),
        getattr(dev, "device_kind", "?"),
        str(jax.device_count()),
        jax.__version__,
        f"interpret={int(bool(interpret))}",
    ])
    return hashlib.sha1(desc.encode()).hexdigest()[:16], desc


class CharDB:
    """One characterization store for one hardware fingerprint."""

    def __init__(self, fingerprint: str, desc: str,
                 directory: Optional[str] = None):
        self.fingerprint = fingerprint
        self.desc = desc
        self.directory = directory
        self._store: dict[str, dict] = {}
        self.counters = {"measured": 0, "reused": 0, "skipped": 0,
                         "stale": 0}
        if directory:
            self._load()

    # -- persistence -------------------------------------------------------

    @property
    def path(self) -> Optional[str]:
        if not self.directory:
            return None
        return os.path.join(self.directory,
                            f"chardb_{self.fingerprint}.json")

    def _load(self) -> None:
        try:
            with open(self.path) as fh:
                payload = json.load(fh)
            if isinstance(payload, dict):
                self._store.update(payload.get("corners", {}))
        except (OSError, ValueError):
            pass

    def _save(self) -> None:
        if not self.path:
            return
        os.makedirs(self.directory, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"fingerprint": self.fingerprint, "desc": self.desc,
                       "corners": self._store}, fh)
        os.replace(tmp, self.path)

    # -- corners -----------------------------------------------------------

    @staticmethod
    def corner_key(**fields) -> str:
        """Deterministic key over the corner coordinates.  Callers pass
        the *workload* coordinates (grid/l_max/K/dtype/backend/direction/
        layout/pipeline...) -- never the dispatch mode, so plans built
        with different modes share corners."""
        blob = json.dumps(fields, sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:24]

    def lookup(self, **fields):
        """The stored record for a corner (None if missing or stale)."""
        rec = self._store.get(self.corner_key(**fields))
        if rec is None or rec.get("schema") != SCHEMA:
            return None
        return rec

    def get_or_measure(self, measure_fn: Callable[[], float], **fields):
        """Return ``(us, status)`` for a corner.

        status: ``"reused"`` (fresh record found), ``"measured"`` (ran
        ``measure_fn`` and stored the result; stale records re-measure),
        or ``"skipped"`` (smoke mode and no fresh record: ``us`` is None
        and the caller should fall back to the cost model).
        """
        key = self.corner_key(**fields)
        with _lock:
            rec = self._store.get(key)
            if rec is not None and rec.get("schema") == SCHEMA:
                self.counters["reused"] += 1
                return rec.get("us"), "reused"
            if rec is not None:
                self.counters["stale"] += 1
        if smoke_mode():
            with _lock:
                self.counters["skipped"] += 1
            return None, "skipped"
        us = float(measure_fn())
        with _lock:
            self.counters["measured"] += 1
            self._store[key] = {"schema": SCHEMA, "us": us,
                                "fields": fields}
            self._save()
        return us, "measured"

    def characterize(self, corners, measure_fn) -> dict:
        """Sweep ``corners`` (iterable of field dicts), measuring any that
        are missing or stale via ``measure_fn(fields) -> us``.  Returns
        ``{status: count}``."""
        out = {"measured": 0, "reused": 0, "skipped": 0}
        for fields in corners:
            _, status = self.get_or_measure(
                lambda f=fields: measure_fn(f), **fields)
            out[status] += 1
        return out

    def stats(self) -> dict:
        return {"fingerprint": self.fingerprint, "corners": len(self._store),
                "path": self.path, **self.counters}


def get_db(directory: Optional[str] = None, *,
           interpret: Optional[bool] = None) -> CharDB:
    """The process-wide CharDB for the current hardware (memoized per
    ``(fingerprint, directory)``).  Pass the plan's disk-cache directory
    to persist corners across processes; None keeps them in memory."""
    fp, desc = hardware_fingerprint(interpret=interpret)
    key = f"{fp}:{directory or ''}"
    with _lock:
        db = _DBS.get(key)
        if db is None:
            db = _DBS[key] = CharDB(fp, desc, directory)
        return db


def stats() -> dict:
    """Aggregate counters over every CharDB opened by this process."""
    agg = {"measured": 0, "reused": 0, "skipped": 0, "stale": 0,
           "corners": 0, "dbs": 0}
    with _lock:
        for db in _DBS.values():
            for k in ("measured", "reused", "skipped", "stale"):
                agg[k] += db.counters[k]
            agg["corners"] += len(db._store)
            agg["dbs"] += 1
    return agg


def reset_stats() -> None:
    with _lock:
        for db in _DBS.values():
            db.counters = {k: 0 for k in db.counters}


def clear() -> None:
    """Drop every in-memory DB (disk files are left alone)."""
    with _lock:
        _DBS.clear()
