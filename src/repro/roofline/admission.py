"""Roofline-based admission control for the SHT serving engine.

libsharp (arXiv 1303.4945) sizes its work units from a calibrated
performance model rather than fixed caps; this module applies the same
idea to the serving engine's K-axis coalescing.  Instead of admitting
micro-batches up to a fixed ``max_k``, the engine asks: *given a p99
latency target, how wide may a coalesced batch of this signature be?*

The answer is the largest power-of-two K whose **predicted** device time
(`repro.roofline.predict_sht_time`, the same 3-term model that drives
``make_plan`` dispatch) still fits the target with a pipeline slack
factor:

    admit K  iff  slack * t_model(K) <= p99_target

``slack`` defaults to 2: under double-buffered serving a request can wait
behind at most one in-flight batch of its own size before its batch
starts, so the end-to-end tail is ~2 batch times in the steady state.
Analysis requests with Jacobi refinement (``iters > 0``) run
``1 + 2*iters`` transforms per call and are charged accordingly.

A target no K satisfies (even K=1 predicts over budget) is *infeasible*:
the engine still serves K=1 batches -- refusing service outright would
turn a mis-set knob into an outage -- but flags the group so
``stats()["admission"]`` surfaces the violation.  The engine also tracks
predicted-vs-measured batch compute (`repro.serve.metrics.Calibration`)
so operators can see how honest the model is on their hardware.
"""

from __future__ import annotations

from typing import Optional

from repro.roofline.analysis import (HW_HOST, HW_V5E, Hardware,
                                     predict_sht_time)

__all__ = ["default_model", "k_caps_for_target"]


def default_model() -> tuple:
    """(backend, Hardware) the admission model should price against on
    this host: the f64 jnp oracle on CPU, the MXU pipeline on devices."""
    import jax
    if jax.default_backend() == "cpu":
        return "jnp", HW_HOST
    return "pallas_mxu", HW_V5E


def k_caps_for_target(*, l_max: int, n_rings: int, n_phi: int, max_k: int,
                      p99_target_s: float, m_max: Optional[int] = None,
                      direction: str = "synth", iters: int = 0,
                      spin: int = 0, fft_lengths=None,
                      backend: Optional[str] = None,
                      hw: Optional[Hardware] = None,
                      slack: float = 2.0) -> dict:
    """The admissible coalescing width for one serving group.

    Evaluates ``predict_sht_time`` at every power-of-two K up to
    ``max_k`` and returns::

        {"k_cap":           largest admitted K (>= 1 always),
         "feasible":        False when even K=1 predicts over budget,
         "predicted_s":     model seconds at k_cap (incl. iters factor),
         "predicted_s_by_k": {K: model seconds} for every candidate K,
         "target_s", "slack", "backend", "direction"}

    ``direction`` is "synth" | "anal"; analysis with ``iters`` Jacobi
    passes costs ``1 + 2*iters`` transforms.  ``fft_lengths`` carries a
    ragged grid's per-ring FFT lengths into the model's phase term.
    """
    assert direction in ("synth", "anal"), direction
    assert p99_target_s > 0.0, p99_target_s
    assert slack > 0.0, slack
    m_max = l_max if m_max is None else m_max
    if backend is None or hw is None:
        b, h = default_model()
        backend = backend or b
        hw = hw or h
    mult = 1.0 if direction == "synth" else 1.0 + 2.0 * iters
    by_k: dict = {}
    k = 1
    while k <= max_k:
        by_k[k] = mult * predict_sht_time(
            backend, l_max=l_max, m_max=m_max, n_rings=n_rings, n_phi=n_phi,
            K=k, direction=direction, hw=hw, fft_lengths=fft_lengths,
            spin=spin)
        k *= 2
    fits = [kk for kk, t in by_k.items() if slack * t <= p99_target_s]
    k_cap = max(fits) if fits else 1
    return {
        "k_cap": int(k_cap),
        "feasible": bool(fits),
        "predicted_s": by_k[k_cap],
        "predicted_s_by_k": by_k,
        "target_s": float(p99_target_s),
        "slack": float(slack),
        "backend": backend,
        "direction": direction,
    }
