"""Warm Plan pool: bounded LRU of live transform plans, keyed by signature.

``make_plan`` memoises globally and never forgets; a serving process that
sees many distinct signatures over its lifetime needs a *bounded* working
set of live plans (each one owns device seed tables and compiled
executables).  ``PlanPool`` keeps the ``capacity`` most-recently-used
plans, releasing evicted ones through ``transform.drop_plan`` so they can
actually be garbage-collected, and exposes hit/miss/eviction/warm-up
counters for the engine's ``stats()``.

Plans here are always built with ``K = k_plan`` -- the engine's coalesced
channel-bucket width -- so one pooled plan serves every micro-batch of its
signature with a dense, fixed-shape device step (libsharp's "never launch
a ragged step" rule applied to the K axis).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from repro.core import cache as plancache

__all__ = ["PlanSig", "PlanPool"]


@dataclasses.dataclass(frozen=True)
class PlanSig:
    """The serving-level plan signature: everything that decides whether
    two requests may share one coalesced device batch (direction rides on
    the group key, not here -- one plan serves both directions)."""

    grid: str
    l_max: Optional[int] = None
    nside: Optional[int] = None
    m_max: Optional[int] = None
    spin: int = 0
    dtype: str = "float64"

    def label(self) -> str:
        geo = f"nside{self.nside}" if self.nside else f"lmax{self.l_max}"
        return f"{self.grid}/{geo}/spin{self.spin}/{self.dtype}"


class PlanPool:
    """Bounded LRU of warm plans on top of ``make_plan``'s signature cache.

    Thread-safe: ``get``/``warm`` may be called from the engine's
    formation thread and from background warm-up threads concurrently.
    The pool lock only guards the LRU map; *building* a plan happens
    outside it behind a per-key build event, so a warm-up compiling one
    signature never blocks ``get`` for a different signature (the
    double-buffered engine's formation thread must keep staging), while
    two concurrent requests for the *same* key still build it once.
    """

    def __init__(self, capacity: int = 8, *, mode: str = "auto",
                 cache: str = "auto", cache_dir: Optional[str] = None):
        self.mode = mode
        self.cache = cache
        self.cache_dir = cache_dir
        self._lock = threading.RLock()
        self._lru = plancache.LRU(capacity, on_evict=self._release)
        self._building: dict = {}           # key -> threading.Event
        self.hits = 0
        self.misses = 0
        self.warmups = 0

    @staticmethod
    def _release(key, plan) -> None:
        from repro.core import transform
        transform.drop_plan(plan)

    @property
    def capacity(self) -> int:
        return self._lru.capacity

    @property
    def evictions(self) -> int:
        return self._lru.evictions

    def __len__(self) -> int:
        return len(self._lru)

    def _key(self, sig: PlanSig, k_plan: int):
        return (sig, int(k_plan))

    def get(self, sig: PlanSig, k_plan: int):
        """The pooled plan for ``(sig, k_plan)``, building it on a miss."""
        import repro
        key = self._key(sig, k_plan)
        while True:
            with self._lock:
                plan = self._lru.get(key)
                if plan is not None:
                    self.hits += 1
                    return plan
                done = self._building.get(key)
                if done is None:
                    done = threading.Event()
                    self._building[key] = done
                    self.misses += 1
                    break
            # another thread is building this key: wait it out, then
            # re-check the LRU (on build failure we retry as the builder)
            done.wait()
        try:
            plan = repro.make_plan(
                sig.grid, sig.l_max, nside=sig.nside, m_max=sig.m_max,
                K=int(k_plan), dtype=sig.dtype, spin=sig.spin,
                mode=self.mode, cache=self.cache, cache_dir=self.cache_dir)
            with self._lock:
                self._lru.put(key, plan)
            return plan
        finally:
            with self._lock:
                del self._building[key]
            done.set()

    def warm(self, sig: PlanSig, k_plan: int,
             directions=("synth", "anal")):
        """Build AND compile the plan for ``(sig, k_plan)`` so the first
        real request pays no trace/compile latency."""
        plan = self.get(sig, k_plan)
        plan.warmup(directions)
        with self._lock:
            self.warmups += 1
        return plan

    def stats(self) -> dict:
        from repro.roofline import chardb
        with self._lock:
            total = self.hits + self.misses
            fusion = {"eligible": 0, "active": 0, "staged": 0}
            for plan in list(self._lru._data.values()):
                ok, _ = plan._fusion_eligibility()
                if not ok:
                    fusion["staged"] += 1
                    continue
                fusion["eligible"] += 1
                if any(plan.layouts.get(d) == "fused"
                       for d in ("synth", "anal")):
                    fusion["active"] += 1
            return {
                "size": len(self._lru),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "warmups": self.warmups,
                "hit_rate": (self.hits / total) if total else float("nan"),
                # fused-pipeline coverage of the warm set: how many pooled
                # plans could fuse and how many actually dispatch fused
                "fusion": fusion,
                # autotune corners behind the pooled plans: a warm pool
                # should show reuse, not re-measurement
                "chardb": chardb.stats(),
            }
