"""Latency accounting for the SHT serving engine.

Per-request timing is split the way a serving dashboard wants it:

* ``queue``   -- submit() to the moment its batch starts executing;
* ``compute`` -- the device wall time of the coalesced batch it rode in
  (shared by every request of that batch);
* ``total``   -- submit() to future resolution.

``percentile`` reimplements numpy's default linear-interpolation estimator
(so `engine.stats()` has no runtime numpy dependency on hot paths) and is
pinned against ``numpy.percentile`` in tests/test_serve.py.
"""

from __future__ import annotations

import math
from typing import Sequence

__all__ = ["percentile", "LatencyWindow", "Calibration"]


def percentile(xs: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) with linear interpolation between closest
    ranks -- numpy.percentile's default ``method="linear"``.  Empty input
    returns NaN."""
    n = len(xs)
    if n == 0:
        return float("nan")
    assert 0.0 <= q <= 100.0, q
    xs = sorted(float(v) for v in xs)
    pos = (q / 100.0) * (n - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class LatencyWindow:
    """Bounded sample store with percentile summaries.

    Keeps the most recent ``maxlen`` samples (a sustained-load engine must
    not grow without bound) while counting every record ever seen.
    """

    def __init__(self, maxlen: int = 4096):
        assert maxlen >= 1, maxlen
        self.maxlen = int(maxlen)
        self._samples: list[float] = []
        self.count = 0

    def record(self, value_s: float) -> None:
        self.count += 1
        self._samples.append(float(value_s))
        if len(self._samples) > self.maxlen:
            del self._samples[: len(self._samples) - self.maxlen]

    def samples(self) -> list[float]:
        return list(self._samples)

    def summary(self) -> dict:
        """count / mean / max / p50 / p95 / p99 over the retained window
        (seconds).  NaNs when nothing was recorded yet."""
        xs = self._samples
        if not xs:
            nan = float("nan")
            return {"count": 0, "mean_s": nan, "max_s": nan,
                    "p50_s": nan, "p95_s": nan, "p99_s": nan}
        return {
            "count": self.count,
            "mean_s": sum(xs) / len(xs),
            "max_s": max(xs),
            "p50_s": percentile(xs, 50.0),
            "p95_s": percentile(xs, 95.0),
            "p99_s": percentile(xs, 99.0),
        }


class Calibration:
    """Predicted-vs-measured batch compute, for admission control.

    The admission controller prices micro-batches with the roofline model
    (`repro.roofline.admission`); this tracker records, per executed
    batch, the model's prediction next to the measured device wall time
    so ``stats()`` can report how honest the model is on this host.
    ``ratio > 1`` means the model is optimistic (the device runs slower
    than predicted, so the admitted K is wider than the target warrants).
    """

    def __init__(self):
        self.count = 0
        self.sum_predicted_s = 0.0
        self.sum_measured_s = 0.0

    def record(self, predicted_s: float, measured_s: float) -> None:
        self.count += 1
        self.sum_predicted_s += float(predicted_s)
        self.sum_measured_s += float(measured_s)

    @property
    def ratio(self) -> float:
        if self.sum_predicted_s <= 0.0:
            return float("nan")
        return self.sum_measured_s / self.sum_predicted_s

    def summary(self) -> dict:
        return {"count": self.count,
                "predicted_s": self.sum_predicted_s,
                "measured_s": self.sum_measured_s,
                "ratio": self.ratio}
