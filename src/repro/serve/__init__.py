"""SHT serving layer: coalesce concurrent transform requests into the K
channel axis over a warm pool of plans.

    from repro.serve import ShtEngine
    eng = ShtEngine(max_k=8, mode="jnp", p99_target_s=0.050)
    fut = eng.submit(direction="alm2map", payload=alm, grid="gl", l_max=64)
    eng.drain()                       # or: with eng: ... (double-buffered
    maps = fut.result()               #     formation/execute threads)
    print(eng.report())               # p50/p95/p99, coalescing, admission

See docs/architecture.md ("Serving layer").
"""

from repro.serve.metrics import Calibration, LatencyWindow, percentile  # noqa: F401
from repro.serve.pool import PlanPool, PlanSig  # noqa: F401
from repro.serve.serve_loop import (  # noqa: F401
    BackpressureError, InvalidStateError, ShtEngine, ShtFuture, ShtRequest,
    ShtTimeoutError,
)

__all__ = [
    "ShtEngine", "ShtRequest", "ShtFuture", "PlanPool", "PlanSig",
    "BackpressureError", "ShtTimeoutError", "InvalidStateError",
    "LatencyWindow", "Calibration", "percentile",
]
