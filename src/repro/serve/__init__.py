# Serving substrate: KV/state caches live in repro.models; this package
# provides the batched prefill/decode loop drivers.
