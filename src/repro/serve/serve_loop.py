"""SHT serving engine: coalesce concurrent transform requests into the K
channel axis.

The batched transform is the throughput lever (the MXU contraction wants a
fat K axis; ``speedup/batched-K4`` in BENCH_*.json), but production traffic
arrives as independent single-map requests of mixed signatures.  This
engine closes that gap:

* requests are grouped by **plan signature** ``(grid, l_max/nside, m_max,
  spin, dtype)`` plus ``(direction, iters)`` -- only transforms that can
  share one device call are mixed;
* within a group, queued requests are **stacked along the K channel axis**
  up to ``max_k`` maps per micro-batch, zero-padded to a power-of-two K
  bucket so every device step has a dense, pre-compiled shape;
* execution goes through a **warm pool** of plans (`repro.serve.PlanPool`,
  a bounded LRU over ``make_plan`` with compile warm-up), so a recurring
  signature never re-traces;
* each request resolves an :class:`ShtFuture` carrying per-request
  queue/compute/total timing; ``engine.stats()`` aggregates latency
  percentiles (p50/p95/p99), coalescing factor, and plan-pool hit rate.

Fault containment: the queue is bounded (`submit` raises
:class:`BackpressureError` instead of growing without bound), a request
whose signature cannot build a plan -- or whose payload does not match its
claimed signature -- fails *its own* future only, and a per-request
``timeout`` evicts stale work at batch-formation time so one wedged
client cannot stall the loop.

Batches preserve FIFO order: within a signature strictly (the coalescer
never reorders a group's deque), and across signatures by oldest waiting
request.  Results are per-channel identical to independent per-request
``Plan`` calls -- the K axis is a pure batch axis in every backend
(asserted to 1e-12/f64 by tests/test_serve.py and bench_serve).

The engine runs in two modes: pump it synchronously (``step()`` /
``drain()``, deterministic -- what the tests use) or start the background
serving thread (``with engine: ...`` or ``start()``/``stop()``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.metrics import LatencyWindow
from repro.serve.pool import PlanPool, PlanSig

__all__ = ["ShtEngine", "ShtRequest", "ShtFuture", "BackpressureError",
           "ShtTimeoutError", "InvalidStateError"]


class BackpressureError(RuntimeError):
    """submit() refused: the bounded request queue is full."""


class ShtTimeoutError(TimeoutError):
    """The request exceeded its timeout while queued and was evicted."""


class InvalidStateError(RuntimeError):
    """A future was resolved twice (engine invariant violation)."""


class ShtFuture:
    """Write-once result handle for one submitted transform request.

    ``result(timeout)`` blocks until the engine resolves it (re-raising
    the failure, if any); ``timing`` carries the per-request latency split
    (``queue_s`` / ``compute_s`` / ``total_s``) once done.
    """

    def __init__(self, rid: int):
        self.rid = rid
        self.timing: dict = {}
        self._event = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        return self._exc

    # -- engine side (write-once) -------------------------------------------

    def _check_unresolved(self) -> None:
        if self._event.is_set():
            raise InvalidStateError(f"future {self.rid} already resolved")

    def _resolve(self, value) -> None:
        self._check_unresolved()
        self._value = value
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._check_unresolved()
        self._exc = exc
        self._event.set()


@dataclasses.dataclass
class ShtRequest:
    """One transform request: a payload plus the plan signature it claims.

    ``payload`` shapes (K axis optional -- a trailing channel axis is
    accepted and split back out; without it the result is unbatched):

    ==========  ======  ===============================
    direction   spin    payload
    ==========  ======  ===============================
    alm2map     0       ``(M, L[, K])`` complex
    alm2map     2       ``(2, M, L[, K])`` complex  (E, B)
    map2alm     0       ``(R, n_phi[, K])`` real
    map2alm     2       ``(2, R, n_phi[, K])`` real (Q, U)
    ==========  ======  ===============================
    """

    direction: str                    # "alm2map" | "map2alm"
    payload: np.ndarray
    grid: str = "gl"
    l_max: Optional[int] = None
    nside: Optional[int] = None
    m_max: Optional[int] = None
    spin: int = 0
    dtype: str = "float64"
    iters: int = 0                    # map2alm Jacobi refinement passes
    timeout: Optional[float] = None   # seconds in queue before eviction
    tag: Optional[str] = None         # caller-side label (not interpreted)

    def signature(self) -> PlanSig:
        return PlanSig(grid=self.grid, l_max=self.l_max, nside=self.nside,
                       m_max=self.m_max, spin=self.spin, dtype=self.dtype)


@dataclasses.dataclass
class _Pending:
    """Queue entry: a validated request plus its engine bookkeeping."""

    request: ShtRequest
    future: ShtFuture
    seq: int
    payload: np.ndarray               # K axis always explicit
    k: int
    squeeze: bool                     # drop the K axis from the result
    t_submit: float
    deadline: Optional[float]


def _normalize_payload(req: ShtRequest) -> tuple[np.ndarray, int, bool]:
    """Coerce the payload to an explicit trailing-K layout; returns
    ``(array, K, squeeze)``.  Raises ValueError on malformed requests --
    the cheap checks run at submit() so obviously-bad requests never
    occupy queue slots."""
    if req.direction not in ("alm2map", "map2alm"):
        raise ValueError(f"unknown direction {req.direction!r}")
    if req.spin not in (0, 2):
        raise ValueError(f"unsupported spin {req.spin!r}")
    if req.dtype not in ("float64", "float32"):
        raise ValueError(f"unsupported dtype {req.dtype!r}")
    if not isinstance(req.grid, str):
        raise ValueError("serving requests take string grid specs "
                         f"(got {type(req.grid).__name__})")
    if req.iters < 0:
        raise ValueError(f"iters must be >= 0 (got {req.iters})")
    arr = np.asarray(req.payload)
    base_ndim = 2 + (1 if req.spin else 0)
    if arr.ndim == base_ndim:
        arr, k, squeeze = arr[..., None], 1, True
    elif arr.ndim == base_ndim + 1:
        k, squeeze = int(arr.shape[-1]), False
        if k < 1:
            raise ValueError(f"empty K axis in payload shape {arr.shape}")
    else:
        raise ValueError(
            f"payload ndim {arr.ndim} does not match a spin-{req.spin} "
            f"{req.direction} request (expected {base_ndim} or "
            f"{base_ndim + 1} dims)")
    want_complex = req.direction == "alm2map"
    if want_complex != np.iscomplexobj(arr):
        kind = "complex alm" if want_complex else "real maps"
        raise ValueError(f"{req.direction} payload must be {kind} "
                         f"(got dtype {arr.dtype})")
    return arr, k, squeeze


class ShtEngine:
    """Many-map SHT serving engine (see module docstring).

    Parameters
    ----------
    max_k : maximum maps coalesced into one device micro-batch (the K
        channel width plans are built for).
    max_queue : bounded pending-request count; ``submit`` raises
        :class:`BackpressureError` beyond it.
    pool_capacity : live plans kept warm (LRU; evictions release the plan
        through ``transform.drop_plan``).
    mode / cache / cache_dir : forwarded to ``make_plan`` for every pooled
        plan (``mode="jnp"`` gives deterministic f64 serving; ``"auto"``
        autotunes per signature, decision cached).
    default_timeout : per-request queue timeout (seconds) used when a
        request does not set its own; None = never evict.
    warm_after : after a signature has been submitted this many times,
        pre-compile its full-width plan in a background thread so the
        steady state never re-traces.  None disables auto warm-up.
    """

    def __init__(self, *, max_k: int = 8, max_queue: int = 128,
                 pool_capacity: int = 8, mode: str = "auto",
                 cache: str = "auto", cache_dir: Optional[str] = None,
                 default_timeout: Optional[float] = None,
                 warm_after: Optional[int] = None,
                 latency_window: int = 4096):
        assert max_k >= 1 and max_queue >= 1
        self.max_k = int(max_k)
        self.max_queue = int(max_queue)
        self.default_timeout = default_timeout
        self.warm_after = warm_after
        self.pool = PlanPool(pool_capacity, mode=mode, cache=cache,
                             cache_dir=cache_dir)

        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._groups: dict = {}             # group key -> deque[_Pending]
        self._seq = 0
        self._closed = False
        self._thread: Optional[threading.Thread] = None
        self._stop = False

        # -- observability ----------------------------------------------------
        self._lat_queue = LatencyWindow(latency_window)
        self._lat_compute = LatencyWindow(latency_window)
        self._lat_total = LatencyWindow(latency_window)
        self.batch_log: list[dict] = []     # bounded, most recent first out
        self._batch_log_cap = latency_window
        self._n_submitted = 0
        self._n_completed = 0
        self._n_failed = 0
        self._n_timed_out = 0
        self._n_batches = 0
        self._sum_batch_requests = 0
        self._sum_batch_k = 0
        self._sum_batch_k_plan = 0
        self._sig_counts: dict[PlanSig, int] = {}
        self._warm_started: set[PlanSig] = set()
        self._warm_threads: list[threading.Thread] = []
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # -- submission -----------------------------------------------------------

    def _k_bucket(self, k: int) -> int:
        """Smallest power-of-two channel width >= k, capped at max_k --
        the set of K shapes plans are ever compiled for."""
        b = 1
        while b < min(k, self.max_k):
            b *= 2
        return min(b, self.max_k)

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._groups.values())

    def submit(self, request: Optional[ShtRequest] = None,
               **kw) -> ShtFuture:
        """Enqueue one transform request; returns its :class:`ShtFuture`.

        Pass a prebuilt :class:`ShtRequest` or its fields as keywords
        (``engine.submit(direction="alm2map", payload=alm, grid="gl",
        l_max=64)``).  Raises ValueError on malformed requests and
        :class:`BackpressureError` when the queue is full.
        """
        if request is None:
            request = ShtRequest(**kw)
        elif kw:
            raise TypeError("pass either a request object or keywords")
        payload, k, squeeze = _normalize_payload(request)
        if k > self.max_k:
            raise ValueError(
                f"request K={k} exceeds the engine's max_k={self.max_k}; "
                "split the batch or build a wider engine")
        timeout = request.timeout if request.timeout is not None \
            else self.default_timeout
        now = time.perf_counter()
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            n_pending = sum(len(q) for q in self._groups.values())
            if n_pending >= self.max_queue:
                raise BackpressureError(
                    f"queue full ({n_pending}/{self.max_queue} pending); "
                    "drain or raise max_queue")
            fut = ShtFuture(rid=self._seq)
            p = _Pending(request=request, future=fut, seq=self._seq,
                         payload=payload, k=k, squeeze=squeeze,
                         t_submit=now,
                         deadline=None if timeout is None else now + timeout)
            self._seq += 1
            self._n_submitted += 1
            if self._t_first_submit is None:
                self._t_first_submit = now
            gkey = (request.signature(), request.direction, request.iters)
            self._groups.setdefault(gkey, deque()).append(p)
            sig = gkey[0]
            self._sig_counts[sig] = self._sig_counts.get(sig, 0) + 1
            warm = (self.warm_after is not None
                    and self._sig_counts[sig] == self.warm_after
                    and sig not in self._warm_started)
            if warm:
                self._warm_started.add(sig)
            self._work.notify_all()
        if warm:
            self._spawn_warm(sig, self.max_k)
        return fut

    def _spawn_warm(self, sig: PlanSig, k: int) -> threading.Thread:
        t = threading.Thread(target=self._warm_quietly, args=(sig, k),
                             name=f"sht-warm-{sig.label()}", daemon=True)
        with self._lock:
            self._warm_threads.append(t)
        t.start()
        return t

    def _join_warmups(self) -> None:
        """Wait out in-flight background warm-ups (a compile racing
        interpreter shutdown aborts the process)."""
        with self._lock:
            threads, self._warm_threads = self._warm_threads, []
        for t in threads:
            t.join()

    def _warm_quietly(self, sig: PlanSig, k: int) -> None:
        try:
            self.pool.warm(sig, self._k_bucket(k))
        except Exception:
            pass  # a bad signature fails loudly on its own batch instead

    def prewarm(self, *, k: Optional[int] = None, background: bool = False,
                **sig_fields):
        """Warm the pool for a signature before traffic arrives.

        ``sig_fields`` are :class:`PlanSig` fields (grid, l_max, nside,
        m_max, spin, dtype); ``k`` defaults to the engine's full ``max_k``
        width.  ``background=True`` returns the started thread instead of
        blocking."""
        sig = PlanSig(**sig_fields)
        k_plan = self._k_bucket(k if k is not None else self.max_k)
        if background:
            return self._spawn_warm(sig, k_plan)
        return self.pool.warm(sig, k_plan)

    # -- the serving loop ------------------------------------------------------

    def _evict_expired_locked(self, now: float) -> list[_Pending]:
        out = []
        for gkey, q in self._groups.items():
            if not any(p.deadline is not None and p.deadline < now
                       for p in q):
                continue
            keep: deque = deque()
            for p in q:
                if p.deadline is not None and p.deadline < now:
                    out.append(p)
                else:
                    keep.append(p)
            self._groups[gkey] = keep
        return out

    def _pop_batch_locked(self):
        """FIFO batch formation: the group whose head waited longest wins;
        its requests are taken in order while they fit in max_k (never
        skipping over one that does not -- order is part of the contract).
        """
        live = {g: q for g, q in self._groups.items() if q}
        if not live:
            return None, []
        gkey = min(live, key=lambda g: live[g][0].seq)
        q = live[gkey]
        batch, k_sum = [], 0
        while q and k_sum + q[0].k <= self.max_k:
            p = q.popleft()
            batch.append(p)
            k_sum += p.k
        return gkey, batch

    def step(self) -> int:
        """Process one coalesced micro-batch (plus any timeout evictions).

        Returns the number of requests retired (resolved, failed or
        evicted); 0 means the queue was empty.
        """
        now = time.perf_counter()
        with self._lock:
            expired = self._evict_expired_locked(now)
            gkey, batch = self._pop_batch_locked()
        n = 0
        for p in expired:
            waited = now - p.t_submit
            self._retire(p, exc=ShtTimeoutError(
                f"request {p.future.rid} evicted after {waited:.3f}s in "
                f"queue (timeout)"), kind="timeout",
                timing={"queue_s": waited, "compute_s": 0.0,
                        "total_s": waited})
            n += 1
        if batch:
            n += self._execute(gkey, batch)
        return n

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every pending request is retired.

        Synchronous mode pumps ``step()`` inline; with the background
        thread running it just waits.  Raises TimeoutError if the queue is
        not empty by ``timeout`` seconds.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        while self.pending:
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(f"drain: {self.pending} request(s) "
                                   f"still pending after {timeout}s")
            if self._thread is None:
                self.step()
            else:
                time.sleep(0.002)
        self._join_warmups()

    # -- execution ------------------------------------------------------------

    def _retire(self, p: _Pending, *, result=None, exc=None, kind: str,
                timing: Optional[dict] = None) -> None:
        p.future.timing = dict(timing or {})
        if exc is not None:
            p.future._fail(exc)
        else:
            p.future._resolve(result)
        with self._lock:
            if kind == "ok":
                self._n_completed += 1
            elif kind == "timeout":
                self._n_timed_out += 1
            else:
                self._n_failed += 1
            t = timing or {}
            if "queue_s" in t:
                self._lat_queue.record(t["queue_s"])
            if kind == "ok":
                self._lat_compute.record(t.get("compute_s", 0.0))
                self._lat_total.record(t.get("total_s", 0.0))
            self._t_last_done = time.perf_counter()

    def _log_batch(self, sig: PlanSig, direction: str, batch, k_total: int,
                   k_plan: int, ok: bool) -> None:
        with self._lock:
            self._n_batches += 1
            self._sum_batch_requests += len(batch)
            self._sum_batch_k += k_total
            self._sum_batch_k_plan += k_plan
            self.batch_log.append({
                "signature": sig.label(), "direction": direction,
                "rids": [p.future.rid for p in batch],
                "n_requests": len(batch), "k_total": k_total,
                "k_plan": k_plan, "ok": ok,
            })
            if len(self.batch_log) > self._batch_log_cap:
                del self.batch_log[: len(self.batch_log)
                                   - self._batch_log_cap]

    def _execute(self, gkey, batch: list[_Pending]) -> int:
        import jax
        import jax.numpy as jnp

        sig, direction, iters = gkey
        t_start = time.perf_counter()
        k_claim = sum(p.k for p in batch)
        k_plan = self._k_bucket(k_claim)

        def fail_all(ps, exc):
            for p in ps:
                waited = t_start - p.t_submit
                self._retire(p, exc=exc, kind="failed",
                             timing={"queue_s": waited})

        try:
            plan = self.pool.get(sig, k_plan)
        except Exception as e:
            fail_all(batch, e)
            self._log_batch(sig, direction, batch, k_claim, k_plan, ok=False)
            return len(batch)

        # per-request shape validation against the *resolved* plan: a
        # payload that lied about its signature fails alone, not its batch
        base = (plan._alm_shape if direction == "alm2map"
                else plan._maps_shape)[:-1]
        good, k_total = [], 0
        for p in batch:
            if p.payload.shape[:-1] != base:
                self._retire(p, exc=ValueError(
                    f"payload shape {p.payload.shape} does not match plan "
                    f"{sig.label()} (expected {base} + (K,))"),
                    kind="failed",
                    timing={"queue_s": t_start - p.t_submit})
            else:
                good.append(p)
                k_total += p.k
        if not good:
            self._log_batch(sig, direction, batch, 0, k_plan, ok=False)
            return len(batch)

        cdtype = np.complex128 if sig.dtype == "float64" else np.complex64
        rdtype = np.dtype(sig.dtype)
        want = cdtype if direction == "alm2map" else rdtype
        parts = [np.ascontiguousarray(p.payload, dtype=want) for p in good]
        if k_total < plan.K:                       # dense K bucket: zero-pad
            parts.append(np.zeros(base + (plan.K - k_total,), dtype=want))
        stacked = np.concatenate(parts, axis=-1)

        try:
            if direction == "alm2map":
                out = plan.alm2map(jnp.asarray(stacked))
            else:
                out = plan.map2alm(jnp.asarray(stacked), iters=iters)
            jax.block_until_ready(out)
        except Exception as e:
            fail_all(good, e)
            self._log_batch(sig, direction, batch, k_total, k_plan, ok=False)
            return len(batch)
        t_done = time.perf_counter()
        compute_s = t_done - t_start

        out = np.asarray(out)
        off = 0
        for p in good:
            res = out[..., off:off + p.k]
            off += p.k
            if p.squeeze:
                res = res[..., 0]
            self._retire(p, result=res, kind="ok", timing={
                "queue_s": t_start - p.t_submit,
                "compute_s": compute_s,
                "total_s": t_done - p.t_submit,
                "k_plan": k_plan,
                "coalesced_with": len(good) - 1,
            })
        self._log_batch(sig, direction, good, k_total, k_plan, ok=True)
        return len(batch)

    # -- background serving ----------------------------------------------------

    def start(self) -> "ShtEngine":
        """Start the background serving thread (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return self
            self._stop = False
            self._thread = threading.Thread(target=self._loop,
                                            name="sht-serve", daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._lock:
                if self._stop:
                    return
            if self.step() == 0:
                with self._work:
                    if self._stop:
                        return
                    self._work.wait(timeout=0.01)

    def stop(self, drain: bool = True) -> None:
        """Stop the background thread; ``drain=True`` (default) retires
        the remaining queue synchronously first."""
        t = self._thread
        if t is not None:
            with self._work:
                self._stop = True
                self._work.notify_all()
            t.join()
            self._thread = None
        if drain:
            while self.pending:
                self.step()
        self._join_warmups()

    def close(self) -> None:
        """Stop serving and refuse further submissions; pending requests
        fail with RuntimeError."""
        self.stop(drain=False)
        with self._lock:
            self._closed = True
            leftovers = [p for q in self._groups.values() for p in q]
            self._groups.clear()
        for p in leftovers:
            self._retire(p, exc=RuntimeError("engine closed"), kind="failed",
                         timing={})

    def __enter__(self) -> "ShtEngine":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop(drain=True)

    # -- observability ---------------------------------------------------------

    def stats(self) -> dict:
        """Structured serving metrics: request counters, latency
        percentiles (seconds), coalescing factors, plan-pool counters and
        sustained throughput."""
        with self._lock:
            n_pending = sum(len(q) for q in self._groups.values())
            nb = self._n_batches
            elapsed = None
            if self._t_first_submit is not None \
                    and self._t_last_done is not None:
                elapsed = self._t_last_done - self._t_first_submit
            return {
                "requests": {
                    "submitted": self._n_submitted,
                    "completed": self._n_completed,
                    "failed": self._n_failed,
                    "timed_out": self._n_timed_out,
                    "pending": n_pending,
                },
                "latency": {
                    "queue": self._lat_queue.summary(),
                    "compute": self._lat_compute.summary(),
                    "total": self._lat_total.summary(),
                },
                "coalescing": {
                    "batches": nb,
                    "requests_per_batch":
                        (self._sum_batch_requests / nb) if nb
                        else float("nan"),
                    "k_per_batch":
                        (self._sum_batch_k / nb) if nb else float("nan"),
                    "k_occupancy":
                        (self._sum_batch_k / self._sum_batch_k_plan)
                        if self._sum_batch_k_plan else float("nan"),
                },
                "pool": self.pool.stats(),
                "signatures": {s.label(): c
                               for s, c in self._sig_counts.items()},
                "throughput_rps":
                    (self._n_completed / elapsed)
                    if elapsed and elapsed > 0 else float("nan"),
            }

    def report(self) -> str:
        """Human-readable ``stats()`` (the serving analogue of
        ``Plan.report()``)."""
        s = self.stats()
        r, lat, co, pool = (s["requests"], s["latency"], s["coalescing"],
                            s["pool"])

        def ms(x):
            return f"{x * 1e3:.2f}ms" if np.isfinite(x) else "n/a"

        lines = [
            f"ShtEngine max_k={self.max_k} queue={r['pending']}/"
            f"{self.max_queue} pool={pool['size']}/{pool['capacity']} "
            f"(hit_rate {pool['hit_rate']:.2f})"
            if np.isfinite(pool["hit_rate"]) else
            f"ShtEngine max_k={self.max_k} queue={r['pending']}/"
            f"{self.max_queue} pool={pool['size']}/{pool['capacity']}",
            f"  requests: {r['completed']} done / {r['failed']} failed / "
            f"{r['timed_out']} timed out "
            f"(throughput {s['throughput_rps']:.1f} req/s)"
            if np.isfinite(s["throughput_rps"]) else
            f"  requests: {r['completed']} done / {r['failed']} failed / "
            f"{r['timed_out']} timed out",
            f"  latency total p50={ms(lat['total']['p50_s'])} "
            f"p95={ms(lat['total']['p95_s'])} "
            f"p99={ms(lat['total']['p99_s'])} "
            f"(queue p50={ms(lat['queue']['p50_s'])}, "
            f"compute p50={ms(lat['compute']['p50_s'])})",
        ]
        if s["coalescing"]["batches"]:
            lines.append(
                f"  coalescing: x{co['requests_per_batch']:.2f} req/batch, "
                f"K {co['k_per_batch']:.2f} "
                f"(occupancy {co['k_occupancy']:.2f}) over "
                f"{co['batches']} batches")
        for label, count in sorted(s["signatures"].items()):
            lines.append(f"    {label}: {count} request(s)")
        return "\n".join(lines)
