"""SHT serving engine: coalesce concurrent transform requests into the K
channel axis, double-buffered against a warm plan pool.

The batched transform is the throughput lever (the MXU contraction wants a
fat K axis; ``speedup/batched-K4`` in BENCH_*.json), but production traffic
arrives as independent single-map requests of mixed signatures.  This
engine closes that gap:

* requests are grouped by **plan signature** ``(grid, l_max/nside, m_max,
  spin, dtype)`` plus ``(direction, iters)`` -- only transforms that can
  share one device call are mixed;
* within a group, queued requests are **stacked along the K channel axis**
  into power-of-two K buckets so every device step has a dense,
  pre-compiled shape.  The bucket width is capped by ``max_k`` and -- when
  a ``p99_target_s`` is set -- by **roofline admission control**
  (`repro.roofline.admission`): the largest K whose *predicted* batch
  time still fits the latency target, libsharp's performance-model idea
  applied to coalescing;
* across groups, batch formation runs **weighted deficit round-robin**
  (WDRR): every signature group with queued work gets a deficit top-up of
  ``quantum * weight`` K-units per scheduling round and spends it to send
  batches, so one hot tenant can be 10x the traffic of a minority
  signature without starving it (FIFO order is still strict *within* a
  group);
* execution goes through a **warm pool** of plans (`repro.serve.PlanPool`,
  a bounded LRU over ``make_plan`` with compile warm-up), so a recurring
  signature never re-traces;
* each request resolves an :class:`ShtFuture` carrying per-request
  queue/form/compute/total timing; ``engine.stats()`` aggregates latency
  percentiles (p50/p95/p99), coalescing factor, plan-pool hit rate,
  admission caps, and roofline-vs-measured calibration.

Request lifecycle (the state machine ``stats()`` accounts for)::

    submit() --> QUEUED --(batch formation pops)--> IN-FLIGHT
                    |                                   |
                    +--(deadline expired)---------------+--> RETIRED
                                                  (resolved | failed
                                                   | timed out)

``pending`` counts QUEUED + IN-FLIGHT, so ``drain()`` cannot return while
a popped micro-batch is still executing, and ``max_queue`` bounds total
engine *occupancy*, not just the queue.

The engine runs in two modes.  Synchronous: pump ``step()`` / ``drain()``
inline (deterministic -- what most tests use).  Background
(``with engine:`` or ``start()``/``stop()``): **double-buffered
submit->execute** in the spirit of the paper's host/device overlap -- a
formation thread stages batch i+1 (pops requests, resolves the pooled
plan, stacks and uploads the host payload) while the execute thread runs
batch i on the device, with a capacity-one condition-variable handoff
slot between them (no polling sleeps anywhere on the serving path).

Fault containment: the queue is bounded (`submit` raises
:class:`BackpressureError` instead of growing without bound), a request
whose signature cannot build a plan -- or whose payload does not match its
claimed signature -- fails *its own* future only, and a per-request
``timeout`` evicts stale work at batch-formation time so one wedged
client cannot stall the loop.

Results are per-channel identical to independent per-request ``Plan``
calls -- the K axis is a pure batch axis in every backend (asserted to
1e-12/f64 by tests/test_serve.py and bench_serve).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.metrics import Calibration, LatencyWindow
from repro.serve.pool import PlanPool, PlanSig

__all__ = ["ShtEngine", "ShtRequest", "ShtFuture", "BackpressureError",
           "ShtTimeoutError", "InvalidStateError"]


class BackpressureError(RuntimeError):
    """submit() refused: queued + in-flight requests already fill
    ``max_queue``."""


class ShtTimeoutError(TimeoutError):
    """The request exceeded its timeout while queued and was evicted."""


class InvalidStateError(RuntimeError):
    """A future was resolved twice (engine invariant violation)."""


class ShtFuture:
    """Write-once result handle for one submitted transform request.

    ``result(timeout)`` blocks until the engine resolves it (re-raising
    the failure, if any); ``timing`` carries the per-request latency split
    (``queue_s`` / ``compute_s`` / ``total_s``) once done.
    """

    def __init__(self, rid: int):
        self.rid = rid
        self.timing: dict = {}
        self._event = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._value

    def exception(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not resolved "
                               f"within {timeout}s")
        return self._exc

    # -- engine side (write-once) -------------------------------------------

    def _check_unresolved(self) -> None:
        if self._event.is_set():
            raise InvalidStateError(f"future {self.rid} already resolved")

    def _resolve(self, value) -> None:
        self._check_unresolved()
        self._value = value
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._check_unresolved()
        self._exc = exc
        self._event.set()


@dataclasses.dataclass
class ShtRequest:
    """One transform request: a payload plus the plan signature it claims.

    ``payload`` shapes (K axis optional -- a trailing channel axis is
    accepted and split back out; without it the result is unbatched):

    ==========  ======  ===============================
    direction   spin    payload
    ==========  ======  ===============================
    alm2map     0       ``(M, L[, K])`` complex
    alm2map     2       ``(2, M, L[, K])`` complex  (E, B)
    map2alm     0       ``(R, n_phi[, K])`` real
    map2alm     2       ``(2, R, n_phi[, K])`` real (Q, U)
    ==========  ======  ===============================
    """

    direction: str                    # "alm2map" | "map2alm"
    payload: np.ndarray
    grid: str = "gl"
    l_max: Optional[int] = None
    nside: Optional[int] = None
    m_max: Optional[int] = None
    spin: int = 0
    dtype: str = "float64"
    iters: int = 0                    # map2alm Jacobi refinement passes
    timeout: Optional[float] = None   # seconds in queue before eviction
    tag: Optional[str] = None         # caller-side label (not interpreted)

    def signature(self) -> PlanSig:
        return PlanSig(grid=self.grid, l_max=self.l_max, nside=self.nside,
                       m_max=self.m_max, spin=self.spin, dtype=self.dtype)


@dataclasses.dataclass
class _Pending:
    """Queue entry: a validated request plus its engine bookkeeping."""

    request: ShtRequest
    future: ShtFuture
    seq: int
    payload: np.ndarray               # K axis always explicit
    k: int
    squeeze: bool                     # drop the K axis from the result
    t_submit: float
    deadline: Optional[float]
    state: str = "queued"             # queued -> in_flight -> retired


@dataclasses.dataclass
class _Staged:
    """A formed micro-batch, host side done: the unit the formation
    thread hands to the execute thread through the double-buffer slot."""

    gkey: tuple                       # (PlanSig, direction, iters)
    plan: object
    good: list                        # _Pending entries riding this batch
    dev: object                       # stacked device payload (K = k_plan)
    k_total: int
    k_plan: int
    form_s: float                     # host-side staging wall time
    predicted_s: Optional[float]      # admission model's batch estimate


class _HandoffSlot:
    """Capacity-one staging slot between formation and execution: the
    double buffer.  ``put`` blocks while the previous staged batch has
    not been taken; ``take`` blocks until a batch arrives (or the slot is
    closed *and* empty, returning None).  Pure condition-variable
    handoff -- no polling."""

    def __init__(self):
        self._cv = threading.Condition()
        self._item = None
        self._closed = False

    def put(self, item) -> bool:
        with self._cv:
            while self._item is not None and not self._closed:
                self._cv.wait()
            if self._closed:
                return False
            self._item = item
            self._cv.notify_all()
            return True

    def take(self):
        with self._cv:
            while self._item is None and not self._closed:
                self._cv.wait()
            item, self._item = self._item, None
            self._cv.notify_all()
            return item

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()


def _pow2_floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


def _normalize_payload(req: ShtRequest) -> tuple[np.ndarray, int, bool]:
    """Coerce the payload to an explicit trailing-K layout; returns
    ``(array, K, squeeze)``.  Raises ValueError on malformed requests --
    the cheap checks run at submit() so obviously-bad requests never
    occupy queue slots."""
    if req.direction not in ("alm2map", "map2alm"):
        raise ValueError(f"unknown direction {req.direction!r}")
    if req.spin not in (0, 2):
        raise ValueError(f"unsupported spin {req.spin!r}")
    if req.dtype not in ("float64", "float32"):
        raise ValueError(f"unsupported dtype {req.dtype!r}")
    if not isinstance(req.grid, str):
        raise ValueError("serving requests take string grid specs "
                         f"(got {type(req.grid).__name__})")
    if req.iters < 0:
        raise ValueError(f"iters must be >= 0 (got {req.iters})")
    arr = np.asarray(req.payload)
    base_ndim = 2 + (1 if req.spin else 0)
    if arr.ndim == base_ndim:
        arr, k, squeeze = arr[..., None], 1, True
    elif arr.ndim == base_ndim + 1:
        k, squeeze = int(arr.shape[-1]), False
        if k < 1:
            raise ValueError(f"empty K axis in payload shape {arr.shape}")
    else:
        raise ValueError(
            f"payload ndim {arr.ndim} does not match a spin-{req.spin} "
            f"{req.direction} request (expected {base_ndim} or "
            f"{base_ndim + 1} dims)")
    want_complex = req.direction == "alm2map"
    if want_complex != np.iscomplexobj(arr):
        kind = "complex alm" if want_complex else "real maps"
        raise ValueError(f"{req.direction} payload must be {kind} "
                         f"(got dtype {arr.dtype})")
    return arr, k, squeeze


class ShtEngine:
    """Many-map SHT serving engine (see module docstring).

    Parameters
    ----------
    max_k : maximum maps coalesced into one device micro-batch.  Clamped
        to the largest power of two <= the requested value (K buckets are
        power-of-two by contract -- a non-power-of-two cap would fragment
        the plan-pool key space); the raw value stays visible as
        ``requested_max_k``.
    max_queue : bounded engine occupancy (queued **plus** in-flight
        requests); ``submit`` raises :class:`BackpressureError` beyond it.
    pool_capacity : live plans kept warm (LRU; evictions release the plan
        through ``transform.drop_plan``).
    mode / cache / cache_dir : forwarded to ``make_plan`` for every pooled
        plan (``mode="jnp"`` gives deterministic f64 serving; ``"auto"``
        autotunes per signature, decision cached).
    default_timeout : per-request queue timeout (seconds) used when a
        request does not set its own; None = never evict.
    warm_after : after a signature has been submitted this many times,
        pre-compile its full-width plan in a background thread so the
        steady state never re-traces.  None disables auto warm-up.
    p99_target_s : tail-latency target driving roofline admission control
        (`repro.roofline.admission`): per serving group, the coalesced K
        bucket is capped at the widest power-of-two K whose predicted
        batch time fits the target with ``admission_slack`` headroom.
        None (default) disables admission control (``max_k`` rules).
    admission_slack : pipeline slack factor for the admission test
        (default 2.0: a request waits behind at most one in-flight batch
        under double buffering).
    weights : optional ``{PlanSig.label(): weight}`` map for WDRR batch
        formation; unlisted signatures weigh 1.0.  A weight-w group earns
        ``w * quantum_k`` K-units of deficit per scheduling round.
    quantum_k : WDRR round quantum in K-units (default: the effective
        ``max_k``, so a weight-1 group can send one full batch per round).
    """

    #: WDRR weights below this are clamped (a zero weight would never
    #: accumulate deficit and starve the group forever)
    MIN_WEIGHT = 1.0 / 64.0

    def __init__(self, *, max_k: int = 8, max_queue: int = 128,
                 pool_capacity: int = 8, mode: str = "auto",
                 cache: str = "auto", cache_dir: Optional[str] = None,
                 default_timeout: Optional[float] = None,
                 warm_after: Optional[int] = None,
                 latency_window: int = 4096,
                 p99_target_s: Optional[float] = None,
                 admission_slack: float = 2.0,
                 weights: Optional[dict] = None,
                 quantum_k: Optional[float] = None):
        assert max_k >= 1 and max_queue >= 1
        self.requested_max_k = int(max_k)
        self.max_k = _pow2_floor(int(max_k))
        self.max_queue = int(max_queue)
        self.default_timeout = default_timeout
        self.warm_after = warm_after
        self.p99_target_s = p99_target_s
        self.admission_slack = float(admission_slack)
        self.weights = {str(k): max(float(v), self.MIN_WEIGHT)
                        for k, v in (weights or {}).items()}
        self.quantum_k = float(quantum_k if quantum_k is not None
                               else self.max_k)
        assert self.quantum_k > 0.0
        self.pool = PlanPool(pool_capacity, mode=mode, cache=cache,
                             cache_dir=cache_dir)

        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)   # new/expired work
        self._idle = threading.Condition(self._lock)   # a request retired
        self._groups: dict = {}             # group key -> deque[_Pending]
        self._rr: deque = deque()           # WDRR ring: non-empty groups
        self._deficit: dict = {}            # group key -> K-units earned
        self._admission: dict = {}          # group key -> admission dict
        self._n_queued = 0                  # O(1) occupancy counters --
        self._n_in_flight = 0               # consistent under self._lock
        self._seq = 0
        self._closed = False
        self._stop = False
        self._form_thread: Optional[threading.Thread] = None
        self._exec_thread: Optional[threading.Thread] = None
        self._slot: Optional[_HandoffSlot] = None

        # -- observability ----------------------------------------------------
        self._lat_queue = LatencyWindow(latency_window)
        self._lat_compute = LatencyWindow(latency_window)
        self._lat_total = LatencyWindow(latency_window)
        self._calib = Calibration()
        self.batch_log: list[dict] = []     # bounded, most recent first out
        self._batch_log_cap = latency_window
        self._n_submitted = 0
        self._n_completed = 0
        self._n_failed = 0
        self._n_timed_out = 0
        self._n_batches = 0
        self._sum_batch_requests = 0
        self._sum_batch_k = 0
        self._sum_batch_k_plan = 0
        self._sig_counts: dict[PlanSig, int] = {}
        self._warm_started: set[PlanSig] = set()
        self._warm_threads: list[threading.Thread] = []
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    # -- submission -----------------------------------------------------------

    def _k_bucket(self, k: int) -> int:
        """Smallest power-of-two channel width >= k, capped at the
        (power-of-two) ``max_k`` -- the set of K shapes plans are ever
        compiled for."""
        b = 1
        while b < min(k, self.max_k):
            b *= 2
        return min(b, self.max_k)

    @property
    def pending(self) -> int:
        """Requests the engine still owes an answer for: queued plus
        in-flight (popped into a micro-batch but not yet retired)."""
        with self._lock:
            return self._n_queued + self._n_in_flight

    @staticmethod
    def _group_label(gkey) -> str:
        sig, direction, iters = gkey
        lbl = f"{sig.label()}/{direction}"
        return lbl if not iters else f"{lbl}/iters{iters}"

    def _weight(self, gkey) -> float:
        return self.weights.get(gkey[0].label(), 1.0)

    def _admission_for(self, request: ShtRequest) -> Optional[dict]:
        """Roofline admission verdict for this request's serving group
        (None when the signature cannot even resolve a geometry -- the
        plan failure will surface on its own batch instead)."""
        from repro.core import transform as tf
        from repro.roofline import admission
        sig = request.signature()
        cache_kind = self.pool.cache
        if cache_kind == "auto":
            cache_kind = "disk" if (self.pool.cache_dir
                                    or os.environ.get("REPRO_CACHE_DIR")) \
                else "memory"
        try:
            g, _ = tf._resolve_grid(sig.grid, sig.l_max, sig.nside,
                                    cache_kind, self.pool.cache_dir)
        except Exception:
            return None
        l_max = sig.l_max if sig.l_max is not None else \
            (2 * g.nside if g.nside else g.n_rings - 1)
        return admission.k_caps_for_target(
            l_max=l_max, m_max=sig.m_max, n_rings=g.n_rings,
            n_phi=g.max_n_phi, max_k=self.max_k,
            p99_target_s=self.p99_target_s,
            direction="synth" if request.direction == "alm2map" else "anal",
            iters=request.iters, spin=sig.spin,
            fft_lengths=None if g.uniform else g.n_phi,
            slack=self.admission_slack)

    def submit(self, request: Optional[ShtRequest] = None,
               **kw) -> ShtFuture:
        """Enqueue one transform request; returns its :class:`ShtFuture`.

        Pass a prebuilt :class:`ShtRequest` or its fields as keywords
        (``engine.submit(direction="alm2map", payload=alm, grid="gl",
        l_max=64)``).  Raises ValueError on malformed requests and
        :class:`BackpressureError` when queued + in-flight requests
        already fill ``max_queue``.
        """
        if request is None:
            request = ShtRequest(**kw)
        elif kw:
            raise TypeError("pass either a request object or keywords")
        payload, k, squeeze = _normalize_payload(request)
        if k > self.max_k:
            raise ValueError(
                f"request K={k} exceeds the engine's max_k={self.max_k}"
                f" (requested_max_k={self.requested_max_k}, clamped to a "
                "power of two); split the batch or build a wider engine")
        timeout = request.timeout if request.timeout is not None \
            else self.default_timeout
        gkey = (request.signature(), request.direction, request.iters)
        adm = None
        if self.p99_target_s is not None and gkey not in self._admission:
            adm = self._admission_for(request)     # geometry work: no lock
        now = time.perf_counter()
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            occupancy = self._n_queued + self._n_in_flight
            if occupancy >= self.max_queue:
                raise BackpressureError(
                    f"engine full ({occupancy}/{self.max_queue} queued + "
                    "in-flight); drain or raise max_queue")
            if adm is not None and gkey not in self._admission:
                self._admission[gkey] = adm
            fut = ShtFuture(rid=self._seq)
            p = _Pending(request=request, future=fut, seq=self._seq,
                         payload=payload, k=k, squeeze=squeeze,
                         t_submit=now,
                         deadline=None if timeout is None else now + timeout)
            self._seq += 1
            self._n_submitted += 1
            self._n_queued += 1
            if self._t_first_submit is None:
                self._t_first_submit = now
            q = self._groups.setdefault(gkey, deque())
            if not q:
                self._rr.append(gkey)              # group (re)enters WDRR
            q.append(p)
            sig = gkey[0]
            self._sig_counts[sig] = self._sig_counts.get(sig, 0) + 1
            warm = (self.warm_after is not None
                    and self._sig_counts[sig] == self.warm_after
                    and sig not in self._warm_started)
            if warm:
                self._warm_started.add(sig)
            self._work.notify_all()
        if warm:
            self._spawn_warm(sig, self.max_k)
        return fut

    def _spawn_warm(self, sig: PlanSig, k: int) -> threading.Thread:
        t = threading.Thread(target=self._warm_quietly, args=(sig, k),
                             name=f"sht-warm-{sig.label()}", daemon=True)
        with self._lock:
            self._warm_threads.append(t)
        t.start()
        return t

    def _join_warmups(self) -> None:
        """Wait out in-flight background warm-ups (a compile racing
        interpreter shutdown aborts the process)."""
        with self._lock:
            threads, self._warm_threads = self._warm_threads, []
        for t in threads:
            t.join()

    def _warm_quietly(self, sig: PlanSig, k: int) -> None:
        try:
            self.pool.warm(sig, self._k_bucket(k))
        except Exception:
            pass  # a bad signature fails loudly on its own batch instead

    def prewarm(self, *, k: Optional[int] = None, background: bool = False,
                **sig_fields):
        """Warm the pool for a signature before traffic arrives.

        ``sig_fields`` are :class:`PlanSig` fields (grid, l_max, nside,
        m_max, spin, dtype); ``k`` defaults to the engine's full ``max_k``
        width.  ``background=True`` returns the started thread instead of
        blocking."""
        sig = PlanSig(**sig_fields)
        k_plan = self._k_bucket(k if k is not None else self.max_k)
        if background:
            return self._spawn_warm(sig, k_plan)
        return self.pool.warm(sig, k_plan)

    # -- batch formation -------------------------------------------------------

    def _take_locked(self, p: _Pending) -> None:
        """queued -> in-flight (caller holds the lock)."""
        assert p.state == "queued", p.state
        p.state = "in_flight"
        self._n_queued -= 1
        self._n_in_flight += 1

    def _drop_group_locked(self, gkey) -> None:
        if gkey in self._rr:
            self._rr.remove(gkey)
        self._deficit.pop(gkey, None)

    def _evict_expired_locked(self, now: float) -> list[_Pending]:
        out = []
        for gkey, q in list(self._groups.items()):
            if not any(p.deadline is not None and p.deadline < now
                       for p in q):
                continue
            keep: deque = deque()
            for p in q:
                if p.deadline is not None and p.deadline < now:
                    self._take_locked(p)
                    out.append(p)
                else:
                    keep.append(p)
            self._groups[gkey] = keep
            if not keep:
                self._drop_group_locked(gkey)
        return out

    def _k_cap_locked(self, gkey) -> int:
        adm = self._admission.get(gkey)
        if adm is None:
            return self.max_k
        return min(self.max_k, int(adm["k_cap"]))

    def _pop_batch_locked(self):
        """WDRR batch formation: visit signature groups round-robin; each
        visit tops the group's deficit up by ``quantum_k * weight`` and
        the group spends deficit, one K-unit per map, to send requests --
        in strict FIFO order within the group, up to the admission-
        controlled K cap per batch.  A hot tenant that exhausts its
        deficit hands the rest of the round to the others; an oversized
        single request (k > cap) still ships alone once its deficit
        covers it, so admission caps coalescing, never service."""
        passes = 0
        while self._rr:
            gkey = self._rr[0]
            q = self._groups.get(gkey)
            if not q:                              # lazily prune emptied
                self._rr.popleft()
                self._deficit.pop(gkey, None)
                continue
            self._deficit[gkey] = (self._deficit.get(gkey, 0.0)
                                   + self.quantum_k * self._weight(gkey))
            cap = self._k_cap_locked(gkey)
            force = passes > 64 * len(self._rr) + 1   # safety: never wedge
            batch, k_sum = [], 0
            while q:
                nk = q[0].k
                if batch and k_sum + nk > cap:
                    break                          # bucket full
                if k_sum + nk > self._deficit[gkey] and not force:
                    break                          # deficit spent
                p = q.popleft()
                self._take_locked(p)
                batch.append(p)
                k_sum += nk
            if batch:
                self._deficit[gkey] -= k_sum
                self._rr.rotate(-1)                # next round: next group
                if not q:
                    self._drop_group_locked(gkey)
                return gkey, batch
            self._rr.rotate(-1)
            passes += 1
        return None, []

    def _form_once(self):
        """Evict expired requests and stage one micro-batch (host side:
        pop, plan lookup, validation, payload stacking + upload).
        Returns ``(staged_or_None, n_retired_during_formation)``."""
        now = time.perf_counter()
        with self._lock:
            expired = self._evict_expired_locked(now)
            gkey, batch = self._pop_batch_locked()
        n = 0
        for p in expired:
            waited = now - p.t_submit
            self._retire(p, exc=ShtTimeoutError(
                f"request {p.future.rid} evicted after {waited:.3f}s in "
                f"queue (timeout)"), kind="timeout",
                timing={"queue_s": waited, "compute_s": 0.0,
                        "total_s": waited})
            n += 1
        if not batch:
            return None, n
        staged, n_failed = self._stage(gkey, batch)
        return staged, n + n_failed

    def _stage(self, gkey, batch: list[_Pending]):
        """Host-side half of a micro-batch: resolve the pooled plan,
        validate each payload against it, stack along K and upload.
        Returns ``(staged_or_None, n_retired)``."""
        import jax.numpy as jnp

        sig, direction, iters = gkey
        t_form = time.perf_counter()
        k_claim = sum(p.k for p in batch)
        k_plan = self._k_bucket(k_claim)

        try:
            plan = self.pool.get(sig, k_plan)
        except Exception as e:
            for p in batch:
                self._retire(p, exc=e, kind="failed",
                             timing={"queue_s": t_form - p.t_submit})
            self._log_batch(sig, direction, batch, k_claim, k_plan, ok=False)
            return None, len(batch)

        # per-request shape validation against the *resolved* plan: a
        # payload that lied about its signature fails alone, not its batch
        base = (plan._alm_shape if direction == "alm2map"
                else plan._maps_shape)[:-1]
        good, k_total = [], 0
        for p in batch:
            if p.payload.shape[:-1] != base:
                self._retire(p, exc=ValueError(
                    f"payload shape {p.payload.shape} does not match plan "
                    f"{sig.label()} (expected {base} + (K,))"),
                    kind="failed",
                    timing={"queue_s": t_form - p.t_submit})
            else:
                good.append(p)
                k_total += p.k
        if not good:
            self._log_batch(sig, direction, batch, 0, k_plan, ok=False)
            return None, len(batch)

        cdtype = np.complex128 if sig.dtype == "float64" else np.complex64
        rdtype = np.dtype(sig.dtype)
        want = cdtype if direction == "alm2map" else rdtype
        parts = [np.ascontiguousarray(p.payload, dtype=want) for p in good]
        if k_total < plan.K:                       # dense K bucket: zero-pad
            parts.append(np.zeros(base + (plan.K - k_total,), dtype=want))
        dev = jnp.asarray(np.concatenate(parts, axis=-1))

        adm = self._admission.get(gkey)
        predicted = None
        if adm is not None:
            predicted = adm["predicted_s_by_k"].get(k_plan)
        staged = _Staged(gkey=gkey, plan=plan, good=good, dev=dev,
                         k_total=k_total, k_plan=k_plan,
                         form_s=time.perf_counter() - t_form,
                         predicted_s=predicted)
        return staged, len(batch) - len(good)

    # -- execution ------------------------------------------------------------

    def _retire(self, p: _Pending, *, result=None, exc=None, kind: str,
                timing: Optional[dict] = None) -> None:
        p.future.timing = dict(timing or {})
        if exc is not None:
            p.future._fail(exc)
        else:
            p.future._resolve(result)
        with self._lock:
            if p.state == "queued":
                self._n_queued -= 1
            elif p.state == "in_flight":
                self._n_in_flight -= 1
            p.state = "retired"
            if kind == "ok":
                self._n_completed += 1
            elif kind == "timeout":
                self._n_timed_out += 1
            else:
                self._n_failed += 1
            t = timing or {}
            if "queue_s" in t:
                self._lat_queue.record(t["queue_s"])
            if kind == "ok":
                self._lat_compute.record(t.get("compute_s", 0.0))
                self._lat_total.record(t.get("total_s", 0.0))
            self._t_last_done = time.perf_counter()
            self._idle.notify_all()

    def _log_batch(self, sig: PlanSig, direction: str, batch, k_total: int,
                   k_plan: int, ok: bool) -> None:
        with self._lock:
            self._n_batches += 1
            self._sum_batch_requests += len(batch)
            self._sum_batch_k += k_total
            self._sum_batch_k_plan += k_plan
            self.batch_log.append({
                "signature": sig.label(), "direction": direction,
                "rids": [p.future.rid for p in batch],
                "n_requests": len(batch), "k_total": k_total,
                "k_plan": k_plan, "ok": ok,
            })
            if len(self.batch_log) > self._batch_log_cap:
                del self.batch_log[: len(self.batch_log)
                                   - self._batch_log_cap]

    def _execute_staged(self, staged: _Staged) -> int:
        """Device half of a micro-batch: run the transform, scatter the
        K slices back to their futures.  Returns requests retired."""
        import jax

        sig, direction, iters = staged.gkey
        plan, good = staged.plan, staged.good
        t_start = time.perf_counter()
        try:
            if direction == "alm2map":
                out = plan.alm2map(staged.dev)
            else:
                out = plan.map2alm(staged.dev, iters=iters)
            jax.block_until_ready(out)
        except Exception as e:
            for p in good:
                self._retire(p, exc=e, kind="failed",
                             timing={"queue_s": t_start - p.t_submit})
            self._log_batch(sig, direction, good, staged.k_total,
                            staged.k_plan, ok=False)
            return len(good)
        t_done = time.perf_counter()
        compute_s = t_done - t_start
        if staged.predicted_s is not None:
            with self._lock:
                self._calib.record(staged.predicted_s, compute_s)

        out = np.asarray(out)
        off = 0
        for p in good:
            res = out[..., off:off + p.k]
            off += p.k
            if p.squeeze:
                res = res[..., 0]
            self._retire(p, result=res, kind="ok", timing={
                "queue_s": t_start - p.t_submit,
                "form_s": staged.form_s,
                "compute_s": compute_s,
                "total_s": t_done - p.t_submit,
                "k_plan": staged.k_plan,
                "coalesced_with": len(good) - 1,
            })
        self._log_batch(sig, direction, good, staged.k_total, staged.k_plan,
                        ok=True)
        return len(good)

    # -- synchronous serving ---------------------------------------------------

    def step(self) -> int:
        """Process one coalesced micro-batch inline (plus any timeout
        evictions).  Synchronous mode only -- with the background threads
        running, submit and ``drain()`` instead.

        Returns the number of requests retired (resolved, failed or
        evicted); 0 means the queue was empty.
        """
        staged, n = self._form_once()
        if staged is not None:
            n += self._execute_staged(staged)
        return n

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every pending request -- queued *and* in-flight --
        is retired.

        Synchronous mode pumps ``step()`` inline; with the background
        threads running it waits on the retirement condition variable (no
        polling).  Raises TimeoutError if requests are still pending
        after ``timeout`` seconds.
        """
        deadline = None if timeout is None else time.perf_counter() + timeout
        background = self._form_thread is not None
        while True:
            with self._lock:
                left = self._n_queued + self._n_in_flight
                if left == 0:
                    break
                if deadline is not None and time.perf_counter() > deadline:
                    raise TimeoutError(f"drain: {left} request(s) "
                                       f"still pending after {timeout}s")
                if background:
                    wait = 0.1 if deadline is None else \
                        max(0.0, min(0.1, deadline - time.perf_counter()))
                    self._idle.wait(wait)
                    continue
            self.step()
        self._join_warmups()

    # -- background serving: double-buffered formation -> execution -----------

    def start(self) -> "ShtEngine":
        """Start the double-buffered serving threads (idempotent): a
        formation thread stages batch i+1 while the execute thread runs
        batch i on the device."""
        with self._lock:
            if self._form_thread is not None:
                return self
            self._stop = False
            self._slot = _HandoffSlot()
            self._form_thread = threading.Thread(
                target=self._formation_loop, name="sht-serve-form",
                daemon=True)
            self._exec_thread = threading.Thread(
                target=self._execute_loop, name="sht-serve-exec",
                daemon=True)
        self._form_thread.start()
        self._exec_thread.start()
        return self

    def _formation_loop(self) -> None:
        while True:
            with self._work:
                while not self._stop and self._n_queued == 0:
                    self._work.wait(timeout=0.1)
                if self._stop:
                    return
            staged, _ = self._form_once()
            if staged is not None and not self._slot.put(staged):
                # slot closed mid-handoff (stop raced us): never strand
                # an in-flight batch -- run it here instead
                self._execute_staged(staged)

    def _execute_loop(self) -> None:
        while True:
            staged = self._slot.take()
            if staged is None:                     # closed and flushed
                return
            self._execute_staged(staged)

    def stop(self, drain: bool = True) -> None:
        """Stop the background threads; ``drain=True`` (default) retires
        the remaining queue synchronously first.  The in-flight staged
        batch (if any) always executes -- stopping never strands a popped
        request."""
        ft, et = self._form_thread, self._exec_thread
        if ft is not None:
            with self._work:
                self._stop = True
                self._work.notify_all()
            ft.join()
            self._slot.close()                     # executor flushes + exits
            et.join()
            self._form_thread = self._exec_thread = None
            self._slot = None
        if drain:
            while self.pending:
                self.step()
        self._join_warmups()

    def close(self) -> None:
        """Stop serving and refuse further submissions; queued requests
        fail with RuntimeError (in-flight batches still complete)."""
        self.stop(drain=False)
        with self._lock:
            self._closed = True
            leftovers = [p for q in self._groups.values() for p in q]
            self._groups.clear()
            self._rr.clear()
            self._deficit.clear()
        for p in leftovers:
            self._retire(p, exc=RuntimeError("engine closed"), kind="failed",
                         timing={})

    def __enter__(self) -> "ShtEngine":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop(drain=True)

    # -- observability ---------------------------------------------------------

    def describe(self) -> dict:
        """Structured engine configuration: coalescing caps, admission
        policy, fairness policy, pool settings, pipeline state.  The
        static complement of :meth:`stats`."""
        with self._lock:
            admission = {
                "p99_target_s": self.p99_target_s,
                "slack": self.admission_slack,
                "groups": {self._group_label(g): {
                    "k_cap": a["k_cap"], "feasible": a["feasible"],
                    "predicted_s": a["predicted_s"], "backend": a["backend"],
                } for g, a in self._admission.items()},
            }
            return {
                "max_k": self.max_k,
                "requested_max_k": self.requested_max_k,
                "max_queue": self.max_queue,
                "default_timeout": self.default_timeout,
                "warm_after": self.warm_after,
                "states": ("queued", "in_flight", "retired"),
                "admission": admission,
                "fairness": {"policy": "wdrr",
                             "quantum_k": self.quantum_k,
                             "weights": dict(self.weights)},
                "pipeline": {
                    "double_buffered": self._form_thread is not None,
                    "threads": [t.name for t in (self._form_thread,
                                                 self._exec_thread) if t],
                },
                "pool": {"capacity": self.pool.capacity,
                         "mode": self.pool.mode, "cache": self.pool.cache,
                         "cache_dir": self.pool.cache_dir},
            }

    def stats(self) -> dict:
        """Structured serving metrics: request counters (queued /
        in-flight / retired states), latency percentiles (seconds),
        coalescing factors, admission caps + model calibration, WDRR
        deficits, plan-pool counters and sustained throughput."""
        with self._lock:
            nb = self._n_batches
            elapsed = None
            if self._t_first_submit is not None \
                    and self._t_last_done is not None:
                elapsed = self._t_last_done - self._t_first_submit
            return {
                "requests": {
                    "submitted": self._n_submitted,
                    "completed": self._n_completed,
                    "failed": self._n_failed,
                    "timed_out": self._n_timed_out,
                    "queued": self._n_queued,
                    "in_flight": self._n_in_flight,
                    "pending": self._n_queued + self._n_in_flight,
                },
                "latency": {
                    "queue": self._lat_queue.summary(),
                    "compute": self._lat_compute.summary(),
                    "total": self._lat_total.summary(),
                },
                "coalescing": {
                    "batches": nb,
                    "requests_per_batch":
                        (self._sum_batch_requests / nb) if nb
                        else float("nan"),
                    "k_per_batch":
                        (self._sum_batch_k / nb) if nb else float("nan"),
                    "k_occupancy":
                        (self._sum_batch_k / self._sum_batch_k_plan)
                        if self._sum_batch_k_plan else float("nan"),
                },
                "admission": {
                    "p99_target_s": self.p99_target_s,
                    "slack": self.admission_slack,
                    "groups": {self._group_label(g): {
                        "k_cap": a["k_cap"], "feasible": a["feasible"],
                        "predicted_s": a["predicted_s"],
                    } for g, a in self._admission.items()},
                    "calibration": self._calib.summary(),
                },
                "fairness": {
                    "policy": "wdrr",
                    "quantum_k": self.quantum_k,
                    "weights": dict(self.weights),
                    "deficits": {self._group_label(g): d
                                 for g, d in self._deficit.items()},
                },
                "pool": self.pool.stats(),
                "signatures": {s.label(): c
                               for s, c in self._sig_counts.items()},
                "throughput_rps":
                    (self._n_completed / elapsed)
                    if elapsed and elapsed > 0 else float("nan"),
            }

    def report(self) -> str:
        """Human-readable ``stats()`` (the serving analogue of
        ``Plan.report()``)."""
        s = self.stats()
        r, lat, co, pool = (s["requests"], s["latency"], s["coalescing"],
                            s["pool"])

        def ms(x):
            return f"{x * 1e3:.2f}ms" if np.isfinite(x) else "n/a"

        lines = [
            f"ShtEngine max_k={self.max_k} queue={r['pending']}/"
            f"{self.max_queue} pool={pool['size']}/{pool['capacity']} "
            f"(hit_rate {pool['hit_rate']:.2f})"
            if np.isfinite(pool["hit_rate"]) else
            f"ShtEngine max_k={self.max_k} queue={r['pending']}/"
            f"{self.max_queue} pool={pool['size']}/{pool['capacity']}",
            f"  requests: {r['completed']} done / {r['failed']} failed / "
            f"{r['timed_out']} timed out "
            f"(throughput {s['throughput_rps']:.1f} req/s)"
            if np.isfinite(s["throughput_rps"]) else
            f"  requests: {r['completed']} done / {r['failed']} failed / "
            f"{r['timed_out']} timed out",
            f"  latency total p50={ms(lat['total']['p50_s'])} "
            f"p95={ms(lat['total']['p95_s'])} "
            f"p99={ms(lat['total']['p99_s'])} "
            f"(queue p50={ms(lat['queue']['p50_s'])}, "
            f"compute p50={ms(lat['compute']['p50_s'])})",
        ]
        if s["coalescing"]["batches"]:
            lines.append(
                f"  coalescing: x{co['requests_per_batch']:.2f} req/batch, "
                f"K {co['k_per_batch']:.2f} "
                f"(occupancy {co['k_occupancy']:.2f}) over "
                f"{co['batches']} batches")
        adm = s["admission"]
        if adm["p99_target_s"] is not None:
            cal = adm["calibration"]
            caps = ", ".join(f"{lbl}: K<={a['k_cap']}"
                             + ("" if a["feasible"] else " (infeasible)")
                             for lbl, a in sorted(adm["groups"].items()))
            lines.append(
                f"  admission: p99 target {ms(adm['p99_target_s'])} "
                f"(slack x{adm['slack']:.1f}) -> {caps or 'no groups yet'}")
            if cal["count"]:
                lines.append(
                    f"  roofline calibration: measured/predicted = "
                    f"{cal['ratio']:.2f} over {cal['count']} batches")
        for label, count in sorted(s["signatures"].items()):
            lines.append(f"    {label}: {count} request(s)")
        return "\n".join(lines)
