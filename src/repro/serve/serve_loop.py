"""Batched serving loop: continuous prefill+decode over a request queue.

Single-program batched serving (static batch slotting): requests occupy
batch slots; each engine step decodes one token for every active slot.
Finished slots (EOS or max_len) are refilled from the queue with a prefill.
This is the standard static-batching TPU serving shape; the decode step is
the unit the decode_32k / long_500k dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Greedy decoding engine over a fixed batch of slots."""

    def __init__(self, bundle, batch: int, max_len: int, eos_id: int = 1):
        self.bundle = bundle
        self.batch = batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = bundle.init_caches(batch, max_len)
        self._decode = jax.jit(bundle.decode_fn)
        self._queue: List[Request] = []
        self._slots: List[Optional[Request]] = [None] * batch
        self.pos = 0

    def submit(self, req: Request):
        self._queue.append(req)

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill a single request by replaying its prompt through decode
        steps (slot-local prefill keeps the static-batch engine simple; the
        bulk prefill path is exercised by prefill_32k)."""
        for t in req.prompt[:-1]:
            tok = jnp.zeros((self.batch, 1), jnp.int32).at[slot, 0].set(int(t))
            _, self.caches = self._decode(self.bundle_params, tok,
                                          jnp.int32(self.pos), self.caches)
            self.pos += 1
        req._last = int(req.prompt[-1])

    def run(self, params, max_steps: int = 64):
        """Serve until queue drained or max_steps decode steps."""
        self.bundle_params = params
        # fill slots
        for i in range(self.batch):
            if self._queue and self._slots[i] is None:
                self._slots[i] = self._queue.pop(0)
                self._prefill_slot(i, self._slots[i])
        for _ in range(max_steps):
            live = [r for r in self._slots if r is not None and not r.done]
            if not live:
                break
            tok = np.zeros((self.batch, 1), np.int32)
            for i, r in enumerate(self._slots):
                if r is not None and not r.done:
                    tok[i, 0] = getattr(r, "_last", 0)
            logits, self.caches = self._decode(
                self.bundle_params, jnp.asarray(tok), jnp.int32(self.pos),
                self.caches)
            self.pos += 1
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for i, r in enumerate(self._slots):
                if r is None or r.done:
                    continue
                t = int(nxt[i])
                r.out_tokens.append(t)
                r._last = t
                if t == self.eos_id or len(r.out_tokens) >= r.max_new \
                        or self.pos >= self.max_len - 1:
                    r.done = True
                    if self._queue:  # refill the slot
                        self._slots[i] = self._queue.pop(0)
                        self._prefill_slot(i, self._slots[i])
                    else:
                        self._slots[i] = r  # keep for collection
        return [r for r in self._slots if r is not None]
