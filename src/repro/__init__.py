"""repro: Parallel Spherical Harmonic Transforms as a multi-pod JAX framework.

Implements Szydlarski et al. (INRIA RR-7635) -- the two-stage distributed SHT
with intra-node acceleration -- adapted to TPU (shard_map + Pallas), together
with the assigned 10-architecture LM model zoo, training/serving substrate,
multi-pod dry-run and roofline tooling.  See DESIGN.md.

float64 is enabled globally: the SHT reference engine is double precision
(matching the paper); all model/kernel code passes explicit dtypes and is
unaffected by the default-dtype change.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.1.0"


def __getattr__(name):
    """Lazy top-level API: ``repro.make_plan`` / ``repro.Plan``.

    Imported on first use so ``import repro`` stays light (the transform
    layer pulls in the SHT engine; the Pallas kernels are only imported if
    a plan actually selects them).
    """
    if name in ("make_plan", "Plan", "available_backends",
                "backend_eligibility", "clear_plan_cache"):
        from repro.core import transform
        return getattr(transform, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
