"""Render the roofline/dry-run tables for EXPERIMENTS.md from
results/dryrun/*.json.

    PYTHONPATH=src python scripts/make_report.py > results/roofline_tables.md
"""

import glob
import json
import os
import sys


def attention_flops(arch, shape_name):
    """Attention-score flops excluded from the 6*N*D convention (for the
    `useful+attn` column).  Causal halves the S^2 term; windowed/local
    attention bounds it; ssm archs have none."""
    from repro.configs import registry
    from repro.configs.base import SHAPES
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return 0.0
    H = cfg.n_heads
    if cfg.attention == "mla":
        qk_d = cfg.qk_nope_dim + cfg.qk_rope_dim
        v_d = cfg.v_head_dim
    else:
        qk_d = v_d = cfg.hd
    if cfg.block_pattern:  # hybrid: only the 'local' layers attend
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "local")
        win = cfg.local_window
    else:
        n_attn = cfg.n_layers + cfg.n_encoder_layers
        win = cfg.sliding_window
    if shape.kind == "decode":
        ctx = min(S, win) if win else S
        per_tok = 2.0 * H * (qk_d + v_d) * ctx
        return B * n_attn * per_tok
    eff = min(S, win) if win else S
    per_layer = 2.0 * B * H * (qk_d + v_d) * S * eff / 2.0   # causal half
    mult = 3.0 if shape.kind == "train" else 1.0             # +backward
    return n_attn * per_layer * mult


def moe_ragged_inflation(arch, shape_name, n_dev):
    """Per-device phantom flops from XLA's ragged_dot cost accounting.

    HloCostAnalysis charges ragged_dot as a DENSE dot over all groups
    (verified: 128x64 @ (8,64,32) groups is counted as ~8x the true work),
    so MoE expert matmuls are inflated by E_local.  A real TPU grouped
    matmul does group_sizes-proportional work; we subtract the analytic
    phantom so t_comp reflects deployable compute.  Raw numbers stay in
    the JSONs (`roofline` field).
    """
    from repro.configs import registry
    from repro.configs.base import SHAPES
    cfg = registry.get(arch)
    if not cfg.n_experts:
        return 0.0
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    s_model = 16
    n_rows_mesh = n_dev // s_model
    e_loc = cfg.n_experts // s_model
    d, ff = cfg.d_model, cfg.moe_d_ff
    n_moe = cfg.n_layers - cfg.first_dense_layers
    if shape.kind == "decode":
        t_loc = max(B // n_rows_mesh, 1) * 1
        cap = int(-(-t_loc * cfg.top_k * cfg.capacity_factor // s_model))
        rows = cap                       # replicated path
        mult = 1.0
    else:
        t_loc = (B // n_rows_mesh) * S // s_model
        cap = int(-(-t_loc * cfg.top_k * cfg.capacity_factor // s_model))
        rows = s_model * cap             # a2a recv buffer
        mult = 3.0 if shape.kind == "train" else 1.0
    true_ffn = rows * 3 * 2 * d * ff
    return true_ffn * (e_loc - 1) * mult * n_moe


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("kB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def load(out_dir):
    recs = {}
    for f in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(f))
        recs[r["tag"]] = r
    return recs


ARCH_ORDER = ["kimi-k2-1t-a32b", "deepseek-v3-671b", "internvl2-1b",
              "qwen1.5-32b", "qwen3-8b", "h2o-danube-3-4b", "qwen2-0.5b",
              "xlstm-125m", "recurrentgemma-9b", "whisper-large-v3",
              "sht_cmb"]
LM_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
SHT_SHAPES = ["synth_2k_k8", "synth_4k_k1", "anal_4k_k4", "synth_8k_k4"]


def table(recs, mesh):
    lines = [
        "| arch | shape | status | t_comp | t_mem | t_coll | bottleneck | "
        "useful/HLO | +attn | roofline frac | HBM/dev (args+tmp) | compile |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        shapes = SHT_SHAPES if arch == "sht_cmb" else LM_SHAPES
        for shape in shapes:
            tag = f"{arch}__{shape}__{mesh}"
            r = recs.get(tag)
            if r is None:
                lines.append(f"| {arch} | {shape} | (pending) "
                             "| | | | | | | | | |")
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | SKIP: "
                             f"{r['reason'][:60]}... | | | | | | | | | |")
                continue
            if r["status"] == "error":
                lines.append(f"| {arch} | {shape} | ERROR: "
                             f"{r['error'][:60]} | | | | | | | | | |")
                continue
            ro = r["roofline"]
            mem = r.get("memory_analysis", {})
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
            flops_dev = ro["flops_per_device"]
            if arch != "sht_cmb":
                flops_dev = max(
                    flops_dev - moe_ragged_inflation(arch, shape,
                                                     ro["n_devices"]), 0.0)
            t_comp = flops_dev / 197e12
            tot_hlo = flops_dev * ro["n_devices"]
            t_max = max(t_comp, ro["t_memory_s"], ro["t_collective_s"])
            bot = {t_comp: "compute", ro["t_memory_s"]: "memory",
                   ro["t_collective_s"]: "collective"}[t_max]
            t_useful = ro["model_flops"] / max(ro["n_devices"], 1) / 197e12
            frac = t_useful / t_max if t_max else 0.0
            if arch != "sht_cmb" and tot_hlo > 0:
                ua = (ro["model_flops"]
                      + attention_flops(arch, shape)) / tot_hlo
                ua_s = f"{min(ua, 9.999):.3f}"
                u_s = f"{ro['model_flops'] / tot_hlo:.3f}"
            else:
                ua_s = "-"
                u_s = f"{ro['useful_flops_fraction']:.3f}"
                frac = ro["roofline_fraction"]
            lines.append(
                f"| {arch} | {shape} | ok "
                f"| {fmt_t(t_comp)} | {fmt_t(ro['t_memory_s'])} "
                f"| {fmt_t(ro['t_collective_s'])} | {bot} "
                f"| {u_s} | {ua_s} "
                f"| {frac:.3f} "
                f"| {fmt_b(hbm)} | {r.get('compile_s', 0):.0f}s |")
    return "\n".join(lines)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = load(out_dir)
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skip")
    n_err = sum(1 for r in recs.values() if r["status"] == "error")
    print(f"<!-- generated by scripts/make_report.py: {n_ok} ok, "
          f"{n_skip} skip, {n_err} error -->\n")
    for mesh in ("single", "multi"):
        print(f"### Mesh: {mesh} "
              f"({'2x16x16 = 512 chips' if mesh == 'multi' else '16x16 = 256 chips'})\n")
        print(table(recs, mesh))
        print()
    # hillclimb variants, if present
    extras = {t: r for t, r in recs.items() if t.count("__") > 2}
    if extras:
        print("### Optimisation-variant cells (hillclimb)\n")
        print("| tag | t_comp | t_mem | t_coll | bottleneck | roofline frac |")
        print("|---|---|---|---|---|---|")
        for t in sorted(extras):
            r = extras[t]
            if r["status"] != "ok":
                print(f"| {t} | {r['status']} | | | | |")
                continue
            ro = r["roofline"]
            print(f"| {t} | {fmt_t(ro['t_compute_s'])} "
                  f"| {fmt_t(ro['t_memory_s'])} "
                  f"| {fmt_t(ro['t_collective_s'])} | {ro['bottleneck']} "
                  f"| {ro['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
