#!/usr/bin/env bash
# CI / local gate: install deps (when the network allows), run tier-1, then
# a CPU smoke benchmark of the plan-dispatch layer.  Exists so a missing
# test dependency (the hypothesis-at-collection breakage) or a broken
# dispatch path can't land silently.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== deps =="
if python -m pip install -q -e ".[test]" 2>/dev/null; then
    echo "installed repro-sht[test]"
else
    echo "pip unavailable/offline: using baked-in deps (tests degrade gracefully)"
fi

echo "== tier-1 =="
PYTHONPATH=src python -m pytest -x -q

echo "== smoke benchmark (plan dispatch, CPU) =="
PYTHONPATH=src REPRO_BENCH_SMOKE=1 python -m benchmarks.bench_dispatch

echo "== ragged-grid smoke (true-HEALPix plan roundtrip) =="
PYTHONPATH=src python - <<'PY'
import numpy as np
import repro
from repro.core import sht, spectra
plan = repro.make_plan("healpix", nside=8, dtype="float64", mode="auto")
alm = sht.random_alm(None, plan.l_max, plan.m_max)
err = float(spectra.d_err(alm, plan.map2alm(plan.alm2map(alm), iters=1)))
assert err < 0.05, f"healpix roundtrip regressed: d_err={err}"
assert plan.describe()["phase"]["kind"] == "bucket"
print(f"healpix nside=8 roundtrip d_err={err:.2e} backends={plan.backends}")
PY

echo "== full benchmark set (one-rep smoke) =="
PYTHONPATH=src REPRO_BENCH_SMOKE=1 python -m benchmarks.run

echo "check.sh: OK"
