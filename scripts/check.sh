#!/usr/bin/env bash
# CI / local gate: install deps (when the network allows), run tier-1, then
# a CPU smoke benchmark of the plan-dispatch layer.  Exists so a missing
# test dependency (the hypothesis-at-collection breakage) or a broken
# dispatch path can't land silently.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== deps =="
if python -m pip install -q -e ".[test]" 2>/dev/null; then
    echo "installed repro-sht[test]"
else
    echo "pip unavailable/offline: using baked-in deps (tests degrade gracefully)"
fi

echo "== tier-1 =="
PYTHONPATH=src python -m pytest -x -q

echo "== smoke benchmark (plan dispatch, CPU) =="
PYTHONPATH=src REPRO_BENCH_SMOKE=1 python -m benchmarks.bench_dispatch

echo "check.sh: OK"
