#!/usr/bin/env bash
# CI / local gate: install deps (when the network allows), run tier-1, then
# a CPU smoke benchmark of the plan-dispatch layer.  Exists so a missing
# test dependency (the hypothesis-at-collection breakage) or a broken
# dispatch path can't land silently.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== deps =="
if python -m pip install -q -e ".[test]" 2>/dev/null; then
    echo "installed repro-sht[test]"
else
    echo "pip unavailable/offline: using baked-in deps (tests degrade gracefully)"
fi

echo "== tier-1 =="
PYTHONPATH=src python -m pytest -x -q

echo "== smoke benchmark (plan dispatch, CPU) =="
PYTHONPATH=src REPRO_BENCH_SMOKE=1 python -m benchmarks.bench_dispatch

echo "== ragged-grid smoke (true-HEALPix plan roundtrip) =="
PYTHONPATH=src python - <<'PY'
import numpy as np
import repro
from repro.core import sht, spectra
plan = repro.make_plan("healpix", nside=8, dtype="float64", mode="auto")
alm = sht.random_alm(seed=0, l_max=plan.l_max, m_max=plan.m_max)
err = float(spectra.d_err(alm, plan.map2alm(plan.alm2map(alm), iters=1)))
assert err < 0.05, f"healpix roundtrip regressed: d_err={err}"
assert plan.describe()["phase"]["kind"] == "bucket"
print(f"healpix nside=8 roundtrip d_err={err:.2e} backends={plan.backends}")
PY

echo "== spin-2 smoke (Q/U roundtrips through make_plan(..., spin=2)) =="
PYTHONPATH=src python - <<'PY'
import numpy as np
import repro
from repro.core import sht, spectra
# exact grid: machine precision; pure-E must synthesise with zero B leakage
plan = repro.make_plan("gl", l_max=32, dtype="float64", mode="auto", spin=2)
alm = sht.random_alm_spin(seed=0, l_max=32, m_max=32)
err = float(spectra.d_err(alm, plan.map2alm(plan.alm2map(alm))))
assert err < 1e-12, f"gl spin-2 roundtrip regressed: d_err={err}"
alm_e = alm.at[1].set(0.0)
back = plan.map2alm(plan.alm2map(alm_e))
leak = float(np.max(np.abs(np.asarray(back[1]))))
assert leak < 1e-12, f"E->B leakage: {leak}"
print(f"gl spin-2 roundtrip d_err={err:.2e}  E->B leakage={leak:.2e}")
# ragged HEALPix spin-2 (quadrature accuracy + Jacobi refinement)
plan = repro.make_plan("healpix", nside=8, dtype="float64", mode="auto",
                       spin=2)
alm = sht.random_alm_spin(seed=1, l_max=plan.l_max, m_max=plan.m_max)
err = float(spectra.d_err(alm, plan.map2alm(plan.alm2map(alm), iters=1)))
assert err < 0.05, f"healpix spin-2 roundtrip regressed: d_err={err}"
print(f"healpix nside=8 spin-2 roundtrip d_err={err:.2e} "
      f"backends={plan.backends}")
# fused spin-2 engine (float32 pallas path): the lambda^{+/-} pair must
# be fusion-eligible and bit-match the staged chain
import jax.numpy as jnp
plan = repro.make_plan("gl", l_max=24, dtype="float32", mode="pallas_vpu",
                       spin=2)
d = plan.describe()["fusion"]
assert d["eligible"] is True, d
alm32 = sht.random_alm_spin(seed=2, l_max=24, m_max=24).astype(jnp.complex64)
f = plan._synth_fn("pallas_vpu", "fused")(alm32)
s = plan._synth_fn("pallas_vpu", "packed")(alm32)
rel = float(jnp.max(jnp.abs(f - s)) / jnp.max(jnp.abs(s)))
assert rel < 1e-5, f"fused spin-2 diverged from staged: {rel}"
print(f"fused spin-2 smoke OK (rel={rel:.2e})")
PY

echo "== differentiable-transform smoke (grad example, one optimizer step) =="
PYTHONPATH=src python examples/grad_cl_estimate.py --lmax 8 --steps 1 --mode jnp
PYTHONPATH=src python - <<'PY'
# jax.grad through the Pallas path + the adjoint identity, one tiny case
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.core import sht
plan = repro.make_plan("gl", l_max=8, dtype="float32", mode="pallas_vpu")
assert plan.grad_ready == {"synth": True, "anal": True}
alm = sht.random_alm(seed=0, l_max=8, m_max=8).astype(jnp.complex64)
t = jnp.asarray(np.random.default_rng(0).normal(size=plan._maps_shape),
                jnp.float32)
loss = lambda a: jnp.sum(plan.alm2map(a) * t)
g = jax.grad(loss)(alm)
v = sht.random_alm(seed=1, l_max=8, m_max=8).astype(jnp.complex64)
eps = 1e-2
fd = float((loss(alm + eps*v) - loss(alm - eps*v)) / (2*eps))
dd = float(jnp.real(jnp.sum(g * v)))
rel = abs(fd - dd) / max(abs(fd), 1e-9)
assert rel < 1e-2, f"pallas gradcheck regressed: rel={rel}"
print(f"pallas_vpu gradcheck OK (rel={rel:.2e})")
PY

echo "== serving smoke (K-coalesced engine, mixed-signature traffic) =="
# the example asserts every coalesced result matches an independent Plan
# call to <1e-12, so a serving-layer regression fails here loudly; the
# second run turns on roofline admission control (p99-target-capped K)
PYTHONPATH=src python examples/serve_sht.py --smoke
PYTHONPATH=src python examples/serve_sht.py --smoke --p99-target-ms 50

echo "== chardb smoke (characterize once, second build re-measures zero) =="
PYTHONPATH=src python - <<'PY'
# the persistent autotune characterization DB: a cold auto plan measures
# its corners exactly once; after every plan/decision cache is cleared a
# rebuild must reuse them all (one-rep, tiny size)
import repro
from repro.core import cache as plancache, transform
from repro.roofline import chardb
chardb.clear()
repro.make_plan("gl", l_max=8, K=1, dtype="float32", mode="auto",
                cache="memory")
first = chardb.stats()
assert first["measured"] > 0, first
transform.clear_plan_cache()
plancache.clear_memory()
chardb.reset_stats()
repro.make_plan("gl", l_max=8, K=1, dtype="float32", mode="auto",
                cache="memory")
again = chardb.stats()
assert again["measured"] == 0, f"chardb re-measured corners: {again}"
assert again["reused"] >= first["measured"], (first, again)
print(f"chardb OK: {first['measured']} corners characterized once, "
      f"{again['reused']} reused on rebuild")
PY

echo "== spin benchmark (one-rep smoke) =="
# standalone (also part of benchmarks.run below) so a spin-bench
# regression fails the gate loudly -- run.py swallows per-module errors
PYTHONPATH=src REPRO_BENCH_SMOKE=1 python -m benchmarks.bench_spin

echo "== full benchmark set (one-rep smoke) + JSON trajectory validation =="
BENCH_OUT="$(mktemp -t bench_check_XXXX.json)"
PYTHONPATH=src python -m benchmarks.run --smoke -o "$BENCH_OUT"
# the perf trajectory (BENCH_<date>.json) is only trustworthy if run.py
# keeps emitting valid numeric rows -- fail loudly if it stops
PYTHONPATH=src BENCH_OUT="$BENCH_OUT" python - <<'PY'
import json, math, os
path = os.environ["BENCH_OUT"]
d = json.load(open(path))
rows = d.get("us_per_call", {})
assert len(rows) >= 10, f"too few benchmark rows ({len(rows)}) in {path}"
bad = {k: v for k, v in rows.items()
       if not isinstance(v, (int, float)) or not math.isfinite(v)}
assert not bad, f"non-numeric benchmark rows: {bad}"
assert not d.get("errors"), f"benchmark modules errored: {d['errors']}"
# launched-grid-step ratio: every dense grid step pays launch latency,
# pl.when-masked or not (the worked-panel ratio rides in the derived col)
ratio = rows.get("recurrence/panels_ratio/lmax512")
assert ratio is not None, "packed-panel accounting row missing"
assert ratio >= 1.5, f"packed grid no longer >=1.5x smaller: {ratio}"
# fused Legendre+phase pipeline: the speedup rows must keep landing.
# The uniform pallas-mxu synth row is the PR-9 acceptance gate -- the
# fused MXU engine must beat the staged chain (the pre-fix kernel
# regressed to ~0.8x); every pallas-vpu synth row must also win.  The
# spin-2/bucket MXU corners (full runs only) are allowed below parity:
# staged MXU still wins there and the autotuner keeps dispatching it.
fused = {k: v for k, v in rows.items()
         if k.startswith("recurrence/fused_speedup/")}
assert fused, "fused_speedup rows missing"
mxu = [v for k, v in fused.items() if "/synth/pallas-mxu/gl/" in k]
assert mxu, "fused_speedup/synth/pallas-mxu (uniform) row missing"
assert min(mxu) >= 1.0, f"fused MXU synth regressed: {fused}"
fs = [v for k, v in fused.items() if "/synth/pallas-vpu/" in k]
assert fs and min(fs) >= 1.0, f"fused VPU synth speedup regressed: {fused}"
# packed analysis must beat the plain grid (committed runs show ~2.7x
# once the bench stopped tracing m_vals -- a traced m_vals makes
# pick_layout silently fall back to plain, which was the root cause of
# the historical ~0.7-1.0x rows)
pa = [v for k, v in rows.items()
      if k.startswith("recurrence/packed_speedup/anal/")]
assert pa and min(pa) >= 1.0, f"packed anal speedup regressed: {pa}"
# bf16 MXU contraction: error band vs the same kernel's f32 run
b16 = {k: v for k, v in rows.items()
       if k.startswith("recurrence/bf16_err/")}
assert b16, "bf16_err rows missing"
assert all(0.0 < v < 1e-2 for v in b16.values()), \
    f"bf16 error band broken: {b16}"
# chunked-exchange overlap (PR 8): the measured dist speedup rows must
# land and never lose to the monolithic baseline (C=1 is always in the
# candidate set, so < 1.0 means the bench or the pipeline broke)
ov = {k: v for k, v in rows.items() if k.startswith("dist/overlap_speedup/")}
assert "dist/overlap_speedup/synth" in ov, "dist overlap speedup row missing"
assert all(isinstance(v, (int, float)) and math.isfinite(v)
           for v in ov.values()), f"non-numeric overlap rows: {ov}"
assert ov["dist/overlap_speedup/synth"] >= 1.0, \
    f"chunked exchange lost to monolithic: {ov}"
# modelled overlap rows: present, numeric, and the comm-bound TPU corner
# must hide more than half of the hideable time
model_ov = {k: v for k, v in rows.items()
            if k.startswith("scaling-model/overlap/")}
assert model_ov, "scaling-model overlap rows missing"
assert all(isinstance(v, (int, float)) and math.isfinite(v)
           for v in model_ov.values()), f"non-numeric model rows: {model_ov}"
hidden = rows.get("scaling-model/overlap/hidden/tpu-v5e/nside4096/p1024")
assert hidden is not None, "tpu-v5e nside4096/p1024 hidden-frac row missing"
assert hidden > 0.5, f"modelled hidden-comm fraction regressed: {hidden}"
# serving trajectory: throughput + tail-latency rows must keep landing
for prefix in ("serve/throughput/", "serve/p99/"):
    hits = [k for k in rows if k.startswith(prefix)]
    assert hits, f"serving benchmark row missing (prefix {prefix})"
serve_err = next(v for k, v in d.get("derived", {}).items()
                 if k.startswith("serve/derr/"))
assert float(serve_err) < 1e-12, \
    f"serving coalescing diverged from independent plans: {serve_err}"
# serving frontier (PR 10): single-threaded vs double-buffered walls over
# the 10:1 hot:minority mix.  Staging overlaps compute only where the
# host has cores the compute doesn't own, so the smoke gate is a
# no-regression bound (a single-core CI box caps the honest ceiling at
# ~1.0x and smoke-size batches are dispatch-bound, GIL-held; the cpu
# count rides in the row's derived string; full runs measure ~1.0x on
# 1 cpu).  The fairness ratio bounds how much the 10:1 hot tenant may
# inflate the minority tenant's worst-case latency: WDRR costs the
# minority at most ~one hot batch per own batch (~2-3x solo at smoke
# sizes where the batches cost the same); the old oldest-head-wins
# policy put the whole hot backlog in front of it (~7x here), which is
# what the bound rejects.
for prefix in ("serve/frontier/single/", "serve/frontier/double/",
               "serve/frontier/p99/"):
    assert any(k.startswith(prefix) for k in rows), \
        f"serving frontier row missing (prefix {prefix})"
sp = rows.get("serve/frontier/speedup")
assert sp is not None and math.isfinite(sp), "frontier speedup row missing"
assert sp >= 0.7, \
    f"double-buffered serving regressed vs single-threaded pump: {sp}"
fair = rows.get("serve/frontier/fair_p99_ratio")
assert fair is not None and math.isfinite(fair), \
    "frontier fairness row missing"
assert 0.0 < fair < 4.0, \
    f"minority tenant starved under the 10:1 hot mix: {fair}"
for key in ("git_rev", "jax_version", "generated_utc"):
    assert d.get(key), f"missing {key} in {path}"
print(f"bench JSON OK: {len(rows)} rows, panels_ratio(lmax512)="
      f"{ratio:.2f}, fused_synth_min={min(fs):.2f}, "
      f"packed_anal_min={min(pa):.2f}, "
      f"overlap_speedup={ov['dist/overlap_speedup/synth']:.2f}, "
      f"hidden_frac(tpu-v5e,4096/1024)={hidden:.2f}, "
      f"serve_frontier={sp:.2f}x fair={fair:.2f}")
PY
rm -f "$BENCH_OUT"

echo "check.sh: OK"
