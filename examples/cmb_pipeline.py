"""End-to-end CMB-style pipeline (the paper's target application):

  C_l power spectrum -> Gaussian a_lm realisations (a Monte-Carlo batch)
  -> alm2map synthesis -> add white noise -> map2alm analysis ->
  pseudo-C_l estimation and comparison against the input spectrum.

Runs distributed when multiple devices are available (set
XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
shard_map two-stage transforms on CPU), serial otherwise.

    PYTHONPATH=src python examples/cmb_pipeline.py --lmax 96 --K 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import spectra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=96)
    ap.add_argument("--K", type=int, default=8, help="Monte-Carlo batch")
    ap.add_argument("--noise", type=float, default=1e-3)
    a = ap.parse_args()

    key = jax.random.PRNGKey(1)
    cl = spectra.cmb_like_cl(a.lmax)
    alm = spectra.alm_from_cl(key, cl, K=a.K)

    # The plan dispatches to the distributed two-stage transform when
    # multiple devices are visible and it wins the autotune; packing and
    # unpacking the distribution layout is internal.
    plan = repro.make_plan("gl", l_max=a.lmax, K=a.K, mode="auto")
    print(f"transforms on {plan.grid.name} ({plan.grid.n_rings} rings), "
          f"backends={plan.backends}")
    maps = plan.alm2map(alm)
    noise = a.noise * jax.random.normal(key, maps.shape)
    alm_back = plan.map2alm(maps + noise)

    cl_est = np.asarray(spectra.cl_from_alm(jnp.asarray(alm_back))).mean(-1)
    l = np.arange(2, a.lmax + 1)
    rel = np.abs(cl_est[2:] - cl[2:]) / cl[2:]
    cosmic = np.sqrt(2.0 / (2 * l + 1) / a.K)          # cosmic variance
    print(f"map rms: {float(jnp.std(maps)):.4e}  "
          f"noise rms: {a.noise:.1e}")
    print(f"pseudo-C_l rel. error: median={np.median(rel):.3f} "
          f"(cosmic-variance bound ~{np.median(cosmic):.3f})")
    ok = np.median(rel) < 5 * np.median(cosmic) + a.noise * 10
    print("PASS" if ok else "FAIL: spectrum recovery outside expectations")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
