"""End-to-end CMB T/Q/U pipeline (the paper's target application, S2HAT's
actual workload: spin-weighted polarised transforms):

  TT/EE/BB/TE spectra -> correlated Gaussian (T, E, B) a_lm realisations
  (a Monte-Carlo batch) -> T synthesis (spin 0) + E/B -> Q/U synthesis
  (spin 2) -> add white noise -> analysis back (spin 0 + spin 2) ->
  pseudo-C_l estimation (TT, EE, BB, TE) against the inputs.

Both plans dispatch through ``repro.make_plan`` -- the spin-2 plan runs the
same backend menu (jnp | pallas_vpu | pallas_mxu | dist) as the scalar one.
Set XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
distributed two-stage transforms on CPU.

    PYTHONPATH=src python examples/cmb_pipeline.py --lmax 96 --K 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import spectra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=96)
    ap.add_argument("--K", type=int, default=8, help="Monte-Carlo batch")
    ap.add_argument("--noise", type=float, default=1e-5)
    a = ap.parse_args()

    key = jax.random.PRNGKey(1)
    cls = spectra.cmb_like_cl_pol(a.lmax)
    alm_teb = spectra.alm_from_cl_pol(key, cls, K=a.K)     # (3, M, L, K)

    plan_t = repro.make_plan("gl", l_max=a.lmax, K=a.K, mode="auto")
    plan_p = repro.make_plan("gl", l_max=a.lmax, K=a.K, mode="auto", spin=2)
    print(f"T   transforms on {plan_t.grid.name} ({plan_t.grid.n_rings} "
          f"rings), backends={plan_t.backends}")
    print(f"Q/U transforms (spin 2), backends={plan_p.backends}")

    t_map = plan_t.alm2map(alm_teb[0])                     # (R, nphi, K)
    qu_maps = plan_p.alm2map(alm_teb[1:])                  # (2, R, nphi, K)

    kn1, kn2 = jax.random.split(key)
    t_map = t_map + a.noise * jax.random.normal(kn1, t_map.shape)
    qu_maps = qu_maps + a.noise * jax.random.normal(kn2, qu_maps.shape)

    alm_t = plan_t.map2alm(t_map)
    alm_eb = plan_p.map2alm(qu_maps)

    est = {
        "tt": np.asarray(spectra.cl_from_alm(alm_t)).mean(-1),
        "ee": np.asarray(spectra.cl_from_alm(alm_eb[0])).mean(-1),
        "bb": np.asarray(spectra.cl_from_alm(alm_eb[1])).mean(-1),
        "te": np.asarray(spectra.cl_cross_from_alm(alm_t,
                                                   alm_eb[0])).mean(-1),
    }

    l = np.arange(2, a.lmax + 1)
    cosmic = np.sqrt(2.0 / (2 * l + 1) / a.K)              # cosmic variance
    print(f"map rms: T={float(jnp.std(t_map)):.3e} "
          f"QU={float(jnp.std(qu_maps)):.3e}  noise rms: {a.noise:.1e}")
    ok = True
    for name in ("tt", "ee", "bb", "te"):
        truth = cls[name][2:]
        # TE crosses zero: normalise by the spectrum's scale, not pointwise
        denom = np.abs(truth) if name != "te" \
            else np.sqrt(cls["tt"][2:] * cls["ee"][2:])
        good = denom > 0
        rel = np.abs(est[name][2:][good] - truth[good]) / denom[good]
        med, bound = np.median(rel), 5 * np.median(cosmic)
        this_ok = med < bound + a.noise * 100
        ok &= this_ok
        print(f"pseudo-C_l {name.upper()}: median rel. err={med:.3f} "
              f"(bound ~{bound:.3f}) {'ok' if this_ok else 'FAIL'}")
    print("PASS" if ok else "FAIL: spectrum recovery outside expectations")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
