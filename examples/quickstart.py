"""Quickstart: the paper's validation experiment in 30 lines.

Synthesises a map from random a_lm (inverse SHT), analyses it back (direct
SHT), and reports the round-trip error D_err (paper eq. 19) -- on the
exact Gauss-Legendre grid this sits at machine precision.

    PYTHONPATH=src python examples/quickstart.py [--lmax 128]
"""

import argparse

import jax

import repro  # noqa: F401
from repro.core import grids, sht, spectra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=128)
    ap.add_argument("--grid", default="gl", choices=["gl", "healpix_ring"])
    ap.add_argument("--K", type=int, default=2, help="simultaneous maps")
    a = ap.parse_args()

    if a.grid == "gl":
        grid = grids.make_grid("gl", l_max=a.lmax)
    else:
        grid = grids.make_grid("healpix_ring", nside=max(a.lmax // 2, 1))
    t = sht.SHT(grid, l_max=a.lmax, m_max=a.lmax)

    key = jax.random.PRNGKey(0)
    alm = sht.random_alm(key, a.lmax, a.lmax, K=a.K)   # uniform (-1,1), paper §5
    maps = t.alm2map(alm)          # inverse SHT (synthesis)
    alm_back = t.map2alm(maps)     # direct SHT (analysis)

    err = spectra.d_err(alm, alm_back)
    print(f"grid={grid.name} rings={grid.n_rings} n_pix={grid.n_pix} "
          f"l_max={a.lmax} K={a.K}")
    print(f"round-trip D_err = {err:.3e}"
          + ("  (exact quadrature: machine precision)" if a.grid == "gl"
             else "  (approximate quadrature, paper Fig. 8 regime)"))


if __name__ == "__main__":
    main()
