"""Quickstart: the paper's validation experiment through the Plan API.

Builds a transform plan (autotuned kernel dispatch + cached precompute),
synthesises a map from random a_lm (inverse SHT), analyses it back (direct
SHT), and reports the round-trip error D_err (paper eq. 19) -- on the
exact Gauss-Legendre grid this sits at machine precision.

    PYTHONPATH=src python examples/quickstart.py [--lmax 128] [--dtype float32]
"""

import argparse

import jax

import repro
from repro.core import sht, spectra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=128)
    ap.add_argument("--grid", default="gl", choices=["gl", "healpix_ring"])
    ap.add_argument("--K", type=int, default=2, help="simultaneous maps")
    ap.add_argument("--dtype", default="float64",
                    choices=["float64", "float32"],
                    help="float32 enables the Pallas kernel backends")
    ap.add_argument("--mode", default="auto",
                    help="auto | model | jnp | pallas_vpu | pallas_mxu | dist")
    a = ap.parse_args()

    # One entry point: the plan owns precompute, layout and kernel choice.
    # A second make_plan with this signature returns the same (cached) plan.
    plan = repro.make_plan(a.grid, l_max=a.lmax,
                           nside=max(a.lmax // 2, 1),
                           K=a.K, dtype=a.dtype, mode=a.mode)

    alm = sht.random_alm(jax.random.PRNGKey(0), plan.l_max, plan.m_max,
                         K=a.K)                  # uniform (-1,1), paper §5
    if a.dtype == "float32":
        alm = alm.astype("complex64")
    maps = plan.alm2map(alm)       # inverse SHT (synthesis)
    alm_back = plan.map2alm(maps)  # direct SHT (analysis)

    err = spectra.d_err(alm, alm_back)
    g = plan.grid
    print(f"grid={g.name} rings={g.n_rings} n_pix={g.n_pix} "
          f"l_max={plan.l_max} K={a.K} dtype={a.dtype}")
    print(f"round-trip D_err = {err:.3e}"
          + ("  (exact quadrature: machine precision)"
             if a.grid == "gl" and a.dtype == "float64"
             else "  (f32/approximate-quadrature regime)"))
    print()
    print(plan.report())


if __name__ == "__main__":
    main()
