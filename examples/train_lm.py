"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on the synthetic pipeline with checkpoint/restart.

Defaults are CPU-sized (a width-reduced qwen3 family config, ~10M params,
50 steps) so the example completes in minutes; pass --full-width for the
real xlstm-125m (125M params) if you have the time budget.

    PYTHONPATH=src python examples/train_lm.py --steps 50
"""

import argparse
import time

import jax
import jax.numpy as jnp

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.models.model import make_bundle
from repro.train import checkpoint as C
from repro.train import data as D
from repro.train import optimizer as O
from repro.train import train_loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--full-width", action="store_true")
    a = ap.parse_args()

    cfg = registry.get(a.arch)
    if not a.full_width:
        cfg = reduced(cfg, d_model=256, n_layers=4, d_ff=1024, vocab=8192)
    bundle = make_bundle(cfg, mesh=None)
    tcfg = TL.TrainConfig(opt=O.AdamWConfig(
        lr=3e-4, warmup_steps=10, total_steps=a.steps))
    step = jax.jit(TL.make_train_step(bundle, tcfg), donate_argnums=(0, 1))

    ds = D.SyntheticLM(vocab=cfg.vocab, seq_len=a.seq, global_batch=a.batch,
                       seed=0)
    key = jax.random.PRNGKey(0)

    last = C.latest_step(a.ckpt)
    if last is None:
        params = bundle.init(key)
        opt = O.init_opt_state(params, tcfg.opt)
        step0 = 0
    else:
        print(f"resuming from checkpoint step {last}")
        params = bundle.init(key)
        opt = O.init_opt_state(params, tcfg.opt)
        state = C.restore(a.ckpt, last, {"params": params, "opt": opt})
        params, opt, step0 = state["params"], state["opt"], last

    t0 = time.time()
    for i in range(step0, a.steps):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        params, opt, m = step(params, opt, batch, key)
        if i % 10 == 0 or i == a.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"({(time.time()-t0):.1f}s)")
        if (i + 1) % 25 == 0:
            C.save(a.ckpt, i + 1, {"params": params, "opt": opt})
            print(f"  checkpoint @ {i+1}")
    print("done")


if __name__ == "__main__":
    main()
