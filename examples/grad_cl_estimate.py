"""Gradient-based C_l recovery through the differentiable transforms.

The workload the adjoint-based custom VJP rules unlock: fit spherical-
harmonic coefficients to an observed (noisy) map by gradient descent on a
pixel-space chi^2 -- ``jax.grad`` flows through ``Plan.alm2map`` via the
adjoint transform (synthesis VJP = weighted analysis), so every backend
(jnp, pallas_vpu, pallas_mxu, dist) is usable inside the optimizer loop --
then read the angular power spectrum off the fitted coefficients.

On the exact Gauss-Legendre grid the normal equations are perfectly
conditioned (A^T A is diagonal in harmonic space up to the quadrature
weights), so plain gradient descent with a per-mode step converges fast;
the point here is the machinery, not the estimator.

    PYTHONPATH=src python examples/grad_cl_estimate.py \
        [--lmax 16] [--steps 25] [--dtype float64] [--mode auto]

``--steps 1`` is the CI smoke configuration (scripts/check.sh).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import sht, spectra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lmax", type=int, default=16)
    ap.add_argument("--grid", default="gl", choices=["gl", "ecp", "healpix"])
    ap.add_argument("--steps", type=int, default=25)
    ap.add_argument("--noise", type=float, default=0.05)
    ap.add_argument("--dtype", default="float64",
                    choices=["float64", "float32"])
    ap.add_argument("--mode", default="auto",
                    help="auto | model | jnp | pallas_vpu | pallas_mxu | dist")
    a = ap.parse_args()

    nside = max(a.lmax // 2, 2) if a.grid == "healpix" else None
    plan = repro.make_plan(a.grid, l_max=a.lmax, nside=nside,
                           dtype=a.dtype, mode=a.mode)
    assert all(plan.grad_ready.values()), plan.grad_ready
    cdt = "complex64" if a.dtype == "float32" else "complex128"

    # --- simulated observation: CMB-like alm + white pixel noise ----------
    cl_true = spectra.cmb_like_cl(plan.l_max, amp=1.0)
    alm_true = spectra.alm_from_cl(jax.random.PRNGKey(0), cl_true,
                                   m_max=plan.m_max).astype(cdt)
    noise = a.noise * jax.random.normal(jax.random.PRNGKey(1),
                                        plan._maps_shape, plan.dtype)
    observed = plan.alm2map(alm_true) + noise

    # --- chi^2 in pixel space, gradient through the synthesis -------------
    w = jnp.asarray(plan.grid.weights, plan.dtype)[:, None, None]

    def loss(alm):
        r = plan.alm2map(alm) - observed
        return 0.5 * jnp.sum(w * r * r)     # quadrature-weighted chi^2

    loss_grad = jax.jit(jax.value_and_grad(loss))

    # Per-mode preconditioner: on exact grids the weighted normal matrix
    # is diagonal with entry fac_m per real degree of freedom (adjointness:
    # sum_pix w |dS/dRe a_lm|^2 = fac_m^2 * 1/fac_m), so lr = 1/fac_m is
    # an exact Newton step there and a good preconditioner elsewhere.
    m = np.arange(plan.m_max + 1)
    fac = jnp.asarray(np.where(m == 0, 1.0, 2.0),
                      plan.dtype)[:, None, None]
    lr = 1.0 / fac

    alm = jnp.zeros_like(alm_true)
    for step in range(a.steps):
        val, g = loss_grad(alm)
        # JAX complex grad is d/dRe - i d/dIm: conjugate for the descent step
        alm = alm - lr * jnp.conj(g)
        if step % 5 == 0 or step == a.steps - 1:
            print(f"step {step:3d}  chi2 = {float(val):.6e}")

    # --- read off the spectrum --------------------------------------------
    cl_hat = np.asarray(spectra.cl_from_alm(alm))[:, 0]
    cl_ref = np.asarray(spectra.cl_from_alm(alm_true))[:, 0]
    sel = slice(2, plan.l_max + 1)
    rel = np.abs(cl_hat[sel] - cl_ref[sel]) / np.maximum(cl_ref[sel], 1e-30)
    print(f"\nC_l recovery vs the realisation's pseudo-C_l "
          f"(l = 2..{plan.l_max}):")
    print(f"  median rel err = {np.median(rel):.3e}   "
          f"max rel err = {np.max(rel):.3e}")
    err = spectra.d_err(alm_true, alm)
    print(f"  alm D_err = {err:.3e}  (noise floor ~ {a.noise})")
    if a.steps >= 10 and a.grid == "gl":
        assert err < 5.0 * a.noise + 1e-6, "gradient descent failed to fit"
    print(f"\nbackends: {plan.backends}  differentiable: "
          f"{plan.describe()['differentiable']}")


if __name__ == "__main__":
    main()
