"""SHT-as-a-service demo: mixed-signature transform requests coalesced
into the K channel axis, served from a warm plan pool.

Submits a mix of Gauss-Legendre and true-HEALPix, spin-0 and spin-2
(Q/U <-> E/B) requests, drains the engine, checks every result against an
independent per-request Plan call, and prints the serving stats table
(latency percentiles, coalescing factor, plan-pool hit rate).

    PYTHONPATH=src python examples/serve_sht.py --requests 12
    PYTHONPATH=src python examples/serve_sht.py --p99-target-ms 50
    PYTHONPATH=src python examples/serve_sht.py --smoke      # CI one-rep

``--p99-target-ms`` switches coalescing from the fixed ``--max-k`` cap to
roofline admission control: per signature, the widest power-of-two K
whose *predicted* batch time fits the target (the admission verdicts and
predicted-vs-measured calibration show up in the stats table).
"""

import argparse

import numpy as np

import repro
from repro.core import sht
from repro.serve import ShtEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-k", type=int, default=4)
    ap.add_argument("--lmax", type=int, default=24)
    ap.add_argument("--nside", type=int, default=8)
    ap.add_argument("--p99-target-ms", type=float, default=None,
                    help="tail-latency target: roofline admission caps "
                         "each group's coalesced K so predicted batch "
                         "time fits the target (default: off, max-k "
                         "rules)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, few requests (CI)")
    a = ap.parse_args()
    if a.smoke:
        a.requests, a.lmax, a.nside = min(a.requests, 6), 12, 4

    target_s = None if a.p99_target_ms is None else a.p99_target_ms * 1e-3
    eng = ShtEngine(max_k=a.max_k, mode="jnp", warm_after=2,
                    p99_target_s=target_s)
    eng.prewarm(grid="gl", l_max=a.lmax, dtype="float64")

    # a traffic mix: GL spin-0, GL spin-2 (polarisation), HEALPix spin-0
    jobs = []
    for rid in range(a.requests):
        kind = rid % 3
        if kind == 0:
            alm = np.asarray(sht.random_alm(seed=rid, l_max=a.lmax,
                                            m_max=a.lmax))[..., 0]
            fut = eng.submit(direction="alm2map", payload=alm, grid="gl",
                             l_max=a.lmax, tag="gl-spin0")
            ref = repro.make_plan("gl", l_max=a.lmax, K=1, dtype="float64",
                                  mode="jnp").alm2map(alm[..., None])
        elif kind == 1:
            alm = np.asarray(sht.random_alm_spin(seed=rid, l_max=a.lmax,
                                                 m_max=a.lmax))[..., 0]
            fut = eng.submit(direction="alm2map", payload=alm, grid="gl",
                             l_max=a.lmax, spin=2, tag="gl-spin2")
            ref = repro.make_plan("gl", l_max=a.lmax, K=1, dtype="float64",
                                  mode="jnp",
                                  spin=2).alm2map(alm[..., None])
        else:
            hp = repro.make_plan("healpix", nside=a.nside, K=1,
                                 dtype="float64", mode="jnp")
            alm = np.asarray(sht.random_alm(seed=rid, l_max=hp.l_max,
                                            m_max=hp.m_max))[..., 0]
            fut = eng.submit(direction="alm2map", payload=alm,
                             grid="healpix", nside=a.nside,
                             tag="healpix-spin0")
            ref = hp.alm2map(alm[..., None])
        jobs.append((fut, np.asarray(ref)[..., 0]))

    eng.drain()
    worst = 0.0
    for fut, ref in jobs:
        worst = max(worst, float(np.max(np.abs(fut.result() - ref))))
    assert worst < 1e-12, f"coalesced result diverged: {worst}"

    print(eng.report())
    print(f"max |coalesced - independent| = {worst:.2e}")
    done = eng.stats()["requests"]["completed"]
    print(f"completed {done}/{a.requests} requests via K-coalesced serving")


if __name__ == "__main__":
    main()
