"""Batched serving example: submit prompts to the static-batch engine,
decode greedily with KV caches, print per-request outputs.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
"""

import argparse

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.models.model import make_bundle
from repro.serve.serve_loop import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    a = ap.parse_args()

    cfg = reduced(registry.get(a.arch), n_layers=2)
    bundle = make_bundle(cfg, mesh=None)
    params = bundle.init(jax.random.PRNGKey(0))

    eng = ServeEngine(bundle, batch=a.batch, max_len=256, eos_id=-1)
    rng = np.random.default_rng(0)
    for rid in range(a.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(3, 8)).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=a.max_new))

    done = eng.run(params, max_steps=200)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"request {r.rid}: prompt={r.prompt.tolist()} -> "
              f"out={r.out_tokens} done={r.done}")
    n_done = sum(r.done for r in done)
    print(f"{n_done} request(s) completed with batched decode")


if __name__ == "__main__":
    main()
