"""Paper Fig. 4: alpha-beta model -- runtime vs n_proc, vs problem size,
and the compute/communication crossover contour.

Columns: name, us_per_call = modelled total time, derived =
compute_us/comm_us/crossover.
"""

import numpy as np

import repro  # noqa: F401
from repro.core import comm_model as CM
from benchmarks.common import emit


def main():
    for params in (CM.MPICH_CLUSTER, CM.TPU_V5E_ICI):
        # left panel: fixed size (nside=4096), sweep processes
        for p in (16, 64, 256, 1024, 4096):
            t = CM.sht_times(4096, p, params)
            emit(f"scaling-model/{params.name}/nside4096/p{p}",
                 t["total"] * 1e6,
                 f"comp={t['compute']*1e6:.0f}us comm={t['comm']*1e6:.0f}us")
        # middle panel: fixed processes (512), sweep size
        for nside in (1024, 2048, 4096, 8192, 16384):
            t = CM.sht_times(nside, 512, params)
            emit(f"scaling-model/{params.name}/p512/nside{nside}",
                 t["total"] * 1e6,
                 f"comp={t['compute']*1e6:.0f}us comm={t['comm']*1e6:.0f}us")
        # right panel: crossover process count per size
        for nside in (1024, 4096, 16384):
            c = CM.crossover_nproc(nside, params)
            emit(f"scaling-model/{params.name}/crossover/nside{nside}",
                 0.0, f"crossover_nproc={c}")
        # overlapped-pipeline model: chunked exchange hides min(comp, comm)
        # behind the adjacent chunks' compute (PR 8); the `hidden` rows
        # carry the realised hidden fraction as the numeric value so the
        # check.sh gate can assert the comm-bound corners stay > 0.5
        for nside, p in ((1024, 256), (2048, 512), (4096, 1024)):
            t = CM.sht_times_overlap(nside, p, params)
            emit(f"scaling-model/overlap/{params.name}/nside{nside}/p{p}",
                 t["overlap"] * 1e6,
                 f"C={t['chunks']} serial={t['serial']*1e6:.0f}us "
                 f"hidden_frac={t['hidden_frac']:.3f}")
            emit(f"scaling-model/overlap/hidden/{params.name}"
                 f"/nside{nside}/p{p}", t["hidden_frac"],
                 f"C={t['chunks']} of hideable min(comp,comm)"
                 f"={min(t['compute'], t['comm'])*1e6:.0f}us")


if __name__ == "__main__":
    main()
