"""Chunked-exchange overlap: measured speedup of the pipelined all_to_all.

Runs in a SUBPROCESS with 8 host devices (this process stays 1-device).
For each direction, the C=1 monolithic exchange and the chunked C=2/C=4
pipelines are timed in ONE group-interleaved loop (`common.time_multi`),
so ``speedup = t[C=1] / min(t)`` is drift-free and >= 1.0 by construction
(the monolithic baseline is in the candidate set -- "best chunking never
loses").  On the host-CPU simulated mesh the collective is a memcpy, so
the measured hiding is modest; the modelled hiding at cluster scale rides
in ``scaling-model/overlap/*`` (bench_scaling_model).

Columns: name, us_per_call (speedup ratio for the ``overlap_speedup``
rows), derived = chosen C and raw per-C times.
"""

from benchmarks.bench_breakdown import run_helper

_HELPER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import repro
from repro.core import sht
from benchmarks.common import time_multi

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
LMAX = 64 if SMOKE else 256
K = 4
REPS = 1 if SMOKE else 5
CHUNKS = (1, 2, 4)

plans = {c: repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64",
                            mode="dist", n_shards=8, comm_chunks=c)
         for c in CHUNKS}
alm = sht.random_alm(jax.random.PRNGKey(0), LMAX, LMAX, K=K)
maps = jax.block_until_ready(plans[1].alm2map(alm))

for direction, make in (("synth", lambda p: (lambda: p.alm2map(alm))),
                        ("anal", lambda p: (lambda: p.map2alm(maps)))):
    ts = time_multi({c: make(p) for c, p in plans.items()}, iters=REPS)
    for c, t in ts.items():
        print(f"CSV dist/overlap/{direction}/C{c},{t*1e6:.1f},"
              f"8dev-lmax{LMAX}-K{K}")
    best = min(ts, key=ts.get)
    speedup = ts[1] / ts[best]
    print(f"CSV dist/overlap_speedup/{direction},{speedup:.4f},"
          f"best C={best} t1={ts[1]*1e6:.1f}us tbest={ts[best]*1e6:.1f}us")
'''


def main():
    r = run_helper(_HELPER)
    if r.returncode != 0:
        print(f"dist/overlap/error,0.0,"
              f"{r.stderr.splitlines()[-1] if r.stderr else 'unknown'}")


if __name__ == "__main__":
    main()
