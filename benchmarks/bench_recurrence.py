"""Paper Figs. 9-10: Legendre-stage time and GFlop/s, synthesis vs analysis.

Compares the engines on the recurrence hot spot (paper's >90% step):
  * f64 jnp engine (the oracle; paper's "multithreaded s2hat" analogue)
  * f32 jnp engine
  * Pallas kernels, vpu and mxu variants (interpret mode on CPU -- wall
    times are NOT TPU times; the derived GFlop/s column is the algorithmic
    flop count / wall, meaningful for relative comparisons only.  On-TPU
    projections live in the roofline, EXPERIMENTS.md §Roofline.)

Also reproduces the paper's direct-vs-inverse dichotomy measurement: the
analysis direction's reduction structure vs the synthesis direction.
Columns: name, us_per_call, derived = GFlop/s | notes.

Two extra row families cover the triangular m-pair packing
(kernels/pack.py):

  * ``recurrence/{synth,anal}/pallas-<var>-{plain,packed}/...`` -- wall
    time of the same kernel on the dense rectangular grid vs the packed
    min-max-paired grid (interpret mode on CPU);
  * ``recurrence/panels_ratio/lmax<N>`` -- analytic grid-step counts:
    the emitted value is plain_launched / packed (every launched step
    pays grid latency); the derived column carries the raw counts and
    the worked-panel ratio.  The l_max=512 row is the acceptance metric
    for the packing optimisation (>= 1.5x fewer executed panels).

And two for the fused Legendre+phase pipeline (kernels/fused.py):

  * ``recurrence/fused_speedup/{synth,anal}/pallas-<var>/<shape>/...`` --
    full staged chain vs the fused single-kernel pipeline, same plan,
    paired interleaved timing, one corner per covered plan shape
    (``gl`` scalar uniform, ``gl-fold``, ``gl-spin2``, ``healpix``
    bucketed).  The uniform ``synth/pallas-mxu`` row is the acceptance
    gate (>= 1.0) and every ``synth/pallas-vpu`` row must beat staged;
    the spin-2/bucket MXU rows are reported honestly (staged MXU still
    wins there, and the plan autotuner keeps dispatching it);
  * ``recurrence/bf16_err/{synth,anal}/...`` -- max relative error of the
    bf16-MXU-contraction fused variant against its own f32 run (the
    measured bf16 error band; gated < 1e-2 by scripts/check.sh).
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import grids, legendre, sht
from repro.kernels import ops as kops, ref as kref
from repro.roofline import analysis as roofline
from benchmarks.common import emit, smoke, time_call, time_pair

KEY = jax.random.PRNGKey(1)


def _flops(l_max, R, K):
    L1 = l_max + 1
    return R * L1 * (L1 + 1) / 2 * (20.0 + 8.0 * K)


def main():
    sizes = ((64, 1),) if smoke() else ((128, 1), (256, 1), (256, 8))
    for l_max, K in sizes:
        g = grids.make_grid("gl", l_max=l_max)
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        a_re = np.real(np.asarray(alm))
        a_im = np.imag(np.asarray(alm))
        fl = _flops(l_max, g.n_rings, K)

        # f64 engine, synthesis
        dt = time_call(lambda: legendre.delta_from_alm(
            a_re, a_im, m_vals, g.cos_theta, g.sin_theta, lm, l_max=l_max),
            iters=2)
        emit(f"recurrence/synth/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

        # f64 engine, analysis (the paper's slower-on-GPU direction)
        d_re, d_im = legendre.delta_from_alm(a_re, a_im, m_vals, g.cos_theta,
                                             g.sin_theta, lm, l_max=l_max)
        w = np.ones(g.n_rings)
        dt = time_call(lambda: legendre.alm_from_delta(
            d_re, d_im, m_vals, g.cos_theta, g.sin_theta, w, lm,
            l_max=l_max), iters=2)
        emit(f"recurrence/anal/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

        # folded synthesis (the beyond-paper recurrence halving)
        nh = (g.n_rings + 1) // 2
        dt = time_call(lambda: legendre.delta_from_alm_folded(
            a_re, a_im, m_vals, g.cos_theta[:nh], g.sin_theta[:nh], lm,
            l_max=l_max), iters=2)
        emit(f"recurrence/synth-fold/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

    # kernels (interpret mode): the plain rectangular grid vs the packed
    # min-max-paired grid, same kernel variant.  Calls are JITTED (the
    # un-jitted dispatch re-traces the kernel every call, which dominated
    # the wall and produced meaningless ratios) and the plain/packed pair
    # is timed interleaved (time_pair) so host drift cancels in the ratio.
    ksizes = ((96, 1, "vpu"),) if smoke() \
        else ((96, 1, "vpu"), (96, 8, "mxu"))
    for l_max, K, var in ksizes:
        g = grids.make_grid("gl", l_max=l_max)
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        a32 = jnp.concatenate([jnp.real(alm), jnp.imag(alm)],
                              axis=-1).astype(jnp.float32)
        pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
        x32 = jnp.asarray(g.cos_theta, jnp.float32)
        fl = _flops(l_max, g.n_rings, K)
        dw = jnp.ones((l_max + 1, 1, g.n_rings, 2 * K), jnp.float32)

        # m_vals MUST be a closure constant, not a jit argument: a traced
        # m_vals can never build a static packing, so pick_layout silently
        # falls back to the plain grid and "packed" rows time the plain
        # kernel (the root cause of the historical packed-anal ~0.7-1.0x
        # rows -- both sides were the same kernel plus noise).
        def jit_synth(layout):
            f = jax.jit(lambda a: kops.synth(a, m_vals, x32, pmm, pms,
                                             l_max=l_max, variant=var,
                                             layout=layout))
            return lambda: f(a32)

        def jit_anal(layout):
            f = jax.jit(lambda d: kops.anal(d, m_vals, x32, pmm, pms,
                                            l_max=l_max, variant=var,
                                            layout=layout))
            return lambda: f(dw)

        # 5 paired reps + 2 warmups even in smoke mode: the packed/plain
        # ratio is a CI gate, and 2-rep medians drift past the +-5% band
        times = {}
        for d, mk in (("synth", jit_synth), ("anal", jit_anal)):
            t_plain, t_packed = time_pair(mk("plain"), mk("packed"),
                                          warmup=2, iters=5)
            times[(d, "plain")], times[(d, "packed")] = t_plain, t_packed
            for layout, dt in (("plain", t_plain), ("packed", t_packed)):
                emit(f"recurrence/{d}/pallas-{var}-{layout}/"
                     f"lmax{l_max}/K{K}", dt * 1e6,
                     f"{fl / dt / 1e9:.2f} (interpret-mode wall)")
        for d in ("synth", "anal"):
            ratio = times[(d, "plain")] / max(times[(d, "packed")], 1e-12)
            emit(f"recurrence/packed_speedup/{d}/pallas-{var}/"
                 f"lmax{l_max}/K{K}", ratio,
                 "plain_wall / packed_wall (interpret mode, paired)")

    # fused Legendre+phase pipeline vs the staged chain: the full jitted
    # alm->maps / maps->alm dispatch path of the same plan, packed staged
    # layout vs the fused single-kernel layout, timed interleaved.  One
    # corner per covered plan shape (scalar uniform, spin-2, equator
    # folded, bucketed HEALPix); the uniform pallas-mxu synth row is the
    # acceptance gate (>= 1.0, scripts/check.sh).
    fcorners = (("gl", "vpu"), ("gl", "mxu")) if smoke() \
        else (("gl", "vpu"), ("gl", "mxu"), ("gl-fold", "vpu"),
              ("gl-spin2", "vpu"), ("gl-spin2", "mxu"),
              ("healpix", "vpu"), ("healpix", "mxu"))
    for tag, var in fcorners:
        kw = dict(K=8, dtype="float32", mode=f"pallas_{var}",
                  cache="memory")
        if tag == "gl":
            plan = repro.make_plan("gl", 96, **kw)
        elif tag == "gl-fold":
            plan = repro.make_plan("gl", 96, fold=True, **kw)
        elif tag == "gl-spin2":
            plan = repro.make_plan("gl", 96, spin=2, **kw)
        else:
            plan = repro.make_plan("healpix", nside=32, **kw)
        l_max, K = plan.l_max, plan.K
        mk_alm = sht.random_alm_spin if plan.spin else sht.random_alm
        alm = mk_alm(KEY, l_max, plan.m_max, K=K).astype(jnp.complex64)
        mshape = (plan.grid.n_rings, plan.grid.max_n_phi, K)
        if plan.spin:
            mshape = (2,) + mshape
        maps = jnp.asarray(
            np.random.default_rng(0).normal(size=mshape), jnp.float32)
        iters = 2 if smoke() else 3
        for d, fn_of, arg in (("synth", plan._synth_fn, alm),
                              ("anal", plan._anal_fn, maps)):
            staged = fn_of(f"pallas_{var}", "packed")
            fused = fn_of(f"pallas_{var}", "fused")
            t_staged, t_fused = time_pair(lambda: staged(arg),
                                          lambda: fused(arg), iters=iters)
            emit(f"recurrence/{d}/staged-{var}/{tag}/lmax{l_max}/K{K}",
                 t_staged * 1e6, "full staged chain (interpret-mode wall)")
            emit(f"recurrence/{d}/fused-{var}/{tag}/lmax{l_max}/K{K}",
                 t_fused * 1e6, "fused pipeline (interpret-mode wall)")
            emit(f"recurrence/fused_speedup/{d}/pallas-{var}/{tag}/"
                 f"lmax{l_max}/K{K}", t_staged / max(t_fused, 1e-12),
                 "staged_wall / fused_wall (interpret mode, paired)")

    # bf16 MXU panel contraction: max relative error of the fused bf16
    # variant against its own f32 run (one forward call each, no timing)
    bsizes = ((32, 2),) if smoke() else ((32, 2), (96, 8))
    for l_max, K in bsizes:
        plan = repro.make_plan("gl", l_max, K=K, dtype="float32",
                               mode="pallas_mxu", cache="memory")
        alm = sht.random_alm(KEY, l_max, l_max, K=K).astype(jnp.complex64)
        f32_s = jax.jit(plan._make_fused_synth("mxu", bf16=False))
        b16_s = jax.jit(plan._make_fused_synth("mxu", bf16=True))
        m32, m16 = f32_s(alm), b16_s(alm)
        err = float(jnp.max(jnp.abs(m16 - m32)) / jnp.max(jnp.abs(m32)))
        emit(f"recurrence/bf16_err/synth/pallas-mxu/lmax{l_max}/K{K}", err,
             "max|bf16 - f32| / max|f32| (fused MXU, f32 accumulation)")
        maps = m32
        f32_a = jax.jit(plan._make_fused_anal("mxu", bf16=False))
        b16_a = jax.jit(plan._make_fused_anal("mxu", bf16=True))
        a32_, a16_ = f32_a(maps), b16_a(maps)
        err = float(jnp.max(jnp.abs(a16_ - a32_)) / jnp.max(jnp.abs(a32_)))
        emit(f"recurrence/bf16_err/anal/pallas-mxu/lmax{l_max}/K{K}", err,
             "max|bf16 - f32| / max|f32| (fused MXU, f32 accumulation)")

    # analytic grid-step accounting at production sizes (cheap, always
    # emitted -- the lmax512 row is the packing acceptance metric)
    for l_max in (256, 512):
        c = roofline.legendre_panel_counts(l_max, l_max)
        emit(f"recurrence/panels_ratio/lmax{l_max}", c["launched_ratio"],
             f"plain_launched={c['plain_launched']} "
             f"plain_worked={c['plain_worked']} packed={c['packed']} "
             f"worked_ratio={c['worked_ratio']:.2f} "
             f"occupancy={c['packed_occupancy']:.2f} lp=128")


if __name__ == "__main__":
    main()
