"""Paper Figs. 9-10: Legendre-stage time and GFlop/s, synthesis vs analysis.

Compares the engines on the recurrence hot spot (paper's >90% step):
  * f64 jnp engine (the oracle; paper's "multithreaded s2hat" analogue)
  * f32 jnp engine
  * Pallas kernels, vpu and mxu variants (interpret mode on CPU -- wall
    times are NOT TPU times; the derived GFlop/s column is the algorithmic
    flop count / wall, meaningful for relative comparisons only.  On-TPU
    projections live in the roofline, EXPERIMENTS.md §Roofline.)

Also reproduces the paper's direct-vs-inverse dichotomy measurement: the
analysis direction's reduction structure vs the synthesis direction.
Columns: name, us_per_call, derived = GFlop/s | notes.

Two extra row families cover the triangular m-pair packing
(kernels/pack.py):

  * ``recurrence/{synth,anal}/pallas-<var>-{plain,packed}/...`` -- wall
    time of the same kernel on the dense rectangular grid vs the packed
    min-max-paired grid (interpret mode on CPU);
  * ``recurrence/panels_ratio/lmax<N>`` -- analytic grid-step counts:
    the emitted value is plain_launched / packed (every launched step
    pays grid latency); the derived column carries the raw counts and
    the worked-panel ratio.  The l_max=512 row is the acceptance metric
    for the packing optimisation (>= 1.5x fewer executed panels).
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import grids, legendre, sht
from repro.kernels import ops as kops, ref as kref
from repro.roofline import analysis as roofline
from benchmarks.common import emit, smoke, time_call

KEY = jax.random.PRNGKey(1)


def _flops(l_max, R, K):
    L1 = l_max + 1
    return R * L1 * (L1 + 1) / 2 * (20.0 + 8.0 * K)


def main():
    sizes = ((64, 1),) if smoke() else ((128, 1), (256, 1), (256, 8))
    for l_max, K in sizes:
        g = grids.make_grid("gl", l_max=l_max)
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        a_re = np.real(np.asarray(alm))
        a_im = np.imag(np.asarray(alm))
        fl = _flops(l_max, g.n_rings, K)

        # f64 engine, synthesis
        dt = time_call(lambda: legendre.delta_from_alm(
            a_re, a_im, m_vals, g.cos_theta, g.sin_theta, lm, l_max=l_max),
            iters=2)
        emit(f"recurrence/synth/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

        # f64 engine, analysis (the paper's slower-on-GPU direction)
        d_re, d_im = legendre.delta_from_alm(a_re, a_im, m_vals, g.cos_theta,
                                             g.sin_theta, lm, l_max=l_max)
        w = np.ones(g.n_rings)
        dt = time_call(lambda: legendre.alm_from_delta(
            d_re, d_im, m_vals, g.cos_theta, g.sin_theta, w, lm,
            l_max=l_max), iters=2)
        emit(f"recurrence/anal/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

        # folded synthesis (the beyond-paper recurrence halving)
        nh = (g.n_rings + 1) // 2
        dt = time_call(lambda: legendre.delta_from_alm_folded(
            a_re, a_im, m_vals, g.cos_theta[:nh], g.sin_theta[:nh], lm,
            l_max=l_max), iters=2)
        emit(f"recurrence/synth-fold/jnp-f64/lmax{l_max}/K{K}", dt * 1e6,
             f"{fl / dt / 1e9:.2f}")

    # kernels (interpret mode): small sizes only; the plain rectangular
    # grid vs the packed triangular m-pair grid, same kernel variant
    ksizes = ((32, 1, "vpu"),) if smoke() \
        else ((96, 1, "vpu"), (96, 8, "mxu"))
    for l_max, K, var in ksizes:
        g = grids.make_grid("gl", l_max=l_max)
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        a32 = jnp.concatenate([jnp.real(alm), jnp.imag(alm)],
                              axis=-1).astype(jnp.float32)
        pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
        x32 = jnp.asarray(g.cos_theta, jnp.float32)
        fl = _flops(l_max, g.n_rings, K)
        dw = jnp.ones((l_max + 1, 1, g.n_rings, 2 * K), jnp.float32)
        times = {}
        for layout in ("plain", "packed"):
            dt = time_call(lambda: kops.synth(a32, m_vals, x32, pmm, pms,
                                              l_max=l_max, variant=var,
                                              layout=layout), iters=1)
            times[("synth", layout)] = dt
            emit(f"recurrence/synth/pallas-{var}-{layout}/lmax{l_max}/K{K}",
                 dt * 1e6, f"{fl / dt / 1e9:.2f} (interpret-mode wall)")
            dt = time_call(lambda: kops.anal(dw, m_vals, x32, pmm, pms,
                                             l_max=l_max, variant=var,
                                             layout=layout), iters=1)
            times[("anal", layout)] = dt
            emit(f"recurrence/anal/pallas-{var}-{layout}/lmax{l_max}/K{K}",
                 dt * 1e6, f"{fl / dt / 1e9:.2f} (interpret-mode wall)")
        for d in ("synth", "anal"):
            ratio = times[(d, "plain")] / max(times[(d, "packed")], 1e-12)
            emit(f"recurrence/packed_speedup/{d}/pallas-{var}/"
                 f"lmax{l_max}/K{K}", ratio,
                 "plain_wall / packed_wall (interpret mode)")

    # analytic grid-step accounting at production sizes (cheap, always
    # emitted -- the lmax512 row is the packing acceptance metric)
    for l_max in (256, 512):
        c = roofline.legendre_panel_counts(l_max, l_max)
        emit(f"recurrence/panels_ratio/lmax{l_max}", c["launched_ratio"],
             f"plain_launched={c['plain_launched']} "
             f"plain_worked={c['plain_worked']} packed={c['packed']} "
             f"worked_ratio={c['worked_ratio']:.2f} "
             f"occupancy={c['packed_occupancy']:.2f} lp=128")


if __name__ == "__main__":
    main()
