"""Paper Fig. 15: relative speed-up across problem sizes -- via make_plan.

The paper reports MPI/CUDA vs MPI/OpenMP speed-up per process count.  Our
measurable analogue on this container: each plan backend vs the float64
jnp baseline for the full transform (both directions), plus the batched-K
amortisation (the MXU story at the algorithmic level).  Every engine is
reached through the unified Plan API -- no hand-wired kernels.

Columns: name, us_per_call (optimised path), derived = speedup vs baseline.
Every ratio comes from ONE paired interleaved loop (`common.time_pair`):
independent timings drift 30-40% between runs on a noisy host, which made
the old A/B ratios meaningless.
"""

import jax
import jax.numpy as jnp

import repro
from repro.core import sht
from benchmarks.common import emit, smoke, time_pair

KEY = jax.random.PRNGKey(3)


def main():
    for l_max in ((32,) if smoke() else (64, 128)):
        alm64 = sht.random_alm(KEY, l_max, l_max)
        base = repro.make_plan("gl", l_max=l_max, K=1, dtype="float64",
                               mode="jnp")
        maps64 = base.alm2map(alm64)

        alm32 = alm64.astype(jnp.complex64)
        maps32 = jnp.asarray(maps64, jnp.float32)
        for mode in ("jnp", "pallas_vpu", "pallas_mxu"):
            p = repro.make_plan("gl", l_max=l_max, K=1, dtype="float32",
                                mode=mode)
            tb_s, ts = time_pair(lambda: base.alm2map(alm64),
                                 lambda: p.alm2map(alm32), iters=2)
            tb_a, ta = time_pair(lambda: base.map2alm(maps64),
                                 lambda: p.map2alm(maps32), iters=2)
            emit(f"speedup/{mode}-f32-synth/lmax{l_max}", ts * 1e6,
                 f"x{tb_s / ts:.2f} vs f64 jnp")
            emit(f"speedup/{mode}-f32-anal/lmax{l_max}", ta * 1e6,
                 f"x{tb_a / ta:.2f} vs f64 jnp")

        # fold optimisation through the plan layer (synthesis only)
        pf = repro.make_plan("gl", l_max=l_max, K=1, dtype="float64",
                             mode="jnp", fold=True)
        tb_s, tf_s = time_pair(lambda: base.alm2map(alm64),
                               lambda: pf.alm2map(alm64), iters=2)
        emit(f"speedup/fold-vs-unfold/lmax{l_max}", tf_s * 1e6,
             f"x{tb_s / tf_s:.2f}")

    # batched-K amortisation: per-map time shrinks as K grows because
    # P_lm generation is shared across the Monte-Carlo batch.
    l_max = 32 if smoke() else 128
    alm1 = sht.random_alm(KEY, l_max, l_max, K=1)
    p1 = repro.make_plan("gl", l_max=l_max, K=1, dtype="float64", mode="jnp")
    for K in ((1, 4) if smoke() else (1, 4, 16)):
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        p = repro.make_plan("gl", l_max=l_max, K=K, dtype="float64",
                            mode="jnp")
        t1, t = time_pair(lambda: p1.alm2map(alm1),
                          lambda: p.alm2map(alm), iters=2)
        if K == 1:
            t1 = t          # same plan: the ratio is 1.0 by definition
        emit(f"speedup/batched-K{K}/lmax{l_max}", t / K * 1e6,
             f"per-map x{t1 / (t / K):.2f} vs K=1")


if __name__ == "__main__":
    main()
