"""Paper Fig. 15: relative speed-up across problem sizes.

The paper reports MPI/CUDA vs MPI/OpenMP speed-up per process count.  Our
measurable analogue on this container: the f32 engine vs the f64 engine
(the precision/layout transformation that enables the TPU kernels), the
fold optimisation, and the batched-K amortisation -- each as a ratio at
several sizes.  Columns: name, us_per_call (optimised path), derived =
speedup vs baseline.
"""

import jax
import numpy as np

import repro  # noqa: F401
from repro.core import grids, legendre, sht
from benchmarks.common import emit, time_call

KEY = jax.random.PRNGKey(3)


def main():
    for l_max in (128, 256):
        g = grids.make_grid("gl", l_max=l_max)
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        alm = sht.random_alm(KEY, l_max, l_max)
        a_re = np.real(np.asarray(alm))
        a_im = np.imag(np.asarray(alm))

        base = time_call(lambda: legendre.delta_from_alm(
            a_re, a_im, m_vals, g.cos_theta, g.sin_theta, lm,
            l_max=l_max, dtype=np.float64), iters=2)
        f32 = time_call(lambda: legendre.delta_from_alm(
            a_re, a_im, m_vals, g.cos_theta, g.sin_theta, lm,
            l_max=l_max, dtype=np.float32), iters=2)
        emit(f"speedup/f32-vs-f64/lmax{l_max}", f32 * 1e6,
             f"x{base / f32:.2f}")

        nh = (g.n_rings + 1) // 2
        fold = time_call(lambda: legendre.delta_from_alm_folded(
            a_re, a_im, m_vals, g.cos_theta[:nh], g.sin_theta[:nh], lm,
            l_max=l_max), iters=2)
        emit(f"speedup/fold-vs-unfold/lmax{l_max}", fold * 1e6,
             f"x{base / fold:.2f}")

    # batched-K amortisation (the MXU story at the algorithmic level):
    # per-map time shrinks as K grows because P generation is shared.
    l_max = 128
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    m_vals = np.arange(l_max + 1)
    t1 = None
    for K in (1, 4, 16):
        alm = sht.random_alm(KEY, l_max, l_max, K=K)
        a_re = np.real(np.asarray(alm))
        a_im = np.imag(np.asarray(alm))
        t = time_call(lambda: legendre.delta_from_alm(
            a_re, a_im, m_vals, g.cos_theta, g.sin_theta, lm, l_max=l_max),
            iters=2)
        if K == 1:
            t1 = t
        emit(f"speedup/batched-K{K}/lmax{l_max}", t / K * 1e6,
             f"per-map x{t1 / (t / K):.2f} vs K=1")


if __name__ == "__main__":
    main()
