"""Paper Fig. 8: round-trip relative error D_err vs (l_max, grid, dtype).

Columns: name, us_per_call (map2alm(alm2map) wall), derived = D_err.
The GL grid isolates implementation error (machine precision); the
HEALPix-ring grid reproduces the paper's aliasing-driven error growth as
l_max approaches the 2*nside sampling limit.
"""

import jax
import numpy as np

import repro  # noqa: F401
from repro.core import grids, sht, spectra
from benchmarks.common import emit, time_call

KEY = jax.random.PRNGKey(0)


def main():
    for l_max in (32, 64, 128, 256):
        t = sht.SHT(grids.make_grid("gl", l_max=l_max), l_max=l_max,
                    m_max=l_max)
        alm = sht.random_alm(KEY, l_max, l_max)
        rt = lambda a: t.map2alm(t.alm2map(a))
        dt = time_call(rt, alm, iters=1)
        err = spectra.d_err(alm, rt(alm))
        emit(f"accuracy/gl/f64/lmax{l_max}", dt * 1e6, f"{err:.3e}")

    for nside in (16, 32, 64):
        # at the sampling limit (l_max = 2 nside) and well-resolved (nside)
        for l_max in (2 * nside, nside):
            g = grids.make_grid("healpix_ring", nside=nside)
            t = sht.SHT(g, l_max=l_max, m_max=l_max)
            alm = sht.random_alm(KEY, l_max, l_max)
            rt = lambda a: t.map2alm(t.alm2map(a))
            dt = time_call(rt, alm, iters=1)
            err = spectra.d_err(alm, rt(alm))
            emit(f"accuracy/healpix_ring/nside{nside}/lmax{l_max}",
                 dt * 1e6, f"{err:.3e}")

    # f32 engine (kernel-precision) error at fixed size
    l_max = 128
    g = grids.make_grid("gl", l_max=l_max)
    t32 = sht.SHT(g, l_max=l_max, m_max=l_max, dtype="float32")
    alm = sht.random_alm(KEY, l_max, l_max).astype(np.complex64)
    rt = lambda a: t32.map2alm(t32.alm2map(a))
    dt = time_call(rt, alm, iters=1)
    err = spectra.d_err(alm, rt(alm))
    emit(f"accuracy/gl/f32/lmax{l_max}", dt * 1e6, f"{err:.3e}")


if __name__ == "__main__":
    main()
