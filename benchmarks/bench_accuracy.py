"""Paper Fig. 8: round-trip relative error D_err vs (l_max, grid, dtype).

Columns: name, us_per_call (map2alm(alm2map) wall), derived = D_err.
The GL grid isolates implementation error (machine precision); the
HEALPix-family grids reproduce the paper's aliasing-driven error growth as
l_max approaches the 2*nside sampling limit.  True (ragged) HEALPix runs
through the same plan path as everything else -- the ring-bucket phase
stage -- including ``iters=1`` Jacobi refinement rows.

Every transform goes through ``repro.make_plan``; no engine hand-wiring.
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import sht, spectra
from benchmarks.common import emit, smoke, time_call

KEY = jax.random.PRNGKey(0)  # explicit: random_alm no longer defaults


def _roundtrip(plan, alm, iters=0):
    rt = lambda a: plan.map2alm(plan.alm2map(a), iters=iters)
    dt = time_call(rt, alm, iters=1)
    return dt, spectra.d_err(alm, rt(alm))


def main():
    gl_sizes = (32,) if smoke() else (32, 64, 128, 256)
    for l_max in gl_sizes:
        plan = repro.make_plan("gl", l_max=l_max, dtype="float64", mode="jnp")
        alm = sht.random_alm(KEY, l_max, l_max)
        dt, err = _roundtrip(plan, alm)
        emit(f"accuracy/gl/f64/lmax{l_max}", dt * 1e6, f"{err:.3e}")

    nsides = (8,) if smoke() else (16, 32, 64)
    for nside in nsides:
        # at the sampling limit (l_max = 2 nside) and well-resolved (nside)
        for l_max in (2 * nside, nside):
            for kind in ("healpix_ring", "healpix"):
                plan = repro.make_plan(kind, nside=nside, l_max=l_max,
                                       dtype="float64", mode="jnp")
                alm = sht.random_alm(KEY, l_max, l_max)
                dt, err = _roundtrip(plan, alm)
                emit(f"accuracy/{kind}/nside{nside}/lmax{l_max}",
                     dt * 1e6, f"{err:.3e}")
        # Jacobi refinement on the approximate-quadrature (ragged) grid
        plan = repro.make_plan("healpix", nside=nside, dtype="float64",
                               mode="jnp")
        alm = sht.random_alm(KEY, plan.l_max, plan.m_max)
        dt, err = _roundtrip(plan, alm, iters=1)
        emit(f"accuracy/healpix/nside{nside}/iters1", dt * 1e6, f"{err:.3e}")

    # f32 engine (kernel-precision) error at fixed size
    l_max = 32 if smoke() else 128
    plan = repro.make_plan("gl", l_max=l_max, dtype="float32", mode="jnp")
    alm = sht.random_alm(KEY, l_max, l_max).astype(np.complex64)
    dt, err = _roundtrip(plan, alm)
    emit(f"accuracy/gl/f32/lmax{l_max}", dt * 1e6, f"{err:.3e}")

    # spin-2 (E/B <-> Q/U) accuracy per backend, alongside the scalar table
    l_max = 16 if smoke() else 64
    for backend, dtype in (("jnp", "float64"), ("pallas_vpu", "float32"),
                           ("pallas_mxu", "float32")):
        plan = repro.make_plan("gl", l_max=l_max, dtype=dtype, mode=backend,
                               spin=2)
        alm = sht.random_alm_spin(KEY, l_max, l_max)
        if dtype == "float32":
            alm = alm.astype(np.complex64)
        dt, err = _roundtrip(plan, alm)
        emit(f"accuracy/gl/spin2/{backend}/lmax{l_max}", dt * 1e6,
             f"{err:.3e}")
    nside = 8 if smoke() else 16
    plan = repro.make_plan("healpix", nside=nside, l_max=nside,
                           dtype="float64", mode="jnp", spin=2)
    alm = sht.random_alm_spin(KEY, plan.l_max, plan.m_max)
    dt, err = _roundtrip(plan, alm, iters=1)
    emit(f"accuracy/healpix/spin2/nside{nside}/iters1", dt * 1e6,
         f"{err:.3e}")


if __name__ == "__main__":
    main()
