"""Paper Figs. 12-13: runtime breakdown of the distributed transforms into
recurrence / communication / FFT stages, under MPI-style sharding.

Runs in a SUBPROCESS with 8 host devices (this process stays 1-device).
Each stage is timed by jitting it in isolation with the same shardings.
Columns: name, us_per_call, derived = stage.
"""

import os
import subprocess
import sys

_HELPER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import numpy as np, jax, jax.numpy as jnp
import repro
from repro import compat
from repro.core import grids, sht, plan as planlib, dist_sht

lmax, K = 256, 2
g = grids.make_grid("gl", l_max=lmax)
mesh = jax.make_mesh((8,), ("procs",))
p = planlib.SHTPlan(g, lmax, lmax, 8)
d = dist_sht.DistSHT(p, mesh, ("procs",))
alm = sht.random_alm(jax.random.PRNGKey(0), lmax, lmax, K=K)
packed = jnp.asarray(p.pack_alm(np.asarray(alm)))

def timeit(f, *a):
    out = f(*a); jax.block_until_ready(out)
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); out = f(*a); jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out

# full transform
t_full, maps = timeit(d.alm2map, packed)
# stage timings via the internal builders
synth, anal, c = d._build(K)
a_re, a_im = jnp.real(packed), jnp.imag(packed)

import functools
from jax.sharding import PartitionSpec as P
spec = P(("procs",))

stage1 = jax.jit(compat.shard_map(lambda ar, ai, m: jnp.concatenate(
    d._stage1_synth(ar, ai, m), -1), mesh=mesh,
    in_specs=(spec, spec, spec), out_specs=spec))
t_s1, delta = timeit(stage1, a_re, a_im, c["m_flat"])

exch = jax.jit(compat.shard_map(lambda x: d._exchange(x, to_rings=True),
    mesh=mesh, in_specs=(spec,), out_specs=spec))
t_comm, exch_out = timeit(exch, delta)

fft = jax.jit(compat.shard_map(lambda x, ph, vl: d._synth_fft(
    x[..., :K], x[..., K:], ph, vl), mesh=mesh,
    in_specs=(spec, spec, spec), out_specs=spec))
t_fft, _ = timeit(fft, exch_out, c["phi0"], c["valid"])

print(f"CSV breakdown/alm2map/full,{t_full*1e6:.1f},8dev-lmax{lmax}")
print(f"CSV breakdown/alm2map/recurrence,{t_s1*1e6:.1f},stage1")
print(f"CSV breakdown/alm2map/all_to_all,{t_comm*1e6:.1f},comm")
print(f"CSV breakdown/alm2map/fft,{t_fft*1e6:.1f},stage2")

# direct transform breakdown (mirror)
maps_plan = jnp.asarray(p.gather_map(np.zeros((g.n_rings, g.max_n_phi, K))))
t_full_a, _ = timeit(d.map2alm, maps_plan)
print(f"CSV breakdown/map2alm/full,{t_full_a*1e6:.1f},8dev-lmax{lmax}")
'''


def main():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-c", _HELPER], capture_output=True,
                       text=True, timeout=560, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            print(line[4:])
    if r.returncode != 0:
        print(f"breakdown/error,0.0,{r.stderr.splitlines()[-1] if r.stderr else 'unknown'}")


if __name__ == "__main__":
    main()
