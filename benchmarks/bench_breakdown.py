"""Paper Figs. 12-13: runtime breakdown of the distributed transforms into
recurrence / communication / FFT stages, under MPI-style sharding.

Runs in a SUBPROCESS with 8 host devices (this process stays 1-device).
The transforms are reached through ``repro.make_plan(..., mode="dist")``;
each stage is then timed by jitting it in isolation with the same
shardings.  All stages of one breakdown are timed in ONE group-interleaved
loop (`common.time_multi`) so the stage fractions are not distorted by
host drift between runs.  Includes a true-HEALPix (ragged) breakdown: its
FFT stage is the bucket engine with bucket-aware ring sharding.
Columns: name, us_per_call, derived = stage.
"""

import os
import subprocess
import sys

from benchmarks.common import emit

_HELPER = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import repro
from repro import compat
from repro.core import sht
from benchmarks.common import time_multi
from jax.sharding import PartitionSpec as P

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
K = 2
REPS = 1 if SMOKE else 3

def breakdown(tag, plan):
    d = plan._dist_engine()
    p = d.plan
    alm = sht.random_alm(jax.random.PRNGKey(0), plan.l_max, plan.m_max, K=K)
    maps = jax.block_until_ready(plan.alm2map(alm))

    packed = jnp.asarray(p.pack_alm(np.asarray(alm)))
    synth, anal, c = d._build(K)
    a_re, a_im = jnp.real(packed), jnp.imag(packed)
    spec = P(d.axis_names)

    stage1 = jax.jit(compat.shard_map(lambda ar, ai, m: jnp.concatenate(
        d._stage1_synth(ar, ai, m), -1), mesh=d.mesh,
        in_specs=(spec, spec, spec), out_specs=spec))
    delta = stage1(a_re, a_im, c["m_flat"])

    exch = jax.jit(compat.shard_map(lambda x: d._exchange(x, to_rings=True),
        mesh=d.mesh, in_specs=(spec,), out_specs=spec))
    exch_out = exch(delta)

    nops = len(c["synth_ops"])
    fft = jax.jit(compat.shard_map(lambda x, ph, vl, *ops: d._synth_fft(
        x[..., :K], x[..., K:], ph, vl, ops), mesh=d.mesh,
        in_specs=(spec,) * (3 + nops), out_specs=spec))

    ts = time_multi({
        "full_s": lambda: plan.alm2map(alm),
        "recurrence": lambda: stage1(a_re, a_im, c["m_flat"]),
        "all_to_all": lambda: exch(delta),
        "fft": lambda: fft(exch_out, c["phi0"], c["valid"], *c["synth_ops"]),
        "full_a": lambda: plan.map2alm(maps),
    }, iters=REPS)

    kind = plan.phase.describe()["kind"]
    print(f"CSV breakdown/{tag}/alm2map/full,{ts['full_s']*1e6:.1f},"
          f"8dev-lmax{plan.l_max}")
    print(f"CSV breakdown/{tag}/alm2map/recurrence,"
          f"{ts['recurrence']*1e6:.1f},stage1")
    print(f"CSV breakdown/{tag}/alm2map/all_to_all,"
          f"{ts['all_to_all']*1e6:.1f},comm")
    print(f"CSV breakdown/{tag}/alm2map/fft,{ts['fft']*1e6:.1f},"
          f"{kind}-phase")
    print(f"CSV breakdown/{tag}/map2alm/full,{ts['full_a']*1e6:.1f},"
          f"8dev-lmax{plan.l_max}")

lmax = 64 if SMOKE else 256
breakdown("gl", repro.make_plan("gl", l_max=lmax, K=K, dtype="float64",
                                mode="dist", n_shards=8))
nside = 8 if SMOKE else 32
breakdown("healpix", repro.make_plan("healpix", nside=nside, K=K,
                                     dtype="float64", mode="dist",
                                     n_shards=8))
'''


def run_helper(helper: str, timeout: int = 560):
    """Run a multi-device benchmark helper in a subprocess and re-emit its
    ``CSV name,us,derived`` lines through `common.emit` so they land in
    the BENCH_<date>.json trajectory."""
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    # src for repro, the repo root for benchmarks.common (time_multi)
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(root, "src"), root])
    r = subprocess.run([sys.executable, "-c", helper], capture_output=True,
                       text=True, timeout=timeout, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("CSV "):
            name, us, derived = line[4:].split(",", 2)
            emit(name, float(us), derived)
    return r


def main():
    r = run_helper(_HELPER)
    if r.returncode != 0:
        print(f"breakdown/error,0.0,{r.stderr.splitlines()[-1] if r.stderr else 'unknown'}")


if __name__ == "__main__":
    main()
