"""Plan-dispatch benchmark: the paper's dichotomy table, via make_plan.

For each problem size, builds an autotuned plan and prints the
``describe()`` numbers: the chosen backend per direction, the cost-model
prediction vs the measurement that decided it, and the warm-vs-cold
``make_plan`` cost (the precompute-cache win).

Columns: name, us_per_call, derived.

``REPRO_BENCH_SMOKE=1`` shrinks the sweep to one small size (CI smoke).
"""

import math
import os
import time

import jax
import jax.numpy as jnp

import repro
from repro.core import sht, spectra
from benchmarks.common import emit


def _sizes():
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return [(32, 2)]
    return [(64, 1), (128, 4), (128, 16)]


def main():
    for l_max, K in _sizes():
        t0 = time.perf_counter()
        plan = repro.make_plan("gl", l_max=l_max, K=K, dtype="float32",
                               mode="auto")
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        plan2 = repro.make_plan("gl", l_max=l_max, K=K, dtype="float32",
                                mode="auto")
        t_warm = time.perf_counter() - t0
        assert plan2 is plan, "plan memoisation regressed"

        d = plan.describe()
        for direction in ("synth", "anal"):
            chosen = d["backends"][direction]
            meas = d["measured_s"].get(chosen, {}).get(direction, float("nan"))
            pred = d["predicted_s"].get(chosen, {}).get(direction, float("nan"))
            if math.isfinite(meas):
                emit(f"dispatch/{direction}/lmax{l_max}-K{K}", meas * 1e6,
                     f"{chosen} (predicted {pred * 1e6:.1f}us)")
            else:
                # chardb smoke mode skips corners missing from the DB (the
                # decision falls back to the cost model, measured_s = inf);
                # keep the trajectory numeric with the model's value
                emit(f"dispatch/{direction}/lmax{l_max}-K{K}", pred * 1e6,
                     f"{chosen} (model-fallback, unmeasured corner)")
        emit(f"dispatch/make_plan-cold/lmax{l_max}-K{K}", t_cold * 1e6,
             f"warm x{t_cold / max(t_warm, 1e-9):.0f} faster")

        # correctness spot-check through the dispatched path
        alm = sht.random_alm(jax.random.PRNGKey(0), l_max, plan.m_max,
                             K=K).astype(jnp.complex64)
        err = spectra.d_err(alm, plan.map2alm(plan.alm2map(alm)))
        emit(f"dispatch/roundtrip-derr/lmax{l_max}-K{K}", 0.0, f"{err:.2e}")


if __name__ == "__main__":
    main()
