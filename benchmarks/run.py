"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Wall times are CPU-host
times (TPU projections live in the roofline analysis; EXPERIMENTS.md).
"""

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_accuracy, bench_recurrence,
                            bench_scaling_model, bench_fft, bench_speedup,
                            bench_breakdown, bench_dispatch)
    print("name,us_per_call,derived")
    for mod in (bench_accuracy, bench_recurrence, bench_scaling_model,
                bench_fft, bench_speedup, bench_breakdown, bench_dispatch):
        try:
            mod.main()
        except Exception as e:  # keep the harness going
            print(f"{mod.__name__}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)


if __name__ == '__main__':
    main()
