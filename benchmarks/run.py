"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Wall times are CPU-host
times (TPU projections live in the roofline analysis; EXPERIMENTS.md).

After the CSV, a machine-readable ``BENCH_<UTC-date>.json`` summary
(name -> us_per_call, plus git rev and jax version) is written to the
current directory so the perf trajectory is trackable across PRs.
"""

import argparse
import datetime
import json
import os
import subprocess
import sys
import traceback


def _git_rev() -> str:
    try:
        r = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return r.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def write_summary(path=None, errors=None) -> str:
    """Dump the collected emit() rows as BENCH_<UTC-date>.json.

    ``errors`` (``{module_name: message}``) records benchmark modules that
    raised -- the harness keeps going, but the JSON carries the failures
    so scripts/check.sh can fail the gate loudly.
    """
    import jax
    from benchmarks import common
    now = datetime.datetime.now(datetime.timezone.utc)
    if path is None:
        path = f"BENCH_{now.strftime('%Y-%m-%d')}.json"
    payload = {
        "generated_utc": now.isoformat(timespec="seconds"),
        "git_rev": _git_rev(),
        "jax_version": jax.__version__,
        "smoke": bool(os.environ.get("REPRO_BENCH_SMOKE")),
        "us_per_call": {name: us for name, us, _ in common.ROWS},
        "derived": {name: d for name, _, d in common.ROWS if d},
        "errors": dict(errors or {}),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="one small size, one rep per bench "
                         "(same as REPRO_BENCH_SMOKE=1)")
    ap.add_argument("-o", "--out", default=None,
                    help="summary JSON path (default BENCH_<UTC-date>.json "
                         "in the current directory)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        # bounded CI runtime: plans built by the benches reuse chardb
        # corners but never one-shot time missing ones (cost-model
        # fallback instead) -- see repro.roofline.chardb
        os.environ["REPRO_CHARDB_SMOKE"] = "1"
    from benchmarks import (bench_accuracy, bench_recurrence,
                            bench_scaling_model, bench_fft, bench_speedup,
                            bench_breakdown, bench_dist_overlap,
                            bench_dispatch, bench_spin, bench_serve)
    print("name,us_per_call,derived")
    errors = {}
    for mod in (bench_accuracy, bench_recurrence, bench_scaling_model,
                bench_fft, bench_speedup, bench_breakdown,
                bench_dist_overlap, bench_dispatch, bench_spin, bench_serve):
        try:
            mod.main()
        except Exception as e:  # keep the harness going
            errors[mod.__name__] = f"{type(e).__name__}: {e}"
            print(f"{mod.__name__}/ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    path = write_summary(args.out, errors)
    print(f"# summary: {path}", file=sys.stderr)


if __name__ == '__main__':
    main()
