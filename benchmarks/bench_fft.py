"""Paper Fig. 14: FFT strategy comparison -- the phase stage head-to-head.

The paper compared CUFFT (GPU) vs MKL (CPU) and kept FFTs on the CPU.  Our
TPU-shaped analogue, through the unified plan layer: the batched
uniform-length engine (ring-uniform HEALPix grid) vs the ring-bucket
engine (true ragged HEALPix), both device-resident and jitted
(`repro.core.phase`).  Also reports the bucket structure and the padding
waste the bucketing trades for its bucket count.

Columns: name, us_per_call, derived = strategy / bucket info.
"""

import jax

import repro
from repro.core import sht
from benchmarks.common import emit, smoke, time_call

KEY = jax.random.PRNGKey(2)


def main():
    nsides = (16,) if smoke() else (32, 64, 128)
    for nside in nsides:
        l_max = 2 * nside
        alm = sht.random_alm(KEY, l_max, l_max)

        plans = {
            "batched-uniform": repro.make_plan(
                "healpix_ring", nside=nside, l_max=l_max, dtype="float64",
                mode="jnp"),
            "bucketed-ragged": repro.make_plan(
                "healpix", nside=nside, l_max=l_max, dtype="float64",
                mode="jnp"),
        }
        delta = plans["batched-uniform"]._sht._delta_from_alm(alm)

        for name, plan in plans.items():
            ph = plan.phase
            d = ph.describe()
            note = (f"n_phi={plan.grid.max_n_phi} rings={plan.grid.n_rings}"
                    if d["kind"] == "uniform" else
                    f"{d['n_buckets']} buckets "
                    f"(+{d['padded_frac'] * 100:.1f}% padding)")
            f_s = jax.jit(ph.synth)
            dt = time_call(f_s, delta, iters=1 if smoke() else 3)
            emit(f"fft/{name}-synth/nside{nside}", dt * 1e6, note)
            maps = f_s(delta)
            f_a = jax.jit(ph.anal)
            dt = time_call(f_a, maps, iters=1 if smoke() else 3)
            emit(f"fft/{name}-anal/nside{nside}", dt * 1e6, note)


if __name__ == "__main__":
    main()
