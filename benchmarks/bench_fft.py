"""Paper Fig. 14: FFT strategy comparison.

The paper compared CUFFT (GPU) vs MKL (CPU) and kept FFTs on the CPU.  Our
TPU-shaped analogue: one batched uniform-length irfft over all rings (the
production path) vs the bucketed variable-length path (true HEALPix
raggedness).  Columns: name, us_per_call, derived = strategy.
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import grids, sht
from benchmarks.common import emit, time_call

KEY = jax.random.PRNGKey(2)


def main():
    for nside in (32, 64, 128):
        l_max = 2 * nside
        alm = sht.random_alm(KEY, l_max, l_max)

        gu = grids.make_grid("healpix_ring", nside=nside)
        tu = sht.SHT(gu, l_max=l_max, m_max=l_max)
        delta = tu._delta_from_alm(alm)
        f_uni = jax.jit(tu._synth_fft_uniform)
        dt = time_call(f_uni, delta, iters=3)
        emit(f"fft/batched-uniform/nside{nside}", dt * 1e6,
             f"n_phi={gu.max_n_phi} rings={gu.n_rings}")

        gr = grids.make_grid("healpix", nside=nside)
        tr = sht.SHT(gr, l_max=l_max, m_max=l_max)
        import time as _t
        t0 = _t.perf_counter()
        tr._synth_fft_ragged(delta)
        dt_r = _t.perf_counter() - t0
        emit(f"fft/bucketed-ragged/nside{nside}", dt_r * 1e6,
             f"{len(np.unique(gr.n_phi))} buckets (host loop)")


if __name__ == "__main__":
    main()
