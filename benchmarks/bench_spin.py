"""Scalar vs spin-2 throughput: the 2x Legendre-panel cost, measured.

A spin-2 transform runs two Wigner-d recurrences per m (the lambda^{+/-}
panel pair) and moves two components (E/B alm, Q/U maps) through the same
phase stage, so the model predicts a wall-clock ratio around 2x on the
recurrence-bound sizes (docs/performance.md).  Columns: us_per_call of
each direction; derived = spin2/scalar ratio at the same signature.

Every transform goes through ``repro.make_plan(..., spin=...)``.
"""

import jax.numpy as jnp

import repro
from repro.core import sht
from benchmarks.common import emit, smoke, time_call


def main():
    sizes = ((32, 4),) if smoke() else ((64, 4), (128, 8))
    backends = (("jnp", "float64"), ("pallas_vpu", "float32"),
                ("pallas_mxu", "float32"))
    iters = 1 if smoke() else 3
    for l_max, K in sizes:
        for backend, dtype in backends:
            cdt = jnp.complex128 if dtype == "float64" else jnp.complex64
            p0 = repro.make_plan("gl", l_max=l_max, K=K, dtype=dtype,
                                 mode=backend)
            p2 = repro.make_plan("gl", l_max=l_max, K=K, dtype=dtype,
                                 mode=backend, spin=2)
            a0 = sht.random_alm(seed=0, l_max=l_max, m_max=l_max,
                                K=K).astype(cdt)
            a2 = sht.random_alm_spin(seed=0, l_max=l_max, m_max=l_max,
                                     K=K).astype(cdt)
            t0 = time_call(p0.alm2map, a0, iters=iters)
            t2 = time_call(p2.alm2map, a2, iters=iters)
            tag = f"spin/{backend}/lmax{l_max}/K{K}"
            emit(f"{tag}/synth/scalar", t0 * 1e6)
            emit(f"{tag}/synth/spin2", t2 * 1e6, f"ratio={t2 / t0:.2f}")
            m0 = p0.alm2map(a0)
            m2 = p2.alm2map(a2)
            ta0 = time_call(p0.map2alm, m0, iters=iters)
            ta2 = time_call(p2.map2alm, m2, iters=iters)
            emit(f"{tag}/anal/scalar", ta0 * 1e6)
            emit(f"{tag}/anal/spin2", ta2 * 1e6, f"ratio={ta2 / ta0:.2f}")


if __name__ == "__main__":
    main()
