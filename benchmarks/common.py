"""Shared benchmark utilities: timing, CSV emission, smoke-mode gating."""

import os
import time

import jax
import numpy as np


def smoke() -> bool:
    """True when REPRO_BENCH_SMOKE=1: one small size, one rep per bench
    (the scripts/check.sh CI gate)."""
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def time_call(fn, *args, warmup=1, iters=3, **kw):
    """Median wall time of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_pair(fn_a, fn_b, warmup=1, iters=3):
    """Paired interleaved wall times -> (median_a, median_b) seconds.

    Interpret-mode pallas wall times drift 30-40% between runs on a noisy
    host, which makes two independent `time_call` measurements useless for
    an A/B ratio.  Alternating A and B inside one loop exposes both to the
    same drift; the per-call medians stay comparable.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


def time_multi(fns, warmup=1, iters=3):
    """Group-interleaved wall times -> {key: median_seconds}.

    `time_pair` for N alternatives: ``fns`` is ``{key: callable}``; every
    iteration runs each callable once, in dict order, so all candidates see
    the same host drift and their ratios stay meaningful.  Used by the
    dist overlap bench, where ``speedup = t[baseline] / min(t.values())``
    is >= 1.0 by construction whenever the baseline is in the candidate
    set.
    """
    for _ in range(warmup):
        for fn in fns.values():
            jax.block_until_ready(fn())
    ts = {k: [] for k in fns}
    for _ in range(iters):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) for k, v in ts.items()}


#: every emit() row of the current process, collected so benchmarks/run.py
#: can write its machine-readable BENCH_<date>.json summary
ROWS: list = []


def emit(name, us_per_call, derived=""):
    ROWS.append((str(name), float(us_per_call), str(derived)))
    print(f"{name},{us_per_call:.1f},{derived}")
