"""Shared benchmark utilities: timing, CSV emission, smoke-mode gating."""

import os
import time

import jax
import numpy as np


def smoke() -> bool:
    """True when REPRO_BENCH_SMOKE=1: one small size, one rep per bench
    (the scripts/check.sh CI gate)."""
    return bool(os.environ.get("REPRO_BENCH_SMOKE"))


def time_call(fn, *args, warmup=1, iters=3, **kw):
    """Median wall time of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_pair(fn_a, fn_b, warmup=1, iters=3):
    """Paired interleaved wall times -> (median_a, median_b) seconds.

    Interpret-mode pallas wall times drift 30-40% between runs on a noisy
    host, which makes two independent `time_call` measurements useless for
    an A/B ratio.  Alternating A and B inside one loop exposes both to the
    same drift; the per-call medians stay comparable.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


#: every emit() row of the current process, collected so benchmarks/run.py
#: can write its machine-readable BENCH_<date>.json summary
ROWS: list = []


def emit(name, us_per_call, derived=""):
    ROWS.append((str(name), float(us_per_call), str(derived)))
    print(f"{name},{us_per_call:.1f},{derived}")
