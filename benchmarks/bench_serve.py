"""Sustained-load SHT serving benchmark: throughput and tail latency.

Drives `repro.serve.ShtEngine` with a mixed-signature request stream
(GL spin-0, GL spin-2, HEALPix spin-0), signatures pre-warmed so the
measurement is the steady serving state, not compile time.  Emits the
serving perf-trajectory rows validated by scripts/check.sh:

  serve/throughput/<mix>  -- mean us per request end-to-end (derived req/s
                             + coalescing factor)
  serve/p99/<mix>         -- p99 total request latency us (derived p50/p95)
  serve/coalesce/<mix>    -- mean K maps per device batch (derived
                             occupancy + plan-pool hit rate)
  serve/derr/<mix>        -- max |coalesced - independent Plan call| over
                             sampled requests (must stay at f64 precision:
                             coalescing is a pure batching transformation)

plus the phase-2 latency/throughput **frontier** over a 10:1
hot:minority tenant mix (GL spin-0 hot, GL spin-2 minority, same l_max),
the same pre-built stream replayed through both serving modes
(min-of-reps walls):

  serve/frontier/single/<mix>  -- us/req, synchronous step() pump
  serve/frontier/double/<mix>  -- us/req, double-buffered form/exec threads
  serve/frontier/speedup       -- wall(single) / wall(double)
  serve/frontier/p99/<mix>     -- p99 total latency us, double-buffered run
  serve/frontier/fair_p99_ratio -- minority-tenant p99 in the 10:1 mix /
                                   minority p99 served solo (WDRR bound)

The speedup ceiling is host-dependent: staging overlaps compute only
where compute leaves host cores free (an accelerator, or XLA CPU on a
multi-core box).  On a single-core host the honest ceiling is 1.0x and
the row demonstrates the pipeline adds no overhead; the derived string
records the visible cpu count so BENCH files are self-describing.

``REPRO_BENCH_SMOKE=1``: small sizes, few requests (the CI gate).
"""

import os
import time

import numpy as np

import repro
from repro.core import sht
from repro.serve import ShtEngine
from benchmarks.common import emit


def _cfg():
    # n_requests is a multiple of 3*max_k so every signature's queue drains
    # in full-K buckets -- the prewarmed plans -- and the latency rows
    # measure steady serving, not an in-stream remainder-bucket compile
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return dict(l_max=16, nside=4, n_requests=24, max_k=4,
                    frontier_n=22, reps=2)
    return dict(l_max=48, nside=8, n_requests=120, max_k=8,
                frontier_n=110, reps=3)


def _frontier(cfg):
    """Single-threaded vs double-buffered serving over a 10:1
    hot:minority tenant mix -- the phase-2 frontier rows."""
    l_max, max_k, n, reps = (cfg["l_max"], cfg["max_k"], cfg["frontier_n"],
                             cfg["reps"])
    label = f"hotcold10to1-lmax{l_max}-{n}req"
    hot = dict(grid="gl", l_max=l_max, dtype="float64")
    cold = dict(grid="gl", l_max=l_max, dtype="float64", spin=2)

    # every 11th request is the minority (spin-2) tenant
    stream = []
    for rid in range(n):
        if rid % 11 == 10:
            alm = np.asarray(sht.random_alm_spin(seed=rid, l_max=l_max,
                                                 m_max=l_max))[..., 0]
            stream.append(dict(direction="alm2map", payload=alm, grid="gl",
                               l_max=l_max, spin=2))
        else:
            alm = np.asarray(sht.random_alm(seed=rid, l_max=l_max,
                                            m_max=l_max))[..., 0]
            stream.append(dict(direction="alm2map", payload=alm, grid="gl",
                               l_max=l_max))
    solo = [r for r in stream if r.get("spin")]
    assert solo, "stream carries no minority requests"

    def _engine():
        eng = ShtEngine(max_k=max_k, max_queue=4 * n, mode="jnp",
                        p99_target_s=60.0)       # bounded-but-generous
        eng.prewarm(**hot)
        eng.prewarm(**cold)
        return eng

    def _replay(requests, background):
        eng = _engine()
        t0 = time.perf_counter()
        if background:
            with eng:                            # form/exec thread pair
                futs = [eng.submit(**r) for r in requests]
                eng.drain()
        else:
            futs = [eng.submit(**r) for r in requests]
            eng.drain()                          # inline step() pump
        wall = time.perf_counter() - t0
        s = eng.stats()
        assert s["requests"]["completed"] == len(requests), s["requests"]
        mino = [f.timing["total_s"] for r, f in zip(requests, futs)
                if r.get("spin")]
        return dict(wall=wall, p99=s["latency"]["total"]["p99_s"],
                    p50=s["latency"]["total"]["p50_s"],
                    mino_max=max(mino) if mino else float("nan"))

    # min-of-reps: same stream, fresh engine per rep (warm global plans)
    single = min((_replay(stream, background=False) for _ in range(reps)),
                 key=lambda r: r["wall"])
    double = min((_replay(stream, background=True) for _ in range(reps)),
                 key=lambda r: r["wall"])
    solo_run = min((_replay(solo, background=True) for _ in range(reps)),
                   key=lambda r: r["wall"])

    emit(f"serve/frontier/single/{label}", single["wall"] / n * 1e6,
         f"{n / single['wall']:.1f} req/s p99={single['p99'] * 1e6:.0f}us")
    emit(f"serve/frontier/double/{label}", double["wall"] / n * 1e6,
         f"{n / double['wall']:.1f} req/s p99={double['p99'] * 1e6:.0f}us")
    emit("serve/frontier/speedup", single["wall"] / double["wall"],
         f"double-buffered wall {double['wall'] * 1e3:.1f}ms vs "
         f"single {single['wall'] * 1e3:.1f}ms ({os.cpu_count()} cpu)")
    emit(f"serve/frontier/p99/{label}", double["p99"] * 1e6,
         f"p50={double['p50'] * 1e6:.0f}us")
    # fairness: the minority tenant's worst latency in the 10:1 mix vs
    # served alone (WDRR keeps the ratio bounded; oldest-head-wins put
    # the whole hot backlog in front of it)
    ratio = double["mino_max"] / solo_run["mino_max"]
    emit("serve/frontier/fair_p99_ratio", ratio,
         f"mixed {double['mino_max'] * 1e6:.0f}us vs solo "
         f"{solo_run['mino_max'] * 1e6:.0f}us")


def main():
    cfg = _cfg()
    l_max, nside = cfg["l_max"], cfg["nside"]
    n, max_k = cfg["n_requests"], cfg["max_k"]
    label = f"mixed-lmax{l_max}-{n}req"

    eng = ShtEngine(max_k=max_k, max_queue=4 * n, mode="jnp")
    eng.prewarm(grid="gl", l_max=l_max, dtype="float64")
    eng.prewarm(grid="gl", l_max=l_max, dtype="float64", spin=2)
    eng.prewarm(grid="healpix", nside=nside, dtype="float64")

    # pre-generate the request stream (payload build must not pollute the
    # serving measurement) + the independent-plan references for a sample
    hp = repro.make_plan("healpix", nside=nside, K=1, dtype="float64",
                         mode="jnp")
    stream, refs = [], {}
    for rid in range(n):
        kind = rid % 3
        if kind == 0:
            alm = np.asarray(sht.random_alm(seed=rid, l_max=l_max,
                                            m_max=l_max))[..., 0]
            stream.append(dict(direction="alm2map", payload=alm, grid="gl",
                               l_max=l_max))
        elif kind == 1:
            alm = np.asarray(sht.random_alm_spin(seed=rid, l_max=l_max,
                                                 m_max=l_max))[..., 0]
            stream.append(dict(direction="alm2map", payload=alm, grid="gl",
                               l_max=l_max, spin=2))
        else:
            alm = np.asarray(sht.random_alm(seed=rid, l_max=hp.l_max,
                                            m_max=hp.m_max))[..., 0]
            stream.append(dict(direction="alm2map", payload=alm,
                               grid="healpix", nside=nside))
        if rid < 3:                       # one reference per signature kind
            plan = repro.make_plan(
                stream[-1]["grid"], stream[-1].get("l_max"),
                nside=stream[-1].get("nside"), K=1, dtype="float64",
                mode="jnp", spin=stream[-1].get("spin", 0))
            refs[rid] = np.asarray(plan.alm2map(alm[..., None]))[..., 0]

    t0 = time.perf_counter()
    futs = [eng.submit(**req) for req in stream]
    eng.drain()
    wall = time.perf_counter() - t0

    done = eng.stats()
    assert done["requests"]["completed"] == n, done["requests"]
    worst = max(float(np.max(np.abs(futs[rid].result() - ref)))
                for rid, ref in refs.items())
    assert worst < 1e-12, f"coalesced serving diverged: {worst}"

    lat, co, pool = (done["latency"]["total"], done["coalescing"],
                     done["pool"])
    emit(f"serve/throughput/{label}", wall / n * 1e6,
         f"{done['throughput_rps']:.1f} req/s coalesce "
         f"x{co['requests_per_batch']:.2f}")
    emit(f"serve/p99/{label}", lat["p99_s"] * 1e6,
         f"p50={lat['p50_s'] * 1e6:.0f}us p95={lat['p95_s'] * 1e6:.0f}us")
    emit(f"serve/coalesce/{label}", co["k_per_batch"],
         f"occupancy {co['k_occupancy']:.2f} pool_hit_rate "
         f"{pool['hit_rate']:.2f}")
    emit(f"serve/derr/{label}", 0.0, f"{worst:.2e}")

    _frontier(cfg)


if __name__ == "__main__":
    main()
