import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import grids, sht, spectra


KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("l_max,K", [(15, 1), (48, 3), (100, 1)])
def test_gl_roundtrip_exact(l_max, K):
    """Paper §5 methodology on the exact-quadrature grid: D_err at machine
    precision isolates implementation error from grid aliasing."""
    t = sht.SHT(grids.make_grid("gl", l_max=l_max), l_max=l_max, m_max=l_max)
    alm = sht.random_alm(KEY, l_max, l_max, K=K)
    out = t.map2alm(t.alm2map(alm))
    assert spectra.d_err(alm, out) < 1e-12


@pytest.mark.parametrize("fold", [False, True])
def test_fold_equivalence(fold):
    l_max = 40
    g = grids.make_grid("gl", l_max=l_max)
    t0 = sht.SHT(g, l_max=l_max, m_max=l_max, fold=False)
    t1 = sht.SHT(g, l_max=l_max, m_max=l_max, fold=fold)
    alm = sht.random_alm(KEY, l_max, l_max)
    m0, m1 = np.asarray(t0.alm2map(alm)), np.asarray(t1.alm2map(alm))
    assert np.max(np.abs(m0 - m1)) < 1e-11
    a0 = np.asarray(t0.map2alm(jnp.asarray(m0)))
    a1 = np.asarray(t1.map2alm(jnp.asarray(m0)))
    assert np.max(np.abs(a0 - a1)) < 1e-12


def test_healpix_ring_error_behaviour():
    """Approximate quadrature: error grows as l_max approaches the sampling
    limit 2*nside (the paper's Fig. 8 aliasing behaviour)."""
    nside = 16
    errs = {}
    for l_max in (8, 16, 32):
        g = grids.make_grid("healpix_ring", nside=nside)
        t = sht.SHT(g, l_max=l_max, m_max=l_max)
        alm = sht.random_alm(KEY, l_max, l_max)
        errs[l_max] = spectra.d_err(alm, t.map2alm(t.alm2map(alm)))
    assert errs[8] < errs[32]
    assert errs[32] < 0.05                # still a usable transform


def test_iterative_analysis_refinement():
    """Jacobi refinement (HEALPix map2alm_iter) cuts the approximate-
    quadrature error by ~an order of magnitude per iteration."""
    nside, l_max = 16, 24
    g = grids.make_grid("healpix_ring", nside=nside)
    t = sht.SHT(g, l_max=l_max, m_max=l_max)
    alm = sht.random_alm(KEY, l_max, l_max)
    maps = t.alm2map(alm)
    e0 = spectra.d_err(alm, t.map2alm(maps, iters=0))
    e1 = spectra.d_err(alm, t.map2alm(maps, iters=1))
    e2 = spectra.d_err(alm, t.map2alm(maps, iters=2))
    assert e1 < e0 / 3
    assert e2 < e1


def test_true_healpix_vs_ring_uniform():
    """The ragged CPU path and the ring-uniform TPU variant agree in
    harmonic space to quadrature accuracy."""
    nside, l_max = 8, 12
    alm = sht.random_alm(KEY, l_max, l_max)
    th = sht.SHT(grids.make_grid("healpix", nside=nside), l_max=l_max,
                 m_max=l_max)
    tr = sht.SHT(grids.make_grid("healpix_ring", nside=nside), l_max=l_max,
                 m_max=l_max)
    ah = np.asarray(th.map2alm(th.alm2map(alm)))
    ar = np.asarray(tr.map2alm(tr.alm2map(alm)))
    # both approximate the identity; they agree with each other much better
    # than either matches the input
    assert spectra.d_err(ah, ar) < 2 * spectra.d_err(np.asarray(alm), ah)


def test_f32_engine_error_bounded():
    l_max = 48
    g = grids.make_grid("gl", l_max=l_max)
    t64 = sht.SHT(g, l_max=l_max, m_max=l_max)
    t32 = sht.SHT(g, l_max=l_max, m_max=l_max, dtype="float32")
    alm = sht.random_alm(KEY, l_max, l_max)
    m64 = np.asarray(t64.alm2map(alm))
    m32 = np.asarray(t32.alm2map(alm.astype(jnp.complex64)))
    rel = np.max(np.abs(m64 - m32)) / np.max(np.abs(m64))
    assert rel < 5e-5                      # f32 recurrence accumulation


def test_parseval_consistency():
    """Power is preserved by synthesis on the exact grid (Parseval)."""
    l_max = 32
    g = grids.make_grid("gl", l_max=l_max)
    t = sht.SHT(g, l_max=l_max, m_max=l_max)
    cl = spectra.cmb_like_cl(l_max)
    alm = spectra.alm_from_cl(KEY, cl)
    maps = np.asarray(t.alm2map(alm))
    w = (g.weights[:, None] * np.ones((1, g.max_n_phi))).ravel()
    power_map = float((maps[..., 0].ravel() ** 2) @ w)
    p = np.abs(np.asarray(alm[..., 0])) ** 2
    power_alm = float(p[0].sum() + 2 * p[1:].sum())
    assert abs(power_map - power_alm) < 1e-8 * max(power_alm, 1e-30)


def test_spectra_estimator():
    l_max = 24
    cl = spectra.cmb_like_cl(l_max)
    alm = spectra.alm_from_cl(KEY, cl, K=64)
    est = np.asarray(spectra.cl_from_alm(alm)).mean(axis=-1)
    # statistical agreement over 64 realisations: ~ sqrt(2/(2l+1)/64)
    l = np.arange(2, l_max + 1)
    rel = np.abs(est[2:] - cl[2:]) / cl[2:]
    assert np.all(rel < 6 * np.sqrt(2.0 / (2 * l + 1) / 64))
