import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import grids


def test_gl_nodes_match_numpy():
    for n in (4, 17, 64, 129):
        x, w = grids._gauss_legendre_nodes(n)
        xr, wr = np.polynomial.legendre.leggauss(n)
        assert np.allclose(np.sort(x), np.sort(xr), atol=1e-14)
        assert np.allclose(np.sort(w), np.sort(wr), atol=1e-13)


@pytest.mark.parametrize("kind,kw", [
    ("gl", dict(l_max=32)),
    ("ecp", dict(l_max=32)),
    ("healpix_ring", dict(nside=8)),
    ("healpix", dict(nside=8)),
])
def test_grid_invariants(kind, kw):
    g = grids.make_grid(kind, **kw)
    g.validate()
    # weights integrate the constant function exactly: sum w = 4 pi
    assert abs(g.weights @ g.n_phi - 4 * np.pi) < 1e-8
    assert g.equator_symmetric


def test_healpix_counts():
    for nside in (1, 2, 4, 16):
        g = grids.make_grid("healpix", nside=nside)
        assert g.n_pix == 12 * nside * nside
        assert g.n_rings == 4 * nside - 1
        assert g.max_n_phi == 4 * nside


def test_healpix_ring_uniform_matches_latitudes():
    hp = grids.make_grid("healpix", nside=8)
    hpr = grids.make_grid("healpix_ring", nside=8)
    assert np.allclose(hp.cos_theta, hpr.cos_theta)
    # per-ring areas identical
    assert np.allclose(hp.ring_areas(), hpr.ring_areas())


def test_ecp_band_areas_exact():
    """ECP per-ring weights are exact latitude-band areas (sum to 4 pi
    exactly) and the grid is uniform + equator-symmetric (fold-eligible)."""
    g = grids.make_grid("ecp", l_max=16)
    assert g.uniform and g.equator_symmetric
    assert g.n_rings == 2 * 17 and g.max_n_phi == 34
    np.testing.assert_allclose(g.weights @ g.n_phi, 4 * np.pi, rtol=1e-14)
    # band areas: 2 pi (cos edge_i - cos edge_{i+1})
    edge = np.cos(np.arange(g.n_rings + 1) * np.pi / g.n_rings)
    np.testing.assert_allclose(g.ring_areas(),
                               2 * np.pi * (edge[:-1] - edge[1:]))


def test_ecp_plan_roundtrip_with_refinement():
    """ECP quadrature is approximate; one Jacobi pass pushes the
    round-trip error down like on HEALPix."""
    from repro.core import sht, spectra
    plan = repro.make_plan("ecp", l_max=12, dtype="float64", mode="jnp")
    alm = sht.random_alm(seed=0, l_max=12, m_max=12)
    maps = plan.alm2map(alm)
    e0 = spectra.d_err(alm, plan.map2alm(maps))
    e1 = spectra.d_err(alm, plan.map2alm(maps, iters=2))
    assert e1 < e0 and e1 < 5e-3, (e0, e1)


def test_gl_quadrature_exactness():
    # GL with n rings integrates polynomials up to degree 2n-1 exactly
    g = grids.make_grid("gl", l_max=16)  # 17 rings
    x = g.cos_theta
    w = g.weights * g.n_phi / (2 * np.pi)  # theta-quadrature weights
    for deg in (0, 5, 20, 33):
        est = w @ (x ** deg)
        exact = 0.0 if deg % 2 else 2.0 / (deg + 1)
        assert abs(est - exact) < 1e-12, deg
