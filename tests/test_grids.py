import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import grids


def test_gl_nodes_match_numpy():
    for n in (4, 17, 64, 129):
        x, w = grids._gauss_legendre_nodes(n)
        xr, wr = np.polynomial.legendre.leggauss(n)
        assert np.allclose(np.sort(x), np.sort(xr), atol=1e-14)
        assert np.allclose(np.sort(w), np.sort(wr), atol=1e-13)


@pytest.mark.parametrize("kind,kw", [
    ("gl", dict(l_max=32)),
    ("healpix_ring", dict(nside=8)),
    ("healpix", dict(nside=8)),
])
def test_grid_invariants(kind, kw):
    g = grids.make_grid(kind, **kw)
    g.validate()
    # weights integrate the constant function exactly: sum w = 4 pi
    assert abs(g.weights @ g.n_phi - 4 * np.pi) < 1e-8
    assert g.equator_symmetric


def test_healpix_counts():
    for nside in (1, 2, 4, 16):
        g = grids.make_grid("healpix", nside=nside)
        assert g.n_pix == 12 * nside * nside
        assert g.n_rings == 4 * nside - 1
        assert g.max_n_phi == 4 * nside


def test_healpix_ring_uniform_matches_latitudes():
    hp = grids.make_grid("healpix", nside=8)
    hpr = grids.make_grid("healpix_ring", nside=8)
    assert np.allclose(hp.cos_theta, hpr.cos_theta)
    # per-ring areas identical
    assert np.allclose(hp.ring_areas(), hpr.ring_areas())


def test_gl_quadrature_exactness():
    # GL with n rings integrates polynomials up to degree 2n-1 exactly
    g = grids.make_grid("gl", l_max=16)  # 17 rings
    x = g.cos_theta
    w = g.weights * g.n_phi / (2 * np.pi)  # theta-quadrature weights
    for deg in (0, 5, 20, 33):
        est = w @ (x ** deg)
        exact = 0.0 if deg % 2 else 2.0 / (deg + 1)
        assert abs(est - exact) < 1e-12, deg
