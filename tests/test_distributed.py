"""Multi-device equivalence tests (subprocess: 8 host-platform devices;
this process stays single-device per the dry-run isolation rule)."""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(helper):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "tests", "helpers", helper)],
        capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"{helper} failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_dist_sht_matches_serial():
    out = _run("dist_sht_check.py")
    assert out.count("OK") == 11   # incl. the 2 shard_map gradcheck lines


def test_moe_expert_parallel_matches_local():
    out = _run("moe_dist_check.py")
    assert "a2a_err" in out


def test_ulysses_attention_matches_mea():
    out = _run("ulysses_check.py")
    assert "ulysses_err" in out
