"""Multi-device equivalence tests (subprocess: 8 host-platform devices;
this process stays single-device per the dry-run isolation rule)."""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(helper):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "tests", "helpers", helper)],
        capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, f"{helper} failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


def test_dist_sht_matches_serial():
    out = _run("dist_sht_check.py")
    assert out.count("OK") == 11   # incl. the 2 shard_map gradcheck lines


def test_dist_chunked_exchange_matches_monolithic():
    # chunked pipelined all_to_all (C=2,4) vs the monolithic C=1 path:
    # bit-identical synthesis, <1e-12 analysis, spin 0 + spin 2, K-axis
    # and m-axis schedules, grad through the chunked pipeline, and the
    # fail-fast mesh ValueError (4 simulated devices).
    out = _run("dist_chunk_check.py")
    assert out.count("OK") == 10
    assert "bit-identical=True" in out


def test_moe_expert_parallel_matches_local():
    out = _run("moe_dist_check.py")
    assert "a2a_err" in out


def test_ulysses_attention_matches_mea():
    out = _run("ulysses_check.py")
    assert "ulysses_err" in out
