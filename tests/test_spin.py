"""Spin-2 (polarisation) transform correctness.

Layers under test:
  * the generalised Wigner-d recurrence (`legendre.delta_from_alm_general`)
    against an explicit textbook Wigner-d sum oracle -- this is the only
    test class that can catch per-(l, m) normalisation/sign errors (the
    round-trip is blind to them: synthesis and analysis share the lambda
    code, so any row scaling cancels);
  * spin-2 round-trips at machine precision on the exact grid, the pure-E
    -> zero-B null test, per-backend error thresholds vs the same
    backend's scalar error (the 10x acceptance band), iters-monotone on
    HEALPix;
  * the spin plan plumbing (signature, describe, cost model) and the
    random_alm key/seed hardening.

The distributed spin path is covered by tests/helpers/dist_sht_check.py
(subprocess, 8 host devices) via tests/test_distributed.py.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import grids, legendre, sht, spectra

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------------------------------
# Wigner-d oracle
# ---------------------------------------------------------------------------


def wigner_d(j, m, mp, beta):
    """Explicit Wigner d^j_{m,mp}(beta) sum formula (z-y-z convention,
    matching the standard d^2 table)."""
    f = math.factorial
    m, mp = mp, m          # the sum below is the transposed-index variant
    pref = math.sqrt(f(j + m) * f(j - m) * f(j + mp) * f(j - mp))
    c, s = math.cos(beta / 2.0), math.sin(beta / 2.0)
    tot = 0.0
    for k in range(max(0, m - mp), min(j + m, j - mp) + 1):
        denom = f(j + m - k) * f(k) * f(j - k - mp) * f(k - m + mp)
        tot += ((-1) ** (k - m + mp) / denom
                * c ** (2 * j - 2 * k + m - mp) * s ** (2 * k - m + mp))
    return pref * tot


def test_wigner_sum_matches_d2_table():
    for beta in (0.3, 1.1, 2.5):
        x = math.cos(beta)
        assert abs(wigner_d(2, 2, 2, beta) - ((1 + x) / 2) ** 2) < 1e-12
        assert abs(wigner_d(2, 2, 1, beta)
                   + 0.5 * math.sin(beta) * (1 + x)) < 1e-12
        assert abs(wigner_d(2, 0, 2, beta)
                   - math.sqrt(6) / 4 * math.sin(beta) ** 2) < 1e-12
        assert abs(wigner_d(2, 0, 0, beta) - 0.5 * (3 * x * x - 1)) < 1e-12


@pytest.mark.parametrize("m", [0, 1, 2, 3, 6])
@pytest.mark.parametrize("mp", [-2, 2, 0])
def test_lambda_recurrence_matches_wigner_oracle(m, mp):
    """lam^{(m')}_lm = (-1)^m sqrt((2l+1)/4pi) d^l_{m,m'} for every l, ring."""
    l_max = 8
    g = grids.make_grid("gl", l_max=l_max)
    thetas = np.arccos(g.cos_theta)
    ls = list(range(l_max + 1))
    a_re = np.zeros((1, l_max + 1, len(ls)))
    for j, l in enumerate(ls):
        a_re[0, l, j] = 1.0      # impulse per l in the K channel
    d_re, _ = legendre.delta_from_alm_general(
        a_re, np.zeros_like(a_re), [m], [mp], g.cos_theta, g.sin_theta,
        l_max=l_max, dtype=jnp.float64)
    got = np.asarray(d_re)[0]    # (R, K): got[r, j] = lam_{l_j, m}(theta_r)
    for j, l in enumerate(ls):
        for r, th in enumerate(thetas):
            if l < max(m, abs(mp)):
                want = 0.0
            else:
                want = ((-1) ** m * math.sqrt((2 * l + 1) / (4 * math.pi))
                        * wigner_d(l, m, mp, th))
            assert abs(got[r, j] - want) < 1e-11 * max(1.0, abs(want)), \
                (l, m, mp, r)


# ---------------------------------------------------------------------------
# round-trips / null tests (serial engine)
# ---------------------------------------------------------------------------


def test_spin2_gl_roundtrip_machine_precision():
    l_max, K = 32, 2
    g = grids.make_grid("gl", l_max=l_max)
    t = sht.SHT(g, l_max=l_max, m_max=l_max)
    alm = sht.random_alm_spin(KEY, l_max, l_max, K=K)
    out = t.map2alm_spin(t.alm2map_spin(alm))
    assert spectra.d_err(alm, out) < 1e-12


def test_pure_e_zero_b_null():
    """Pure-E alm synthesise Q/U that analyse back with zero B leakage."""
    l_max = 24
    g = grids.make_grid("gl", l_max=l_max)
    t = sht.SHT(g, l_max=l_max, m_max=l_max)
    alm = sht.random_alm_spin(KEY, l_max, l_max).at[1].set(0.0)
    back = t.map2alm_spin(t.alm2map_spin(alm))
    e_scale = float(np.max(np.abs(np.asarray(alm[0]))))
    assert float(np.max(np.abs(np.asarray(back[1])))) < 1e-13 * e_scale
    # and the E channel itself is recovered
    assert spectra.d_err(alm[0], back[0]) < 1e-12


def test_spin2_fold_rejected():
    g = grids.make_grid("gl", l_max=8)
    t = sht.SHT(g, l_max=8, m_max=8, fold=True)
    alm = sht.random_alm_spin(KEY, 8, 8)
    with pytest.raises(AssertionError):
        t.alm2map_spin(alm)
    with pytest.raises(ValueError):
        repro.make_plan("gl", l_max=8, fold=True, spin=2)
    with pytest.raises(ValueError):
        repro.make_plan("gl", l_max=8, spin=1)


# ---------------------------------------------------------------------------
# plan-level: every backend within 10x of its own scalar error
# ---------------------------------------------------------------------------


def _plan_roundtrip_err(grid_kw, backend, dtype, spin, key):
    p = repro.make_plan(dtype=dtype, mode=backend, K=2, spin=spin, **grid_kw)
    if spin == 0:
        alm = sht.random_alm(key, p.l_max, p.m_max, K=2)
    else:
        alm = sht.random_alm_spin(key, p.l_max, p.m_max, K=2)
    if dtype == "float32":
        alm = alm.astype(jnp.complex64)
    return spectra.d_err(alm, p.map2alm(p.alm2map(alm)))


@pytest.mark.parametrize("grid_kw", [
    {"grid": "gl", "l_max": 24},
    {"grid": "healpix", "nside": 8, "l_max": 16},
], ids=["gl", "healpix"])
@pytest.mark.parametrize("backend,dtype", [
    ("jnp", "float64"), ("pallas_vpu", "float32"), ("pallas_mxu", "float32"),
])
def test_spin_backends_within_10x_of_scalar(grid_kw, backend, dtype):
    err_s = _plan_roundtrip_err(grid_kw, backend, dtype, 2, KEY)
    err_0 = _plan_roundtrip_err(grid_kw, backend, dtype, 0, KEY)
    assert err_s < 10 * err_0 + 1e-12, (err_s, err_0)


def test_spin_iters_monotone_on_healpix():
    p = repro.make_plan("healpix", nside=8, dtype="float64", mode="jnp",
                        spin=2)
    alm = sht.random_alm_spin(KEY, p.l_max, p.m_max, K=1)
    maps = p.alm2map(alm)
    errs = [spectra.d_err(alm, p.map2alm(maps, iters=i)) for i in range(3)]
    assert errs[1] < errs[0] / 3
    assert errs[2] < errs[1]


# ---------------------------------------------------------------------------
# plan plumbing / cost model / spectra helpers / random_alm hardening
# ---------------------------------------------------------------------------


def test_spin_plan_signature_and_describe():
    p0 = repro.make_plan("gl", l_max=16, dtype="float64", mode="jnp")
    p2 = repro.make_plan("gl", l_max=16, dtype="float64", mode="jnp", spin=2)
    assert p0 is not p2
    d = p2.describe()
    assert d["signature"]["spin"] == 2
    w0, w2 = p0.describe()["work"], d["work"]
    assert w2["recurrence_flops"] == 2 * w0["recurrence_flops"]
    assert w2["accum_flops"] == 2 * w0["accum_flops"]
    assert "spin=2" in p2.report()
    # shape validation is pair-aware
    with pytest.raises(AssertionError):
        p2.alm2map(jnp.zeros((17, 17, 1), jnp.complex128))


def test_spectra_pol_helpers():
    l_max = 24
    cls = spectra.cmb_like_cl_pol(l_max)
    assert np.all(np.abs(cls["te"]) <= np.sqrt(cls["tt"] * cls["ee"]) + 1e-15)
    alm = spectra.alm_from_cl_pol(KEY, cls, K=256)
    for i, name in enumerate(("tt", "ee", "bb")):
        est = np.asarray(spectra.cl_from_alm(alm[i])).mean(-1)
        good = cls[name][2:] > 0
        rel = np.abs(est[2:][good] - cls[name][2:][good]) / cls[name][2:][good]
        assert np.median(rel) < 0.2, name
    te = np.asarray(spectra.cl_cross_from_alm(alm[0], alm[1])).mean(-1)
    scale = np.sqrt(cls["tt"][2:] * cls["ee"][2:])
    assert np.median(np.abs(te[2:] - cls["te"][2:]) / scale) < 0.2
    # E/B start at l = 2
    assert np.all(np.asarray(alm[1])[:, :2] == 0)


def test_random_alm_requires_key_or_seed():
    with pytest.raises(ValueError):
        sht.random_alm(None, 4, 4)
    with pytest.raises(ValueError):
        sht.random_alm(KEY, 4, 4, seed=0)
    with pytest.raises(ValueError):
        sht.random_alm_spin(l_max=4, m_max=4)
    a1 = sht.random_alm(seed=7, l_max=4, m_max=4)
    a2 = sht.random_alm(seed=7, l_max=4, m_max=4)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
