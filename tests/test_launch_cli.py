"""CLI smoke tests: the launch drivers run end to end (reduced widths)."""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=_ROOT)
    assert r.returncode == 0, f"{args} failed:\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}"
    return r.stdout


def test_train_cli_smoke(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "qwen2-0.5b",
                "--smoke", "--steps", "6", "--global-batch", "2",
                "--seq", "32", "--ckpt", str(tmp_path), "--ckpt-every", "3"])
    assert "training complete" in out
    assert any(d.startswith("step_") for d in os.listdir(tmp_path))


def test_serve_cli_smoke():
    out = _run(["-m", "repro.launch.serve", "--smoke", "--requests", "4"])
    assert "completed 4/4" in out
    assert "coalescing" in out


def test_quickstart_example():
    out = _run(["examples/quickstart.py", "--lmax", "32"])
    assert "D_err" in out
