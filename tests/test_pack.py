"""Triangular m-pair packing: layout invariants, packed-vs-plain kernel
equality (all four variants, fold and spin rows, padding edges), the
packed-schedule ref oracle, and the layout/variant selection knobs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import grids, legendre, sht
from repro.kernels import ops as kops
from repro.kernels import pack as kpack
from repro.kernels import ref as kref
from repro.roofline import analysis as roofline

KEY = jax.random.PRNGKey(7)
LP = 16                     # small panels so tiny problems span >1 panel


def _setup(l_max, K, m_vals=None):
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    m_vals = np.arange(l_max + 1) if m_vals is None else np.asarray(m_vals)
    alm = sht.random_alm(KEY, l_max, l_max, K=K)
    a_re = np.real(np.asarray(alm))[m_vals.clip(0)]
    a_im = np.imag(np.asarray(alm))[m_vals.clip(0)]
    a32 = jnp.concatenate([jnp.asarray(a_re), jnp.asarray(a_im)],
                          axis=-1).astype(jnp.float32)
    pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
    x32 = jnp.asarray(g.cos_theta, jnp.float32)
    return g, lm, m_vals, a32, pmm, pms, x32


# ---------------------------------------------------------------------------
# layout invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_max,l_max", [
    (24, 24),      # even m_max: odd row count -> unpaired middle m
    (23, 23),      # odd m_max: every row paired
    (6, 40),       # m_max < lp_size
    (7, 40),
    (15, 15),      # L1 exactly lp_size
])
def test_layout_covers_triangle_exactly(m_max, l_max):
    m = np.arange(m_max + 1)
    lo = kpack.build_layout(m, l_max, lp_size=LP)
    got = set()
    row, l = lo.a_row, lo.a_l
    for s in range(lo.n_slots):
        for g in range(lo.S):
            if row[s, g] >= 0:
                key = (int(row[s, g]), int(l[s, g]))
                assert key not in got, f"duplicate stream position {key}"
                got.add(key)
    want = {(mm, ll) for mm in m for ll in range(mm, l_max + 1)}
    assert got == want
    # min-max pairing: full pair slots carry the invariant total length
    seg_valid = lo.slot_row >= 0
    lens = np.where(seg_valid,
                    l_max + 1 - np.maximum(lo.slot_m, np.abs(lo.slot_mp)), 0)
    pair_tot = lens.sum(axis=1)[seg_valid[:, 1]]
    if pair_tot.size:
        assert np.all(pair_tot == 2 * l_max - m_max + 2)
    # unpaired middle m present iff the row count is odd
    assert (np.count_nonzero(~seg_valid[:, 1]) == 1) == (m_max % 2 == 0)


def test_layout_skips_padding_rows_and_counts():
    m = np.array([0, 5, -1, 17, -1])
    lo = kpack.build_layout(m, 20, lp_size=LP)
    assert set(lo.slot_row[lo.slot_row >= 0].tolist()) == {0, 1, 3}
    c = kpack.panel_counts(m, 20, lp_size=LP)
    assert c["packed"] == lo.n_panels
    assert c["ideal_steps"] == (21 - 0) + (21 - 5) + (21 - 17)
    # all-padding row sets cannot pack
    assert kpack.build_layout(np.array([-1, -1]), 20, lp_size=LP) is None


def test_roofline_panel_counts_match_pack():
    for l_max, spin in ((127, 0), (128, 0), (64, 2)):
        c = roofline.legendre_panel_counts(l_max, l_max, spin=spin)
        m = np.arange(l_max + 1)
        if spin:
            m2 = np.concatenate([m, m])
            mp2 = np.concatenate([np.full(l_max + 1, -2),
                                  np.full(l_max + 1, 2)])
            want = kpack.panel_counts(m2, l_max, mp_vals=mp2)
        else:
            want = kpack.panel_counts(m, l_max)
        assert c == want
    # the acceptance numbers: ~2x fewer grid steps at l_max = 512
    c = roofline.legendre_panel_counts(512, 512)
    assert c["plain_launched"] == 2565 and c["packed"] == 1285
    assert c["launched_ratio"] >= 1.5
    assert "panels" in roofline.sht_work(64, 64, 65, 130, 1)


# ---------------------------------------------------------------------------
# packed-vs-plain kernel equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("l_max,K", [(15, 1), (24, 2)])
@pytest.mark.parametrize("variant", ["vpu", "mxu"])
@pytest.mark.parametrize("fold", [False, True])
def test_synth_packed_vs_plain(l_max, K, variant, fold):
    g, lm, m_vals, a32, pmm, pms, x32 = _setup(l_max, K)
    nh = (g.n_rings + 1) // 2
    xs = jnp.asarray(g.cos_theta[:nh] if fold else g.cos_theta, jnp.float32)
    sins = g.sin_theta[:nh] if fold else g.sin_theta
    pmm_f, pms_f = kref.prepare_seeds(m_vals, sins, lm)
    plain = kops.synth(a32, m_vals, xs, pmm_f, pms_f, l_max=l_max,
                       fold=fold, variant=variant, layout="plain")
    packed = kops.synth(a32, m_vals, xs, pmm_f, pms_f, l_max=l_max,
                        fold=fold, variant=variant, layout="packed",
                        lp_size=LP)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(plain),
                               rtol=0, atol=2e-6)


@pytest.mark.parametrize("l_max,K", [(15, 1), (24, 2)])
@pytest.mark.parametrize("variant", ["vpu", "mxu"])
@pytest.mark.parametrize("fold", [False, True])
def test_anal_packed_vs_plain(l_max, K, variant, fold):
    g, lm, m_vals, a32, pmm, pms, x32 = _setup(l_max, K)
    rng = np.random.default_rng(1)
    nh = (g.n_rings + 1) // 2
    R = nh if fold else g.n_rings
    n_par = 2 if fold else 1
    xs = jnp.asarray(g.cos_theta[:R], jnp.float32)
    pmm_f, pms_f = kref.prepare_seeds(m_vals, g.sin_theta[:R], lm)
    dw = jnp.asarray(rng.normal(size=(len(m_vals), n_par, R, 2 * K)),
                     jnp.float32)
    plain = kops.anal(dw, m_vals, xs, pmm_f, pms_f, l_max=l_max, fold=fold,
                      variant=variant, layout="plain")
    packed = kops.anal(dw, m_vals, xs, pmm_f, pms_f, l_max=l_max, fold=fold,
                       variant=variant, layout="packed", lp_size=LP)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(plain),
                               rtol=0, atol=5e-5)


@pytest.mark.parametrize("variant", ["vpu", "mxu"])
def test_spin_rows_packed_vs_plain(variant):
    l_max, K = 24, 1
    g, lm, m_vals, a32, pmm, pms, x32 = _setup(l_max, K)
    m2, mp2 = kops.spin_rows(m_vals)
    pmm_s, pms_s = kref.prepare_seeds_spin(m2, mp2, g.cos_theta,
                                           g.sin_theta, m_max=l_max)
    a2 = jnp.concatenate([a32, a32], axis=0)
    plain = kops.synth(a2, m2, x32, pmm_s, pms_s, l_max=l_max,
                       variant=variant, mp_vals=mp2, layout="plain")
    packed = kops.synth(a2, m2, x32, pmm_s, pms_s, l_max=l_max,
                        variant=variant, mp_vals=mp2, layout="packed",
                        lp_size=LP)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(plain),
                               rtol=0, atol=2e-6)
    rng = np.random.default_rng(2)
    dw = jnp.asarray(rng.normal(size=(len(m2), 1, g.n_rings, 2 * K)),
                     jnp.float32)
    plain = kops.anal(dw, m2, x32, pmm_s, pms_s, l_max=l_max,
                      variant=variant, mp_vals=mp2, layout="plain")
    packed = kops.anal(dw, m2, x32, pmm_s, pms_s, l_max=l_max,
                       variant=variant, mp_vals=mp2, layout="packed",
                       lp_size=LP)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(plain),
                               rtol=0, atol=5e-5)


def test_packed_padding_rows_are_zero():
    l_max = 20
    m_vals = np.array([0, 5, -1, 17, -1])
    g, lm, m_vals, a32, pmm, pms, x32 = _setup(l_max, 1, m_vals)
    got = np.asarray(kops.synth(a32, m_vals, x32, pmm, pms, l_max=l_max,
                                variant="vpu", layout="packed", lp_size=LP))
    assert np.all(got[2] == 0.0) and np.all(got[4] == 0.0)
    assert np.any(got[1] != 0.0)
    dw = jnp.ones((len(m_vals), 1, g.n_rings, 2), jnp.float32)
    out = np.asarray(kops.anal(dw, m_vals, x32, pmm, pms, l_max=l_max,
                               variant="mxu", layout="packed", lp_size=LP))
    assert np.all(out[2] == 0.0) and np.all(out[4] == 0.0)
    # sub-diagonal rows (l < m) stay exactly zero after unpack
    assert np.all(out[3, :17] == 0.0) and np.any(out[3, 17:] != 0.0)


# ---------------------------------------------------------------------------
# packed-schedule ref oracle (bit-matched to the packed kernels)
# ---------------------------------------------------------------------------


def test_packed_ref_matches_packed_kernels():
    l_max, K = 24, 2
    g, lm, m_vals, a32, pmm, pms, x32 = _setup(l_max, K)
    lo = kpack.build_layout(m_vals, l_max, lp_size=LP)
    Rp = -(-g.n_rings // 1024) * 1024
    a_pk = kops._pack_a(a32, lo)
    pmm_pk = kops._pack_rows(jnp.pad(pmm, ((0, 0), (0, Rp - g.n_rings))), lo)
    pms_pk = kops._pack_rows(jnp.pad(pms, ((0, 0), (0, Rp - g.n_rings))), lo)
    x_p = jnp.pad(x32, (0, Rp - g.n_rings))
    from repro.kernels import legendre_pallas as lk
    out_k = lk.synth_vpu_packed(
        a_pk, kops._pack_maps(lo), x_p.reshape(-1, 128),
        pmm_pk.reshape(lo.n_slots, 2, -1, 128),
        pms_pk.reshape(lo.n_slots, 2, -1, 128), l_max=l_max, lp_size=LP)
    out_k = jnp.moveaxis(out_k, 2, -1).reshape(lo.n_slots, 2, Rp, 2 * K)
    out_r = kref.synth_packed_ref(a_pk, lo, x_p, pmm_pk, pms_pk)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=0, atol=1e-7)
    rng = np.random.default_rng(3)
    dw_pk = jnp.asarray(rng.normal(size=(lo.n_slots, 2, Rp, 2 * K)),
                        jnp.float32)
    dwk = jnp.moveaxis(dw_pk.reshape(lo.n_slots, 2, -1, 128, 2 * K), -1, 2)
    rows_k = lk.anal_vpu_packed(
        dwk, kops._pack_maps(lo), x_p.reshape(-1, 128),
        pmm_pk.reshape(lo.n_slots, 2, -1, 128),
        pms_pk.reshape(lo.n_slots, 2, -1, 128), l_max=l_max, s_len=lo.S,
        lp_size=LP)
    rows_r = kref.anal_packed_ref(dw_pk, lo, x_p, pmm_pk, pms_pk)
    # the kernel reduces rings in (8, 128) tiles, the oracle in one sweep:
    # identical schedule, reassociated sum
    np.testing.assert_allclose(np.asarray(rows_k), np.asarray(rows_r),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# selection knobs: pick_layout / pick_variant autotune / plan dispatch
# ---------------------------------------------------------------------------


def test_pick_layout_rules(monkeypatch):
    m = np.arange(5)
    assert kops.pick_layout(m) == "packed"
    assert kops.pick_layout(m, "plain") == "plain"
    monkeypatch.setenv("REPRO_LEGENDRE_LAYOUT", "plain")
    assert kops.pick_layout(m) == "plain"
    # the env var is the global force: it outranks explicit per-call
    # arguments (and therefore plan-autotuned layouts) too
    assert kops.pick_layout(m, "packed") == "plain"
    monkeypatch.setenv("REPRO_LEGENDRE_LAYOUT", "packed")
    assert kops.pick_layout(m) == "packed"

    def traced(mv):
        # traced row sets can never pack, even under the env override
        assert kops.pick_layout(mv) == "plain"
        return mv

    jax.jit(traced)(jnp.arange(5))


def test_pick_variant_autotune_cached(monkeypatch):
    calls = []

    def fake_measure(K2, var):
        calls.append((K2, var))
        return {"vpu": 0.1, "mxu": 0.2}[var]

    monkeypatch.setattr(kops, "_measure_variant", fake_measure)
    monkeypatch.setenv("REPRO_LEGENDRE_AUTOTUNE", "1")
    monkeypatch.delenv("REPRO_LEGENDRE_VARIANT", raising=False)
    from repro.core import cache as plancache
    plancache.clear_memory()
    assert kops.pick_variant(2) == "vpu"
    assert len(calls) == 2                      # both variants measured once
    assert kops.pick_variant(2) == "vpu"        # decision cached
    assert len(calls) == 2
    monkeypatch.setenv("REPRO_LEGENDRE_VARIANT", "mxu")
    assert kops.pick_variant(2) == "mxu"        # env beats autotune
    monkeypatch.delenv("REPRO_LEGENDRE_VARIANT")
    monkeypatch.delenv("REPRO_LEGENDRE_AUTOTUNE")
    assert kops.pick_variant(2) == "vpu"        # static rule restored
    assert kops.pick_variant(32) == "mxu"


def test_plan_reports_layouts_and_panels():
    from repro.core import transform
    transform.clear_plan_cache()
    plan = repro.make_plan("gl", l_max=16, K=1, dtype="float32",
                           mode="pallas_vpu", cache="memory")
    assert plan.layouts["synth"] in ("packed", "plain", "fused")
    assert plan.layouts["anal"] in ("packed", "plain", "fused")
    d = plan.describe()
    assert d["legendre"]["panels"]["packed"] > 0
    assert d["layouts"] == plan.layouts
    assert "legendre:" in plan.report()
    alm = sht.random_alm(seed=3, l_max=16, m_max=16).astype(np.complex64)
    from repro.core import spectra
    err = float(spectra.d_err(alm, plan.map2alm(plan.alm2map(alm))))
    assert err < 1e-4
    # jnp-backed plans carry no layout
    p64 = repro.make_plan("gl", l_max=16, K=1, dtype="float64", mode="jnp",
                          cache="memory")
    assert p64.layouts == {"synth": None, "anal": None}
