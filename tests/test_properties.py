"""Property-based tests (hypothesis) on the transform invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

import repro  # noqa: F401
from repro.core import grids, sht, spectra

KEY = jax.random.PRNGKey(21)


@settings(max_examples=8, deadline=None)
@given(l_max=st.integers(8, 48), seed=st.integers(0, 1000))
def test_sht_linearity(l_max, seed):
    """alm2map(a + c*b) == alm2map(a) + c*alm2map(b)."""
    t = sht.SHT(grids.make_grid("gl", l_max=l_max), l_max=l_max, m_max=l_max)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = sht.random_alm(k1, l_max, l_max)
    b = sht.random_alm(k2, l_max, l_max)
    c = 0.37
    lhs = np.asarray(t.alm2map(a + c * b))
    rhs = np.asarray(t.alm2map(a)) + c * np.asarray(t.alm2map(b))
    assert np.max(np.abs(lhs - rhs)) < 1e-10 * max(1.0, np.abs(lhs).max())


@settings(max_examples=8, deadline=None)
@given(l_max=st.integers(8, 40), seed=st.integers(0, 1000))
def test_synthesis_is_real(l_max, seed):
    """Real-field convention (a_l,-m = (-1)^m conj(a_lm)) => real maps.
    Our engine stores m >= 0 only; the synthesized field must be real and
    the analysis of it must return (numerically) the same m>=0 table."""
    t = sht.SHT(grids.make_grid("gl", l_max=l_max), l_max=l_max, m_max=l_max)
    alm = sht.random_alm(jax.random.PRNGKey(seed), l_max, l_max)
    maps = np.asarray(t.alm2map(alm))
    assert np.isrealobj(maps)
    back = np.asarray(t.map2alm(jnp.asarray(maps)))
    assert spectra.d_err(np.asarray(alm), back) < 1e-11


@settings(max_examples=6, deadline=None)
@given(l_max=st.integers(8, 32), seed=st.integers(0, 100))
def test_monopole_and_mean(l_max, seed):
    """a_00 relates to the map mean: mean = a_00 * Y_00 = a_00/sqrt(4pi)."""
    g = grids.make_grid("gl", l_max=l_max)
    t = sht.SHT(g, l_max=l_max, m_max=l_max)
    alm = sht.random_alm(jax.random.PRNGKey(seed), l_max, l_max)
    maps = np.asarray(t.alm2map(alm))
    w = (g.weights[:, None] * np.ones((1, g.max_n_phi))).ravel()
    mean = (maps[..., 0].ravel() @ w) / (4 * np.pi)
    a00 = float(np.real(np.asarray(alm)[0, 0, 0]))
    assert abs(mean - a00 / np.sqrt(4 * np.pi)) < 1e-10


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), nside=st.sampled_from([4, 8]))
def test_band_limited_alias_free(seed, nside):
    """A field band-limited well below the grid's support round-trips on
    healpix_ring to much better accuracy than a full-band field."""
    g = grids.make_grid("healpix_ring", nside=nside)
    lo, hi = nside // 2 + 1, 2 * nside
    t_lo = sht.SHT(g, l_max=lo, m_max=lo)
    t_hi = sht.SHT(g, l_max=hi, m_max=hi)
    a_lo = sht.random_alm(jax.random.PRNGKey(seed), lo, lo)
    a_hi = sht.random_alm(jax.random.PRNGKey(seed), hi, hi)
    e_lo = spectra.d_err(a_lo, t_lo.map2alm(t_lo.alm2map(a_lo)))
    e_hi = spectra.d_err(a_hi, t_hi.map2alm(t_hi.alm2map(a_hi)))
    assert e_lo < e_hi
