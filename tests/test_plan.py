import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro  # noqa: F401
from repro.core import grids
from repro.core.plan import SHTPlan, minmax_m_order


def test_minmax_order_basic():
    assert list(minmax_m_order(5)) == [0, 5, 1, 4, 2, 3]
    assert list(minmax_m_order(4)) == [0, 4, 1, 3, 2]


@settings(max_examples=30, deadline=None)
@given(m_max=st.integers(1, 600))
def test_minmax_order_is_permutation(m_max):
    o = minmax_m_order(m_max)
    assert sorted(o) == list(range(m_max + 1))
    # consecutive pairs sum to m_max (the paper's balance invariant)
    for i in range(0, m_max - 1, 2):
        assert o[i] + o[i + 1] == m_max


@settings(max_examples=15, deadline=None)
@given(l_max=st.integers(8, 128),
       n_shards=st.sampled_from([2, 4, 8, 16]))
def test_plan_balance_and_coverage(l_max, n_shards):
    g = grids.make_grid("gl", l_max=l_max)
    p = SHTPlan(g, l_max, l_max, n_shards)
    a = p.m_assignment
    vals = a[a >= 0]
    assert sorted(vals.tolist()) == list(range(l_max + 1))   # coverage
    # paper invariant: per-shard recurrence steps within one pair's work
    steps = p.recurrence_steps_per_shard
    pair_work = 2 * (l_max + 1) - l_max + 2
    assert steps.max() - steps.min() <= 2 * pair_work
    # rings: every real ring appears exactly once
    ro = p.ring_order
    real = ro[ro >= 0]
    assert sorted(real.tolist()) == list(range(g.n_rings))
    assert p.r_pad % n_shards == 0
    assert p.r_local % 2 == 0             # whole mirror pairs per shard


@settings(max_examples=10, deadline=None)
@given(l_max=st.integers(4, 64), n_shards=st.sampled_from([2, 4, 8]),
       K=st.integers(1, 3))
def test_pack_unpack_roundtrip(l_max, n_shards, K):
    g = grids.make_grid("gl", l_max=l_max)
    p = SHTPlan(g, l_max, l_max, n_shards)
    rng = np.random.default_rng(0)
    alm = rng.normal(size=(l_max + 1, l_max + 1, K)) \
        + 1j * rng.normal(size=(l_max + 1, l_max + 1, K))
    packed = p.pack_alm(alm)
    back = p.unpack_alm(packed)
    assert np.allclose(back, alm)


@settings(max_examples=10, deadline=None)
@given(l_max=st.integers(4, 64), n_shards=st.sampled_from([2, 4, 8]))
def test_map_gather_scatter_roundtrip(l_max, n_shards):
    g = grids.make_grid("gl", l_max=l_max)
    p = SHTPlan(g, l_max, l_max, n_shards)
    rng = np.random.default_rng(1)
    maps = rng.normal(size=(g.n_rings, g.max_n_phi, 2))
    assert np.allclose(p.scatter_map(p.gather_map(maps)), maps)


@settings(max_examples=8, deadline=None)
@given(nside=st.sampled_from([4, 8, 16]), n_shards=st.sampled_from([2, 4, 8]))
def test_ragged_plan_bucket_aware_dealing(nside, n_shards):
    """Ragged grids: every ring dealt once, and every shard owns the SAME
    local slot->bucket structure with balanced per-bucket ring counts
    (shard_map's single-program requirement + paper §4.1 FFT balance)."""
    g = grids.make_grid("healpix", nside=nside)
    p = SHTPlan(g, 2 * nside, 2 * nside, n_shards)
    ro = p.ring_order
    real = ro[ro >= 0]
    assert sorted(real.tolist()) == list(range(g.n_rings))   # coverage
    assert p.r_pad % n_shards == 0 and p.r_local % 2 == 0
    lay = p.local_fft_layout
    assert sum(len(sl) for sl in lay.slots) == p.r_local
    for s in range(n_shards):
        loc = ro[s * p.r_local:(s + 1) * p.r_local]
        for B, sl in zip(lay.lengths, lay.slots):
            rings = loc[np.asarray(sl)]
            rings = rings[rings >= 0]
            # exact divisor embedding holds on every shard's every slot
            assert np.all(B % g.n_phi[rings] == 0), (s, B)
    # bin maps are consistent with slot geometry
    pos, neg = p.fft_bin_maps
    assert pos.shape == (p.r_pad, p.m_flat.shape[0])
    blen = p.slot_fft_len
    assert np.all(pos < blen[:, None]) and np.all(neg < blen[:, None])


def test_mirror_pairs_adjacent():
    g = grids.make_grid("healpix_ring", nside=8)   # odd ring count
    p = SHTPlan(g, 16, 16, 4)
    ro = p.ring_order
    R = g.n_rings
    for i in range(R // 2):
        assert ro[2 * i] == i
        assert ro[2 * i + 1] == R - 1 - i
    assert ro[2 * (R // 2)] == R // 2      # equator north slot
    assert ro[2 * (R // 2) + 1] == -1      # equator's dummy south
