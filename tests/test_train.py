import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.models.model import make_bundle
from repro.train import checkpoint as C
from repro.train import data as D
from repro.train import optimizer as O
from repro.train import train_loop as TL

KEY = jax.random.PRNGKey(0)


def _setup(grad_accum=1, compression=None):
    cfg = reduced(registry.ARCHS["qwen2-0.5b"], n_layers=2)
    b = make_bundle(cfg, mesh=None)
    params = b.init(KEY)
    tcfg = TL.TrainConfig(
        opt=O.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
        grad_accum=grad_accum, grad_compression=compression)
    step = jax.jit(TL.make_train_step(b, tcfg))
    opt = O.init_opt_state(params, tcfg.opt)
    ds = D.SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    return b, params, opt, step, ds


def test_loss_decreases():
    b, params, opt, step, ds = _setup()
    losses = []
    for i in range(20):
        batch = jax.tree.map(jnp.asarray, ds.batch(0))  # fixed batch
        params, opt, m = step(params, opt, batch, KEY)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    b, params, opt, step1, ds = _setup(grad_accum=1)
    _, params2, opt2, step4, _ = _setup(grad_accum=4)
    batch = jax.tree.map(jnp.asarray, ds.batch(0))
    p1, o1, m1 = step1(params, opt, batch, KEY)
    p4, o4, m4 = step4(params, opt, batch, KEY)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


@pytest.mark.parametrize("compression", ["bfloat16", "int8"])
def test_grad_compression_still_learns(compression):
    b, params, opt, step, ds = _setup(compression=compression)
    losses = []
    for i in range(15):
        batch = jax.tree.map(jnp.asarray, ds.batch(0))
        params, opt, m = step(params, opt, batch, KEY)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_data_pipeline_determinism_and_sharding():
    ds = D.SyntheticLM(vocab=100, seq_len=16, global_batch=8, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    assert np.array_equal(a["tokens"], b["tokens"])      # pure function
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    h0 = ds.batch(5, host_index=0, n_hosts=2)
    h1 = ds.batch(5, host_index=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    b, params, opt, step, ds = _setup()
    state = {"params": params, "opt": opt, "data_step": jnp.int32(7)}
    C.save(str(tmp_path), 3, state)
    assert C.latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    back = C.restore(str(tmp_path), 3, like)
    flat1, flat2 = jax.tree.leaves(state), jax.tree.leaves(back)
    for x, y in zip(flat1, flat2):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_zero1_specs_shard_largest_axis():
    from jax.sharding import PartitionSpec as P
    specs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((512, 64), jnp.float32)}
    out = O.opt_state_specs(specs, shapes, O.AdamWConfig(zero1=True))
    assert out["mu"]["w"] == P("data", "model")
    out2 = O.opt_state_specs(specs, shapes, O.AdamWConfig(zero1=False))
    assert out2["mu"]["w"] == P(None, "model")
