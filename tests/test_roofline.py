import numpy as np

import repro  # noqa: F401
from repro.core import comm_model as CM
from repro.roofline import analysis as RA


HLO = """
ENTRY main {
  %p = f32[1024,256]{1,0} parameter(0)
  %ar = f32[1024,256]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[4096,128]{1,0} all-gather(%x), dimensions={0}, replica_groups=[2,256]<=[512]
  %a2a = f32[512,64]{1,0} all-to-all(%y), dimensions={0}
  %cp = f32[16,16]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ars = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce-start(%w), replica_groups={{0,1}}
  %ard = f32[8,8]{1,0} all-reduce-done(%ars)
}
"""


def test_collective_parser():
    ops = RA.parse_hlo_collectives(HLO, world=512)
    kinds = [k for k, _, _ in ops]
    assert kinds.count("all-reduce") == 2      # sync + async start
    assert "all-gather" in kinds and "all-to-all" in kinds
    assert "collective-permute" in kinds
    by = {((k, g)): s for k, s, g in ops}
    assert by[("all-reduce", 4)] == 1024 * 256 * 4
    assert by[("all-gather", 256)] == 4096 * 128 * 2
    assert by[("all-reduce", 2)] == 8 * 8 * 4   # async tuple halved


def test_collective_wire_model():
    out = RA.collective_bytes(HLO, world=512)
    # ring all-reduce: 2 * S * (g-1)/g
    assert abs(out["all-reduce"] - (2 * 1024 * 256 * 4 * 3 / 4
                                    + 2 * 8 * 8 * 4 * 1 / 2)) < 1
    assert out["total"] > 0


def test_roofline_terms_and_bottleneck():
    r = RA.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                    wire_bytes_per_device=0.0, n_devices=4,
                    model_flops=4 * 197e12 / 2)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.useful_flops_fraction == 0.5
    assert r.roofline_fraction == 0.5
    r2 = RA.Roofline(1e12, 1e9, 1e12, 4)
    assert r2.bottleneck == "collective"


def test_comm_model_matches_paper_structure():
    p = CM.MPICH_CLUSTER
    # Fig. 4 middle/left behaviours: compute ~ 1/nproc, comm ~ flat (large
    # msgs), so a crossover exists and grows with problem size.
    t64 = CM.sht_times(4096, 64, p)
    t512 = CM.sht_times(4096, 512, p)
    assert t512["compute"] < t64["compute"] / 4
    assert t512["comm"] >= 0.8 * t64["comm"]
    c1 = CM.crossover_nproc(1024, p)
    c2 = CM.crossover_nproc(8192, p)
    assert c2 >= c1
    # message-size switch: tiny problems land in the Bruck branch
    small = CM.message_size(63, 32, 64)
    assert small < p.bruck_cutoff


def test_comm_model_fold_reduces_compute():
    p = CM.TPU_V5E_ICI
    a = CM.sht_times(2048, 256, p, fold=False)
    b = CM.sht_times(2048, 256, p, fold=True)
    assert b["compute"] < a["compute"]
    assert b["comm"] == a["comm"]
