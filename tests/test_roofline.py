import numpy as np

import repro  # noqa: F401
from repro.core import comm_model as CM
from repro.roofline import analysis as RA


HLO = """
ENTRY main {
  %p = f32[1024,256]{1,0} parameter(0)
  %ar = f32[1024,256]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[4096,128]{1,0} all-gather(%x), dimensions={0}, replica_groups=[2,256]<=[512]
  %a2a = f32[512,64]{1,0} all-to-all(%y), dimensions={0}
  %cp = f32[16,16]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ars = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce-start(%w), replica_groups={{0,1}}
  %ard = f32[8,8]{1,0} all-reduce-done(%ars)
}
"""


def test_collective_parser():
    ops = RA.parse_hlo_collectives(HLO, world=512)
    kinds = [k for k, _, _ in ops]
    assert kinds.count("all-reduce") == 2      # sync + async start
    assert "all-gather" in kinds and "all-to-all" in kinds
    assert "collective-permute" in kinds
    by = {((k, g)): s for k, s, g in ops}
    assert by[("all-reduce", 4)] == 1024 * 256 * 4
    assert by[("all-gather", 256)] == 4096 * 128 * 2
    assert by[("all-reduce", 2)] == 8 * 8 * 4   # async tuple halved


def test_collective_wire_model():
    out = RA.collective_bytes(HLO, world=512)
    # ring all-reduce: 2 * S * (g-1)/g
    assert abs(out["all-reduce"] - (2 * 1024 * 256 * 4 * 3 / 4
                                    + 2 * 8 * 8 * 4 * 1 / 2)) < 1
    assert out["total"] > 0


def test_roofline_terms_and_bottleneck():
    r = RA.Roofline(flops_per_device=197e12, bytes_per_device=819e9,
                    wire_bytes_per_device=0.0, n_devices=4,
                    model_flops=4 * 197e12 / 2)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert r.useful_flops_fraction == 0.5
    assert r.roofline_fraction == 0.5
    r2 = RA.Roofline(1e12, 1e9, 1e12, 4)
    assert r2.bottleneck == "collective"


def test_comm_model_matches_paper_structure():
    p = CM.MPICH_CLUSTER
    # Fig. 4 middle/left behaviours: compute ~ 1/nproc, comm ~ flat (large
    # msgs), so a crossover exists and grows with problem size.
    t64 = CM.sht_times(4096, 64, p)
    t512 = CM.sht_times(4096, 512, p)
    assert t512["compute"] < t64["compute"] / 4
    assert t512["comm"] >= 0.8 * t64["comm"]
    c1 = CM.crossover_nproc(1024, p)
    c2 = CM.crossover_nproc(8192, p)
    assert c2 >= c1
    # message-size switch: tiny problems land in the Bruck branch
    small = CM.message_size(63, 32, 64)
    assert small < p.bruck_cutoff


def test_comm_model_fold_reduces_compute():
    p = CM.TPU_V5E_ICI
    a = CM.sht_times(2048, 256, p, fold=False)
    b = CM.sht_times(2048, 256, p, fold=True)
    assert b["compute"] < a["compute"]
    assert b["comm"] == a["comm"]


def test_overlap_model_chunked_pipeline():
    for p in (CM.MPICH_CLUSTER, CM.TPU_V5E_ICI):
        serial = CM.sht_times(4096, 1024, p)
        # C=1 degenerates to the serial comp + comm sum
        t1 = CM.sht_times_overlap(4096, 1024, p, chunks=1)
        assert abs(t1["overlap"] - serial["total"]) < 1e-12
        assert t1["hidden_frac"] == 0.0
        # the auto pick never loses to serial, and hidden_frac is a fraction
        tb = CM.sht_times_overlap(4096, 1024, p)
        assert tb["chunks"] >= 1
        assert tb["overlap"] <= serial["total"] + 1e-15
        assert 0.0 <= tb["hidden_frac"] <= 1.0
        assert CM.best_chunks(4096, 1024, p) == tb["chunks"]
    # acceptance corner: comm-bound TPU mesh hides > half the hideable time
    corner = CM.sht_times_overlap(4096, 1024, CM.TPU_V5E_ICI)
    assert corner["chunks"] > 1
    assert corner["hidden_frac"] > 0.5, corner


def test_overlap_model_single_process_is_serial():
    t = CM.sht_times_overlap(1024, 1, CM.MPICH_CLUSTER, chunks=8)
    assert t["overlap"] == t["serial"]
    assert t["hidden_frac"] == 0.0


def test_predict_sht_time_overlap_and_chunk_pick():
    kw = dict(l_max=2048, m_max=2048, n_rings=4097, n_phi=8192, K=4,
              hw=RA.HW_V5E, n_devices=16)
    serial = RA.predict_sht_time("dist", **kw)
    over1 = RA.predict_sht_time("dist", overlap=True, comm_chunks=1, **kw)
    assert abs(over1 - serial) < 1e-15          # C=1 == blocking exchange
    c = RA.predict_comm_chunks(**kw)
    assert c >= 1
    over = RA.predict_sht_time("dist", overlap=True, comm_chunks=c, **kw)
    assert over <= serial + 1e-15
    # the pick must beat (or tie) a deliberately bad chunk count
    worse = RA.predict_sht_time("dist", overlap=True, comm_chunks=4096, **kw)
    assert over <= worse + 1e-15


def test_predict_comm_chunks_respects_axis_bounds():
    # K=1 on a single dealt m row leaves nothing to chunk -> C=1
    c = RA.predict_comm_chunks(l_max=8, m_max=8, n_rings=17, n_phi=34,
                               K=1, hw=RA.HW_V5E, n_devices=8, max_chunks=64)
    assert c >= 1
    assert c <= max(1, 64)
