# NOTE: deliberately NO --xla_force_host_platform_device_count here --
# smoke tests and benches must see 1 device (the dry-run sets its own flags
# as the first lines of repro.launch.dryrun).  Multi-device tests spawn
# subprocesses (see tests/helpers/).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# tests/ itself, for the _hypothesis_compat shim (real hypothesis when
# installed, deterministic fallback runner otherwise)
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
