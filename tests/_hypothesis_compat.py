"""hypothesis compatibility shim.

Re-exports the real ``hypothesis`` when installed.  When it is missing
(containers where we cannot pip install), provides a deterministic
mini-runner implementing the tiny subset these tests use --
``@settings(max_examples=..., deadline=...)``, ``@given(**strategies)``,
``st.integers(lo, hi)`` and ``st.sampled_from(values)`` -- so the property
tests still execute with seeded pseudo-random + boundary examples instead
of being skipped wholesale.

The fallback is intentionally simple: no shrinking, no example database.
Draws are seeded per-test (crc32 of the test name), so failures reproduce.
Install the real ``hypothesis`` (``pip install -e ".[test]"``) to get full
property-based testing.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def draw(self, rng, i):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def draw(self, rng, i):
            if i == 0:
                return self.lo
            if i == 1:
                return self.hi
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, values):
            self.values = list(values)

        def draw(self, rng, i):
            if i < len(self.values):          # cycle through all values first
                return self.values[i]
            return self.values[int(rng.integers(len(self.values)))]

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(values):
            return _SampledFrom(values)

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = {k: s.draw(rng, i) for k, s in strats.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example ({fn.__name__}): {drawn}"
                        ) from e
            # hide the property parameters from pytest's fixture resolution
            # (functools.wraps exposes them via __wrapped__)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
