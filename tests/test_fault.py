"""Fault tolerance: restart-from-checkpoint reproduces the uninterrupted
run bit-for-bit; elastic restore re-places state; serve engine smoke."""

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.models.model import make_bundle
from repro.train import checkpoint as C
from repro.train import data as D
from repro.train import fault as F
from repro.train import optimizer as O
from repro.train import train_loop as TL

KEY = jax.random.PRNGKey(0)


def _mk():
    cfg = reduced(registry.ARCHS["xlstm-125m"], n_layers=2)
    b = make_bundle(cfg, mesh=None)
    tcfg = TL.TrainConfig(opt=O.AdamWConfig(lr=1e-3, warmup_steps=1,
                                            total_steps=50))
    step = jax.jit(TL.make_train_step(b, tcfg))
    ds = D.SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=2, seed=1)

    def init_state():
        params = b.init(KEY)
        return {"params": params, "opt": O.init_opt_state(params, tcfg.opt)}

    losses = []

    def step_fn(state, i):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        p, o, m = step(state["params"], state["opt"], batch, KEY)
        losses.append((i, float(m["loss"])))
        return {"params": p, "opt": o}

    return init_state, step_fn, losses


def test_restart_reproduces_trajectory(tmp_path):
    init_state, step_fn, losses_a = _mk()
    cfgA = F.RunConfig(total_steps=12, ckpt_dir=str(tmp_path / "a"),
                       ckpt_every=4)
    F.run_with_restarts(cfgA, init_state=init_state, step_fn=step_fn)

    init_state, step_fn, losses_b = _mk()
    cfgB = F.RunConfig(total_steps=12, ckpt_dir=str(tmp_path / "b"),
                       ckpt_every=4)
    inj = F.FailureInjector(fail_at=(6, 10))
    F.run_with_restarts(cfgB, init_state=init_state, step_fn=step_fn,
                        injector=inj)
    # same (step, loss) pairs for the last steps despite two injected kills
    tail_a = dict(losses_a)[11]
    tail_b = dict(losses_b)[11]
    assert tail_a == tail_b


def test_restart_data_order_preserved(tmp_path):
    """After a failure the data stream continues at the checkpointed step
    (stateless batch(step) indexing)."""
    ds = D.SyntheticLM(vocab=64, seq_len=8, global_batch=2, seed=9)
    seen = []

    def init_state():
        return {"x": jnp.zeros(())}

    def step_fn(state, i):
        seen.append(int(ds.batch(i)["tokens"][0, 0]))
        return state

    inj = F.FailureInjector(fail_at=(3,))
    F.run_with_restarts(
        F.RunConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=1),
        init_state=init_state, step_fn=step_fn, injector=inj)
    uninterrupted = [int(ds.batch(i)["tokens"][0, 0]) for i in range(6)]
    # the replayed suffix after the kill equals the uninterrupted stream
    assert seen[-3:] == uninterrupted[-3:]


def test_elastic_restore_replaces_arrays(tmp_path):
    """Restore onto a 'different mesh': here 1 device with a new sharding
    object -- the arrays land with the requested placement."""
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    C.save(str(tmp_path), 1, state)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    back = C.restore(str(tmp_path), 1, like, shardings=sh)
    assert np.array_equal(np.asarray(back["w"]), np.asarray(state["w"]))
    assert back["w"].sharding == sh["w"]


def test_serve_engine_greedy():
    from repro.serve.serve_loop import Request, ServeEngine
    cfg = reduced(registry.ARCHS["qwen2-0.5b"], n_layers=2)
    b = make_bundle(cfg, mesh=None)
    params = b.init(KEY)
    eng = ServeEngine(b, batch=2, max_len=64, eos_id=-123)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=rng.integers(0, cfg.vocab, 4)
                           .astype(np.int32), max_new=4))
    done = eng.run(params, max_steps=40)
    finished = [r for r in done if r.done]
    assert len(finished) >= 2
    for r in finished:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
