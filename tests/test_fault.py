"""Fault tolerance: restart-from-checkpoint reproduces the uninterrupted
run bit-for-bit; elastic restore re-places state; SHT serving engine
fault containment (backpressure, poisoned signatures, timeout eviction)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import reduced
from repro.models.model import make_bundle
from repro.train import checkpoint as C
from repro.train import data as D
from repro.train import fault as F
from repro.train import optimizer as O
from repro.train import train_loop as TL

KEY = jax.random.PRNGKey(0)


def _mk():
    cfg = reduced(registry.ARCHS["xlstm-125m"], n_layers=2)
    b = make_bundle(cfg, mesh=None)
    tcfg = TL.TrainConfig(opt=O.AdamWConfig(lr=1e-3, warmup_steps=1,
                                            total_steps=50))
    step = jax.jit(TL.make_train_step(b, tcfg))
    ds = D.SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=2, seed=1)

    def init_state():
        params = b.init(KEY)
        return {"params": params, "opt": O.init_opt_state(params, tcfg.opt)}

    losses = []

    def step_fn(state, i):
        batch = jax.tree.map(jnp.asarray, ds.batch(i))
        p, o, m = step(state["params"], state["opt"], batch, KEY)
        losses.append((i, float(m["loss"])))
        return {"params": p, "opt": o}

    return init_state, step_fn, losses


def test_restart_reproduces_trajectory(tmp_path):
    init_state, step_fn, losses_a = _mk()
    cfgA = F.RunConfig(total_steps=12, ckpt_dir=str(tmp_path / "a"),
                       ckpt_every=4)
    F.run_with_restarts(cfgA, init_state=init_state, step_fn=step_fn)

    init_state, step_fn, losses_b = _mk()
    cfgB = F.RunConfig(total_steps=12, ckpt_dir=str(tmp_path / "b"),
                       ckpt_every=4)
    inj = F.FailureInjector(fail_at=(6, 10))
    F.run_with_restarts(cfgB, init_state=init_state, step_fn=step_fn,
                        injector=inj)
    # same (step, loss) pairs for the last steps despite two injected kills
    tail_a = dict(losses_a)[11]
    tail_b = dict(losses_b)[11]
    assert tail_a == tail_b


def test_restart_data_order_preserved(tmp_path):
    """After a failure the data stream continues at the checkpointed step
    (stateless batch(step) indexing)."""
    ds = D.SyntheticLM(vocab=64, seq_len=8, global_batch=2, seed=9)
    seen = []

    def init_state():
        return {"x": jnp.zeros(())}

    def step_fn(state, i):
        seen.append(int(ds.batch(i)["tokens"][0, 0]))
        return state

    inj = F.FailureInjector(fail_at=(3,))
    F.run_with_restarts(
        F.RunConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=1),
        init_state=init_state, step_fn=step_fn, injector=inj)
    uninterrupted = [int(ds.batch(i)["tokens"][0, 0]) for i in range(6)]
    # the replayed suffix after the kill equals the uninterrupted stream
    assert seen[-3:] == uninterrupted[-3:]


def test_elastic_restore_replaces_arrays(tmp_path):
    """Restore onto a 'different mesh': here 1 device with a new sharding
    object -- the arrays land with the requested placement."""
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    C.save(str(tmp_path), 1, state)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    back = C.restore(str(tmp_path), 1, like, shardings=sh)
    assert np.array_equal(np.asarray(back["w"]), np.asarray(state["w"]))
    assert back["w"].sharding == sh["w"]


# -- SHT serving engine: fault containment -----------------------------------


def _serve_alm(seed, l_max=12):
    from repro.core import sht
    return np.asarray(sht.random_alm(seed=seed, l_max=l_max,
                                     m_max=l_max))[..., 0]


def test_serve_queue_overflow_backpressure():
    """A full queue refuses new work with a BackpressureError instead of
    growing without bound; draining reopens it."""
    import pytest
    from repro.serve import BackpressureError, ShtEngine
    eng = ShtEngine(max_k=2, max_queue=3, mode="jnp")
    futs = [eng.submit(direction="alm2map", payload=_serve_alm(i),
                       grid="gl", l_max=12) for i in range(3)]
    with pytest.raises(BackpressureError):
        eng.submit(direction="alm2map", payload=_serve_alm(9), grid="gl",
                   l_max=12)
    assert eng.stats()["requests"]["submitted"] == 3    # rejected != queued
    eng.drain()
    assert all(f.done() for f in futs)
    late = eng.submit(direction="alm2map", payload=_serve_alm(4), grid="gl",
                      l_max=12)                         # accepted again
    eng.drain()
    assert late.done() and late.exception() is None


def test_serve_invalid_signature_fails_only_its_future():
    """A request whose signature cannot build a plan (unknown grid) fails
    its own future; the engine keeps serving later requests."""
    import pytest
    from repro.serve import ShtEngine
    eng = ShtEngine(max_k=2, mode="jnp")
    bad = eng.submit(direction="alm2map",
                     payload=np.zeros((13, 13), complex),
                     grid="klein_bottle", l_max=12)
    good = eng.submit(direction="alm2map", payload=_serve_alm(0), grid="gl",
                      l_max=12)
    eng.drain()
    assert isinstance(bad.exception(), Exception)
    with pytest.raises(Exception):
        bad.result()
    assert good.exception() is None and good.result().shape == (13, 26)
    s = eng.stats()["requests"]
    assert s["failed"] == 1 and s["completed"] == 1


def test_serve_mismatched_payload_does_not_poison_batch():
    """A payload that lies about its signature fails alone -- the
    requests coalesced with it still complete."""
    from repro.serve import ShtEngine
    eng = ShtEngine(max_k=4, mode="jnp")
    liar = eng.submit(direction="alm2map",
                      payload=np.zeros((9, 9), complex),   # l_max=8 shape...
                      grid="gl", l_max=12)                 # ...claims 12
    honest = eng.submit(direction="alm2map", payload=_serve_alm(1),
                        grid="gl", l_max=12)
    eng.drain()
    assert isinstance(liar.exception(), ValueError)
    assert honest.exception() is None and honest.done()


def test_serve_timeout_evicted_later_requests_complete():
    """An expired request is evicted with ShtTimeoutError at batch
    formation; requests behind it still run."""
    import pytest
    from repro.serve import ShtEngine, ShtTimeoutError
    eng = ShtEngine(max_k=2, mode="jnp")
    stale = eng.submit(direction="alm2map", payload=_serve_alm(0),
                       grid="gl", l_max=12, timeout=0.0)
    fresh = eng.submit(direction="alm2map", payload=_serve_alm(1),
                       grid="gl", l_max=12)
    time.sleep(0.01)                             # let the deadline pass
    eng.drain()
    with pytest.raises(ShtTimeoutError):
        stale.result()
    assert fresh.exception() is None and fresh.done()
    s = eng.stats()["requests"]
    assert s["timed_out"] == 1 and s["completed"] == 1
    assert stale.timing["queue_s"] >= 0.0


def test_serve_timeout_eviction_while_group_mid_batch():
    """A request that expires while an earlier batch of its *own group*
    is still executing on the background threads is evicted at the next
    formation pass -- a wedged batch never pins its group's queue."""
    import threading

    import pytest
    from repro.serve import ShtEngine, ShtTimeoutError

    eng = ShtEngine(max_k=1, max_queue=8, mode="jnp")
    started, release = threading.Event(), threading.Event()
    real_get = eng.pool.get

    class _Stall:
        def __init__(self, plan):
            self._plan = plan

        def __getattr__(self, name):
            return getattr(self._plan, name)

        def alm2map(self, x):
            started.set()
            assert release.wait(30.0)
            return self._plan.alm2map(x)

    eng.pool.get = lambda sig, k: _Stall(real_get(sig, k))
    with eng:
        slow = eng.submit(direction="alm2map", payload=_serve_alm(0),
                          grid="gl", l_max=12)
        assert started.wait(30.0)                # batch 1 wedged mid-flight
        stale = eng.submit(direction="alm2map", payload=_serve_alm(1),
                           grid="gl", l_max=12, timeout=0.0)
        fresh = eng.submit(direction="alm2map", payload=_serve_alm(2),
                           grid="gl", l_max=12)
        time.sleep(0.05)                         # stale's deadline passes
        release.set()
        eng.drain(timeout=30.0)
    assert slow.exception() is None
    with pytest.raises(ShtTimeoutError):
        stale.result()
    assert fresh.exception() is None
    s = eng.stats()["requests"]
    assert s["timed_out"] == 1 and s["completed"] == 2 and s["pending"] == 0


def test_serve_stop_and_close_with_live_threads_and_executing_batch():
    """stop() never strands a popped batch (the in-flight staged work
    executes before the threads join), and close() fails the queued
    leftovers instead of dropping them -- with background warm-up threads
    alive through the whole teardown."""
    import threading

    import pytest
    from repro.serve import ShtEngine

    eng = ShtEngine(max_k=1, max_queue=8, mode="jnp", warm_after=1)
    started, release = threading.Event(), threading.Event()
    real_get = eng.pool.get

    class _Stall:
        def __init__(self, plan):
            self._plan = plan

        def __getattr__(self, name):
            return getattr(self._plan, name)

        def alm2map(self, x):
            started.set()
            assert release.wait(30.0)
            return self._plan.alm2map(x)

    eng.pool.get = lambda sig, k: _Stall(real_get(sig, k))
    eng.start()
    inflight = eng.submit(direction="alm2map", payload=_serve_alm(0),
                          grid="gl", l_max=12)   # warm_after=1 fires here
    assert started.wait(30.0)                    # wedged mid-execution
    timer = threading.Timer(0.05, release.set)
    timer.start()
    eng.stop(drain=False)    # returns only after the wedged batch lands
    timer.join()
    assert inflight.done() and inflight.exception() is None
    assert eng.describe()["pipeline"]["double_buffered"] is False
    queued = eng.submit(direction="alm2map", payload=_serve_alm(1),
                        grid="gl", l_max=12)     # stopped != closed
    eng.close()                                  # now fail the leftovers
    assert isinstance(queued.exception(), RuntimeError)
    with pytest.raises(RuntimeError):
        eng.submit(direction="alm2map", payload=_serve_alm(2), grid="gl",
                   l_max=12)                     # closed = no new work
    s = eng.stats()["requests"]
    assert s["pending"] == 0 and s["completed"] == 1 and s["failed"] == 1
