"""The pluggable FFT/phase stage: bucket geometry, exactness, caching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import cache as plancache
from repro.core import grids, phase, sht

KEY = jax.random.PRNGKey(11)


# -- bucket geometry ---------------------------------------------------------


@pytest.mark.parametrize("nside", [4, 8, 16])
def test_ring_buckets_invariants(nside):
    g = grids.make_grid("healpix", nside=nside)
    buckets = g.fft_buckets()
    seen = np.concatenate([b.rings for b in buckets])
    # partition of all rings
    assert sorted(seen.tolist()) == list(range(g.n_rings))
    for b in buckets:
        # exact divisor embedding, and bucket lengths are real ring lengths
        assert np.all(b.length % g.n_phi[b.rings] == 0)
        assert b.length in g.n_phi
    # merging actually reduced the bucket count below the distinct lengths
    assert len(buckets) < len(np.unique(g.n_phi))


def test_ring_buckets_max_stretch_tradeoff():
    g = grids.make_grid("healpix", nside=8)
    merged = g.fft_buckets()
    exact = g.fft_buckets(max_stretch=1)
    assert len(exact) == len(np.unique(g.n_phi))      # no merging
    assert len(merged) < len(exact)                   # fewer buckets...
    lay_m = grids.BucketLayout.from_buckets(merged)
    lay_e = grids.BucketLayout.from_buckets(exact)
    # ...at the price of FFT padding
    assert lay_e.padded_frac(g.n_phi) == 0.0
    assert lay_m.padded_frac(g.n_phi) > 0.0


def test_uniform_grid_single_bucket():
    g = grids.make_grid("gl", l_max=16)
    buckets = g.fft_buckets()
    assert len(buckets) == 1 and buckets[0].length == g.max_n_phi


def test_bucket_permutation_contiguous():
    g = grids.make_grid("healpix", nside=8)
    perm = g.bucket_permutation()
    assert sorted(perm.tolist()) == list(range(g.n_rings))
    lens = g.bucket_lengths()[perm]
    # bucket-major: per-ring bucket lengths change at most n_buckets times
    changes = int(np.sum(lens[1:] != lens[:-1]))
    assert changes == len(g.fft_buckets()) - 1


# -- exactness against the direct DFT ----------------------------------------


def _dft_reference(g, dp):
    """Brute-force per-ring DFT synthesis: s_j = Re(sum_m dp e^{im 2pi j/n}
    + sum_{m>0} conj(dp) e^{-im 2pi j/n}) (phi0 already folded into dp)."""
    M, R, K = dp.shape
    out = np.zeros((R, g.max_n_phi, K))
    for r in range(R):
        n = int(g.n_phi[r])
        j = np.arange(n)
        for m in range(M):
            w = np.exp(2j * np.pi * m * j / n)[:, None]
            out[r, :n] += (dp[m, r][None, :] * w).real
            if m > 0:
                out[r, :n] += (np.conj(dp[m, r])[None, :] / w).real
    return out


def test_bucket_synth_matches_direct_dft():
    g = grids.make_grid("healpix", nside=4)
    m_max = 8
    t = sht.SHT(g, l_max=m_max, m_max=m_max)
    alm = sht.random_alm(KEY, m_max, m_max, K=2)
    delta = np.asarray(t._delta_from_alm(alm))
    ph = np.exp(1j * np.arange(m_max + 1)[:, None] * g.phi0[None, :])
    ref = _dft_reference(g, delta * ph[..., None])
    got = np.asarray(t.phase.synth(jnp.asarray(delta)))
    assert np.max(np.abs(got - ref)) < 1e-12


def test_bucket_anal_matches_direct_dft():
    g = grids.make_grid("healpix", nside=4)
    m_max = 8
    t = sht.SHT(g, l_max=m_max, m_max=m_max)
    rng = np.random.default_rng(0)
    maps = np.zeros((g.n_rings, g.max_n_phi, 2))
    for r in range(g.n_rings):
        maps[r, : int(g.n_phi[r])] = rng.normal(size=(int(g.n_phi[r]), 2))
    got = np.asarray(t.phase.anal(jnp.asarray(maps)))
    for r in (0, 3, g.n_rings // 2, g.n_rings - 1):
        n = int(g.n_phi[r])
        j = np.arange(n)
        for m in (0, 1, 5, m_max):
            ref = (maps[r, :n]
                   * np.exp(-2j * np.pi * m * j / n)[:, None]).sum(axis=0)
            ref *= np.exp(-1j * m * g.phi0[r]) * g.weights[r]
            assert np.max(np.abs(got[m, r] - ref)) < 1e-12, (r, m)


def test_anal_masks_padding_garbage():
    """Samples beyond a ring's n_phi must not leak into the analysis."""
    g = grids.make_grid("healpix", nside=4)
    t = sht.SHT(g, l_max=8, m_max=8)
    alm = sht.random_alm(KEY, 8, 8)
    maps = np.asarray(t.alm2map(alm))
    dirty = maps.copy()
    for r in range(g.n_rings):
        dirty[r, int(g.n_phi[r]):] = 99.0
    a_clean = np.asarray(t.map2alm(jnp.asarray(maps)))
    a_dirty = np.asarray(t.map2alm(jnp.asarray(dirty)))
    assert np.max(np.abs(a_clean - a_dirty)) < 1e-12


def test_bucket_engine_jits():
    g = grids.make_grid("healpix", nside=8)
    t = sht.SHT(g, l_max=16, m_max=16)
    alm = sht.random_alm(KEY, 16, 16)
    eager = np.asarray(t.alm2map(alm))
    jitted = np.asarray(jax.jit(t.alm2map)(alm))
    assert np.max(np.abs(eager - jitted)) < 1e-12
    a_e = np.asarray(t.map2alm(jnp.asarray(eager)))
    a_j = np.asarray(jax.jit(t.map2alm)(jnp.asarray(eager)))
    assert np.max(np.abs(a_e - a_j)) < 1e-12


def test_uniform_phase_engine_matches_ragged_on_degenerate_grid():
    """A ragged grid whose rings all share n_phi must reproduce the uniform
    engine exactly (the bucket engine is a strict generalisation)."""
    gu = grids.make_grid("healpix_ring", nside=4)
    # same geometry, but declared ragged -> routed to the bucket engine
    gr = grids.RingGrid(name="healpix_ring_ragged", cos_theta=gu.cos_theta,
                        sin_theta=gu.sin_theta, weights=gu.weights,
                        n_phi=gu.n_phi, phi0=gu.phi0, uniform=False,
                        nside=gu.nside)
    m_max = 8
    pu = phase.make_phase(gu, m_max, "float64")
    pr = phase.make_phase(gr, m_max, "float64")
    assert pu.kind == "uniform" and pr.kind == "bucket"
    alm = sht.random_alm(KEY, m_max, m_max)
    t = sht.SHT(gu, l_max=m_max, m_max=m_max)
    delta = t._delta_from_alm(alm)
    su, sr = np.asarray(pu.synth(delta)), np.asarray(pr.synth(delta))
    assert np.max(np.abs(su - sr)) < 1e-12
    au = np.asarray(pu.anal(jnp.asarray(su)))
    ar = np.asarray(pr.anal(jnp.asarray(su)))
    assert np.max(np.abs(au - ar)) < 1e-12


# -- plan-cache integration ---------------------------------------------------


def test_phase_index_maps_cached(tmp_path):
    plancache.clear_memory()
    plancache.reset_stats()
    g = grids.make_grid("healpix", nside=8)
    phase.make_phase(g, 16, "float64", cache="disk", cache_dir=str(tmp_path))
    builds = plancache.stats().builds
    assert builds > 0
    phase.make_phase(g, 16, "float64", cache="disk", cache_dir=str(tmp_path))
    assert plancache.stats().builds == builds        # memory hit
    plancache.clear_memory()
    phase.make_phase(g, 16, "float64", cache="disk", cache_dir=str(tmp_path))
    assert plancache.stats().builds == builds        # disk hit, no rebuild
    assert plancache.stats().disk_hits > 0
    plancache.clear_memory()
    plancache.reset_stats()
