"""Property-based adjointness and gradient tests for the differentiable
transforms.

The direct and inverse SHT are (up to quadrature weights) adjoints of each
other -- the identity the custom JVP/VJP rules are built on.  This suite
checks it at every layer and through every backend:

* the *plan-level* dot-product identity, exact in exact arithmetic on any
  grid (including ragged HEALPix with alias-folded short rings):

      <alm2map(a), t>_pix  ==  sum_{m,l} fac_m Re(a_lm conj(ahat_lm)),
      ahat = map2alm(t / w),  fac_m = 1 (m = 0) | 2 (m > 0)

* the kernel-level transpose (ops.synth vs ops.anal, plain and packed,
  scalar and spin rows, fold on/off);

* JVP-vs-VJP consistency of the custom rules (the transpose is checked
  against the forward linearisation, which the forward tests pin down);

* finite-difference gradient checks of ``jax.grad`` through
  ``Plan.alm2map`` and ``Plan.map2alm`` on every eligible backend, both
  Legendre layouts, spin 0 and 2.

Hypothesis runs through the `_hypothesis_compat` fallback runner, so the
property tests execute (seeded + boundary examples) even without the real
hypothesis package.  The @settings counts below sum to > 200 generated
cases (the acceptance bar for this suite).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, strategies as st

import repro
from repro.core import grids as gridlib
from repro.core import legendre
from repro.core import sht as shtlib
from repro.core.sht import random_alm, random_alm_spin

# every plan here is memoised by signature, so repeated draws are cheap
GRIDS = ("gl", "ecp", "healpix")


def _make_plan(grid_kind, l_max, dtype, mode, spin=0, K=1):
    nside = None
    if grid_kind == "healpix":
        nside = max(4, (l_max + 1) // 2)
        l_max = min(l_max, 2 * nside)
    return repro.make_plan(grid_kind, l_max=l_max, nside=nside, K=K,
                           dtype=dtype, mode=mode, spin=spin)


def _rand_alm(plan, seed):
    f = random_alm_spin if plan.spin else random_alm
    a = f(seed=seed, l_max=plan.l_max, m_max=plan.m_max, K=plan.K)
    return a.astype(jnp.complex64 if plan.dtype == "float32"
                    else jnp.complex128)


def _rand_maps(plan, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=plan._maps_shape), plan.dtype)


def _fac(plan):
    m = np.arange(plan.m_max + 1)
    return jnp.asarray(np.where(m == 0, 1.0, 2.0))[:, None, None]


def _harmonic_dot(plan, a, ahat):
    """sum_{m,l} fac_m Re(a conj(ahat)), summed over components and K."""
    p = jnp.real(a * jnp.conj(ahat))
    fac = _fac(plan)
    if plan.spin:
        fac = fac[None]
    return float(jnp.sum(fac * p))


def _adjoint_identity_err(plan, seed, layout=None):
    """Relative error of the plan-level adjointness identity.

    ``layout`` pins the Legendre layout on both directions (the compiled-
    callable cache is keyed by layout, so pinning is jit-cache friendly).
    """
    a = _rand_alm(plan, seed)
    t = _rand_maps(plan, seed + 1)
    w = jnp.asarray(plan.grid.weights, plan.dtype)[:, None, None]
    t_over_w = t / (w if plan.spin == 0 else w[None])
    synth = plan._synth_fn(plan.backends["synth"], layout)
    anal = plan._anal_fn(plan.backends["anal"], layout)
    lhs = float(jnp.sum(synth(a) * t))
    ahat = anal(t_over_w)
    rhs = _harmonic_dot(plan, a, ahat)
    scale = max(abs(lhs), abs(rhs), 1e-30)
    return abs(lhs - rhs) / scale


# ---------------------------------------------------------------------------
# plan-level adjointness: <A x, y> == <x, A* y> across grids/backends/spins
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(grid=st.sampled_from(GRIDS), l_max=st.integers(4, 12),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 10**6))
def test_adjointness_jnp_f64(grid, l_max, k, seed):
    plan = _make_plan(grid, l_max, "float64", "jnp", K=k)
    assert _adjoint_identity_err(plan, seed) < 1e-11


@settings(max_examples=40, deadline=None)
@given(grid=st.sampled_from(GRIDS), backend=st.sampled_from(
           ["pallas_vpu", "pallas_mxu"]),
       layout=st.sampled_from(["plain", "packed"]),
       seed=st.integers(0, 10**6))
def test_adjointness_pallas_f32(grid, backend, layout, seed):
    plan = _make_plan(grid, 8, "float32", backend)
    assert _adjoint_identity_err(plan, seed, layout=layout) < 2e-3


@settings(max_examples=40, deadline=None)
@given(grid=st.sampled_from(GRIDS), l_max=st.integers(4, 10),
       seed=st.integers(0, 10**6))
def test_adjointness_spin2_jnp(grid, l_max, seed):
    plan = _make_plan(grid, max(l_max, 4), "float64", "jnp", spin=2)
    assert _adjoint_identity_err(plan, seed) < 1e-11


@settings(max_examples=20, deadline=None)
@given(backend=st.sampled_from(["pallas_vpu", "pallas_mxu"]),
       layout=st.sampled_from(["plain", "packed"]),
       seed=st.integers(0, 10**6))
def test_adjointness_spin2_pallas(backend, layout, seed):
    plan = _make_plan("gl", 8, "float32", backend, spin=2)
    assert _adjoint_identity_err(plan, seed, layout=layout) < 2e-3


# ---------------------------------------------------------------------------
# kernel-level transpose: <synth(a), y> == <a, anal(y)> (no weights at
# this layer, so the pairing is the plain elementwise dot product)
# ---------------------------------------------------------------------------


def _kernel_operands(l_max, fold, spin, K2=2, seed=0):
    from repro.kernels import ref as kref
    g = gridlib.make_grid("gl", l_max=l_max)
    rng = np.random.default_rng(seed)
    if spin:
        m2, mp2 = legendre._spin_rows(np.arange(l_max + 1))
        pmm, pms = kref.prepare_seeds_spin(m2, mp2, g.cos_theta, g.sin_theta,
                                           m_max=l_max)
        m_vals, mp_vals, x = m2, mp2, g.cos_theta
    else:
        lm = legendre.log_mu(l_max)
        m_vals = np.arange(l_max + 1)
        sin = g.sin_theta[0::2] if fold else g.sin_theta
        x = g.cos_theta[0::2] if fold else g.cos_theta
        pmm, pms = kref.prepare_seeds(m_vals, sin, lm)
        mp_vals = None
    Mp = m_vals.shape[0]
    R = x.shape[0]
    P = 2 if fold else 1
    a = jnp.asarray(rng.normal(size=(Mp, l_max + 1, K2)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(Mp, P, R, K2)), jnp.float32)
    x32 = jnp.asarray(x, jnp.float32)
    return a, y, m_vals, mp_vals, x32, pmm, pms


@settings(max_examples=24, deadline=None)
@given(variant=st.sampled_from(["vpu", "mxu"]),
       layout=st.sampled_from(["plain", "packed"]),
       fold=st.sampled_from([False, True]),
       spin=st.sampled_from([False, True]))
def test_kernel_transpose(variant, layout, fold, spin):
    from repro.kernels import ops as kops
    if spin and fold:
        return  # spin rows never fold
    l_max = 8
    a, y, m_vals, mp_vals, x32, pmm, pms = _kernel_operands(l_max, fold, spin)
    kw = dict(l_max=l_max, fold=fold, variant=variant, mp_vals=mp_vals,
              layout=layout)
    lhs = float(jnp.sum(kops.synth(a, m_vals, x32, pmm, pms, **kw) * y))
    rhs = float(jnp.sum(kops.anal(y, m_vals, x32, pmm, pms, **kw) * a))
    assert abs(lhs - rhs) <= 2e-4 * max(abs(lhs), abs(rhs), 1e-30), \
        (lhs, rhs)


# ---------------------------------------------------------------------------
# phase-stage custom rules: VJP transpose consistent with the JVP (forward
# linearisation), both engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid_kind", ["gl", "ecp", "healpix"])
def test_phase_vjp_jvp_consistency(grid_kind, seed=0):
    nside = 4
    g = gridlib.make_grid(grid_kind, l_max=6, nside=nside)
    m_max = 6 if g.uniform else 2 * nside
    t = shtlib.SHT(g, l_max=m_max, m_max=m_max)
    ph = t.phase
    rng = np.random.default_rng(seed)
    M, R = m_max + 1, g.n_rings
    d = jnp.asarray(rng.normal(size=(M, R, 1)) + 1j * rng.normal(size=(M, R, 1)))
    v = jnp.asarray(rng.normal(size=(M, R, 1)) + 1j * rng.normal(size=(M, R, 1)))
    # synth: <J v, t> == Re(sum(vjp(t) * v))  (JAX bilinear pairing)
    maps, vjp = jax.vjp(ph.synth, d)
    tmap = jnp.asarray(rng.normal(size=maps.shape))
    (ct,) = vjp(tmap)
    _, jv = jax.jvp(ph.synth, (d,), (v,))
    lhs = float(jnp.sum(jv * tmap))
    rhs = float(jnp.real(jnp.sum(ct * v)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10)
    # anal: same consistency on the reverse direction
    mreal = jnp.asarray(rng.normal(size=maps.shape))
    vm = jnp.asarray(rng.normal(size=maps.shape))
    dw, vjp2 = jax.vjp(ph.anal, mreal)
    ct_d = jnp.asarray(rng.normal(size=dw.shape) + 1j * rng.normal(size=dw.shape))
    (ctm,) = vjp2(ct_d)
    _, jv2 = jax.jvp(ph.anal, (mreal,), (vm,))
    lhs2 = float(jnp.real(jnp.sum(jv2 * ct_d)))
    rhs2 = float(jnp.sum(ctm * vm))
    np.testing.assert_allclose(lhs2, rhs2, rtol=1e-10)


# ---------------------------------------------------------------------------
# finite-difference gradient checks through the Plan API
# ---------------------------------------------------------------------------


def _grad_dir(g, v):
    """Directional derivative from a jax.grad result: Re(sum(g * v)).

    JAX's complex-gradient convention is grad = d/dRe - i * d/dIm, so the
    bilinear (non-conjugating) pairing reproduces the derivative.
    """
    return float(jnp.real(jnp.sum(g * v)))


def _check_grad_synth(plan, seed, rtol, layout=None):
    a = _rand_alm(plan, seed)
    t = _rand_maps(plan, seed + 1)
    v = _rand_alm(plan, seed + 2)
    synth = plan._synth_fn(plan.backends["synth"], layout)

    def loss(x):
        return jnp.sum(synth(x) * t)

    g = jax.grad(loss)(a)
    eps = 1e-6 if plan.dtype == "float64" else 1e-2
    fd = float((loss(a + eps * v) - loss(a - eps * v)) / (2 * eps))
    np.testing.assert_allclose(_grad_dir(g, v), fd, rtol=rtol,
                               atol=rtol * max(abs(fd), 1.0))


def _check_grad_anal(plan, seed, rtol, iters=0, layout=None):
    a = _rand_alm(plan, seed)
    maps0 = plan.alm2map(a)
    vm = _rand_maps(plan, seed + 3)
    anal = plan._anal_fn(plan.backends["anal"], layout)

    def loss(mp):
        alm = anal(mp)
        for _ in range(iters):
            alm = alm + anal(mp - plan.alm2map(alm))
        return jnp.sum(jnp.abs(alm) ** 2)

    g = jax.grad(loss)(maps0)
    eps = 1e-6 if plan.dtype == "float64" else 1e-2
    fd = float((loss(maps0 + eps * vm) - loss(maps0 - eps * vm)) / (2 * eps))
    np.testing.assert_allclose(float(jnp.sum(g * vm)), fd, rtol=rtol,
                               atol=rtol * max(abs(fd), 1.0))


@settings(max_examples=24, deadline=None)
@given(grid=st.sampled_from(GRIDS), spin=st.sampled_from([0, 2]),
       seed=st.integers(0, 10**6))
def test_gradcheck_jnp_f64(grid, spin, seed):
    plan = _make_plan(grid, 8, "float64", "jnp", spin=spin)
    _check_grad_synth(plan, seed, rtol=1e-6)
    _check_grad_anal(plan, seed, rtol=1e-6)


@settings(max_examples=16, deadline=None)
@given(backend=st.sampled_from(["pallas_vpu", "pallas_mxu"]),
       layout=st.sampled_from(["plain", "packed"]),
       spin=st.sampled_from([0, 2]))
def test_gradcheck_pallas_f32(backend, layout, spin):
    plan = _make_plan("gl", 8, "float32", backend, spin=spin)
    _check_grad_synth(plan, 7, rtol=1e-3, layout=layout)
    _check_grad_anal(plan, 11, rtol=1e-3, layout=layout)


def test_gradcheck_through_jacobi_iters():
    """map2alm(iters=1) (residual refinement) stays differentiable."""
    plan = _make_plan("healpix", 8, "float64", "jnp")
    _check_grad_anal(plan, 3, rtol=1e-6, iters=1)


def test_jvp_linearity_and_consistency():
    """JVP of a linear transform is the transform itself; VJP pairs with it."""
    plan = _make_plan("gl", 10, "float64", "jnp")
    a = _rand_alm(plan, 0)
    v = _rand_alm(plan, 1)
    y, dy = jax.jvp(plan.alm2map, (a,), (v,))
    np.testing.assert_allclose(np.asarray(dy), np.asarray(plan.alm2map(v)),
                               atol=1e-12)


def test_residual_gradients_raise_not_silently_zero():
    """d/d(weights, geometry, ...) is undefined under the adjoint rules;
    asking for it must raise, not return an all-zero gradient."""
    g = gridlib.gauss_legendre_grid(6)
    lm = legendre.log_mu(6)
    m_vals = np.arange(7)
    rng = np.random.default_rng(0)
    d_re = jnp.asarray(rng.normal(size=(7, g.n_rings, 1)))
    d_im = jnp.zeros_like(d_re)

    def loss_w(w):
        a_re, _ = legendre.alm_from_delta(d_re, d_im, m_vals, g.cos_theta,
                                          g.sin_theta, w, lm, l_max=6)
        return jnp.sum(a_re)

    with pytest.raises(ValueError, match="residual"):
        jax.grad(loss_w)(jnp.asarray(g.weights))


def test_grad_ready_surface():
    plan = _make_plan("gl", 8, "float64", "jnp")
    assert plan.grad_ready == {"synth": True, "anal": True}
    d = plan.describe()["differentiable"]
    assert d["synth"] and d["anal"] and d["higher_order"] is False


def test_grad_through_power_spectrum_loss():
    """The motivating workload: grad of a C_l-space loss wrt alm."""
    from repro.core import spectra
    plan = _make_plan("gl", 8, "float64", "jnp")
    a0 = _rand_alm(plan, 5)
    target = spectra.cl_from_alm(a0)

    def loss(a):
        cl = spectra.cl_from_alm(plan.map2alm(plan.alm2map(a)))
        return jnp.sum((cl - target) ** 2)

    g = jax.grad(loss)(a0 * 0.5)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.abs(g).max()) > 0.0
