"""Fused Legendre+phase pipeline (kernels/fused.py) and the persistent
per-hardware characterization DB (roofline/chardb.py): fused-vs-staged
equality, single-kernel (no Delta HBM round-trip) pin, adjointness of the
linear_pair wrappers, bf16 error band, plan-level dispatch/describe()
wiring, chardb staleness / reuse / fingerprint isolation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import cache as plancache
from repro.core import sht, spectra, transform
from repro.roofline import chardb

KEY = jax.random.PRNGKey(11)
LMAX, K = 24, 2


@pytest.fixture(autouse=True)
def _fresh_caches():
    transform.clear_plan_cache()
    plancache.reset_stats()
    chardb.clear()
    yield
    transform.clear_plan_cache()
    plancache.reset_stats()
    chardb.clear()


def _plan(l_max=LMAX, k=K, var="vpu", **kw):
    return repro.make_plan("gl", l_max=l_max, K=k, dtype="float32",
                           mode=f"pallas_{var}", cache="memory", **kw)


# ---------------------------------------------------------------------------
# fused == staged
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("var", ["vpu", "mxu"])
@pytest.mark.parametrize("l_max", [LMAX, 17])
def test_fused_matches_staged_synth(var, l_max):
    plan = _plan(l_max=l_max, var=var)
    alm = sht.random_alm(KEY, l_max, l_max, K=K).astype(jnp.complex64)
    got = plan._synth_fn(f"pallas_{var}", "fused")(alm)
    want = plan._synth_fn(f"pallas_{var}", "packed")(alm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5 * float(jnp.max(
                                   jnp.abs(want))))


@pytest.mark.parametrize("var", ["vpu", "mxu"])
@pytest.mark.parametrize("l_max", [LMAX, 17])
def test_fused_matches_staged_anal(var, l_max):
    plan = _plan(l_max=l_max, var=var)
    maps = jnp.asarray(
        np.random.default_rng(0).normal(
            size=(plan.grid.n_rings, plan.grid.max_n_phi, K)), jnp.float32)
    got = plan._anal_fn(f"pallas_{var}", "fused")(maps)
    want = plan._anal_fn(f"pallas_{var}", "packed")(maps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-5 * float(jnp.max(
                                   jnp.abs(want))))


def test_fused_roundtrip_accuracy():
    plan = _plan()
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
    synth = plan._synth_fn("pallas_vpu", "fused")
    anal = plan._anal_fn("pallas_vpu", "fused")
    err = float(spectra.d_err(alm, anal(synth(alm))))
    assert err < 1e-4, err


def test_fused_synth_is_one_kernel_no_delta_hbm():
    """The tentpole property: the fused pipeline runs Legendre+phase as a
    single pallas_call, so the Delta intermediate never round-trips HBM.
    The staged chain shows >= 2 device ops with the Delta array between
    them; fused must show exactly one pallas_call in its jaxpr."""
    plan = _plan()
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
    for direction, fn_of, arg in (("synth", plan._synth_fn, alm),):
        fused = fn_of("pallas_vpu", "fused")
        txt = str(jax.make_jaxpr(fused)(arg))
        assert txt.count("pallas_call") == 1, (direction, txt.count(
            "pallas_call"))
    maps = jnp.zeros((plan.grid.n_rings, plan.grid.max_n_phi, K),
                     jnp.float32)
    txt = str(jax.make_jaxpr(plan._anal_fn("pallas_vpu", "fused"))(maps))
    assert txt.count("pallas_call") == 1


# ---------------------------------------------------------------------------
# adjointness (linear_pair wiring)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("var", ["vpu", "mxu"])
def test_fused_synth_adjoint_identity(var):
    """<J v, y> == Re(sum(vjp(y) * v)) -- the JAX bilinear pairing, same
    convention as tests/test_adjoint.py."""
    plan = _plan(var=var)
    f = plan._synth_fn(f"pallas_{var}", "fused")
    rng = np.random.default_rng(3)
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
    v = sht.random_alm(jax.random.PRNGKey(4), LMAX, LMAX,
                       K=K).astype(jnp.complex64)
    y = jnp.asarray(rng.normal(size=(plan.grid.n_rings,
                                     plan.grid.max_n_phi, K)), jnp.float32)
    _, vjp = jax.vjp(f, alm)
    (ct,) = vjp(y)
    _, jv = jax.jvp(f, (alm,), (v,))
    lhs = float(jnp.sum(jv * y))
    rhs = float(jnp.real(jnp.sum(ct * v)))
    scale = max(abs(lhs), abs(rhs), 1.0)
    assert abs(lhs - rhs) / scale < 1e-4, (lhs, rhs)


def test_fused_anal_jvp_runs():
    plan = _plan()
    f = plan._anal_fn("pallas_vpu", "fused")
    maps = jnp.asarray(np.random.default_rng(5).normal(
        size=(plan.grid.n_rings, plan.grid.max_n_phi, K)), jnp.float32)
    out, tangent = jax.jvp(f, (maps,), (maps,))
    # linear map: f(x) pushed forward along x is f(x) itself
    np.testing.assert_allclose(np.asarray(tangent), np.asarray(out),
                               rtol=0, atol=1e-5 * float(jnp.max(
                                   jnp.abs(out))))


# ---------------------------------------------------------------------------
# bf16 MXU contraction error band
# ---------------------------------------------------------------------------


def test_fused_bf16_error_band():
    plan = _plan(var="mxu")
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
    m32 = plan._make_fused_synth("mxu", bf16=False)(alm)
    m16 = plan._make_fused_synth("mxu", bf16=True)(alm)
    err = float(jnp.max(jnp.abs(m16 - m32)) / jnp.max(jnp.abs(m32)))
    assert 0.0 < err < 1e-2, err    # bf16 differs from f32 but stays banded
    a32 = plan._make_fused_anal("mxu", bf16=False)(m32)
    a16 = plan._make_fused_anal("mxu", bf16=True)(m32)
    err = float(jnp.max(jnp.abs(a16 - a32)) / jnp.max(jnp.abs(a32)))
    assert 0.0 < err < 1e-2, err


# ---------------------------------------------------------------------------
# eligibility + describe()
# ---------------------------------------------------------------------------


def test_fusion_eligible_on_uniform_spin0():
    plan = _plan()
    ok, reason = plan._fusion_eligibility()
    assert ok and reason is None
    assert "fused" in plan._pallas_layouts()
    d = plan.describe()["fusion"]
    assert d["eligible"] is True and d["reason"] is None
    assert set(d["pipelines"]) == {"synth", "anal"}
    for direction in ("synth", "anal"):
        assert d["pipelines"][direction] in ("fused", "staged")
        assert d["active"][direction] == (
            plan.layouts[direction] == "fused")


# ---------------------------------------------------------------------------
# full coverage: spin-2, equator fold, bucketed (HEALPix) through the
# fused pipeline
# ---------------------------------------------------------------------------

SHAPES = ["fold", "spin2", "bucket", "spin2-bucket"]


def _shape_plan(shape, var="vpu", k=K):
    kw = dict(K=k, dtype="float32", mode=f"pallas_{var}", cache="memory")
    if shape == "fold":
        return repro.make_plan("gl", l_max=LMAX, fold=True, **kw)
    if shape == "spin2":
        return repro.make_plan("gl", l_max=LMAX, spin=2, **kw)
    if shape == "bucket":
        return repro.make_plan("healpix", nside=8, **kw)
    assert shape == "spin2-bucket", shape
    return repro.make_plan("healpix", nside=8, spin=2, **kw)


def _shape_alm(plan, key=KEY):
    mk = sht.random_alm_spin if plan.spin else sht.random_alm
    return mk(key, plan.l_max, plan.m_max, K=plan.K).astype(jnp.complex64)


def _assert_fused_matches_staged(plan, var="vpu", tol=1e-5):
    ok, reason = plan._fusion_eligibility()
    assert ok, reason
    alm = _shape_alm(plan)
    got = plan._synth_fn(f"pallas_{var}", "fused")(alm)
    want = plan._synth_fn(f"pallas_{var}", "packed")(alm)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=0,
        atol=tol * float(jnp.max(jnp.abs(want))))
    ga = plan._anal_fn(f"pallas_{var}", "fused")(want)
    wa = plan._anal_fn(f"pallas_{var}", "packed")(want)
    np.testing.assert_allclose(
        np.asarray(ga), np.asarray(wa), rtol=0,
        atol=tol * float(jnp.max(jnp.abs(wa))))


@pytest.mark.parametrize("var", ["vpu", "mxu"])
@pytest.mark.parametrize("shape", SHAPES)
def test_fused_matches_staged_all_shapes(shape, var):
    _assert_fused_matches_staged(_shape_plan(shape, var=var), var=var)


@pytest.mark.parametrize("var", ["vpu", "mxu"])
@pytest.mark.parametrize("shape", SHAPES)
def test_fused_gradients_match_staged_all_shapes(shape, var):
    """linear_pair wiring per variant: the fused VJPs must equal the
    staged VJPs (property-tested in tests/test_adjoint.py) both ways."""
    plan = _shape_plan(shape, var=var)
    alm = _shape_alm(plan)
    maps, vjp_f = jax.vjp(plan._synth_fn(f"pallas_{var}", "fused"), alm)
    _, vjp_s = jax.vjp(plan._synth_fn(f"pallas_{var}", "packed"), alm)
    t = jax.random.normal(jax.random.PRNGKey(8), maps.shape, maps.dtype)
    (cf,), (cs,) = vjp_f(t), vjp_s(t)
    rel = float(jnp.max(jnp.abs(cf - cs)) / (jnp.max(jnp.abs(cs)) + 1e-30))
    assert rel < 1e-4, (shape, var, rel)
    _, vjpa_f = jax.vjp(plan._anal_fn(f"pallas_{var}", "fused"), maps)
    _, vjpa_s = jax.vjp(plan._anal_fn(f"pallas_{var}", "packed"), maps)
    g = _shape_alm(plan, key=jax.random.PRNGKey(9))
    (mf,), (ms,) = vjpa_f(g), vjpa_s(g)
    rel = float(jnp.max(jnp.abs(mf - ms)) / (jnp.max(jnp.abs(ms)) + 1e-30))
    assert rel < 1e-4, (shape, var, rel)


def test_fused_edge_fold_odd_rings_k1():
    """Odd ring count exercises the folded equator zero-pad; K=1 the
    minimal channel block."""
    plan = repro.make_plan("gl", l_max=16, K=1, dtype="float32",
                           mode="pallas_vpu", cache="memory", fold=True)
    assert plan.grid.n_rings % 2 == 1
    _assert_fused_matches_staged(plan)


def test_fused_edge_spin2_odd_lmax_k1():
    plan = repro.make_plan("gl", l_max=17, K=1, dtype="float32",
                           mode="pallas_vpu", cache="memory", spin=2)
    _assert_fused_matches_staged(plan)


def test_fused_edge_single_bucket_healpix():
    """nside=2 collapses every HEALPix ring into one FFT bucket -- the
    degenerate bin-map scatter."""
    plan = repro.make_plan("healpix", nside=2, K=1, dtype="float32",
                           mode="pallas_vpu", cache="memory")
    assert plan.phase.layout.n_buckets == 1
    _assert_fused_matches_staged(plan)


def test_fused_bucket_synth_is_one_kernel():
    """The bucket engine must also skip the Delta HBM round-trip."""
    plan = _shape_plan("bucket")
    alm = _shape_alm(plan)
    txt = str(jax.make_jaxpr(plan._synth_fn("pallas_vpu", "fused"))(alm))
    assert txt.count("pallas_call") == 1


# ---------------------------------------------------------------------------
# residual ineligible shapes + the $REPRO_LEGENDRE_LAYOUT override
# ---------------------------------------------------------------------------


def test_fusion_ineligible_fold_on_bucket():
    plan = repro.make_plan("healpix", nside=8, fold=True, dtype="float32",
                           mode="pallas_vpu", cache="memory")
    ok, reason = plan._fusion_eligibility()
    assert not ok and "fold" in reason
    assert "fused" not in plan._pallas_layouts()
    with pytest.raises(ValueError, match="fused layout unavailable"):
        plan._synth_fn("pallas_vpu", "fused")
    d = plan.describe()["fusion"]
    assert d["eligible"] is False
    assert d["skipped"] == reason


def test_fusion_ineligible_spin2_nyquist():
    from repro.core import grids
    g = grids.gauss_legendre_grid(LMAX, n_phi=2 * LMAX)
    plan = repro.make_plan(g, l_max=LMAX, K=1, dtype="float32", spin=2,
                           mode="pallas_vpu", cache="memory")
    ok, reason = plan._fusion_eligibility()
    assert not ok and "Nyquist" in reason
    assert "fused" not in plan._pallas_layouts()
    with pytest.raises(ValueError, match="fused layout unavailable"):
        plan._anal_fn("pallas_vpu", "fused")
    assert plan.describe()["fusion"]["skipped"] == reason


def test_layout_env_override_raises_on_ineligible(monkeypatch):
    plan = repro.make_plan("healpix", nside=8, fold=True, dtype="float32",
                           mode="pallas_vpu", cache="memory")
    monkeypatch.setenv("REPRO_LEGENDRE_LAYOUT", "fused")
    with pytest.raises(ValueError, match="ineligible"):
        plan._synth_fn("pallas_vpu", "packed")
    with pytest.raises(ValueError, match="equator fold"):
        plan._anal_fn("pallas_vpu", "packed")


def test_layout_env_override_routes_eligible_to_fused(monkeypatch):
    plan = _plan()
    monkeypatch.setenv("REPRO_LEGENDRE_LAYOUT", "fused")
    fn = plan._synth_fn("pallas_vpu", "packed")
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
    txt = str(jax.make_jaxpr(fn)(alm))
    assert txt.count("pallas_call") == 1    # rerouted onto the fused kernel


def test_ops_pick_layout_env_fused_rejected(monkeypatch):
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_LEGENDRE_LAYOUT", "fused")
    with pytest.raises(ValueError, match="plan level"):
        ops.pick_layout(np.arange(4))


def test_ops_pick_layout_traced_warns_once_then_degrades():
    import warnings as _warnings

    from repro.kernels import ops
    ops._TRACED_WARNED = False
    picked = []

    @jax.jit
    def probe(m):
        picked.append(ops.pick_layout(m))
        return m

    with pytest.warns(RuntimeWarning, match="plain rectangular"):
        probe(jnp.arange(4))
    assert picked == ["plain"]

    @jax.jit
    def probe2(m):
        picked.append(ops.pick_layout(m, layout="packed"))
        return m

    with _warnings.catch_warnings():        # one-time: no second warning
        _warnings.simplefilter("error")
        probe2(jnp.arange(5))
    assert picked[-1] == "plain"


# ---------------------------------------------------------------------------
# characterization DB
# ---------------------------------------------------------------------------


def test_chardb_measures_once_then_reuses(tmp_path):
    db = chardb.CharDB("cafe" * 4, "test-hw", str(tmp_path))
    calls = []

    def measure():
        calls.append(1)
        return 42.0

    us, status = db.get_or_measure(measure, l_max=8, backend="pallas_vpu")
    assert (us, status) == (42.0, "measured") and len(calls) == 1
    us, status = db.get_or_measure(measure, l_max=8, backend="pallas_vpu")
    assert (us, status) == (42.0, "reused") and len(calls) == 1
    # a fresh DB instance on the same directory reloads from disk
    db2 = chardb.CharDB("cafe" * 4, "test-hw", str(tmp_path))
    us, status = db2.get_or_measure(measure, l_max=8, backend="pallas_vpu")
    assert (us, status) == (42.0, "reused") and len(calls) == 1


def test_chardb_stale_schema_remeasured(tmp_path):
    db = chardb.CharDB("beef" * 4, "test-hw", str(tmp_path))
    key = db.corner_key(l_max=8, backend="jnp")
    db._store[key] = {"schema": chardb.SCHEMA - 1, "us": 1.0, "fields": {}}
    assert db.lookup(l_max=8, backend="jnp") is None
    us, status = db.get_or_measure(lambda: 7.0, l_max=8, backend="jnp")
    assert (us, status) == (7.0, "measured")
    assert db.counters["stale"] == 1
    assert db.lookup(l_max=8, backend="jnp")["us"] == 7.0


def test_chardb_fingerprint_isolation(tmp_path):
    """Corners measured on one hardware fingerprint must never leak into
    another DB sharing the same cache directory (the hardware-key
    collision regression)."""
    a = chardb.CharDB("a" * 16, "hw-a", str(tmp_path))
    b = chardb.CharDB("b" * 16, "hw-b", str(tmp_path))
    a.get_or_measure(lambda: 1.0, l_max=8, backend="jnp")
    assert a.path != b.path
    assert b.lookup(l_max=8, backend="jnp") is None
    us, status = b.get_or_measure(lambda: 2.0, l_max=8, backend="jnp")
    assert (us, status) == (2.0, "measured")
    # reload both from disk: each sees only its own corner value
    assert chardb.CharDB("a" * 16, "hw-a", str(tmp_path)).lookup(
        l_max=8, backend="jnp")["us"] == 1.0
    assert chardb.CharDB("b" * 16, "hw-b", str(tmp_path)).lookup(
        l_max=8, backend="jnp")["us"] == 2.0


def test_chardb_corner_key_order_invariant():
    k1 = chardb.CharDB.corner_key(l_max=8, backend="jnp", K=2)
    k2 = chardb.CharDB.corner_key(K=2, backend="jnp", l_max=8)
    k3 = chardb.CharDB.corner_key(K=3, backend="jnp", l_max=8)
    assert k1 == k2 and k1 != k3


def test_chardb_smoke_skips_missing_reuses_present(monkeypatch, tmp_path):
    db = chardb.CharDB("d00d" * 4, "test-hw", str(tmp_path))
    db.get_or_measure(lambda: 5.0, l_max=8, backend="jnp")
    monkeypatch.setenv("REPRO_CHARDB_SMOKE", "1")
    assert chardb.smoke_mode()
    us, status = db.get_or_measure(lambda: 9.0, l_max=8, backend="jnp")
    assert (us, status) == (5.0, "reused")        # present: reused
    us, status = db.get_or_measure(lambda: 9.0, l_max=99, backend="jnp")
    assert (us, status) == (None, "skipped")      # missing: never timed
    assert db.counters["skipped"] == 1


def test_chardb_exception_not_stored(tmp_path):
    db = chardb.CharDB("f00d" * 4, "test-hw", str(tmp_path))

    def boom():
        raise RuntimeError("transient")

    with pytest.raises(RuntimeError):
        db.get_or_measure(boom, l_max=8, backend="jnp")
    assert db.lookup(l_max=8, backend="jnp") is None    # retryable
    us, status = db.get_or_measure(lambda: 3.0, l_max=8, backend="jnp")
    assert (us, status) == (3.0, "measured")


def test_auto_plan_second_build_remeasures_zero_corners():
    """The acceptance property: after a first mode='auto' build
    characterizes its corners, clearing every plan/decision cache and
    rebuilding re-measures nothing -- all corners come from the chardb."""
    chardb.get_db().counters.update(
        {k: 0 for k in chardb.get_db().counters})
    repro.make_plan("gl", l_max=8, K=1, dtype="float32", mode="auto",
                    cache="memory")
    first = dict(chardb.get_db().counters)
    assert first["measured"] > 0
    transform.clear_plan_cache()
    plancache.clear_memory()          # decision cache gone too
    chardb.reset_stats()
    plan = repro.make_plan("gl", l_max=8, K=1, dtype="float32", mode="auto",
                           cache="memory")
    again = dict(chardb.get_db().counters)
    assert again["measured"] == 0, again
    assert again["reused"] >= first["measured"]
    assert plan.backends["synth"] in transform.BACKENDS
    ch = plan.describe()["cache"]["chardb"]
    assert ch["corners"] >= first["measured"]


def test_auto_plan_smoke_mode_model_fallback(monkeypatch):
    """REPRO_CHARDB_SMOKE on a cold signature: zero corners are timed and
    dispatch falls back to the cost-model ordering (decision not saved)."""
    monkeypatch.setenv("REPRO_CHARDB_SMOKE", "1")
    chardb.clear()
    plan = repro.make_plan("gl", l_max=10, K=1, dtype="float32",
                           mode="auto", cache="memory")
    st = chardb.stats()
    assert st["measured"] == 0 and st["skipped"] > 0
    assert plan.cache_events.get("decision") == "model-fallback"
    assert plan.backends["synth"] in transform.BACKENDS
    alm = sht.random_alm(KEY, 10, 10, K=1).astype(jnp.complex64)
    maps = plan.alm2map(alm)        # the fallback plan still transforms
    assert np.all(np.isfinite(np.asarray(maps)))


def test_fused_lp_candidates_schedule():
    from repro.kernels import pack as kpack
    assert kpack.fused_lp_candidates(24) == (128,)
    assert kpack.fused_lp_candidates(127) == (128,)
    assert kpack.fused_lp_candidates(128) == (128, 256)


def test_chardb_lp_corners_remeasured_zero(monkeypatch):
    """Block-shape (lp_size) autotune corners persist in the chardb: a
    second plan build after clearing every plan/decision cache re-measures
    zero corners, and picks the same panel length."""
    from repro.kernels import pack as kpack
    monkeypatch.setattr(kpack, "fused_lp_candidates",
                        lambda l_max: (128, 256))
    plan = repro.make_plan("gl", l_max=8, K=1, dtype="float32", mode="auto",
                           cache="memory")
    lp1 = plan._fused_lp_size()
    assert lp1 in (128, 256)
    db = chardb.get_db()
    lp_sizes = {rec["fields"].get("lp_size")
                for rec in db._store.values()
                if rec["fields"].get("layout") == "fused"}
    assert {128, 256} <= lp_sizes        # both candidates characterized
    assert db.counters["measured"] > 0
    transform.clear_plan_cache()
    plancache.clear_memory()
    chardb.reset_stats()
    plan2 = repro.make_plan("gl", l_max=8, K=1, dtype="float32",
                            mode="auto", cache="memory")
    assert plan2._fused_lp_size() == lp1
    again = dict(chardb.get_db().counters)
    assert again["measured"] == 0, again
    assert plan2.describe()["fusion"]["lp_size"] == lp1
