"""Golden-value tests: single-coefficient synthesis against closed-form
spherical harmonics.

Round-trip tests can pass with a wrong normalisation or phase convention
(analysis absorbs whatever synthesis emits); these tests pin the absolute
convention instead.  A field with exactly one nonzero coefficient a_lm = 1
synthesises to

    s(theta, phi) = fac_m * lambda_lm(theta) * cos(m phi),
    fac_m = 1 (m = 0) | 2 (m > 0)

with lambda_lm the orthonormalised associated Legendre function WITHOUT
the Condon-Shortley phase (this repo's convention: the P_mm seed
``mu_m sin^m theta`` is positive).  The reference values are built from
``numpy.polynomial.legendre`` derivatives of P_l -- closed forms entirely
independent of the repro recurrence code -- for every (l, m) with
l <= 4.

The spin-2 goldens use the explicit Wigner-d l = 2 seed formulas
(lam^{(+-2)}_{2,m}; Goldberg et al. conventions as spelled out in
core/legendre.py) to check the full E/B <-> Q/U pipeline: a pure-E or
pure-B single coefficient produces Q/U maps with hand-computable theta
profiles and cos/sin azimuthal structure.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest

import repro
from repro.core import sht as shtlib

L_MAX = 6          # plan band-limit (> 4 so l <= 4 modes are interior)


def _lambda_lm(l, m, x):
    """Orthonormal associated Legendre (no Condon-Shortley), from numpy's
    Legendre-polynomial derivatives: lambda_lm = N_lm (1-x^2)^{m/2} d^m P_l.
    """
    from numpy.polynomial import legendre as npleg
    c = np.zeros(l + 1)
    c[l] = 1.0
    dm = npleg.legder(c, m) if m else c
    plm = npleg.legval(x, dm) * np.sqrt(1.0 - x * x) ** m
    norm = math.sqrt((2 * l + 1) / (4.0 * math.pi)
                     * math.factorial(l - m) / math.factorial(l + m))
    return norm * plm


@pytest.fixture(scope="module")
def plan():
    return repro.make_plan("gl", l_max=L_MAX, dtype="float64", mode="jnp")


@pytest.mark.parametrize("l,m", [(l, m) for l in range(5)
                                 for m in range(l + 1)])
def test_scalar_single_coefficient_golden(plan, l, m):
    g = plan.grid
    alm = np.zeros((L_MAX + 1, L_MAX + 1, 1), np.complex128)
    alm[m, l, 0] = 1.0
    maps = np.asarray(plan.alm2map(jnp.asarray(alm)))[:, :, 0]
    phi = 2.0 * np.pi * np.arange(g.max_n_phi) / g.max_n_phi
    fac = 1.0 if m == 0 else 2.0
    expect = fac * _lambda_lm(l, m, g.cos_theta)[:, None] \
        * np.cos(m * phi)[None, :]
    np.testing.assert_allclose(maps, expect, atol=1e-13)


def test_scalar_imaginary_coefficient_golden(plan):
    """a_lm = i (m > 0) synthesises the -sin(m phi) azimuthal mode."""
    g = plan.grid
    l, m = 3, 2
    alm = np.zeros((L_MAX + 1, L_MAX + 1, 1), np.complex128)
    alm[m, l, 0] = 1.0j
    maps = np.asarray(plan.alm2map(jnp.asarray(alm)))[:, :, 0]
    phi = 2.0 * np.pi * np.arange(g.max_n_phi) / g.max_n_phi
    expect = -2.0 * _lambda_lm(l, m, g.cos_theta)[:, None] \
        * np.sin(m * phi)[None, :]
    np.testing.assert_allclose(maps, expect, atol=1e-13)


def test_analysis_single_coefficient_golden(plan):
    """map2alm of a golden-synthesised mode recovers exactly that
    coefficient (exact GL quadrature), pinning the analysis normalisation
    against the same closed forms."""
    g = plan.grid
    l, m = 4, 3
    phi = 2.0 * np.pi * np.arange(g.max_n_phi) / g.max_n_phi
    maps = 2.0 * _lambda_lm(l, m, g.cos_theta)[:, None] * np.cos(m * phi)
    alm = np.asarray(plan.map2alm(jnp.asarray(maps[..., None])))[:, :, 0]
    expect = np.zeros_like(alm)
    expect[m, l] = 1.0
    np.testing.assert_allclose(alm, expect, atol=1e-12)


# ---------------------------------------------------------------------------
# spin-2 goldens from the explicit l = 2 Wigner-d seed formulas
# ---------------------------------------------------------------------------


def _lam2(mprime, m, x):
    """lam^{(m')}_{2,m}(theta) closed forms, m' = +-2, m = 0, 1, 2."""
    s = np.sqrt(1.0 - x * x)
    c5 = math.sqrt(5.0 / (4.0 * math.pi))
    if m == 0:
        return c5 * (math.sqrt(6.0) / 4.0) * s * s
    if m == 1:
        return c5 * 0.5 * s * (1.0 - x) if mprime == -2 \
            else -c5 * 0.5 * s * (1.0 + x)
    assert m == 2
    half_c2 = (1.0 + x) / 2.0          # cos^2(theta/2)
    half_s2 = (1.0 - x) / 2.0          # sin^2(theta/2)
    return c5 * (half_c2 ** 2 if mprime == 2 else half_s2 ** 2)


@pytest.fixture(scope="module")
def plan_spin():
    return repro.make_plan("gl", l_max=L_MAX, dtype="float64", mode="jnp",
                           spin=2)


@pytest.mark.parametrize("m", [0, 1, 2])
@pytest.mark.parametrize("comp", ["E", "B"])
def test_spin2_single_coefficient_golden(plan_spin, m, comp):
    """Pure E_2m = 1 (or B_2m = 1) against hand-derived Q/U maps.

    With a^{+-} = -(E +- iB) and Delta_Q/U = (Delta^+ +- Delta^-) / 2
    (Delta^{+-} built from lam^{(-+2)}), a unit coefficient gives

      E: Q = -fac (lam^- + lam^+)/2 cos(m phi),
         U = -fac (lam^- - lam^+)/2 sin(m phi)
      B: Q = -fac (lam^+ - lam^-)/2 sin(m phi),
         U = -fac (lam^+ + lam^-)/2 cos(m phi)

    where lam^{-+} = lam^{(-2)}_{2,m}, lam^{(+2)}_{2,m} and fac as usual.
    """
    g = plan_spin.grid
    alm = np.zeros((2, L_MAX + 1, L_MAX + 1, 1), np.complex128)
    alm[0 if comp == "E" else 1, m, 2, 0] = 1.0
    qu = np.asarray(plan_spin.alm2map(jnp.asarray(alm)))[..., 0]
    x = g.cos_theta
    lam_m = _lam2(-2, m, x)[:, None]
    lam_p = _lam2(+2, m, x)[:, None]
    phi = 2.0 * np.pi * np.arange(g.max_n_phi) / g.max_n_phi
    fac = 1.0 if m == 0 else 2.0
    cos, sin = np.cos(m * phi)[None, :], np.sin(m * phi)[None, :]
    if comp == "E":
        q = -fac * (lam_m + lam_p) / 2.0 * cos
        u = -fac * (lam_m - lam_p) / 2.0 * sin
    else:
        q = -fac * (lam_p - lam_m) / 2.0 * sin
        u = -fac * (lam_p + lam_m) / 2.0 * cos
    np.testing.assert_allclose(qu[0], q, atol=1e-13)
    np.testing.assert_allclose(qu[1], u, atol=1e-13)


def test_spin2_matches_scalar_at_high_l(plan_spin):
    """Cross-check the generalised recurrence beyond the seed row: a pure-E
    mode at l = 4 synthesises |Q+iU| with the (4-2)!/(4+2)! spin-raising
    norm -- verified here against the f64 oracle round-trip instead of a
    table: synth then analyse must return the unit coefficient."""
    alm = np.zeros((2, L_MAX + 1, L_MAX + 1, 1), np.complex128)
    alm[0, 3, 4, 0] = 1.0
    back = np.asarray(plan_spin.map2alm(plan_spin.alm2map(jnp.asarray(alm))))
    np.testing.assert_allclose(back, alm, atol=1e-12)
