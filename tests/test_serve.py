"""The SHT serving engine: K-coalescing correctness, signature grouping,
FIFO fairness, futures, percentile math, and the warm plan pool."""

import threading
import time

import numpy as np
import pytest

import repro
from repro.core import cache as plancache
from repro.core import sht, spectra, transform
from repro.serve import (BackpressureError, InvalidStateError, PlanPool,
                         PlanSig, ShtEngine, ShtFuture, ShtRequest,
                         percentile)

from _hypothesis_compat import given, settings, strategies as st

LMAX = 16


@pytest.fixture(autouse=True)
def _fresh_caches():
    transform.clear_plan_cache()
    plancache.reset_stats()
    yield
    transform.clear_plan_cache()
    plancache.reset_stats()


def _alm(seed, l_max=LMAX, K=None, spin=0):
    fn = sht.random_alm_spin if spin else sht.random_alm
    a = np.asarray(fn(seed=seed, l_max=l_max, m_max=l_max, K=K or 1))
    return a if K else a[..., 0]


def _engine(**kw):
    kw.setdefault("max_k", 4)
    kw.setdefault("mode", "jnp")
    return ShtEngine(**kw)


# -- coalescing correctness ---------------------------------------------------


def test_coalesced_batch_matches_independent_plan_calls():
    """A K-stacked batch of mixed requests returns results identical to
    per-request Plan calls (synthesis bitwise on the f64 jnp path;
    analysis to 1e-12 -- the contraction order over K may differ)."""
    eng = _engine(max_k=4)
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    alms = [_alm(seed=i) for i in range(3)]
    maps = [np.asarray(plan.alm2map(a[..., None]))[..., 0] for a in alms]

    futs_s = [eng.submit(direction="alm2map", payload=a, grid="gl",
                         l_max=LMAX) for a in alms]
    futs_a = [eng.submit(direction="map2alm", payload=m, grid="gl",
                         l_max=LMAX) for m in maps]
    eng.drain()

    for f, ref in zip(futs_s, maps):
        np.testing.assert_array_equal(f.result(), ref)     # bit-identical
    for f, a in zip(futs_a, alms):
        ref = np.asarray(plan.map2alm(
            np.asarray(plan.alm2map(a[..., None]))))[..., 0]
        assert np.max(np.abs(f.result() - ref)) < 1e-12
    # the synthesis requests actually shared one device batch
    synth_batches = [b for b in eng.batch_log
                     if b["direction"] == "alm2map"]
    assert len(synth_batches) == 1
    assert synth_batches[0]["n_requests"] == 3


def test_coalesced_multi_k_and_spin2_requests():
    """Requests carrying their own K axis, and spin-2 (E,B)->(Q,U) pairs,
    coalesce and come back allclose to independent plans (f64 <= 1e-12)."""
    eng = _engine(max_k=8)
    a2 = _alm(seed=0, K=2)                       # (M, L, 2)
    a1 = _alm(seed=1)                            # (M, L)
    s2 = _alm(seed=2, spin=2)                    # (2, M, L)
    f2 = eng.submit(direction="alm2map", payload=a2, grid="gl", l_max=LMAX)
    f1 = eng.submit(direction="alm2map", payload=a1, grid="gl", l_max=LMAX)
    fs = eng.submit(direction="alm2map", payload=s2, grid="gl", l_max=LMAX,
                    spin=2)
    eng.drain()

    p2 = repro.make_plan("gl", l_max=LMAX, K=2, dtype="float64", mode="jnp")
    p1 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64", mode="jnp")
    ps = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64", mode="jnp",
                         spin=2)
    assert np.max(np.abs(f2.result() - np.asarray(p2.alm2map(a2)))) < 1e-12
    assert np.max(np.abs(f1.result()
                         - np.asarray(p1.alm2map(a1[..., None]))[..., 0])) \
        < 1e-12
    assert np.max(np.abs(fs.result()
                         - np.asarray(ps.alm2map(s2[..., None]))[..., 0])) \
        < 1e-12
    # scalar requests coalesced (K=2 + K=1 -> one batch); spin-2 separate
    scalar = [b for b in eng.batch_log if "spin0" in b["signature"]]
    assert len(scalar) == 1 and scalar[0]["k_total"] == 3
    assert scalar[0]["k_plan"] == 4              # padded to the K bucket


def test_no_cross_signature_mixing():
    """Different (grid, l_max, spin, dtype) signatures never share a
    device batch, even when submitted interleaved."""
    eng = _engine(max_k=8)
    for i in range(3):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
        eng.submit(direction="alm2map", payload=_alm(seed=10 + i, l_max=24),
                   grid="gl", l_max=24)
    eng.drain()
    assert len(eng.batch_log) == 2
    for b in eng.batch_log:
        assert b["n_requests"] == 3              # each group fully coalesced
    assert {b["signature"] for b in eng.batch_log} == \
        {"gl/lmax16/spin0/float64", "gl/lmax24/spin0/float64"}


def test_direction_and_iters_split_groups():
    """alm2map vs map2alm, and differing Jacobi iters, are separate
    groups -- they cannot share one device call."""
    eng = _engine(max_k=8)
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    m = np.asarray(plan.alm2map(_alm(seed=0)[..., None]))[..., 0]
    eng.submit(direction="alm2map", payload=_alm(seed=1), grid="gl",
               l_max=LMAX)
    eng.submit(direction="map2alm", payload=m, grid="gl", l_max=LMAX)
    eng.submit(direction="map2alm", payload=m, grid="gl", l_max=LMAX,
               iters=1)
    eng.drain()
    assert len(eng.batch_log) == 3


def test_fifo_within_signature():
    """Requests of one signature retire in submission order, across
    however many micro-batches the max_k budget forces."""
    eng = _engine(max_k=2)
    futs = [eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                       l_max=LMAX) for i in range(5)]
    eng.drain()
    rids = [rid for b in eng.batch_log for rid in b["rids"]]
    assert rids == [f.rid for f in futs]         # strict FIFO
    assert [b["n_requests"] for b in eng.batch_log] == [2, 2, 1]


def test_oldest_request_picks_next_group():
    """Across signatures the batch former serves the group whose head
    waited longest (no starvation of a low-traffic signature)."""
    eng = _engine(max_k=8)
    f_old = eng.submit(direction="alm2map", payload=_alm(seed=0, l_max=24),
                       grid="gl", l_max=24)
    for i in range(3):
        eng.submit(direction="alm2map", payload=_alm(seed=1 + i), grid="gl",
                   l_max=LMAX)
    assert eng.step() > 0
    assert f_old.done()                          # oldest head went first


# -- futures ------------------------------------------------------------------


def test_futures_resolve_exactly_once():
    eng = _engine()
    fut = eng.submit(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX)
    eng.drain()
    assert fut.done()
    r1 = fut.result()
    assert r1 is fut.result()                    # cached, not recomputed
    with pytest.raises(InvalidStateError):
        fut._resolve(None)
    with pytest.raises(InvalidStateError):
        fut._fail(RuntimeError("x"))
    f = ShtFuture(rid=99)
    f._resolve(1)
    with pytest.raises(InvalidStateError):
        f._resolve(2)


def test_future_timing_populated():
    eng = _engine()
    fut = eng.submit(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX)
    eng.drain()
    t = fut.timing
    assert t["total_s"] >= t["compute_s"] >= 0
    assert t["queue_s"] >= 0
    assert t["k_plan"] == 1 and t["coalesced_with"] == 0


def test_submit_validation_is_eager():
    eng = _engine()
    with pytest.raises(ValueError):              # bad direction
        eng.submit(direction="sideways", payload=_alm(seed=0))
    with pytest.raises(ValueError):              # real payload for alm2map
        eng.submit(direction="alm2map", payload=np.zeros((17, 17)))
    with pytest.raises(ValueError):              # complex maps payload
        eng.submit(direction="map2alm",
                   payload=np.zeros((17, 34), complex))
    with pytest.raises(ValueError):              # ndim mismatch for spin
        eng.submit(direction="alm2map", payload=_alm(seed=0), spin=2)
    with pytest.raises(ValueError):              # K wider than the engine
        eng.submit(direction="alm2map", payload=_alm(seed=0, K=9),
                   grid="gl", l_max=LMAX)
    assert eng.pending == 0                      # nothing leaked into queue


# -- stats() ------------------------------------------------------------------


def test_percentile_pinned_against_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 101):
        xs = rng.exponential(size=n).tolist()
        for q in (0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0):
            np.testing.assert_allclose(percentile(xs, q),
                                       np.percentile(xs, q), rtol=1e-12)
    assert np.isnan(percentile([], 50.0))


def test_stats_shape_and_counters():
    eng = _engine(max_k=4)
    for i in range(4):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    s = eng.stats()
    assert s["requests"]["submitted"] == 4
    assert s["requests"]["completed"] == 4
    assert s["requests"]["pending"] == 0
    assert s["coalescing"]["batches"] == 1
    assert s["coalescing"]["k_per_batch"] == 4.0
    assert s["coalescing"]["k_occupancy"] == 1.0
    lat = s["latency"]["total"]
    assert lat["count"] == 4
    assert lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]
    assert np.isfinite(s["throughput_rps"]) and s["throughput_rps"] > 0
    r = eng.report()
    assert "p99" in r and "coalescing" in r and "pool" in r


def test_stats_percentiles_match_numpy_over_recorded_latencies():
    eng = _engine(max_k=1)                       # one batch per request
    for i in range(5):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    xs = eng._lat_total.samples()
    assert len(xs) == 5
    s = eng.stats()["latency"]["total"]
    np.testing.assert_allclose(s["p50_s"], np.percentile(xs, 50))
    np.testing.assert_allclose(s["p95_s"], np.percentile(xs, 95))
    np.testing.assert_allclose(s["p99_s"], np.percentile(xs, 99))


# -- warm plan pool -----------------------------------------------------------


def test_pool_hits_and_warmup():
    eng = _engine(max_k=2)
    eng.prewarm(grid="gl", l_max=LMAX, dtype="float64")
    assert eng.pool.stats()["warmups"] == 1
    for i in range(4):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    p = eng.pool.stats()
    # prewarm built the (sig, max_k=2) plan; both batches then hit it
    assert p["misses"] == 1 and p["hits"] == 2
    assert eng.stats()["pool"]["hit_rate"] == pytest.approx(2 / 3)
    # fused-pipeline coverage of the warm set: the gl plan is eligible
    f = p["fusion"]
    assert f["eligible"] == 1 and f["staged"] == 0
    assert f["active"] in (0, 1)        # autotune decides the dispatch


def test_pool_lru_eviction_releases_plans():
    pool = PlanPool(capacity=2, mode="jnp")
    sigs = [PlanSig(grid="gl", l_max=8 * (i + 1), dtype="float64")
            for i in range(3)]
    plans = [pool.get(s, 1) for s in sigs]
    assert pool.stats()["evictions"] == 1
    assert len(pool) == 2
    # the evicted plan is also gone from make_plan's memoisation...
    key0 = plans[0]._signature_key
    assert key0 not in transform._PLANS
    # ...while the survivors are still memoised
    assert plans[2]._signature_key in transform._PLANS
    # re-requesting the evicted signature rebuilds (a miss, not a hit)
    misses = pool.stats()["misses"]
    pool.get(sigs[0], 1)
    assert pool.stats()["misses"] == misses + 1


def test_background_thread_serves():
    eng = _engine(max_k=4)
    with eng:
        futs = [eng.submit(direction="alm2map", payload=_alm(seed=i),
                           grid="gl", l_max=LMAX) for i in range(3)]
        res = [f.result(timeout=120) for f in futs]
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    for a, r in zip([_alm(seed=i) for i in range(3)], res):
        ref = np.asarray(plan.alm2map(a[..., None]))[..., 0]
        assert np.max(np.abs(r - ref)) < 1e-12


# -- property: random interleavings never drop/duplicate/cross-wire ----------


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_sigs=st.integers(2, 4),
       max_k=st.integers(1, 6))
def test_random_interleavings_roundtrip(seed, n_sigs, max_k):
    """Random submit interleavings across 2-4 signatures with request K in
    1..max_k: every future resolves exactly once with *its own* payload's
    transform (seeded random_alm per request; any cross-wiring, drop or
    duplication shows up as a wrong result or an unresolved future)."""
    rng = np.random.default_rng(seed)
    transform.clear_plan_cache()
    eng = _engine(max_k=max_k, max_queue=256)
    lmaxes = [8, 12, 16, 20][:n_sigs]
    plans = {L: repro.make_plan("gl", l_max=L, K=1, dtype="float64",
                                mode="jnp") for L in lmaxes}
    jobs = []
    for rid in range(12):
        L = int(rng.choice(lmaxes))
        # the engine clamps max_k to a power of two; submits above the
        # effective cap are rejected, so draw against eng.max_k
        k = int(rng.integers(1, eng.max_k + 1))
        alm = np.asarray(sht.random_alm(seed=1000 + rid, l_max=L, m_max=L,
                                        K=k))
        if rng.integers(2) == 0:
            fut = eng.submit(direction="alm2map", payload=alm, grid="gl",
                             l_max=L)
            jobs.append(("alm2map", L, alm, fut))
        else:
            maps = np.asarray(plans[L].alm2map(alm[..., :1]))
            fut = eng.submit(direction="map2alm", payload=maps[..., 0],
                             grid="gl", l_max=L)
            jobs.append(("map2alm", L, alm[..., :1], fut))
        if rng.integers(3) == 0:                 # interleave partial drains
            eng.step()
    eng.drain()
    for direction, L, alm, fut in jobs:
        assert fut.done(), "request dropped"
        got = fut.result()
        if direction == "alm2map":
            ref = np.asarray(repro.make_plan(
                "gl", l_max=L, K=alm.shape[-1], dtype="float64",
                mode="jnp").alm2map(alm))
            assert np.max(np.abs(got - ref)) < 1e-12
        else:
            # recovery: analysing the synthesised map returns the payload
            err = spectra.d_err(alm[..., 0], got)
            assert err < 1e-10, err
    s = eng.stats()["requests"]
    assert s["completed"] == len(jobs) and s["pending"] == 0


# -- request object API -------------------------------------------------------


def test_submit_request_object_and_tag():
    eng = _engine()
    req = ShtRequest(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX, tag="mc-chain-7")
    fut = eng.submit(req)
    with pytest.raises(TypeError):               # object XOR keywords
        eng.submit(req, grid="gl")
    eng.drain()
    assert fut.done() and req.tag == "mc-chain-7"


# -- phase 2: K buckets, in-flight accounting, double buffering ---------------


class _StallPlan:
    """Proxy around a real plan whose synthesis blocks until released --
    makes the 'popped but not retired' in-flight window observable."""

    def __init__(self, plan, started, release):
        self._plan = plan
        self._started = started
        self._release = release

    def __getattr__(self, name):
        return getattr(self._plan, name)

    def alm2map(self, x):
        self._started.set()
        assert self._release.wait(30.0), "test forgot to release the batch"
        return self._plan.alm2map(x)


def _stall_pool(eng):
    """Wrap eng.pool.get so every served plan stalls in alm2map; returns
    the (started, release) events."""
    started, release = threading.Event(), threading.Event()
    real_get = eng.pool.get
    eng.pool.get = lambda sig, k: _StallPlan(real_get(sig, k), started,
                                             release)
    return started, release


def test_max_k_clamped_to_power_of_two_and_bucket_invariants():
    """K buckets are power-of-two by contract.  Historically max_k=6 with
    a 5-wide batch produced bucket 6 (min(8, 6)) -- a shape no pooled plan
    key space expects.  Now the engine clamps max_k itself to a power of
    two and every bucket is an admissible plan width."""
    eng = _engine(max_k=6)
    assert eng.max_k == 4 and eng.requested_max_k == 6
    for req_max in (1, 2, 3, 4, 5, 6, 7, 8, 12, 16):
        e = _engine(max_k=req_max)
        assert e.max_k & (e.max_k - 1) == 0          # power of two
        assert e.max_k <= req_max < 2 * e.max_k      # largest such
        for k in range(1, e.max_k + 1):
            b = e._k_bucket(k)
            assert b & (b - 1) == 0, (req_max, k, b)
            assert k <= b <= e.max_k
    # a request wider than the *effective* cap is rejected eagerly
    with pytest.raises(ValueError, match="max_k"):
        eng.submit(direction="alm2map", payload=_alm(seed=0, K=5),
                   grid="gl", l_max=LMAX)


def test_drain_waits_for_in_flight_batch():
    """Regression: drain() used to watch only the *queued* count, so with
    the background threads running it could return while a popped
    micro-batch was still executing -- leaving the caller holding an
    unresolved future after a 'complete' drain."""
    eng = _engine(max_k=2)
    started, release = _stall_pool(eng)
    with eng:
        fut = eng.submit(direction="alm2map", payload=_alm(seed=0),
                         grid="gl", l_max=LMAX)
        assert started.wait(30.0)                # popped, mid-execution
        assert eng.pending == 1                  # in-flight, not queued
        t = threading.Timer(0.05, release.set)
        t.start()
        eng.drain(timeout=30.0)
        assert fut.done(), "drain() returned with the batch in flight"
        t.join()
    assert fut.exception() is None
    assert fut.timing["compute_s"] > 0.0


def test_backpressure_counts_in_flight():
    """max_queue bounds engine *occupancy*: a request executing on the
    background threads still holds its slot, so submit() past the bound
    raises BackpressureError even though the queue proper is empty."""
    eng = _engine(max_k=1, max_queue=1)
    started, release = _stall_pool(eng)
    with eng:
        fut = eng.submit(direction="alm2map", payload=_alm(seed=0),
                         grid="gl", l_max=LMAX)
        assert started.wait(30.0)
        s = eng.stats()["requests"]
        assert s["queued"] == 0 and s["in_flight"] == 1 and s["pending"] == 1
        with pytest.raises(BackpressureError):
            eng.submit(direction="alm2map", payload=_alm(seed=1),
                       grid="gl", l_max=LMAX)
        release.set()
        eng.drain(timeout=30.0)
    assert fut.done() and fut.exception() is None
    late = eng.submit(direction="alm2map", payload=_alm(seed=2), grid="gl",
                      l_max=LMAX)                # slot freed by retirement
    eng.drain()
    assert late.exception() is None


# -- phase 2: WDRR fairness ---------------------------------------------------


def test_wdrr_minority_group_not_starved():
    """10+:1 hot:minority mix.  Oldest-head-wins served the hot group's
    whole backlog first; WDRR visits groups round-robin, so the minority
    signature's batch ships within the first scheduling rounds."""
    eng = _engine(max_k=2)
    hot = [eng.submit(direction="alm2map", payload=_alm(seed=i, l_max=8),
                      grid="gl", l_max=8) for i in range(12)]
    mino = eng.submit(direction="alm2map", payload=_alm(seed=99, l_max=12),
                      grid="gl", l_max=12)
    eng.drain()
    assert mino.exception() is None
    assert all(f.exception() is None for f in hot)
    mino_batches = [i for i, b in enumerate(eng.batch_log)
                    if "lmax12" in b["signature"]]
    assert mino_batches and mino_batches[0] <= 2, eng.batch_log


def test_wdrr_weight_throttles_group():
    """A weight-1/4 group earns a quarter of the K-unit deficit per round
    and must wait out extra rounds between its batches -- so the unit-
    weight group finishes well before the throttled hot group."""
    hot_label = "gl/lmax8/spin0/float64"
    eng = _engine(max_k=2, weights={hot_label: 0.25})
    assert eng.describe()["fairness"]["weights"][hot_label] == 0.25
    hot = [eng.submit(direction="alm2map", payload=_alm(seed=i, l_max=8),
                      grid="gl", l_max=8) for i in range(4)]
    mino = [eng.submit(direction="alm2map", payload=_alm(seed=50 + i,
                                                         l_max=12),
                       grid="gl", l_max=12) for i in range(4)]
    eng.drain()
    assert all(f.exception() is None for f in hot + mino)
    log = eng.batch_log
    last_mino = max(i for i, b in enumerate(log)
                    if "lmax12" in b["signature"])
    hot_before = sum(b["n_requests"] for b in log[:last_mino]
                     if "lmax8" in b["signature"])
    # by the time the minority stream finishes, the throttled hot group
    # has shipped at most half its backlog
    assert hot_before <= 2, log
    assert eng.stats()["fairness"]["policy"] == "wdrr"


# -- phase 2: roofline admission control --------------------------------------


def test_admission_tiny_target_caps_coalescing_at_k1():
    """An unachievable p99 target (1 ns) caps every batch at K=1 and
    flags the group infeasible -- service degrades to singles, never to
    refusal."""
    eng = _engine(max_k=4, p99_target_s=1e-9)
    futs = [eng.submit(direction="alm2map", payload=_alm(seed=i),
                       grid="gl", l_max=LMAX) for i in range(4)]
    eng.drain()
    assert all(f.exception() is None for f in futs)
    assert [b["k_plan"] for b in eng.batch_log] == [1, 1, 1, 1]
    adm = eng.stats()["admission"]
    assert adm["p99_target_s"] == 1e-9
    (group,) = adm["groups"].values()
    assert group["k_cap"] == 1 and group["feasible"] is False


def test_admission_generous_target_keeps_full_bucket_and_calibrates():
    """A 60 s p99 target admits the full max_k bucket, and every executed
    batch feeds the predicted-vs-measured calibration tracker."""
    eng = _engine(max_k=4, p99_target_s=60.0)
    futs = [eng.submit(direction="alm2map", payload=_alm(seed=i),
                       grid="gl", l_max=LMAX) for i in range(4)]
    eng.drain()
    assert all(f.exception() is None for f in futs)
    assert len(eng.batch_log) == 1 and eng.batch_log[0]["k_plan"] == 4
    adm = eng.stats()["admission"]
    (group,) = adm["groups"].values()
    assert group["k_cap"] == 4 and group["feasible"] is True
    cal = adm["calibration"]
    assert cal["count"] == 1
    assert np.isfinite(cal["ratio"]) and cal["ratio"] > 0.0
    assert "admission" in eng.report()


def test_engine_describe():
    eng = _engine(max_k=6, p99_target_s=0.5,
                  weights={"gl/lmax16/spin0/float64": 0.5})
    d = eng.describe()
    assert d["max_k"] == 4 and d["requested_max_k"] == 6
    assert d["states"] == ("queued", "in_flight", "retired")
    assert d["fairness"]["policy"] == "wdrr" and d["fairness"]["quantum_k"]
    assert d["admission"]["p99_target_s"] == 0.5
    assert d["pipeline"]["double_buffered"] is False
    assert d["pool"]["capacity"] == eng.pool.capacity
    with eng:
        d2 = eng.describe()
        assert d2["pipeline"]["double_buffered"] is True
        assert len(d2["pipeline"]["threads"]) == 2
    # admission verdicts appear per group after first sighting
    eng.submit(direction="alm2map", payload=_alm(seed=0), grid="gl",
               l_max=LMAX)
    eng.drain()
    (group,) = eng.describe()["admission"]["groups"].values()
    assert set(group) >= {"k_cap", "feasible", "predicted_s"}


def test_pool_concurrent_get_builds_once():
    """Racing get() calls for one key build the plan exactly once (the
    build happens outside the pool lock behind a per-key event)."""
    pool = PlanPool(4, mode="jnp")
    out, errs = [], []

    def worker():
        try:
            out.append(pool.get(PlanSig(grid="gl", l_max=8), 2))
        except Exception as e:                    # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(out) == 4 and len({id(p) for p in out}) == 1
    assert pool.misses == 1


# -- phase 2: threaded clients, exactly-once resolution -----------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_threaded_submissions_resolve_exactly_once(seed):
    """Several client threads submit mixed signatures against the live
    double-buffered engine; every future resolves exactly once with its
    own request's transform, and the in-flight accounting lands at zero."""
    transform.clear_plan_cache()
    lmaxes = [8, 12]
    refs = {L: repro.make_plan("gl", l_max=L, K=1, dtype="float64",
                               mode="jnp") for L in lmaxes}
    eng = _engine(max_k=4, max_queue=256)
    jobs, jlock = [], threading.Lock()

    def client(tid):
        rng = np.random.default_rng(seed * 17 + tid)
        for i in range(6):
            L = int(rng.choice(lmaxes))
            alm = np.asarray(sht.random_alm(
                seed=seed % 1000 + tid * 100 + i, l_max=L, m_max=L,
                K=1))[..., 0]
            fut = eng.submit(direction="alm2map", payload=alm, grid="gl",
                             l_max=L)
            with jlock:
                jobs.append((L, alm, fut))
            if rng.integers(2):
                time.sleep(0.001)

    with eng:
        clients = [threading.Thread(target=client, args=(t,))
                   for t in range(3)]
        for t in clients:
            t.start()
        for t in clients:
            t.join()
        eng.drain(timeout=120.0)

    assert len(jobs) == 18
    for L, alm, fut in jobs:
        assert fut.done(), "request dropped"
        ref = np.asarray(refs[L].alm2map(alm[..., None]))[..., 0]
        np.testing.assert_array_equal(fut.result(), ref)
    s = eng.stats()["requests"]
    assert s["completed"] == 18 and s["pending"] == 0
    assert s["queued"] == 0 and s["in_flight"] == 0
    with pytest.raises(InvalidStateError):       # write-once enforced
        jobs[0][2]._resolve(None)
