"""The SHT serving engine: K-coalescing correctness, signature grouping,
FIFO fairness, futures, percentile math, and the warm plan pool."""

import numpy as np
import pytest

import repro
from repro.core import cache as plancache
from repro.core import sht, spectra, transform
from repro.serve import (InvalidStateError, PlanPool, PlanSig, ShtEngine,
                         ShtFuture, ShtRequest, percentile)

from _hypothesis_compat import given, settings, strategies as st

LMAX = 16


@pytest.fixture(autouse=True)
def _fresh_caches():
    transform.clear_plan_cache()
    plancache.reset_stats()
    yield
    transform.clear_plan_cache()
    plancache.reset_stats()


def _alm(seed, l_max=LMAX, K=None, spin=0):
    fn = sht.random_alm_spin if spin else sht.random_alm
    a = np.asarray(fn(seed=seed, l_max=l_max, m_max=l_max, K=K or 1))
    return a if K else a[..., 0]


def _engine(**kw):
    kw.setdefault("max_k", 4)
    kw.setdefault("mode", "jnp")
    return ShtEngine(**kw)


# -- coalescing correctness ---------------------------------------------------


def test_coalesced_batch_matches_independent_plan_calls():
    """A K-stacked batch of mixed requests returns results identical to
    per-request Plan calls (synthesis bitwise on the f64 jnp path;
    analysis to 1e-12 -- the contraction order over K may differ)."""
    eng = _engine(max_k=4)
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    alms = [_alm(seed=i) for i in range(3)]
    maps = [np.asarray(plan.alm2map(a[..., None]))[..., 0] for a in alms]

    futs_s = [eng.submit(direction="alm2map", payload=a, grid="gl",
                         l_max=LMAX) for a in alms]
    futs_a = [eng.submit(direction="map2alm", payload=m, grid="gl",
                         l_max=LMAX) for m in maps]
    eng.drain()

    for f, ref in zip(futs_s, maps):
        np.testing.assert_array_equal(f.result(), ref)     # bit-identical
    for f, a in zip(futs_a, alms):
        ref = np.asarray(plan.map2alm(
            np.asarray(plan.alm2map(a[..., None]))))[..., 0]
        assert np.max(np.abs(f.result() - ref)) < 1e-12
    # the synthesis requests actually shared one device batch
    synth_batches = [b for b in eng.batch_log
                     if b["direction"] == "alm2map"]
    assert len(synth_batches) == 1
    assert synth_batches[0]["n_requests"] == 3


def test_coalesced_multi_k_and_spin2_requests():
    """Requests carrying their own K axis, and spin-2 (E,B)->(Q,U) pairs,
    coalesce and come back allclose to independent plans (f64 <= 1e-12)."""
    eng = _engine(max_k=8)
    a2 = _alm(seed=0, K=2)                       # (M, L, 2)
    a1 = _alm(seed=1)                            # (M, L)
    s2 = _alm(seed=2, spin=2)                    # (2, M, L)
    f2 = eng.submit(direction="alm2map", payload=a2, grid="gl", l_max=LMAX)
    f1 = eng.submit(direction="alm2map", payload=a1, grid="gl", l_max=LMAX)
    fs = eng.submit(direction="alm2map", payload=s2, grid="gl", l_max=LMAX,
                    spin=2)
    eng.drain()

    p2 = repro.make_plan("gl", l_max=LMAX, K=2, dtype="float64", mode="jnp")
    p1 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64", mode="jnp")
    ps = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64", mode="jnp",
                         spin=2)
    assert np.max(np.abs(f2.result() - np.asarray(p2.alm2map(a2)))) < 1e-12
    assert np.max(np.abs(f1.result()
                         - np.asarray(p1.alm2map(a1[..., None]))[..., 0])) \
        < 1e-12
    assert np.max(np.abs(fs.result()
                         - np.asarray(ps.alm2map(s2[..., None]))[..., 0])) \
        < 1e-12
    # scalar requests coalesced (K=2 + K=1 -> one batch); spin-2 separate
    scalar = [b for b in eng.batch_log if "spin0" in b["signature"]]
    assert len(scalar) == 1 and scalar[0]["k_total"] == 3
    assert scalar[0]["k_plan"] == 4              # padded to the K bucket


def test_no_cross_signature_mixing():
    """Different (grid, l_max, spin, dtype) signatures never share a
    device batch, even when submitted interleaved."""
    eng = _engine(max_k=8)
    for i in range(3):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
        eng.submit(direction="alm2map", payload=_alm(seed=10 + i, l_max=24),
                   grid="gl", l_max=24)
    eng.drain()
    assert len(eng.batch_log) == 2
    for b in eng.batch_log:
        assert b["n_requests"] == 3              # each group fully coalesced
    assert {b["signature"] for b in eng.batch_log} == \
        {"gl/lmax16/spin0/float64", "gl/lmax24/spin0/float64"}


def test_direction_and_iters_split_groups():
    """alm2map vs map2alm, and differing Jacobi iters, are separate
    groups -- they cannot share one device call."""
    eng = _engine(max_k=8)
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    m = np.asarray(plan.alm2map(_alm(seed=0)[..., None]))[..., 0]
    eng.submit(direction="alm2map", payload=_alm(seed=1), grid="gl",
               l_max=LMAX)
    eng.submit(direction="map2alm", payload=m, grid="gl", l_max=LMAX)
    eng.submit(direction="map2alm", payload=m, grid="gl", l_max=LMAX,
               iters=1)
    eng.drain()
    assert len(eng.batch_log) == 3


def test_fifo_within_signature():
    """Requests of one signature retire in submission order, across
    however many micro-batches the max_k budget forces."""
    eng = _engine(max_k=2)
    futs = [eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                       l_max=LMAX) for i in range(5)]
    eng.drain()
    rids = [rid for b in eng.batch_log for rid in b["rids"]]
    assert rids == [f.rid for f in futs]         # strict FIFO
    assert [b["n_requests"] for b in eng.batch_log] == [2, 2, 1]


def test_oldest_request_picks_next_group():
    """Across signatures the batch former serves the group whose head
    waited longest (no starvation of a low-traffic signature)."""
    eng = _engine(max_k=8)
    f_old = eng.submit(direction="alm2map", payload=_alm(seed=0, l_max=24),
                       grid="gl", l_max=24)
    for i in range(3):
        eng.submit(direction="alm2map", payload=_alm(seed=1 + i), grid="gl",
                   l_max=LMAX)
    assert eng.step() > 0
    assert f_old.done()                          # oldest head went first


# -- futures ------------------------------------------------------------------


def test_futures_resolve_exactly_once():
    eng = _engine()
    fut = eng.submit(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX)
    eng.drain()
    assert fut.done()
    r1 = fut.result()
    assert r1 is fut.result()                    # cached, not recomputed
    with pytest.raises(InvalidStateError):
        fut._resolve(None)
    with pytest.raises(InvalidStateError):
        fut._fail(RuntimeError("x"))
    f = ShtFuture(rid=99)
    f._resolve(1)
    with pytest.raises(InvalidStateError):
        f._resolve(2)


def test_future_timing_populated():
    eng = _engine()
    fut = eng.submit(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX)
    eng.drain()
    t = fut.timing
    assert t["total_s"] >= t["compute_s"] >= 0
    assert t["queue_s"] >= 0
    assert t["k_plan"] == 1 and t["coalesced_with"] == 0


def test_submit_validation_is_eager():
    eng = _engine()
    with pytest.raises(ValueError):              # bad direction
        eng.submit(direction="sideways", payload=_alm(seed=0))
    with pytest.raises(ValueError):              # real payload for alm2map
        eng.submit(direction="alm2map", payload=np.zeros((17, 17)))
    with pytest.raises(ValueError):              # complex maps payload
        eng.submit(direction="map2alm",
                   payload=np.zeros((17, 34), complex))
    with pytest.raises(ValueError):              # ndim mismatch for spin
        eng.submit(direction="alm2map", payload=_alm(seed=0), spin=2)
    with pytest.raises(ValueError):              # K wider than the engine
        eng.submit(direction="alm2map", payload=_alm(seed=0, K=9),
                   grid="gl", l_max=LMAX)
    assert eng.pending == 0                      # nothing leaked into queue


# -- stats() ------------------------------------------------------------------


def test_percentile_pinned_against_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 101):
        xs = rng.exponential(size=n).tolist()
        for q in (0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0):
            np.testing.assert_allclose(percentile(xs, q),
                                       np.percentile(xs, q), rtol=1e-12)
    assert np.isnan(percentile([], 50.0))


def test_stats_shape_and_counters():
    eng = _engine(max_k=4)
    for i in range(4):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    s = eng.stats()
    assert s["requests"]["submitted"] == 4
    assert s["requests"]["completed"] == 4
    assert s["requests"]["pending"] == 0
    assert s["coalescing"]["batches"] == 1
    assert s["coalescing"]["k_per_batch"] == 4.0
    assert s["coalescing"]["k_occupancy"] == 1.0
    lat = s["latency"]["total"]
    assert lat["count"] == 4
    assert lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"] <= lat["max_s"]
    assert np.isfinite(s["throughput_rps"]) and s["throughput_rps"] > 0
    r = eng.report()
    assert "p99" in r and "coalescing" in r and "pool" in r


def test_stats_percentiles_match_numpy_over_recorded_latencies():
    eng = _engine(max_k=1)                       # one batch per request
    for i in range(5):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    xs = eng._lat_total.samples()
    assert len(xs) == 5
    s = eng.stats()["latency"]["total"]
    np.testing.assert_allclose(s["p50_s"], np.percentile(xs, 50))
    np.testing.assert_allclose(s["p95_s"], np.percentile(xs, 95))
    np.testing.assert_allclose(s["p99_s"], np.percentile(xs, 99))


# -- warm plan pool -----------------------------------------------------------


def test_pool_hits_and_warmup():
    eng = _engine(max_k=2)
    eng.prewarm(grid="gl", l_max=LMAX, dtype="float64")
    assert eng.pool.stats()["warmups"] == 1
    for i in range(4):
        eng.submit(direction="alm2map", payload=_alm(seed=i), grid="gl",
                   l_max=LMAX)
    eng.drain()
    p = eng.pool.stats()
    # prewarm built the (sig, max_k=2) plan; both batches then hit it
    assert p["misses"] == 1 and p["hits"] == 2
    assert eng.stats()["pool"]["hit_rate"] == pytest.approx(2 / 3)
    # fused-pipeline coverage of the warm set: the gl plan is eligible
    f = p["fusion"]
    assert f["eligible"] == 1 and f["staged"] == 0
    assert f["active"] in (0, 1)        # autotune decides the dispatch


def test_pool_lru_eviction_releases_plans():
    pool = PlanPool(capacity=2, mode="jnp")
    sigs = [PlanSig(grid="gl", l_max=8 * (i + 1), dtype="float64")
            for i in range(3)]
    plans = [pool.get(s, 1) for s in sigs]
    assert pool.stats()["evictions"] == 1
    assert len(pool) == 2
    # the evicted plan is also gone from make_plan's memoisation...
    key0 = plans[0]._signature_key
    assert key0 not in transform._PLANS
    # ...while the survivors are still memoised
    assert plans[2]._signature_key in transform._PLANS
    # re-requesting the evicted signature rebuilds (a miss, not a hit)
    misses = pool.stats()["misses"]
    pool.get(sigs[0], 1)
    assert pool.stats()["misses"] == misses + 1


def test_background_thread_serves():
    eng = _engine(max_k=4)
    with eng:
        futs = [eng.submit(direction="alm2map", payload=_alm(seed=i),
                           grid="gl", l_max=LMAX) for i in range(3)]
        res = [f.result(timeout=120) for f in futs]
    plan = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float64",
                           mode="jnp")
    for a, r in zip([_alm(seed=i) for i in range(3)], res):
        ref = np.asarray(plan.alm2map(a[..., None]))[..., 0]
        assert np.max(np.abs(r - ref)) < 1e-12


# -- property: random interleavings never drop/duplicate/cross-wire ----------


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_sigs=st.integers(2, 4),
       max_k=st.integers(1, 6))
def test_random_interleavings_roundtrip(seed, n_sigs, max_k):
    """Random submit interleavings across 2-4 signatures with request K in
    1..max_k: every future resolves exactly once with *its own* payload's
    transform (seeded random_alm per request; any cross-wiring, drop or
    duplication shows up as a wrong result or an unresolved future)."""
    rng = np.random.default_rng(seed)
    transform.clear_plan_cache()
    eng = _engine(max_k=max_k, max_queue=256)
    lmaxes = [8, 12, 16, 20][:n_sigs]
    plans = {L: repro.make_plan("gl", l_max=L, K=1, dtype="float64",
                                mode="jnp") for L in lmaxes}
    jobs = []
    for rid in range(12):
        L = int(rng.choice(lmaxes))
        k = int(rng.integers(1, max_k + 1))
        alm = np.asarray(sht.random_alm(seed=1000 + rid, l_max=L, m_max=L,
                                        K=k))
        if rng.integers(2) == 0:
            fut = eng.submit(direction="alm2map", payload=alm, grid="gl",
                             l_max=L)
            jobs.append(("alm2map", L, alm, fut))
        else:
            maps = np.asarray(plans[L].alm2map(alm[..., :1]))
            fut = eng.submit(direction="map2alm", payload=maps[..., 0],
                             grid="gl", l_max=L)
            jobs.append(("map2alm", L, alm[..., :1], fut))
        if rng.integers(3) == 0:                 # interleave partial drains
            eng.step()
    eng.drain()
    for direction, L, alm, fut in jobs:
        assert fut.done(), "request dropped"
        got = fut.result()
        if direction == "alm2map":
            ref = np.asarray(repro.make_plan(
                "gl", l_max=L, K=alm.shape[-1], dtype="float64",
                mode="jnp").alm2map(alm))
            assert np.max(np.abs(got - ref)) < 1e-12
        else:
            # recovery: analysing the synthesised map returns the payload
            err = spectra.d_err(alm[..., 0], got)
            assert err < 1e-10, err
    s = eng.stats()["requests"]
    assert s["completed"] == len(jobs) and s["pending"] == 0


# -- request object API -------------------------------------------------------


def test_submit_request_object_and_tag():
    eng = _engine()
    req = ShtRequest(direction="alm2map", payload=_alm(seed=0), grid="gl",
                     l_max=LMAX, tag="mc-chain-7")
    fut = eng.submit(req)
    with pytest.raises(TypeError):               # object XOR keywords
        eng.submit(req, grid="gl")
    eng.drain()
    assert fut.done() and req.tag == "mc-chain-7"
